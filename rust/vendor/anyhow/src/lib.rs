//! Offline in-tree stand-in for the `anyhow` crate.
//!
//! The build image has no registry access, so this shim provides the
//! subset of anyhow the workspace actually uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] macros, and the [`Context`] extension
//! trait for `Result` and `Option`. Semantics match the real crate where
//! it matters here:
//!
//! * `Error` does **not** implement `std::error::Error`, so the blanket
//!   `From<E: std::error::Error>` conversion coexists with the reflexive
//!   `From<Error>` used by `?`.
//! * `Display` shows the outermost message; `{:#}` (alternate) shows the
//!   whole context chain `outer: ...: root`, like anyhow's `{:#}`.

use std::error::Error as StdError;
use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a root message (or wrapped `std::error::Error`) plus
/// a stack of human-readable context layers.
pub struct Error {
    /// Rendered root cause.
    msg: String,
    /// The wrapped source error, when constructed via `From`.
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
    /// Context layers, innermost first (pushed outward).
    context: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None, context: Vec::new() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.context.push(context.to_string());
        self
    }

    /// The root-cause message.
    pub fn root_cause_msg(&self) -> &str {
        &self.msg
    }

    /// Reference to the wrapped source error, if any.
    pub fn source(&self) -> Option<&(dyn StdError + Send + Sync + 'static)> {
        self.source.as_deref()
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for ctx in self.context.iter().rev() {
            write!(f, "{ctx}: ")?;
        }
        write!(f, "{}", self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.write_chain(f)
        } else {
            match self.context.last() {
                Some(outer) => write!(f, "{outer}"),
                None => write!(f, "{}", self.msg),
            }
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        self.write_chain(f)
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)), context: Vec::new() }
    }
}

/// Context extension for `Result` and `Option`, mirroring anyhow's.
pub trait Context<T, E> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error value with lazily evaluated context.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or any displayable).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_formats() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let n = 3;
        let e = anyhow!("value {} and {n}", 7);
        assert_eq!(e.to_string(), "value 7 and 3");
    }

    #[test]
    fn from_std_error() {
        let e: Error = io_err().into();
        assert!(e.to_string().contains("gone"));
        assert!(e.source().is_some());
    }

    #[test]
    fn context_chain_renders_alternate() {
        let e: Result<(), Error> = Err(io_err().into());
        let e = e.context("reading file").unwrap_err();
        assert_eq!(format!("{e}"), "reading file");
        assert_eq!(format!("{e:#}"), "reading file: gone");
    }

    #[test]
    fn with_context_on_option() {
        let v: Option<u32> = None;
        let e = v.with_context(|| "missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
        let v: Option<u32> = Some(5);
        assert_eq!(v.context("unused").unwrap(), 5);
    }

    #[test]
    fn bail_returns_early() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 1);
            }
            Ok(9)
        }
        assert_eq!(f(false).unwrap(), 9);
        assert_eq!(f(true).unwrap_err().to_string(), "boom 1");
    }

    #[test]
    fn question_mark_interop() {
        fn io() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        fn nested() -> Result<()> {
            io()?;
            Ok(())
        }
        assert!(nested().is_err());
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
