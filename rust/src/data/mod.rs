//! Workload generators for every experiment in the paper's evaluation.
//!
//! The paper's workloads: uniform random integers (figs. 14–15), skewed /
//! duplicate-heavy data (§4.1), key-value records with duplicate keys
//! (§6 tie-record), and pre-sorted sublists feeding the mergers. All
//! generators are deterministic in the seed.

use crate::key::{Item, Kv, Kv64};
use crate::util::rng::Rng;

/// Data distribution shapes used across benches and tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Distribution {
    /// Uniform random over the full key range (paper's default).
    Uniform,
    /// Small alphabet — duplicate-heavy, the §4.1 skew stressor.
    DupHeavy { alphabet: u32 },
    /// Zipf-ish: rank-skewed draws, the classic database skew model.
    Zipf { s_x100: u32, n_ranks: u32 },
    /// Already sorted ascending (adversarial for descending mergers).
    SortedAsc,
    /// Already sorted descending (best case).
    SortedDesc,
    /// Sawtooth runs of the given length.
    Runs { run: u32 },
    /// All elements equal — the degenerate skew extreme.
    Constant,
}

impl Distribution {
    pub fn name(&self) -> String {
        match self {
            Distribution::Uniform => "uniform".into(),
            Distribution::DupHeavy { alphabet } => format!("dup{alphabet}"),
            Distribution::Zipf { s_x100, n_ranks } => {
                format!("zipf{}_{}", s_x100, n_ranks)
            }
            Distribution::SortedAsc => "sorted_asc".into(),
            Distribution::SortedDesc => "sorted_desc".into(),
            Distribution::Runs { run } => format!("runs{run}"),
            Distribution::Constant => "constant".into(),
        }
    }
}

/// Generate `n` u32 keys from the distribution.
pub fn gen_u32(rng: &mut Rng, n: usize, dist: Distribution) -> Vec<u32> {
    match dist {
        Distribution::Uniform => (0..n).map(|_| rng.next_u32()).collect(),
        Distribution::DupHeavy { alphabet } => {
            (0..n).map(|_| rng.below(alphabet as u64) as u32).collect()
        }
        Distribution::Zipf { s_x100, n_ranks } => {
            let zipf = ZipfSampler::new(n_ranks as usize, s_x100 as f64 / 100.0);
            (0..n).map(|_| zipf.sample(rng)).collect()
        }
        Distribution::SortedAsc => {
            let mut v: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            v.sort_unstable();
            v
        }
        Distribution::SortedDesc => {
            let mut v: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            v.sort_unstable_by(|a, b| b.cmp(a));
            v
        }
        Distribution::Runs { run } => {
            let mut v = Vec::with_capacity(n);
            while v.len() < n {
                let len = (run as usize).min(n - v.len());
                let mut chunk: Vec<u32> = (0..len).map(|_| rng.next_u32()).collect();
                chunk.sort_unstable_by(|a, b| b.cmp(a));
                v.extend(chunk);
            }
            v
        }
        Distribution::Constant => vec![0xC0FFEE; n],
    }
}

/// Generate `n` u64 keys (uniform only needs widening; others map through
/// the u32 generator to keep distributions identical across widths).
pub fn gen_u64(rng: &mut Rng, n: usize, dist: Distribution) -> Vec<u64> {
    match dist {
        Distribution::Uniform => (0..n).map(|_| rng.next_u64()).collect(),
        _ => gen_u32(rng, n, dist).into_iter().map(u64::from).collect(),
    }
}

/// Generate `n` i32 keys: the u32 draws mapped through the inverse
/// sign-flip bias, so uniform covers the full signed range (negative
/// and positive halves equally) and skewed distributions keep their
/// shape around the low end of the signed line.
pub fn gen_i32(rng: &mut Rng, n: usize, dist: Distribution) -> Vec<i32> {
    gen_u32(rng, n, dist).into_iter().map(|x| (x ^ 0x8000_0000) as i32).collect()
}

/// Generate `n` i64 keys (see [`gen_i32`]).
pub fn gen_i64(rng: &mut Rng, n: usize, dist: Distribution) -> Vec<i64> {
    gen_u64(rng, n, dist).into_iter().map(|x| (x ^ (1 << 63)) as i64).collect()
}

/// Key-value records with payload = original index, so payload integrity
/// and stable order are checkable after any merge/sort.
pub fn gen_kv(rng: &mut Rng, n: usize, dist: Distribution) -> Vec<Kv> {
    gen_u32(rng, n, dist)
        .into_iter()
        .enumerate()
        .map(|(i, key)| Kv::new(key, i as u32))
        .collect()
}

/// Wide key-value records with payload = original index (see [`gen_kv`]).
pub fn gen_kv64(rng: &mut Rng, n: usize, dist: Distribution) -> Vec<Kv64> {
    gen_u64(rng, n, dist)
        .into_iter()
        .enumerate()
        .map(|(i, key)| Kv64 { key, val: i as u64 })
        .collect()
}

/// A pair of descending-sorted lists for 2-way merger inputs.
pub fn gen_sorted_pair<T, F>(rng: &mut Rng, n_a: usize, n_b: usize, dist: Distribution, gen: F) -> (Vec<T>, Vec<T>)
where
    T: Item,
    F: Fn(&mut Rng, usize, Distribution) -> Vec<T>,
{
    let mut a = gen(rng, n_a, dist);
    let mut b = gen(rng, n_b, dist);
    sort_desc(&mut a);
    sort_desc(&mut b);
    (a, b)
}

/// `k` descending-sorted lists (merge-tree leaves).
pub fn gen_sorted_lists(rng: &mut Rng, k: usize, each: usize, dist: Distribution) -> Vec<Vec<u32>> {
    (0..k)
        .map(|_| {
            let mut v = gen_u32(rng, each, dist);
            v.sort_unstable_by(|a, b| b.cmp(a));
            v
        })
        .collect()
}

/// Descending stable sort by key (test oracle ordering).
pub fn sort_desc<T: Item>(xs: &mut [T]) {
    xs.sort_by(|a, b| b.key().cmp(&a.key()));
}

/// Zipf sampler over ranks 1..=n with exponent s (inverse-CDF on a
/// precomputed table; exact, no rejection).
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n_ranks: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n_ranks);
        let mut acc = 0.0;
        for k in 1..=n_ranks {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap_or(&1.0);
        for c in &mut cdf {
            *c /= total;
        }
        ZipfSampler { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> u32 {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) | Err(i) => i.min(self.cdf.len() - 1) as u32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::key::is_sorted_desc;

    #[test]
    fn uniform_deterministic() {
        let a = gen_u32(&mut Rng::new(1), 100, Distribution::Uniform);
        let b = gen_u32(&mut Rng::new(1), 100, Distribution::Uniform);
        assert_eq!(a, b);
    }

    #[test]
    fn dup_heavy_respects_alphabet() {
        let v = gen_u32(&mut Rng::new(2), 1000, Distribution::DupHeavy { alphabet: 4 });
        assert!(v.iter().all(|&x| x < 4));
        // All four symbols should appear in 1000 draws.
        for s in 0..4 {
            assert!(v.contains(&s), "symbol {s} missing");
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let v = gen_u32(
            &mut Rng::new(3),
            10_000,
            Distribution::Zipf { s_x100: 120, n_ranks: 1000 },
        );
        let top = v.iter().filter(|&&x| x == 0).count();
        let tail = v.iter().filter(|&&x| x == 999).count();
        assert!(top > tail * 5, "rank 0: {top}, rank 999: {tail}");
    }

    #[test]
    fn sorted_variants_sorted() {
        let asc = gen_u32(&mut Rng::new(4), 500, Distribution::SortedAsc);
        assert!(asc.windows(2).all(|w| w[0] <= w[1]));
        let desc = gen_u32(&mut Rng::new(4), 500, Distribution::SortedDesc);
        assert!(is_sorted_desc(&desc));
    }

    #[test]
    fn runs_have_descending_runs() {
        let v = gen_u32(&mut Rng::new(5), 64, Distribution::Runs { run: 16 });
        for c in v.chunks(16) {
            assert!(is_sorted_desc(c));
        }
    }

    #[test]
    fn signed_generators_cover_both_signs() {
        let v = gen_i32(&mut Rng::new(9), 1000, Distribution::Uniform);
        assert!(v.iter().any(|&x| x < 0) && v.iter().any(|&x| x >= 0));
        let v = gen_i64(&mut Rng::new(9), 1000, Distribution::Uniform);
        assert!(v.iter().any(|&x| x < 0) && v.iter().any(|&x| x >= 0));
        let kv = gen_kv64(&mut Rng::new(10), 50, Distribution::Uniform);
        for (i, r) in kv.iter().enumerate() {
            assert_eq!(r.val, i as u64);
        }
    }

    #[test]
    fn kv_payload_is_index() {
        let v = gen_kv(&mut Rng::new(6), 50, Distribution::Uniform);
        for (i, kv) in v.iter().enumerate() {
            assert_eq!(kv.val, i as u32);
        }
    }

    #[test]
    fn sorted_pair_is_sorted() {
        let (a, b) = gen_sorted_pair(&mut Rng::new(7), 64, 32, Distribution::Uniform, gen_u32);
        assert!(is_sorted_desc(&a) && is_sorted_desc(&b));
        assert_eq!(a.len(), 64);
        assert_eq!(b.len(), 32);
    }

    #[test]
    fn sorted_lists_shape() {
        let ls = gen_sorted_lists(&mut Rng::new(8), 8, 100, Distribution::Uniform);
        assert_eq!(ls.len(), 8);
        assert!(ls.iter().all(|l| l.len() == 100 && is_sorted_desc(l)));
    }
}
