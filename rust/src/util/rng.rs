//! Deterministic PRNG (splitmix64 seeded xoshiro256**) for workload
//! generation and property tests. No external crates; reproducible across
//! runs so every experiment in EXPERIMENTS.md is replayable.

/// xoshiro256** — fast, high-quality, and tiny. Seeded via splitmix64 so
/// any u64 seed produces a well-mixed state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, n)` (Lemire's multiply-shift; fine for workloads).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard-ish normal via Irwin–Hall (sum of 12 uniforms) — plenty
    /// for workload shaping.
    pub fn normal(&mut self) -> f64 {
        (0..12).map(|_| self.f64()).sum::<f64>() - 6.0
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut r = Rng::new(3);
        let mut buckets = [0usize; 10];
        for _ in 0..10_000 {
            buckets[r.below(10) as usize] += 1;
        }
        for &b in &buckets {
            assert!((700..1300).contains(&b), "bucket {b}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
