//! Minimal criterion-style micro-benchmark harness (criterion itself is
//! not available offline). Warms up, runs timed batches until a wall
//! budget is exhausted, and reports median / mean / min with MAD spread.
//!
//! Every `rust/benches/*.rs` target (declared `harness = false`) uses
//! this; `cargo bench` therefore prints the paper-table rows directly.
//! Each bench also accepts `--json <path>` (write a machine-readable
//! `BENCH_<name>.json` trajectory via [`write_json_report`] — schema in
//! docs/OBSERVABILITY.md) and `--smoke` (shrunken workloads, no perf
//! assertions: the CI smoke lane), parsed leniently by [`BenchArgs`].

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// nanoseconds per iteration
    pub median_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
    /// median absolute deviation, ns
    pub mad_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    /// Throughput in items per second given items processed per iteration.
    pub fn items_per_sec(&self, items_per_iter: usize) -> f64 {
        items_per_iter as f64 / (self.median_ns * 1e-9)
    }

    /// Throughput in M items/s.
    pub fn mitems_per_sec(&self, items_per_iter: usize) -> f64 {
        self.items_per_sec(items_per_iter) / 1e6
    }

    /// A result from one timed run (the macro-benchmarks: whole external
    /// sorts are seconds long, so they run once per cell rather than in
    /// [`bench`] batches). median = mean = min = the single sample.
    pub fn single(name: &str, elapsed: Duration) -> BenchResult {
        let ns = elapsed.as_nanos() as f64;
        BenchResult {
            name: name.to_string(),
            median_ns: ns,
            mean_ns: ns,
            min_ns: ns,
            mad_ns: 0.0,
            iters: 1,
        }
    }

    /// This result as one JSON object (the `results[]` rows of
    /// [`write_json_report`]).
    pub fn json_row(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"median_ns\":{:.1},\"mean_ns\":{:.1},\"min_ns\":{:.1},\
             \"mad_ns\":{:.1},\"iters\":{}}}",
            json_escape(&self.name),
            self.median_ns,
            self.mean_ns,
            self.min_ns,
            self.mad_ns,
            self.iters
        )
    }
}

/// Escape a string for embedding in a JSON string literal.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Write the machine-readable bench trajectory:
/// `{"bench":"<name>","schema":1,"results":[{...}, …]}` (one object per
/// [`BenchResult`], field-for-field — the schema is documented in
/// docs/OBSERVABILITY.md and consumed by the CI `bench-smoke` artifact).
pub fn write_json_report(bench: &str, results: &[BenchResult], path: &Path) -> std::io::Result<()> {
    let mut out = format!("{{\"bench\":\"{}\",\"schema\":1,\"results\":[", json_escape(bench));
    for (i, r) in results.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push('\n');
        out.push_str(&r.json_row());
    }
    out.push_str("\n]}\n");
    std::fs::write(path, out)
}

/// Bench command-line options, parsed leniently: `cargo bench` forwards
/// its own flags (`--bench`, the bench name) to `harness = false`
/// targets, so anything unrecognised is ignored rather than an error.
#[derive(Clone, Debug, Default)]
pub struct BenchArgs {
    /// `--json <path>`: where to write the [`write_json_report`] file.
    pub json: Option<PathBuf>,
    /// `--smoke`: shrink the workload and skip the perf assertions (the
    /// CI smoke lane exercises the reporting path, not the numbers).
    pub smoke: bool,
}

impl BenchArgs {
    /// Parse the process's arguments (see [`BenchArgs`]).
    pub fn parse() -> BenchArgs {
        Self::from_iter(std::env::args().skip(1))
    }

    fn from_iter<I: IntoIterator<Item = String>>(args: I) -> BenchArgs {
        let mut out = BenchArgs::default();
        let mut args = args.into_iter();
        while let Some(a) = args.next() {
            match a.as_str() {
                "--json" => {
                    if let Some(path) = args.next() {
                        out.json = Some(PathBuf::from(path));
                    }
                }
                "--smoke" => out.smoke = true,
                _ => {} // cargo's own flags, the bench-name filter, etc.
            }
        }
        out
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Benchmark `f`, returning per-iteration statistics.
///
/// `f` is run once for warmup, then in sample batches sized so each batch
/// takes ≥ ~2ms, until `budget` elapses (or ≥ 15 samples collected).
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + batch sizing.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as u64;
    let batch = (2_000_000 / once).clamp(1, 1_000_000);

    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    let mut iters: u64 = 0;
    while (start.elapsed() < budget || samples.len() < 15) && samples.len() < 2000 {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t.elapsed().as_nanos() as f64 / batch as f64;
        samples.push(dt);
        iters += batch;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples[0];
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    BenchResult {
        name: name.to_string(),
        median_ns: median,
        mean_ns: mean,
        min_ns: min,
        mad_ns: mad,
        iters,
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let mut x = 0u64;
        let r = bench("noop-ish", Duration::from_millis(20), || {
            x = black_box(x.wrapping_add(1));
        });
        assert!(r.median_ns > 0.0);
        assert!(r.min_ns <= r.median_ns * 1.5 + 1.0);
        assert!(r.iters > 0);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "t".into(),
            median_ns: 1000.0,
            mean_ns: 1000.0,
            min_ns: 1000.0,
            mad_ns: 0.0,
            iters: 1,
        };
        // 1000 items in 1µs = 1e9 items/s
        assert!((r.items_per_sec(1000) - 1e9).abs() < 1.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("µs"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }

    #[test]
    fn single_sample_result() {
        let r = BenchResult::single("one", Duration::from_micros(3));
        assert_eq!(r.median_ns, 3000.0);
        assert_eq!(r.mean_ns, 3000.0);
        assert_eq!(r.min_ns, 3000.0);
        assert_eq!(r.mad_ns, 0.0);
        assert_eq!(r.iters, 1);
    }

    #[test]
    fn json_row_shape_and_escaping() {
        let r = BenchResult::single("a \"quoted\"\\name", Duration::from_nanos(1500));
        let row = r.json_row();
        assert_eq!(
            row,
            "{\"name\":\"a \\\"quoted\\\"\\\\name\",\"median_ns\":1500.0,\
             \"mean_ns\":1500.0,\"min_ns\":1500.0,\"mad_ns\":0.0,\"iters\":1}"
        );
    }

    #[test]
    fn json_report_round_trips_through_a_file() {
        let dir = std::env::temp_dir().join(format!("flims-bench-json-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let rows = [
            BenchResult::single("row_a", Duration::from_micros(10)),
            BenchResult::single("row_b", Duration::from_micros(20)),
        ];
        write_json_report("test", &rows, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("{\"bench\":\"test\",\"schema\":1,\"results\":["), "{text}");
        assert!(text.contains("\"name\":\"row_a\""), "{text}");
        assert!(text.contains("\"name\":\"row_b\""), "{text}");
        assert!(text.trim_end().ends_with("]}"), "{text}");
        // Exactly one comma between the two rows, none trailing.
        assert_eq!(text.matches("},\n{").count(), 1, "{text}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bench_args_parse_leniently() {
        let args = |v: &[&str]| BenchArgs::from_iter(v.iter().map(|s| s.to_string()));
        let a = args(&["--bench", "merge_hot_path", "--json", "out.json", "--smoke"]);
        assert_eq!(a.json, Some(PathBuf::from("out.json")));
        assert!(a.smoke);
        // cargo's stray flags and a missing --json value are ignored.
        let a = args(&["--exact", "somefilter", "--json"]);
        assert_eq!(a.json, None);
        assert!(!a.smoke);
    }
}
