//! Minimal criterion-style micro-benchmark harness (criterion itself is
//! not available offline). Warms up, runs timed batches until a wall
//! budget is exhausted, and reports median / mean / min with MAD spread.
//!
//! Every `rust/benches/*.rs` target (declared `harness = false`) uses
//! this; `cargo bench` therefore prints the paper-table rows directly.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// nanoseconds per iteration
    pub median_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
    /// median absolute deviation, ns
    pub mad_ns: f64,
    pub iters: u64,
}

impl BenchResult {
    /// Throughput in items per second given items processed per iteration.
    pub fn items_per_sec(&self, items_per_iter: usize) -> f64 {
        items_per_iter as f64 / (self.median_ns * 1e-9)
    }

    /// Throughput in M items/s.
    pub fn mitems_per_sec(&self, items_per_iter: usize) -> f64 {
        self.items_per_sec(items_per_iter) / 1e6
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Benchmark `f`, returning per-iteration statistics.
///
/// `f` is run once for warmup, then in sample batches sized so each batch
/// takes ≥ ~2ms, until `budget` elapses (or ≥ 15 samples collected).
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + batch sizing.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as u64;
    let batch = (2_000_000 / once).clamp(1, 1_000_000);

    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    let mut iters: u64 = 0;
    while (start.elapsed() < budget || samples.len() < 15) && samples.len() < 2000 {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t.elapsed().as_nanos() as f64 / batch as f64;
        samples.push(dt);
        iters += batch;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples[0];
    let mut devs: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
    devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mad = devs[devs.len() / 2];
    BenchResult {
        name: name.to_string(),
        median_ns: median,
        mean_ns: mean,
        min_ns: min,
        mad_ns: mad,
        iters,
    }
}

/// Format nanoseconds human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let mut x = 0u64;
        let r = bench("noop-ish", Duration::from_millis(20), || {
            x = black_box(x.wrapping_add(1));
        });
        assert!(r.median_ns > 0.0);
        assert!(r.min_ns <= r.median_ns * 1.5 + 1.0);
        assert!(r.iters > 0);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "t".into(),
            median_ns: 1000.0,
            mean_ns: 1000.0,
            min_ns: 1000.0,
            mad_ns: 0.0,
            iters: 1,
        };
        // 1000 items in 1µs = 1e9 items/s
        assert!((r.items_per_sec(1000) - 1e9).abs() < 1.0);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5e3).ends_with("µs"));
        assert!(fmt_ns(5e6).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
