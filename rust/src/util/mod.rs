//! In-tree utilities replacing unavailable external crates (the build is
//! fully offline; see Cargo.toml): deterministic RNG, a criterion-style
//! micro-benchmark harness, and a lightweight property-testing helper.

pub mod bench;
pub mod prop;
pub mod rng;

pub use bench::{bench, BenchResult};
pub use rng::Rng;
