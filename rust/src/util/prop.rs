//! Lightweight property-testing helper (proptest is not available
//! offline). Runs a property over many seeded random cases and, on
//! failure, retries with progressively smaller size parameters to report
//! a small counterexample.

use super::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    /// maximum "size" hint passed to the generator
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 256,
            seed: 0xF11A5,
            max_size: 256,
        }
    }
}

/// Run `prop(rng, size)` for `cfg.cases` seeded cases with sizes ramping
/// up from tiny to `cfg.max_size`. On failure, re-runs smaller sizes with
/// the failing seed to find a reduced case, then panics with both.
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        // Ramp sizes: early cases small, later cases up to max.
        let size = 1 + (cfg.max_size - 1) * case / cfg.cases.max(1);
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng, size) {
            // Shrink: try the same seed at smaller sizes.
            let mut best = (size, msg.clone());
            for s in 1..size {
                let mut r2 = Rng::new(case_seed);
                if let Err(m2) = prop(&mut r2, s) {
                    best = (s, m2);
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {case_seed:#x}, \
                 size {size}; smallest reproduced size {}): {}",
                best.0, best.1
            );
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", Config { cases: 50, ..Default::default() }, |rng, _| {
            let (a, b) = (rng.next_u32() as u64, rng.next_u32() as u64);
            if a + b == b + a {
                Ok(())
            } else {
                Err("math broke".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_context() {
        check(
            "always-fails",
            Config { cases: 5, ..Default::default() },
            |_, _| Err("nope".into()),
        );
    }

    #[test]
    fn sizes_ramp_within_bounds() {
        let mut seen_small = false;
        let mut max_seen = 0;
        check(
            "size-ramp",
            Config { cases: 100, max_size: 64, ..Default::default() },
            |_, size| {
                if size <= 4 {
                    seen_small = true;
                }
                max_seen = max_seen.max(size);
                if size <= 64 {
                    Ok(())
                } else {
                    Err(format!("size {size} out of bounds"))
                }
            },
        );
        assert!(seen_small);
        assert!(max_seen >= 32);
    }
}
