//! Single-rate many-leaf merger: a loser (tournament) tree emitting one
//! element per step — the "K-merger" leaf block of the HPMT (fig. 2).
//! Many-leaf mergers support thousands of inputs but are single-rate,
//! which is exactly the trade-off the HPMT combines away (§2.1).

use crate::key::Item;

/// Classic loser tree over `k` descending-sorted input cursors.
pub struct LoserTree<'a, T: Item> {
    inputs: Vec<&'a [T]>,
    pos: Vec<usize>,
    /// internal nodes hold the *loser* of the subtree match; `winner`
    /// holds the overall winner's input index
    losers: Vec<usize>,
    winner: usize,
    k: usize,
}

impl<'a, T: Item> LoserTree<'a, T> {
    pub fn new(inputs: Vec<&'a [T]>) -> Self {
        let k = inputs.len().next_power_of_two().max(1);
        let mut t = LoserTree {
            pos: vec![0; inputs.len()],
            inputs,
            losers: vec![usize::MAX; k],
            winner: usize::MAX,
            k,
        };
        t.rebuild();
        t
    }

    fn key_at(&self, input: usize) -> Option<<T as Item>::K> {
        if input >= self.inputs.len() {
            return None; // padding leaf
        }
        self.inputs[input].get(self.pos[input]).map(|x| x.key())
    }

    /// `true` if input `a` currently beats input `b` (descending; an
    /// exhausted input always loses; ties prefer the lower index for
    /// stability across runs).
    fn beats(&self, a: usize, b: usize) -> bool {
        match (self.key_at(a), self.key_at(b)) {
            (Some(x), Some(y)) => x > y || (x == y && a < b),
            (Some(_), None) => true,
            (None, Some(_)) => false,
            (None, None) => a < b,
        }
    }

    fn rebuild(&mut self) {
        // Play the full tournament bottom-up.
        let k = self.k;
        let mut winners = vec![usize::MAX; 2 * k];
        for leaf in 0..k {
            winners[k + leaf] = leaf;
        }
        for n in (1..k).rev() {
            let (a, b) = (winners[2 * n], winners[2 * n + 1]);
            let (w, l) = if self.beats(a, b) { (a, b) } else { (b, a) };
            winners[n] = w;
            self.losers[n] = l;
        }
        self.winner = if k > 0 { winners[1] } else { usize::MAX };
    }

    /// Pop the next (largest) element; None when all inputs drain.
    pub fn pop(&mut self) -> Option<T> {
        let w = self.winner;
        if w == usize::MAX || w >= self.inputs.len() {
            return None;
        }
        let item = *self.inputs[w].get(self.pos[w])?;
        self.pos[w] += 1;
        // Replay matches from the winner's leaf to the root.
        let mut node = (self.k + w) / 2;
        let mut cur = w;
        while node >= 1 {
            let other = self.losers[node];
            if !self.beats(cur, other) {
                self.losers[node] = cur;
                cur = other;
            }
            node /= 2;
        }
        self.winner = cur;
        Some(item)
    }

    /// Drain everything.
    pub fn run(mut self) -> Vec<T> {
        let total: usize = self.inputs.iter().map(|l| l.len()).sum();
        let mut out = Vec::with_capacity(total);
        while let Some(x) = self.pop() {
            out.push(x);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_sorted_lists, Distribution};
    use crate::util::rng::Rng;

    fn oracle(lists: &[Vec<u32>]) -> Vec<u32> {
        let mut v: Vec<u32> = lists.iter().flatten().copied().collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v
    }

    #[test]
    fn merges_many_lists() {
        let mut rng = Rng::new(211);
        for k in [1usize, 2, 3, 5, 8, 16, 33, 100] {
            let lists = gen_sorted_lists(&mut rng, k, 50, Distribution::Uniform);
            let refs: Vec<&[u32]> = lists.iter().map(|l| l.as_slice()).collect();
            let out = LoserTree::new(refs).run();
            assert_eq!(out, oracle(&lists), "k={k}");
        }
    }

    #[test]
    fn handles_empty_lists() {
        let lists: Vec<Vec<u32>> = vec![vec![], vec![5, 3], vec![], vec![4]];
        let refs: Vec<&[u32]> = lists.iter().map(|l| l.as_slice()).collect();
        assert_eq!(LoserTree::new(refs).run(), vec![5, 4, 3]);
    }

    #[test]
    fn duplicates() {
        let mut rng = Rng::new(212);
        let lists = gen_sorted_lists(&mut rng, 7, 100, Distribution::DupHeavy { alphabet: 2 });
        let refs: Vec<&[u32]> = lists.iter().map(|l| l.as_slice()).collect();
        assert_eq!(LoserTree::new(refs).run(), oracle(&lists));
    }

    #[test]
    fn thousand_leaves() {
        // Many-leaf scale (§2.1: "up to a few thousands").
        let mut rng = Rng::new(213);
        let lists = gen_sorted_lists(&mut rng, 1024, 20, Distribution::Uniform);
        let refs: Vec<&[u32]> = lists.iter().map(|l| l.as_slice()).collect();
        assert_eq!(LoserTree::new(refs).run(), oracle(&lists));
    }
}
