//! Hybrid parallel merge tree (fig. 2): `g` many-leaf single-rate
//! mergers (loser trees over `K` inputs each) feed a PMT of 2-way
//! high-throughput mergers, giving `g·K` total inputs at an output rate
//! of `g` — "the size of the HPMT can be easily adjusted to saturate the
//! bandwidth of the target architecture, while eliminating the number of
//! passes of the data by still using many-leaf merging" (§2.1).

use super::loser::LoserTree;
use super::pmt::{Pmt, PmtStats};
use crate::flims::scalar::Variant;
use crate::key::Item;

/// HPMT configuration and execution.
pub struct Hpmt;

impl Hpmt {
    /// Merge `lists` through `groups` many-leaf mergers + a PMT root of
    /// rate `w`. `groups` must be a power of two ≥ 2 and divide the
    /// list count evenly (pad with empty lists otherwise).
    pub fn run<T: Item>(
        lists: &[Vec<T>],
        groups: usize,
        w: usize,
        variant: Variant,
    ) -> (Vec<T>, PmtStats) {
        assert!(groups.is_power_of_two() && groups >= 2);
        let per = lists.len().div_ceil(groups);
        // Stage 1: many-leaf single-rate mergers (the K-input blocks).
        let merged_groups: Vec<Vec<T>> = (0..groups)
            .map(|gi| {
                let lo = gi * per;
                let hi = ((gi + 1) * per).min(lists.len());
                let refs: Vec<&[T]> =
                    lists[lo.min(lists.len())..hi].iter().map(|l| l.as_slice()).collect();
                if refs.is_empty() {
                    Vec::new()
                } else {
                    LoserTree::new(refs).run()
                }
            })
            .collect();
        // Stage 2: the PMT over the group outputs.
        let refs: Vec<&[T]> = merged_groups.iter().map(|l| l.as_slice()).collect();
        Pmt::new(refs, w, variant).run()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_sorted_lists, Distribution};
    use crate::util::rng::Rng;

    fn oracle(lists: &[Vec<u32>]) -> Vec<u32> {
        let mut v: Vec<u32> = lists.iter().flatten().copied().collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v
    }

    #[test]
    fn fig2_shape_4_groups_of_k() {
        // Fig. 2: 4 many-leaf mergers of K inputs → 4K lists, rate 4.
        let mut rng = Rng::new(221);
        for k_per_group in [4usize, 16, 64] {
            let lists =
                gen_sorted_lists(&mut rng, 4 * k_per_group, 40, Distribution::Uniform);
            let (out, _) = Hpmt::run(&lists, 4, 4, Variant::Basic);
            assert_eq!(out, oracle(&lists), "K={k_per_group}");
        }
    }

    #[test]
    fn uneven_group_split() {
        let mut rng = Rng::new(222);
        let lists = gen_sorted_lists(&mut rng, 13, 30, Distribution::Uniform);
        let (out, _) = Hpmt::run(&lists, 4, 8, Variant::Basic);
        assert_eq!(out, oracle(&lists));
    }

    #[test]
    fn skewed_data_through_hpmt() {
        let mut rng = Rng::new(223);
        let lists = gen_sorted_lists(&mut rng, 32, 100, Distribution::DupHeavy { alphabet: 2 });
        let (out, _) = Hpmt::run(&lists, 8, 8, Variant::Skew);
        assert_eq!(out, oracle(&lists));
    }

    #[test]
    fn single_pass_over_many_inputs() {
        // The HPMT's purpose: merge many lists in ONE pass. 256 lists
        // through 8 groups; every element moves through exactly one
        // loser tree and one PMT.
        let mut rng = Rng::new(224);
        let lists = gen_sorted_lists(&mut rng, 256, 32, Distribution::Uniform);
        let (out, stats) = Hpmt::run(&lists, 8, 16, Variant::Basic);
        assert_eq!(out, oracle(&lists));
        assert_eq!(stats.elements, 256 * 32);
    }
}
