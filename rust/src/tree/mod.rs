//! Merge-tree coordination: the embedding context of the FLiMS merger.
//!
//! * [`pmt`] — the parallel merge tree of fig. 1: a binary tree of
//!   2-way high-throughput mergers with bounded FIFO queues and
//!   level-halving rates, plus stall accounting (the §4.1 rate-mismatch
//!   observable).
//! * [`loser`] — a single-rate many-leaf merger (tournament / loser
//!   tree), the "K-merger" building block of fig. 2.
//! * [`hpmt`] — the hybrid parallel merge tree of fig. 2: many-leaf
//!   single-rate mergers at the leaves, a PMT above them.

pub mod hpmt;
pub mod loser;
pub mod pmt;

pub use hpmt::Hpmt;
pub use loser::LoserTree;
pub use pmt::{Pmt, PmtStats};
