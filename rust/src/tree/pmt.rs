//! Parallel merge tree (fig. 1): `k` sorted input lists merge through a
//! binary tree of 2-way mergers with bounded FIFO queues between levels.
//!
//! Rates follow the paper: the root emits up to `w` elements per round,
//! each level below half as many ("the 'merge rate' of the mergers in
//! each level … directly contributes to the throughput … the difference
//! in widths from level to level is managed by rate converters and the
//! appropriate stall signals"). A node stalls when its output queue is
//! full (backpressure) or its inputs cannot supply data yet; stall
//! counts per level are the observable behind the §4.1 skew discussion.

use crate::flims::scalar::Variant;
use crate::key::Item;
use std::collections::VecDeque;

/// Per-run tree statistics.
#[derive(Clone, Debug, Default)]
pub struct PmtStats {
    /// scheduler rounds until fully drained (root-cycle analogue)
    pub rounds: usize,
    /// per-level stall events (node could not meet its rate)
    pub stalls_per_level: Vec<usize>,
    /// total elements moved
    pub elements: usize,
}

/// One internal 2-way merge node with bounded input queues.
struct Node<T> {
    q_in: [VecDeque<T>; 2],
    in_done: [bool; 2],
    /// skew-optimisation dir bit (algorithm 2) — per node, emulating the
    /// MAX units' oscillation at element granularity
    dir: bool,
    variant: Variant,
}

impl<T: Item> Node<T> {
    fn new(variant: Variant) -> Self {
        Node {
            q_in: [VecDeque::new(), VecDeque::new()],
            in_done: [false, false],
            dir: false,
            variant,
        }
    }

    /// Pop the next merged element if the decision is determined.
    fn pop_next(&mut self) -> Option<T> {
        let a = self.q_in[0].front();
        let b = self.q_in[1].front();
        match (a, b) {
            (Some(x), Some(y)) => {
                let take_a = match x.key().cmp(&y.key()) {
                    std::cmp::Ordering::Greater => true,
                    std::cmp::Ordering::Less => false,
                    std::cmp::Ordering::Equal => match self.variant {
                        Variant::Basic => false,
                        // Algorithm 2: alternate sources on duplicates.
                        Variant::Skew => self.dir,
                    },
                };
                self.dir = !take_a;
                if take_a {
                    self.q_in[0].pop_front()
                } else {
                    self.q_in[1].pop_front()
                }
            }
            (Some(_), None) if self.in_done[1] => self.q_in[0].pop_front(),
            (None, Some(_)) if self.in_done[0] => self.q_in[1].pop_front(),
            _ => None,
        }
    }

    fn exhausted(&self) -> bool {
        self.in_done[0]
            && self.in_done[1]
            && self.q_in[0].is_empty()
            && self.q_in[1].is_empty()
    }
}

/// The tree. Nodes are stored heap-style: node 0 is the root; node `i`
/// has children `2i+1`, `2i+2`; leaves attach to the input lists.
pub struct Pmt<'a, T: Item> {
    k: usize,
    w: usize,
    nodes: Vec<Node<T>>,
    inputs: Vec<&'a [T]>,
    in_pos: Vec<usize>,
    /// per-input feed bandwidth (elements per round) — fig. 1's leaves
    /// are rate-1
    leaf_rate: usize,
    fifo_cap: usize,
}

impl<'a, T: Item> Pmt<'a, T> {
    /// `inputs.len()` must be a power of two ≥ 2; `w` is the root rate.
    pub fn new(inputs: Vec<&'a [T]>, w: usize, variant: Variant) -> Self {
        let k = inputs.len();
        assert!(k.is_power_of_two() && k >= 2, "k must be a power of two >= 2");
        assert!(w.is_power_of_two());
        let nodes = (0..k - 1).map(|_| Node::new(variant)).collect();
        Pmt {
            k,
            w,
            nodes,
            in_pos: vec![0; k],
            inputs,
            leaf_rate: 1.max(2 * w / k),
            fifo_cap: 4 * w.max(8),
        }
    }

    pub fn levels(&self) -> usize {
        self.k.trailing_zeros() as usize
    }

    /// Rate (elements per round) of a node at `depth` (root = 0).
    fn rate(&self, depth: usize) -> usize {
        (self.w >> depth).max(1)
    }

    fn depth_of(idx: usize) -> usize {
        (usize::BITS - (idx + 1).leading_zeros() - 1) as usize
    }

    /// Run to completion, returning the merged output and statistics.
    pub fn run(mut self) -> (Vec<T>, PmtStats) {
        let total: usize = self.inputs.iter().map(|l| l.len()).sum();
        let mut out = Vec::with_capacity(total);
        let levels = self.levels();
        let mut stats = PmtStats {
            rounds: 0,
            stalls_per_level: vec![0; levels],
            elements: total,
        };
        let first_leaf_parent = (self.k - 1) / 2; // nodes whose children are inputs

        while out.len() < total {
            stats.rounds += 1;
            // 1) feed leaves: each input list delivers up to leaf_rate
            //    elements into its parent node's queue (bounded).
            for input_idx in 0..self.k {
                let parent = first_leaf_parent + input_idx / 2;
                let side = input_idx % 2;
                let pos = &mut self.in_pos[input_idx];
                let src = self.inputs[input_idx];
                let node = &mut self.nodes[parent];
                let mut budget = self.leaf_rate;
                while budget > 0 && *pos < src.len() && node.q_in[side].len() < self.fifo_cap
                {
                    node.q_in[side].push_back(src[*pos]);
                    *pos += 1;
                    budget -= 1;
                }
                if *pos >= src.len() {
                    node.in_done[side] = true;
                }
            }
            // 2) service internal nodes bottom-up so data flows one level
            //    per round (pipeline), root last.
            for idx in (0..self.nodes.len()).rev() {
                let depth = Self::depth_of(idx);
                let rate = self.rate(depth);
                let is_root = idx == 0;
                let mut moved = 0;
                for _ in 0..rate {
                    // Output backpressure (non-root): parent queue cap.
                    if !is_root {
                        let parent = (idx - 1) / 2;
                        let side = (idx - 1) % 2;
                        if self.nodes[parent].q_in[side].len() >= self.fifo_cap {
                            break;
                        }
                        match self.nodes[idx].pop_next() {
                            Some(x) => {
                                let parent_node = &mut self.nodes[parent];
                                parent_node.q_in[side].push_back(x);
                                moved += 1;
                            }
                            None => break,
                        }
                    } else {
                        match self.nodes[0].pop_next() {
                            Some(x) => {
                                out.push(x);
                                moved += 1;
                            }
                            None => break,
                        }
                    }
                }
                if moved < rate && !self.nodes[idx].exhausted() {
                    stats.stalls_per_level[depth] += 1;
                }
                // Propagate upstream completion.
                if !is_root && self.nodes[idx].exhausted() {
                    let parent = (idx - 1) / 2;
                    let side = (idx - 1) % 2;
                    self.nodes[parent].in_done[side] = true;
                }
            }
            // Safety: a fully stalled tree would loop forever.
            debug_assert!(stats.rounds < 100 * (total + self.k * self.fifo_cap).max(64));
        }
        (out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_sorted_lists, Distribution};
    use crate::key::is_sorted_desc;
    use crate::util::rng::Rng;

    fn oracle(lists: &[Vec<u32>]) -> Vec<u32> {
        let mut v: Vec<u32> = lists.iter().flatten().copied().collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v
    }

    #[test]
    fn merges_k_lists() {
        let mut rng = Rng::new(201);
        for k in [2usize, 4, 8, 16] {
            let lists = gen_sorted_lists(&mut rng, k, 200, Distribution::Uniform);
            let refs: Vec<&[u32]> = lists.iter().map(|l| l.as_slice()).collect();
            let (out, _) = Pmt::new(refs, 8, Variant::Basic).run();
            assert_eq!(out, oracle(&lists), "k={k}");
        }
    }

    #[test]
    fn uneven_list_lengths() {
        let mut rng = Rng::new(202);
        let mut lists = gen_sorted_lists(&mut rng, 8, 64, Distribution::Uniform);
        lists[0] = Vec::new();
        lists[3].truncate(5);
        let refs: Vec<&[u32]> = lists.iter().map(|l| l.as_slice()).collect();
        let (out, _) = Pmt::new(refs, 4, Variant::Basic).run();
        assert_eq!(out, oracle(&lists));
    }

    #[test]
    fn output_is_sorted_with_duplicates() {
        let mut rng = Rng::new(203);
        let lists = gen_sorted_lists(&mut rng, 8, 300, Distribution::DupHeavy { alphabet: 3 });
        let refs: Vec<&[u32]> = lists.iter().map(|l| l.as_slice()).collect();
        let (out, _) = Pmt::new(refs, 8, Variant::Skew).run();
        assert!(is_sorted_desc(&out));
        assert_eq!(out, oracle(&lists));
    }

    #[test]
    fn skew_variant_reduces_stalls_on_duplicates() {
        // §4.1: on duplicate-heavy data the basic tree starves interior
        // mergers; the skew optimisation balances both inputs of every
        // node and finishes in fewer rounds.
        let k = 8;
        let lists: Vec<Vec<u32>> = (0..k).map(|_| vec![9u32; 512]).collect();
        let refs1: Vec<&[u32]> = lists.iter().map(|l| l.as_slice()).collect();
        let refs2 = refs1.clone();
        let (out1, s_basic) = Pmt::new(refs1, 8, Variant::Basic).run();
        let (out2, s_skew) = Pmt::new(refs2, 8, Variant::Skew).run();
        assert_eq!(out1.len(), k * 512);
        assert_eq!(out2.len(), k * 512);
        assert!(
            s_skew.rounds < s_basic.rounds,
            "skew {} rounds vs basic {}",
            s_skew.rounds,
            s_basic.rounds
        );
    }

    #[test]
    fn throughput_scales_with_root_rate() {
        let mut rng = Rng::new(204);
        let lists = gen_sorted_lists(&mut rng, 4, 4096, Distribution::Uniform);
        let r1: Vec<&[u32]> = lists.iter().map(|l| l.as_slice()).collect();
        let r2 = r1.clone();
        let (_, s_w4) = Pmt::new(r1, 4, Variant::Basic).run();
        let (_, s_w16) = Pmt::new(r2, 16, Variant::Basic).run();
        assert!(
            (s_w4.rounds as f64) > 2.5 * s_w16.rounds as f64,
            "w=4 {} vs w=16 {} rounds",
            s_w4.rounds,
            s_w16.rounds
        );
    }
}
