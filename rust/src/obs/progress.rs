//! Process-wide sort progress: how far the running external sorts have
//! got, visible while they are still running.
//!
//! The counters are global (they accumulate across every sort the
//! process runs — Prometheus-style monotonic totals, not per-job
//! values) and updated straight from the pipeline's hot points: a run
//! sealing, a group merge firing, a block landing in the output. The
//! service surfaces them through the `progress` verb and inside the
//! `metrics` exposition; a client polls either to watch a long
//! `sortfile` advance.

use std::sync::atomic::{AtomicU64, Ordering};

static ACTIVE: AtomicU64 = AtomicU64::new(0);
static RUNS_SEALED: AtomicU64 = AtomicU64::new(0);
static MERGES_FIRED: AtomicU64 = AtomicU64::new(0);
static ELEMENTS_OUT: AtomicU64 = AtomicU64::new(0);
static BYTES_OUT: AtomicU64 = AtomicU64::new(0);

/// A point-in-time copy of the progress counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProgressSnapshot {
    /// External sorts currently running (a gauge, not a total).
    pub active_sorts: u64,
    /// Phase-1/intermediate runs sealed on disk, ever.
    pub runs_sealed: u64,
    /// Phase-2 group merges completed, ever.
    pub merges_fired: u64,
    /// Elements written to final sort outputs, ever.
    pub elements_out: u64,
    /// Bytes written to final sort outputs, ever.
    pub bytes_out: u64,
}

/// RAII marker for one running external sort: increments the active
/// gauge on creation, decrements on drop (including the error path).
#[derive(Debug)]
pub struct ActiveSort(());

impl Drop for ActiveSort {
    fn drop(&mut self) {
        ACTIVE.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Mark an external sort as started; hold the guard for its duration.
pub fn sort_started() -> ActiveSort {
    ACTIVE.fetch_add(1, Ordering::Relaxed);
    ActiveSort(())
}

/// Count one sealed run.
pub fn run_sealed() {
    RUNS_SEALED.fetch_add(1, Ordering::Relaxed);
}

/// Count one completed group merge.
pub fn merge_fired() {
    MERGES_FIRED.fetch_add(1, Ordering::Relaxed);
}

/// Count a block of final output (`elements` records, `bytes` on the
/// wire).
pub fn block_out(elements: u64, bytes: u64) {
    ELEMENTS_OUT.fetch_add(elements, Ordering::Relaxed);
    BYTES_OUT.fetch_add(bytes, Ordering::Relaxed);
}

/// Read every counter at once.
pub fn snapshot() -> ProgressSnapshot {
    ProgressSnapshot {
        active_sorts: ACTIVE.load(Ordering::Relaxed),
        runs_sealed: RUNS_SEALED.load(Ordering::Relaxed),
        merges_fired: MERGES_FIRED.load(Ordering::Relaxed),
        elements_out: ELEMENTS_OUT.load(Ordering::Relaxed),
        bytes_out: BYTES_OUT.load(Ordering::Relaxed),
    }
}

/// The one-line `progress` verb payload.
pub fn report() -> String {
    let s = snapshot();
    format!(
        "active={} runs_sealed={} merges_fired={} elements_out={} bytes_out={}",
        s.active_sorts, s.runs_sealed, s.merges_fired, s.elements_out, s.bytes_out
    )
}

/// Append the progress counters in Prometheus text format.
pub fn prometheus_into(out: &mut String) {
    use std::fmt::Write as _;
    let s = snapshot();
    let mut metric = |name: &str, help: &str, kind: &str, v: u64| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
        let _ = writeln!(out, "{name} {v}");
    };
    metric("flims_active_sorts", "External sorts currently running.", "gauge", s.active_sorts);
    metric(
        "flims_progress_runs_sealed_total",
        "Runs sealed on disk across all sorts.",
        "counter",
        s.runs_sealed,
    );
    metric(
        "flims_progress_merges_fired_total",
        "Group merges completed across all sorts.",
        "counter",
        s.merges_fired,
    );
    metric(
        "flims_progress_elements_out_total",
        "Elements written to final sort outputs.",
        "counter",
        s.elements_out,
    );
    metric(
        "flims_progress_bytes_out_total",
        "Bytes written to final sort outputs.",
        "counter",
        s.bytes_out,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    // Counters are process-global and other tests run concurrently, so
    // every assertion is a monotone before/after comparison.
    #[test]
    fn counters_accumulate() {
        let before = snapshot();
        let guard = sort_started();
        run_sealed();
        run_sealed();
        merge_fired();
        block_out(100, 400);
        let during = snapshot();
        assert!(during.active_sorts >= 1);
        assert!(during.runs_sealed >= before.runs_sealed + 2);
        assert!(during.merges_fired >= before.merges_fired + 1);
        assert!(during.elements_out >= before.elements_out + 100);
        assert!(during.bytes_out >= before.bytes_out + 400);
        drop(guard);
    }

    #[test]
    fn report_and_prometheus_render() {
        let r = report();
        for key in ["active=", "runs_sealed=", "merges_fired=", "elements_out=", "bytes_out="] {
            assert!(r.contains(key), "{r}");
        }
        let mut s = String::new();
        prometheus_into(&mut s);
        assert!(s.contains("# TYPE flims_active_sorts gauge"), "{s}");
        assert!(s.contains("# TYPE flims_progress_runs_sealed_total counter"), "{s}");
        for line in s.lines() {
            assert!(
                line.starts_with('#')
                    || line
                        .split_once(' ')
                        .is_some_and(|(name, v)| !name.is_empty() && v.parse::<f64>().is_ok()),
                "unparseable exposition line: {line}"
            );
        }
    }
}
