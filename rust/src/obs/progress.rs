//! Sort progress: how far the running external sorts have got, visible
//! while they are still running.
//!
//! Two granularities share one update path:
//!
//! * **Process-wide totals** — global counters that accumulate across
//!   every sort the process runs (Prometheus-style monotonic totals).
//!   The service surfaces them through the `progress` verb and inside
//!   the `metrics` exposition.
//! * **Per-job counters** — a [`ProgressCounters`] instance owned by
//!   one scheduler job, surfaced through the `status <id>` verb so a
//!   client can watch *its own* `sortfile` advance while other jobs
//!   run concurrently.
//!
//! The pipeline's hot points (a run sealing, a group merge firing, a
//! block landing in the output) update both through a
//! [`ProgressHandle`]: the global totals always, plus the job's
//! counters when the sort runs under the job scheduler.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

static ACTIVE: AtomicU64 = AtomicU64::new(0);
static RUNS_SEALED: AtomicU64 = AtomicU64::new(0);
static MERGES_FIRED: AtomicU64 = AtomicU64::new(0);
static ELEMENTS_OUT: AtomicU64 = AtomicU64::new(0);
static BYTES_OUT: AtomicU64 = AtomicU64::new(0);

/// A point-in-time copy of the process-wide progress counters.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ProgressSnapshot {
    /// External sorts currently running (a gauge, not a total).
    pub active_sorts: u64,
    /// Phase-1/intermediate runs sealed on disk, ever.
    pub runs_sealed: u64,
    /// Phase-2 group merges completed, ever.
    pub merges_fired: u64,
    /// Elements written to final sort outputs, ever.
    pub elements_out: u64,
    /// Bytes written to final sort outputs, ever.
    pub bytes_out: u64,
}

/// Live counters for one scheduler job (shared between the sorting
/// thread and `status <id>` readers).
#[derive(Debug, Default)]
pub struct ProgressCounters {
    runs_sealed: AtomicU64,
    merges_fired: AtomicU64,
    elements_out: AtomicU64,
    bytes_out: AtomicU64,
}

/// A point-in-time copy of one job's [`ProgressCounters`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JobProgress {
    /// Runs this job has sealed on disk.
    pub runs_sealed: u64,
    /// Group merges this job has completed.
    pub merges_fired: u64,
    /// Elements this job has written to its final output.
    pub elements_out: u64,
    /// Bytes this job has written to its final output.
    pub bytes_out: u64,
}

impl ProgressCounters {
    /// Read every per-job counter at once.
    pub fn snapshot(&self) -> JobProgress {
        JobProgress {
            runs_sealed: self.runs_sealed.load(Ordering::Relaxed),
            merges_fired: self.merges_fired.load(Ordering::Relaxed),
            elements_out: self.elements_out.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
        }
    }
}

/// Where a pipeline hot point reports progress: always the global
/// totals, plus one job's [`ProgressCounters`] when the sort runs
/// under the job scheduler. Cloning is cheap (an `Option<Arc>`).
#[derive(Clone, Debug, Default)]
pub struct ProgressHandle {
    job: Option<Arc<ProgressCounters>>,
}

impl ProgressHandle {
    /// A handle that updates only the process-wide totals (the
    /// behaviour of every pre-scheduler entry point).
    pub fn global() -> Self {
        ProgressHandle { job: None }
    }

    /// A handle that additionally updates `job`'s counters.
    pub fn with_job(job: Arc<ProgressCounters>) -> Self {
        ProgressHandle { job: Some(job) }
    }

    /// Count one sealed run.
    pub fn run_sealed(&self) {
        RUNS_SEALED.fetch_add(1, Ordering::Relaxed);
        if let Some(j) = &self.job {
            j.runs_sealed.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one completed group merge.
    pub fn merge_fired(&self) {
        MERGES_FIRED.fetch_add(1, Ordering::Relaxed);
        if let Some(j) = &self.job {
            j.merges_fired.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count a block of final output (`elements` records, `bytes` on
    /// the wire).
    pub fn block_out(&self, elements: u64, bytes: u64) {
        ELEMENTS_OUT.fetch_add(elements, Ordering::Relaxed);
        BYTES_OUT.fetch_add(bytes, Ordering::Relaxed);
        if let Some(j) = &self.job {
            j.elements_out.fetch_add(elements, Ordering::Relaxed);
            j.bytes_out.fetch_add(bytes, Ordering::Relaxed);
        }
    }
}

/// RAII marker for one running external sort: increments the active
/// gauge on creation, decrements on drop (including the error path).
#[derive(Debug)]
pub struct ActiveSort(());

impl Drop for ActiveSort {
    fn drop(&mut self) {
        ACTIVE.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Mark an external sort as started; hold the guard for its duration.
pub fn sort_started() -> ActiveSort {
    ACTIVE.fetch_add(1, Ordering::Relaxed);
    ActiveSort(())
}

/// Count one sealed run (process-wide totals only).
pub fn run_sealed() {
    ProgressHandle::global().run_sealed();
}

/// Count one completed group merge (process-wide totals only).
pub fn merge_fired() {
    ProgressHandle::global().merge_fired();
}

/// Count a block of final output (`elements` records, `bytes` on the
/// wire; process-wide totals only).
pub fn block_out(elements: u64, bytes: u64) {
    ProgressHandle::global().block_out(elements, bytes);
}

/// Read every process-wide counter at once.
pub fn snapshot() -> ProgressSnapshot {
    ProgressSnapshot {
        active_sorts: ACTIVE.load(Ordering::Relaxed),
        runs_sealed: RUNS_SEALED.load(Ordering::Relaxed),
        merges_fired: MERGES_FIRED.load(Ordering::Relaxed),
        elements_out: ELEMENTS_OUT.load(Ordering::Relaxed),
        bytes_out: BYTES_OUT.load(Ordering::Relaxed),
    }
}

/// The one-line `progress` verb payload.
pub fn report() -> String {
    let s = snapshot();
    format!(
        "active={} runs_sealed={} merges_fired={} elements_out={} bytes_out={}",
        s.active_sorts, s.runs_sealed, s.merges_fired, s.elements_out, s.bytes_out
    )
}

/// Append the progress counters in Prometheus text format.
pub fn prometheus_into(out: &mut String) {
    use std::fmt::Write as _;
    let s = snapshot();
    let mut metric = |name: &str, help: &str, kind: &str, v: u64| {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
        let _ = writeln!(out, "{name} {v}");
    };
    metric("flims_active_sorts", "External sorts currently running.", "gauge", s.active_sorts);
    metric(
        "flims_progress_runs_sealed_total",
        "Runs sealed on disk across all sorts.",
        "counter",
        s.runs_sealed,
    );
    metric(
        "flims_progress_merges_fired_total",
        "Group merges completed across all sorts.",
        "counter",
        s.merges_fired,
    );
    metric(
        "flims_progress_elements_out_total",
        "Elements written to final sort outputs.",
        "counter",
        s.elements_out,
    );
    metric(
        "flims_progress_bytes_out_total",
        "Bytes written to final sort outputs.",
        "counter",
        s.bytes_out,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    // Counters are process-global and other tests run concurrently, so
    // every assertion is a monotone before/after comparison.
    #[test]
    fn counters_accumulate() {
        let before = snapshot();
        let guard = sort_started();
        run_sealed();
        run_sealed();
        merge_fired();
        block_out(100, 400);
        let during = snapshot();
        assert!(during.active_sorts >= 1);
        assert!(during.runs_sealed >= before.runs_sealed + 2);
        assert!(during.merges_fired >= before.merges_fired + 1);
        assert!(during.elements_out >= before.elements_out + 100);
        assert!(during.bytes_out >= before.bytes_out + 400);
        drop(guard);
    }

    #[test]
    fn job_handle_updates_both_levels() {
        let job = Arc::new(ProgressCounters::default());
        let h = ProgressHandle::with_job(job.clone());
        let before = snapshot();
        h.run_sealed();
        h.merge_fired();
        h.block_out(10, 40);
        let after = snapshot();
        // Globals advanced…
        assert!(after.runs_sealed >= before.runs_sealed + 1);
        assert!(after.merges_fired >= before.merges_fired + 1);
        assert!(after.elements_out >= before.elements_out + 10);
        // …and the job's own counters are exact (nothing else holds
        // this Arc).
        let j = job.snapshot();
        assert_eq!(
            j,
            JobProgress { runs_sealed: 1, merges_fired: 1, elements_out: 10, bytes_out: 40 }
        );
    }

    #[test]
    fn global_handle_leaves_jobs_alone() {
        let job = Arc::new(ProgressCounters::default());
        let _h = ProgressHandle::with_job(job.clone());
        ProgressHandle::global().run_sealed();
        assert_eq!(job.snapshot().runs_sealed, 0);
    }

    #[test]
    fn report_and_prometheus_render() {
        let r = report();
        for key in ["active=", "runs_sealed=", "merges_fired=", "elements_out=", "bytes_out="] {
            assert!(r.contains(key), "{r}");
        }
        let mut s = String::new();
        prometheus_into(&mut s);
        assert!(s.contains("# TYPE flims_active_sorts gauge"), "{s}");
        assert!(s.contains("# TYPE flims_progress_runs_sealed_total counter"), "{s}");
        for line in s.lines() {
            assert!(
                line.starts_with('#')
                    || line
                        .split_once(' ')
                        .is_some_and(|(name, v)| !name.is_empty() && v.parse::<f64>().is_ok()),
                "unparseable exposition line: {line}"
            );
        }
    }
}
