//! Chrome `trace_event` JSON rendering for a finished [`Trace`].
//!
//! The output is the JSON-object flavour of the [trace-event format]:
//! one complete event (`"ph":"X"`) per recorded span, timestamps and
//! durations in fractional microseconds relative to the trace's
//! creation, the recording thread's lane as `tid`. Load the file in
//! `chrome://tracing` or <https://ui.perfetto.dev> — each sort worker
//! gets its own row, so the pipelined schedule's phase-1 `seal_run`
//! spans are visibly concurrent with phase-2 `group_merge` spans.
//!
//! [trace-event format]: https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
//!
//! ```
//! use flims::obs::{chrome, SpanKind, Trace};
//!
//! let t = Trace::enabled();
//! t.end(SpanKind::FinalDrain, t.begin(), 42);
//! let json = chrome::render(&t);
//! assert!(json.starts_with("{\"traceEvents\":["));
//! assert!(json.contains("\"name\":\"final_drain\""));
//! assert!(json.contains("\"ph\":\"X\""));
//! ```

use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use super::Trace;

/// Render `trace` as a Chrome trace-event JSON document (always valid
/// JSON, even for an empty or disabled trace).
pub fn render(trace: &Trace) -> String {
    let spans = trace.spans();
    let mut s = String::with_capacity(spans.len() * 128 + 128);
    s.push_str("{\"traceEvents\":[");
    for (i, sp) in spans.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        let _ = write!(
            s,
            "\n{{\"name\":\"{}\",\"cat\":\"flims\",\"ph\":\"X\",\
             \"ts\":{}.{:03},\"dur\":{}.{:03},\"pid\":1,\"tid\":{},\
             \"args\":{{\"{}\":{}}}}}",
            sp.kind.name(),
            sp.start_ns / 1000,
            sp.start_ns % 1000,
            sp.dur_ns / 1000,
            sp.dur_ns % 1000,
            sp.lane,
            sp.kind.arg_name(),
            sp.arg,
        );
    }
    let _ = write!(
        s,
        "\n],\"displayTimeUnit\":\"ms\",\"otherData\":{{\"dropped_spans\":{}}}}}\n",
        trace.dropped()
    );
    s
}

/// Render `trace` and write it to `path`, creating parent directories
/// as needed.
pub fn write_file(trace: &Trace, path: &Path) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, render(trace))
}

/// Write `trace` into `dir` under a generated per-process, per-sort
/// file name (`flims-trace-<pid>-<seq>.json`) — the `[obs] trace_dir`
/// / `FLIMS_TRACE_DIR` auto-trace path. A write failure is reported on
/// stderr and swallowed: tracing must never fail a sort that already
/// produced its output. Returns the path written, if any.
pub fn write_auto(trace: &Trace, dir: &Path) -> Option<PathBuf> {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    let path = dir.join(format!("flims-trace-{}-{seq}.json", std::process::id()));
    match std::fs::create_dir_all(dir).and_then(|()| std::fs::write(&path, render(trace))) {
        Ok(()) => Some(path),
        Err(e) => {
            eprintln!("obs: writing trace {}: {e}", path.display());
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::SpanKind;
    use std::time::Instant;

    #[test]
    fn empty_trace_renders_valid_skeleton() {
        for t in [Trace::disabled(), Trace::enabled()] {
            let json = render(&t);
            assert!(json.starts_with("{\"traceEvents\":["));
            assert!(json.contains("\"dropped_spans\":0"));
            assert_eq!(json.matches('{').count(), json.matches('}').count());
        }
    }

    #[test]
    fn events_carry_every_required_field() {
        let t = Trace::enabled();
        let base = Instant::now();
        t.record_dur(SpanKind::ChunkSort, base, 1_234_567, 4096);
        t.record_dur(SpanKind::GroupMerge, base, 10, 7);
        let json = render(&t);
        assert_eq!(json.matches("\"ph\":\"X\"").count(), 2);
        assert!(json.contains("\"name\":\"chunk_sort\""));
        assert!(json.contains("\"name\":\"group_merge\""));
        assert!(json.contains("\"dur\":1234.567"), "{json}");
        assert!(json.contains("\"dur\":0.010"), "{json}");
        assert!(json.contains("\"args\":{\"elems\":4096}"), "{json}");
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn write_file_creates_parents() {
        let dir = std::env::temp_dir().join(format!("flims-chrome-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested/trace.json");
        let t = Trace::enabled();
        t.end(SpanKind::SealRun, t.begin(), 3);
        write_file(&t, &path).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert!(back.contains("seal_run"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn write_auto_generates_unique_names() {
        let dir = std::env::temp_dir().join(format!("flims-chrome-auto-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let t = Trace::enabled();
        let a = write_auto(&t, &dir).unwrap();
        let b = write_auto(&t, &dir).unwrap();
        assert_ne!(a, b);
        assert!(a.file_name().unwrap().to_str().unwrap().starts_with("flims-trace-"));
        assert!(a.exists() && b.exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
