//! Observability: per-sort span tracing and process-wide progress.
//!
//! The external sorter is a pipeline of concurrent stages — phase-1
//! chunk sorts feeding spilled runs, phase-2 group merges consuming
//! them, codec and prefetch threads in between — and a one-line stats
//! summary cannot show *where* a multi-pass sort spends its wall-clock,
//! or whether the pipelined schedule actually overlaps the phases it
//! claims to (the TopSort-style `overlap = on` schedule). This module
//! provides the instrumentation:
//!
//! * [`Trace`] — a per-sort span recorder. A cheap clonable handle;
//!   every recording call on a *disabled* trace returns before touching
//!   any state (zero allocation, pinned by `tests/obs_alloc.rs`).
//!   Enabled traces write into a bounded lock-free ring of atomic
//!   slots, so the hot path never locks or allocates either.
//! * [`chrome`] — renders a finished trace as Chrome `trace_event`
//!   JSON, loadable in `chrome://tracing` or [Perfetto](https://ui.perfetto.dev),
//!   where the overlap schedule is literally visible: `seal_run` spans
//!   from phase 1 running concurrently with `group_merge` spans from
//!   phase 2.
//! * [`progress`] — process-wide progress counters (runs sealed,
//!   merges fired, elements out) surfaced by the service `progress`
//!   verb while long sorts are still running.
//!
//! Tracing never changes what the sorter produces: the span points
//! observe timestamps only, and the determinism suites run byte-exact
//! with tracing on and off (the CI `test-trace` job).
//!
//! # Example
//!
//! ```
//! use flims::obs::{SpanKind, Trace};
//!
//! let trace = Trace::enabled();
//! let t0 = trace.begin();
//! // ... the work being measured ...
//! trace.end(SpanKind::ChunkSort, t0, 1024);
//! assert_eq!(trace.recorded(), 1);
//! let spans = trace.spans();
//! assert_eq!(spans[0].kind, SpanKind::ChunkSort);
//! assert_eq!(spans[0].arg, 1024);
//!
//! // A disabled trace accepts the same calls and records nothing.
//! let off = Trace::disabled();
//! off.end(SpanKind::ChunkSort, off.begin(), 1024);
//! assert_eq!(off.recorded(), 0);
//! ```

pub mod chrome;
pub mod progress;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// What a span measured. One value per instrumentation point in the
/// external sorter (the span taxonomy — `docs/OBSERVABILITY.md`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum SpanKind {
    /// Phase 1: one in-memory chunk sort (`ExtItem::sort_run`).
    ChunkSort = 1,
    /// Phase 1: the lifetime of one spilled run, from the first block
    /// handed to its writer until the run is sealed on disk.
    SealRun = 2,
    /// Codec encode wall-clock attributed to one sealed run (runs on
    /// the writer thread, inside the enclosing `SealRun` interval).
    CodecEncode = 3,
    /// Phase 2: one fan-in group merged into an intermediate run.
    GroupMerge = 4,
    /// Codec decode wall-clock aggregated over every leaf reader of
    /// the merge (recorded once per sort as an attributed span).
    CodecDecode = 5,
    /// A merge asked a prefetch leaf for a block that was not buffered
    /// yet — the time the merge spent blocked on disk/decode.
    PrefetchWait = 6,
    /// The final pass: draining the root merge tree into the output.
    FinalDrain = 7,
    /// A spill-I/O operation was retried after a transient error
    /// (injected or real) — the span covers the backoff sleep.
    IoRetry = 8,
    /// The fault injector stalled an I/O operation (latency fault); the
    /// span covers the injected delay.
    FaultStall = 9,
}

impl SpanKind {
    /// Every kind, in declaration order.
    pub const ALL: [SpanKind; 9] = [
        SpanKind::ChunkSort,
        SpanKind::SealRun,
        SpanKind::CodecEncode,
        SpanKind::GroupMerge,
        SpanKind::CodecDecode,
        SpanKind::PrefetchWait,
        SpanKind::FinalDrain,
        SpanKind::IoRetry,
        SpanKind::FaultStall,
    ];

    /// The event name rendered into the Chrome trace.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::ChunkSort => "chunk_sort",
            SpanKind::SealRun => "seal_run",
            SpanKind::CodecEncode => "codec_encode",
            SpanKind::GroupMerge => "group_merge",
            SpanKind::CodecDecode => "codec_decode",
            SpanKind::PrefetchWait => "prefetch_wait",
            SpanKind::FinalDrain => "final_drain",
            SpanKind::IoRetry => "io_retry",
            SpanKind::FaultStall => "fault_stall",
        }
    }

    /// What the span's `arg` value counts.
    pub fn arg_name(self) -> &'static str {
        match self {
            SpanKind::ChunkSort
            | SpanKind::SealRun
            | SpanKind::CodecEncode
            | SpanKind::GroupMerge
            | SpanKind::FinalDrain => "elems",
            SpanKind::CodecDecode | SpanKind::PrefetchWait => "n",
            // attempt number for a retry; the fault `Op` code for a stall
            SpanKind::IoRetry | SpanKind::FaultStall => "n",
        }
    }

    fn from_u64(v: u64) -> Option<SpanKind> {
        SpanKind::ALL.into_iter().find(|k| *k as u64 == v)
    }
}

/// One recorded span, as returned by [`Trace::spans`]. Times are
/// nanoseconds relative to the trace's creation.
#[derive(Clone, Copy, Debug)]
pub struct SpanRecord {
    /// What was measured.
    pub kind: SpanKind,
    /// Recording thread's lane id (the Chrome `tid`). Lanes are
    /// assigned per OS thread in first-record order, so every worker
    /// gets its own row in the viewer.
    pub lane: u64,
    /// Span start, nanoseconds since the trace was created.
    pub start_ns: u64,
    /// Span duration in nanoseconds.
    pub dur_ns: u64,
    /// Kind-specific magnitude (see [`SpanKind::arg_name`]).
    pub arg: u64,
}

impl SpanRecord {
    /// Span end, nanoseconds since the trace was created.
    pub fn end_ns(&self) -> u64 {
        self.start_ns.saturating_add(self.dur_ns)
    }

    /// Whether two spans overlap in wall-clock time.
    pub fn overlaps(&self, other: &SpanRecord) -> bool {
        self.start_ns < other.end_ns() && other.start_ns < self.end_ns()
    }
}

/// One ring slot: per-field atomics so writers never lock. A writer
/// that wraps the ring while another is mid-write can tear that slot —
/// accepted lossy-ring semantics; rendering happens after the sort's
/// workers have joined, when the ring is quiescent.
#[derive(Default)]
struct Slot {
    kind: AtomicU64,
    lane: AtomicU64,
    start_ns: AtomicU64,
    dur_ns: AtomicU64,
    arg: AtomicU64,
}

struct TraceInner {
    /// All span times are relative to this.
    epoch: Instant,
    slots: Box<[Slot]>,
    /// Total spans ever claimed; `head % capacity` is the next slot.
    head: AtomicUsize,
    /// Spans overwritten after the ring wrapped.
    dropped: AtomicU64,
}

/// Per-sort span recorder. Clone freely — clones share the ring. The
/// default value is disabled; [`Trace::enabled`] allocates the ring
/// once up front (never on the recording path).
#[derive(Clone, Default)]
pub struct Trace(Option<Arc<TraceInner>>);

/// Default ring capacity: enough for every run/merge span of a
/// multi-thousand-run sort at ~40 bytes per slot.
const DEFAULT_CAPACITY: usize = 65_536;

static NEXT_LANE: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static LANE: u64 = NEXT_LANE.fetch_add(1, Ordering::Relaxed);
}

fn lane_id() -> u64 {
    LANE.with(|l| *l)
}

impl Trace {
    /// A trace that records nothing. Every call on it is a no-op that
    /// returns before touching any shared state.
    pub fn disabled() -> Self {
        Trace(None)
    }

    /// An enabled trace with the default ring capacity.
    pub fn enabled() -> Self {
        Self::with_capacity(DEFAULT_CAPACITY)
    }

    /// An enabled trace whose ring holds `capacity` spans (clamped to
    /// ≥ 1); older spans are overwritten once it wraps.
    pub fn with_capacity(capacity: usize) -> Self {
        let cap = capacity.max(1);
        Trace(Some(Arc::new(TraceInner {
            epoch: Instant::now(),
            slots: (0..cap).map(|_| Slot::default()).collect(),
            head: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        })))
    }

    /// Whether this trace records anything.
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Start timing a span: `Some(now)` when enabled, `None` when
    /// disabled (so the disabled path skips the clock read too). Pair
    /// with [`Trace::end`].
    pub fn begin(&self) -> Option<Instant> {
        if self.0.is_some() {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Finish a span started by [`Trace::begin`], recording it with
    /// the current time as its end. No-op when `started` is `None`.
    pub fn end(&self, kind: SpanKind, started: Option<Instant>, arg: u64) {
        let Some(t0) = started else { return };
        self.record(kind, t0, Instant::now(), arg);
    }

    /// Record a span over an explicit `[start, end]` interval.
    pub fn record(&self, kind: SpanKind, start: Instant, end: Instant, arg: u64) {
        if self.0.is_none() {
            return;
        }
        let dur = end.saturating_duration_since(start);
        self.record_dur(kind, start, dur.as_nanos().min(u64::MAX as u128) as u64, arg);
    }

    /// Record a span starting at `start` with an externally measured
    /// duration — how attributed spans (codec encode/decode time
    /// accumulated on other threads) land on the timeline.
    pub fn record_dur(&self, kind: SpanKind, start: Instant, dur_ns: u64, arg: u64) {
        let Some(inner) = &self.0 else { return };
        let start_ns =
            start.saturating_duration_since(inner.epoch).as_nanos().min(u64::MAX as u128) as u64;
        let cap = inner.slots.len();
        let idx = inner.head.fetch_add(1, Ordering::Relaxed);
        if idx >= cap {
            inner.dropped.fetch_add(1, Ordering::Relaxed);
        }
        let slot = &inner.slots[idx % cap];
        slot.kind.store(kind as u64, Ordering::Relaxed);
        slot.lane.store(lane_id(), Ordering::Relaxed);
        slot.start_ns.store(start_ns, Ordering::Relaxed);
        slot.dur_ns.store(dur_ns, Ordering::Relaxed);
        slot.arg.store(arg, Ordering::Relaxed);
    }

    /// Spans currently held in the ring (≤ the ring capacity).
    pub fn recorded(&self) -> u64 {
        match &self.0 {
            None => 0,
            Some(inner) => inner.head.load(Ordering::Relaxed).min(inner.slots.len()) as u64,
        }
    }

    /// Spans lost to ring wrap-around (oldest first).
    pub fn dropped(&self) -> u64 {
        match &self.0 {
            None => 0,
            Some(inner) => inner.dropped.load(Ordering::Relaxed),
        }
    }

    /// Snapshot the ring, sorted by start time. Meant for rendering
    /// and assertions after the traced work has finished; a snapshot
    /// taken while writers are active may contain torn spans.
    pub fn spans(&self) -> Vec<SpanRecord> {
        let Some(inner) = &self.0 else { return Vec::new() };
        let n = inner.head.load(Ordering::Relaxed).min(inner.slots.len());
        let mut out = Vec::with_capacity(n);
        for slot in inner.slots[..n].iter() {
            let Some(kind) = SpanKind::from_u64(slot.kind.load(Ordering::Relaxed)) else {
                continue;
            };
            out.push(SpanRecord {
                kind,
                lane: slot.lane.load(Ordering::Relaxed),
                start_ns: slot.start_ns.load(Ordering::Relaxed),
                dur_ns: slot.dur_ns.load(Ordering::Relaxed),
                arg: slot.arg.load(Ordering::Relaxed),
            });
        }
        out.sort_by_key(|s| (s.start_ns, s.lane, s.kind));
        out
    }
}

impl std::fmt::Debug for Trace {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            None => write!(f, "Trace(disabled)"),
            Some(inner) => write!(
                f,
                "Trace(recorded={}, dropped={}, capacity={})",
                self.recorded(),
                self.dropped(),
                inner.slots.len()
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn disabled_trace_records_nothing() {
        let t = Trace::disabled();
        assert!(!t.is_enabled());
        assert!(t.begin().is_none());
        t.end(SpanKind::ChunkSort, t.begin(), 5);
        let now = Instant::now();
        t.record(SpanKind::GroupMerge, now, now, 1);
        t.record_dur(SpanKind::CodecEncode, now, 100, 1);
        assert_eq!(t.recorded(), 0);
        assert_eq!(t.dropped(), 0);
        assert!(t.spans().is_empty());
    }

    #[test]
    fn enabled_trace_round_trips_spans() {
        let t = Trace::enabled();
        assert!(t.is_enabled());
        let t0 = t.begin();
        assert!(t0.is_some());
        t.end(SpanKind::ChunkSort, t0, 123);
        let base = Instant::now();
        t.record(SpanKind::GroupMerge, base, base + Duration::from_micros(50), 9);
        let spans = t.spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(t.recorded(), 2);
        let merge = spans.iter().find(|s| s.kind == SpanKind::GroupMerge).unwrap();
        assert_eq!(merge.arg, 9);
        assert!(merge.dur_ns >= 50_000, "dur_ns={}", merge.dur_ns);
        let sort = spans.iter().find(|s| s.kind == SpanKind::ChunkSort).unwrap();
        assert_eq!(sort.arg, 123);
        assert!(sort.lane > 0);
    }

    #[test]
    fn ring_wraps_and_counts_drops() {
        let t = Trace::with_capacity(4);
        let base = Instant::now();
        for i in 0..10u64 {
            t.record_dur(SpanKind::SealRun, base, i, i);
        }
        assert_eq!(t.recorded(), 4);
        assert_eq!(t.dropped(), 6);
        let spans = t.spans();
        assert_eq!(spans.len(), 4);
        // The survivors are the newest four records (args 6..=9).
        let mut args: Vec<u64> = spans.iter().map(|s| s.arg).collect();
        args.sort_unstable();
        assert_eq!(args, vec![6, 7, 8, 9]);
    }

    #[test]
    fn clones_share_the_ring() {
        let t = Trace::enabled();
        let c = t.clone();
        c.end(SpanKind::FinalDrain, c.begin(), 1);
        assert_eq!(t.recorded(), 1);
        assert_eq!(t.spans()[0].kind, SpanKind::FinalDrain);
    }

    #[test]
    fn lanes_distinguish_threads() {
        let t = Trace::enabled();
        let base = Instant::now();
        t.record_dur(SpanKind::ChunkSort, base, 1, 0);
        std::thread::scope(|s| {
            let tc = t.clone();
            s.spawn(move || tc.record_dur(SpanKind::ChunkSort, base, 1, 0));
        });
        let spans = t.spans();
        assert_eq!(spans.len(), 2);
        assert_ne!(spans[0].lane, spans[1].lane);
    }

    #[test]
    fn span_overlap_predicate() {
        let a = SpanRecord { kind: SpanKind::SealRun, lane: 1, start_ns: 0, dur_ns: 100, arg: 0 };
        let b =
            SpanRecord { kind: SpanKind::GroupMerge, lane: 2, start_ns: 50, dur_ns: 100, arg: 0 };
        let c =
            SpanRecord { kind: SpanKind::GroupMerge, lane: 2, start_ns: 100, dur_ns: 10, arg: 0 };
        assert!(a.overlaps(&b));
        assert!(b.overlaps(&a));
        assert!(!a.overlaps(&c), "touching intervals do not overlap");
        assert_eq!(a.end_ns(), 100);
    }

    #[test]
    fn kind_names_and_tags_are_total() {
        for k in SpanKind::ALL {
            assert!(!k.name().is_empty());
            assert!(!k.arg_name().is_empty());
            assert_eq!(SpanKind::from_u64(k as u64), Some(k));
        }
        assert_eq!(SpanKind::from_u64(0), None);
        assert_eq!(SpanKind::from_u64(255), None);
    }
}
