//! FLiMSj — the whole-row-dequeue variant (paper §4.3, algorithm 4,
//! fig. 10).
//!
//! The plain FLiMS dequeues banks individually (w dequeue signals per
//! input). FLiMSj unifies them: a single shared register row `cR` buffers
//! the "top 2w-to-w" survivors so that, per cycle, exactly ONE whole
//! w-row is fetched — from the input indicated by `dir_0` (lane 0's MAX
//! decision). This is legal because the FIFOs are consumed round-robin
//! and two bank cursors of one input never differ by more than one row.
//!
//! Register roles per lane i (our reading of algorithm 4):
//!   * `c_r[i]` — the surviving candidate (loser of the last comparison);
//!     `src[i]` names the side it substitutes (1 = the survivor is the
//!     *B-side* candidate, so the A-side candidate comes fresh from the
//!     prefetched row register `c_a[i]`; 0 = mirrored).
//!   * `c_a[i]` / `c_b[i]` — prefetched row elements of A / reversed B.
//!
//! Row accounting (the paper's point): rows are fetched whole, one per
//! cycle, totalling (|A|+|B|)/w fetches — matching output exactly.

use crate::flims::butterfly::butterfly_desc;
use crate::key::Item;

/// Per-run statistics: whole-row fetches per input — the observable that
/// distinguishes FLiMSj (w-wide dequeue signals) from plain FLiMS.
#[derive(Clone, Debug, Default)]
pub struct RowStats {
    pub rows_a: usize,
    pub rows_b: usize,
    pub cycles: usize,
}

/// Merge two descending-sorted slices with whole-row dequeues
/// (algorithm 4). Plain-key variant (sentinel-safe by value).
pub fn merge_flimsj<T>(a: &[T], b: &[T], w: usize) -> (Vec<T>, RowStats)
where
    T: Item<K = T> + crate::key::Key,
{
    assert!(w.is_power_of_two());
    let total = a.len() + b.len();
    let mut out = Vec::with_capacity(total + w);
    let mut stats = RowStats::default();
    if total == 0 {
        return (out, stats);
    }

    // Whole-row fetch: row r of A → lane i gets a[r*w + i]; row r of
    // reversed B → lane i gets b[r*w + (w-1-i)]; sentinel past the end.
    let fetch_row_a = |r: usize, c: &mut [T]| {
        for (i, slot) in c.iter_mut().enumerate() {
            let idx = r * w + i;
            *slot = if idx < a.len() { a[idx] } else { T::SENTINEL };
        }
    };
    let fetch_row_b = |r: usize, c: &mut [T]| {
        for (i, slot) in c.iter_mut().enumerate() {
            let idx = r * w + (w - 1 - i);
            *slot = if idx < b.len() { b[idx] } else { T::SENTINEL };
        }
    };

    let mut c_a = vec![T::SENTINEL; w];
    let mut c_b = vec![T::SENTINEL; w];
    let mut c_r = vec![T::SENTINEL; w];
    // Init: candidates are row 0 of A (in cA, src=1) and reversed row 0
    // of B (in cR); row 1 of B is prefetched into cB.
    fetch_row_a(0, &mut c_a);
    fetch_row_b(0, &mut c_r);
    fetch_row_b(1, &mut c_b);
    stats.rows_a = 1;
    stats.rows_b = 2;
    let mut src = vec![true; w]; // true: survivor cR plays the B side
    let mut row_a = 1usize; // next unfetched A row
    let mut row_b = 2usize;

    let mut chosen = vec![T::SENTINEL; w];
    let mut dir = vec![false; w]; // false: winner from A-side
    let steps = total.div_ceil(w);
    for _ in 0..steps {
        for i in 0..w {
            let a_cand = if src[i] { c_a[i] } else { c_r[i] };
            let b_cand = if src[i] { c_r[i] } else { c_b[i] };
            let take_a = a_cand > b_cand;
            chosen[i] = if take_a { a_cand } else { b_cand };
            dir[i] = !take_a;
        }
        let d0 = dir[0];
        // Lanes that consumed their survivor refill cR from the side d0's
        // row register (algorithm 4 lines 15–18); `src` follows MAX_0.
        for i in 0..w {
            let consumed_survivor = src[i] == dir[i]; // (src=1,dir=1)|(src=0,dir=0)
            if consumed_survivor {
                c_r[i] = if d0 { c_b[i] } else { c_a[i] };
                src[i] = d0;
            }
        }
        // Collective whole-row fetch (algorithm 4 line 21).
        if d0 {
            fetch_row_b(row_b, &mut c_b);
            row_b += 1;
            stats.rows_b += 1;
        } else {
            fetch_row_a(row_a, &mut c_a);
            row_a += 1;
            stats.rows_a += 1;
        }
        stats.cycles += 1;

        let mut chunk = chosen.clone();
        butterfly_desc(&mut chunk);
        out.extend_from_slice(&chunk);
    }
    out.truncate(total);
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_sorted_pair, gen_u32, Distribution};
    use crate::util::rng::Rng;

    fn oracle(a: &[u32], b: &[u32]) -> Vec<u32> {
        let mut v: Vec<u32> = a.iter().chain(b.iter()).copied().collect();
        v.sort_unstable_by(|x, y| y.cmp(x));
        v
    }

    #[test]
    fn matches_oracle_random() {
        let mut rng = Rng::new(41);
        for wexp in 1..=6 {
            let w = 1 << wexp;
            for _ in 0..20 {
                let (na, nb) = (rng.range(0, 400), rng.range(0, 400));
                let (a, b) = gen_sorted_pair(&mut rng, na, nb, Distribution::Uniform, gen_u32);
                let (out, _) = merge_flimsj(&a, &b, w);
                assert_eq!(out, oracle(&a, &b), "w={w} |a|={} |b|={}", a.len(), b.len());
            }
        }
    }

    #[test]
    fn matches_oracle_duplicates() {
        let mut rng = Rng::new(42);
        for _ in 0..30 {
            let (na, nb) = (rng.range(0, 200), rng.range(0, 200));
            let (a, b) = gen_sorted_pair(&mut rng, na, nb, Distribution::DupHeavy { alphabet: 2 }, gen_u32);
            let (out, _) = merge_flimsj(&a, &b, 8);
            assert_eq!(out, oracle(&a, &b));
        }
    }

    #[test]
    fn one_sided_inputs() {
        let mut rng = Rng::new(43);
        let (a, _) = gen_sorted_pair(&mut rng, 128, 0, Distribution::Uniform, gen_u32);
        let (out, _) = merge_flimsj(&a, &[], 8);
        assert_eq!(out, a);
        let (out, _) = merge_flimsj(&[], &a, 8);
        assert_eq!(out, a);
    }

    #[test]
    fn whole_rows_fetched_match_consumption() {
        // FLiMSj's defining property: rows fetched (beyond the 3-row
        // prime) equals cycles run — exactly one per cycle.
        let mut rng = Rng::new(44);
        let (a, b) = gen_sorted_pair(&mut rng, 512, 512, Distribution::Uniform, gen_u32);
        let (out, stats) = merge_flimsj(&a, &b, 16);
        assert_eq!(out, oracle(&a, &b));
        assert_eq!(stats.rows_a + stats.rows_b, 3 + stats.cycles);
        assert_eq!(stats.cycles, (a.len() + b.len()) / 16);
    }

    #[test]
    fn dominated_input() {
        // All of A above all of B: fetch pattern is maximally one-sided.
        let a: Vec<u32> = (1000..1064).rev().collect();
        let b: Vec<u32> = (0..64).rev().collect();
        let (out, _) = merge_flimsj(&a, &b, 8);
        assert_eq!(out, oracle(&a, &b));
    }
}
