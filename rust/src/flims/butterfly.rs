//! The CAS network of FLiMS: a `log2(w)`-stage butterfly — the bitonic
//! partial merger *minus its first stage* (paper §3.2, fig. 9).
//!
//! It is not a sorting network for arbitrary input, but it sorts every
//! (cyclic rotation of a) bitonic sequence, which is exactly what the
//! selector stage emits (paper proof §5.1).

use crate::key::Item;

/// Sort a rotated-bitonic slice descending in place.
///
/// `x.len()` must be a power of two. Stage strides go w/2, w/4, …, 1 —
/// the classic butterfly topology; each pair is a compare-and-swap (CAS)
/// with the larger element moving to the lower index.
#[inline]
pub fn butterfly_desc<T: Item>(x: &mut [T]) {
    let w = x.len();
    debug_assert!(w.is_power_of_two());
    let mut stride = w / 2;
    while stride >= 1 {
        let mut g = 0;
        while g < w {
            for i in g..g + stride {
                let (a, b) = (x[i], x[i + stride]);
                // CAS: max to the top (descending).
                let swap = b.key() > a.key();
                x[i] = if swap { b } else { a };
                x[i + stride] = if swap { a } else { b };
            }
            g += 2 * stride;
        }
        stride /= 2;
    }
}

/// Const-width butterfly over an array — monomorphized so the compiler
/// fully unrolls the stage loops (the software analogue of instantiating
/// the CAS network at a fixed `w` in hardware).
#[inline]
pub fn butterfly_desc_w<T: Item, const W: usize>(x: &mut [T; W]) {
    let mut stride = W / 2;
    while stride >= 1 {
        let mut g = 0;
        while g < W {
            for i in g..g + stride {
                let (a, b) = (x[i], x[i + stride]);
                let swap = b.key() > a.key();
                x[i] = if swap { b } else { a };
                x[i + stride] = if swap { a } else { b };
            }
            g += 2 * stride;
        }
        stride /= 2;
    }
}

/// Number of CAS units in the butterfly: `(w/2)·log2(w)` — the paper's
/// `½·w·log2(w)` term in Table 2.
pub fn cas_count(w: usize) -> usize {
    debug_assert!(w.is_power_of_two());
    (w / 2) * w.trailing_zeros() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::key::is_sorted_desc;
    use crate::util::rng::Rng;

    fn bitonic(rng: &mut Rng, w: usize) -> Vec<u32> {
        // ascending prefix + descending suffix of random data
        let mut v: Vec<u32> = (0..w).map(|_| rng.below(50) as u32).collect();
        let k = rng.below(w as u64 + 1) as usize;
        v[..k].sort_unstable();
        v[k..].sort_unstable_by(|a, b| b.cmp(a));
        v
    }

    #[test]
    fn sorts_bitonic_sequences() {
        let mut rng = Rng::new(1);
        for wexp in 1..=7 {
            let w = 1 << wexp;
            for _ in 0..50 {
                let mut v = bitonic(&mut rng, w);
                let mut expect = v.clone();
                expect.sort_unstable_by(|a, b| b.cmp(a));
                butterfly_desc(&mut v);
                assert_eq!(v, expect, "w={w}");
            }
        }
    }

    #[test]
    fn sorts_rotated_bitonic_sequences() {
        let mut rng = Rng::new(2);
        for wexp in 1..=6 {
            let w = 1 << wexp;
            for _ in 0..50 {
                let mut v = bitonic(&mut rng, w);
                let r = rng.below(w as u64) as usize;
                v.rotate_left(r);
                let mut expect = v.clone();
                expect.sort_unstable_by(|a, b| b.cmp(a));
                butterfly_desc(&mut v);
                assert_eq!(v, expect, "w={w} rot={r}");
            }
        }
    }

    #[test]
    fn does_not_sort_arbitrary_input() {
        // Sanity: the butterfly alone is not a sorting network (§3.2).
        let mut v = vec![3u32, 9, 1, 7];
        butterfly_desc(&mut v);
        assert!(!is_sorted_desc(&v));
    }

    #[test]
    fn const_width_matches_dynamic() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let v = bitonic(&mut rng, 16);
            let mut a: [u32; 16] = v.clone().try_into().unwrap();
            let mut b = v.clone();
            butterfly_desc_w(&mut a);
            butterfly_desc(&mut b);
            assert_eq!(a.to_vec(), b);
        }
    }

    #[test]
    fn cas_counts_match_paper_formula() {
        // ½ w log2 w
        assert_eq!(cas_count(2), 1);
        assert_eq!(cas_count(4), 4);
        assert_eq!(cas_count(8), 12);
        assert_eq!(cas_count(16), 32);
        assert_eq!(cas_count(512), 2304);
    }

    #[test]
    fn width_one_is_noop() {
        let mut v = [5u32];
        butterfly_desc(&mut v);
        assert_eq!(v, [5]);
    }
}
