//! Multi-threaded FLiMS sort (paper §8.2's OpenMP variant): the
//! sort-in-chunks pass runs on all cores over equal slices, then each
//! merge-pass level distributes its independent pair-merges across the
//! pool — "a similar loop initiates multiple instances of the FLiMS-based
//! merge, as long as there are enough sublists in the current merge
//! iteration".
//!
//! Implemented with `std::thread::scope` (no external pool crate): each
//! pass spawns at most `threads` workers over disjoint output regions, so
//! no synchronisation beyond the pass barrier is needed — the same
//! barrier structure as a PMT level.

use crate::flims::simd::{merge_desc_kernel_slice, MergeKernel, SimdMergeable};
use crate::flims::sort::{sort_desc_with, SortConfig};

/// Parallel sort configuration.
#[derive(Clone, Copy, Debug)]
pub struct ParSortConfig {
    pub base: SortConfig,
    /// worker threads (`0` = all available)
    pub threads: usize,
    /// below this, fall back to single-threaded sort
    pub seq_cutoff: usize,
    /// merge-kernel tier for the per-thread sorts and the pass merges
    /// (defaults from `FLIMS_KERNEL`)
    pub kernel: MergeKernel,
}

impl Default for ParSortConfig {
    fn default() -> Self {
        ParSortConfig {
            base: SortConfig::default(),
            threads: 0,
            seq_cutoff: 1 << 15,
            kernel: MergeKernel::env_default(),
        }
    }
}

fn effective_threads(req: usize) -> usize {
    if req > 0 {
        req
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Sort descending using multiple threads.
pub fn par_sort_desc<T>(x: &mut Vec<T>, cfg: ParSortConfig)
where
    T: SimdMergeable,
{
    let n = x.len();
    let threads = effective_threads(cfg.threads);
    if n < cfg.seq_cutoff || threads == 1 {
        sort_desc_with(x, cfg.base, cfg.kernel);
        return;
    }

    // Phase 1: split into `parts` equal consecutive portions, sort each
    // on its own thread (paper: "sorting-in-chunks now happens on all
    // cores, operating on equally-sized consecutive portions").
    let parts = threads.next_power_of_two().min(64);
    let part_len = n.div_ceil(parts);
    {
        let base = cfg.base;
        let kernel = cfg.kernel;
        std::thread::scope(|s| {
            for piece in x.chunks_mut(part_len) {
                s.spawn(move || {
                    let mut v = piece.to_vec();
                    sort_desc_with(&mut v, base, kernel);
                    piece.copy_from_slice(&v);
                });
            }
        });
    }

    // Phase 2: log2(parts) merge levels; each level merges adjacent run
    // pairs in parallel (runs are `part_len`-scaled, last may be short).
    let mut scratch: Vec<T> = vec![T::SENTINEL; n];
    let mut run = part_len;
    let mut src_is_x = true;
    while run < n {
        {
            let (src, dst): (&[T], &mut [T]) = if src_is_x {
                (&x[..], &mut scratch[..])
            } else {
                (&scratch[..], &mut x[..])
            };
            let w = cfg.base.w;
            let kernel = cfg.kernel;
            std::thread::scope(|s| {
                let mut pos = 0;
                let mut dst_rest = dst;
                while pos < n {
                    let end = (pos + 2 * run).min(n);
                    let (dst_piece, rest) = dst_rest.split_at_mut(end - pos);
                    dst_rest = rest;
                    let src_a = &src[pos..(pos + run).min(end)];
                    let src_b = &src[(pos + run).min(end)..end];
                    s.spawn(move || {
                        if src_b.is_empty() {
                            dst_piece.copy_from_slice(src_a);
                        } else {
                            merge_desc_kernel_slice(src_a, src_b, w, kernel, dst_piece);
                        }
                    });
                    pos = end;
                }
            });
        }
        src_is_x = !src_is_x;
        run *= 2;
    }
    if !src_is_x {
        x.copy_from_slice(&scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_u32, Distribution};
    use crate::util::rng::Rng;

    fn check(mut v: Vec<u32>, cfg: ParSortConfig) {
        let mut expect = v.clone();
        expect.sort_unstable_by(|a, b| b.cmp(a));
        par_sort_desc(&mut v, cfg);
        assert_eq!(v, expect);
    }

    #[test]
    fn parallel_matches_sequential() {
        let mut rng = Rng::new(71);
        for n in [100usize, 40_000, 100_000, 250_000] {
            let v = gen_u32(&mut rng, n, Distribution::Uniform);
            check(
                v,
                ParSortConfig { threads: 4, seq_cutoff: 1 << 10, ..Default::default() },
            );
        }
    }

    #[test]
    fn thread_counts() {
        let mut rng = Rng::new(72);
        let v = gen_u32(&mut rng, 150_000, Distribution::Uniform);
        for t in [1usize, 2, 3, 8] {
            check(
                v.clone(),
                ParSortConfig { threads: t, seq_cutoff: 1 << 10, ..Default::default() },
            );
        }
    }

    #[test]
    fn skewed_data() {
        let mut rng = Rng::new(73);
        let v = gen_u32(&mut rng, 120_000, Distribution::DupHeavy { alphabet: 5 });
        check(
            v,
            ParSortConfig { threads: 4, seq_cutoff: 1 << 10, ..Default::default() },
        );
    }

    #[test]
    fn small_input_takes_sequential_path() {
        let mut rng = Rng::new(74);
        let v = gen_u32(&mut rng, 500, Distribution::Uniform);
        check(v, ParSortConfig::default());
    }
}
