//! Sort-in-chunks (paper §8.2): a Batcher bitonic sorting network over
//! fixed-size chunks, producing the initial sorted runs that the FLiMS
//! merge passes then combine. The paper found chunk = 512 optimal on
//! AVX2; our sort pipeline tunes this per host (see `SortConfig`).

use crate::flims::simd::{rowpair_minmax, MergeKernel, SimdMergeable};
use crate::key::Item;

/// Sort `x` descending with the full bitonic network. `x.len()` must be
/// a power of two. The stage structure (k blocks with direction flips,
/// then the butterfly cleanup strides) is the textbook network — every
/// stage is a data-independent column of CAS units, which is what makes
/// both the SIMD and hardware formulations of the paper possible.
pub fn bitonic_sort_desc<T: Item>(x: &mut [T]) {
    let n = x.len();
    debug_assert!(n.is_power_of_two());
    let mut k = 2;
    while k <= n {
        let mut j = k / 2;
        while j >= 1 {
            for i in 0..n {
                let p = i ^ j;
                if p > i {
                    // Block direction: descending overall ⇒ blocks with
                    // (i & k) == 0 sort descending.
                    let desc_block = (i & k) == 0;
                    let (a, b) = (x[i], x[p]);
                    let out_of_order = if desc_block {
                        b.key() > a.key()
                    } else {
                        a.key() > b.key()
                    };
                    if out_of_order {
                        x.swap(i, p);
                    }
                }
            }
            j /= 2;
        }
        k *= 2;
    }
}

/// Sort each `chunk`-sized run of `x` descending. `x.len()` must be a
/// multiple of `chunk`; `chunk` a power of two.
pub fn sort_chunks_desc<T: Item>(x: &mut [T], chunk: usize) {
    debug_assert!(chunk.is_power_of_two());
    debug_assert_eq!(x.len() % chunk, 0);
    for c in x.chunks_mut(chunk) {
        bitonic_sort_desc(c);
    }
}

/// Columnar (structure-of-arrays) chunk sorter — the faithful software
/// image of the paper's SIMD sort-in-chunks (§8.2): many chunks are
/// sorted *simultaneously*, with each network stage executed as
/// contiguous elementwise min/max over a row of lanes (one lane = one
/// chunk). The data is transposed into (position, lane) layout so every
/// compare-exchange column is a pair of contiguous rows — exactly what
/// the auto-vectorizer wants, and the same trick AVX2 code plays with
/// registers.
///
/// Plain keys only (`T::K == T`); `x.len()` must be a multiple of
/// `chunk`, `chunk` a power of two. Runs on the process-default merge
/// kernel; [`sort_chunks_columnar_with`] takes an explicit one.
pub fn sort_chunks_columnar<T>(x: &mut [T], chunk: usize)
where
    T: SimdMergeable,
{
    sort_chunks_columnar_with(x, chunk, MergeKernel::env_default())
}

/// [`sort_chunks_columnar`] on an explicit merge kernel: every CAS
/// column of the network runs through
/// [`rowpair_minmax`](crate::flims::simd::rowpair_minmax) — explicit
/// SIMD min/max rows when the kernel and key type allow, the scalar
/// loop otherwise (identical values either way).
pub fn sort_chunks_columnar_with<T>(x: &mut [T], chunk: usize, kernel: MergeKernel)
where
    T: SimdMergeable,
{
    debug_assert!(chunk.is_power_of_two());
    debug_assert_eq!(x.len() % chunk, 0);
    /// lanes per group: 64 u32 lanes = 256 B per row — a few cache lines.
    const G: usize = 64;
    let nchunks = x.len() / chunk;
    if nchunks == 0 {
        return;
    }
    let mut scratch: Vec<T> = vec![T::SENTINEL; chunk * G];
    let mut base = 0;
    while base < nchunks {
        let g = G.min(nchunks - base);
        let off = base * chunk;
        // Transpose in: scratch[pos * g + lane] = x[off + lane*chunk + pos].
        // Loop order: contiguous writes + strided reads (gathers), which
        // vectorizes much better than the scatter orientation.
        {
            let group = &x[off..off + g * chunk];
            for pos in 0..chunk {
                let row = &mut scratch[pos * g..pos * g + g];
                for (lane, slot) in row.iter_mut().enumerate() {
                    *slot = group[lane * chunk + pos];
                }
            }
        }
        // Bitonic network over positions; rows of g lanes vectorize.
        let mut k = 2;
        while k <= chunk {
            let mut j = k / 2;
            while j >= 1 {
                for i in 0..chunk {
                    let p = i ^ j;
                    if p > i {
                        let desc_block = (i & k) == 0;
                        // Split to get two disjoint rows.
                        let (lo, hi) = scratch.split_at_mut(p * g);
                        let row_i = &mut lo[i * g..i * g + g];
                        let row_p = &mut hi[..g];
                        if desc_block {
                            rowpair_minmax(row_i, row_p, kernel);
                        } else {
                            rowpair_minmax(row_p, row_i, kernel);
                        }
                    }
                }
                j /= 2;
            }
            k *= 2;
        }
        // Transpose out.
        for lane in 0..g {
            let dst = &mut x[off + lane * chunk..off + (lane + 1) * chunk];
            for (pos, v) in dst.iter_mut().enumerate() {
                *v = scratch[pos * g + lane];
            }
        }
        base += g;
    }
}

/// Insertion-sort fallback for short non-power-of-two tails.
pub fn insertion_sort_desc<T: Item>(x: &mut [T]) {
    for i in 1..x.len() {
        let v = x[i];
        let mut j = i;
        while j > 0 && x[j - 1].key() < v.key() {
            x[j] = x[j - 1];
            j -= 1;
        }
        x[j] = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::key::is_sorted_desc;
    use crate::util::rng::Rng;

    #[test]
    fn bitonic_sorts_all_sizes() {
        let mut rng = Rng::new(51);
        for nexp in 0..=10 {
            let n = 1 << nexp;
            for _ in 0..5 {
                let mut v: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
                let mut expect = v.clone();
                expect.sort_unstable_by(|a, b| b.cmp(a));
                bitonic_sort_desc(&mut v);
                assert_eq!(v, expect, "n={n}");
            }
        }
    }

    #[test]
    fn bitonic_exhaustive_small() {
        // 0/1 principle-ish: all 2^n boolean inputs for n=8 — if a
        // comparison network sorts all 0/1 sequences it sorts everything.
        for bits in 0u32..256 {
            let mut v: Vec<u32> = (0..8).map(|i| (bits >> i) & 1).collect();
            bitonic_sort_desc(&mut v);
            assert!(is_sorted_desc(&v), "bits={bits:#b} -> {v:?}");
        }
    }

    #[test]
    fn chunked_sort() {
        let mut rng = Rng::new(52);
        let mut v: Vec<u32> = (0..512).map(|_| rng.next_u32()).collect();
        sort_chunks_desc(&mut v, 64);
        for c in v.chunks(64) {
            assert!(is_sorted_desc(c));
        }
    }

    #[test]
    fn columnar_matches_scalar() {
        let mut rng = Rng::new(54);
        for chunk in [4usize, 32, 128, 512] {
            for nchunks in [1usize, 3, 64, 65, 130] {
                let mut v: Vec<u32> =
                    (0..chunk * nchunks).map(|_| rng.next_u32()).collect();
                let mut expect = v.clone();
                sort_chunks_desc(&mut expect, chunk);
                sort_chunks_columnar(&mut v, chunk);
                assert_eq!(v, expect, "chunk={chunk} n={nchunks}");
            }
        }
    }

    #[test]
    fn columnar_kernels_agree() {
        // The SIMD rowpair columns must leave exactly the bytes the
        // scalar columns leave — elementwise min/max is value-unique.
        let mut rng = Rng::new(55);
        for chunk in [4usize, 128] {
            for nchunks in [1usize, 64, 65] {
                let v: Vec<u32> = (0..chunk * nchunks).map(|_| rng.next_u32()).collect();
                let mut scalar = v.clone();
                sort_chunks_columnar_with(&mut scalar, chunk, MergeKernel::Scalar);
                let mut simd = v.clone();
                sort_chunks_columnar_with(&mut simd, chunk, MergeKernel::Simd);
                assert_eq!(simd, scalar, "chunk={chunk} n={nchunks}");
            }
        }
    }

    #[test]
    fn insertion_sort_small() {
        let mut rng = Rng::new(53);
        for n in 0..40 {
            let mut v: Vec<u32> = (0..n).map(|_| rng.below(10) as u32).collect();
            let mut expect = v.clone();
            expect.sort_unstable_by(|a, b| b.cmp(a));
            insertion_sort_desc(&mut v);
            assert_eq!(v, expect);
        }
    }

    #[test]
    fn duplicates_everywhere() {
        let mut v = vec![3u32; 128];
        bitonic_sort_desc(&mut v);
        assert_eq!(v, vec![3u32; 128]);
    }
}
