//! The optimized `w`-lane FLiMS merge — the hot path (paper §8's SIMD
//! role, here as branchless rust the compiler auto-vectorises).
//!
//! Two tiers:
//!
//! * [`merge_desc`] / [`merge_desc_into`] — dynamic `w`, works for any
//!   [`Item`] including payload records (pad-aware comparisons).
//! * [`merge_desc_w`] — const-generic `W`, plain-key fast path used by
//!   the sort pipeline: the selector + butterfly fully unroll, lane state
//!   lives in stack arrays (the software image of the paper's registers),
//!   and the steady-state loop runs without bounds checks.
//!
//! Plain keys may equal the sentinel — output is still the correct
//! multiset because pad values are indistinguishable from real sentinels
//! by value; for payload records use the pad-aware tier (see the
//! tie-record discussion, paper §6).

use crate::flims::butterfly::butterfly_desc_w;
use crate::key::{Item, Key};

/// Merge two descending-sorted slices; returns a new vector.
pub fn merge_desc<T: Item>(a: &[T], b: &[T], w: usize) -> Vec<T> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    merge_desc_into(a, b, w, &mut out);
    out
}

/// Merge two **ascending**-sorted slices into a new ascending vector —
/// the convenience wrapper for callers outside the paper's descending
/// convention. The inputs are merged through reversed *views* (an
/// ascending slice read back to front is descending) and only the
/// output is reversed, in place — the output buffer is the single
/// allocation, whatever the input sizes.
pub fn merge_asc<T: Item>(a: &[T], b: &[T], w: usize) -> Vec<T> {
    let mut out = Vec::new();
    merge_desc_core::<T, true>(a, b, w, &mut out);
    out.reverse();
    out
}

/// Merge two descending-sorted slices into `out` (cleared first).
///
/// Pad-aware: safe for payload records whose key equals the sentinel.
pub fn merge_desc_into<T: Item>(a: &[T], b: &[T], w: usize, out: &mut Vec<T>) {
    merge_desc_core::<T, false>(a, b, w, out);
}

/// The dynamic-width pad-aware merge, parameterised over the read
/// direction: `REV = true` indexes both inputs back to front, which is
/// how [`merge_asc`] treats ascending slices as descending ones without
/// materialising reversed copies.
fn merge_desc_core<T: Item, const REV: bool>(a: &[T], b: &[T], w: usize, out: &mut Vec<T>) {
    assert!(w.is_power_of_two());
    out.clear();
    let total = a.len() + b.len();
    out.reserve(total);
    if total == 0 {
        return;
    }
    // (item, real) lane registers; B lanes bank-reversed (§3.1).
    let fetch = |xs: &[T], idx: usize| -> (T, bool) {
        if idx < xs.len() {
            let i = if REV { xs.len() - 1 - idx } else { idx };
            (xs[i], true)
        } else {
            (T::sentinel(), false)
        }
    };
    let mut c_a: Vec<(T, bool)> = (0..w).map(|i| fetch(a, i)).collect();
    let mut c_b: Vec<(T, bool)> = (0..w).map(|i| fetch(b, w - 1 - i)).collect();
    let mut t_a = vec![0usize; w];
    let mut t_b = vec![0usize; w];
    let mut chosen: Vec<(T, bool)> = vec![(T::sentinel(), false); w];

    let steps = total.div_ceil(w);
    for _ in 0..steps {
        for i in 0..w {
            let (ka, ra) = (c_a[i].0.key(), c_a[i].1);
            let (kb, rb) = (c_b[i].0.key(), c_b[i].1);
            // Descending "greater": key, then realness (pads lose ties).
            let take_a = ka > kb || (ka == kb && ra && !rb);
            chosen[i] = if take_a { c_a[i] } else { c_b[i] };
            if take_a {
                t_a[i] += 1;
                c_a[i] = fetch(a, i + w * t_a[i]);
            } else {
                t_b[i] += 1;
                c_b[i] = fetch(b, (w - 1 - i) + w * t_b[i]);
            }
        }
        butterfly_pairs(&mut chosen);
        for &(x, real) in chosen.iter() {
            if real {
                out.push(x);
            }
        }
    }
    debug_assert_eq!(out.len(), total);
}

#[inline]
fn butterfly_pairs<T: Item>(x: &mut [(T, bool)]) {
    let w = x.len();
    let mut stride = w / 2;
    while stride >= 1 {
        let mut g = 0;
        while g < w {
            for i in g..g + stride {
                let (ka, ra) = (x[i].0.key(), x[i].1);
                let (kb, rb) = (x[i + stride].0.key(), x[i + stride].1);
                if kb > ka || (kb == ka && rb && !ra) {
                    x.swap(i, i + stride);
                }
            }
            g += 2 * stride;
        }
        stride /= 2;
    }
}

/// Const-width plain-key fast path. `T::K == T` (plain keys) is implied
/// by usage; sentinel-valued inputs keep multiset correctness.
///
/// Appends exactly `a.len() + b.len()` elements to `out`.
pub fn merge_desc_w<T, const W: usize>(a: &[T], b: &[T], out: &mut Vec<T>)
where
    T: Item<K = T> + Key,
{
    let total = a.len() + b.len();
    out.reserve(total);
    if total == 0 {
        return;
    }

    #[inline(always)]
    fn fetch<T: Item<K = T> + Key>(xs: &[T], idx: usize) -> T {
        // Sentinel beyond the end — the §3.1 end-of-stream filler.
        if idx < xs.len() {
            xs[idx]
        } else {
            T::SENTINEL
        }
    }

    let mut c_a = [T::SENTINEL; W];
    let mut c_b = [T::SENTINEL; W];
    let mut t_a = [0usize; W];
    let mut t_b = [0usize; W];
    for i in 0..W {
        c_a[i] = fetch(a, i);
        c_b[i] = fetch(b, W - 1 - i);
    }

    let base = out.len();
    let steps = total.div_ceil(W);
    let mut chosen = [T::SENTINEL; W];
    for _ in 0..steps {
        // Selector stage (algorithm 1), branch-free select.
        for i in 0..W {
            let take_a = c_a[i] > c_b[i];
            chosen[i] = if take_a { c_a[i] } else { c_b[i] };
            // Advance exactly one of the two lane cursors.
            t_a[i] += take_a as usize;
            t_b[i] += !take_a as usize;
            let na = fetch(a, i + W * t_a[i]);
            let nb = fetch(b, (W - 1 - i) + W * t_b[i]);
            c_a[i] = if take_a { na } else { c_a[i] };
            c_b[i] = if take_a { c_b[i] } else { nb };
        }
        butterfly_desc_w(&mut chosen);
        out.extend_from_slice(&chosen);
    }
    out.truncate(base + total);
}

/// Const-width plain-key merge writing into an exact-sized slice —
/// `dst.len()` must equal `a.len() + b.len()`. Used by the sort pipeline
/// so ping-pong passes never touch `Vec` lengths (the output region can
/// be the middle of a larger buffer).
pub fn merge_desc_w_slice<T, const W: usize>(a: &[T], b: &[T], dst: &mut [T])
where
    T: Item<K = T> + Key,
{
    let total = a.len() + b.len();
    debug_assert_eq!(dst.len(), total);
    if total == 0 {
        return;
    }

    #[inline(always)]
    fn fetch<T: Item<K = T> + Key>(xs: &[T], idx: usize) -> T {
        if idx < xs.len() {
            xs[idx]
        } else {
            T::SENTINEL
        }
    }

    let mut c_a = [T::SENTINEL; W];
    let mut c_b = [T::SENTINEL; W];
    for i in 0..W {
        c_a[i] = fetch(a, i);
        c_b[i] = fetch(b, W - 1 - i);
    }

    let full_steps = total / W;
    let mut chosen = [T::SENTINEL; W];
    // Incremental lane indices replace the counters: idx_a[i] always
    // points at the *next* element of bank A_i (one multiply-free
    // conditional add per lane per step).
    let mut idx_a = [0usize; W];
    let mut idx_b = [0usize; W];
    for i in 0..W {
        idx_a[i] = i + W;
        idx_b[i] = (W - 1 - i) + W;
    }

    // Phase 1 — provably in-bounds: after s steps every lane cursor is
    // at most i + W·s < min(|a|,|b|) while s < min/W, so the first
    // `safe_steps` selections need neither bounds checks nor sentinels.
    let safe_steps = (a.len() / W).min(b.len() / W).saturating_sub(1).min(full_steps);
    for s in 0..safe_steps {
        for i in 0..W {
            let take_a = c_a[i] > c_b[i];
            chosen[i] = if take_a { c_a[i] } else { c_b[i] };
            // SAFETY: idx_a[i] <= i + W*(s+1) < a.len() (resp. b) by the
            // safe_steps bound above; indices only advance on a take.
            let na = unsafe { *a.get_unchecked(idx_a[i]) };
            let nb = unsafe { *b.get_unchecked(idx_b[i]) };
            c_a[i] = if take_a { na } else { c_a[i] };
            c_b[i] = if take_a { c_b[i] } else { nb };
            idx_a[i] += if take_a { W } else { 0 };
            idx_b[i] += if take_a { 0 } else { W };
        }
        butterfly_desc_w(&mut chosen);
        dst[s * W..(s + 1) * W].copy_from_slice(&chosen);
    }

    // Phase 2 — tail with sentinel fills (end-of-stream, §3.1).
    let step = |chosen: &mut [T; W],
                c_a: &mut [T; W],
                c_b: &mut [T; W],
                idx_a: &mut [usize; W],
                idx_b: &mut [usize; W]| {
        for i in 0..W {
            let take_a = c_a[i] > c_b[i];
            chosen[i] = if take_a { c_a[i] } else { c_b[i] };
            let na = fetch(a, idx_a[i]);
            let nb = fetch(b, idx_b[i]);
            c_a[i] = if take_a { na } else { c_a[i] };
            c_b[i] = if take_a { c_b[i] } else { nb };
            idx_a[i] += if take_a { W } else { 0 };
            idx_b[i] += if take_a { 0 } else { W };
        }
        butterfly_desc_w(chosen);
    };
    for s in safe_steps..full_steps {
        step(&mut chosen, &mut c_a, &mut c_b, &mut idx_a, &mut idx_b);
        dst[s * W..(s + 1) * W].copy_from_slice(&chosen);
    }
    let rem = total % W;
    if rem > 0 {
        step(&mut chosen, &mut c_a, &mut c_b, &mut idx_a, &mut idx_b);
        dst[full_steps * W..].copy_from_slice(&chosen[..rem]);
    }
}

/// FLiMSj-style const-width merge into a slice — the *preferred faster
/// method* of paper §8.1: "pre-fetching w-sized batches … reminiscent of
/// FLiMSj". Per step the selector works purely on registers (no per-lane
/// gathers), and exactly ONE contiguous w-row is fetched from the input
/// chosen by lane 0's MAX decision (algorithm 4) — a straight memcpy the
/// auto-vectorizer loves, replacing the 2·w scattered loads of the
/// per-bank formulation.
pub fn merge_flimsj_w_slice<T, const W: usize>(a: &[T], b: &[T], dst: &mut [T])
where
    T: Item<K = T> + Key,
{
    let total = a.len() + b.len();
    debug_assert_eq!(dst.len(), total);
    if total == 0 {
        return;
    }

    #[inline(always)]
    fn fetch_row_a<T: Item<K = T> + Key, const W: usize>(a: &[T], r: usize, c: &mut [T; W]) {
        let base = r * W;
        if base + W <= a.len() {
            c.copy_from_slice(&a[base..base + W]);
        } else {
            for (i, slot) in c.iter_mut().enumerate() {
                *slot = if base + i < a.len() { a[base + i] } else { T::SENTINEL };
            }
        }
    }
    #[inline(always)]
    fn fetch_row_b<T: Item<K = T> + Key, const W: usize>(b: &[T], r: usize, c: &mut [T; W]) {
        // reversed row: lane i gets b[r*W + W-1-i]
        let base = r * W;
        if base + W <= b.len() {
            for i in 0..W {
                c[i] = b[base + W - 1 - i];
            }
        } else {
            for (i, slot) in c.iter_mut().enumerate() {
                let idx = base + W - 1 - i;
                *slot = if idx < b.len() { b[idx] } else { T::SENTINEL };
            }
        }
    }

    let mut c_a = [T::SENTINEL; W];
    let mut c_b = [T::SENTINEL; W];
    let mut c_r = [T::SENTINEL; W];
    // Init (algorithm 4): candidates = row 0 of A (cA) + reversed row 0
    // of B (cR, src=1); reversed row 1 of B prefetched into cB.
    fetch_row_a(a, 0, &mut c_a);
    fetch_row_b(b, 0, &mut c_r);
    fetch_row_b(b, 1, &mut c_b);
    let mut src = [true; W];
    let (mut row_a, mut row_b) = (1usize, 2usize);

    let mut chosen = [T::SENTINEL; W];
    let mut take_a = [false; W];
    let full_steps = total / W;
    let rem = total % W;
    let steps = full_steps + (rem > 0) as usize;
    for s in 0..steps {
        // Selector (register-only, branch-free per lane).
        for i in 0..W {
            let ac = if src[i] { c_a[i] } else { c_r[i] };
            let bc = if src[i] { c_r[i] } else { c_b[i] };
            let ta = ac > bc;
            chosen[i] = if ta { ac } else { bc };
            take_a[i] = ta;
        }
        let d0 = !take_a[0];
        // Survivor steering: lanes that consumed their cR refill it from
        // the side d0 indicates; src follows MAX_0 (algorithm 4 l.15-18).
        for i in 0..W {
            let consumed_r = src[i] != take_a[i]; // src==dir, dir = !take_a
            let refill = if d0 { c_b[i] } else { c_a[i] };
            c_r[i] = if consumed_r { refill } else { c_r[i] };
            src[i] = if consumed_r { d0 } else { src[i] };
        }
        // One whole-row fetch (algorithm 4 line 21).
        if d0 {
            fetch_row_b(b, row_b, &mut c_b);
            row_b += 1;
        } else {
            fetch_row_a(a, row_a, &mut c_a);
            row_a += 1;
        }
        butterfly_desc_w(&mut chosen);
        if s < full_steps {
            dst[s * W..(s + 1) * W].copy_from_slice(&chosen);
        } else {
            dst[s * W..].copy_from_slice(&chosen[..rem]);
        }
    }
}

/// Dynamic-width dispatch of [`merge_flimsj_w_slice`].
pub fn merge_flimsj_fast_slice<T>(a: &[T], b: &[T], w: usize, dst: &mut [T])
where
    T: Item<K = T> + Key,
{
    match w {
        2 => merge_flimsj_w_slice::<T, 2>(a, b, dst),
        4 => merge_flimsj_w_slice::<T, 4>(a, b, dst),
        8 => merge_flimsj_w_slice::<T, 8>(a, b, dst),
        16 => merge_flimsj_w_slice::<T, 16>(a, b, dst),
        32 => merge_flimsj_w_slice::<T, 32>(a, b, dst),
        64 => merge_flimsj_w_slice::<T, 64>(a, b, dst),
        128 => merge_flimsj_w_slice::<T, 128>(a, b, dst),
        256 => merge_flimsj_w_slice::<T, 256>(a, b, dst),
        _ => merge_desc_fast_slice(a, b, w, dst),
    }
}

/// Dynamic-width dispatch of [`merge_desc_w_slice`].
pub fn merge_desc_fast_slice<T>(a: &[T], b: &[T], w: usize, dst: &mut [T])
where
    T: Item<K = T> + Key,
{
    match w {
        2 => merge_desc_w_slice::<T, 2>(a, b, dst),
        4 => merge_desc_w_slice::<T, 4>(a, b, dst),
        8 => merge_desc_w_slice::<T, 8>(a, b, dst),
        16 => merge_desc_w_slice::<T, 16>(a, b, dst),
        32 => merge_desc_w_slice::<T, 32>(a, b, dst),
        64 => merge_desc_w_slice::<T, 64>(a, b, dst),
        128 => merge_desc_w_slice::<T, 128>(a, b, dst),
        256 => merge_desc_w_slice::<T, 256>(a, b, dst),
        _ => {
            let mut tmp = Vec::new();
            merge_desc_into(a, b, w, &mut tmp);
            dst.copy_from_slice(&tmp);
        }
    }
}

/// Dynamic dispatch over the supported const widths.
pub fn merge_desc_fast<T>(a: &[T], b: &[T], w: usize, out: &mut Vec<T>)
where
    T: Item<K = T> + Key,
{
    match w {
        2 => merge_desc_w::<T, 2>(a, b, out),
        4 => merge_desc_w::<T, 4>(a, b, out),
        8 => merge_desc_w::<T, 8>(a, b, out),
        16 => merge_desc_w::<T, 16>(a, b, out),
        32 => merge_desc_w::<T, 32>(a, b, out),
        64 => merge_desc_w::<T, 64>(a, b, out),
        128 => merge_desc_w::<T, 128>(a, b, out),
        256 => merge_desc_w::<T, 256>(a, b, out),
        _ => {
            let mut tmp = Vec::new();
            merge_desc_into(a, b, w, &mut tmp);
            out.extend_from_slice(&tmp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_sorted_pair, gen_u32, Distribution};
    use crate::key::Kv;
    use crate::util::rng::Rng;

    fn oracle<T: Item>(a: &[T], b: &[T]) -> Vec<T> {
        let mut v: Vec<T> = a.iter().chain(b.iter()).copied().collect();
        v.sort_by(|x, y| y.key().cmp(&x.key()));
        v
    }

    #[test]
    fn dynamic_matches_oracle() {
        let mut rng = Rng::new(21);
        for wexp in 0..=6 {
            let w = 1 << wexp;
            for _ in 0..15 {
                let (na, nb) = (rng.range(0, 300), rng.range(0, 300));
                let (a, b) = gen_sorted_pair(&mut rng, na, nb, Distribution::Uniform, gen_u32);
                assert_eq!(merge_desc(&a, &b, w), oracle(&a, &b), "w={w}");
            }
        }
    }

    #[test]
    fn const_width_matches_oracle() {
        let mut rng = Rng::new(22);
        for _ in 0..30 {
            let (na, nb) = (rng.range(0, 500), rng.range(0, 500));
            let (a, b) = gen_sorted_pair(&mut rng, na, nb, Distribution::Uniform, gen_u32);
            let mut out = Vec::new();
            merge_desc_w::<u32, 16>(&a, &b, &mut out);
            assert_eq!(out, oracle(&a, &b));
        }
    }

    #[test]
    fn const_width_all_widths() {
        let mut rng = Rng::new(23);
        let (a, b) = gen_sorted_pair(&mut rng, 700, 300, Distribution::Uniform, gen_u32);
        let expect = oracle(&a, &b);
        for w in [2usize, 4, 8, 16, 32, 64, 128, 256] {
            let mut out = Vec::new();
            merge_desc_fast(&a, &b, w, &mut out);
            assert_eq!(out, expect, "w={w}");
        }
    }

    #[test]
    fn flimsj_slice_matches_oracle() {
        let mut rng = Rng::new(26);
        for w in [2usize, 4, 8, 16, 32] {
            for _ in 0..20 {
                let (na, nb) = (rng.range(0, 700), rng.range(0, 700));
                let (a, b) = gen_sorted_pair(&mut rng, na, nb, Distribution::Uniform, gen_u32);
                let mut dst = vec![0u32; na + nb];
                merge_flimsj_fast_slice(&a, &b, w, &mut dst);
                assert_eq!(dst, oracle(&a, &b), "w={w} na={na} nb={nb}");
            }
        }
    }

    #[test]
    fn flimsj_slice_duplicates_and_dominance() {
        let mut rng = Rng::new(27);
        for _ in 0..20 {
            let (na, nb) = (rng.range(0, 300), rng.range(0, 300));
            let (a, b) = gen_sorted_pair(&mut rng, na, nb, Distribution::DupHeavy { alphabet: 2 }, gen_u32);
            let mut dst = vec![0u32; na + nb];
            merge_flimsj_fast_slice(&a, &b, 8, &mut dst);
            assert_eq!(dst, oracle(&a, &b));
        }
        // one-sided
        let a: Vec<u32> = (0..100u32).rev().collect();
        let mut dst = vec![0u32; 100];
        merge_flimsj_fast_slice(&a, &[], 16, &mut dst);
        assert_eq!(dst, a);
        let mut dst = vec![0u32; 100];
        merge_flimsj_fast_slice(&[], &a, 16, &mut dst);
        assert_eq!(dst, a);
    }

    #[test]
    fn merge_asc_matches_sorted_union() {
        let mut rng = Rng::new(28);
        for _ in 0..20 {
            let (na, nb) = (rng.range(0, 200), rng.range(0, 200));
            let mut a: Vec<u32> = (0..na).map(|_| rng.next_u32()).collect();
            let mut b: Vec<u32> = (0..nb).map(|_| rng.next_u32()).collect();
            a.sort_unstable();
            b.sort_unstable();
            let mut expect: Vec<u32> = a.iter().chain(b.iter()).copied().collect();
            expect.sort_unstable();
            assert_eq!(merge_asc(&a, &b, 8), expect);
        }
        assert_eq!(merge_asc::<u32>(&[], &[], 4), Vec::<u32>::new());
        assert_eq!(merge_asc(&[1u32, 5], &[], 4), vec![1, 5]);
    }

    #[test]
    fn zero_and_sentinel_values() {
        // u32 sentinel is 0; zeros in the payload must survive by value.
        let a = vec![9u32, 4, 0, 0];
        let b = vec![7u32, 0];
        assert_eq!(merge_desc(&a, &b, 4), vec![9, 7, 4, 0, 0, 0]);
        let mut out = Vec::new();
        merge_desc_w::<u32, 4>(&a, &b, &mut out);
        assert_eq!(out, vec![9, 7, 4, 0, 0, 0]);
    }

    #[test]
    fn records_with_sentinel_keys_keep_payloads() {
        let a = vec![Kv::new(3, 10), Kv::new(0, 11)];
        let b = vec![Kv::new(0, 12), Kv::new(0, 13)];
        let out = merge_desc(&a, &b, 8);
        let mut vals: Vec<u32> = out.iter().map(|k| k.val).collect();
        vals.sort_unstable();
        assert_eq!(vals, vec![10, 11, 12, 13]);
    }

    #[test]
    fn dup_heavy_all_widths() {
        let mut rng = Rng::new(24);
        for w in [4usize, 16, 64] {
            let (a, b) = gen_sorted_pair(
                &mut rng,
                256,
                128,
                Distribution::DupHeavy { alphabet: 2 },
                gen_u32,
            );
            let mut out = Vec::new();
            merge_desc_fast(&a, &b, w, &mut out);
            assert_eq!(out, oracle(&a, &b), "w={w}");
        }
    }

    #[test]
    fn empty_sides() {
        let mut out = Vec::new();
        merge_desc_w::<u32, 8>(&[], &[], &mut out);
        assert!(out.is_empty());
        merge_desc_w::<u32, 8>(&[5, 1], &[], &mut out);
        assert_eq!(out, vec![5, 1]);
        out.clear();
        merge_desc_w::<u32, 8>(&[], &[9, 2], &mut out);
        assert_eq!(out, vec![9, 2]);
    }

    #[test]
    fn appends_without_clobbering() {
        let mut out = vec![111u32];
        merge_desc_w::<u32, 4>(&[5, 3], &[4, 2], &mut out);
        assert_eq!(out, vec![111, 5, 4, 3, 2]);
    }

    #[test]
    fn u64_and_i32_keys() {
        let mut rng = Rng::new(25);
        let a64: Vec<u64> = {
            let mut v: Vec<u64> = (0..100).map(|_| rng.next_u64()).collect();
            v.sort_unstable_by(|x, y| y.cmp(x));
            v
        };
        let b64: Vec<u64> = {
            let mut v: Vec<u64> = (0..77).map(|_| rng.next_u64()).collect();
            v.sort_unstable_by(|x, y| y.cmp(x));
            v
        };
        let mut out = Vec::new();
        merge_desc_w::<u64, 8>(&a64, &b64, &mut out);
        assert_eq!(out, oracle(&a64, &b64));

        let ai: Vec<i32> = {
            let mut v: Vec<i32> = (0..64).map(|_| rng.next_u32() as i32).collect();
            v.sort_unstable_by(|x, y| y.cmp(x));
            v
        };
        let bi: Vec<i32> = {
            let mut v: Vec<i32> = (0..32).map(|_| rng.next_u32() as i32).collect();
            v.sort_unstable_by(|x, y| y.cmp(x));
            v
        };
        let mut out = Vec::new();
        merge_desc_w::<i32, 16>(&ai, &bi, &mut out);
        assert_eq!(out, oracle(&ai, &bi));
    }
}
