//! Hardware-faithful FLiMS merger (paper §3, algorithm 1; §4.1,
//! algorithm 2): per-bank FIFO queues, `w` distributed MAX units with
//! `cA`/`cB` head registers, and per-cycle execution with optional trace
//! capture — the model behind the Table 1 example and the oracle the
//! cycle-accurate `hw::` netlists are checked against.
//!
//! This module favours clarity and observability over speed; the fast
//! path lives in [`super::lanes`].

use crate::key::Item;

/// Which MAX-unit algorithm runs in the selector stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    /// Algorithm 1: ties dequeue from B.
    Basic,
    /// Algorithm 2: 1-bit `dir` register appended as comparison LSB makes
    /// duplicate runs alternate sources (the §4.1 skew optimisation).
    Skew,
}

/// A lane slot: a record plus a validity flag. Pads (end-of-stream
/// filler, paper §3.1) always compare below real records so payload
/// records whose key equals the sentinel are never displaced.
#[derive(Clone, Copy, Debug)]
struct Slot<T> {
    item: T,
    real: bool,
}

impl<T: Item> Slot<T> {
    fn pad() -> Self {
        Slot { item: T::sentinel(), real: false }
    }
    /// Descending-order "greater than": real beats pad on key ties.
    #[inline]
    fn gt(&self, other: &Slot<T>) -> bool {
        match self.item.key().cmp(&other.item.key()) {
            std::cmp::Ordering::Greater => true,
            std::cmp::Ordering::Less => false,
            std::cmp::Ordering::Equal => self.real && !other.real,
        }
    }
}

/// Per-cycle dequeue statistics — the observable the §4.1 skew
/// experiments measure (balanced consumption of A and B).
#[derive(Clone, Debug, Default)]
pub struct MergeStats {
    pub cycles: usize,
    pub dequeued_a: usize,
    pub dequeued_b: usize,
    /// Maximum over cycles of |cumulative dequeues from A − from B|: the
    /// rate-mismatch measure of §4.1. Algorithm 2 bounds this near `w`
    /// on duplicate runs; algorithm 1 lets it grow with the run length.
    pub max_cum_imbalance: usize,
}

/// One captured cycle for Table-1 style traces.
#[derive(Clone, Debug)]
pub struct TraceCycle {
    pub cycle: usize,
    pub c_a: Vec<Option<String>>,
    pub c_b: Vec<Option<String>>,
    pub output: Vec<String>,
}

/// Full execution trace (paper Table 1).
#[derive(Clone, Debug, Default)]
pub struct MergeTrace {
    pub cycles: Vec<TraceCycle>,
}

impl MergeTrace {
    /// Render as an aligned text table resembling the paper's Table 1.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("cycle | cA | cB | output chunk\n");
        for c in &self.cycles {
            let f = |v: &Vec<Option<String>>| {
                v.iter()
                    .map(|x| x.clone().unwrap_or_else(|| "-".into()))
                    .collect::<Vec<_>>()
                    .join(" ")
            };
            out.push_str(&format!(
                "{:>5} | {} | {} | {}\n",
                c.cycle,
                f(&c.c_a),
                f(&c.c_b),
                c.output.join(" ")
            ));
        }
        out
    }
}

/// The hardware-faithful streaming merger.
///
/// Banks are modelled as cursors into the input slices (elements are
/// stored round-robin across banks, so bank `i` of `A` serves
/// `a[i], a[i+w], …` — paper §3.1).
pub struct FlimsMerger<'a, T: Item> {
    w: usize,
    variant: Variant,
    a: &'a [T],
    b: &'a [T],
    /// per-lane next fetch count for bank A_i / B_{w-1-i}
    t_a: Vec<usize>,
    t_b: Vec<usize>,
    c_a: Vec<Slot<T>>,
    c_b: Vec<Slot<T>>,
    dir: Vec<bool>,
    pub stats: MergeStats,
}

impl<'a, T: Item> FlimsMerger<'a, T> {
    pub fn new(a: &'a [T], b: &'a [T], w: usize, variant: Variant) -> Self {
        assert!(w.is_power_of_two(), "w must be a power of two");
        let fetch = |xs: &[T], idx: usize| -> Slot<T> {
            xs.get(idx)
                .map(|&item| Slot { item, real: true })
                .unwrap_or_else(Slot::pad)
        };
        // Lane i holds head of bank A_i and head of bank B_{w-1-i}.
        let c_a: Vec<_> = (0..w).map(|i| fetch(a, i)).collect();
        let c_b: Vec<_> = (0..w).map(|i| fetch(b, w - 1 - i)).collect();
        FlimsMerger {
            w,
            variant,
            a,
            b,
            t_a: vec![0; w],
            t_b: vec![0; w],
            c_a,
            c_b,
            dir: vec![false; w],
            stats: MergeStats::default(),
        }
    }

    /// Total cycles needed to drain both inputs.
    pub fn total_cycles(&self) -> usize {
        (self.a.len() + self.b.len()).div_ceil(self.w)
    }

    /// Execute one cycle: the selector stage picks the top `w`, the CAS
    /// network sorts it, and the chosen lanes refill from their banks.
    /// Returns the `w`-sized output chunk (pads stripped).
    pub fn step(&mut self) -> Vec<T> {
        let w = self.w;
        let mut chosen: Vec<Slot<T>> = Vec::with_capacity(w);
        let mut take_a_mask = vec![false; w];
        for i in 0..w {
            let (ca, cb) = (self.c_a[i], self.c_b[i]);
            let take_a = match self.variant {
                Variant::Basic => ca.gt(&cb),
                Variant::Skew => {
                    // Algorithm 2: {cA, dir} > {cB, !dir} — the 1-bit
                    // history appended as LSB flips tie outcomes so
                    // duplicate runs alternate sources.
                    if ca.item.key() != cb.item.key() || ca.real != cb.real {
                        ca.gt(&cb)
                    } else {
                        self.dir[i]
                    }
                }
            };
            take_a_mask[i] = take_a;
            chosen.push(if take_a { ca } else { cb });
        }
        // Refill fired lanes from their banks (round-robin addressing).
        for i in 0..w {
            if take_a_mask[i] {
                self.t_a[i] += 1;
                let idx = i + w * self.t_a[i];
                self.c_a[i] = self
                    .a
                    .get(idx)
                    .map(|&item| Slot { item, real: true })
                    .unwrap_or_else(Slot::pad);
                self.dir[i] = false; // dir=0: took from A (alg 2 line 9)
                if chosen[i].real {
                    self.stats.dequeued_a += 1;
                }
            } else {
                self.t_b[i] += 1;
                let idx = (w - 1 - i) + w * self.t_b[i];
                self.c_b[i] = self
                    .b
                    .get(idx)
                    .map(|&item| Slot { item, real: true })
                    .unwrap_or_else(Slot::pad);
                self.dir[i] = true; // dir=1: took from B (alg 2 line 13)
                if chosen[i].real {
                    self.stats.dequeued_b += 1;
                }
            }
        }
        self.stats.cycles += 1;
        let cum = self.stats.dequeued_a.abs_diff(self.stats.dequeued_b);
        self.stats.max_cum_imbalance = self.stats.max_cum_imbalance.max(cum);

        // CAS network sorts the (rotated-bitonic) selection.
        butterfly_slots(&mut chosen);
        chosen
            .into_iter()
            .filter(|s| s.real)
            .map(|s| s.item)
            .collect()
    }

    /// Drain everything into a vector.
    pub fn run(mut self) -> (Vec<T>, MergeStats) {
        let total = self.a.len() + self.b.len();
        let mut out = Vec::with_capacity(total);
        for _ in 0..self.total_cycles() {
            out.extend(self.step());
        }
        debug_assert_eq!(out.len(), total);
        (out, self.stats)
    }

    /// Drain with a Table-1 style trace (records `cA`/`cB` registers and
    /// output chunk per cycle).
    pub fn run_traced(mut self) -> (Vec<T>, MergeTrace) {
        let total = self.a.len() + self.b.len();
        let mut out = Vec::with_capacity(total);
        let mut trace = MergeTrace::default();
        for cycle in 0..self.total_cycles() {
            let fmt = |v: &Vec<Slot<T>>| {
                v.iter()
                    .map(|s| s.real.then(|| format!("{:?}", s.item.key())))
                    .collect()
            };
            let c_a = fmt(&self.c_a);
            let c_b = fmt(&self.c_b);
            let chunk = self.step();
            trace.cycles.push(TraceCycle {
                cycle: cycle + 1,
                c_a,
                c_b,
                output: chunk.iter().map(|x| format!("{:?}", x.key())).collect(),
            });
            out.extend(chunk);
        }
        (out, trace)
    }
}

fn butterfly_slots<T: Item>(x: &mut [Slot<T>]) {
    // Butterfly with the pad-aware comparison (pads lose key ties).
    let w = x.len();
    let mut stride = w / 2;
    while stride >= 1 {
        let mut g = 0;
        while g < w {
            for i in g..g + stride {
                if x[i + stride].gt(&x[i]) {
                    x.swap(i, i + stride);
                }
            }
            g += 2 * stride;
        }
        stride /= 2;
    }
}

/// Merge two descending-sorted slices (algorithm 1). Convenience wrapper.
pub fn merge_basic<T: Item>(a: &[T], b: &[T], w: usize) -> Vec<T> {
    FlimsMerger::new(a, b, w, Variant::Basic).run().0
}

/// Merge with the §4.1 skewness optimisation (algorithm 2).
pub fn merge_skew<T: Item>(a: &[T], b: &[T], w: usize) -> (Vec<T>, MergeStats) {
    FlimsMerger::new(a, b, w, Variant::Skew).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_sorted_pair, gen_u32, Distribution};
    use crate::key::{is_sorted_desc, Kv};
    use crate::util::rng::Rng;

    fn oracle<T: Item>(a: &[T], b: &[T]) -> Vec<T> {
        let mut v: Vec<T> = a.iter().chain(b.iter()).copied().collect();
        v.sort_by(|x, y| y.key().cmp(&x.key()));
        v
    }

    #[test]
    fn paper_table1_example() {
        // Table 1, w=4: descending inputs; output must be the merged list.
        let a: Vec<u32> = vec![29, 26, 26, 17, 16, 11, 5, 4, 3, 3];
        let b: Vec<u32> = vec![22, 21, 19, 18, 15, 12, 9, 8, 7, 0];
        // Pad to a multiple of anything is NOT required: lengths are 10+10.
        let out = merge_basic(&a, &b, 4);
        assert_eq!(out, oracle(&a, &b));
        // First chunk should be the paper's first output row 29 26 26 22.
        assert_eq!(&out[..4], &[29, 26, 26, 22]);
    }

    #[test]
    fn random_merges_all_w() {
        let mut rng = Rng::new(11);
        for wexp in 0..=6 {
            let w = 1 << wexp;
            for _ in 0..20 {
                let n_a = rng.range(0, 200);
                let n_b = rng.range(0, 200);
                let (a, b) =
                    gen_sorted_pair(&mut rng, n_a, n_b, Distribution::Uniform, gen_u32);
                let out = merge_basic(&a, &b, w);
                assert_eq!(out, oracle(&a, &b), "w={w} nA={n_a} nB={n_b}");
            }
        }
    }

    #[test]
    fn duplicate_heavy_merges() {
        let mut rng = Rng::new(12);
        for _ in 0..20 {
            let (a, b) = gen_sorted_pair(
                &mut rng,
                96,
                96,
                Distribution::DupHeavy { alphabet: 3 },
                gen_u32,
            );
            assert_eq!(merge_basic(&a, &b, 8), oracle(&a, &b));
        }
    }

    #[test]
    fn kv_payloads_survive_sentinel_keys() {
        // Records whose key equals the sentinel (0) must keep payloads —
        // the pad-aware comparison guarantees it.
        let a = vec![Kv::new(5, 1), Kv::new(0, 2), Kv::new(0, 3)];
        let b = vec![Kv::new(0, 4)];
        let out = merge_basic(&a, &b, 4);
        let mut vals: Vec<u32> = out.iter().map(|kv| kv.val).collect();
        vals.sort_unstable();
        assert_eq!(vals, vec![1, 2, 3, 4]);
        assert!(is_sorted_desc(&out));
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(merge_basic::<u32>(&[], &[], 4), vec![]);
        assert_eq!(merge_basic(&[3u32, 1], &[], 4), vec![3, 1]);
        assert_eq!(merge_basic(&[], &[9u32], 8), vec![9]);
    }

    #[test]
    fn skew_variant_correct() {
        let mut rng = Rng::new(13);
        for _ in 0..20 {
            let (a, b) = gen_sorted_pair(
                &mut rng,
                64,
                64,
                Distribution::DupHeavy { alphabet: 2 },
                gen_u32,
            );
            let (out, _) = merge_skew(&a, &b, 8);
            assert_eq!(out, oracle(&a, &b));
        }
    }

    #[test]
    fn skew_variant_balances_duplicates() {
        // All-equal inputs: algorithm 1 drains B first (ties pick B);
        // algorithm 2 must alternate, halving the imbalance (§4.1).
        let a = vec![7u32; 256];
        let b = vec![7u32; 256];
        let w = 8;

        let mut basic = FlimsMerger::new(&a, &b, w, Variant::Basic);
        for _ in 0..basic.total_cycles() / 2 {
            basic.step();
        }
        let basic_stats = basic.stats.clone();

        let mut skew = FlimsMerger::new(&a, &b, w, Variant::Skew);
        for _ in 0..skew.total_cycles() / 2 {
            skew.step();
        }
        let skew_stats = skew.stats.clone();

        // Basic: first half of cycles dequeue only from B.
        assert_eq!(basic_stats.dequeued_a, 0);
        // Skew: both inputs consumed at a similar rate.
        let (da, db) = (skew_stats.dequeued_a, skew_stats.dequeued_b);
        assert!(
            da.abs_diff(db) <= w,
            "skew variant imbalance too high: A={da} B={db}"
        );
        // Algorithm 2 keeps cumulative imbalance bounded (≤ 2w here);
        // algorithm 1's grows with the duplicate-run length.
        assert!(skew_stats.max_cum_imbalance <= 2 * w);
        assert!(basic_stats.max_cum_imbalance >= 128 - w);
    }

    #[test]
    fn per_cycle_output_is_w_when_full() {
        let mut rng = Rng::new(14);
        let (a, b) = gen_sorted_pair(&mut rng, 64, 64, Distribution::Uniform, gen_u32);
        let mut m = FlimsMerger::new(&a, &b, 8, Variant::Basic);
        let mut prev_min: Option<u32> = None;
        for _ in 0..m.total_cycles() {
            let chunk = m.step();
            assert_eq!(chunk.len(), 8, "valid cycles emit exactly w elements");
            assert!(is_sorted_desc(&chunk));
            if let Some(p) = prev_min {
                assert!(chunk[0] <= p, "chunks must be globally descending");
            }
            prev_min = Some(*chunk.last().unwrap());
        }
    }

    #[test]
    fn trace_matches_paper_shape() {
        let a: Vec<u32> = vec![29, 26, 26, 17, 16, 11, 5, 4, 3, 3];
        let b: Vec<u32> = vec![22, 21, 19, 18, 15, 12, 9, 8, 7, 0];
        let (out, trace) = FlimsMerger::new(&a, &b, 4, Variant::Basic).run_traced();
        assert_eq!(out.len(), 20);
        assert_eq!(trace.cycles.len(), 5);
        let rendered = trace.render();
        assert!(rendered.contains("29 26 26 22") || rendered.contains("22 26 26 29"));
    }
}
