//! The FLiMS algorithm family — the paper's core contribution, as a
//! software library.
//!
//! Module map (paper section → module):
//!
//! * §3 algorithm 1 (selector stage + CAS network) →
//!   [`scalar`] (hardware-faithful, per-bank queues, trace support) and
//!   [`lanes`] (the optimized `w`-lane hot path, the §8 "SIMD" role).
//! * §4.1 algorithm 2 (skewness optimisation) → [`scalar::merge_skew`].
//! * §4.2 algorithm 3 (stable merge) → [`stable`].
//! * §4.3 algorithm 4 (FLiMSj, whole-row dequeues) → [`flimsj`].
//! * §8 explicit-SIMD kernels (selector + butterfly as `core::arch`
//!   intrinsics, runtime-dispatched) → [`simd`].
//! * §8.2 sort-in-chunks + complete sort → [`chunk_sort`], [`sort`],
//!   [`parallel`].
//!
//! Everything merges/sorts in **descending** order (the paper's
//! convention); ascending wrappers are provided on the public API.

pub mod butterfly;
pub mod chunk_sort;
pub mod flimsj;
pub mod lanes;
pub mod parallel;
pub mod scalar;
pub mod simd;
pub mod sort;
pub mod stable;

pub use butterfly::butterfly_desc;
pub use lanes::{merge_asc, merge_desc};
pub use parallel::par_sort_desc;
pub use scalar::{merge_basic, merge_skew, FlimsMerger, MergeTrace, Variant};
pub use simd::{merge_desc_kernel, merge_desc_kernel_slice, MergeKernel, SimdMergeable};
pub use sort::{sort_asc, sort_desc, SortConfig};
pub use stable::{
    merge_stable, merge_stable_into, merge_stable_simd, sort_stable_desc, sort_stable_desc_with,
    StableSimdMerge,
};
