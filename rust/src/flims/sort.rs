//! Complete FLiMS-based merge sort (paper §8.2): sort-in-chunks builds
//! the initial runs, then FLiMS merge passes double the run length until
//! one run remains. Ping-pong buffers avoid per-pass allocation.
//!
//! Handles arbitrary lengths (not just powers of two): the bulk is
//! chunk-aligned; the tail run is sorted directly and folded in by a
//! final unbalanced merge — the merger itself supports unequal inputs.

use crate::flims::chunk_sort::{insertion_sort_desc, sort_chunks_columnar_with};
use crate::flims::simd::{merge_desc_kernel_slice, MergeKernel, SimdMergeable};

/// Tuning knobs for the sort pipeline.
#[derive(Clone, Copy, Debug)]
pub struct SortConfig {
    /// Lane parallelism of the merge passes (paper fig. 14 sweeps this;
    /// 16–32 was optimal on their AVX2 host).
    pub w: usize,
    /// Initial sorted-run length (paper §8.2: 512 on their host).
    pub chunk: usize,
}

impl Default for SortConfig {
    fn default() -> Self {
        SortConfig { w: 16, chunk: 128 }
    }
}

impl SortConfig {
    pub fn validate(&self) -> Result<(), String> {
        if !self.w.is_power_of_two() {
            return Err(format!("w={} must be a power of two", self.w));
        }
        if !self.chunk.is_power_of_two() {
            return Err(format!("chunk={} must be a power of two", self.chunk));
        }
        if self.chunk < self.w {
            return Err(format!(
                "chunk={} must be >= w={}",
                self.chunk, self.w
            ));
        }
        Ok(())
    }
}

/// Sort descending in place (buffer strategy internally ping-pongs),
/// on the process-default merge kernel ([`MergeKernel::env_default`]).
pub fn sort_desc<T>(x: &mut Vec<T>, cfg: SortConfig)
where
    T: SimdMergeable,
{
    sort_desc_with(x, cfg, MergeKernel::env_default())
}

/// [`sort_desc`] on an explicit merge kernel: every merge pass (and the
/// sort-in-chunks CAS columns) dispatches through `kernel` — the seam
/// the config/CLI/service kernel knobs thread down to.
pub fn sort_desc_with<T>(x: &mut Vec<T>, cfg: SortConfig, kernel: MergeKernel)
where
    T: SimdMergeable,
{
    cfg.validate().expect("invalid SortConfig");
    let n = x.len();
    if n <= 1 {
        return;
    }
    if n < 2 * cfg.chunk {
        insertion_sort_desc(x);
        return;
    }

    // Split: chunk-aligned bulk + tail.
    let bulk = (n / cfg.chunk) * cfg.chunk;
    sort_chunks_columnar_with(&mut x[..bulk], cfg.chunk, kernel);
    insertion_sort_desc(&mut x[bulk..]);

    // Merge passes over the bulk, ping-ponging between x and a scratch
    // buffer. All writes go through exact-sized slices so the unsorted
    // tail `x[bulk..]` is never disturbed.
    //
    // The lane width adapts to the run length (fig. 14: the optimum w
    // grows with how much streaming work amortises the prime/drain):
    // short early runs use cfg.w, long streaming passes widen up to 128.
    let mut scratch: Vec<T> = vec![T::SENTINEL; n];
    let mut run = cfg.chunk;
    let mut src_is_x = true;
    while run < bulk {
        let w = adaptive_w(cfg.w, run);
        {
            let (src, dst): (&[T], &mut [T]) = if src_is_x {
                (&x[..bulk], &mut scratch[..bulk])
            } else {
                (&scratch[..bulk], &mut x[..bulk])
            };
            let mut pos = 0;
            while pos < bulk {
                let end = (pos + 2 * run).min(bulk);
                if pos + run >= end {
                    // Lone (possibly short) run: copy through.
                    dst[pos..end].copy_from_slice(&src[pos..end]);
                } else {
                    let (a, b) = (&src[pos..pos + run], &src[pos + run..end]);
                    merge_desc_kernel_slice(a, b, w, kernel, &mut dst[pos..end]);
                }
                pos = end;
            }
        }
        src_is_x = !src_is_x;
        run *= 2;
    }

    // Bring the bulk back into x if it ended in scratch.
    if !src_is_x {
        x[..bulk].copy_from_slice(&scratch[..bulk]);
    }

    // Fold in the tail (already sorted) with one unbalanced merge.
    if bulk < n {
        {
            let (head, tail) = x.split_at(bulk);
            merge_desc_kernel_slice(head, tail, cfg.w, kernel, &mut scratch[..n]);
        }
        x.copy_from_slice(&scratch[..n]);
    }
}

/// Lane width for a merge pass over runs of length `run`: at least the
/// configured `w`, widened (up to 128) once the runs are long enough to
/// amortise the wider merger's prime/drain (≈ run/2).
#[inline]
pub fn adaptive_w(base_w: usize, run: usize) -> usize {
    let cap = (run / 2).next_power_of_two().min(128).max(1);
    base_w.max(cap.min(128)).min(run.next_power_of_two())
}

/// Sort ascending in place (descending sort + reverse).
pub fn sort_asc<T>(x: &mut Vec<T>, cfg: SortConfig)
where
    T: SimdMergeable,
{
    sort_desc(x, cfg);
    x.reverse();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_u32, Distribution};
    use crate::util::rng::Rng;

    fn check(mut v: Vec<u32>, cfg: SortConfig) {
        let mut expect = v.clone();
        expect.sort_unstable_by(|a, b| b.cmp(a));
        sort_desc(&mut v, cfg);
        assert_eq!(v, expect, "cfg={cfg:?}");
    }

    #[test]
    fn sorts_various_sizes() {
        let mut rng = Rng::new(61);
        for n in [0usize, 1, 2, 7, 100, 127, 128, 129, 1000, 4096, 10_000, 65_536] {
            let v = gen_u32(&mut rng, n, Distribution::Uniform);
            check(v, SortConfig::default());
        }
    }

    #[test]
    fn sorts_all_distributions() {
        let mut rng = Rng::new(62);
        for dist in [
            Distribution::Uniform,
            Distribution::DupHeavy { alphabet: 3 },
            Distribution::SortedAsc,
            Distribution::SortedDesc,
            Distribution::Runs { run: 32 },
            Distribution::Constant,
            Distribution::Zipf { s_x100: 120, n_ranks: 64 },
        ] {
            let v = gen_u32(&mut rng, 5000, dist);
            check(v, SortConfig::default());
        }
    }

    #[test]
    fn sorts_with_all_configs() {
        let mut rng = Rng::new(63);
        let v = gen_u32(&mut rng, 20_000, Distribution::Uniform);
        for w in [4usize, 8, 16, 32, 64] {
            for chunk in [64usize, 128, 512] {
                if chunk >= w {
                    check(v.clone(), SortConfig { w, chunk });
                }
            }
        }
    }

    #[test]
    fn kernels_sort_identically() {
        // Forced-scalar and forced-SIMD pipelines must emit the same
        // bytes for every width that changes the SIMD block choice.
        let mut rng = Rng::new(66);
        let v = gen_u32(&mut rng, 30_000, Distribution::Zipf { s_x100: 120, n_ranks: 64 });
        for w in [4usize, 8, 16, 32] {
            let cfg = SortConfig { w, chunk: 128 };
            let mut scalar = v.clone();
            sort_desc_with(&mut scalar, cfg, MergeKernel::Scalar);
            let mut simd = v.clone();
            sort_desc_with(&mut simd, cfg, MergeKernel::Simd);
            assert_eq!(simd, scalar, "w={w}");
        }
        let mut v64: Vec<u64> = (0..20_000).map(|_| rng.next_u64()).collect();
        let mut expect = v64.clone();
        expect.sort_unstable_by(|a, b| b.cmp(a));
        sort_desc_with(&mut v64, SortConfig::default(), MergeKernel::Simd);
        assert_eq!(v64, expect);
    }

    #[test]
    fn ascending_wrapper() {
        let mut rng = Rng::new(64);
        let mut v = gen_u32(&mut rng, 3000, Distribution::Uniform);
        let mut expect = v.clone();
        expect.sort_unstable();
        sort_asc(&mut v, SortConfig::default());
        assert_eq!(v, expect);
    }

    #[test]
    fn u64_keys() {
        let mut rng = Rng::new(65);
        let mut v: Vec<u64> = (0..10_000).map(|_| rng.next_u64()).collect();
        let mut expect = v.clone();
        expect.sort_unstable_by(|a, b| b.cmp(a));
        sort_desc(&mut v, SortConfig::default());
        assert_eq!(v, expect);
    }

    #[test]
    fn config_validation() {
        assert!(SortConfig { w: 3, chunk: 128 }.validate().is_err());
        assert!(SortConfig { w: 16, chunk: 100 }.validate().is_err());
        assert!(SortConfig { w: 16, chunk: 8 }.validate().is_err());
        assert!(SortConfig { w: 16, chunk: 16 }.validate().is_ok());
    }
}
