//! Stable FLiMS merge (paper §4.2, algorithm 3).
//!
//! Stability: duplicates from input A precede duplicates from input B in
//! the output, and within each input the original order is kept. The
//! hardware scheme appends {source bit, 2-bit batch-order counter, port
//! number} to the key MSB-side; the 2-bit counter wraps, and the CAS
//! units special-case the `00 vs 11` comparison — earliness is only ever
//! compared between tags at distance ≤ 1, so two bits suffice (§4.2).
//!
//! This module implements the *faithful finite-tag* scheme (not a
//! widened sequence number), so the paper's claim that 2 bits are enough
//! is itself under test here.
//!
//! Next to the tagged scalar path lives the **SIMD stable tier**
//! ([`merge_stable_simd`]): payload records merge as `(key,
//! source-index)` pairs packed into the plain `u64` kernels — the index
//! breaks key ties exactly the way the tags do — and the payloads are
//! then gathered through the resulting permutation. Output is
//! byte-identical to the tagged path, so the §6 guarantee holds on both
//! tiers.

use crate::flims::simd::{MergeKernel, SimdMergeable, SIMD_MIN_SIDE};
use crate::flims::sort::SortConfig;
use crate::key::{Item, Kv, Kv64};

/// Augmented lane record: item + stability tag.
#[derive(Clone, Copy, Debug)]
struct Tagged<T> {
    item: T,
    /// true if from input A (A wins key ties — algorithm 3 line 6).
    from_a: bool,
    /// 2-bit wrapping batch-order counter (algorithm 3: starts 0,
    /// decrements per dequeue of the lane's bank).
    order: u8,
    /// port tag: `w-1-i` for A-lanes, `i` for B-lanes (algorithm 3
    /// lines 7/11) — disambiguates order inside one batch.
    port: u32,
    real: bool,
}

/// Compare two wrapping 2-bit order tags for "earlier" (greater priority
/// in descending output). Values decrement over time: 0,3,2,1,0,…
/// Adjacent comparisons: 0>3 (special case "00 beats 11"), 3>2, 2>1, 1>0.
#[inline]
fn order_earlier(a: u8, b: u8) -> std::cmp::Ordering {
    use std::cmp::Ordering::*;
    if a == b {
        return Equal;
    }
    // Special case from §4.2: "00" against "11" — 00 is earlier.
    match (a, b) {
        (0b00, 0b11) => Greater,
        (0b11, 0b00) => Less,
        // All other reachable pairs differ by one: larger = earlier.
        _ => a.cmp(&b),
    }
}

impl<T: Item> Tagged<T> {
    /// Descending priority comparison with stability tags, matching the
    /// augmented-key comparison of the modified CAS units.
    #[inline]
    fn beats(&self, other: &Tagged<T>) -> bool {
        use std::cmp::Ordering::*;
        match self.item.key().cmp(&other.item.key()) {
            Greater => true,
            Less => false,
            Equal => match (self.real, other.real) {
                (true, false) => true,
                (false, true) => false,
                (false, false) => false,
                (true, true) => match (self.from_a, other.from_a) {
                    (true, false) => true, // A-duplicates first
                    (false, true) => false,
                    _ => match order_earlier(self.order, other.order) {
                        Greater => true,
                        Less => false,
                        // Same batch: higher port tag = earlier element.
                        Equal => self.port > other.port,
                    },
                },
            },
        }
    }
}

/// Stable merge of two descending-sorted (stably) slices — algorithm 3.
pub fn merge_stable<T: Item>(a: &[T], b: &[T], w: usize) -> Vec<T> {
    let mut out = Vec::with_capacity(a.len() + b.len());
    merge_stable_into(a, b, w, &mut out);
    out
}

/// [`merge_stable`] appending into a caller-owned buffer (`out` is not
/// cleared) — the allocation-reusing form the external merge trees use
/// on every block.
pub fn merge_stable_into<T: Item>(a: &[T], b: &[T], w: usize, out: &mut Vec<T>) {
    assert!(w.is_power_of_two());
    let total = a.len() + b.len();
    out.reserve(total);
    if total == 0 {
        return;
    }
    let base = out.len();

    let fetch_a = |i: usize, t: usize| -> Option<T> { a.get(i + w * t).copied() };
    let fetch_b = |i: usize, t: usize| -> Option<T> { b.get((w - 1 - i) + w * t).copied() };

    let mut c_a: Vec<Tagged<T>> = (0..w)
        .map(|i| Tagged {
            item: fetch_a(i, 0).unwrap_or_else(T::sentinel),
            from_a: true,
            order: 0,
            port: (w - 1 - i) as u32,
            real: fetch_a(i, 0).is_some(),
        })
        .collect();
    let mut c_b: Vec<Tagged<T>> = (0..w)
        .map(|i| Tagged {
            item: fetch_b(i, 0).unwrap_or_else(T::sentinel),
            from_a: false,
            order: 0,
            port: i as u32,
            real: fetch_b(i, 0).is_some(),
        })
        .collect();
    let mut t_a = vec![0usize; w];
    let mut t_b = vec![0usize; w];
    // Per-lane 2-bit order counters (algorithm 3 lines 9/13: decrement).
    let mut order_a = vec![0u8; w];
    let mut order_b = vec![0u8; w];

    let steps = total.div_ceil(w);
    let mut chosen: Vec<Tagged<T>> = Vec::with_capacity(w);
    for _ in 0..steps {
        chosen.clear();
        for i in 0..w {
            // Algorithm 3 line 6: A wins ties.
            let take_a = c_a[i].beats(&c_b[i]);
            chosen.push(if take_a { c_a[i] } else { c_b[i] });
            if take_a {
                t_a[i] += 1;
                order_a[i] = order_a[i].wrapping_sub(1) & 0b11;
                let nxt = fetch_a(i, t_a[i]);
                c_a[i] = Tagged {
                    item: nxt.unwrap_or_else(T::sentinel),
                    from_a: true,
                    order: order_a[i],
                    port: (w - 1 - i) as u32,
                    real: nxt.is_some(),
                };
            } else {
                t_b[i] += 1;
                order_b[i] = order_b[i].wrapping_sub(1) & 0b11;
                let nxt = fetch_b(i, t_b[i]);
                c_b[i] = Tagged {
                    item: nxt.unwrap_or_else(T::sentinel),
                    from_a: false,
                    order: order_b[i],
                    port: i as u32,
                    real: nxt.is_some(),
                };
            }
        }
        // CAS network with tag-aware comparisons.
        let mut stride = w / 2;
        while stride >= 1 {
            let mut g = 0;
            while g < w {
                for i in g..g + stride {
                    if chosen[i + stride].beats(&chosen[i]) {
                        chosen.swap(i, i + stride);
                    }
                }
                g += 2 * stride;
            }
            stride /= 2;
        }
        for s in chosen.iter().filter(|s| s.real) {
            out.push(s.item);
        }
    }
    debug_assert_eq!(out.len() - base, total);
}

/// A payload record whose stable merge can ride the plain-key SIMD
/// kernels: merge `(key, source-index)` pairs — the index ordered so
/// that the packed comparison reproduces exactly the stable tie order
/// (A's records before B's, input order within each side) — then
/// gather the payloads through the resulting permutation.
pub trait StableSimdMerge: Item {
    /// Append the stable descending merge of `a` and `b` to `out`
    /// using a SIMD key–index merge. Returns `false` when no kernel
    /// fits this type or CPU (the caller takes the tagged scalar
    /// path). When it returns `true` the output is byte-identical to
    /// [`merge_stable_into`].
    fn simd_stable_merge(a: &[Self], b: &[Self], w: usize, out: &mut Vec<Self>) -> bool {
        let _ = (a, b, w, out);
        false
    }
}

/// `Kv` packs `(key << 32) | rank` into single `u64` lanes. Ranks are
/// assigned descending in stable output order — A's record `i` gets
/// `total−1−i`, B's record `j` gets `nb−1−j` — so all ranks are
/// distinct, every A rank exceeds every B rank (A wins key ties), and
/// within each side earlier records hold larger ranks. Both packed
/// arrays are then *strictly* descending, and the unique descending
/// u64 merge of them is exactly the stable merge of the records.
impl StableSimdMerge for Kv {
    fn simd_stable_merge(a: &[Self], b: &[Self], w: usize, out: &mut Vec<Self>) -> bool {
        let (na, nb) = (a.len(), b.len());
        let total = na + nb;
        if total > u32::MAX as usize || <u64 as SimdMergeable>::simd_tier() == "scalar" {
            return false;
        }
        let pa: Vec<u64> = a
            .iter()
            .enumerate()
            .map(|(i, kv)| ((kv.key as u64) << 32) | (total - 1 - i) as u64)
            .collect();
        let pb: Vec<u64> = b
            .iter()
            .enumerate()
            .map(|(j, kv)| ((kv.key as u64) << 32) | (nb - 1 - j) as u64)
            .collect();
        let mut merged = vec![0u64; total];
        if !<u64 as SimdMergeable>::simd_merge_desc(&pa, &pb, w, &mut merged) {
            return false;
        }
        out.reserve(total);
        for &p in &merged {
            let idx = (p & 0xffff_ffff) as usize;
            // A ranks occupy [nb, total); B ranks occupy [0, nb).
            out.push(if idx >= nb { a[total - 1 - idx] } else { b[nb - 1 - idx] });
        }
        true
    }
}

/// `Kv64` keys fill a whole lane, so no index rides along: SIMD-merge
/// the bare keys, then reconstruct the record order with a stable
/// two-pointer gather. At each output slot the merged key is the max
/// of the two remaining heads, so "A's head matches" is exactly the
/// stable A-wins-ties rule, and each side is consumed in input order.
impl StableSimdMerge for Kv64 {
    fn simd_stable_merge(a: &[Self], b: &[Self], w: usize, out: &mut Vec<Self>) -> bool {
        if <u64 as SimdMergeable>::simd_tier() == "scalar" {
            return false;
        }
        let (na, nb) = (a.len(), b.len());
        let ka: Vec<u64> = a.iter().map(|r| r.key).collect();
        let kb: Vec<u64> = b.iter().map(|r| r.key).collect();
        let mut merged = vec![0u64; na + nb];
        if !<u64 as SimdMergeable>::simd_merge_desc(&ka, &kb, w, &mut merged) {
            return false;
        }
        out.reserve(na + nb);
        let (mut ia, mut ib) = (0usize, 0usize);
        for &k in &merged {
            if ia < na && a[ia].key == k {
                out.push(a[ia]);
                ia += 1;
            } else {
                out.push(b[ib]);
                ib += 1;
            }
        }
        true
    }
}

/// [`merge_stable_into`] with kernel dispatch: the SIMD key–index tier
/// when the kernel asks for it and both sides can prime a block, the
/// tagged scalar path otherwise. Byte-identical either way — this is
/// the entry `ExtItem::merge_into` uses for payload records, so both
/// external-sort phases dispatch the same way.
pub fn merge_stable_simd<T: StableSimdMerge>(
    a: &[T],
    b: &[T],
    w: usize,
    kernel: MergeKernel,
    out: &mut Vec<T>,
) {
    if kernel.wants_simd()
        && a.len().min(b.len()) >= SIMD_MIN_SIDE
        && T::simd_stable_merge(a, b, w, out)
    {
        return;
    }
    merge_stable_into(a, b, w, out);
}

/// Stable descending sort of arbitrary [`Item`] records: insertion-sorted
/// base runs of `cfg.chunk` (insertion sort is stable), then bottom-up
/// [`merge_stable_into`] passes. This is the phase-1 pipeline the external
/// sort uses for payload records (`Kv`/`Kv64`), where the paper's §6
/// tie-record guarantee — ties keep input order, payloads ride untouched —
/// must hold end to end; plain keys take the faster unstable
/// [`crate::flims::sort::sort_desc`] instead.
pub fn sort_stable_desc<T: Item>(x: &mut Vec<T>, cfg: crate::flims::sort::SortConfig) {
    use crate::flims::chunk_sort::insertion_sort_desc;
    let n = x.len();
    let chunk = cfg.chunk.max(2);
    for c in x.chunks_mut(chunk) {
        insertion_sort_desc(c);
    }
    if n <= chunk {
        return;
    }
    // Ping-pong between the input buffer and a scratch vector; merging
    // adjacent runs keeps earlier-input records on the A side, so every
    // pass (and hence the whole sort) is stable.
    let mut src = std::mem::take(x);
    let mut dst: Vec<T> = Vec::with_capacity(n);
    let mut run = chunk;
    while run < n {
        dst.clear();
        let mut pos = 0;
        while pos < n {
            let end = (pos + 2 * run).min(n);
            let mid = (pos + run).min(end);
            if mid == end {
                dst.extend_from_slice(&src[pos..end]);
            } else {
                merge_stable_into(&src[pos..mid], &src[mid..end], cfg.w, &mut dst);
            }
            pos = end;
        }
        std::mem::swap(&mut src, &mut dst);
        run *= 2;
    }
    *x = src;
}

/// [`sort_stable_desc`] with kernel dispatch: every bottom-up pass
/// merges through [`merge_stable_simd`], so phase-1 chunk sorts of
/// payload records run the SIMD key–index tier too (under
/// `kernel=scalar` this is exactly [`sort_stable_desc`]).
pub fn sort_stable_desc_with<T: StableSimdMerge>(
    x: &mut Vec<T>,
    cfg: SortConfig,
    kernel: MergeKernel,
) {
    use crate::flims::chunk_sort::insertion_sort_desc;
    let n = x.len();
    let chunk = cfg.chunk.max(2);
    for c in x.chunks_mut(chunk) {
        insertion_sort_desc(c);
    }
    if n <= chunk {
        return;
    }
    let mut src = std::mem::take(x);
    let mut dst: Vec<T> = Vec::with_capacity(n);
    let mut run = chunk;
    while run < n {
        dst.clear();
        let mut pos = 0;
        while pos < n {
            let end = (pos + 2 * run).min(n);
            let mid = (pos + run).min(end);
            if mid == end {
                dst.extend_from_slice(&src[pos..end]);
            } else {
                merge_stable_simd(&src[pos..mid], &src[mid..end], cfg.w, kernel, &mut dst);
            }
            pos = end;
        }
        std::mem::swap(&mut src, &mut dst);
        run *= 2;
    }
    *x = src;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_kv, gen_kv64, Distribution};
    use crate::key::Kv;
    use crate::util::rng::Rng;

    /// Stable descending oracle: A's records precede B's on ties, each
    /// input keeps its own order.
    fn oracle(a: &[Kv], b: &[Kv]) -> Vec<Kv> {
        let mut v: Vec<(u32, usize, Kv)> = a
            .iter()
            .enumerate()
            .map(|(i, &kv)| (0, i, kv))
            .chain(b.iter().enumerate().map(|(i, &kv)| (1, i, kv)))
            .map(|(src, i, kv)| (src, i, kv))
            .collect();
        v.sort_by(|x, y| {
            y.2.key
                .cmp(&x.2.key)
                .then(x.0.cmp(&y.0))
                .then(x.1.cmp(&y.1))
        });
        v.into_iter().map(|(_, _, kv)| kv).collect()
    }

    fn sorted_kv(rng: &mut Rng, n: usize, alphabet: u32) -> Vec<Kv> {
        let mut v = gen_kv(rng, n, Distribution::DupHeavy { alphabet });
        v.sort_by(|a, b| b.key.cmp(&a.key).then(a.val.cmp(&b.val)));
        v
    }

    #[test]
    fn a_duplicates_precede_b() {
        let a = vec![Kv::new(5, 0), Kv::new(5, 1)];
        let b = vec![Kv::new(5, 100), Kv::new(5, 101)];
        let out = merge_stable(&a, &b, 4);
        assert_eq!(out, vec![Kv::new(5, 0), Kv::new(5, 1), Kv::new(5, 100), Kv::new(5, 101)]);
    }

    #[test]
    fn stable_on_duplicate_heavy_inputs() {
        let mut rng = Rng::new(31);
        for w in [2usize, 4, 8, 16] {
            for _ in 0..10 {
                let (na, nb) = (rng.range(0, 120), rng.range(0, 120));
                let a = sorted_kv(&mut rng, na, 3);
                let b = sorted_kv(&mut rng, nb, 3);
                let out = merge_stable(&a, &b, w);
                assert_eq!(out, oracle(&a, &b), "w={w}");
            }
        }
    }

    #[test]
    fn stable_on_unique_keys_matches_plain_sort() {
        let mut rng = Rng::new(32);
        let mut a: Vec<Kv> = (0..64).map(|i| Kv::new(rng.next_u32() | 1, i)).collect();
        let mut b: Vec<Kv> = (0..64).map(|i| Kv::new(rng.next_u32() | 1, 1000 + i)).collect();
        a.sort_by(|x, y| y.key.cmp(&x.key));
        b.sort_by(|x, y| y.key.cmp(&x.key));
        let out = merge_stable(&a, &b, 8);
        assert_eq!(out, oracle(&a, &b));
    }

    #[test]
    fn all_equal_keys_keeps_input_order() {
        // The hardest stability case: every key identical — output must
        // be exactly A in order, then B in order.
        for w in [2usize, 4, 8] {
            let a: Vec<Kv> = (0..4 * w as u32).map(|i| Kv::new(9, i)).collect();
            let b: Vec<Kv> = (0..4 * w as u32).map(|i| Kv::new(9, 500 + i)).collect();
            let out = merge_stable(&a, &b, w);
            let expect: Vec<Kv> = a.iter().chain(b.iter()).copied().collect();
            assert_eq!(out, expect, "w={w}");
        }
    }

    #[test]
    fn order_tag_special_case() {
        use std::cmp::Ordering::*;
        assert_eq!(order_earlier(0b00, 0b11), Greater); // the §4.2 case
        assert_eq!(order_earlier(0b11, 0b00), Less);
        assert_eq!(order_earlier(0b11, 0b10), Greater);
        assert_eq!(order_earlier(0b10, 0b01), Greater);
        assert_eq!(order_earlier(0b01, 0b00), Greater);
        assert_eq!(order_earlier(0b10, 0b10), Equal);
    }

    #[test]
    fn unequal_lengths() {
        let mut rng = Rng::new(33);
        let a = sorted_kv(&mut rng, 5, 2);
        let b = sorted_kv(&mut rng, 37, 2);
        assert_eq!(merge_stable(&a, &b, 8), oracle(&a, &b));
    }

    #[test]
    fn empty_inputs() {
        let a: Vec<Kv> = vec![];
        let b = vec![Kv::new(1, 0)];
        assert_eq!(merge_stable(&a, &b, 4), b);
        assert_eq!(merge_stable(&b, &a, 4), b);
        assert!(merge_stable(&a, &a, 4).is_empty());
    }

    #[test]
    fn merge_stable_into_appends() {
        let mut out = vec![Kv::new(99, 99)];
        merge_stable_into(&[Kv::new(5, 0)], &[Kv::new(7, 1)], 4, &mut out);
        assert_eq!(out, vec![Kv::new(99, 99), Kv::new(7, 1), Kv::new(5, 0)]);
    }

    #[test]
    fn sort_stable_desc_matches_std_stable_sort() {
        use crate::flims::sort::SortConfig;
        let mut rng = Rng::new(34);
        for n in [0usize, 1, 2, 100, 129, 1000, 5000] {
            for alphabet in [2u32, 16, 1 << 20] {
                let mut v = gen_kv(&mut rng, n, Distribution::DupHeavy { alphabet });
                let mut expect = v.clone();
                expect.sort_by(|a, b| b.key.cmp(&a.key)); // std stable sort
                sort_stable_desc(&mut v, SortConfig { w: 8, chunk: 64 });
                assert_eq!(v, expect, "n={n} alphabet={alphabet}");
            }
        }
    }

    #[test]
    fn sort_stable_desc_all_equal_keeps_order() {
        use crate::flims::sort::SortConfig;
        let mut v: Vec<Kv> = (0..3000).map(|i| Kv::new(7, i)).collect();
        let expect = v.clone();
        sort_stable_desc(&mut v, SortConfig::default());
        assert_eq!(v, expect);
    }

    #[test]
    fn kv_simd_stable_merge_matches_scalar() {
        let mut rng = Rng::new(35);
        for w in [4usize, 8, 16] {
            for alphabet in [1u32, 3, 1 << 20] {
                for _ in 0..8 {
                    let (na, nb) = (rng.range(0, 300), rng.range(0, 300));
                    let a = sorted_kv(&mut rng, na, alphabet);
                    let b = sorted_kv(&mut rng, nb, alphabet);
                    let mut scalar = Vec::new();
                    merge_stable_simd(&a, &b, w, MergeKernel::Scalar, &mut scalar);
                    let mut simd = Vec::new();
                    merge_stable_simd(&a, &b, w, MergeKernel::Simd, &mut simd);
                    assert_eq!(scalar, oracle(&a, &b), "scalar w={w}");
                    assert_eq!(simd, scalar, "simd w={w} alphabet={alphabet}");
                }
            }
        }
    }

    #[test]
    fn kv64_simd_stable_merge_matches_scalar() {
        let mut rng = Rng::new(36);
        for w in [4usize, 8] {
            for dist in [
                Distribution::Uniform,
                Distribution::DupHeavy { alphabet: 3 },
                Distribution::Zipf { s_x100: 150, n_ranks: 64 },
            ] {
                for _ in 0..6 {
                    let (na, nb) = (rng.range(0, 300), rng.range(0, 300));
                    let mk = |n: usize, rng: &mut Rng| -> Vec<Kv64> {
                        let mut v = gen_kv64(rng, n, dist);
                        v.sort_by(|a, b| b.key.cmp(&a.key).then(a.val.cmp(&b.val)));
                        v
                    };
                    let a = mk(na, &mut rng);
                    let b = mk(nb, &mut rng);
                    let mut scalar = Vec::new();
                    merge_stable_into(&a, &b, w, &mut scalar);
                    let mut simd = Vec::new();
                    merge_stable_simd(&a, &b, w, MergeKernel::Simd, &mut simd);
                    assert_eq!(simd, scalar, "w={w} dist={dist:?}");
                }
            }
        }
    }

    #[test]
    fn simd_stable_all_equal_keys_keeps_input_order() {
        // The §6 extreme on the SIMD tier: every key identical — the
        // key–index packing must emit exactly A in order, then B.
        let a: Vec<Kv> = (0..64u32).map(|i| Kv::new(9, i)).collect();
        let b: Vec<Kv> = (0..48u32).map(|i| Kv::new(9, 500 + i)).collect();
        let mut out = Vec::new();
        merge_stable_simd(&a, &b, 8, MergeKernel::Simd, &mut out);
        let expect: Vec<Kv> = a.iter().chain(b.iter()).copied().collect();
        assert_eq!(out, expect);
        let a64: Vec<Kv64> = (0..64u64).map(|i| Kv64 { key: 9, val: i }).collect();
        let b64: Vec<Kv64> = (0..48u64).map(|i| Kv64 { key: 9, val: 500 + i }).collect();
        let mut out = Vec::new();
        merge_stable_simd(&a64, &b64, 8, MergeKernel::Simd, &mut out);
        let expect: Vec<Kv64> = a64.iter().chain(b64.iter()).copied().collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn merge_stable_simd_appends() {
        let mut out = vec![Kv::new(99, 99)];
        let a: Vec<Kv> = (0..8u32).map(|i| Kv::new(50 - i, i)).collect();
        let b: Vec<Kv> = (0..8u32).map(|i| Kv::new(49 - i, 100 + i)).collect();
        merge_stable_simd(&a, &b, 4, MergeKernel::Simd, &mut out);
        assert_eq!(out[0], Kv::new(99, 99));
        let mut expect = vec![Kv::new(99, 99)];
        merge_stable_into(&a, &b, 4, &mut expect);
        assert_eq!(out, expect);
    }

    #[test]
    fn sort_stable_desc_with_matches_scalar_sort() {
        let mut rng = Rng::new(37);
        for n in [0usize, 1, 100, 1000, 5000] {
            for alphabet in [2u32, 1 << 20] {
                let v0 = gen_kv(&mut rng, n, Distribution::DupHeavy { alphabet });
                let mut scalar = v0.clone();
                sort_stable_desc(&mut scalar, SortConfig { w: 8, chunk: 64 });
                let mut simd = v0.clone();
                sort_stable_desc_with(&mut simd, SortConfig { w: 8, chunk: 64 }, MergeKernel::Simd);
                assert_eq!(simd, scalar, "n={n} alphabet={alphabet}");
            }
        }
    }
}
