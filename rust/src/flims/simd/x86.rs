//! x86_64 FLiMS merge kernels: SSE2 baseline (part of the x86_64
//! ABI — no detection needed) and AVX2 (runtime-detected once, cached).
//!
//! Every kernel is an instance of the `gen_merge!` skeleton from the
//! parent module: the §3 selector as an elementwise unsigned min/max of
//! the candidate block against the bank-reversed carry block, then the
//! §3.2 butterfly as `log2(W)` shuffle + min/max + recombine stages.
//! Multi-register blocks (W = 8 on SSE2, W = 16 on AVX2, W = 8 for
//! `u64`) add one cross-register CAS per doubling before the
//! intra-register stages — the classic bitonic-merge register network.
//!
//! SSE2 has no unsigned 32-bit min/max or 64-bit compare, so the SSE2
//! tier emulates `minmax_epu32` with a sign-bias + `cmpgt` + mask
//! select, and `u64` kernels exist only on AVX2 (whose `cmpgt_epi64` +
//! `blendv` make the emulation cheap).
//!
//! Signed keys (`i32`/`i64`) reuse the unsigned kernels through the
//! order-preserving sign-flip bias: XORing the sign bit maps signed
//! order onto unsigned order, so biased loads/stores bracket the same
//! selector + butterfly bodies and the vector math never changes.

use core::arch::x86_64::*;
use std::sync::atomic::{AtomicU8, Ordering};

/// AVX2 support, detected once via `is_x86_feature_detected!` and
/// cached (0 = unknown, 1 = absent, 2 = present).
pub(super) fn have_avx2() -> bool {
    static CACHE: AtomicU8 = AtomicU8::new(0);
    match CACHE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => {
            let v = is_x86_feature_detected!("avx2");
            CACHE.store(if v { 2 } else { 1 }, Ordering::Relaxed);
            v
        }
    }
}

// ---------------------------------------------------------------------
// SSE2 tier: u32 at W = 4 (one xmm) and W = 8 (two xmm).
// ---------------------------------------------------------------------

#[inline]
unsafe fn ld4(p: *const u32) -> __m128i {
    _mm_loadu_si128(p as *const __m128i)
}

#[inline]
unsafe fn st4(p: *mut u32, x: __m128i) {
    _mm_storeu_si128(p as *mut __m128i, x)
}

#[inline]
unsafe fn ld8(p: *const u32) -> (__m128i, __m128i) {
    (ld4(p), ld4(p.add(4)))
}

#[inline]
unsafe fn st8(p: *mut u32, x: (__m128i, __m128i)) {
    st4(p, x.0);
    st4(p.add(4), x.1);
}

/// Full lane reversal `[x3, x2, x1, x0]` — the §3.1 bank reversal.
#[inline]
unsafe fn rev4(x: __m128i) -> __m128i {
    _mm_shuffle_epi32::<0x1B>(x)
}

#[inline]
unsafe fn rev8(x: (__m128i, __m128i)) -> (__m128i, __m128i) {
    (rev4(x.1), rev4(x.0))
}

/// Elementwise unsigned (min, max) — SSE2 has no `epu32` min/max, so
/// bias both operands by the sign bit and select through the compare
/// mask.
#[inline]
unsafe fn minmax4(a: __m128i, b: __m128i) -> (__m128i, __m128i) {
    let bias = _mm_set1_epi32(i32::MIN);
    let gt = _mm_cmpgt_epi32(_mm_xor_si128(a, bias), _mm_xor_si128(b, bias));
    let mx = _mm_or_si128(_mm_and_si128(gt, a), _mm_andnot_si128(gt, b));
    let mn = _mm_or_si128(_mm_and_si128(gt, b), _mm_andnot_si128(gt, a));
    (mn, mx)
}

#[inline]
unsafe fn stage4(a: __m128i, b: __m128i) -> (__m128i, __m128i) {
    minmax4(a, b)
}

#[inline]
unsafe fn stage8(
    a: (__m128i, __m128i),
    b: (__m128i, __m128i),
) -> ((__m128i, __m128i), (__m128i, __m128i)) {
    let (l0, h0) = minmax4(a.0, b.0);
    let (l1, h1) = minmax4(a.1, b.1);
    ((l0, l1), (h0, h1))
}

/// Descending butterfly over 4 lanes: stride 2 then stride 1, maxes to
/// the lower index (§3.2).
#[inline]
unsafe fn bf4(x: __m128i) -> __m128i {
    // stride 2: pairs (0,2) and (1,3)
    let t = _mm_shuffle_epi32::<0x4E>(x); // [x2, x3, x0, x1]
    let (mn, mx) = minmax4(x, t);
    // mx = [M0, M1, M0, M1], mn = [m0, m1, m0, m1] → [M0, M1, m0, m1]
    let x = _mm_unpacklo_epi64(mx, mn);
    // stride 1: pairs (0,1) and (2,3)
    let t = _mm_shuffle_epi32::<0xB1>(x); // [x1, x0, x3, x2]
    let (mn, mx) = minmax4(x, t);
    // mx = [Ma, Ma, Mb, Mb], mn = [ma, ma, mb, mb] → [Ma, ma, Mb, mb]
    let lo = _mm_unpacklo_epi32(mx, mn);
    let hi = _mm_unpackhi_epi32(mx, mn);
    _mm_unpacklo_epi64(lo, hi)
}

/// W = 8 butterfly: one cross-register CAS (stride 4), then the 4-lane
/// butterfly in each register.
#[inline]
unsafe fn bf8(x: (__m128i, __m128i)) -> (__m128i, __m128i) {
    let (mn, mx) = minmax4(x.0, x.1);
    (bf4(mx), bf4(mn))
}

gen_merge!(merge_u32_w4_sse2, u32, 4, ld4, st4, rev4, stage4, bf4);
gen_merge!(merge_u32_w8_sse2, u32, 8, ld8, st8, rev8, stage8, bf8);

// ---------------------------------------------------------------------
// AVX2 tier: u32 at W = 8 (one ymm) and W = 16 (two ymm);
//            u64 at W = 4 (one ymm) and W = 8 (two ymm).
// ---------------------------------------------------------------------

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn ld8a(p: *const u32) -> __m256i {
    _mm256_loadu_si256(p as *const __m256i)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn st8a(p: *mut u32, x: __m256i) {
    _mm256_storeu_si256(p as *mut __m256i, x)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn ld16a(p: *const u32) -> (__m256i, __m256i) {
    (ld8a(p), ld8a(p.add(8)))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn st16a(p: *mut u32, x: (__m256i, __m256i)) {
    st8a(p, x.0);
    st8a(p.add(8), x.1);
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn rev8a(x: __m256i) -> __m256i {
    let idx = _mm256_setr_epi32(7, 6, 5, 4, 3, 2, 1, 0);
    _mm256_permutevar8x32_epi32(x, idx)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn rev16a(x: (__m256i, __m256i)) -> (__m256i, __m256i) {
    (rev8a(x.1), rev8a(x.0))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn minmax8a(a: __m256i, b: __m256i) -> (__m256i, __m256i) {
    (_mm256_min_epu32(a, b), _mm256_max_epu32(a, b))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn stage8a(a: __m256i, b: __m256i) -> (__m256i, __m256i) {
    minmax8a(a, b)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn stage16a(
    a: (__m256i, __m256i),
    b: (__m256i, __m256i),
) -> ((__m256i, __m256i), (__m256i, __m256i)) {
    let (l0, h0) = minmax8a(a.0, b.0);
    let (l1, h1) = minmax8a(a.1, b.1);
    ((l0, l1), (h0, h1))
}

/// Descending butterfly over 8 lanes: strides 4, 2, 1; maxes blend to
/// the lower indices.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn bf8a(x: __m256i) -> __m256i {
    // stride 4: swap the 128-bit halves
    let t = _mm256_permute2x128_si256::<0x01>(x, x);
    let (mn, mx) = minmax8a(x, t);
    let x = _mm256_blend_epi32::<0b1111_0000>(mx, mn);
    // stride 2
    let t = _mm256_shuffle_epi32::<0x4E>(x);
    let (mn, mx) = minmax8a(x, t);
    let x = _mm256_blend_epi32::<0b1100_1100>(mx, mn);
    // stride 1
    let t = _mm256_shuffle_epi32::<0xB1>(x);
    let (mn, mx) = minmax8a(x, t);
    _mm256_blend_epi32::<0b1010_1010>(mx, mn)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn bf16a(x: (__m256i, __m256i)) -> (__m256i, __m256i) {
    let (mn, mx) = minmax8a(x.0, x.1);
    (bf8a(mx), bf8a(mn))
}

gen_merge!(
    #[target_feature(enable = "avx2")]
    merge_u32_w8_avx2,
    u32,
    8,
    ld8a,
    st8a,
    rev8a,
    stage8a,
    bf8a
);
gen_merge!(
    #[target_feature(enable = "avx2")]
    merge_u32_w16_avx2,
    u32,
    16,
    ld16a,
    st16a,
    rev16a,
    stage16a,
    bf16a
);

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn ld4q(p: *const u64) -> __m256i {
    _mm256_loadu_si256(p as *const __m256i)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn st4q(p: *mut u64, x: __m256i) {
    _mm256_storeu_si256(p as *mut __m256i, x)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn ld8q(p: *const u64) -> (__m256i, __m256i) {
    (ld4q(p), ld4q(p.add(4)))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn st8q(p: *mut u64, x: (__m256i, __m256i)) {
    st4q(p, x.0);
    st4q(p.add(4), x.1);
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn rev4q(x: __m256i) -> __m256i {
    _mm256_permute4x64_epi64::<0x1B>(x)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn rev8q(x: (__m256i, __m256i)) -> (__m256i, __m256i) {
    (rev4q(x.1), rev4q(x.0))
}

/// Elementwise unsigned 64-bit (min, max): sign-bias + `cmpgt_epi64`,
/// then `blendv` through the lane-wide mask.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn minmax4q(a: __m256i, b: __m256i) -> (__m256i, __m256i) {
    let bias = _mm256_set1_epi64x(i64::MIN);
    let gt = _mm256_cmpgt_epi64(_mm256_xor_si256(a, bias), _mm256_xor_si256(b, bias));
    let mx = _mm256_blendv_epi8(b, a, gt);
    let mn = _mm256_blendv_epi8(a, b, gt);
    (mn, mx)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn stage4q(a: __m256i, b: __m256i) -> (__m256i, __m256i) {
    minmax4q(a, b)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn stage8q(
    a: (__m256i, __m256i),
    b: (__m256i, __m256i),
) -> ((__m256i, __m256i), (__m256i, __m256i)) {
    let (l0, h0) = minmax4q(a.0, b.0);
    let (l1, h1) = minmax4q(a.1, b.1);
    ((l0, l1), (h0, h1))
}

/// Descending butterfly over 4 u64 lanes: stride 2 then stride 1.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn bf4q(x: __m256i) -> __m256i {
    // stride 2: pairs (0,2) and (1,3)
    let t = _mm256_permute4x64_epi64::<0x4E>(x);
    let (mn, mx) = minmax4q(x, t);
    let x = _mm256_blend_epi32::<0b1111_0000>(mx, mn);
    // stride 1: pairs (0,1) and (2,3) — swap the u64 halves of each
    // 128-bit lane
    let t = _mm256_shuffle_epi32::<0x4E>(x);
    let (mn, mx) = minmax4q(x, t);
    _mm256_blend_epi32::<0b1100_1100>(mx, mn)
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn bf8q(x: (__m256i, __m256i)) -> (__m256i, __m256i) {
    let (mn, mx) = minmax4q(x.0, x.1);
    (bf4q(mx), bf4q(mn))
}

gen_merge!(
    #[target_feature(enable = "avx2")]
    merge_u64_w4_avx2,
    u64,
    4,
    ld4q,
    st4q,
    rev4q,
    stage4q,
    bf4q
);
gen_merge!(
    #[target_feature(enable = "avx2")]
    merge_u64_w8_avx2,
    u64,
    8,
    ld8q,
    st8q,
    rev8q,
    stage8q,
    bf8q
);

// ---------------------------------------------------------------------
// Signed tier: i32/i64 ride the unsigned kernels above through biased
// loads/stores (x ^ sign-bit is the order-preserving map from signed
// to unsigned order). Only the memory boundary changes; every
// selector/butterfly body is reused verbatim in the biased domain.
// ---------------------------------------------------------------------

#[inline]
unsafe fn ld4s(p: *const i32) -> __m128i {
    _mm_xor_si128(ld4(p as *const u32), _mm_set1_epi32(i32::MIN))
}

#[inline]
unsafe fn st4s(p: *mut i32, x: __m128i) {
    st4(p as *mut u32, _mm_xor_si128(x, _mm_set1_epi32(i32::MIN)))
}

#[inline]
unsafe fn ld8s(p: *const i32) -> (__m128i, __m128i) {
    (ld4s(p), ld4s(p.add(4)))
}

#[inline]
unsafe fn st8s(p: *mut i32, x: (__m128i, __m128i)) {
    st4s(p, x.0);
    st4s(p.add(4), x.1);
}

gen_merge!(merge_i32_w4_sse2, i32, 4, ld4s, st4s, rev4, stage4, bf4);
gen_merge!(merge_i32_w8_sse2, i32, 8, ld8s, st8s, rev8, stage8, bf8);

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn ld8as(p: *const i32) -> __m256i {
    _mm256_xor_si256(ld8a(p as *const u32), _mm256_set1_epi32(i32::MIN))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn st8as(p: *mut i32, x: __m256i) {
    st8a(p as *mut u32, _mm256_xor_si256(x, _mm256_set1_epi32(i32::MIN)))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn ld16as(p: *const i32) -> (__m256i, __m256i) {
    (ld8as(p), ld8as(p.add(8)))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn st16as(p: *mut i32, x: (__m256i, __m256i)) {
    st8as(p, x.0);
    st8as(p.add(8), x.1);
}

gen_merge!(
    #[target_feature(enable = "avx2")]
    merge_i32_w8_avx2,
    i32,
    8,
    ld8as,
    st8as,
    rev8a,
    stage8a,
    bf8a
);
gen_merge!(
    #[target_feature(enable = "avx2")]
    merge_i32_w16_avx2,
    i32,
    16,
    ld16as,
    st16as,
    rev16a,
    stage16a,
    bf16a
);

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn ld4qs(p: *const i64) -> __m256i {
    _mm256_xor_si256(ld4q(p as *const u64), _mm256_set1_epi64x(i64::MIN))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn st4qs(p: *mut i64, x: __m256i) {
    st4q(p as *mut u64, _mm256_xor_si256(x, _mm256_set1_epi64x(i64::MIN)))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn ld8qs(p: *const i64) -> (__m256i, __m256i) {
    (ld4qs(p), ld4qs(p.add(4)))
}

#[inline]
#[target_feature(enable = "avx2")]
unsafe fn st8qs(p: *mut i64, x: (__m256i, __m256i)) {
    st4qs(p, x.0);
    st4qs(p.add(4), x.1);
}

gen_merge!(
    #[target_feature(enable = "avx2")]
    merge_i64_w4_avx2,
    i64,
    4,
    ld4qs,
    st4qs,
    rev4q,
    stage4q,
    bf4q
);
gen_merge!(
    #[target_feature(enable = "avx2")]
    merge_i64_w8_avx2,
    i64,
    8,
    ld8qs,
    st8qs,
    rev8q,
    stage8q,
    bf8q
);

// ---------------------------------------------------------------------
// Dispatchers (safe entry points used by the SimdMergeable impls).
// ---------------------------------------------------------------------

/// Pick the kernel block width: the configured lane width clamped to
/// the supported range, halved until both inputs can prime a block.
fn pick_width(w: usize, min_side: usize, max: usize) -> usize {
    let mut width = w.clamp(4, max).next_power_of_two();
    if width > max {
        width = max;
    }
    while width > min_side {
        width /= 2;
    }
    width
}

/// u32 merge through the widest kernel the config, input sizes, and
/// CPU allow. Returns `false` (scalar fallback) only when an input side
/// cannot prime even the narrowest block.
pub(super) fn merge_desc_u32(a: &[u32], b: &[u32], w: usize, dst: &mut [u32]) -> bool {
    let width = pick_width(w, a.len().min(b.len()), 16);
    if width < 4 {
        return false;
    }
    unsafe {
        match width {
            4 => merge_u32_w4_sse2(a, b, dst),
            8 if have_avx2() => merge_u32_w8_avx2(a, b, dst),
            8 => merge_u32_w8_sse2(a, b, dst),
            _ if have_avx2() => merge_u32_w16_avx2(a, b, dst),
            _ => merge_u32_w8_sse2(a, b, dst),
        }
    }
    true
}

/// u64 merge — AVX2 only (SSE2 lacks a usable 64-bit compare).
pub(super) fn merge_desc_u64(a: &[u64], b: &[u64], w: usize, dst: &mut [u64]) -> bool {
    if !have_avx2() {
        return false;
    }
    let width = pick_width(w, a.len().min(b.len()), 8);
    if width < 4 {
        return false;
    }
    unsafe {
        if width >= 8 {
            merge_u64_w8_avx2(a, b, dst);
        } else {
            merge_u64_w4_avx2(a, b, dst);
        }
    }
    true
}

/// i32 merge — same width menu as `u32`, through the biased kernels.
pub(super) fn merge_desc_i32(a: &[i32], b: &[i32], w: usize, dst: &mut [i32]) -> bool {
    let width = pick_width(w, a.len().min(b.len()), 16);
    if width < 4 {
        return false;
    }
    unsafe {
        match width {
            4 => merge_i32_w4_sse2(a, b, dst),
            8 if have_avx2() => merge_i32_w8_avx2(a, b, dst),
            8 => merge_i32_w8_sse2(a, b, dst),
            _ if have_avx2() => merge_i32_w16_avx2(a, b, dst),
            _ => merge_i32_w8_sse2(a, b, dst),
        }
    }
    true
}

/// i64 merge — AVX2 only, like `u64`.
pub(super) fn merge_desc_i64(a: &[i64], b: &[i64], w: usize, dst: &mut [i64]) -> bool {
    if !have_avx2() {
        return false;
    }
    let width = pick_width(w, a.len().min(b.len()), 8);
    if width < 4 {
        return false;
    }
    unsafe {
        if width >= 8 {
            merge_i64_w8_avx2(a, b, dst);
        } else {
            merge_i64_w4_avx2(a, b, dst);
        }
    }
    true
}

/// Elementwise CAS column over two u32 rows (`hi` keeps maxes) — the
/// sort-in-chunks network stage, 8 lanes per step on AVX2, 4 on SSE2,
/// scalar tail.
pub(super) fn rowpair_minmax_u32(hi: &mut [u32], lo: &mut [u32]) -> bool {
    debug_assert_eq!(hi.len(), lo.len());
    unsafe {
        if have_avx2() {
            rowpair_u32_avx2(hi, lo);
        } else {
            rowpair_u32_sse2(hi, lo);
        }
    }
    true
}

unsafe fn rowpair_u32_sse2(hi: &mut [u32], lo: &mut [u32]) {
    let n = hi.len();
    let mut i = 0;
    while i + 4 <= n {
        let a = ld4(hi.as_ptr().add(i));
        let b = ld4(lo.as_ptr().add(i));
        let (mn, mx) = minmax4(a, b);
        st4(hi.as_mut_ptr().add(i), mx);
        st4(lo.as_mut_ptr().add(i), mn);
        i += 4;
    }
    super::rowpair_scalar(&mut hi[i..], &mut lo[i..]);
}

#[target_feature(enable = "avx2")]
unsafe fn rowpair_u32_avx2(hi: &mut [u32], lo: &mut [u32]) {
    let n = hi.len();
    let mut i = 0;
    while i + 8 <= n {
        let a = ld8a(hi.as_ptr().add(i));
        let b = ld8a(lo.as_ptr().add(i));
        let (mn, mx) = minmax8a(a, b);
        st8a(hi.as_mut_ptr().add(i), mx);
        st8a(lo.as_mut_ptr().add(i), mn);
        i += 8;
    }
    super::rowpair_scalar(&mut hi[i..], &mut lo[i..]);
}

/// Elementwise signed (min, max): SSE2's `cmpgt_epi32` is natively
/// signed, so no bias is needed here.
#[inline]
unsafe fn minmax4s(a: __m128i, b: __m128i) -> (__m128i, __m128i) {
    let gt = _mm_cmpgt_epi32(a, b);
    let mx = _mm_or_si128(_mm_and_si128(gt, a), _mm_andnot_si128(gt, b));
    let mn = _mm_or_si128(_mm_and_si128(gt, b), _mm_andnot_si128(gt, a));
    (mn, mx)
}

/// Elementwise CAS column over two i32 rows — native signed compares,
/// scalar tail.
pub(super) fn rowpair_minmax_i32(hi: &mut [i32], lo: &mut [i32]) -> bool {
    debug_assert_eq!(hi.len(), lo.len());
    unsafe {
        if have_avx2() {
            rowpair_i32_avx2(hi, lo);
        } else {
            rowpair_i32_sse2(hi, lo);
        }
    }
    true
}

unsafe fn rowpair_i32_sse2(hi: &mut [i32], lo: &mut [i32]) {
    let n = hi.len();
    let mut i = 0;
    while i + 4 <= n {
        let a = ld4(hi.as_ptr().add(i) as *const u32);
        let b = ld4(lo.as_ptr().add(i) as *const u32);
        let (mn, mx) = minmax4s(a, b);
        st4(hi.as_mut_ptr().add(i) as *mut u32, mx);
        st4(lo.as_mut_ptr().add(i) as *mut u32, mn);
        i += 4;
    }
    super::rowpair_scalar(&mut hi[i..], &mut lo[i..]);
}

#[target_feature(enable = "avx2")]
unsafe fn rowpair_i32_avx2(hi: &mut [i32], lo: &mut [i32]) {
    let n = hi.len();
    let mut i = 0;
    while i + 8 <= n {
        let a = ld8a(hi.as_ptr().add(i) as *const u32);
        let b = ld8a(lo.as_ptr().add(i) as *const u32);
        let mn = _mm256_min_epi32(a, b);
        let mx = _mm256_max_epi32(a, b);
        st8a(hi.as_mut_ptr().add(i) as *mut u32, mx);
        st8a(lo.as_mut_ptr().add(i) as *mut u32, mn);
        i += 8;
    }
    super::rowpair_scalar(&mut hi[i..], &mut lo[i..]);
}
