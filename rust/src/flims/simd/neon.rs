//! aarch64 NEON FLiMS merge kernels. NEON (ASIMD) is architectural on
//! aarch64, so there is no runtime detection — every kernel is always
//! available.
//!
//! Same structure as the x86 tier: the §3 selector as elementwise
//! min/max of the candidate block against the bank-reversed carry
//! block, the §3.2 butterfly as `ext`/`rev`/`trn` shuffles + min/max.
//! `u32` runs at W ∈ {4, 8} (one/two q-registers), `u64` at W = 4 (two
//! q-registers; `vcgtq_u64` + `vbslq_u64` stand in for the missing
//! 64-bit min/max).

use core::arch::aarch64::*;

// ---------------------------------------------------------------------
// u32: W = 4 (one q) and W = 8 (two q).
// ---------------------------------------------------------------------

#[inline]
unsafe fn ld4(p: *const u32) -> uint32x4_t {
    vld1q_u32(p)
}

#[inline]
unsafe fn st4(p: *mut u32, x: uint32x4_t) {
    vst1q_u32(p, x)
}

#[inline]
unsafe fn ld8(p: *const u32) -> (uint32x4_t, uint32x4_t) {
    (ld4(p), ld4(p.add(4)))
}

#[inline]
unsafe fn st8(p: *mut u32, x: (uint32x4_t, uint32x4_t)) {
    st4(p, x.0);
    st4(p.add(4), x.1);
}

/// Full lane reversal `[x3, x2, x1, x0]`: reverse within 64-bit pairs,
/// then swap the pairs.
#[inline]
unsafe fn rev4(x: uint32x4_t) -> uint32x4_t {
    let r = vrev64q_u32(x);
    vextq_u32::<2>(r, r)
}

#[inline]
unsafe fn rev8(x: (uint32x4_t, uint32x4_t)) -> (uint32x4_t, uint32x4_t) {
    (rev4(x.1), rev4(x.0))
}

#[inline]
unsafe fn minmax4(a: uint32x4_t, b: uint32x4_t) -> (uint32x4_t, uint32x4_t) {
    (vminq_u32(a, b), vmaxq_u32(a, b))
}

#[inline]
unsafe fn stage4(a: uint32x4_t, b: uint32x4_t) -> (uint32x4_t, uint32x4_t) {
    minmax4(a, b)
}

#[inline]
unsafe fn stage8(
    a: (uint32x4_t, uint32x4_t),
    b: (uint32x4_t, uint32x4_t),
) -> ((uint32x4_t, uint32x4_t), (uint32x4_t, uint32x4_t)) {
    let (l0, h0) = minmax4(a.0, b.0);
    let (l1, h1) = minmax4(a.1, b.1);
    ((l0, l1), (h0, h1))
}

/// Descending butterfly over 4 lanes (stride 2 then stride 1, maxes to
/// the lower index).
#[inline]
unsafe fn bf4(x: uint32x4_t) -> uint32x4_t {
    // stride 2: pairs (0,2) and (1,3)
    let t = vextq_u32::<2>(x, x); // [x2, x3, x0, x1]
    let (mn, mx) = minmax4(x, t);
    // want [mx0, mx1, mn2, mn3]
    let x = vcombine_u32(vget_low_u32(mx), vget_high_u32(mn));
    // stride 1: pairs (0,1) and (2,3)
    let t = vrev64q_u32(x); // [x1, x0, x3, x2]
    let (mn, mx) = minmax4(x, t);
    // mx = [Ma, Ma, Mb, Mb], mn = [ma, ma, mb, mb] → [Ma, ma, Mb, mb]
    vtrn1q_u32(mx, mn)
}

#[inline]
unsafe fn bf8(x: (uint32x4_t, uint32x4_t)) -> (uint32x4_t, uint32x4_t) {
    let (mn, mx) = minmax4(x.0, x.1);
    (bf4(mx), bf4(mn))
}

gen_merge!(merge_u32_w4_neon, u32, 4, ld4, st4, rev4, stage4, bf4);
gen_merge!(merge_u32_w8_neon, u32, 8, ld8, st8, rev8, stage8, bf8);

// ---------------------------------------------------------------------
// u64: W = 4 (two q-registers of 2 lanes each).
// ---------------------------------------------------------------------

#[inline]
unsafe fn ld4q(p: *const u64) -> (uint64x2_t, uint64x2_t) {
    (vld1q_u64(p), vld1q_u64(p.add(2)))
}

#[inline]
unsafe fn st4q(p: *mut u64, x: (uint64x2_t, uint64x2_t)) {
    vst1q_u64(p, x.0);
    vst1q_u64(p.add(2), x.1);
}

#[inline]
unsafe fn rev2q(x: uint64x2_t) -> uint64x2_t {
    vextq_u64::<1>(x, x)
}

#[inline]
unsafe fn rev4q(x: (uint64x2_t, uint64x2_t)) -> (uint64x2_t, uint64x2_t) {
    (rev2q(x.1), rev2q(x.0))
}

#[inline]
unsafe fn minmax2q(a: uint64x2_t, b: uint64x2_t) -> (uint64x2_t, uint64x2_t) {
    let gt = vcgtq_u64(a, b);
    (vbslq_u64(gt, b, a), vbslq_u64(gt, a, b))
}

#[inline]
unsafe fn stage4q(
    a: (uint64x2_t, uint64x2_t),
    b: (uint64x2_t, uint64x2_t),
) -> ((uint64x2_t, uint64x2_t), (uint64x2_t, uint64x2_t)) {
    let (l0, h0) = minmax2q(a.0, b.0);
    let (l1, h1) = minmax2q(a.1, b.1);
    ((l0, l1), (h0, h1))
}

/// Descending sort of a bitonic 2-lane register.
#[inline]
unsafe fn bf2q(x: uint64x2_t) -> uint64x2_t {
    let t = vextq_u64::<1>(x, x); // [x1, x0]
    let (mn, mx) = minmax2q(x, t);
    vtrn1q_u64(mx, mn) // [max, min]
}

#[inline]
unsafe fn bf4q(x: (uint64x2_t, uint64x2_t)) -> (uint64x2_t, uint64x2_t) {
    let (mn, mx) = minmax2q(x.0, x.1);
    (bf2q(mx), bf2q(mn))
}

gen_merge!(merge_u64_w4_neon, u64, 4, ld4q, st4q, rev4q, stage4q, bf4q);

// ---------------------------------------------------------------------
// Signed tier: i32/i64 ride the unsigned kernels through biased
// loads/stores (x ^ sign-bit maps signed order onto unsigned order);
// the selector/butterfly bodies above are reused verbatim.
// ---------------------------------------------------------------------

#[inline]
unsafe fn ld4s(p: *const i32) -> uint32x4_t {
    veorq_u32(ld4(p as *const u32), vdupq_n_u32(0x8000_0000))
}

#[inline]
unsafe fn st4s(p: *mut i32, x: uint32x4_t) {
    st4(p as *mut u32, veorq_u32(x, vdupq_n_u32(0x8000_0000)))
}

#[inline]
unsafe fn ld8s(p: *const i32) -> (uint32x4_t, uint32x4_t) {
    (ld4s(p), ld4s(p.add(4)))
}

#[inline]
unsafe fn st8s(p: *mut i32, x: (uint32x4_t, uint32x4_t)) {
    st4s(p, x.0);
    st4s(p.add(4), x.1);
}

gen_merge!(merge_i32_w4_neon, i32, 4, ld4s, st4s, rev4, stage4, bf4);
gen_merge!(merge_i32_w8_neon, i32, 8, ld8s, st8s, rev8, stage8, bf8);

#[inline]
unsafe fn ld4qs(p: *const i64) -> (uint64x2_t, uint64x2_t) {
    let bias = vdupq_n_u64(1 << 63);
    let (x0, x1) = ld4q(p as *const u64);
    (veorq_u64(x0, bias), veorq_u64(x1, bias))
}

#[inline]
unsafe fn st4qs(p: *mut i64, x: (uint64x2_t, uint64x2_t)) {
    let bias = vdupq_n_u64(1 << 63);
    st4q(p as *mut u64, (veorq_u64(x.0, bias), veorq_u64(x.1, bias)));
}

gen_merge!(merge_i64_w4_neon, i64, 4, ld4qs, st4qs, rev4q, stage4q, bf4q);

// ---------------------------------------------------------------------
// Dispatchers.
// ---------------------------------------------------------------------

/// u32 merge through the widest NEON kernel the config and input sizes
/// allow.
pub(super) fn merge_desc_u32(a: &[u32], b: &[u32], w: usize, dst: &mut [u32]) -> bool {
    let min_side = a.len().min(b.len());
    if min_side < 4 {
        return false;
    }
    unsafe {
        if w >= 8 && min_side >= 8 {
            merge_u32_w8_neon(a, b, dst);
        } else {
            merge_u32_w4_neon(a, b, dst);
        }
    }
    true
}

/// u64 merge (W = 4).
pub(super) fn merge_desc_u64(a: &[u64], b: &[u64], w: usize, dst: &mut [u64]) -> bool {
    let _ = w;
    if a.len().min(b.len()) < 4 {
        return false;
    }
    unsafe {
        merge_u64_w4_neon(a, b, dst);
    }
    true
}

/// i32 merge — same width menu as `u32`, through the biased kernels.
pub(super) fn merge_desc_i32(a: &[i32], b: &[i32], w: usize, dst: &mut [i32]) -> bool {
    let min_side = a.len().min(b.len());
    if min_side < 4 {
        return false;
    }
    unsafe {
        if w >= 8 && min_side >= 8 {
            merge_i32_w8_neon(a, b, dst);
        } else {
            merge_i32_w4_neon(a, b, dst);
        }
    }
    true
}

/// i64 merge (W = 4), through the biased kernel.
pub(super) fn merge_desc_i64(a: &[i64], b: &[i64], w: usize, dst: &mut [i64]) -> bool {
    let _ = w;
    if a.len().min(b.len()) < 4 {
        return false;
    }
    unsafe {
        merge_i64_w4_neon(a, b, dst);
    }
    true
}

/// Elementwise CAS column over two u32 rows, 4 lanes per step.
pub(super) fn rowpair_minmax_u32(hi: &mut [u32], lo: &mut [u32]) -> bool {
    debug_assert_eq!(hi.len(), lo.len());
    unsafe {
        let n = hi.len();
        let mut i = 0;
        while i + 4 <= n {
            let a = ld4(hi.as_ptr().add(i));
            let b = ld4(lo.as_ptr().add(i));
            let (mn, mx) = minmax4(a, b);
            st4(hi.as_mut_ptr().add(i), mx);
            st4(lo.as_mut_ptr().add(i), mn);
            i += 4;
        }
        super::rowpair_scalar(&mut hi[i..], &mut lo[i..]);
    }
    true
}

/// Elementwise CAS column over two i32 rows — native signed min/max.
pub(super) fn rowpair_minmax_i32(hi: &mut [i32], lo: &mut [i32]) -> bool {
    debug_assert_eq!(hi.len(), lo.len());
    unsafe {
        let n = hi.len();
        let mut i = 0;
        while i + 4 <= n {
            let a = vld1q_s32(hi.as_ptr().add(i));
            let b = vld1q_s32(lo.as_ptr().add(i));
            vst1q_s32(hi.as_mut_ptr().add(i), vmaxq_s32(a, b));
            vst1q_s32(lo.as_mut_ptr().add(i), vminq_s32(a, b));
            i += 4;
        }
        super::rowpair_scalar(&mut hi[i..], &mut lo[i..]);
    }
    true
}
