//! Explicit-SIMD FLiMS merge kernels with runtime dispatch.
//!
//! The paper's §8 runs FLiMS "as conventional software on modern CPUs
//! supporting SIMD instructions"; [`lanes`](crate::flims::lanes) is the
//! branchless scalar tier that *hopes* the auto-vectoriser finds that
//! shape. This module is the explicit version: the §3 selector stage
//! (pairwise max of the candidate lanes against the **bank-reversed**
//! carry lanes) and the §3.2 butterfly cleanup network written directly
//! with `core::arch` min/max + shuffle intrinsics, with the FLiMSj-style
//! whole-row candidate refill of §8.1 (one scalar head compare steers a
//! contiguous `w`-row load — no per-lane gathers).
//!
//! Tiers and dispatch:
//!
//! * **x86_64** — SSE2 baseline (always present on the target) for
//!   `u32`/`i32` at W ∈ {4, 8}; AVX2 (runtime-detected once via
//!   `is_x86_feature_detected!`, cached) for `u32`/`i32` at W ∈ {8, 16}
//!   and `u64`/`i64` at W ∈ {4, 8}.
//! * **aarch64** — NEON (architectural) for `u32`/`i32` at W ∈ {4, 8}
//!   and `u64`/`i64` at W = 4.
//! * everything else — the scalar lanes.
//!
//! Every key shape reaches these kernels through an order-preserving
//! bit map: `f32` via the [`F32Key`] mapping, `i32`/`i64` via the
//! sign-flip bias fused into the kernels' loads/stores (`x ^ sign-bit`
//! maps signed order onto unsigned order), and `u16` by widening to
//! `u32` lanes. Payload records (`Kv`, `Kv64`) ride the same kernels
//! one level up: [`merge_stable_simd`](crate::flims::stable) merges
//! `(key, source-index)` pairs packed into `u64` lanes — the index
//! breaking key ties — then gathers payloads through the resulting
//! permutation, so the §6 tie-record guarantee is preserved *on* the
//! SIMD tier. For plain keys the descending merge output of a multiset
//! is *unique*, so every kernel produces byte-identical output by
//! construction — the `prop_kernel` equivalence suite pins this across
//! dtypes, widths, schedules and adversarial inputs.
//!
//! Selection is a [`MergeKernel`] knob threaded through every layer
//! that touches the lane merger: `[core] kernel` in the config file,
//! the `FLIMS_KERNEL` environment variable (the process default — how
//! CI forces the whole suite onto the scalar tier), `--kernel` on the
//! CLI, and `kernel=<k>` on the service's `sortfile` command. See
//! `docs/KERNELS.md` for the full dispatch table.

use std::sync::OnceLock;

use crate::flims::lanes::{merge_desc_fast, merge_desc_fast_slice};
use crate::key::{F32Key, Item, Key};

/// Which merge-kernel tier the lane mergers run on.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MergeKernel {
    /// Pick per type and CPU: explicit SIMD where a kernel exists,
    /// scalar otherwise. The default.
    Auto,
    /// Force the branchless scalar lanes everywhere.
    Scalar,
    /// Ask for the explicit-SIMD tier. Falls back to scalar for types,
    /// widths, or CPUs without a kernel.
    Simd,
}

impl Default for MergeKernel {
    /// The process default: [`MergeKernel::env_default`].
    fn default() -> Self {
        MergeKernel::env_default()
    }
}

impl MergeKernel {
    /// Parse a kernel name (`auto` | `scalar` | `simd`), forgiving case
    /// and surrounding whitespace.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(MergeKernel::Auto),
            "scalar" => Ok(MergeKernel::Scalar),
            "simd" => Ok(MergeKernel::Simd),
            other => Err(format!("unknown kernel '{other}' (expected auto|scalar|simd)")),
        }
    }

    /// The knob spelling of this kernel.
    pub fn name(self) -> &'static str {
        match self {
            MergeKernel::Auto => "auto",
            MergeKernel::Scalar => "scalar",
            MergeKernel::Simd => "simd",
        }
    }

    /// Whether this kernel tries the SIMD tier before falling back.
    #[inline]
    pub fn wants_simd(self) -> bool {
        !matches!(self, MergeKernel::Scalar)
    }

    /// What this kernel resolves to on the running CPU — the CPU's
    /// tier *ceiling* (`scalar`, `simd-sse2`, `simd-avx2`, or
    /// `simd-neon`). Per-dtype surfaces (the `stats` protocol line,
    /// the CLI report, the Prometheus `kernel` label) report the
    /// *effective* tier instead, via
    /// [`Dtype::effective_kernel`](crate::external::Dtype::effective_kernel):
    /// a dtype whose kernel is missing on this CPU reports `scalar`
    /// even under `auto`/`simd` (see `docs/KERNELS.md`).
    pub fn resolved_name(self) -> &'static str {
        match self {
            MergeKernel::Scalar => "scalar",
            MergeKernel::Auto | MergeKernel::Simd => simd_tier_name(),
        }
    }

    /// The kernel default: the `FLIMS_KERNEL` environment variable when
    /// set, else `auto`. Read once and cached — this is how CI runs the
    /// whole suite with the scalar tier forced. An unparseable value
    /// warns on stderr instead of silently meaning `auto`.
    pub fn env_default() -> Self {
        static CACHE: OnceLock<MergeKernel> = OnceLock::new();
        *CACHE.get_or_init(|| match std::env::var("FLIMS_KERNEL") {
            Err(_) => MergeKernel::Auto,
            Ok(v) => MergeKernel::parse(&v).unwrap_or_else(|e| {
                eprintln!("warning: FLIMS_KERNEL ignored: {e}");
                MergeKernel::Auto
            }),
        })
    }
}

/// The SIMD tier available on the running CPU, by name (`simd-avx2`,
/// `simd-sse2`, `simd-neon`, or `scalar` when no kernel exists).
pub fn simd_tier_name() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if x86::have_avx2() {
            "simd-avx2"
        } else {
            "simd-sse2"
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        "simd-neon"
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        "scalar"
    }
}

/// A plain-key element the kernel dispatcher can route: every method
/// returns `false` to mean "no SIMD kernel here — take the scalar
/// tier". Unsigned keys (`u32`, `u64`) dispatch directly; [`F32Key`],
/// `i32`, and `i64` reach the same kernels through order-preserving
/// bit maps (transparent cast / sign-flip bias), and `u16` widens to
/// `u32` lanes.
pub trait SimdMergeable: Item<K = Self> + Key {
    /// Merge two descending-sorted slices into `dst` (`dst.len() ==
    /// a.len() + b.len()`) with an explicit-SIMD kernel near lane width
    /// `w`. Returns `false` when no kernel fits this type, width, or
    /// CPU.
    fn simd_merge_desc(a: &[Self], b: &[Self], w: usize, dst: &mut [Self]) -> bool {
        let _ = (a, b, w, dst);
        false
    }

    /// One elementwise CAS column over two equal-length rows (`hi[i]`
    /// keeps the max, `lo[i]` the min) — the sort-in-chunks network
    /// stage of §8.2. Returns `false` when no kernel exists.
    fn simd_rowpair_minmax(hi: &mut [Self], lo: &mut [Self]) -> bool {
        let _ = (hi, lo);
        false
    }

    /// The SIMD tier this type's merge kernel actually runs on for the
    /// running CPU (`simd-sse2` | `simd-avx2` | `simd-neon`), or
    /// `scalar` when no kernel exists — the *effective* name surfaced
    /// per dtype in stats, the CLI report, and metrics labels.
    fn simd_tier() -> &'static str {
        "scalar"
    }
}

impl SimdMergeable for u16 {
    /// `u16` rides the `u32` kernels by widening: no dedicated 16-bit
    /// network, but the widened merge still beats the scalar tier for
    /// block-sized inputs.
    fn simd_merge_desc(a: &[Self], b: &[Self], w: usize, dst: &mut [Self]) -> bool {
        if a.len().min(b.len()) < SIMD_MIN_SIDE {
            return false;
        }
        let wa: Vec<u32> = a.iter().map(|&x| x as u32).collect();
        let wb: Vec<u32> = b.iter().map(|&x| x as u32).collect();
        let mut wide = vec![0u32; dst.len()];
        if !<u32 as SimdMergeable>::simd_merge_desc(&wa, &wb, w, &mut wide) {
            return false;
        }
        for (d, &x) in dst.iter_mut().zip(wide.iter()) {
            *d = x as u16;
        }
        true
    }

    fn simd_tier() -> &'static str {
        <u32 as SimdMergeable>::simd_tier()
    }
}

impl SimdMergeable for u32 {
    fn simd_merge_desc(a: &[Self], b: &[Self], w: usize, dst: &mut [Self]) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            x86::merge_desc_u32(a, b, w, dst)
        }
        #[cfg(target_arch = "aarch64")]
        {
            neon::merge_desc_u32(a, b, w, dst)
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            let _ = (a, b, w, dst);
            false
        }
    }

    fn simd_rowpair_minmax(hi: &mut [Self], lo: &mut [Self]) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            x86::rowpair_minmax_u32(hi, lo)
        }
        #[cfg(target_arch = "aarch64")]
        {
            neon::rowpair_minmax_u32(hi, lo)
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            let _ = (hi, lo);
            false
        }
    }

    fn simd_tier() -> &'static str {
        simd_tier_name()
    }
}

impl SimdMergeable for i32 {
    fn simd_merge_desc(a: &[Self], b: &[Self], w: usize, dst: &mut [Self]) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            x86::merge_desc_i32(a, b, w, dst)
        }
        #[cfg(target_arch = "aarch64")]
        {
            neon::merge_desc_i32(a, b, w, dst)
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            let _ = (a, b, w, dst);
            false
        }
    }

    fn simd_rowpair_minmax(hi: &mut [Self], lo: &mut [Self]) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            x86::rowpair_minmax_i32(hi, lo)
        }
        #[cfg(target_arch = "aarch64")]
        {
            neon::rowpair_minmax_i32(hi, lo)
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            let _ = (hi, lo);
            false
        }
    }

    /// The biased i32 kernels cover exactly the `u32` width menu.
    fn simd_tier() -> &'static str {
        <u32 as SimdMergeable>::simd_tier()
    }
}

impl SimdMergeable for u64 {
    fn simd_merge_desc(a: &[Self], b: &[Self], w: usize, dst: &mut [Self]) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            x86::merge_desc_u64(a, b, w, dst)
        }
        #[cfg(target_arch = "aarch64")]
        {
            neon::merge_desc_u64(a, b, w, dst)
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            let _ = (a, b, w, dst);
            false
        }
    }

    /// 64-bit kernels need AVX2 on x86 (SSE2 lacks a usable 64-bit
    /// compare), so an SSE2-only CPU reports `scalar` here.
    fn simd_tier() -> &'static str {
        #[cfg(target_arch = "x86_64")]
        {
            if x86::have_avx2() {
                "simd-avx2"
            } else {
                "scalar"
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            "simd-neon"
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            "scalar"
        }
    }
}

impl SimdMergeable for i64 {
    fn simd_merge_desc(a: &[Self], b: &[Self], w: usize, dst: &mut [Self]) -> bool {
        #[cfg(target_arch = "x86_64")]
        {
            x86::merge_desc_i64(a, b, w, dst)
        }
        #[cfg(target_arch = "aarch64")]
        {
            neon::merge_desc_i64(a, b, w, dst)
        }
        #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
        {
            let _ = (a, b, w, dst);
            false
        }
    }

    /// The biased i64 kernels cover exactly the `u64` width menu.
    fn simd_tier() -> &'static str {
        <u64 as SimdMergeable>::simd_tier()
    }
}

// SAFETY of the casts below: `F32Key` is `#[repr(transparent)]` over
// `u32`, and its derived `Ord` is exactly the wrapped integer's order
// (that is the whole point of the order-preserving bit mapping), so the
// u32 kernels merge it bit-exactly.
impl SimdMergeable for F32Key {
    fn simd_merge_desc(a: &[Self], b: &[Self], w: usize, dst: &mut [Self]) -> bool {
        let (a, b) = (f32key_bits(a), f32key_bits(b));
        let dst = f32key_bits_mut(dst);
        <u32 as SimdMergeable>::simd_merge_desc(a, b, w, dst)
    }

    fn simd_rowpair_minmax(hi: &mut [Self], lo: &mut [Self]) -> bool {
        <u32 as SimdMergeable>::simd_rowpair_minmax(f32key_bits_mut(hi), f32key_bits_mut(lo))
    }

    fn simd_tier() -> &'static str {
        <u32 as SimdMergeable>::simd_tier()
    }
}

#[inline]
fn f32key_bits(xs: &[F32Key]) -> &[u32] {
    // SAFETY: see the comment on the `SimdMergeable for F32Key` impl.
    unsafe { std::slice::from_raw_parts(xs.as_ptr().cast(), xs.len()) }
}

#[inline]
fn f32key_bits_mut(xs: &mut [F32Key]) -> &mut [u32] {
    // SAFETY: see the comment on the `SimdMergeable for F32Key` impl.
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr().cast(), xs.len()) }
}

/// Merge two descending-sorted plain-key slices into `dst`
/// (`dst.len()` must equal `a.len() + b.len()`) through the selected
/// kernel: explicit SIMD when `kernel` wants it and the type/CPU
/// supports it, otherwise the branchless scalar lanes
/// ([`merge_desc_fast_slice`]). Output bytes are identical whichever
/// tier runs — a plain-key descending merge output is unique.
pub fn merge_desc_kernel_slice<T: SimdMergeable>(
    a: &[T],
    b: &[T],
    w: usize,
    kernel: MergeKernel,
    dst: &mut [T],
) {
    debug_assert_eq!(dst.len(), a.len() + b.len());
    // The length check is a hard gate, not just the debug assert: the
    // SIMD kernels store through raw pointers, so a contract-violating
    // caller must land on the scalar tier (which panics cleanly on its
    // slice bounds) rather than write out of bounds in release builds.
    if kernel.wants_simd()
        && dst.len() == a.len() + b.len()
        && T::simd_merge_desc(a, b, w, dst)
    {
        return;
    }
    merge_desc_fast_slice(a, b, w, dst);
}

/// The smallest per-side length any SIMD kernel accepts (the narrowest
/// block is 4 lanes on every supported arch) — lets Vec-appending
/// callers (here and the stable key–index path in
/// [`crate::flims::stable`]) skip setup for merges no kernel would
/// take.
pub(crate) const SIMD_MIN_SIDE: usize = 4;

/// [`merge_desc_kernel_slice`] appending to a `Vec` — the shape
/// [`ExtItem::merge_into`](crate::external::ExtItem::merge_into) wants.
pub fn merge_desc_kernel<T: SimdMergeable>(
    a: &[T],
    b: &[T],
    w: usize,
    kernel: MergeKernel,
    out: &mut Vec<T>,
) {
    // Only pre-size the output when a kernel could actually take this
    // merge (both sides can prime the narrowest block) — tail blocks
    // and tiny merges go straight to the scalar append path with no
    // wasted sentinel fill. (When a kernel does run, the fill is one
    // vectorised pass the merge immediately overwrites — small next to
    // the merge itself.)
    if kernel.wants_simd() && a.len().min(b.len()) >= SIMD_MIN_SIDE {
        let base = out.len();
        let total = a.len() + b.len();
        out.resize(base + total, T::SENTINEL);
        if T::simd_merge_desc(a, b, w, &mut out[base..]) {
            return;
        }
        out.truncate(base);
    }
    merge_desc_fast(a, b, w, out);
}

/// One elementwise CAS column over two equal-length rows: `hi[i]` keeps
/// the max, `lo[i]` the min — the sort-in-chunks network stage (§8.2),
/// SIMD when the kernel and type allow.
pub fn rowpair_minmax<T: SimdMergeable>(hi: &mut [T], lo: &mut [T], kernel: MergeKernel) {
    debug_assert_eq!(hi.len(), lo.len());
    // Hard equal-length gate for the same reason as the merge entry:
    // the SIMD rows store through raw pointers; mismatched callers get
    // the scalar path's zip semantics instead of out-of-bounds writes.
    if kernel.wants_simd() && hi.len() == lo.len() && T::simd_rowpair_minmax(hi, lo) {
        return;
    }
    rowpair_scalar(hi, lo);
}

/// The scalar CAS column — also the tail pass of the SIMD rowpair
/// kernels for lengths off the register width.
pub(crate) fn rowpair_scalar<T: Copy + Ord>(hi: &mut [T], lo: &mut [T]) {
    for (h, l) in hi.iter_mut().zip(lo.iter_mut()) {
        if *l > *h {
            std::mem::swap(h, l);
        }
    }
}

/// Simple scalar 2-way descending merge into an exact-sized slice —
/// used by the kernel epilogues to fold the carry block into the
/// *short* input remainder (both at most `2·W − 1` elements). Plain
/// keys only, so any tie order is correct.
pub(crate) fn merge2_desc<T: Copy + Ord>(a: &[T], b: &[T], dst: &mut [T]) {
    debug_assert_eq!(dst.len(), a.len() + b.len());
    let (mut i, mut j) = (0usize, 0usize);
    for slot in dst.iter_mut() {
        let take_a = match (a.get(i), b.get(j)) {
            (Some(x), Some(y)) => x >= y,
            (Some(_), None) => true,
            _ => false,
        };
        if take_a {
            *slot = a[i];
            i += 1;
        } else {
            *slot = b[j];
            j += 1;
        }
    }
}

/// Generates one explicit-SIMD merge kernel: the §3 selector (pairwise
/// compare of the candidate block against the bank-reversed carry
/// block), the §3.2 butterfly cleanup on both halves, and the
/// FLiMSj-style whole-row refill of §8.1 steered by one scalar head
/// compare. Callers must guarantee `a.len() >= W`, `b.len() >= W`,
/// `dst.len() == a.len() + b.len()`, and (for feature-gated kernels)
/// that the CPU supports the instruction set.
macro_rules! gen_merge {
    ($(#[$attr:meta])* $name:ident, $ty:ty, $w:expr,
     $load:ident, $store:ident, $rev:ident, $stage:ident, $butterfly:ident) => {
        $(#[$attr])*
        unsafe fn $name(a: &[$ty], b: &[$ty], dst: &mut [$ty]) {
            const W: usize = $w;
            debug_assert!(a.len() >= W && b.len() >= W);
            debug_assert_eq!(dst.len(), a.len() + b.len());
            let (na, nb) = (a.len(), b.len());
            let mut va = $load(a.as_ptr());
            let mut carry = $load(b.as_ptr());
            let (mut ia, mut ib, mut o) = (W, W, 0usize);
            loop {
                // Selector stage: lane i of the candidate block against
                // the bank-reversed carry lane (§3.1); maxes stream out,
                // mins become the next carry — both butterfly-cleaned
                // (§3.2).
                let (lo, hi) = $stage(va, $rev(carry));
                $store(dst.as_mut_ptr().add(o), $butterfly(hi));
                o += W;
                carry = $butterfly(lo);
                if ia + W > na || ib + W > nb {
                    break;
                }
                // Whole-row refill (§8.1): the stream with the larger
                // head must supply the next candidates.
                if *a.get_unchecked(ia) > *b.get_unchecked(ib) {
                    va = $load(a.as_ptr().add(ia));
                    ia += W;
                } else {
                    va = $load(b.as_ptr().add(ib));
                    ib += W;
                }
            }
            // Tail. The loop only breaks when a remainder cannot fill a
            // row, so the *shorter* remainder holds < W elements: fold
            // the spilled carry into it scalar-2-way (≤ 2·W−1 values),
            // then finish against the long remainder on the branchless
            // scalar lanes — a skewed merge never drains its dominant
            // side through a slow element-at-a-time loop.
            let mut tail = [0 as $ty; W];
            $store(tail.as_mut_ptr(), carry);
            let (ra, rb) = (&a[ia..], &b[ib..]);
            let (short, long) = if ra.len() <= rb.len() { (ra, rb) } else { (rb, ra) };
            let mut small = [0 as $ty; 2 * W];
            let n_small = W + short.len();
            super::merge2_desc(&tail, short, &mut small[..n_small]);
            crate::flims::lanes::merge_desc_fast_slice(&small[..n_small], long, W, &mut dst[o..]);
        }
    };
}

#[cfg(target_arch = "x86_64")]
mod x86;

#[cfg(target_arch = "aarch64")]
mod neon;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_i32, gen_i64, gen_sorted_pair, gen_u32, gen_u64, Distribution};
    use crate::util::rng::Rng;

    fn oracle<T: Item>(a: &[T], b: &[T]) -> Vec<T> {
        let mut v: Vec<T> = a.iter().chain(b.iter()).copied().collect();
        v.sort_by(|x, y| y.key().cmp(&x.key()));
        v
    }

    fn both_kernels<T: SimdMergeable + PartialEq + std::fmt::Debug>(a: &[T], b: &[T], w: usize) {
        let total = a.len() + b.len();
        let mut scalar = vec![T::SENTINEL; total];
        merge_desc_kernel_slice(a, b, w, MergeKernel::Scalar, &mut scalar);
        let mut simd = vec![T::SENTINEL; total];
        merge_desc_kernel_slice(a, b, w, MergeKernel::Simd, &mut simd);
        let expect = oracle(a, b);
        assert_eq!(scalar, expect, "scalar w={w} na={} nb={}", a.len(), b.len());
        assert_eq!(simd, expect, "simd w={w} na={} nb={}", a.len(), b.len());
    }

    #[test]
    fn parse_and_names() {
        assert_eq!(MergeKernel::parse("auto").unwrap(), MergeKernel::Auto);
        assert_eq!(MergeKernel::parse(" Scalar ").unwrap(), MergeKernel::Scalar);
        assert_eq!(MergeKernel::parse("SIMD").unwrap(), MergeKernel::Simd);
        let err = MergeKernel::parse("gpu").unwrap_err();
        assert!(err.contains("unknown kernel"), "{err}");
        assert_eq!(MergeKernel::Auto.name(), "auto");
        assert_eq!(MergeKernel::Scalar.name(), "scalar");
        assert_eq!(MergeKernel::Simd.name(), "simd");
        assert!(!MergeKernel::Scalar.wants_simd());
        assert!(MergeKernel::Auto.wants_simd());
        assert_eq!(MergeKernel::Scalar.resolved_name(), "scalar");
        // Auto and Simd resolve to the same tier name, whatever the CPU.
        assert_eq!(MergeKernel::Auto.resolved_name(), MergeKernel::Simd.resolved_name());
        assert_eq!(MergeKernel::Auto.resolved_name(), simd_tier_name());
    }

    #[test]
    fn merge2_desc_matches_oracle() {
        let mut rng = Rng::new(771);
        for _ in 0..50 {
            let mk = |n: usize, rng: &mut Rng| -> Vec<u32> {
                let mut v: Vec<u32> = (0..n).map(|_| rng.below(50) as u32).collect();
                v.sort_unstable_by(|a, b| b.cmp(a));
                v
            };
            let (na, nb) = (rng.range(0, 20), rng.range(0, 20));
            let (a, b) = (mk(na, &mut rng), mk(nb, &mut rng));
            let mut dst = vec![0u32; na + nb];
            merge2_desc(&a, &b, &mut dst);
            let mut expect: Vec<u32> = a.iter().chain(b.iter()).copied().collect();
            expect.sort_unstable_by(|x, y| y.cmp(x));
            assert_eq!(dst, expect, "na={na} nb={nb}");
        }
    }

    #[test]
    fn length_contract_violations_stay_safe() {
        // A wrong-size dst must land on the scalar tier's clean panic,
        // never on a raw-pointer SIMD store (release-mode safety gate).
        let a: Vec<u32> = (0..64u32).rev().collect();
        let b: Vec<u32> = (0..64u32).rev().collect();
        let mut dst = vec![0u32; 100]; // != 128
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            merge_desc_kernel_slice(&a, &b, 16, MergeKernel::Simd, &mut dst);
        }))
        .is_err();
        assert!(panicked, "short dst must panic cleanly, not write out of bounds");
    }

    #[test]
    fn u32_kernels_match_scalar_all_widths() {
        let mut rng = Rng::new(772);
        for w in [2usize, 4, 8, 16, 32] {
            for _ in 0..20 {
                let (na, nb) = (rng.range(0, 600), rng.range(0, 600));
                let (a, b) = gen_sorted_pair(&mut rng, na, nb, Distribution::Uniform, gen_u32);
                both_kernels(&a, &b, w);
            }
        }
    }

    #[test]
    fn u64_kernels_match_scalar_all_widths() {
        let mut rng = Rng::new(773);
        for w in [4usize, 8, 16] {
            for _ in 0..15 {
                let (na, nb) = (rng.range(0, 500), rng.range(0, 500));
                let (a, b) = gen_sorted_pair(&mut rng, na, nb, Distribution::Uniform, gen_u64);
                both_kernels(&a, &b, w);
            }
        }
    }

    #[test]
    fn f32key_kernel_matches_scalar() {
        let mut rng = Rng::new(774);
        for _ in 0..15 {
            let mk = |n: usize, rng: &mut Rng| -> Vec<F32Key> {
                let mut v: Vec<F32Key> = (0..n)
                    .map(|_| F32Key::from_f32(rng.next_u32() as f32 - 2e9))
                    .collect();
                v.sort_unstable_by(|a, b| b.cmp(a));
                v
            };
            let (na, nb) = (rng.range(0, 400), rng.range(0, 400));
            let (a, b) = (mk(na, &mut rng), mk(nb, &mut rng));
            both_kernels(&a, &b, 16);
        }
    }

    #[test]
    fn edge_shapes_and_sentinels() {
        // Empty sides, singles, all-equal, sentinel-valued keys, and
        // lengths off the register width.
        both_kernels::<u32>(&[], &[], 8);
        both_kernels::<u32>(&[7], &[], 8);
        both_kernels::<u32>(&[], &[7], 8);
        both_kernels::<u32>(&[9, 4, 0, 0, 0], &[7, 0], 8);
        both_kernels::<u32>(&[5u32; 100], &[5u32; 37], 16);
        let a: Vec<u32> = (0..97u32).rev().collect();
        let b: Vec<u32> = (0..31u32).rev().map(|x| x * 3).collect();
        for w in [4usize, 8, 16] {
            both_kernels(&a, &b, w);
        }
        // One side far shorter than the other (adversarial skew).
        let long: Vec<u32> = (0..5000u32).rev().collect();
        both_kernels(&long, &[2500, 2500, 2500], 16);
    }

    #[test]
    fn append_variant_preserves_prefix() {
        let mut out = vec![111u32];
        merge_desc_kernel(&[5u32, 3], &[4, 2], 4, MergeKernel::Simd, &mut out);
        assert_eq!(out, vec![111, 5, 4, 3, 2]);
        let mut out = vec![222u32];
        merge_desc_kernel(&[5u32, 3], &[4, 2], 4, MergeKernel::Scalar, &mut out);
        assert_eq!(out, vec![222, 5, 4, 3, 2]);
        // Large enough to actually hit a SIMD kernel.
        let mut rng = Rng::new(775);
        let (a, b) = gen_sorted_pair(&mut rng, 300, 200, Distribution::Uniform, gen_u32);
        let mut simd = vec![1u32, 2];
        merge_desc_kernel(&a, &b, 16, MergeKernel::Simd, &mut simd);
        let mut scalar = vec![1u32, 2];
        merge_desc_kernel(&a, &b, 16, MergeKernel::Scalar, &mut scalar);
        assert_eq!(simd, scalar);
    }

    #[test]
    fn rowpair_matches_scalar() {
        let mut rng = Rng::new(776);
        for n in [0usize, 1, 3, 4, 7, 8, 64, 65] {
            let hi0: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            let lo0: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            let (mut hi_a, mut lo_a) = (hi0.clone(), lo0.clone());
            rowpair_minmax(&mut hi_a, &mut lo_a, MergeKernel::Scalar);
            let (mut hi_b, mut lo_b) = (hi0.clone(), lo0.clone());
            rowpair_minmax(&mut hi_b, &mut lo_b, MergeKernel::Simd);
            assert_eq!(hi_a, hi_b, "n={n}");
            assert_eq!(lo_a, lo_b, "n={n}");
            for i in 0..n {
                assert_eq!(hi_a[i], hi0[i].max(lo0[i]));
                assert_eq!(lo_a[i], hi0[i].min(lo0[i]));
            }
        }
    }

    #[test]
    fn i32_kernels_match_scalar_all_widths() {
        let mut rng = Rng::new(778);
        for w in [2usize, 4, 8, 16, 32] {
            for _ in 0..20 {
                let (na, nb) = (rng.range(0, 600), rng.range(0, 600));
                let (a, b) = gen_sorted_pair(&mut rng, na, nb, Distribution::Uniform, gen_i32);
                both_kernels(&a, &b, w);
            }
        }
    }

    #[test]
    fn i64_kernels_match_scalar_all_widths() {
        let mut rng = Rng::new(779);
        for w in [4usize, 8, 16] {
            for _ in 0..15 {
                let (na, nb) = (rng.range(0, 500), rng.range(0, 500));
                let (a, b) = gen_sorted_pair(&mut rng, na, nb, Distribution::Uniform, gen_i64);
                both_kernels(&a, &b, w);
            }
        }
    }

    #[test]
    fn signed_sentinels_cross_zero_correctly() {
        // The sign-flip bias must order MIN < -1 < 0 < MAX exactly like
        // native signed compares, including inside the vector blocks.
        let a: Vec<i32> = vec![i32::MAX, 100, 1, 0, -1, -100, i32::MIN + 1, i32::MIN];
        let b: Vec<i32> = vec![i32::MAX - 1, 2, 0, 0, -2, -99, i32::MIN + 2, i32::MIN];
        for w in [4usize, 8, 16] {
            both_kernels(&a, &b, w);
        }
        let a: Vec<i64> = vec![i64::MAX, 7, 0, -1, -7, i64::MIN + 1, i64::MIN, i64::MIN];
        let b: Vec<i64> = vec![i64::MAX, 6, 1, 0, -6, -8, i64::MIN + 2, i64::MIN];
        for w in [4usize, 8] {
            both_kernels(&a, &b, w);
        }
        // All-negative and straddling-zero skew shapes.
        let neg: Vec<i32> = (0..300).map(|i| -1 - 3 * i).collect();
        both_kernels(&neg, &[-2, -500, -501, -502, -900], 8);
    }

    #[test]
    fn u16_kernel_matches_scalar_via_widening() {
        let mut rng = Rng::new(780);
        for _ in 0..15 {
            let mk = |n: usize, rng: &mut Rng| -> Vec<u16> {
                let mut v: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
                v.sort_unstable_by(|a, b| b.cmp(a));
                v
            };
            let (na, nb) = (rng.range(0, 400), rng.range(0, 400));
            let (a, b) = (mk(na, &mut rng), mk(nb, &mut rng));
            both_kernels(&a, &b, 8);
        }
        both_kernels::<u16>(&[u16::MAX, 9, 0], &[u16::MAX, 1, 0, 0], 8);
    }

    #[test]
    fn simd_tier_names_are_consistent() {
        let valid = ["scalar", "simd-sse2", "simd-avx2", "simd-neon"];
        assert!(valid.contains(&<u32 as SimdMergeable>::simd_tier()));
        assert!(valid.contains(&<u64 as SimdMergeable>::simd_tier()));
        // The mapped types ride the unsigned kernels, so their tiers
        // must agree exactly.
        assert_eq!(<i32 as SimdMergeable>::simd_tier(), <u32 as SimdMergeable>::simd_tier());
        assert_eq!(<u16 as SimdMergeable>::simd_tier(), <u32 as SimdMergeable>::simd_tier());
        assert_eq!(<F32Key as SimdMergeable>::simd_tier(), <u32 as SimdMergeable>::simd_tier());
        assert_eq!(<i64 as SimdMergeable>::simd_tier(), <u64 as SimdMergeable>::simd_tier());
    }

    #[test]
    fn dup_heavy_and_zipf_inputs() {
        let mut rng = Rng::new(777);
        for dist in [
            Distribution::DupHeavy { alphabet: 2 },
            Distribution::Zipf { s_x100: 150, n_ranks: 32 },
        ] {
            for w in [4usize, 8, 16] {
                let (a, b) = gen_sorted_pair(&mut rng, 700, 300, dist, gen_u32);
                both_kernels(&a, &b, w);
            }
        }
    }
}
