//! Sort-item abstractions shared by every layer of the library.
//!
//! All FLiMS algorithms in this crate merge in **descending** order, like
//! the paper's exposition (§3, Table 1). Items are compared by key only —
//! the separation between key and payload is what makes the paper's
//! *tie-record issue* (§6) expressible: competitor mergers may corrupt
//! payloads when keys collide, FLiMS may not.

use std::fmt::Debug;

/// A totally ordered sort key with a "below everything" sentinel.
///
/// The sentinel plays the role of the paper's end-of-stream filler
/// (§3.1: "the value 0 can be passed afterwards" for naturals — we use
/// the type minimum so arbitrary data works).
pub trait Key: Copy + Ord + Debug + Send + Sync + 'static {
    /// Value that sorts below (or equal to) every payload key.
    const SENTINEL: Self;
}

impl Key for u32 {
    const SENTINEL: Self = 0;
}
impl Key for u64 {
    const SENTINEL: Self = 0;
}
impl Key for i32 {
    const SENTINEL: Self = i32::MIN;
}
impl Key for i64 {
    const SENTINEL: Self = i64::MIN;
}
impl Key for u16 {
    const SENTINEL: Self = 0;
}

/// Order-preserving total order over `f32` bit patterns.
///
/// Standard trick: flip the sign bit for non-negatives, flip all bits for
/// negatives; the resulting `u32` order matches IEEE-754 numeric order
/// (with -NaN lowest). This is how the PJRT runtime path and the native
/// engines agree on float ordering.
///
/// `repr(transparent)` is load-bearing: the SIMD kernel tier
/// ([`crate::flims::simd`]) reinterprets `F32Key` slices as `u32`
/// slices (the derived `Ord` *is* the wrapped integer's order), so f32
/// datasets ride the unsigned-integer merge kernels bit-exactly.
#[derive(Copy, Clone, PartialEq, Eq, PartialOrd, Ord, Debug, Hash)]
#[repr(transparent)]
pub struct F32Key(pub u32);

impl F32Key {
    #[inline]
    pub fn from_f32(x: f32) -> Self {
        let b = x.to_bits();
        F32Key(if b & 0x8000_0000 != 0 { !b } else { b | 0x8000_0000 })
    }
    #[inline]
    pub fn to_f32(self) -> f32 {
        let b = self.0;
        f32::from_bits(if b & 0x8000_0000 != 0 { b & 0x7fff_ffff } else { !b })
    }
}

impl Key for F32Key {
    const SENTINEL: Self = F32Key(0);
}

/// An element that can flow through the mergers: a copyable record
/// exposing a [`Key`]. Payload (if any) rides along untouched — exactly
/// the "satellite data" of the paper's key-value discussion.
pub trait Item: Copy + Debug + Send + Sync + 'static {
    type K: Key;
    fn key(&self) -> Self::K;
    /// The end-of-stream filler record.
    fn sentinel() -> Self;
}

impl Item for u32 {
    type K = u32;
    #[inline]
    fn key(&self) -> u32 {
        *self
    }
    fn sentinel() -> Self {
        0
    }
}

impl Item for u64 {
    type K = u64;
    #[inline]
    fn key(&self) -> u64 {
        *self
    }
    fn sentinel() -> Self {
        0
    }
}

impl Item for i32 {
    type K = i32;
    #[inline]
    fn key(&self) -> i32 {
        *self
    }
    fn sentinel() -> Self {
        i32::MIN
    }
}

impl Item for i64 {
    type K = i64;
    #[inline]
    fn key(&self) -> i64 {
        *self
    }
    fn sentinel() -> Self {
        i64::MIN
    }
}

impl Item for u16 {
    type K = u16;
    #[inline]
    fn key(&self) -> u16 {
        *self
    }
    fn sentinel() -> Self {
        0
    }
}

impl Item for F32Key {
    type K = F32Key;
    #[inline]
    fn key(&self) -> F32Key {
        *self
    }
    fn sentinel() -> Self {
        F32Key::SENTINEL
    }
}

/// Key-value record: 32-bit key, 32-bit payload. The shape used by the
/// paper's tie-record discussion (§6) and the stable-merge variant (§4.2).
#[derive(Copy, Clone, PartialEq, Eq, Debug, Hash)]
pub struct Kv {
    pub key: u32,
    pub val: u32,
}

impl Kv {
    pub fn new(key: u32, val: u32) -> Self {
        Kv { key, val }
    }
}

impl Item for Kv {
    type K = u32;
    #[inline]
    fn key(&self) -> u32 {
        self.key
    }
    fn sentinel() -> Self {
        Kv { key: 0, val: u32::MAX }
    }
}

/// 64-bit key-value record (64-bit key + 64-bit payload), matching the
/// paper's FPGA evaluation width ("64-bit mergers", §7).
#[derive(Copy, Clone, PartialEq, Eq, Debug, Hash)]
pub struct Kv64 {
    pub key: u64,
    pub val: u64,
}

impl Item for Kv64 {
    type K = u64;
    #[inline]
    fn key(&self) -> u64 {
        self.key
    }
    fn sentinel() -> Self {
        Kv64 { key: 0, val: u64::MAX }
    }
}

/// True iff `xs` is sorted descending by key (duplicates allowed).
pub fn is_sorted_desc<T: Item>(xs: &[T]) -> bool {
    xs.windows(2).all(|p| p[0].key() >= p[1].key())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32key_order_matches_float_order() {
        let vals = [
            f32::NEG_INFINITY,
            -1e30,
            -1.5,
            -0.0,
            0.0,
            1e-30,
            2.5,
            1e30,
            f32::INFINITY,
        ];
        for w in vals.windows(2) {
            assert!(
                F32Key::from_f32(w[0]) <= F32Key::from_f32(w[1]),
                "{} vs {}",
                w[0],
                w[1]
            );
        }
    }

    #[test]
    fn f32key_roundtrips() {
        for &x in &[0.0f32, -0.0, 1.25, -7.5, 1e20, -1e20, f32::INFINITY] {
            let k = F32Key::from_f32(x);
            assert_eq!(k.to_f32().to_bits(), x.to_bits());
        }
    }

    #[test]
    fn sentinels_are_minimal() {
        assert!(u32::SENTINEL <= 1);
        assert_eq!(i32::SENTINEL, i32::MIN);
        assert!(F32Key::SENTINEL <= F32Key::from_f32(f32::NEG_INFINITY));
    }

    #[test]
    fn kv_compares_by_key_only() {
        let a = Kv::new(5, 1);
        let b = Kv::new(5, 2);
        assert_eq!(a.key(), b.key());
    }

    #[test]
    fn is_sorted_desc_works() {
        assert!(is_sorted_desc(&[5u32, 5, 3, 1]));
        assert!(!is_sorted_desc(&[5u32, 6]));
        assert!(is_sorted_desc(&[] as &[u32]));
        assert!(is_sorted_desc(&[1u32]));
    }
}
