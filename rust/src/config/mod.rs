//! Configuration system: a TOML-subset file format plus CLI overrides.
//! (The full `toml`/`serde` crates are unavailable offline; this parser
//! covers the subset the framework uses: `[section]` headers, `key =
//! value` with integers, booleans and strings.)

use std::collections::BTreeMap;
use std::path::Path;

use crate::external::{parse_codec_arg, parse_dtype_arg, Dtype, ExternalConfig};
use crate::flims::simd::MergeKernel;

/// Parsed configuration: section → key → raw value string.
#[derive(Clone, Debug, Default)]
pub struct RawConfig {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl RawConfig {
    /// Parse config text (`[section]` headers, `key = value` lines,
    /// `#` comments).
    pub fn parse(text: &str) -> Result<Self, String> {
        let mut cfg = RawConfig::default();
        let mut section = String::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected key = value", ln + 1))?;
            let v = v.trim().trim_matches('"').to_string();
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), v);
        }
        Ok(cfg)
    }

    /// [`parse`](RawConfig::parse) the file at `path`.
    pub fn load(path: &Path) -> Result<Self, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    /// Raw string value of `section.key`, if present.
    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    /// Set `section.key` (tests and programmatic overrides).
    pub fn set(&mut self, section: &str, key: &str, value: &str) {
        self.sections
            .entry(section.to_string())
            .or_default()
            .insert(key.to_string(), value.to_string());
    }

    /// `section.key` parsed as an integer (`None` when absent).
    pub fn get_usize(&self, section: &str, key: &str) -> Result<Option<usize>, String> {
        match self.get(section, key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("{section}.{key}: '{v}' is not an integer")),
        }
    }

    /// `section.key` parsed as a bool (`None` when absent).
    pub fn get_bool(&self, section: &str, key: &str) -> Result<Option<bool>, String> {
        match self.get(section, key) {
            None => Ok(None),
            Some("true") => Ok(Some(true)),
            Some("false") => Ok(Some(false)),
            Some(v) => Err(format!("{section}.{key}: '{v}' is not a bool")),
        }
    }
}

/// Top-level framework configuration with defaults, file and CLI layers.
#[derive(Clone, Debug)]
pub struct AppConfig {
    /// merge-lane parallelism (paper `w`)
    pub w: usize,
    /// sort-in-chunks run length (paper §8.2)
    pub chunk: usize,
    /// worker threads (0 = auto)
    pub threads: usize,
    /// merge-kernel tier (`[core] kernel = auto|scalar|simd`) for every
    /// lane merge — the in-memory pipelines, the service's merge
    /// commands, and (substituted into [`AppConfig::external_config`])
    /// the external sorter. Defaults from `FLIMS_KERNEL` (unset =
    /// `auto`).
    pub kernel: MergeKernel,
    /// AOT artifact directory for the PJRT runtime
    pub artifacts_dir: String,
    /// hardware-sim FIFO depth per bank
    pub fifo_depth: usize,
    /// service bind address
    pub bind: String,
    /// dynamic-batcher max batch
    pub batch_max: usize,
    /// dynamic-batcher window in microseconds
    pub batch_window_us: u64,
    /// maximum concurrently *running* scheduler jobs (`[server]
    /// max_jobs`; 1 = serial, the pre-scheduler behaviour). The
    /// `[external]` memory/disk/thread budgets are carved evenly across
    /// this many slots. Defaults from `FLIMS_MAX_JOBS` (unset = 2) so
    /// CI can run the whole suite with a wider scheduler.
    pub max_jobs: usize,
    /// bounded admission queue: jobs beyond the running `max_jobs` wait
    /// here (`[server] queue_depth`); past that, requests are rejected
    /// with `err busy` — backpressure instead of unbounded pile-up.
    pub job_queue_depth: usize,
    /// times a job that failed on a *transient* I/O error is re-admitted
    /// before its failure is final (`[server] job_retries`; 0 = never,
    /// the default — a deterministic sort that failed once normally
    /// fails again). Capped at 8.
    pub job_retries: usize,
    /// per-connection read timeout in milliseconds (`[server]
    /// read_timeout_ms`; 0 = wait forever). A client that connects and
    /// goes silent is reaped after this long instead of pinning its
    /// handler thread for the life of the process.
    pub read_timeout_ms: u64,
    /// external (out-of-core) sort tuning; `w`/`chunk` here are
    /// placeholders — [`AppConfig::external_config`] substitutes the
    /// engine's values so one pair of knobs tunes both pipelines.
    pub external: ExternalConfig,
}

impl Default for AppConfig {
    fn default() -> Self {
        AppConfig {
            w: 16,
            chunk: 128,
            threads: 0,
            kernel: MergeKernel::env_default(),
            artifacts_dir: "artifacts".into(),
            fifo_depth: 2,
            bind: "127.0.0.1:7171".into(),
            batch_max: 8,
            batch_window_us: 500,
            max_jobs: max_jobs_default(),
            job_queue_depth: 16,
            job_retries: 0,
            read_timeout_ms: 300_000,
            external: ExternalConfig::default(),
        }
    }
}

/// The `max_jobs` default: the `FLIMS_MAX_JOBS` environment variable
/// when set and valid, else 2. This is how the CI `test-concurrent`
/// lane runs the full suite with a wider scheduler without touching
/// every test's config. An invalid value warns on stderr instead of
/// silently meaning "2" — a typo would quietly serialise the lane.
fn max_jobs_default() -> usize {
    match std::env::var("FLIMS_MAX_JOBS") {
        Err(_) => 2,
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if (1..=64).contains(&n) => n,
            _ => {
                eprintln!("warning: FLIMS_MAX_JOBS ignored: '{v}' (expected 1..=64)");
                2
            }
        },
    }
}

impl AppConfig {
    /// Layer a RawConfig (file) over the defaults.
    pub fn apply(&mut self, raw: &RawConfig) -> Result<(), String> {
        if let Some(v) = raw.get_usize("engine", "w")? {
            self.w = v;
        }
        if let Some(v) = raw.get_usize("engine", "chunk")? {
            self.chunk = v;
        }
        if let Some(v) = raw.get_usize("engine", "threads")? {
            self.threads = v;
        }
        if let Some(v) = raw.get("core", "kernel") {
            self.kernel = MergeKernel::parse(v).map_err(|e| format!("core.kernel: {e}"))?;
        }
        if let Some(v) = raw.get("runtime", "artifacts_dir") {
            self.artifacts_dir = v.to_string();
        }
        if let Some(v) = raw.get_usize("hw", "fifo_depth")? {
            self.fifo_depth = v;
        }
        if let Some(v) = raw.get("service", "bind") {
            self.bind = v.to_string();
        }
        if let Some(v) = raw.get_usize("service", "batch_max")? {
            self.batch_max = v;
        }
        if let Some(v) = raw.get_usize("service", "batch_window_us")? {
            self.batch_window_us = v as u64;
        }
        if let Some(v) = raw.get_usize("server", "max_jobs")? {
            self.max_jobs = v;
        }
        if let Some(v) = raw.get_usize("server", "queue_depth")? {
            self.job_queue_depth = v;
        }
        if let Some(v) = raw.get_usize("server", "job_retries")? {
            self.job_retries = v;
        }
        if let Some(v) = raw.get_usize("server", "read_timeout_ms")? {
            self.read_timeout_ms = v as u64;
        }
        if let Some(v) = raw.get("fault", "plan") {
            // The fault section maps onto the external config's
            // injection plan — same grammar (and error wording) as the
            // CLI's --faults and the protocol's faults= option. An
            // empty value / "off" disables injection, overriding a
            // FLIMS_FAULTS env default.
            self.external.fault =
                crate::fault::parse_faults_arg(v).map_err(|e| format!("fault.plan: {e}"))?;
        }
        if let Some(v) = raw.get_usize("external", "mem_budget_mb")? {
            self.external.mem_budget_bytes = v << 20;
        }
        if let Some(v) = raw.get_usize("external", "fan_in")? {
            self.external.fan_in = v;
        }
        if let Some(v) = raw.get("external", "tmp_dir") {
            self.external.tmp_dir = Some(std::path::PathBuf::from(v));
        }
        if let Some(v) = raw.get_usize("external", "disk_budget_mb")? {
            self.external.disk_budget_bytes = Some((v as u64) << 20);
        }
        if let Some(v) = raw.get_usize("external", "threads")? {
            self.external.threads = v;
        }
        if let Some(v) = raw.get_usize("external", "prefetch_blocks")? {
            self.external.prefetch_blocks = v;
        }
        if let Some(v) = raw.get("external", "overlap") {
            self.external.overlap = crate::external::parse_overlap(v)
                .map_err(|e| format!("external.overlap: {e}"))?;
        }
        if let Some(v) = raw.get("external", "dtype") {
            // Same parser (and error wording) as the CLI and protocol.
            self.external.dtype = parse_dtype_arg(v)?;
        }
        if let Some(v) = raw.get("external", "codec") {
            // One parser for config/CLI/protocol: the "codec argument:"
            // prefix is the same everywhere a codec name can be typed.
            self.external.codec = parse_codec_arg(v)?;
        }
        if let Some(v) = raw.get("obs", "trace_dir") {
            // The observability section maps onto the external config's
            // trace_dir — every external sort auto-writes a Chrome
            // trace-event JSON into the directory (empty = disable,
            // overriding a FLIMS_TRACE_DIR env default).
            self.external.trace_dir =
                if v.is_empty() { None } else { Some(std::path::PathBuf::from(v)) };
        }
        self.validate()
    }

    /// Reject configurations the engines cannot run with.
    pub fn validate(&self) -> Result<(), String> {
        if !self.w.is_power_of_two() {
            return Err(format!("engine.w = {} must be a power of two", self.w));
        }
        if !self.chunk.is_power_of_two() || self.chunk < self.w {
            return Err(format!(
                "engine.chunk = {} must be a power of two >= w",
                self.chunk
            ));
        }
        if self.batch_max == 0 {
            return Err("service.batch_max must be > 0".into());
        }
        if !(1..=64).contains(&self.max_jobs) {
            return Err(format!("server.max_jobs = {} must be in 1..=64", self.max_jobs));
        }
        if self.job_queue_depth > 1024 {
            return Err(format!(
                "server.queue_depth = {} is absurd (max 1024, 0 = reject when slots are full)",
                self.job_queue_depth
            ));
        }
        if self.job_retries > 8 {
            return Err(format!(
                "server.job_retries = {} is absurd (max 8, 0 = never re-admit)",
                self.job_retries
            ));
        }
        self.external_config().validate()
    }

    /// The external-sort configuration with the engine's `w`/`chunk`
    /// and the `[core]` merge kernel substituted in (the `[external]`
    /// section tunes only the out-of-core knobs).
    pub fn external_config(&self) -> ExternalConfig {
        ExternalConfig {
            w: self.w,
            chunk: self.chunk,
            kernel: self.kernel,
            ..self.external.clone()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::external::Codec;

    const SAMPLE: &str = r#"
# engine tuning
[engine]
w = 32
chunk = 256
threads = 4

[runtime]
artifacts_dir = "custom/artifacts"

[service]
bind = "0.0.0.0:9999"
batch_max = 16
"#;

    #[test]
    fn parses_sections_and_values() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        assert_eq!(raw.get("engine", "w"), Some("32"));
        assert_eq!(raw.get("runtime", "artifacts_dir"), Some("custom/artifacts"));
        assert_eq!(raw.get("service", "bind"), Some("0.0.0.0:9999"));
        assert_eq!(raw.get("nope", "x"), None);
    }

    #[test]
    fn applies_over_defaults() {
        let raw = RawConfig::parse(SAMPLE).unwrap();
        let mut cfg = AppConfig::default();
        cfg.apply(&raw).unwrap();
        assert_eq!(cfg.w, 32);
        assert_eq!(cfg.chunk, 256);
        assert_eq!(cfg.threads, 4);
        assert_eq!(cfg.batch_max, 16);
        assert_eq!(cfg.fifo_depth, 2); // untouched default
    }

    #[test]
    fn rejects_bad_values() {
        let raw = RawConfig::parse("[engine]\nw = 3\n").unwrap();
        let mut cfg = AppConfig::default();
        assert!(cfg.apply(&raw).is_err());

        let raw = RawConfig::parse("[engine]\nw = banana\n").unwrap();
        let mut cfg = AppConfig::default();
        assert!(cfg.apply(&raw).is_err());
    }

    #[test]
    fn comments_and_blank_lines() {
        let raw = RawConfig::parse("# hi\n\n[a]\nx = 1 # trailing\n").unwrap();
        assert_eq!(raw.get("a", "x"), Some("1"));
    }

    #[test]
    fn malformed_line_errors() {
        assert!(RawConfig::parse("[a]\nnot-a-kv\n").is_err());
    }

    #[test]
    fn bools_parse() {
        let raw = RawConfig::parse("[x]\na = true\nb = false\n").unwrap();
        assert_eq!(raw.get_bool("x", "a").unwrap(), Some(true));
        assert_eq!(raw.get_bool("x", "b").unwrap(), Some(false));
        assert!(RawConfig::parse("[x]\na = maybe\n")
            .unwrap()
            .get_bool("x", "a")
            .is_err());
    }

    #[test]
    fn chunk_must_cover_w() {
        let raw = RawConfig::parse("[engine]\nw = 64\nchunk = 32\n").unwrap();
        let mut cfg = AppConfig::default();
        assert!(cfg.apply(&raw).is_err());
    }

    #[test]
    fn external_section_applies() {
        let raw = RawConfig::parse(
            "[engine]\nw = 32\nchunk = 256\n\
             [external]\nmem_budget_mb = 16\nfan_in = 4\n\
             tmp_dir = \"/tmp/spills\"\ndisk_budget_mb = 512\n\
             threads = 4\nprefetch_blocks = 3\noverlap = \"on\"\n\
             dtype = \"kv\"\ncodec = \"delta\"\n",
        )
        .unwrap();
        let mut cfg = AppConfig::default();
        cfg.apply(&raw).unwrap();
        let ext = cfg.external_config();
        assert_eq!(ext.mem_budget_bytes, 16 << 20);
        assert_eq!(ext.fan_in, 4);
        assert_eq!(ext.tmp_dir, Some(std::path::PathBuf::from("/tmp/spills")));
        assert_eq!(ext.disk_budget_bytes, Some(512 << 20));
        assert_eq!(ext.threads, 4);
        assert_eq!(ext.prefetch_blocks, 3);
        assert!(ext.overlap);
        assert_eq!(ext.dtype, Dtype::Kv);
        assert_eq!(ext.codec, Codec::Delta);
        // The engine's lane/chunk tuning flows into the external sort.
        assert_eq!(ext.w, 32);
        assert_eq!(ext.chunk, 256);

        // And overlap switches back off explicitly, whatever the env
        // default was.
        let raw = RawConfig::parse("[external]\noverlap = off\n").unwrap();
        let mut cfg = AppConfig::default();
        cfg.apply(&raw).unwrap();
        assert!(!cfg.external.overlap);

        // All three codec names round-trip through the one parser.
        for (name, codec) in
            [("raw", Codec::Raw), ("delta", Codec::Delta), ("flr3", Codec::Flr3)]
        {
            let raw =
                RawConfig::parse(&format!("[external]\ncodec = \"{name}\"\n")).unwrap();
            let mut cfg = AppConfig::default();
            cfg.apply(&raw).unwrap();
            assert_eq!(cfg.external.codec, codec, "{name}");
        }
    }

    #[test]
    fn core_kernel_applies_and_flows_into_external() {
        let raw = RawConfig::parse("[core]\nkernel = \"scalar\"\n").unwrap();
        let mut cfg = AppConfig::default();
        cfg.apply(&raw).unwrap();
        assert_eq!(cfg.kernel, MergeKernel::Scalar);
        assert_eq!(cfg.external_config().kernel, MergeKernel::Scalar);

        let raw = RawConfig::parse("[core]\nkernel = simd\n").unwrap();
        let mut cfg = AppConfig::default();
        cfg.apply(&raw).unwrap();
        assert_eq!(cfg.kernel, MergeKernel::Simd);

        // ExternalConfig-style validation: a bad value is a loud error
        // naming the key, before anything runs.
        let raw = RawConfig::parse("[core]\nkernel = \"gpu\"\n").unwrap();
        let mut cfg = AppConfig::default();
        let err = cfg.apply(&raw).unwrap_err();
        assert!(err.contains("core.kernel: unknown kernel 'gpu'"), "{err}");
    }

    #[test]
    fn obs_trace_dir_applies_and_flows_into_external() {
        let raw = RawConfig::parse("[obs]\ntrace_dir = \"/tmp/flims-traces\"\n").unwrap();
        let mut cfg = AppConfig::default();
        cfg.apply(&raw).unwrap();
        assert_eq!(
            cfg.external_config().trace_dir,
            Some(std::path::PathBuf::from("/tmp/flims-traces"))
        );

        // An empty value disables auto-tracing even over an env default.
        let raw = RawConfig::parse("[obs]\ntrace_dir = \"\"\n").unwrap();
        let mut cfg = AppConfig::default();
        cfg.external.trace_dir = Some(std::path::PathBuf::from("/elsewhere"));
        cfg.apply(&raw).unwrap();
        assert_eq!(cfg.external_config().trace_dir, None);
    }

    #[test]
    fn server_section_applies() {
        let raw = RawConfig::parse(
            "[server]\nmax_jobs = 4\nqueue_depth = 32\njob_retries = 2\nread_timeout_ms = 5000\n",
        )
        .unwrap();
        let mut cfg = AppConfig::default();
        cfg.apply(&raw).unwrap();
        assert_eq!(cfg.max_jobs, 4);
        assert_eq!(cfg.job_queue_depth, 32);
        assert_eq!(cfg.job_retries, 2);
        assert_eq!(cfg.read_timeout_ms, 5000);
        // Defaults: no re-admission, 5-minute idle reap.
        let cfg = AppConfig::default();
        assert_eq!(cfg.job_retries, 0);
        assert_eq!(cfg.read_timeout_ms, 300_000);
    }

    #[test]
    fn fault_plan_applies_and_flows_into_external() {
        use crate::fault::{FaultSpec, KIND_STALL, KIND_TRANSIENT};
        let raw = RawConfig::parse("[fault]\nplan = \"7:0.01:transient,stall\"\n").unwrap();
        let mut cfg = AppConfig::default();
        cfg.apply(&raw).unwrap();
        assert_eq!(
            cfg.external_config().fault,
            Some(FaultSpec { seed: 7, rate_ppm: 10_000, kinds: KIND_TRANSIENT | KIND_STALL })
        );

        // "off" disables injection even over an env default.
        let raw = RawConfig::parse("[fault]\nplan = \"off\"\n").unwrap();
        let mut cfg = AppConfig::default();
        cfg.external.fault =
            Some(FaultSpec { seed: 1, rate_ppm: 5, kinds: KIND_TRANSIENT });
        cfg.apply(&raw).unwrap();
        assert_eq!(cfg.external_config().fault, None);

        // Bad plans are loud config errors naming the key.
        let raw = RawConfig::parse("[fault]\nplan = \"7:2.0:all\"\n").unwrap();
        let mut cfg = AppConfig::default();
        let err = cfg.apply(&raw).unwrap_err();
        assert!(err.contains("fault.plan:"), "{err}");
    }

    #[test]
    fn bad_server_values_rejected() {
        let raw = RawConfig::parse("[server]\nmax_jobs = 0\n").unwrap();
        let mut cfg = AppConfig::default();
        let err = cfg.apply(&raw).unwrap_err();
        assert!(err.contains("server.max_jobs"), "{err}");
        let raw = RawConfig::parse("[server]\nmax_jobs = 100\n").unwrap();
        let mut cfg = AppConfig::default();
        assert!(cfg.apply(&raw).is_err());
        let raw = RawConfig::parse("[server]\nqueue_depth = 100000\n").unwrap();
        let mut cfg = AppConfig::default();
        let err = cfg.apply(&raw).unwrap_err();
        assert!(err.contains("server.queue_depth"), "{err}");
        let raw = RawConfig::parse("[server]\njob_retries = 100\n").unwrap();
        let mut cfg = AppConfig::default();
        let err = cfg.apply(&raw).unwrap_err();
        assert!(err.contains("server.job_retries"), "{err}");
    }

    #[test]
    fn external_defaults_are_serial_u32() {
        let cfg = AppConfig::default();
        assert_eq!(cfg.external.threads, 1);
        assert_eq!(cfg.external.prefetch_blocks, 2);
        // The dtype and codec defaults honour FLIMS_DTYPE/FLIMS_CODEC
        // (the kv64 and flr3 CI lanes), so compare against the
        // env-aware defaults, not the literal U32/Raw.
        assert_eq!(cfg.external.dtype, ExternalConfig::default().dtype);
        assert_eq!(cfg.external.codec, ExternalConfig::default().codec);
    }

    #[test]
    fn bad_external_values_rejected() {
        let raw = RawConfig::parse("[external]\nfan_in = 1\n").unwrap();
        let mut cfg = AppConfig::default();
        assert!(cfg.apply(&raw).is_err());
        let raw = RawConfig::parse("[external]\nmem_budget_mb = banana\n").unwrap();
        let mut cfg = AppConfig::default();
        assert!(cfg.apply(&raw).is_err());
        let raw = RawConfig::parse("[external]\ndtype = \"f64\"\n").unwrap();
        let mut cfg = AppConfig::default();
        let err = cfg.apply(&raw).unwrap_err();
        assert!(err.contains("unknown dtype"), "{err}");
        let raw = RawConfig::parse("[external]\ncodec = \"lz4\"\n").unwrap();
        let mut cfg = AppConfig::default();
        let err = cfg.apply(&raw).unwrap_err();
        // Same wording as CLI/protocol: one parser, one error shape.
        assert!(err.contains("codec argument: unknown codec 'lz4'"), "{err}");
        assert!(err.contains("raw|delta|flr3"), "{err}");
        let raw = RawConfig::parse("[external]\nthreads = 5000\n").unwrap();
        let mut cfg = AppConfig::default();
        assert!(cfg.apply(&raw).is_err());
        // prefetch_blocks is bounded like threads — absurd values are
        // config errors, not silent thread storms.
        let raw = RawConfig::parse("[external]\nprefetch_blocks = 100000\n").unwrap();
        let mut cfg = AppConfig::default();
        let err = cfg.apply(&raw).unwrap_err();
        assert!(err.contains("prefetch_blocks"), "{err}");
        let raw = RawConfig::parse("[external]\noverlap = \"sideways\"\n").unwrap();
        let mut cfg = AppConfig::default();
        let err = cfg.apply(&raw).unwrap_err();
        assert!(err.contains("external.overlap: unknown overlap value"), "{err}");
    }
}
