//! # FLiMS — Fast Lightweight 2-way Merge Sorter
//!
//! Full-system reproduction of *"FLiMS: a Fast Lightweight 2-way Merge
//! Sorter"* (Papaphilippou, Luk, Brooks — IEEE Transactions on
//! Computers, 2022; DOI 10.1109/TC.2022.3146509).
//!
//! The crate is the runtime (Layer-3) half of a three-layer stack:
//!
//! * **Layer 1** (`python/compile/kernels/`): the FLiMS merge step and
//!   bitonic sort-in-chunks as Pallas kernels.
//! * **Layer 2** (`python/compile/model.py`): JAX merge/sort graphs,
//!   AOT-lowered to HLO-text artifacts.
//! * **Layer 3** (this crate): the FLiMS algorithm family in rust, the
//!   cycle-accurate hardware substrate, merge-tree coordination, a sort
//!   service, and a PJRT runtime that executes the AOT artifacts —
//!   Python never runs on the request path.
//!
//! Module tour:
//!
//! * [`key`] — sort-item traits (keys, records, sentinels).
//! * [`flims`] — the paper's algorithms 1–4 plus complete sort
//!   (sequential and parallel).
//! * [`baselines`] — std-sort, LSD radix, samplesort, and the "basic"
//!   bitonic merger the paper compares against.
//! * [`hw`] — structural netlist generators + cycle-accurate simulator
//!   for FLiMS/FLiMSj/PMT/MMS/VMS/WMS/EHMS/basic, with LUT/FF cost and
//!   Fmax timing models (the FPGA-substrate substitute; DESIGN.md §4).
//! * [`tree`] — PMT / HPMT merge-tree coordination (fig. 1–2).
//! * [`external`] — out-of-core external sort, parallel in both phases
//!   and generic over the dataset type (`u32`/`u64`/`kv`/`kv64`/`f32`):
//!   phase 1 spills bounded-memory runs from a pool of sort workers fed
//!   by a bounded work queue; phase 2 is a k-way streaming merge through
//!   trees of FLiMS 2-way mergers — the stable §4.2 variant for payload
//!   records, the fast untagged lanes for plain keys (multi-pass above
//!   the fan-in,
//!   independent group merges of a pass running concurrently), with
//!   double-buffered leaves — a prefetch thread per run overlaps disk
//!   reads with merging. Key ties keep input order end to end (§6).
//! * [`coordinator`] — sorting-as-a-service: router + dynamic batcher.
//! * [`runtime`] — PJRT client wrapper executing `artifacts/*.hlo.txt`
//!   (a stub unless built with the `pjrt` feature).
//! * [`config`] / [`metrics`] / [`data`] / [`util`] — framework glue.

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod external;
pub mod flims;
pub mod hw;
pub mod key;
pub mod metrics;
pub mod runtime;
pub mod tree;
pub mod util;

pub use external::{sort_file, sort_file_dtype, Dtype, ExtItem, ExternalConfig, SpillStats};
pub use flims::{merge_asc, merge_desc, par_sort_desc, sort_asc, sort_desc, SortConfig};
pub use key::{is_sorted_desc, F32Key, Item, Key, Kv, Kv64};
