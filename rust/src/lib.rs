//! # FLiMS — Fast Lightweight 2-way Merge Sorter
//!
//! Full-system reproduction of *"FLiMS: a Fast Lightweight 2-way Merge
//! Sorter"* (Papaphilippou, Luk, Brooks — IEEE Transactions on
//! Computers, 2022; DOI 10.1109/TC.2022.3146509). See the repository
//! `README.md` for the architecture map and quickstart, and
//! `docs/FORMATS.md` for the on-disk formats.
//!
//! The crate is the runtime (Layer-3) half of a three-layer stack:
//!
//! * **Layer 1** (`python/compile/kernels/`): the FLiMS merge step and
//!   bitonic sort-in-chunks as Pallas kernels.
//! * **Layer 2** (`python/compile/model.py`): JAX merge/sort graphs,
//!   AOT-lowered to HLO-text artifacts.
//! * **Layer 3** (this crate): the FLiMS algorithm family in rust, the
//!   cycle-accurate hardware substrate, merge-tree coordination, a sort
//!   service, and a PJRT runtime that executes the AOT artifacts —
//!   Python never runs on the request path.
//!
//! ## Example
//!
//! Sort a vector through the external pipeline and merge two sorted
//! lists with the paper's 2-way merger:
//!
//! ```
//! use flims::{merge_asc, sort_vec, ExternalConfig};
//!
//! // Bounded-memory sort (descending). Inputs that fit one run skip
//! // the spill machinery entirely.
//! let (sorted, stats) = sort_vec(&[5u32, 1, 9, 3], &ExternalConfig::default()).unwrap();
//! assert_eq!(sorted, vec![9, 5, 3, 1]);
//! assert_eq!(stats.runs_spilled, 0); // fits in memory: no disk involved
//!
//! // The FLiMS 2-way merge (ascending wrapper), lane width w = 4.
//! let merged = merge_asc(&[1u32, 4, 7], &[2, 3, 9], 4);
//! assert_eq!(merged, vec![1, 2, 3, 4, 7, 9]);
//! ```
//!
//! ## Module tour
//!
//! * [`key`] — sort-item traits (keys, records, sentinels).
//! * [`flims`] — the paper's algorithms 1–4 plus complete sort
//!   (sequential and parallel). [`flims::simd`] is the explicit-SIMD
//!   kernel tier (§8): the selector + butterfly written with
//!   `core::arch` intrinsics (SSE2/AVX2/NEON, runtime-dispatched) for
//!   the plain-key dtypes, selected by the `[core] kernel` config key,
//!   the `FLIMS_KERNEL` env var, `--kernel`, or `kernel=` per request —
//!   byte-identical output on every tier (see `docs/KERNELS.md`).
//! * [`baselines`] — std-sort, LSD radix, samplesort, and the "basic"
//!   bitonic merger the paper compares against.
//! * [`hw`] — structural netlist generators + cycle-accurate simulator
//!   for FLiMS/FLiMSj/PMT/MMS/VMS/WMS/EHMS/basic, with LUT/FF cost and
//!   Fmax timing models (the FPGA-substrate substitute; DESIGN.md §4).
//! * [`tree`] — PMT / HPMT merge-tree coordination (fig. 1–2).
//! * [`external`] — out-of-core external sort, parallel in both phases
//!   and generic over the dataset type (`u32`/`u64`/`kv`/`kv64`/`f32`):
//!   phase 1 spills bounded-memory runs from a pool of sort workers fed
//!   by a bounded work queue; phase 2 is a k-way streaming merge through
//!   trees of FLiMS 2-way mergers — the stable §4.2 variant for payload
//!   records, the fast untagged lanes for plain keys (multi-pass above
//!   the fan-in, independent group merges of a pass running
//!   concurrently). With `[external] overlap = on` the two phases run
//!   as one pipeline (TopSort-style): phase 1 announces each sealed run
//!   over a bounded channel and fan-in groups start merging while later
//!   runs still spill — byte-identical output, overlapping wall-clock.
//!   Both spill boundaries flow through the run-codec layer
//!   ([`external::codec`]): raw `FLR1` or delta+varint `FLR2` runs,
//!   encoded on pooled double-buffered writer threads and decoded on
//!   the prefetch threads, so codec CPU and disk I/O overlap the merge.
//!   Key ties keep input order end to end (§6).
//! * [`fault`] — deterministic seeded fault injection at every spill-I/O
//!   seam plus the recovery half: bounded-backoff retry, disk-pressure
//!   degradation, and crash-recovery sweeps (see `docs/ROBUSTNESS.md`).
//! * [`coordinator`] — sorting-as-a-service: router + dynamic batcher.
//! * [`obs`] — observability: the per-sort [`obs::Trace`] span ring
//!   rendered as Chrome trace-event JSON ([`obs::chrome`]), plus the
//!   process-wide progress counters ([`obs::progress`]) behind the
//!   `progress` verb and the Prometheus exposition served by the
//!   `metrics` verb (see `docs/OBSERVABILITY.md`).
//! * [`runtime`] — PJRT client wrapper executing `artifacts/*.hlo.txt`
//!   (a stub unless built with the `pjrt` feature).
//! * [`config`] / [`metrics`] / [`data`] / [`util`] — framework glue.

#![warn(missing_docs)]

// The documentation gate (`missing_docs` + `cargo doc -D warnings` in
// CI) is enforced module-by-module as the rustdoc pass spreads. These
// pre-codec modules are grandfathered; new modules must not be added
// here.
#[allow(missing_docs)]
pub mod baselines;
pub mod config;
pub mod coordinator;
#[allow(missing_docs)]
pub mod data;
pub mod external;
pub mod fault;
#[allow(missing_docs)]
pub mod flims;
#[allow(missing_docs)]
pub mod hw;
#[allow(missing_docs)]
pub mod key;
pub mod metrics;
pub mod obs;
#[allow(missing_docs)]
pub mod runtime;
#[allow(missing_docs)]
pub mod tree;
#[allow(missing_docs)]
pub mod util;

pub use external::{
    sort_file, sort_file_dtype, sort_vec, Codec, Dtype, ExtItem, ExternalConfig, SpillStats,
};
pub use flims::{
    merge_asc, merge_desc, par_sort_desc, sort_asc, sort_desc, MergeKernel, SortConfig,
};
pub use key::{is_sorted_desc, F32Key, Item, Key, Kv, Kv64};
pub use obs::{SpanKind, Trace};
