//! Lightweight metrics: atomic counters, gauges, and a latency
//! histogram with percentile snapshots — the process-wide registry
//! behind the coordinator's data plane, the `stats` line, and the
//! Prometheus text exposition served by the `metrics` protocol verb
//! (`docs/OBSERVABILITY.md` lists every metric and label).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// A zeroed counter, usable in `static` registries.
    pub const fn new() -> Self {
        Counter { v: AtomicU64::new(0) }
    }
    /// Add one.
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }
    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }
    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
    /// Zero the counter (`stats reset`).
    pub fn reset(&self) {
        self.v.store(0, Ordering::Relaxed);
    }
}

/// A value that goes up *and* down (active jobs, queue depths).
/// Increments and decrements must balance — the counter wraps rather
/// than saturating on a stray extra decrement.
#[derive(Debug, Default)]
pub struct Gauge {
    v: AtomicU64,
}

impl Gauge {
    /// A zeroed gauge, usable in `static` registries.
    pub const fn new() -> Self {
        Gauge { v: AtomicU64::new(0) }
    }
    /// Add one.
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }
    /// Subtract one.
    pub fn dec(&self) {
        self.v.fetch_sub(1, Ordering::Relaxed);
    }
    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Log-bucketed latency histogram (1µs … ~64s, 2× buckets) — coarse but
/// lock-free and allocation-free on the hot path.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

const NBUCKETS: usize = 27; // 2^0 .. 2^26 µs

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    /// Record one latency sample.
    pub fn observe(&self, d: Duration) {
        // Clamp before narrowing: a pathological duration must land in
        // the overflow bucket, not wrap the microsecond math; and the
        // running sum saturates instead of overflowing.
        let ns = d.as_nanos().min(u64::MAX as u128) as u64;
        let us = (ns / 1000).max(1);
        let bucket = (63 - us.leading_zeros() as usize).min(NBUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let _ = self
            .sum_ns
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |c| Some(c.saturating_add(ns)));
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Total of every observed duration, nanoseconds (saturating).
    pub fn sum_ns(&self) -> u64 {
        self.sum_ns.load(Ordering::Relaxed)
    }

    /// A snapshot of the per-bucket sample counts; bucket `i` covers
    /// `[2^i, 2^(i+1))` µs, with the last bucket open-ended.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Mean latency.
    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / c)
    }

    /// Approximate percentile, interpolated within the winning bucket:
    /// the `r`-th of `k` samples in bucket `[lo, 2·lo)` is read at
    /// `lo + lo·(r − ½)/k` (midpoint-rank), so a histogram holding one
    /// 3µs sample reports p50 = 3µs, not the 4µs bucket upper bound.
    pub fn percentile(&self, p: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = (((total as f64) * p / 100.0).ceil() as u64).clamp(1, total);
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let k = b.load(Ordering::Relaxed);
            if k > 0 && acc + k >= target {
                let lower = (1u64 << i) as f64; // µs; bucket width == lower
                let rank = (target - acc) as f64;
                let frac = ((rank - 0.5) / k as f64).clamp(0.0, 1.0);
                let us = lower + lower * frac;
                return Duration::from_nanos((us * 1000.0).round() as u64);
            }
            acc += k;
        }
        // Unreachable (target ≤ total); keep the historical bound.
        Duration::from_micros(1u64 << NBUCKETS)
    }

    /// Zero every bucket and total (`stats reset`).
    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum_ns.store(0, Ordering::Relaxed);
    }

    /// One-line count/mean/percentile summary.
    pub fn snapshot(&self) -> String {
        format!(
            "count={} mean={:?} p50={:?} p99={:?}",
            self.count(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(99.0)
        )
    }

    /// Append this histogram in Prometheus text format (cumulative
    /// `_bucket{le=…}` lines in seconds, then `_sum` / `_count`). The
    /// open-ended overflow bucket folds into `le="+Inf"`.
    pub fn prometheus_into(&self, name: &str, help: &str, out: &mut String) {
        use std::fmt::Write as _;
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().take(NBUCKETS - 1).enumerate() {
            acc += b.load(Ordering::Relaxed);
            let le = (1u64 << (i + 1)) as f64 * 1e-6;
            let _ = writeln!(out, "{name}_bucket{{le=\"{le}\"}} {acc}");
        }
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", self.count());
        let _ = writeln!(out, "{name}_sum {}", self.sum_ns() as f64 * 1e-9);
        let _ = writeln!(out, "{name}_count {}", self.count());
    }
}

/// Append one `# HELP`/`# TYPE`/value triple in Prometheus text format.
fn push_metric(
    out: &mut String,
    name: &str,
    help: &str,
    kind: &str,
    value: impl std::fmt::Display,
) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    let _ = writeln!(out, "{name} {value}");
}

/// The label set a per-sort sample is aggregated under in the
/// exposition: what was sorted (`dtype`), how its spill runs were
/// encoded (`codec`), which merge-kernel tier ran (`kernel`, the
/// *effective* name for that dtype — see `Dtype::effective_kernel`),
/// and which schedule (`overlap`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct SortLabels {
    /// Record type name (`u32` | `u64` | `i32` | `i64` | `kv` | `kv64` | `f32`).
    pub dtype: &'static str,
    /// Effective spill codec name (`raw` | `delta`).
    pub codec: &'static str,
    /// Effective merge-kernel name for this dtype (`scalar`,
    /// `simd-avx2`, …) — what the sort's merges actually ran on, not
    /// the CPU-wide resolved ceiling.
    pub kernel: &'static str,
    /// Whether the pipelined schedule ran.
    pub overlap: bool,
}

impl SortLabels {
    fn render(&self) -> String {
        format!(
            "dtype=\"{}\",codec=\"{}\",kernel=\"{}\",overlap=\"{}\"",
            self.dtype,
            self.codec,
            self.kernel,
            if self.overlap { "on" } else { "off" }
        )
    }
}

/// The per-sort quantities aggregated under [`SortLabels`] — a plain
/// mirror of the external sorter's `SpillStats` fields that belong in
/// the exposition (the router converts between the two, keeping this
/// module free of external-sort types).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SortSample {
    /// Elements sorted.
    pub elements: u64,
    /// Runs spilled (initial + intermediate).
    pub runs_spilled: u64,
    /// Encoded bytes written to spill files.
    pub bytes_spilled: u64,
    /// The same traffic uncompressed.
    pub bytes_spilled_raw: u64,
    /// Merge passes executed.
    pub merge_passes: u64,
    /// End-to-end wall-clock, microseconds.
    pub wall_us: u64,
    /// Time the two phases ran concurrently, microseconds.
    pub overlap_us: u64,
    /// Codec encode wall-clock, microseconds.
    pub codec_encode_us: u64,
    /// Codec decode wall-clock, microseconds.
    pub codec_decode_us: u64,
}

impl SortSample {
    fn absorb(&mut self, o: &SortSample) {
        self.elements += o.elements;
        self.runs_spilled += o.runs_spilled;
        self.bytes_spilled += o.bytes_spilled;
        self.bytes_spilled_raw += o.bytes_spilled_raw;
        self.merge_passes += o.merge_passes;
        self.wall_us += o.wall_us;
        self.overlap_us += o.overlap_us;
        self.codec_encode_us += o.codec_encode_us;
        self.codec_decode_us += o.codec_decode_us;
    }
}

/// Labelled external-sort aggregates: every finished sort folds its
/// [`SortSample`] into the bucket for its [`SortLabels`], and the
/// exposition emits one line per label set per metric.
#[derive(Debug, Default)]
pub struct LabeledSpills {
    per_label: Mutex<BTreeMap<SortLabels, (u64, SortSample)>>,
}

impl LabeledSpills {
    /// Fold one finished sort into its label bucket.
    pub fn record(&self, labels: SortLabels, sample: &SortSample) {
        let mut map = self.per_label.lock().unwrap();
        let entry = map.entry(labels).or_default();
        entry.0 += 1;
        entry.1.absorb(sample);
    }

    /// Drop every aggregate (`stats reset`).
    pub fn reset(&self) {
        self.per_label.lock().unwrap().clear();
    }

    /// Append the labelled aggregates in Prometheus text format.
    pub fn prometheus_into(&self, out: &mut String) {
        use std::fmt::Write as _;
        let map = self.per_label.lock().unwrap();
        if map.is_empty() {
            return;
        }
        let mut metric = |name: &str, help: &str, value: &dyn Fn(u64, &SortSample) -> f64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            for (labels, (sorts, sample)) in map.iter() {
                let _ = writeln!(out, "{name}{{{}}} {}", labels.render(), value(*sorts, sample));
            }
        };
        metric("flims_sorts_total", "External sorts finished, by label.", &|s, _| s as f64);
        metric("flims_sort_elements_total", "Elements sorted, by label.", &|_, x| {
            x.elements as f64
        });
        metric("flims_sort_runs_spilled_total", "Runs spilled, by label.", &|_, x| {
            x.runs_spilled as f64
        });
        metric("flims_sort_spilled_bytes_total", "Encoded spill bytes, by label.", &|_, x| {
            x.bytes_spilled as f64
        });
        metric(
            "flims_sort_spilled_raw_bytes_total",
            "Uncompressed equivalent of the spill traffic, by label.",
            &|_, x| x.bytes_spilled_raw as f64,
        );
        metric("flims_sort_merge_passes_total", "Merge passes executed, by label.", &|_, x| {
            x.merge_passes as f64
        });
        metric("flims_sort_wall_seconds_total", "End-to-end sort wall-clock, by label.", &|_, x| {
            x.wall_us as f64 * 1e-6
        });
        metric(
            "flims_sort_overlap_seconds_total",
            "Wall-clock the two phases ran concurrently, by label.",
            &|_, x| x.overlap_us as f64 * 1e-6,
        );
        metric(
            "flims_sort_codec_encode_seconds_total",
            "Run-codec encode wall-clock, by label.",
            &|_, x| x.codec_encode_us as f64 * 1e-6,
        );
        metric(
            "flims_sort_codec_decode_seconds_total",
            "Run-codec decode wall-clock, by label.",
            &|_, x| x.codec_decode_us as f64 * 1e-6,
        );
    }
}

/// The coordinator's metric set.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Requests routed (all commands).
    pub requests: Counter,
    /// Batches the dynamic batcher flushed.
    pub batches: Counter,
    /// Elements across every sorted/merged request.
    pub elements_sorted: Counter,
    /// Requests answered with an `err` line.
    pub errors: Counter,
    /// End-to-end request latency.
    pub latency: LatencyHistogram,
    /// External (out-of-core) sort activity.
    pub external_sorts: Counter,
    /// Spilled runs written (initial + intermediate merge passes).
    pub runs_spilled: Counter,
    /// Encoded bytes written to spill files (what hit the disk).
    pub bytes_spilled: Counter,
    /// What the same spill traffic would occupy uncompressed — the
    /// denominator of the spill compression ratio.
    pub bytes_spilled_raw: Counter,
    /// Merge passes executed over spilled data.
    pub merge_passes: Counter,
    /// Cumulative run-codec encode wall-clock, microseconds.
    pub codec_encode_us: Counter,
    /// Cumulative run-codec decode wall-clock, microseconds.
    pub codec_decode_us: Counter,
    /// Cumulative phase-1 (run generation) wall-clock, microseconds.
    pub phase1_us: Counter,
    /// Cumulative phase-2 (k-way merge) wall-clock, microseconds.
    pub phase2_us: Counter,
    /// Cumulative end-to-end external-sort wall-clock, microseconds
    /// (under the overlapped schedule, less than phase1 + phase2).
    pub wall_us: Counter,
    /// Cumulative time the two phases ran concurrently, microseconds
    /// (0 for every serial-schedule sort).
    pub overlap_us: Counter,
    /// Leaf blocks the prefetch threads had ready before the merge
    /// asked (disk read fully overlapped with merging).
    pub prefetch_hits: Counter,
    /// Leaf blocks the merge had to wait for.
    pub prefetch_misses: Counter,
    /// External-sort aggregates by `dtype`/`codec`/`kernel`/`overlap`.
    pub per_sort: LabeledSpills,
}

impl ServiceMetrics {
    /// One-line snapshot of every counter — the `stats` protocol reply.
    pub fn report(&self) -> String {
        format!(
            "requests={} batches={} elements={} errors={} latency[{}] \
             external[sorts={} runs={} spilled_bytes={} spilled_raw={} \
             codec_enc_us={} codec_dec_us={} passes={} \
             phase1_us={} phase2_us={} wall_us={} overlap_us={} \
             prefetch_hits={} prefetch_misses={}]",
            self.requests.get(),
            self.batches.get(),
            self.elements_sorted.get(),
            self.errors.get(),
            self.latency.snapshot(),
            self.external_sorts.get(),
            self.runs_spilled.get(),
            self.bytes_spilled.get(),
            self.bytes_spilled_raw.get(),
            self.codec_encode_us.get(),
            self.codec_decode_us.get(),
            self.merge_passes.get(),
            self.phase1_us.get(),
            self.phase2_us.get(),
            self.wall_us.get(),
            self.overlap_us.get(),
            self.prefetch_hits.get(),
            self.prefetch_misses.get(),
        )
    }

    /// Zero every counter, the latency histogram, and the labelled
    /// aggregates (`stats reset`).
    pub fn reset(&self) {
        for c in [
            &self.requests,
            &self.batches,
            &self.elements_sorted,
            &self.errors,
            &self.external_sorts,
            &self.runs_spilled,
            &self.bytes_spilled,
            &self.bytes_spilled_raw,
            &self.merge_passes,
            &self.codec_encode_us,
            &self.codec_decode_us,
            &self.phase1_us,
            &self.phase2_us,
            &self.wall_us,
            &self.overlap_us,
            &self.prefetch_hits,
            &self.prefetch_misses,
        ] {
            c.reset();
        }
        self.latency.reset();
        self.per_sort.reset();
    }

    /// The full Prometheus text exposition of this metric set (no
    /// trailing `# EOF` — the serving layer appends process-level
    /// sections and the terminator).
    pub fn prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        let c = |out: &mut String, name: &str, help: &str, v: u64| {
            push_metric(out, name, help, "counter", v);
        };
        c(&mut out, "flims_requests_total", "Requests routed (all commands).", self.requests.get());
        c(
            &mut out,
            "flims_batches_total",
            "Batches the dynamic batcher flushed.",
            self.batches.get(),
        );
        c(
            &mut out,
            "flims_elements_sorted_total",
            "Elements across every sorted/merged request.",
            self.elements_sorted.get(),
        );
        c(&mut out, "flims_errors_total", "Requests answered with an err line.", self.errors.get());
        c(
            &mut out,
            "flims_external_sorts_total",
            "External (out-of-core) sorts finished.",
            self.external_sorts.get(),
        );
        c(
            &mut out,
            "flims_runs_spilled_total",
            "Spilled runs written (initial + intermediate).",
            self.runs_spilled.get(),
        );
        c(
            &mut out,
            "flims_spilled_bytes_total",
            "Encoded bytes written to spill files.",
            self.bytes_spilled.get(),
        );
        c(
            &mut out,
            "flims_spilled_raw_bytes_total",
            "Uncompressed equivalent of the spill traffic.",
            self.bytes_spilled_raw.get(),
        );
        c(
            &mut out,
            "flims_merge_passes_total",
            "Merge passes executed over spilled data.",
            self.merge_passes.get(),
        );
        let s = |out: &mut String, name: &str, help: &str, us: u64| {
            push_metric(out, name, help, "counter", us as f64 * 1e-6);
        };
        s(
            &mut out,
            "flims_codec_encode_seconds_total",
            "Run-codec encode wall-clock.",
            self.codec_encode_us.get(),
        );
        s(
            &mut out,
            "flims_codec_decode_seconds_total",
            "Run-codec decode wall-clock.",
            self.codec_decode_us.get(),
        );
        s(
            &mut out,
            "flims_phase1_seconds_total",
            "Phase-1 (run generation) wall-clock.",
            self.phase1_us.get(),
        );
        s(
            &mut out,
            "flims_phase2_seconds_total",
            "Phase-2 (k-way merge) wall-clock.",
            self.phase2_us.get(),
        );
        s(
            &mut out,
            "flims_wall_seconds_total",
            "End-to-end external-sort wall-clock.",
            self.wall_us.get(),
        );
        s(
            &mut out,
            "flims_overlap_seconds_total",
            "Wall-clock the two phases ran concurrently.",
            self.overlap_us.get(),
        );
        c(
            &mut out,
            "flims_prefetch_hits_total",
            "Leaf blocks buffered before the merge asked.",
            self.prefetch_hits.get(),
        );
        c(
            &mut out,
            "flims_prefetch_misses_total",
            "Leaf blocks the merge had to wait for.",
            self.prefetch_misses.get(),
        );
        self.latency.prometheus_into(
            "flims_request_latency_seconds",
            "End-to-end request latency.",
            &mut out,
        );
        self.per_sort.prometheus_into(&mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.reset();
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn gauge_basics() {
        let g = Gauge::new();
        g.inc();
        g.inc();
        g.dec();
        assert_eq!(g.get(), 1);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::default();
        for us in [10u64, 20, 40, 80, 500, 1000, 5000] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 7);
        assert!(h.percentile(50.0) <= h.percentile(99.0));
        assert!(h.mean() > Duration::ZERO);
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile(99.0), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.sum_ns(), 0);
        assert!(h.bucket_counts().iter().all(|&b| b == 0));
    }

    #[test]
    fn percentile_interpolates_within_bucket() {
        // One 3µs sample: bucket [2, 4) µs, midpoint rank → exactly
        // 3µs, not the 4µs upper bound the pre-fix code reported.
        let h = LatencyHistogram::default();
        h.observe(Duration::from_micros(3));
        assert_eq!(h.percentile(50.0), Duration::from_micros(3));
        assert_eq!(h.percentile(100.0), Duration::from_micros(3));

        // One 10µs sample: bucket [8, 16) µs → its midpoint, 12µs.
        let h = LatencyHistogram::default();
        h.observe(Duration::from_micros(10));
        assert_eq!(h.percentile(50.0), Duration::from_micros(12));
    }

    #[test]
    fn percentile_ranks_within_a_shared_bucket() {
        // Two samples in [2, 4) µs: ranks read at 2 + 2·(r−½)/2.
        let h = LatencyHistogram::default();
        h.observe(Duration::from_micros(2));
        h.observe(Duration::from_micros(3));
        assert_eq!(h.percentile(50.0), Duration::from_nanos(2500));
        assert_eq!(h.percentile(100.0), Duration::from_nanos(3500));
        assert!(h.percentile(50.0) >= Duration::from_micros(2));
        assert!(h.percentile(100.0) < Duration::from_micros(4));
    }

    #[test]
    fn observe_saturates_instead_of_wrapping() {
        let h = LatencyHistogram::default();
        h.observe(Duration::MAX);
        h.observe(Duration::MAX);
        assert_eq!(h.count(), 2);
        assert_eq!(h.sum_ns(), u64::MAX, "sum must saturate, not wrap");
        assert!(h.mean() > Duration::ZERO);
        // Both samples land in the open-ended overflow bucket.
        assert_eq!(h.bucket_counts()[NBUCKETS - 1], 2);
        assert!(h.percentile(50.0) >= Duration::from_micros(1 << 26));
    }

    #[test]
    fn overflow_bucket_percentile_is_finite() {
        let h = LatencyHistogram::default();
        h.observe(Duration::from_secs(1 << 30));
        let p = h.percentile(50.0);
        assert!(p >= Duration::from_micros(1 << 26));
        assert!(p <= Duration::from_micros(1 << 27));
    }

    #[test]
    fn histogram_reset_clears_everything() {
        let h = LatencyHistogram::default();
        h.observe(Duration::from_micros(5));
        h.reset();
        assert_eq!(h.count(), 0);
        assert_eq!(h.sum_ns(), 0);
        assert_eq!(h.percentile(50.0), Duration::ZERO);
    }

    #[test]
    fn metrics_report_formats() {
        let m = ServiceMetrics::default();
        m.requests.inc();
        let s = m.report();
        assert!(s.contains("requests=1"));
    }

    #[test]
    fn report_includes_spill_counters() {
        let m = ServiceMetrics::default();
        m.external_sorts.inc();
        m.runs_spilled.add(7);
        m.bytes_spilled.add(1024);
        m.bytes_spilled_raw.add(4096);
        m.codec_encode_us.add(300);
        m.codec_decode_us.add(200);
        m.merge_passes.add(2);
        m.phase1_us.add(1500);
        m.phase2_us.add(2500);
        m.wall_us.add(3000);
        m.overlap_us.add(1000);
        m.prefetch_hits.add(40);
        m.prefetch_misses.add(2);
        let s = m.report();
        assert!(s.contains("external[sorts=1 runs=7 spilled_bytes=1024 spilled_raw=4096"), "{s}");
        assert!(s.contains("codec_enc_us=300 codec_dec_us=200 passes=2"), "{s}");
        assert!(s.contains("phase1_us=1500 phase2_us=2500 wall_us=3000 overlap_us=1000"), "{s}");
        assert!(s.contains("prefetch_hits=40 prefetch_misses=2]"), "{s}");
    }

    #[test]
    fn service_metrics_reset_zeroes_the_report() {
        let m = ServiceMetrics::default();
        m.requests.add(9);
        m.bytes_spilled.add(512);
        m.latency.observe(Duration::from_micros(50));
        m.per_sort.record(
            SortLabels { dtype: "u32", codec: "raw", kernel: "scalar", overlap: false },
            &SortSample { elements: 10, ..Default::default() },
        );
        m.reset();
        let s = m.report();
        assert!(s.contains("requests=0"), "{s}");
        assert!(s.contains("spilled_bytes=0"), "{s}");
        assert!(s.contains("count=0"), "{s}");
        assert!(!m.prometheus().contains("flims_sorts_total{"));
    }

    /// Every exposition line must be a comment or `name[{labels}] value`
    /// with a float-parseable value — the grammar Prometheus scrapes.
    fn assert_exposition_parses(text: &str) {
        assert!(!text.is_empty());
        for line in text.lines() {
            if line.starts_with("# ") {
                continue;
            }
            let (series, value) = line.rsplit_once(' ').unwrap_or_else(|| {
                panic!("exposition line has no value: {line}");
            });
            assert!(!series.is_empty(), "{line}");
            let name = series.split('{').next().unwrap();
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name in: {line}"
            );
            if let Some(rest) = series.strip_prefix(name) {
                if !rest.is_empty() {
                    assert!(rest.starts_with('{') && rest.ends_with('}'), "{line}");
                }
            }
            assert!(value.parse::<f64>().is_ok(), "unparseable value in: {line}");
        }
    }

    #[test]
    fn prometheus_exposition_golden() {
        let m = ServiceMetrics::default();
        m.requests.add(5);
        m.errors.inc();
        m.bytes_spilled.add(2048);
        m.wall_us.add(1_500_000);
        m.latency.observe(Duration::from_micros(3));
        m.latency.observe(Duration::from_micros(700));
        let text = m.prometheus();
        assert_exposition_parses(&text);
        assert!(text.contains("# TYPE flims_requests_total counter"), "{text}");
        assert!(text.contains("\nflims_requests_total 5\n"), "{text}");
        assert!(text.contains("\nflims_errors_total 1\n"), "{text}");
        assert!(text.contains("\nflims_spilled_bytes_total 2048\n"), "{text}");
        assert!(text.contains("\nflims_wall_seconds_total 1.5\n"), "{text}");
        assert!(text.contains("# TYPE flims_request_latency_seconds histogram"), "{text}");
        assert!(text.contains("flims_request_latency_seconds_bucket{le=\"+Inf\"} 2"), "{text}");
        assert!(text.contains("flims_request_latency_seconds_count 2"), "{text}");
        // Cumulative buckets are monotone non-decreasing.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket{le=")) {
            let v: f64 = line.rsplit_once(' ').unwrap().1.parse().unwrap();
            assert!(v as u64 >= last, "bucket counts must be cumulative: {line}");
            last = v as u64;
        }
    }

    #[test]
    fn labeled_spills_expose_per_label_series() {
        let m = ServiceMetrics::default();
        let a = SortLabels { dtype: "u32", codec: "raw", kernel: "scalar", overlap: false };
        let b = SortLabels { dtype: "kv", codec: "delta", kernel: "scalar", overlap: true };
        m.per_sort.record(a, &SortSample { elements: 100, wall_us: 2000, ..Default::default() });
        m.per_sort.record(a, &SortSample { elements: 50, wall_us: 1000, ..Default::default() });
        m.per_sort.record(b, &SortSample { elements: 7, runs_spilled: 3, ..Default::default() });
        let text = m.prometheus();
        assert_exposition_parses(&text);
        let a_labels = "dtype=\"u32\",codec=\"raw\",kernel=\"scalar\",overlap=\"off\"";
        let b_labels = "dtype=\"kv\",codec=\"delta\",kernel=\"scalar\",overlap=\"on\"";
        assert!(text.contains(&format!("flims_sorts_total{{{a_labels}}} 2")), "{text}");
        assert!(text.contains(&format!("flims_sort_elements_total{{{a_labels}}} 150")), "{text}");
        let wall = format!("flims_sort_wall_seconds_total{{{a_labels}}} 0.003");
        assert!(text.contains(&wall), "{text}");
        assert!(text.contains(&format!("flims_sorts_total{{{b_labels}}} 1")), "{text}");
        assert!(text.contains(&format!("flims_sort_runs_spilled_total{{{b_labels}}} 3")), "{text}");
    }

    #[test]
    fn concurrent_counting() {
        let m = std::sync::Arc::new(Counter::default());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.inc();
                    }
                });
            }
        });
        assert_eq!(m.get(), 4000);
    }
}
