//! Lightweight metrics: atomic counters and a latency histogram with
//! percentile snapshots, used by the coordinator's data plane.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Monotonic counter.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    /// Add one.
    pub fn inc(&self) {
        self.v.fetch_add(1, Ordering::Relaxed);
    }
    /// Add `n`.
    pub fn add(&self, n: u64) {
        self.v.fetch_add(n, Ordering::Relaxed);
    }
    /// Current value.
    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Log-bucketed latency histogram (1µs … ~64s, 2× buckets) — coarse but
/// lock-free and allocation-free on the hot path.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_ns: AtomicU64,
}

const NBUCKETS: usize = 27; // 2^0 .. 2^26 µs

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: (0..NBUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    /// Record one latency sample.
    pub fn observe(&self, d: Duration) {
        let us = (d.as_nanos() / 1000).max(1) as u64;
        let bucket = (63 - us.leading_zeros() as usize).min(NBUCKETS - 1);
        self.buckets[bucket].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency.
    pub fn mean(&self) -> Duration {
        let c = self.count();
        if c == 0 {
            return Duration::ZERO;
        }
        Duration::from_nanos(self.sum_ns.load(Ordering::Relaxed) / c)
    }

    /// Approximate percentile (upper bound of the bucket containing it).
    pub fn percentile(&self, p: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((total as f64) * p / 100.0).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Duration::from_micros(1u64 << (i + 1));
            }
        }
        Duration::from_micros(1u64 << NBUCKETS)
    }

    /// One-line count/mean/percentile summary.
    pub fn snapshot(&self) -> String {
        format!(
            "count={} mean={:?} p50={:?} p99={:?}",
            self.count(),
            self.mean(),
            self.percentile(50.0),
            self.percentile(99.0)
        )
    }
}

/// The coordinator's metric set.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    /// Requests routed (all commands).
    pub requests: Counter,
    /// Batches the dynamic batcher flushed.
    pub batches: Counter,
    /// Elements across every sorted/merged request.
    pub elements_sorted: Counter,
    /// Requests answered with an `err` line.
    pub errors: Counter,
    /// End-to-end request latency.
    pub latency: LatencyHistogram,
    /// External (out-of-core) sort activity.
    pub external_sorts: Counter,
    /// Spilled runs written (initial + intermediate merge passes).
    pub runs_spilled: Counter,
    /// Encoded bytes written to spill files (what hit the disk).
    pub bytes_spilled: Counter,
    /// What the same spill traffic would occupy uncompressed — the
    /// denominator of the spill compression ratio.
    pub bytes_spilled_raw: Counter,
    /// Merge passes executed over spilled data.
    pub merge_passes: Counter,
    /// Cumulative run-codec encode wall-clock, microseconds.
    pub codec_encode_us: Counter,
    /// Cumulative run-codec decode wall-clock, microseconds.
    pub codec_decode_us: Counter,
    /// Cumulative phase-1 (run generation) wall-clock, microseconds.
    pub phase1_us: Counter,
    /// Cumulative phase-2 (k-way merge) wall-clock, microseconds.
    pub phase2_us: Counter,
    /// Cumulative end-to-end external-sort wall-clock, microseconds
    /// (under the overlapped schedule, less than phase1 + phase2).
    pub wall_us: Counter,
    /// Cumulative time the two phases ran concurrently, microseconds
    /// (0 for every serial-schedule sort).
    pub overlap_us: Counter,
    /// Leaf blocks the prefetch threads had ready before the merge
    /// asked (disk read fully overlapped with merging).
    pub prefetch_hits: Counter,
    /// Leaf blocks the merge had to wait for.
    pub prefetch_misses: Counter,
}

impl ServiceMetrics {
    /// One-line snapshot of every counter — the `stats` protocol reply.
    pub fn report(&self) -> String {
        format!(
            "requests={} batches={} elements={} errors={} latency[{}] \
             external[sorts={} runs={} spilled_bytes={} spilled_raw={} \
             codec_enc_us={} codec_dec_us={} passes={} \
             phase1_us={} phase2_us={} wall_us={} overlap_us={} \
             prefetch_hits={} prefetch_misses={}]",
            self.requests.get(),
            self.batches.get(),
            self.elements_sorted.get(),
            self.errors.get(),
            self.latency.snapshot(),
            self.external_sorts.get(),
            self.runs_spilled.get(),
            self.bytes_spilled.get(),
            self.bytes_spilled_raw.get(),
            self.codec_encode_us.get(),
            self.codec_decode_us.get(),
            self.merge_passes.get(),
            self.phase1_us.get(),
            self.phase2_us.get(),
            self.wall_us.get(),
            self.overlap_us.get(),
            self.prefetch_hits.get(),
            self.prefetch_misses.get(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_basics() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn histogram_percentiles_ordered() {
        let h = LatencyHistogram::default();
        for us in [10u64, 20, 40, 80, 500, 1000, 5000] {
            h.observe(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 7);
        assert!(h.percentile(50.0) <= h.percentile(99.0));
        assert!(h.mean() > Duration::ZERO);
    }

    #[test]
    fn histogram_empty_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.percentile(99.0), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn metrics_report_formats() {
        let m = ServiceMetrics::default();
        m.requests.inc();
        let s = m.report();
        assert!(s.contains("requests=1"));
    }

    #[test]
    fn report_includes_spill_counters() {
        let m = ServiceMetrics::default();
        m.external_sorts.inc();
        m.runs_spilled.add(7);
        m.bytes_spilled.add(1024);
        m.bytes_spilled_raw.add(4096);
        m.codec_encode_us.add(300);
        m.codec_decode_us.add(200);
        m.merge_passes.add(2);
        m.phase1_us.add(1500);
        m.phase2_us.add(2500);
        m.wall_us.add(3000);
        m.overlap_us.add(1000);
        m.prefetch_hits.add(40);
        m.prefetch_misses.add(2);
        let s = m.report();
        assert!(s.contains("external[sorts=1 runs=7 spilled_bytes=1024 spilled_raw=4096"), "{s}");
        assert!(s.contains("codec_enc_us=300 codec_dec_us=200 passes=2"), "{s}");
        assert!(s.contains("phase1_us=1500 phase2_us=2500 wall_us=3000 overlap_us=1000"), "{s}");
        assert!(s.contains("prefetch_hits=40 prefetch_misses=2]"), "{s}");
    }

    #[test]
    fn concurrent_counting() {
        let m = std::sync::Arc::new(Counter::default());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let m = m.clone();
                s.spawn(move || {
                    for _ in 0..1000 {
                        m.inc();
                    }
                });
            }
        });
        assert_eq!(m.get(), 4000);
    }
}
