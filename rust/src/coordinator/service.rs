//! TCP service front end: a line-oriented protocol over the router and
//! batcher. One worker thread per connection; a timer thread drives the
//! batching window.
//!
//! Protocol (request → response, all one-line, values space-separated):
//!
//! ```text
//! sort  <backend> <v1> <v2> …   →  ok <sorted descending>
//! sortf <backend> <f1> <f2> …   →  ok <sorted descending>   (f32)
//! batch <f1> <f2> …             →  ok <sorted>  (goes through the batcher)
//! merge <a...> | <b...>         →  ok <merged>  (desc-sorted u32 inputs)
//! sortfile external <path> [dtype=<d>] [codec=<c>] [overlap=<o>] [kernel=<k>]
//!                   [faults=<f>] [trace=<t>]
//!                               →  ok <n> <output-path>  (raw record file,
//!                                   sorted descending to <path>.sorted;
//!                                   d = u32|u64|kv|kv64|f32,
//!                                   c = raw|delta|flr3, o = on|off (the
//!                                   pipelined vs serial schedule — same
//!                                   output bytes), k =
//!                                   auto|scalar|simd (the merge-kernel
//!                                   tier — also same output bytes) and
//!                                   t = a path to write a Chrome
//!                                   trace-event JSON of the sort to
//!                                   (load it in chrome://tracing or
//!                                   Perfetto; tracing never changes the
//!                                   output bytes), f = a fault plan
//!                                   `<seed>:<rate>:<kinds>` (or `off`)
//!                                   injected into THIS request only —
//!                                   the deterministic fault-injection
//!                                   hook the robustness tests drive
//!                                   (docs/ROBUSTNESS.md), defaults
//!                                   from the
//!                                   `[external]` / `[core]` config
//!                                   sections; only trailing `dtype=`/
//!                                   `codec=`/`overlap=`/`kernel=`/
//!                                   `faults=`/`trace=`-prefixed tokens are
//!                                   treated as options, so paths
//!                                   containing spaces keep working. A
//!                                   bad value is a one-line `err`
//!                                   naming the offending argument)
//! stats                         →  ok <metrics summary> kernel=<active>
//!                                   [last[…] — the most recent external
//!                                   sort's labels + timings]
//! stats reset                   →  ok reset  (zeroes every counter,
//!                                   histogram, per-label aggregate and
//!                                   the `last[…]` block; rejected with
//!                                   a one-line `err` while any job is
//!                                   running or queued, so a reset can
//!                                   never tear an in-flight sort's
//!                                   counters)
//! progress                      →  ok <live progress counters>  (runs
//!                                   sealed / merges fired / elements +
//!                                   bytes out, process-wide)
//! jobs                          →  ok jobs=<admitted> running=<r>
//!                                   queued=<q> <id>:<state>…  (every
//!                                   retained job in id order; external
//!                                   sorts big enough to spill run as
//!                                   scheduler jobs)
//! status <id>                   →  ok job=<id> state=<state>
//!                                   runs_sealed=… merges_fired=…
//!                                   elements_out=… bytes_out=…  (the
//!                                   job's OWN progress counters; a
//!                                   failed job's error=<msg> comes
//!                                   last)
//! cancel <id>                   →  ok cancelled <id>  (queued jobs
//!                                   leave the queue promptly; running
//!                                   jobs abort at the pipeline's next
//!                                   check point and their spill files
//!                                   and partial output are removed;
//!                                   cancelling an already-finished or
//!                                   already-cancelled job is a no-op
//!                                   `ok` — cancel is idempotent. Both
//!                                   `status` and `cancel` answer a
//!                                   missing id with the same
//!                                   `err unknown job: <id>` line)
//! metrics                       →  Prometheus text exposition ending
//!                                   with `# EOF` (the ONE multi-line
//!                                   response; clients read until the
//!                                   terminator — see
//!                                   docs/OBSERVABILITY.md)
//! quit                          →  (closes the connection)
//! ```
//!
//! Malformed requests (empty value lists, a missing `|` in `merge`,
//! unknown backends or commands, bad numbers) always produce a one-line
//! `err …` response — protocol errors never tear down the connection.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::router::{Backend, Router};

/// The TCP front end: owns the router + batcher and serves the
/// line-oriented protocol documented in this module's header.
pub struct Service {
    /// Backend dispatch (shared with the batcher).
    pub router: Arc<Router>,
    /// Dynamic batcher for the `batch` command.
    pub batcher: Arc<Batcher>,
    stop: Arc<AtomicBool>,
}

/// One live connection tracked by the accept loop: a clone of its
/// socket (shut down to unblock the reader) and the worker thread's
/// handle (joined on shutdown — connection threads are never detached).
struct ConnSlot {
    socket: Option<TcpStream>,
    handle: std::thread::JoinHandle<()>,
}

impl Service {
    /// Build a service over `router` with the given batching policy.
    pub fn new(router: Arc<Router>, bcfg: BatcherConfig) -> Self {
        let batcher = Arc::new(Batcher::new(router.clone(), bcfg));
        Service { router, batcher, stop: Arc::new(AtomicBool::new(false)) }
    }

    /// Handle one protocol line, always producing exactly one response
    /// line: `ok …`, `bye`, or `err …`. Errors are rendered here (and
    /// counted) rather than propagated, so a malformed request can
    /// never tear down the connection thread.
    pub fn handle_line(&self, line: &str) -> String {
        match self.dispatch(line) {
            Ok(resp) => resp,
            Err(e) => {
                self.router.metrics.errors.inc();
                // Keep the protocol line-oriented whatever the error
                // message contains.
                let msg = format!("{e:#}").replace(['\n', '\r'], " ");
                format!("err {msg}")
            }
        }
    }

    fn dispatch(&self, line: &str) -> Result<String> {
        let line = line.trim();
        let (cmd, rest) = line.split_once(' ').unwrap_or((line, ""));
        match cmd {
            "sort" => {
                let (backend, nums) = rest
                    .split_once(' ')
                    .ok_or_else(|| anyhow!("usage: sort <backend> <values…>"))?;
                let backend = Backend::parse(backend)?;
                let data: Vec<u32> = parse_nums(nums)?;
                if data.is_empty() {
                    bail!("empty value list");
                }
                let out = self.router.sort_u32(data, backend)?;
                Ok(format!("ok {}", join(&out)))
            }
            "sortf" => {
                let (backend, nums) = rest
                    .split_once(' ')
                    .ok_or_else(|| anyhow!("usage: sortf <backend> <values…>"))?;
                let backend = Backend::parse(backend)?;
                let data: Vec<f32> = parse_nums(nums)?;
                if data.is_empty() {
                    bail!("empty value list");
                }
                let out = self.router.sort_f32(data, backend)?;
                Ok(format!("ok {}", join(&out)))
            }
            "batch" => {
                let data: Vec<f32> = parse_nums(rest)?;
                if data.is_empty() {
                    bail!("empty value list");
                }
                let rx = self.batcher.submit(data);
                // Ensure progress even if the batch never fills.
                self.batcher.flush_if_due();
                let out = match rx.recv_timeout(Duration::from_secs(30)) {
                    Ok(r) => r?,
                    Err(_) => {
                        self.batcher.flush();
                        rx.recv().map_err(|e| anyhow!("batch dropped: {e}"))??
                    }
                };
                Ok(format!("ok {}", join(&out)))
            }
            "merge" => {
                let (a, b) = rest
                    .split_once('|')
                    .ok_or_else(|| anyhow!("usage: merge <a…> | <b…>"))?;
                let a: Vec<u32> = parse_nums(a.trim())?;
                let b: Vec<u32> = parse_nums(b.trim())?;
                if a.is_empty() && b.is_empty() {
                    bail!("empty value list");
                }
                let out = self.router.merge_u32(&a, &b);
                Ok(format!("ok {}", join(&out)))
            }
            "sortfile" => {
                let usage = "usage: sortfile external <path> [dtype=<d>] [codec=<c>] \
                             [overlap=<o>] [kernel=<k>] [faults=<f>] [trace=<t>]";
                let (backend, rest) =
                    rest.split_once(' ').ok_or_else(|| anyhow!("{usage}"))?;
                let backend = Backend::parse(backend)?;
                if backend != Backend::External {
                    bail!("sortfile requires the 'external' backend");
                }
                // Only explicit trailing `dtype=` / `codec=` /
                // `overlap=` / `kernel=` / `trace=` tokens are options
                // — a bad value is a loud error *naming the argument*,
                // and paths containing spaces are untouched (PR 1
                // grammar, extended).
                let mut path = rest.trim();
                let mut dtype = None;
                let mut codec = None;
                let mut overlap = None;
                let mut kernel = None;
                // Two-level Option: the outer layer is the dup check
                // (`faults=off` is a legal value meaning "no plan").
                let mut faults: Option<Option<crate::fault::FaultSpec>> = None;
                let mut trace: Option<std::path::PathBuf> = None;
                while !path.is_empty() {
                    // The last whitespace-separated token; the whole
                    // string when no space remains.
                    let (head, tail) = match path.rsplit_once(' ') {
                        Some((h, t)) => (h.trim_end(), t.trim()),
                        None => ("", path),
                    };
                    if let Some(name) = tail.strip_prefix("dtype=") {
                        // parse_dtype_arg already says "dtype argument:"
                        // — the same wording as the CLI and config paths.
                        let d = crate::external::parse_dtype_arg(name)
                            .map_err(|e| anyhow!("{e}"))?;
                        if dtype.replace(d).is_some() {
                            bail!("dtype argument: given more than once");
                        }
                    } else if let Some(name) = tail.strip_prefix("codec=") {
                        // parse_codec_arg already says "codec argument:"
                        // — the same wording as the CLI and config paths.
                        let c = crate::external::parse_codec_arg(name)
                            .map_err(|e| anyhow!("{e}"))?;
                        if codec.replace(c).is_some() {
                            bail!("codec argument: given more than once");
                        }
                    } else if let Some(name) = tail.strip_prefix("overlap=") {
                        let o = crate::external::parse_overlap(name)
                            .map_err(|e| anyhow!("overlap argument: {e}"))?;
                        if overlap.replace(o).is_some() {
                            bail!("overlap argument: given more than once");
                        }
                    } else if let Some(name) = tail.strip_prefix("kernel=") {
                        let k = crate::flims::simd::MergeKernel::parse(name)
                            .map_err(|e| anyhow!("kernel argument: {e}"))?;
                        if kernel.replace(k).is_some() {
                            bail!("kernel argument: given more than once");
                        }
                    } else if let Some(name) = tail.strip_prefix("faults=") {
                        let f = crate::fault::parse_faults_arg(name)
                            .map_err(|e| anyhow!("faults argument: {e}"))?;
                        if faults.replace(f).is_some() {
                            bail!("faults argument: given more than once");
                        }
                    } else if let Some(name) = tail.strip_prefix("trace=") {
                        if name.is_empty() {
                            bail!("trace argument: empty path");
                        }
                        if trace.replace(std::path::PathBuf::from(name)).is_some() {
                            bail!("trace argument: given more than once");
                        }
                    } else {
                        break;
                    }
                    path = head;
                }
                if path.is_empty() {
                    bail!("{usage}");
                }
                let (output, stats) = self.router.sort_file_external(
                    Path::new(path),
                    dtype,
                    codec,
                    overlap,
                    kernel,
                    faults.flatten(),
                    trace.as_deref(),
                )?;
                Ok(format!("ok {} {}", stats.elements, output.display()))
            }
            "stats" => match rest.trim() {
                "" => {
                    let mut out = format!(
                        "ok {} kernel={}",
                        self.router.metrics.report(),
                        self.router.kernel_name()
                    );
                    if let Some((labels, stats)) = self.router.last_sort() {
                        // `kernel=` here is the *effective* tier the
                        // last sort's dtype merged on — the header's
                        // `kernel=` above is the CPU-wide resolution.
                        out.push_str(&format!(
                            " last[dtype={} codec={} kernel={} overlap={} wall_us={} \
                             overlap_us={} codec_enc_us={} codec_dec_us={}]",
                            labels.dtype,
                            labels.codec,
                            labels.kernel,
                            if labels.overlap { "on" } else { "off" },
                            stats.wall_us,
                            stats.overlap_us,
                            stats.codec_encode_us,
                            stats.codec_decode_us,
                        ));
                    }
                    Ok(out)
                }
                "reset" => {
                    self.router.reset_metrics()?;
                    Ok("ok reset".into())
                }
                other => Err(anyhow!("unknown stats subcommand '{other}'")),
            },
            "progress" => Ok(format!("ok {}", crate::obs::progress::report())),
            "jobs" => {
                if !rest.trim().is_empty() {
                    bail!("usage: jobs");
                }
                Ok(format!("ok {}", self.router.jobs.report()))
            }
            "status" => {
                let id: u64 =
                    rest.trim().parse().map_err(|_| anyhow!("usage: status <job-id>"))?;
                Ok(format!("ok {}", self.router.jobs.status_line(id)?))
            }
            "cancel" => {
                let id: u64 =
                    rest.trim().parse().map_err(|_| anyhow!("usage: cancel <job-id>"))?;
                self.router.jobs.cancel(id)?;
                Ok(format!("ok cancelled {id}"))
            }
            // The one multi-line response: Prometheus text exposition,
            // terminated by `# EOF` so clients know where it stops.
            "metrics" => Ok(self.router.prometheus()),
            "quit" => Ok("bye".into()),
            other => Err(anyhow!("unknown command: {other}")),
        }
    }

    /// Serve on `bind` until [`shutdown`](Self::shutdown) (blocking). A
    /// background timer thread drives `flush_if_due` so the batching
    /// window is honoured even while connections idle.
    ///
    /// The listener is nonblocking: the accept loop polls the stop flag
    /// every couple of milliseconds, so `shutdown` takes effect
    /// promptly instead of waiting for one more connection to arrive.
    /// On the way out every live connection socket is shut down (which
    /// unblocks its reader) and every connection thread — plus the
    /// timer — is joined before `serve` returns.
    pub fn serve(self: &Arc<Self>, bind: &str) -> Result<()> {
        let listener = TcpListener::bind(bind)?;
        listener.set_nonblocking(true)?;
        eprintln!("flims service listening on {bind}");
        let timer = {
            let svc = self.clone();
            std::thread::spawn(move || loop {
                if svc.stop.load(Ordering::Relaxed) {
                    break;
                }
                svc.batcher.flush_if_due();
                std::thread::sleep(Duration::from_micros(200));
            })
        };
        let mut conns: Vec<ConnSlot> = Vec::new();
        while !self.stop.load(Ordering::Relaxed) {
            match listener.accept() {
                Ok((stream, _)) => {
                    // Connection I/O stays blocking; only accept polls.
                    if stream.set_nonblocking(false).is_err() {
                        continue;
                    }
                    let socket = stream.try_clone().ok();
                    let svc = self.clone();
                    let handle = std::thread::spawn(move || svc.handle_conn(stream));
                    conns.push(ConnSlot { socket, handle });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    // Reap threads whose connections already closed, so
                    // the slot list tracks live connections rather than
                    // every connection ever accepted.
                    let mut i = 0;
                    while i < conns.len() {
                        if conns[i].handle.is_finished() {
                            let _ = conns.swap_remove(i).handle.join();
                        } else {
                            i += 1;
                        }
                    }
                    std::thread::sleep(Duration::from_millis(2));
                }
                Err(e) => {
                    eprintln!("accept error: {e}");
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
        }
        // Unblock every connection thread parked in a read, then join
        // them all — shutdown leaves no detached threads behind.
        for slot in conns {
            if let Some(socket) = &slot.socket {
                let _ = socket.shutdown(Shutdown::Both);
            }
            let _ = slot.handle.join();
        }
        let _ = timer.join();
        Ok(())
    }

    /// Ask `serve` to stop: the accept loop notices within its poll
    /// interval (no extra connection needed), shuts down the live
    /// connection sockets, and joins every worker before returning.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    fn handle_conn(&self, stream: TcpStream) {
        // Arm the per-connection read timeout ([server] read_timeout_ms;
        // 0 = wait forever). A client that connects and then says
        // nothing holds a worker thread + socket; when the timeout
        // fires the blocked read returns Err, the loop below breaks,
        // and the accept loop reaps the finished thread — idle
        // connections can't accumulate forever.
        let _ = stream.set_read_timeout(self.router.conn_read_timeout());
        // Buffer the writes (one syscall per response, not one per
        // formatting fragment) and flush per response so the client
        // always sees the full reply before its next request.
        let mut writer = match stream.try_clone() {
            Ok(w) => BufWriter::new(w),
            Err(_) => return,
        };
        let reader = BufReader::new(stream);
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break,
            };
            // Trim once, up front: a CRLF client's trailing `\r` (and
            // stray whitespace) is gone before dispatch reads the verb,
            // not just on the `quit` comparison.
            let line = line.trim();
            if line == "quit" {
                let _ = writeln!(writer, "bye");
                let _ = writer.flush();
                break;
            }
            let resp = self.handle_line(line);
            if writeln!(writer, "{resp}").is_err() || writer.flush().is_err() {
                break;
            }
        }
    }
}

fn parse_nums<T: std::str::FromStr>(s: &str) -> Result<Vec<T>> {
    s.split_whitespace()
        .map(|t| t.parse::<T>().map_err(|_| anyhow!("bad number '{t}'")))
        .collect()
}

fn join<T: std::fmt::Display>(v: &[T]) -> String {
    v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AppConfig;

    fn svc() -> Service {
        // Pin the default dtype to u32: these tests sort u32 datasets
        // without a `dtype=` argument, and the FLIMS_DTYPE CI lane
        // must not change the record type under them.
        let mut app = AppConfig::default();
        app.external.dtype = crate::external::Dtype::U32;
        let router = Arc::new(Router::new(app, None));
        Service::new(router, BatcherConfig { max_batch: 2, window: Duration::from_micros(1) })
    }

    #[test]
    fn sort_command() {
        let s = svc();
        assert_eq!(s.handle_line("sort native 3 1 2"), "ok 3 2 1");
    }

    #[test]
    fn sortf_command() {
        let s = svc();
        assert_eq!(s.handle_line("sortf native 1.5 -2 0"), "ok 1.5 0 -2");
    }

    #[test]
    fn merge_command() {
        let s = svc();
        assert_eq!(s.handle_line("merge 9 5 | 7 3"), "ok 9 7 5 3");
    }

    #[test]
    fn batch_command_completes_via_window() {
        let s = svc();
        // Single request: window flush path must answer it.
        assert_eq!(s.handle_line("batch 4 8 6"), "ok 8 6 4");
    }

    #[test]
    fn stats_command() {
        let s = svc();
        let _ = s.handle_line("sort native 2 1");
        let out = s.handle_line("stats");
        assert!(out.starts_with("ok requests="));
        assert!(out.contains("external[sorts="), "{out}");
        // The active merge-kernel name rides the stats line.
        assert!(out.contains(" kernel="), "{out}");
    }

    #[test]
    fn sortfile_with_kernel_argument() {
        use crate::external::format::{read_raw, write_raw};
        let dir = std::env::temp_dir().join(format!("flims-svc-krn-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("req.u32");
        let data: Vec<u32> = (0..20_000u32).map(|i| i.wrapping_mul(2654435761)).collect();
        write_raw(&input, &data).unwrap();

        // Tight budget so the request really spills through the kernels.
        let mut app = crate::config::AppConfig::default();
        app.external.mem_budget_bytes = 4096;
        app.external.dtype = crate::external::Dtype::U32;
        let router = Arc::new(Router::new(app, None));
        let s = Service::new(
            router,
            BatcherConfig { max_batch: 2, window: Duration::from_micros(1) },
        );

        let mut expect = data;
        expect.sort_unstable_by(|a, b| b.cmp(a));
        let expect_path = format!("{}.sorted", input.display());
        for arg in ["kernel=scalar", "kernel=simd", "kernel=auto dtype=u32 codec=delta"] {
            let resp = s.handle_line(&format!("sortfile external {} {arg}", input.display()));
            assert_eq!(resp, format!("ok 20000 {expect_path}"), "{arg}");
            assert_eq!(
                read_raw::<u32>(Path::new(&expect_path)).unwrap(),
                expect,
                "{arg}: the kernel must not change the sorted bytes"
            );
        }

        // Bad values are one-line errors naming the offending argument.
        let resp =
            s.handle_line(&format!("sortfile external {} kernel=gpu", input.display()));
        assert!(resp.starts_with("err "), "{resp}");
        assert!(resp.contains("kernel argument: unknown kernel 'gpu'"), "{resp}");
        assert!(!resp.contains('\n'), "response must stay one line");
        let resp = s.handle_line(&format!(
            "sortfile external {} kernel=simd kernel=scalar",
            input.display()
        ));
        assert!(resp.starts_with("err "), "{resp}");
        assert!(resp.contains("kernel argument: given more than once"), "{resp}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn errors_are_one_line_err_responses() {
        let s = svc();
        for (req, expect) in [
            ("sort martian 1 2", "unknown backend"),
            ("frobnicate", "unknown command"),
            ("sort native 1 banana", "bad number"),
            ("sortfile native /tmp/x", "external"),
        ] {
            let resp = s.handle_line(req);
            assert!(resp.starts_with("err "), "{req} → {resp}");
            assert!(resp.contains(expect), "{req} → {resp}");
            assert!(!resp.contains('\n'), "response must stay one line");
        }
        assert_eq!(s.router.metrics.errors.get(), 4);
    }

    #[test]
    fn unknown_command_names_the_verb_with_a_colon() {
        let s = svc();
        assert_eq!(s.handle_line("frobnicate"), "err unknown command: frobnicate");
        assert_eq!(s.handle_line("frobnicate the widget"), "err unknown command: frobnicate");
    }

    #[test]
    fn metrics_command_returns_prometheus_text() {
        let s = svc();
        let _ = s.handle_line("sort native 3 1 2");
        let text = s.handle_line("metrics");
        assert!(!text.starts_with("ok "), "raw exposition, no ok prefix");
        assert!(!text.starts_with("err "), "{text}");
        assert!(text.contains("# TYPE flims_requests_total counter"), "{text}");
        assert!(text.contains("\nflims_requests_total 1\n"), "{text}");
        assert!(text.contains("flims_request_latency_seconds_bucket{le="), "{text}");
        assert!(text.ends_with("# EOF"), "clients read until the terminator");
    }

    #[test]
    fn progress_command_reports_live_counters() {
        let s = svc();
        let resp = s.handle_line("progress");
        assert!(resp.starts_with("ok active="), "{resp}");
        for field in ["runs_sealed=", "merges_fired=", "elements_out=", "bytes_out="] {
            assert!(resp.contains(field), "{resp}");
        }
    }

    #[test]
    fn stats_reset_zeroes_and_forgets_the_last_sort() {
        use crate::external::format::write_raw;
        let dir = std::env::temp_dir().join(format!("flims-svc-reset-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("req.u32");
        let data: Vec<u32> = (0..3000u32).map(|i| i.wrapping_mul(2654435761)).collect();
        write_raw(&input, &data).unwrap();

        let s = svc();
        assert!(!s.handle_line("stats").contains("last["), "no sort ran yet");
        let resp = s.handle_line(&format!("sortfile external {}", input.display()));
        assert!(resp.starts_with("ok 3000 "), "{resp}");
        let stats = s.handle_line("stats");
        assert!(stats.contains(" last[dtype=u32 codec="), "{stats}");
        assert!(stats.contains(" wall_us="), "{stats}");
        // Both the global resolved kernel and the last sort's effective
        // kernel ride the line.
        assert_eq!(stats.matches(" kernel=").count(), 2, "{stats}");
        let last = stats.split(" last[").nth(1).unwrap();
        let eff = last.split(" kernel=").nth(1).unwrap().split(' ').next().unwrap();
        assert!(
            ["scalar", "simd-sse2", "simd-avx2", "simd-neon"].contains(&eff),
            "{stats}"
        );

        assert_eq!(s.handle_line("stats reset"), "ok reset");
        let stats = s.handle_line("stats");
        assert!(stats.contains("requests=0"), "{stats}");
        assert!(!stats.contains("last["), "reset must forget the last sort: {stats}");
        let resp = s.handle_line("stats frobnicate");
        assert!(resp.starts_with("err unknown stats subcommand"), "{resp}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sortfile_with_trace_argument_writes_chrome_json() {
        use crate::external::format::{read_raw, write_raw};
        let dir = std::env::temp_dir().join(format!("flims-svc-trc-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("req.u32");
        let data: Vec<u32> = (0..20_000u32).map(|i| i.wrapping_mul(2654435761)).collect();
        write_raw(&input, &data).unwrap();

        // Tight budget so the traced request really spills.
        let mut app = crate::config::AppConfig::default();
        app.external.mem_budget_bytes = 4096;
        app.external.dtype = crate::external::Dtype::U32;
        let router = Arc::new(Router::new(app, None));
        let s = Service::new(
            router,
            BatcherConfig { max_batch: 2, window: Duration::from_micros(1) },
        );

        let trace_path = dir.join("req.trace.json");
        let resp = s.handle_line(&format!(
            "sortfile external {} codec=delta trace={}",
            input.display(),
            trace_path.display()
        ));
        let expect_path = format!("{}.sorted", input.display());
        assert_eq!(resp, format!("ok 20000 {expect_path}"));

        let json = std::fs::read_to_string(&trace_path).unwrap();
        assert!(json.starts_with("{\"traceEvents\":["), "{}", &json[..40.min(json.len())]);
        assert!(json.contains("\"name\":\"seal_run\""), "traced sort must record spans");

        // Tracing must not perturb the sorted bytes.
        let mut expect = data;
        expect.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(read_raw::<u32>(Path::new(&expect_path)).unwrap(), expect);

        // Bad values are one-line errors naming the offending argument.
        let resp = s.handle_line(&format!("sortfile external {} trace=", input.display()));
        assert!(resp.contains("trace argument: empty path"), "{resp}");
        let resp = s.handle_line(&format!(
            "sortfile external {} trace=/tmp/a.json trace=/tmp/b.json",
            input.display()
        ));
        assert!(resp.contains("trace argument: given more than once"), "{resp}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_value_lists_are_errors() {
        let s = svc();
        for req in ["sort native", "sort native ", "sortf parallel ", "batch", "batch ", "merge |"] {
            let resp = s.handle_line(req);
            assert!(resp.starts_with("err "), "{req:?} → {resp}");
        }
        // One-sided merge is legal — only both-empty is rejected.
        assert_eq!(s.handle_line("merge 5 2 |"), "ok 5 2");
        assert_eq!(s.handle_line("merge | 4 1"), "ok 4 1");
    }

    #[test]
    fn merge_without_separator_is_an_error() {
        let s = svc();
        let resp = s.handle_line("merge 1 2 3");
        assert!(resp.starts_with("err "), "{resp}");
        assert!(resp.contains("usage: merge"), "{resp}");
    }

    #[test]
    fn unknown_backend_in_every_command() {
        let s = svc();
        for req in ["sort gpu 1", "sortf gpu 1.0", "sortfile gpu /tmp/x"] {
            let resp = s.handle_line(req);
            assert!(resp.starts_with("err "), "{req} → {resp}");
            assert!(resp.contains("unknown backend"), "{req} → {resp}");
        }
    }

    #[test]
    fn sortfile_round_trip() {
        use crate::external::format::{read_raw, write_raw};
        let dir = std::env::temp_dir().join(format!("flims-svc-ext-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("req.u32");
        let data: Vec<u32> = (0..5000u32).map(|i| i.wrapping_mul(2654435761)).collect();
        write_raw(&input, &data).unwrap();

        let s = svc();
        let resp = s.handle_line(&format!("sortfile external {}", input.display()));
        let expect_path = format!("{}.sorted", input.display());
        assert_eq!(resp, format!("ok 5000 {expect_path}"));

        let mut expect = data;
        expect.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(read_raw::<u32>(Path::new(&expect_path)).unwrap(), expect);

        // Missing file: still a one-line err, connection-safe.
        let resp = s.handle_line("sortfile external /nonexistent/nope.u32");
        assert!(resp.starts_with("err "), "{resp}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sortfile_with_dtype_argument() {
        use crate::external::format::{read_raw, write_raw};
        use crate::key::Kv;
        let dir = std::env::temp_dir().join(format!("flims-svc-dtype-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("req.kv");
        let recs: Vec<Kv> = (0..2000).map(|i| Kv::new(i % 5, i)).collect();
        write_raw(&input, &recs).unwrap();

        let s = svc();
        let resp = s.handle_line(&format!("sortfile external {} dtype=kv", input.display()));
        let expect_path = format!("{}.sorted", input.display());
        assert_eq!(resp, format!("ok 2000 {expect_path}"));
        let mut expect = recs;
        expect.sort_by(|a, b| b.key.cmp(&a.key)); // stable: ties keep order
        assert_eq!(read_raw::<Kv>(Path::new(&expect_path)).unwrap(), expect);

        // The same file read as the default dtype (u32) still sorts —
        // it is just 4000 u32 words — so dtype actually changes behavior.
        let resp = s.handle_line(&format!("sortfile external {} dtype=u32", input.display()));
        assert!(resp.starts_with("ok 4000 "), "{resp}");

        // A bad dtype value is a loud one-line error, not a path guess —
        // and it names the offending argument.
        let resp = s.handle_line(&format!("sortfile external {} dtype=f64", input.display()));
        assert!(resp.starts_with("err "), "{resp}");
        assert!(resp.contains("dtype argument: unknown dtype"), "{resp}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sortfile_with_codec_argument() {
        use crate::external::format::{read_raw, write_raw};
        let dir = std::env::temp_dir().join(format!("flims-svc-codec-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("req.u32");
        let data: Vec<u32> = (0..3000u32).collect();
        write_raw(&input, &data).unwrap();

        // Tight budget so the request really spills through the codec.
        let mut app = crate::config::AppConfig::default();
        app.external.mem_budget_bytes = 4096;
        app.external.dtype = crate::external::Dtype::U32;
        let router = Arc::new(Router::new(app, None));
        let s = Service::new(
            router,
            BatcherConfig { max_batch: 2, window: Duration::from_micros(1) },
        );

        // codec + dtype combine, in either order; every codec name the
        // protocol accepts sorts to the same bytes.
        for req in [
            format!("sortfile external {} codec=delta", input.display()),
            format!("sortfile external {} dtype=u32 codec=delta", input.display()),
            format!("sortfile external {} codec=delta dtype=u32", input.display()),
            format!("sortfile external {} codec=flr3", input.display()),
            format!("sortfile external {} codec=flr3 dtype=u32", input.display()),
        ] {
            let resp = s.handle_line(&req);
            let expect_path = format!("{}.sorted", input.display());
            assert_eq!(resp, format!("ok 3000 {expect_path}"), "{req}");
            let mut expect = data.clone();
            expect.sort_unstable_by(|a, b| b.cmp(a));
            assert_eq!(read_raw::<u32>(Path::new(&expect_path)).unwrap(), expect);
        }
        // The compressed spill shows in the service metrics.
        assert!(
            s.router.metrics.bytes_spilled.get() < s.router.metrics.bytes_spilled_raw.get(),
            "sorted input under codec=delta must spill fewer bytes"
        );

        // Bad values are one-line errors naming the offending argument.
        let resp = s.handle_line(&format!("sortfile external {} codec=lz4", input.display()));
        assert!(resp.starts_with("err "), "{resp}");
        assert!(resp.contains("codec argument: unknown codec"), "{resp}");
        let resp = s.handle_line(&format!(
            "sortfile external {} codec=delta codec=raw",
            input.display()
        ));
        assert!(resp.starts_with("err "), "{resp}");
        assert!(resp.contains("codec argument: given more than once"), "{resp}");
        let resp = s.handle_line("sortfile external codec=delta");
        assert!(resp.starts_with("err "), "{resp}");
        assert!(resp.contains("usage: sortfile"), "path-less request → usage: {resp}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sortfile_with_overlap_argument() {
        use crate::external::format::{read_raw, write_raw};
        let dir = std::env::temp_dir().join(format!("flims-svc-ovl-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("req.u32");
        let data: Vec<u32> = (0..20_000u32).map(|i| i.wrapping_mul(2654435761)).collect();
        write_raw(&input, &data).unwrap();

        // Tight budget so both schedules really spill multi-pass.
        let mut app = crate::config::AppConfig::default();
        app.external.mem_budget_bytes = 4096;
        app.external.fan_in = 4;
        app.external.dtype = crate::external::Dtype::U32;
        let router = Arc::new(Router::new(app, None));
        let s = Service::new(
            router,
            BatcherConfig { max_batch: 2, window: Duration::from_micros(1) },
        );

        let mut expect = data;
        expect.sort_unstable_by(|a, b| b.cmp(a));
        let expect_path = format!("{}.sorted", input.display());
        for arg in ["overlap=on", "overlap=off", "overlap=on dtype=u32 codec=delta"] {
            let resp = s.handle_line(&format!("sortfile external {} {arg}", input.display()));
            assert_eq!(resp, format!("ok 20000 {expect_path}"), "{arg}");
            assert_eq!(
                read_raw::<u32>(Path::new(&expect_path)).unwrap(),
                expect,
                "{arg}: overlap must not change the sorted bytes"
            );
        }

        // Bad values are one-line errors naming the offending argument.
        let resp =
            s.handle_line(&format!("sortfile external {} overlap=sideways", input.display()));
        assert!(resp.starts_with("err "), "{resp}");
        assert!(resp.contains("overlap argument: unknown overlap value"), "{resp}");
        let resp = s.handle_line(&format!(
            "sortfile external {} overlap=on overlap=off",
            input.display()
        ));
        assert!(resp.starts_with("err "), "{resp}");
        assert!(resp.contains("overlap argument: given more than once"), "{resp}");
        // The overlapped runs show up in the wall/overlap counters.
        assert!(s.router.metrics.wall_us.get() > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tcp_round_trip() {
        use std::io::{BufRead, BufReader, Write};
        let router = Arc::new(Router::new(AppConfig::default(), None));
        let service = Arc::new(Service::new(
            router,
            BatcherConfig { max_batch: 4, window: Duration::from_micros(100) },
        ));
        // Bind on an ephemeral port, then serve in the background.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let svc2 = service.clone();
        let bind = addr.to_string();
        let handle = std::thread::spawn(move || {
            let _ = svc2.serve(&bind);
        });
        std::thread::sleep(Duration::from_millis(50));

        let mut conn = TcpStream::connect(addr).unwrap();
        writeln!(conn, "sort native 5 9 1").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "ok 9 5 1");

        writeln!(conn, "quit").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "bye");
        service.shutdown();
        handle.join().unwrap();
    }

    /// Regression: `shutdown` must unblock the accept loop promptly
    /// even with idle connections open. The old blocking `incoming()`
    /// loop only noticed the stop flag after one more client connected,
    /// and connection threads were detached, never joined.
    #[test]
    fn shutdown_unblocks_accept_and_joins_with_idle_connections() {
        use std::io::{BufRead, BufReader, Write};
        let router = Arc::new(Router::new(AppConfig::default(), None));
        let service = Arc::new(Service::new(
            router,
            BatcherConfig { max_batch: 4, window: Duration::from_micros(100) },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let svc2 = service.clone();
        let bind = addr.to_string();
        let serve_thread = std::thread::spawn(move || svc2.serve(&bind));
        std::thread::sleep(Duration::from_millis(50));

        // Two connections, both left open — and NO further connection
        // after shutdown() to poke the loop awake.
        let mut active = TcpStream::connect(addr).unwrap();
        let _idle = TcpStream::connect(addr).unwrap();
        writeln!(active, "sort native 2 1").unwrap();
        let mut reader = BufReader::new(active.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "ok 2 1");

        let t0 = std::time::Instant::now();
        service.shutdown();
        serve_thread.join().unwrap().unwrap();
        assert!(
            t0.elapsed() < Duration::from_secs(5),
            "serve must return without another connection (took {:?})",
            t0.elapsed()
        );
        // The server shut the socket down, so the open connection is
        // at EOF (or reset) rather than parked forever.
        let mut end = String::new();
        assert_eq!(reader.read_line(&mut end).unwrap_or(0), 0, "{end:?}");
    }

    /// CRLF clients (telnet, Windows netcat) terminate lines with
    /// `\r\n`; every verb must dispatch with the `\r` stripped, not
    /// just the `quit` comparison.
    #[test]
    fn crlf_lines_dispatch_every_verb() {
        use std::io::{BufRead, BufReader, Write};
        let router = Arc::new(Router::new(AppConfig::default(), None));
        let service = Arc::new(Service::new(
            router,
            BatcherConfig { max_batch: 4, window: Duration::from_micros(100) },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let svc2 = service.clone();
        let bind = addr.to_string();
        let serve_thread = std::thread::spawn(move || svc2.serve(&bind));
        std::thread::sleep(Duration::from_millis(50));

        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();

        conn.write_all(b"sort native 3 1 2\r\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "ok 3 2 1");

        line.clear();
        conn.write_all(b"jobs\r\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ok jobs=0 running=0 queued=0"), "{line}");

        line.clear();
        conn.write_all(b"status 7\r\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "err unknown job: 7");

        line.clear();
        conn.write_all(b"quit\r\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim_end(), "bye");

        service.shutdown();
        serve_thread.join().unwrap().unwrap();
    }

    /// The job verbs end to end: an external `sortfile` big enough to
    /// spill runs as a scheduler job, `jobs` lists it, `status <id>`
    /// shows its own progress counters, and the cancel/usage errors
    /// stay one-line.
    #[test]
    fn job_verbs_over_the_protocol() {
        use crate::external::format::write_raw;
        let dir = std::env::temp_dir().join(format!("flims-svc-jobs-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("req.u32");
        let data: Vec<u32> = (0..20_000u32).map(|i| i.wrapping_mul(2654435761)).collect();
        write_raw(&input, &data).unwrap();

        // Tight budget so the request really spills (and so becomes a
        // job with nonzero per-job progress).
        let mut app = crate::config::AppConfig::default();
        app.external.mem_budget_bytes = 4096;
        app.external.dtype = crate::external::Dtype::U32;
        let router = Arc::new(Router::new(app, None));
        let s = Service::new(
            router,
            BatcherConfig { max_batch: 2, window: Duration::from_micros(1) },
        );

        assert_eq!(s.handle_line("jobs"), "ok jobs=0 running=0 queued=0");
        let resp = s.handle_line(&format!("sortfile external {}", input.display()));
        assert!(resp.starts_with("ok 20000 "), "{resp}");
        assert_eq!(s.handle_line("jobs"), "ok jobs=1 running=0 queued=0 1:done");
        let status = s.handle_line("status 1");
        assert!(status.starts_with("ok job=1 state=done runs_sealed="), "{status}");
        assert!(!status.contains("runs_sealed=0 "), "a spilling sort seals runs: {status}");

        // Cancelling a finished job is an idempotent no-op `ok`;
        // unknown ids answer the same one-line error from both verbs,
        // and bad arguments are one-line usage errors.
        assert_eq!(s.handle_line("cancel 1"), "ok cancelled 1");
        let status = s.handle_line("status 1");
        assert!(status.contains("state=done"), "idempotent cancel must not flip state: {status}");
        assert_eq!(s.handle_line("status 99"), "err unknown job: 99");
        assert_eq!(s.handle_line("cancel 99"), "err unknown job: 99");
        assert_eq!(s.handle_line("status banana"), "err usage: status <job-id>");
        assert_eq!(s.handle_line("cancel"), "err usage: cancel <job-id>");
        assert_eq!(s.handle_line("jobs now"), "err usage: jobs");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// A connection that goes silent is reaped by the `[server]`
    /// read-timeout: its worker's blocked read returns, the thread
    /// exits, and the client sees EOF — idle sockets can't pin worker
    /// threads forever.
    #[test]
    fn idle_connections_are_reaped_by_the_read_timeout() {
        use std::io::{BufRead, BufReader, Write};
        let mut app = AppConfig::default();
        app.read_timeout_ms = 200;
        let router = Arc::new(Router::new(app, None));
        let service = Arc::new(Service::new(
            router,
            BatcherConfig { max_batch: 4, window: Duration::from_micros(100) },
        ));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        drop(listener);
        let svc2 = service.clone();
        let bind = addr.to_string();
        let serve_thread = std::thread::spawn(move || svc2.serve(&bind));
        std::thread::sleep(Duration::from_millis(50));

        // A chatty connection answers normally…
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        writeln!(conn, "sort native 2 1").unwrap();
        reader.read_line(&mut line).unwrap();
        assert_eq!(line.trim(), "ok 2 1");

        // …while a silent one is closed by the server once the timeout
        // fires, instead of holding its worker thread forever.
        let idle = TcpStream::connect(addr).unwrap();
        let mut idle_reader = BufReader::new(idle);
        let mut end = String::new();
        let t0 = std::time::Instant::now();
        let got = idle_reader.read_line(&mut end);
        assert!(
            matches!(got, Ok(0) | Err(_)),
            "reaped connection must see EOF/reset, got {end:?}"
        );
        assert!(t0.elapsed() < Duration::from_secs(5), "reap took {:?}", t0.elapsed());

        service.shutdown();
        serve_thread.join().unwrap().unwrap();
    }

    /// The `faults=` request argument: a survivable transient plan is
    /// retried to byte-identical output, a lethal ENOSPC plan fails
    /// that one request with a one-line `err` (the service keeps
    /// serving), and bad values name the offending argument.
    #[test]
    fn sortfile_with_faults_argument() {
        use crate::external::format::{read_raw, write_raw};
        let dir = std::env::temp_dir().join(format!("flims-svc-flt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("req.u32");
        let data: Vec<u32> = (0..20_000u32).map(|i| i.wrapping_mul(2654435761)).collect();
        write_raw(&input, &data).unwrap();

        // Tight budget so every spill seam is actually exercised. Pin
        // the config-level plan to None: this test drives faults per
        // request, and the FLIMS_FAULTS CI lane must not pre-arm one.
        let mut app = crate::config::AppConfig::default();
        app.external.mem_budget_bytes = 4096;
        app.external.dtype = crate::external::Dtype::U32;
        app.external.fault = None;
        let router = Arc::new(Router::new(app, None));
        let s = Service::new(
            router,
            BatcherConfig { max_batch: 2, window: Duration::from_micros(1) },
        );

        let mut expect = data;
        expect.sort_unstable_by(|a, b| b.cmp(a));
        let expect_path = format!("{}.sorted", input.display());

        // Transient faults are absorbed by the retry layer: same `ok`
        // line, same output bytes as a fault-free sort.
        let resp = s.handle_line(&format!(
            "sortfile external {} faults=7:0.02:transient",
            input.display()
        ));
        assert_eq!(resp, format!("ok 20000 {expect_path}"));
        assert_eq!(read_raw::<u32>(Path::new(&expect_path)).unwrap(), expect);

        // `faults=off` is a legal explicit no-plan value.
        let resp =
            s.handle_line(&format!("sortfile external {} faults=off", input.display()));
        assert_eq!(resp, format!("ok 20000 {expect_path}"));

        // A certain-death plan (ENOSPC on every draw) fails THAT
        // request with one clean line; the next plain request succeeds.
        let resp = s.handle_line(&format!(
            "sortfile external {} faults=1:1.0:enospc",
            input.display()
        ));
        assert!(resp.starts_with("err "), "{resp}");
        assert!(!resp.contains('\n'), "response must stay one line");
        let resp = s.handle_line(&format!("sortfile external {}", input.display()));
        assert_eq!(resp, format!("ok 20000 {expect_path}"));
        assert_eq!(read_raw::<u32>(Path::new(&expect_path)).unwrap(), expect);

        // Bad values are one-line errors naming the offending argument.
        let resp =
            s.handle_line(&format!("sortfile external {} faults=7:2.0:all", input.display()));
        assert!(resp.starts_with("err "), "{resp}");
        assert!(resp.contains("faults argument:"), "{resp}");
        let resp = s.handle_line(&format!(
            "sortfile external {} faults=1:0.1:all faults=off",
            input.display()
        ));
        assert!(resp.contains("faults argument: given more than once"), "{resp}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// `stats reset` is rejected — atomically, under the scheduler's
    /// admission lock — while any job is running or queued, so a reset
    /// can never tear an in-flight sort's counters.
    #[test]
    fn stats_reset_rejected_while_a_job_is_active() {
        use std::sync::mpsc;
        let s = svc();
        let router = s.router.clone();
        let (started_tx, started_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let t = std::thread::spawn(move || {
            router.jobs.run("held", |_| {
                started_tx.send(()).unwrap();
                release_rx.recv().unwrap();
                Ok(())
            })
        });
        started_rx.recv().unwrap();
        assert_eq!(s.handle_line("stats reset"), "err stats reset rejected: 1 job(s) active");
        release_tx.send(()).unwrap();
        t.join().unwrap().unwrap();
        assert_eq!(s.handle_line("stats reset"), "ok reset");
    }
}
