//! Dynamic batcher: coalesces concurrent same-shape sort requests into
//! one batched execution (the `batched_sort` artifact on PJRT, or a
//! parallel native pass), amortising dispatch overhead — the same
//! window/max-batch policy a serving router applies to model calls.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::router::{Backend, Router};
use crate::metrics::ServiceMetrics;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// flush when this many requests are queued
    pub max_batch: usize,
    /// or when the oldest request has waited this long
    pub window: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, window: Duration::from_micros(500) }
    }
}

struct Pending {
    data: Vec<f32>,
    reply: mpsc::Sender<anyhow::Result<Vec<f32>>>,
    enqueued: Instant,
}

/// A synchronous dynamic batcher. `submit` blocks until the request's
/// batch executes (in the caller that triggers the flush, or a later
/// one). A background flusher is intentionally avoided: with a
/// single-threaded driver the window check happens on each submit; the
/// service layer calls `flush_if_due` from its accept loop as the timer.
pub struct Batcher {
    router: Arc<Router>,
    cfg: BatcherConfig,
    queue: Mutex<Vec<Pending>>,
    /// Shared service metrics (same set the router updates).
    pub metrics: Arc<ServiceMetrics>,
}

impl Batcher {
    /// Build a batcher over `router` with the given policy.
    pub fn new(router: Arc<Router>, cfg: BatcherConfig) -> Self {
        let metrics = router.metrics.clone();
        Batcher { router, cfg, queue: Mutex::new(Vec::new()), metrics }
    }

    /// Enqueue a sort request; returns a receiver for its result.
    pub fn submit(&self, data: Vec<f32>) -> mpsc::Receiver<anyhow::Result<Vec<f32>>> {
        let (tx, rx) = mpsc::channel();
        let flush_now = {
            let mut q = self.queue.lock().unwrap();
            q.push(Pending { data, reply: tx, enqueued: Instant::now() });
            q.len() >= self.cfg.max_batch
        };
        if flush_now {
            self.flush();
        }
        rx
    }

    /// Flush if the oldest request exceeded the window.
    pub fn flush_if_due(&self) {
        let due = {
            let q = self.queue.lock().unwrap();
            q.first().map(|p| p.enqueued.elapsed() >= self.cfg.window).unwrap_or(false)
        };
        if due {
            self.flush();
        }
    }

    /// Execute everything queued as one batch.
    pub fn flush(&self) {
        let batch: Vec<Pending> = {
            let mut q = self.queue.lock().unwrap();
            std::mem::take(&mut *q)
        };
        if batch.is_empty() {
            return;
        }
        self.metrics.batches.inc();

        // Try the PJRT batched artifact when every request fits one
        // shape; otherwise execute individually on the native engine.
        let use_pjrt_batch = self.router.has_pjrt() && batch.len() >= 2;
        if use_pjrt_batch {
            if let Some(rt) = self.router.runtime() {
                let spec = rt.specs().ok().and_then(|specs| {
                    specs.into_iter().find(|s| {
                        s.kind == crate::runtime::ArtifactKind::BatchedSort
                            && s.batch >= batch.len()
                            && batch.iter().all(|p| p.data.len() <= s.n)
                    })
                });
                if let Some(spec) = spec {
                    let rows: Vec<Vec<f32>> = (0..spec.batch)
                        .map(|i| {
                            let mut row = batch
                                .get(i)
                                .map(|p| p.data.clone())
                                .unwrap_or_default();
                            row.resize(spec.n, f32::NEG_INFINITY);
                            row
                        })
                        .collect();
                    match rt.batched_sort(&spec.name, rows) {
                        Ok(sorted) => {
                            for (i, p) in batch.into_iter().enumerate() {
                                let mut row = sorted[i].clone();
                                row.truncate(p.data.len());
                                let _ = p.reply.send(Ok(row));
                            }
                            return;
                        }
                        Err(e) => {
                            // fall through to per-request native path
                            eprintln!("batched pjrt execution failed: {e:#}");
                        }
                    }
                }
            }
        }
        for p in batch {
            let out = self.router.sort_f32(p.data, Backend::Native);
            let _ = p.reply.send(out);
        }
    }

    /// Queued depth (observability).
    pub fn depth(&self) -> usize {
        self.queue.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AppConfig;

    fn mk() -> Batcher {
        let router = Arc::new(Router::new(AppConfig::default(), None));
        Batcher::new(router, BatcherConfig { max_batch: 3, window: Duration::from_millis(5) })
    }

    #[test]
    fn full_batch_flushes_immediately() {
        let b = mk();
        let r1 = b.submit(vec![3.0, 1.0, 2.0]);
        let r2 = b.submit(vec![5.0, 4.0]);
        assert_eq!(b.depth(), 2);
        let r3 = b.submit(vec![9.0]); // hits max_batch=3 → flush
        assert_eq!(b.depth(), 0);
        assert_eq!(r1.recv().unwrap().unwrap(), vec![3.0, 2.0, 1.0]);
        assert_eq!(r2.recv().unwrap().unwrap(), vec![5.0, 4.0]);
        assert_eq!(r3.recv().unwrap().unwrap(), vec![9.0]);
        assert_eq!(b.metrics.batches.get(), 1);
    }

    #[test]
    fn window_flush() {
        let b = mk();
        let r1 = b.submit(vec![2.0, 7.0]);
        std::thread::sleep(Duration::from_millis(10));
        b.flush_if_due();
        assert_eq!(r1.recv().unwrap().unwrap(), vec![7.0, 2.0]);
    }

    #[test]
    fn flush_if_not_due_keeps_queue() {
        let b = mk();
        let _r = b.submit(vec![1.0]);
        b.flush_if_due(); // window is 5ms; not due yet
        assert_eq!(b.depth(), 1);
        b.flush();
        assert_eq!(b.depth(), 0);
    }

    #[test]
    fn empty_flush_is_noop() {
        let b = mk();
        b.flush();
        assert_eq!(b.metrics.batches.get(), 0);
    }
}
