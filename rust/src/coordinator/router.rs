//! Request router: picks an execution backend per request.
//!
//! Native = the rust FLiMS engine (always available, any length).
//! Pjrt = the AOT-compiled Pallas/JAX artifacts (f32, artifact shapes,
//! padded as needed) — the path that proves the three-layer stack
//! composes, with Python absent at request time.
//! External = the out-of-core pipeline: data round-trips through spill
//! files and FLiMS merge trees, so memory stays bounded regardless of
//! request size (and `sort_file_external` sorts whole datasets on disk).

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Context, Result};

use super::jobs::{Job, JobScheduler};
use crate::config::AppConfig;
use crate::external::{self, Codec, Dtype, ExtItem, ExternalConfig, SpillStats};
use crate::fault::{self, FaultSpec};
use crate::flims::parallel::{par_sort_desc, ParSortConfig};
use crate::flims::simd::{merge_desc_kernel, MergeKernel};
use crate::flims::sort::{sort_desc_with, SortConfig};
use crate::key::F32Key;
use crate::metrics::{ServiceMetrics, SortLabels, SortSample};
use crate::obs::{self, progress, Trace};
use crate::runtime::RuntimeHandle;

/// Execution backend for a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// The sequential rust FLiMS engine.
    Native,
    /// The multi-threaded rust FLiMS engine.
    NativeParallel,
    /// AOT-compiled Pallas/JAX artifacts through the PJRT runtime.
    Pjrt,
    /// The out-of-core external sort (bounded memory, spill files).
    External,
}

impl Backend {
    /// Parse a backend name (`native` | `parallel` | `pjrt` | `external`).
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "native" => Backend::Native,
            "parallel" => Backend::NativeParallel,
            "pjrt" => Backend::Pjrt,
            "external" => Backend::External,
            other => return Err(anyhow!("unknown backend '{other}'")),
        })
    }
}

/// The router owns the engines, the job scheduler, and the metrics.
pub struct Router {
    cfg: AppConfig,
    runtime: Option<RuntimeHandle>,
    /// Shared service metrics, updated on every routed request.
    pub metrics: Arc<ServiceMetrics>,
    /// The multi-tenant job scheduler every external sort runs under
    /// (the `jobs`/`status <id>`/`cancel <id>` verbs talk to it).
    pub jobs: Arc<JobScheduler>,
    /// The most recent external sort's labels + stats (the `stats`
    /// verb's `last[…]` block).
    last_sort: Mutex<Option<(SortLabels, SpillStats)>>,
}

impl Router {
    /// Build a router over the given config and (optional) PJRT runtime.
    pub fn new(cfg: AppConfig, runtime: Option<RuntimeHandle>) -> Self {
        let jobs = Arc::new(JobScheduler::new(&cfg));
        Router {
            cfg,
            runtime,
            metrics: Arc::new(ServiceMetrics::default()),
            jobs,
            last_sort: Mutex::new(None),
        }
    }

    /// Whether the PJRT runtime loaded (the `pjrt` backend is servable).
    pub fn has_pjrt(&self) -> bool {
        self.runtime.is_some()
    }

    /// The PJRT runtime handle, when loaded.
    pub fn runtime(&self) -> Option<&RuntimeHandle> {
        self.runtime.as_ref()
    }

    fn sort_cfg(&self) -> SortConfig {
        SortConfig { w: self.cfg.w, chunk: self.cfg.chunk }
    }

    /// What the configured merge kernel resolves to on this CPU —
    /// surfaced in the `stats` protocol line and the CLI report.
    pub fn kernel_name(&self) -> &'static str {
        self.cfg.kernel.resolved_name()
    }

    /// Sort u32 keys descending on the requested backend.
    pub fn sort_u32(&self, mut data: Vec<u32>, backend: Backend) -> Result<Vec<u32>> {
        self.metrics.requests.inc();
        self.metrics.elements_sorted.add(data.len() as u64);
        let t = std::time::Instant::now();
        let out = match backend {
            Backend::Native => {
                sort_desc_with(&mut data, self.sort_cfg(), self.cfg.kernel);
                data
            }
            Backend::NativeParallel => {
                par_sort_desc(
                    &mut data,
                    ParSortConfig {
                        base: self.sort_cfg(),
                        threads: self.cfg.threads,
                        kernel: self.cfg.kernel,
                        ..Default::default()
                    },
                );
                data
            }
            Backend::Pjrt => {
                // u32 → order-preserving f32 is lossy; route u32 through
                // the native engine and reserve PJRT for f32 payloads.
                return Err(anyhow!("pjrt backend sorts f32 only (use 'sortf')"));
            }
            Backend::External => {
                let ext = self.cfg.external_config();
                // Inputs that fit a single run take `sort_vec`'s
                // in-memory fast path — no spill machinery, nothing to
                // schedule — so small `sort external` requests keep
                // their tail latency however many huge `sortfile` jobs
                // are queued. Everything larger runs as a job under the
                // carved budgets.
                let (out, stats) = if data.len() <= ext.run_elems_for(<u32 as ExtItem>::WIRE_BYTES)
                {
                    external::sort_vec(&data, &ext)?
                } else {
                    let carved = self.jobs.carve(&ext);
                    self.jobs.run("sort external", |job| {
                        let (ext, job_dir) = Self::job_ext(&carved, job);
                        let res =
                            external::sort_vec_ctx(&data, &ext, &job.ctx(), self.jobs.pool());
                        if let Some(d) = &job_dir {
                            let _ = std::fs::remove_dir(d);
                        }
                        res
                    })?
                };
                self.record_spill(&stats, Self::labels_for(&ext, Dtype::U32));
                out
            }
        };
        self.metrics.latency.observe(t.elapsed());
        Ok(out)
    }

    /// Sort the raw dataset at `input` with the external pipeline,
    /// writing `<input>.sorted` (descending). `dtype` selects the record
    /// type, `codec` the spill-run codec, `overlap` the schedule
    /// (pipelined vs serial — same output bytes), and `kernel` the
    /// merge-kernel tier (scalar vs explicit SIMD — also same output
    /// bytes; `None` = the `[external]`/`[core]` config defaults).
    /// Memory stays within the configured budget however large the
    /// file is. `trace` writes a Chrome trace-event JSON of the sort to
    /// that path (the `--trace` flag / `trace=` protocol option),
    /// independent of the config's `trace_dir` auto-tracing.
    ///
    /// Every `sortfile` runs as a scheduler job: it waits for one of
    /// the `max_jobs` running slots (rejected with `busy` past the
    /// admission queue), sorts under the carved per-slot budgets with
    /// its own progress counters and cancel token, and draws spill
    /// writers from the shared process-wide pool. The sorted bytes are
    /// identical to a serial run — carving changes spill layout, never
    /// output.
    ///
    /// `faults` attaches a per-request fault-injection plan (the
    /// protocol's `faults=` option / `--faults`), overriding the
    /// `[fault] plan` config default for this request only. With
    /// `[server] job_retries > 0`, a job that fails on a *transient*
    /// I/O error (injection exhausted its in-line retries, or a real
    /// `EINTR` surfaced) is re-admitted that many times before the
    /// failure is final — each re-admission is a fresh job with a fresh
    /// id, and a deterministic non-transient failure is never retried.
    pub fn sort_file_external(
        &self,
        input: &Path,
        dtype: Option<Dtype>,
        codec: Option<Codec>,
        overlap: Option<bool>,
        kernel: Option<MergeKernel>,
        faults: Option<FaultSpec>,
        trace: Option<&Path>,
    ) -> Result<(PathBuf, SpillStats)> {
        self.metrics.requests.inc();
        let dtype = dtype.unwrap_or(self.cfg.external.dtype);
        let t = std::time::Instant::now();
        let mut name = input.as_os_str().to_owned();
        name.push(".sorted");
        let output = PathBuf::from(name);
        let mut ext = self.jobs.carve(&self.cfg.external_config());
        if let Some(codec) = codec {
            ext.codec = codec;
        }
        if let Some(overlap) = overlap {
            ext.overlap = overlap;
        }
        if let Some(kernel) = kernel {
            ext.kernel = kernel;
        }
        if let Some(spec) = faults {
            ext.fault = Some(spec);
        }
        let desc = format!("sortfile {}", input.display());
        let mut attempt = 0usize;
        let stats = loop {
            let res = self.jobs.run(&desc, |job| {
                let (ext, job_dir) = Self::job_ext(&ext, job);
                let ctx = job.ctx();
                let pool = self.jobs.pool();
                let res = match trace {
                    None => {
                        let handle = ext.make_trace();
                        let res = external::sort_file_dtype_ctx(
                            input, &output, &ext, dtype, &ctx, pool, &handle,
                        );
                        if let (Ok(_), Some(dir)) = (&res, &ext.trace_dir) {
                            obs::chrome::write_auto(&handle, dir);
                        }
                        res
                    }
                    Some(trace_path) => {
                        let handle = Trace::enabled();
                        external::sort_file_dtype_ctx(
                            input, &output, &ext, dtype, &ctx, pool, &handle,
                        )
                        .and_then(|stats| {
                            obs::chrome::write_file(&handle, trace_path).with_context(|| {
                                format!("writing trace {}", trace_path.display())
                            })?;
                            Ok(stats)
                        })
                    }
                };
                if let Some(d) = &job_dir {
                    let _ = std::fs::remove_dir(d);
                }
                res
            });
            match res {
                Ok(stats) => break stats,
                // Only transient I/O failures are worth a second job;
                // everything else (bad input, budget, cancellation)
                // would fail identically.
                Err(e) if attempt < self.cfg.job_retries && fault::error_is_transient(&e) => {
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        };
        self.metrics.elements_sorted.add(stats.elements);
        self.record_spill(&stats, Self::labels_for(&ext, dtype));
        self.metrics.latency.observe(t.elapsed());
        Ok((output, stats))
    }

    /// `ext` with `job`'s private spill subdirectory substituted in
    /// (when a `tmp_dir` is configured at all): concurrent jobs sharing
    /// one configured directory would collide on `run-NNNNNN.flr`
    /// names. Returns the subdirectory so the caller can best-effort
    /// remove it after the job (the `SpillManager` deletes the run
    /// files but treats a caller-provided directory as caller-owned).
    fn job_ext(ext: &ExternalConfig, job: &Job) -> (ExternalConfig, Option<PathBuf>) {
        let mut e = ext.clone();
        let dir = e.tmp_dir.take().map(|d| d.join(format!("job-{}", job.id)));
        e.tmp_dir.clone_from(&dir);
        (e, dir)
    }

    /// The exposition label set an external sort ran under. The kernel
    /// label is the *effective* tier for this dtype (what its merges
    /// actually ran on), not the CPU-wide resolved ceiling.
    fn labels_for(ext: &ExternalConfig, dtype: Dtype) -> SortLabels {
        SortLabels {
            dtype: dtype.name(),
            codec: ext.codec_for(dtype).name(),
            kernel: dtype.effective_kernel(ext.kernel),
            overlap: ext.overlap,
        }
    }

    fn record_spill(&self, stats: &SpillStats, labels: SortLabels) {
        self.metrics.external_sorts.inc();
        self.metrics.runs_spilled.add(stats.runs_spilled);
        self.metrics.bytes_spilled.add(stats.bytes_spilled);
        self.metrics.bytes_spilled_raw.add(stats.bytes_spilled_raw);
        self.metrics.merge_passes.add(stats.merge_passes);
        self.metrics.phase1_us.add(stats.phase1_us);
        self.metrics.phase2_us.add(stats.phase2_us);
        self.metrics.wall_us.add(stats.wall_us);
        self.metrics.overlap_us.add(stats.overlap_us);
        self.metrics.prefetch_hits.add(stats.prefetch_hits);
        self.metrics.prefetch_misses.add(stats.prefetch_misses);
        self.metrics.codec_encode_us.add(stats.codec_encode_us);
        self.metrics.codec_decode_us.add(stats.codec_decode_us);
        self.metrics.per_sort.record(
            labels,
            &SortSample {
                elements: stats.elements,
                runs_spilled: stats.runs_spilled,
                bytes_spilled: stats.bytes_spilled,
                bytes_spilled_raw: stats.bytes_spilled_raw,
                merge_passes: stats.merge_passes,
                wall_us: stats.wall_us,
                overlap_us: stats.overlap_us,
                codec_encode_us: stats.codec_encode_us,
                codec_decode_us: stats.codec_decode_us,
            },
        );
        *self.last_sort.lock().unwrap() = Some((labels, *stats));
    }

    /// The most recent external sort's labels + stats, if any sort ran
    /// since startup (or the last `stats reset`).
    pub fn last_sort(&self) -> Option<(SortLabels, SpillStats)> {
        *self.last_sort.lock().unwrap()
    }

    /// Zero every counter, histogram, and per-label aggregate, and
    /// forget the last sort (`stats reset`). The process-wide progress
    /// totals are left alone — they are monotonic by contract.
    ///
    /// Rejected while any job is running or queued: a reset landing
    /// mid-sort would zero counters between a job's updates, leaving
    /// the per-sort label aggregates inconsistent with the totals. The
    /// check holds the scheduler's admission lock, so no job can slip
    /// in while the counters swap.
    pub fn reset_metrics(&self) -> Result<()> {
        self.jobs
            .if_idle(|| {
                self.metrics.reset();
                *self.last_sort.lock().unwrap() = None;
            })
            .map_err(|active| anyhow!("stats reset rejected: {active} job(s) active"))
    }

    /// The full Prometheus text exposition: the service metric set, the
    /// per-label sort aggregates, the process-wide progress counters,
    /// and the job scheduler's series (admission totals, queue gauges,
    /// per-job `flims_job_*{job="<id>"}` progress), terminated by
    /// `# EOF` (OpenMetrics-style, and the marker TCP clients read up
    /// to).
    pub fn prometheus(&self) -> String {
        let mut out = self.metrics.prometheus();
        progress::prometheus_into(&mut out);
        self.jobs.prometheus_into(&mut out);
        fault::prometheus_into(&mut out);
        out.push_str("# EOF");
        out
    }

    /// The per-connection read timeout from `[server] read_timeout_ms`
    /// (`None` = wait forever) — what `handle_conn` arms each accepted
    /// socket with so silent clients are reaped instead of pinning
    /// handler threads.
    pub fn conn_read_timeout(&self) -> Option<std::time::Duration> {
        match self.cfg.read_timeout_ms {
            0 => None,
            ms => Some(std::time::Duration::from_millis(ms)),
        }
    }

    /// Sort f32 values descending on the requested backend.
    pub fn sort_f32(&self, data: Vec<f32>, backend: Backend) -> Result<Vec<f32>> {
        self.metrics.requests.inc();
        self.metrics.elements_sorted.add(data.len() as u64);
        let t = std::time::Instant::now();
        let out = match backend {
            Backend::Native | Backend::NativeParallel => {
                let mut keys: Vec<F32Key> = data.iter().map(|&x| F32Key::from_f32(x)).collect();
                if backend == Backend::NativeParallel {
                    par_sort_desc(
                        &mut keys,
                        ParSortConfig {
                            base: self.sort_cfg(),
                            threads: self.cfg.threads,
                            kernel: self.cfg.kernel,
                            ..Default::default()
                        },
                    );
                } else {
                    sort_desc_with(&mut keys, self.sort_cfg(), self.cfg.kernel);
                }
                keys.into_iter().map(|k| k.to_f32()).collect()
            }
            Backend::Pjrt => {
                let rt = self
                    .runtime
                    .as_ref()
                    .ok_or_else(|| anyhow!("pjrt runtime not loaded (run `make artifacts`)"))?;
                rt.sort_padded(data.clone())?
            }
            Backend::External => {
                return Err(anyhow!("external backend sorts u32 datasets (use 'sort external' or 'sortfile')"));
            }
        };
        self.metrics.latency.observe(t.elapsed());
        Ok(out)
    }

    /// Merge two descending-sorted u32 lists (native FLiMS lanes).
    pub fn merge_u32(&self, a: &[u32], b: &[u32]) -> Vec<u32> {
        self.metrics.requests.inc();
        self.metrics.elements_sorted.add((a.len() + b.len()) as u64);
        let mut out = Vec::with_capacity(a.len() + b.len());
        merge_desc_kernel(a, b, self.cfg.w, self.cfg.kernel, &mut out);
        out
    }

    /// Merge two descending-sorted f32 lists via the PJRT merge2
    /// artifact (padded), falling back to native when absent.
    pub fn merge_f32(&self, a: &[f32], b: &[f32], backend: Backend) -> Result<Vec<f32>> {
        self.metrics.requests.inc();
        match backend {
            Backend::Pjrt => {
                let rt = self
                    .runtime
                    .as_ref()
                    .ok_or_else(|| anyhow!("pjrt runtime not loaded"))?;
                let spec = rt
                    .best_for(crate::runtime::ArtifactKind::Merge2, a.len().max(b.len()))?
                    .ok_or_else(|| anyhow!("no merge2 artifact fits {}", a.len().max(b.len())))?;
                let pad = |v: &[f32]| {
                    let mut p = v.to_vec();
                    p.resize(spec.n, f32::NEG_INFINITY);
                    p
                };
                let mut out = rt.merge2(&spec.name, pad(a), pad(b))?;
                out.truncate(a.len() + b.len());
                Ok(out)
            }
            _ => {
                let ka: Vec<F32Key> = a.iter().map(|&x| F32Key::from_f32(x)).collect();
                let kb: Vec<F32Key> = b.iter().map(|&x| F32Key::from_f32(x)).collect();
                let mut out = Vec::with_capacity(ka.len() + kb.len());
                merge_desc_kernel(&ka, &kb, self.cfg.w, self.cfg.kernel, &mut out);
                Ok(out.into_iter().map(|k| k.to_f32()).collect())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_u32, Distribution};
    use crate::util::rng::Rng;

    fn router() -> Router {
        Router::new(AppConfig::default(), None)
    }

    /// `AppConfig::default()` with the external dtype pinned to u32:
    /// tests below write u32 datasets and pass `dtype: None`, so the
    /// `FLIMS_DTYPE` CI lane must not change the record type under
    /// them.
    fn u32_cfg() -> AppConfig {
        let mut cfg = AppConfig::default();
        cfg.external.dtype = Dtype::U32;
        cfg
    }

    #[test]
    fn native_sort_u32() {
        let mut rng = Rng::new(301);
        let v = gen_u32(&mut rng, 5000, Distribution::Uniform);
        let mut expect = v.clone();
        expect.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(router().sort_u32(v, Backend::Native).unwrap(), expect);
    }

    #[test]
    fn parallel_sort_u32() {
        let mut rng = Rng::new(302);
        let v = gen_u32(&mut rng, 100_000, Distribution::Uniform);
        let mut expect = v.clone();
        expect.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(router().sort_u32(v, Backend::NativeParallel).unwrap(), expect);
    }

    #[test]
    fn native_sort_f32_handles_negatives() {
        let v = vec![1.5f32, -2.0, 0.0, -0.5, 3.25, f32::NEG_INFINITY];
        let out = router().sort_f32(v, Backend::Native).unwrap();
        assert_eq!(out, vec![3.25, 1.5, 0.0, -0.5, -2.0, f32::NEG_INFINITY]);
    }

    #[test]
    fn merge_u32_works() {
        let out = router().merge_u32(&[9, 5, 1], &[7, 3]);
        assert_eq!(out, vec![9, 7, 5, 3, 1]);
    }

    #[test]
    fn pjrt_without_runtime_errors() {
        assert!(router().sort_f32(vec![1.0], Backend::Pjrt).is_err());
        assert!(router().sort_u32(vec![1], Backend::Pjrt).is_err());
    }

    #[test]
    fn backend_parse() {
        assert_eq!(Backend::parse("native").unwrap(), Backend::Native);
        assert_eq!(Backend::parse("parallel").unwrap(), Backend::NativeParallel);
        assert_eq!(Backend::parse("pjrt").unwrap(), Backend::Pjrt);
        assert_eq!(Backend::parse("external").unwrap(), Backend::External);
        assert!(Backend::parse("gpu").is_err());
    }

    #[test]
    fn external_sort_u32_spills_and_sorts() {
        let mut cfg = AppConfig::default();
        cfg.external.mem_budget_bytes = 4096; // force multiple runs
        cfg.external.fan_in = 4;
        let r = Router::new(cfg, None);
        let mut rng = Rng::new(303);
        let v = gen_u32(&mut rng, 10_000, Distribution::Uniform);
        let mut expect = v.clone();
        expect.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(r.sort_u32(v, Backend::External).unwrap(), expect);
        assert_eq!(r.metrics.external_sorts.get(), 1);
        assert!(r.metrics.runs_spilled.get() >= 10, "10k elems / 1k runs");
        assert!(r.metrics.merge_passes.get() >= 2);
        assert!(r.metrics.bytes_spilled.get() >= 40_000);
    }

    #[test]
    fn external_backend_rejects_f32() {
        assert!(router().sort_f32(vec![1.0], Backend::External).is_err());
    }

    #[test]
    fn sort_file_external_round_trip() {
        let dir = std::env::temp_dir().join(format!("flims-router-ext-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("data.u32");
        let mut rng = Rng::new(304);
        let v = gen_u32(&mut rng, 5000, Distribution::Uniform);
        crate::external::format::write_raw(&input, &v).unwrap();

        let mut cfg = u32_cfg();
        cfg.external.mem_budget_bytes = 4096;
        let r = Router::new(cfg, None);
        let (out_path, stats) =
            r.sort_file_external(&input, None, None, None, None, None, None).unwrap();
        assert_eq!(out_path, dir.join("data.u32.sorted"));
        assert_eq!(stats.elements, 5000);

        let mut expect = v;
        expect.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(crate::external::format::read_raw::<u32>(&out_path).unwrap(), expect);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sort_file_external_with_delta_codec() {
        let dir = std::env::temp_dir().join(format!("flims-router-codec-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("data.u32");
        // Nearly sorted data: the delta codec must shrink the spill.
        let v: Vec<u32> = (0..20_000u32).map(|i| i ^ 7).collect();
        crate::external::format::write_raw(&input, &v).unwrap();

        let mut cfg = u32_cfg();
        cfg.external.mem_budget_bytes = 4096;
        let r = Router::new(cfg, None);
        let (out_path, stats) =
            r.sort_file_external(&input, None, Some(Codec::Delta), None, None, None, None).unwrap();
        assert_eq!(stats.elements, 20_000);
        assert!(
            stats.bytes_spilled < stats.bytes_spilled_raw,
            "sorted u32 data must compress: {} vs {}",
            stats.bytes_spilled,
            stats.bytes_spilled_raw
        );
        assert_eq!(r.metrics.bytes_spilled.get(), stats.bytes_spilled);
        assert_eq!(r.metrics.bytes_spilled_raw.get(), stats.bytes_spilled_raw);

        let mut expect = v;
        expect.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(crate::external::format::read_raw::<u32>(&out_path).unwrap(), expect);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sort_file_external_kv_dtype() {
        use crate::key::Kv;
        let dir = std::env::temp_dir().join(format!("flims-router-kv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("data.kv");
        let mut rng = Rng::new(305);
        let recs: Vec<Kv> = (0..4000)
            .map(|i| Kv::new(rng.below(16) as u32, i as u32))
            .collect();
        crate::external::format::write_raw(&input, &recs).unwrap();

        let mut cfg = AppConfig::default();
        cfg.external.mem_budget_bytes = 8192; // 1024-record Kv runs
        let r = Router::new(cfg, None);
        let (out_path, stats) = r
            .sort_file_external(&input, Some(crate::external::Dtype::Kv), None, None, None, None, None)
            .unwrap();
        assert_eq!(stats.elements, 4000);

        // Stable: equal keys keep input (payload) order.
        let mut expect = recs;
        expect.sort_by(|a, b| b.key.cmp(&a.key));
        assert_eq!(crate::external::format::read_raw::<Kv>(&out_path).unwrap(), expect);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sort_file_external_overlap_override_matches_serial() {
        let dir =
            std::env::temp_dir().join(format!("flims-router-ovl-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(306);
        let v = gen_u32(&mut rng, 20_000, Distribution::Uniform);

        let mut cfg = u32_cfg();
        cfg.external.mem_budget_bytes = 4096; // 20 runs, fan-in 8 → 2 passes
        cfg.external.fan_in = 4;
        let r = Router::new(cfg, None);
        let mut outputs = Vec::new();
        for overlap in [false, true] {
            let input = dir.join(format!("data-{overlap}.u32"));
            crate::external::format::write_raw(&input, &v).unwrap();
            let (out_path, stats) =
                r.sort_file_external(&input, None, None, Some(overlap), None, None, None).unwrap();
            assert_eq!(stats.elements, 20_000);
            assert!(stats.merge_passes >= 2, "multi-pass workload expected");
            if !overlap {
                assert_eq!(stats.overlap_us, 0, "serial schedule cannot overlap");
            }
            outputs.push(std::fs::read(&out_path).unwrap());
        }
        assert_eq!(outputs[0], outputs[1], "overlap must not change output bytes");
        // Both runs fed the cumulative wall/overlap counters.
        assert!(r.metrics.wall_us.get() > 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sort_file_external_kernel_override_matches() {
        // The per-request kernel override must not change the output
        // bytes — only which tier computed them.
        let dir =
            std::env::temp_dir().join(format!("flims-router-krn-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(307);
        let v = gen_u32(&mut rng, 20_000, Distribution::Uniform);

        let mut cfg = u32_cfg();
        cfg.external.mem_budget_bytes = 4096;
        let r = Router::new(cfg, None);
        let mut outputs = Vec::new();
        for kernel in [MergeKernel::Scalar, MergeKernel::Simd] {
            let input = dir.join(format!("data-{}.u32", kernel.name()));
            crate::external::format::write_raw(&input, &v).unwrap();
            let (out_path, stats) =
                r.sort_file_external(&input, None, None, None, Some(kernel), None, None).unwrap();
            assert_eq!(stats.elements, 20_000);
            outputs.push(std::fs::read(&out_path).unwrap());
        }
        assert_eq!(outputs[0], outputs[1], "kernel must not change output bytes");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn kernel_name_is_resolved() {
        let r = router();
        let name = r.kernel_name();
        assert!(
            ["scalar", "simd-sse2", "simd-avx2", "simd-neon"].contains(&name),
            "{name}"
        );
        let mut cfg = AppConfig::default();
        cfg.kernel = MergeKernel::Scalar;
        assert_eq!(Router::new(cfg, None).kernel_name(), "scalar");
    }

    #[test]
    fn metrics_count_requests() {
        let r = router();
        let _ = r.sort_u32(vec![3, 1, 2], Backend::Native);
        let _ = r.merge_u32(&[2], &[1]);
        assert_eq!(r.metrics.requests.get(), 2);
        assert_eq!(r.metrics.elements_sorted.get(), 5);
    }

    #[test]
    fn sort_file_external_trace_writes_chrome_json() {
        let dir = std::env::temp_dir().join(format!("flims-router-trc-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("data.u32");
        let mut rng = Rng::new(308);
        let v = gen_u32(&mut rng, 10_000, Distribution::Uniform);
        crate::external::format::write_raw(&input, &v).unwrap();

        let mut cfg = u32_cfg();
        cfg.external.mem_budget_bytes = 4096;
        let r = Router::new(cfg, None);
        let trace_path = dir.join("sort.trace.json");
        let (out_path, stats) = r
            .sort_file_external(&input, None, None, None, None, None, Some(&trace_path))
            .unwrap();
        assert_eq!(stats.elements, 10_000);

        // Tracing must not perturb the sorted bytes.
        let mut expect = v;
        expect.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(crate::external::format::read_raw::<u32>(&out_path).unwrap(), expect);

        let json = std::fs::read_to_string(&trace_path).unwrap();
        assert!(json.starts_with("{\"traceEvents\":["), "{}", &json[..40.min(json.len())]);
        for name in ["chunk_sort", "seal_run", "group_merge"] {
            assert!(json.contains(&format!("\"name\":\"{name}\"")), "missing {name} span");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn labeled_metrics_and_last_sort_flow_from_external_sorts() {
        let mut cfg = AppConfig::default();
        cfg.external.mem_budget_bytes = 4096;
        let r = Router::new(cfg, None);
        assert!(r.last_sort().is_none());
        let mut rng = Rng::new(309);
        let v = gen_u32(&mut rng, 10_000, Distribution::Uniform);
        r.sort_u32(v, Backend::External).unwrap();

        let (labels, stats) = r.last_sort().expect("external sort must record last_sort");
        assert_eq!(labels.dtype, "u32");
        assert!(stats.wall_us > 0, "wall clock must be recorded");
        assert_eq!(stats.elements, 10_000);

        let text = r.prometheus();
        assert!(text.ends_with("# EOF"), "exposition must end with # EOF");
        let series = format!(
            "flims_sorts_total{{dtype=\"u32\",codec=\"{}\",kernel=\"{}\",overlap=\"{}\"}} 1",
            labels.codec,
            labels.kernel,
            if labels.overlap { "on" } else { "off" },
        );
        assert!(text.contains(&series), "missing {series} in:\n{text}");

        r.reset_metrics().unwrap();
        assert!(r.last_sort().is_none());
        assert_eq!(r.metrics.external_sorts.get(), 0);
        assert!(!r.prometheus().contains("flims_sorts_total{"), "per-label series must reset");
    }

    #[test]
    fn external_sorts_run_as_jobs_and_small_sorts_bypass() {
        let cfg = AppConfig {
            // 1024-element u32 runs
            external: ExternalConfig { mem_budget_bytes: 4096, ..ExternalConfig::default() },
            ..AppConfig::default()
        };
        let r = Router::new(cfg, None);
        let mut rng = Rng::new(310);
        // 500 elements fit one run: served inline, no job admitted.
        let small = gen_u32(&mut rng, 500, Distribution::Uniform);
        r.sort_u32(small, Backend::External).unwrap();
        assert!(r.jobs.report().starts_with("jobs=0"), "{}", r.jobs.report());
        // 10k elements spill: runs under the scheduler with per-job
        // progress visible afterwards.
        let big = gen_u32(&mut rng, 10_000, Distribution::Uniform);
        let mut expect = big.clone();
        expect.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(r.sort_u32(big, Backend::External).unwrap(), expect);
        assert!(r.jobs.report().contains("1:done"), "{}", r.jobs.report());
        let status = r.jobs.status_line(1).unwrap();
        assert!(status.contains("state=done"), "{status}");
        assert!(!status.contains("runs_sealed=0 "), "per-job progress must tick: {status}");
        let text = r.prometheus();
        assert!(text.contains("flims_jobs_completed_total 1"), "{text}");
        assert!(text.contains("flims_job_runs_sealed{job=\"1\"}"), "{text}");
    }

    #[test]
    fn transient_job_failures_are_readmitted_then_final() {
        let dir =
            std::env::temp_dir().join(format!("flims-router-retry-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("data.u32");
        let mut rng = Rng::new(312);
        let v = gen_u32(&mut rng, 10_000, Distribution::Uniform);
        crate::external::format::write_raw(&input, &v).unwrap();

        // A rate-1.0 transient-only plan: every spill op injects until
        // the in-line retries are exhausted, so every job fails with a
        // transient error — deterministically.
        let mut cfg = u32_cfg();
        cfg.external.mem_budget_bytes = 4096;
        cfg.job_retries = 2;
        cfg.external.fault = Some(crate::fault::FaultSpec {
            seed: 1,
            rate_ppm: 1_000_000,
            kinds: crate::fault::KIND_TRANSIENT,
        });
        let r = Router::new(cfg, None);
        let err = format!(
            "{:#}",
            r.sort_file_external(&input, None, None, None, None, None, None).unwrap_err()
        );
        assert!(err.contains("injected transient"), "{err}");
        // Re-admitted twice after the first failure: three jobs total,
        // all failed, and no partial output or spill left behind.
        let report = r.jobs.report();
        assert!(report.starts_with("jobs=3"), "{report}");
        for id in 1..=3 {
            assert!(report.contains(&format!("{id}:failed")), "{report}");
        }
        assert!(!dir.join("data.u32.sorted").exists(), "partial output must be removed");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn stats_reset_rejected_while_jobs_active() {
        use std::sync::mpsc;
        let r = Arc::new(router());
        r.reset_metrics().unwrap(); // idle: allowed
        let (started_tx, started_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let r2 = Arc::clone(&r);
        let t = std::thread::spawn(move || {
            r2.jobs.run("hold", |_| {
                started_tx.send(()).unwrap();
                release_rx.recv().unwrap();
                Ok(())
            })
        });
        started_rx.recv().unwrap();
        let err = r.reset_metrics().unwrap_err();
        assert!(format!("{err:#}").contains("1 job(s) active"), "{err:#}");
        release_tx.send(()).unwrap();
        t.join().unwrap().unwrap();
        r.reset_metrics().unwrap();
    }

    #[test]
    fn concurrent_sortfile_jobs_share_a_tmp_dir_without_colliding() {
        use std::sync::mpsc;
        let dir =
            std::env::temp_dir().join(format!("flims-router-jobs-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = AppConfig {
            max_jobs: 2,
            external: ExternalConfig {
                mem_budget_bytes: 8192, // carved to 4096 at max_jobs 2
                fan_in: 4,
                tmp_dir: Some(dir.join("spill")),
                dtype: Dtype::U32, // u32 datasets below, whatever FLIMS_DTYPE says
                ..ExternalConfig::default()
            },
            ..AppConfig::default()
        };
        let r = Arc::new(Router::new(cfg, None));

        let mut rng = Rng::new(311);
        let (tx, rx) = mpsc::channel();
        let mut expects = Vec::new();
        for i in 0..2u32 {
            let v = gen_u32(&mut rng, 20_000, Distribution::Uniform);
            let input = dir.join(format!("data-{i}.u32"));
            crate::external::format::write_raw(&input, &v).unwrap();
            let mut expect = v;
            expect.sort_unstable_by(|a, b| b.cmp(a));
            expects.push((input.clone(), expect));
            let r = Arc::clone(&r);
            let tx = tx.clone();
            std::thread::spawn(move || {
                tx.send(r.sort_file_external(&input, None, None, None, None, None, None)).unwrap();
            });
        }
        drop(tx);
        for res in rx {
            let (out_path, stats) = res.unwrap();
            assert_eq!(stats.elements, 20_000);
            let got = crate::external::format::read_raw::<u32>(&out_path).unwrap();
            let (_, want) = expects
                .iter()
                .find(|(i, _)| out_path == PathBuf::from(format!("{}.sorted", i.display())))
                .expect("output path must match one input");
            assert_eq!(&got, want, "concurrent job output must match serial sort");
        }
        // Both jobs retired; their spill subdirectories are gone.
        assert!(r.jobs.report().contains("1:done"), "{}", r.jobs.report());
        assert!(r.jobs.report().contains("2:done"), "{}", r.jobs.report());
        let leftovers: Vec<_> = std::fs::read_dir(dir.join("spill"))
            .map(|d| d.collect())
            .unwrap_or_default();
        assert!(leftovers.is_empty(), "spill leaked: {leftovers:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
