//! The multi-tenant job scheduler: admits N concurrent `sortfile`/`sort`
//! jobs, queues the overflow (bounded — beyond that requests are
//! rejected with `err busy`, backpressure instead of pile-up), carves
//! the `[external]` memory/disk/thread budgets evenly across the
//! running slots, and owns the one process-wide [`WriterPool`] every
//! job's spill writers draw from instead of spawning per-sort pools.
//!
//! A job is born `queued`, becomes `running` when it reaches the front
//! of the FIFO queue and a slot is free, and retires as `done`,
//! `failed`, or `cancelled`. Each job carries its own
//! [`ProgressCounters`] (surfaced by `status <id>`) and a
//! [`CancelToken`] (tripped by `cancel <id>`): cancellation lands at
//! the sort pipeline's batch boundaries and unwinds through the normal
//! error path, so spill files and partial outputs never leak. A
//! cancelled job that never started simply leaves the queue.
//!
//! Budget carving is static — each slot gets `1/max_jobs` of the
//! configured memory/disk/thread budgets — so admission is trivially
//! safe: N admitted jobs can never oversubscribe the totals. Carving
//! changes only the spill layout (run sizes), never the sorted output
//! bytes, which depend on the input data and dtype alone.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{anyhow, Result};

use crate::config::AppConfig;
use crate::external::{CancelToken, ExternalConfig, SortCtx, WriterPool};
use crate::obs::progress::{ProgressCounters, ProgressHandle};

/// Finished jobs kept visible to `jobs`/`status <id>` before the oldest
/// are forgotten.
const RETAIN_FINISHED: usize = 64;

/// Where a job is in its lifecycle.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Admitted, waiting for a running slot.
    Queued,
    /// Occupying one of the `max_jobs` slots.
    Running,
    /// Completed successfully.
    Done,
    /// Completed with an error (the message).
    Failed(String),
    /// Cancelled — before or while running.
    Cancelled,
}

impl JobState {
    /// The wire-format state name (`status <id>` / `jobs`).
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed(_) => "failed",
            JobState::Cancelled => "cancelled",
        }
    }
}

/// One admitted job: identity, live progress, and the cancel flag.
#[derive(Debug)]
pub struct Job {
    /// Process-unique id (`status <id>` / `cancel <id>`).
    pub id: u64,
    /// Human-readable request description (shown nowhere yet; kept for
    /// log lines and debugging).
    pub desc: String,
    /// This job's live progress counters.
    pub progress: Arc<ProgressCounters>,
    /// Trip to request cancellation.
    pub cancel: CancelToken,
    state: Mutex<JobState>,
}

impl Job {
    /// The [`SortCtx`] to thread through this job's sort: progress
    /// lands on the job's counters (and the process totals), and the
    /// job's cancel token aborts it.
    pub fn ctx(&self) -> SortCtx {
        SortCtx {
            progress: ProgressHandle::with_job(Arc::clone(&self.progress)),
            cancel: self.cancel.clone(),
        }
    }

    /// Current lifecycle state (a clone; the job may move on).
    pub fn state(&self) -> JobState {
        self.state.lock().unwrap().clone()
    }
}

#[derive(Default)]
struct SchedState {
    next_id: u64,
    running: usize,
    queue: VecDeque<u64>,
    jobs: BTreeMap<u64, Arc<Job>>,
    finished: VecDeque<u64>,
}

/// The scheduler itself — one per [`Router`](super::Router), long-lived.
pub struct JobScheduler {
    max_jobs: usize,
    queue_depth: usize,
    /// The process-wide spill-writer pool every job shares. `None` only
    /// if thread spawning failed at startup; jobs then build per-sort
    /// pools exactly as before the scheduler existed.
    pool: Option<WriterPool>,
    state: Mutex<SchedState>,
    slot_free: Condvar,
    admitted_total: AtomicU64,
    rejected_total: AtomicU64,
    completed_total: AtomicU64,
    failed_total: AtomicU64,
    cancelled_total: AtomicU64,
}

impl std::fmt::Debug for JobScheduler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("JobScheduler")
            .field("max_jobs", &self.max_jobs)
            .field("queue_depth", &self.queue_depth)
            .finish_non_exhaustive()
    }
}

impl JobScheduler {
    /// Build the scheduler for `cfg`: `[server] max_jobs` running
    /// slots, `[server] queue_depth` waiters, and one process-wide
    /// writer pool sized for every slot's spill writers at once.
    pub fn new(cfg: &AppConfig) -> Self {
        let ext = cfg.external_config();
        // One writer thread per concurrent spill writer across all
        // slots (each job: its phase-1 producer + its group merges),
        // plus slack. `try_execute` falls back to a dedicated thread
        // under saturation, so undersizing costs a spawn, never a
        // deadlock.
        let workers = ext.effective_threads() + cfg.max_jobs + 2;
        JobScheduler {
            max_jobs: cfg.max_jobs.max(1),
            queue_depth: cfg.job_queue_depth,
            pool: WriterPool::new(workers).ok(),
            state: Mutex::new(SchedState { next_id: 1, ..Default::default() }),
            slot_free: Condvar::new(),
            admitted_total: AtomicU64::new(0),
            rejected_total: AtomicU64::new(0),
            completed_total: AtomicU64::new(0),
            failed_total: AtomicU64::new(0),
            cancelled_total: AtomicU64::new(0),
        }
    }

    /// The shared process-wide writer pool (for `sort_stream_ctx`'s
    /// `shared_pool` argument).
    pub fn pool(&self) -> Option<&WriterPool> {
        self.pool.as_ref()
    }

    /// Configured running-slot count.
    pub fn max_jobs(&self) -> usize {
        self.max_jobs
    }

    /// `cfg` with the memory/disk/thread budgets carved down to one
    /// slot's share, floored at the smallest valid values, so
    /// `max_jobs` concurrent sorts stay inside the configured totals.
    /// With `max_jobs = 1` the config passes through untouched.
    pub fn carve(&self, ext: &ExternalConfig) -> ExternalConfig {
        let n = self.max_jobs;
        if n <= 1 {
            return ext.clone();
        }
        let mut c = ext.clone();
        c.mem_budget_bytes = (ext.mem_budget_bytes / n).max(4096);
        c.threads = (ext.effective_threads() / n).max(1);
        if let Some(d) = ext.disk_budget_bytes {
            c.disk_budget_bytes = Some((d / n as u64).max(1));
        }
        c
    }

    /// Admit, wait for a slot, run `f`, retire. The whole job lifecycle:
    /// rejects with `busy` when the server is at capacity
    /// (`max_jobs` running + `queue_depth` queued), waits FIFO for a
    /// running slot otherwise, and classifies the outcome —
    /// `cancelled` whenever the job's token was tripped, regardless of
    /// which pipeline check point surfaced the abort.
    pub fn run<R>(&self, desc: &str, f: impl FnOnce(&Job) -> Result<R>) -> Result<R> {
        let job = self.admit(desc)?;
        self.wait_for_slot(&job)?;
        let res = f(&job);
        self.retire_running(&job, &res);
        res
    }

    fn admit(&self, desc: &str) -> Result<Arc<Job>> {
        let mut st = self.state.lock().unwrap();
        if st.running + st.queue.len() >= self.max_jobs + self.queue_depth {
            self.rejected_total.fetch_add(1, Ordering::Relaxed);
            return Err(anyhow!(
                "busy: {} running, {} queued (capacity {} jobs + {} queued)",
                st.running,
                st.queue.len(),
                self.max_jobs,
                self.queue_depth
            ));
        }
        let id = st.next_id;
        st.next_id += 1;
        let job = Arc::new(Job {
            id,
            desc: desc.to_string(),
            progress: Arc::new(ProgressCounters::default()),
            cancel: CancelToken::new(),
            state: Mutex::new(JobState::Queued),
        });
        st.queue.push_back(id);
        st.jobs.insert(id, Arc::clone(&job));
        self.admitted_total.fetch_add(1, Ordering::Relaxed);
        // A slot may be free right now; the waiter loop checks.
        self.slot_free.notify_all();
        Ok(job)
    }

    /// Block until `job` reaches the queue front and a running slot is
    /// free (strict FIFO — small jobs do not overtake big ones *in the
    /// scheduler*; tail latency for small `sort`s is preserved by the
    /// router's bypass, not by reordering). Returns an error if the job
    /// is cancelled while still queued.
    fn wait_for_slot(&self, job: &Job) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if job.cancel.is_cancelled() {
                st.queue.retain(|&id| id != job.id);
                self.retire_locked(&mut st, job, JobState::Cancelled);
                return Err(anyhow!("job {} cancelled", job.id));
            }
            if st.queue.front() == Some(&job.id) && st.running < self.max_jobs {
                st.queue.pop_front();
                st.running += 1;
                *job.state.lock().unwrap() = JobState::Running;
                return Ok(());
            }
            st = self.slot_free.wait(st).unwrap();
        }
    }

    fn retire_running<R>(&self, job: &Job, res: &Result<R>) {
        let state = match res {
            Ok(_) => JobState::Done,
            // The token decides, not the message: whichever check point
            // surfaced the abort ("sort cancelled", "sort aborted",
            // "merge cancelled"), a tripped token means cancelled.
            Err(_) if job.cancel.is_cancelled() => JobState::Cancelled,
            Err(e) => JobState::Failed(format!("{e:#}")),
        };
        let mut st = self.state.lock().unwrap();
        st.running -= 1;
        self.retire_locked(&mut st, job, state);
    }

    fn retire_locked(&self, st: &mut SchedState, job: &Job, state: JobState) {
        match &state {
            JobState::Done => &self.completed_total,
            JobState::Failed(_) => &self.failed_total,
            _ => &self.cancelled_total,
        }
        .fetch_add(1, Ordering::Relaxed);
        *job.state.lock().unwrap() = state;
        st.finished.push_back(job.id);
        while st.finished.len() > RETAIN_FINISHED {
            if let Some(old) = st.finished.pop_front() {
                st.jobs.remove(&old);
            }
        }
        self.slot_free.notify_all();
    }

    /// Trip `id`'s cancel token. Queued jobs leave the queue promptly;
    /// running jobs abort at the pipeline's next check point and retire
    /// as `cancelled`. Idempotent: cancelling a job that already
    /// finished (done, failed, or cancelled) is a no-op success — a
    /// client retrying a timed-out `cancel` must not get an error for
    /// having succeeded the first time. Only an id the scheduler never
    /// issued (or has forgotten) errors.
    pub fn cancel(&self, id: u64) -> Result<()> {
        let st = self.state.lock().unwrap();
        let job = st.jobs.get(&id).ok_or_else(|| anyhow!("unknown job: {id}"))?;
        match job.state() {
            JobState::Queued | JobState::Running => {
                job.cancel.cancel();
                self.slot_free.notify_all();
                Ok(())
            }
            JobState::Done | JobState::Failed(_) | JobState::Cancelled => Ok(()),
        }
    }

    /// The `status <id>` payload: state plus the job's own progress
    /// counters; a failed job's error message comes last (it may
    /// contain spaces — everything before it is strict `k=v`).
    pub fn status_line(&self, id: u64) -> Result<String> {
        let job = {
            let st = self.state.lock().unwrap();
            st.jobs.get(&id).cloned().ok_or_else(|| anyhow!("unknown job: {id}"))?
        };
        let p = job.progress.snapshot();
        let state = job.state();
        let mut line = format!(
            "job={id} state={} runs_sealed={} merges_fired={} elements_out={} bytes_out={}",
            state.name(),
            p.runs_sealed,
            p.merges_fired,
            p.elements_out,
            p.bytes_out
        );
        if let JobState::Failed(msg) = &state {
            line.push_str(" error=");
            line.push_str(msg);
        }
        Ok(line)
    }

    /// The `jobs` payload: totals, live gauges, and every retained job
    /// as `<id>:<state>` in id order.
    pub fn report(&self) -> String {
        let st = self.state.lock().unwrap();
        let mut s = format!(
            "jobs={} running={} queued={}",
            self.admitted_total.load(Ordering::Relaxed),
            st.running,
            st.queue.len()
        );
        for (id, job) in &st.jobs {
            s.push_str(&format!(" {}:{}", id, job.state().name()));
        }
        s
    }

    /// Jobs currently running or queued.
    pub fn active(&self) -> usize {
        let st = self.state.lock().unwrap();
        st.running + st.queue.len()
    }

    /// Run `f` only if no job is running or queued, holding the
    /// scheduler lock throughout so none can be admitted mid-`f` — the
    /// `stats reset` race fix. `Err(active)` reports how many jobs
    /// blocked it.
    pub fn if_idle<R>(&self, f: impl FnOnce() -> R) -> Result<R, usize> {
        let st = self.state.lock().unwrap();
        let active = st.running + st.queue.len();
        if active > 0 {
            return Err(active);
        }
        let out = f();
        drop(st);
        Ok(out)
    }

    /// Append the scheduler's Prometheus series: admission totals, live
    /// gauges, and one `flims_job_*{job="<id>"}` sample per retained
    /// job (queued, running, and recently finished).
    pub fn prometheus_into(&self, out: &mut String) {
        use std::fmt::Write as _;
        let mut metric = |name: &str, help: &str, kind: &str, v: u64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} {kind}");
            let _ = writeln!(out, "{name} {v}");
        };
        metric(
            "flims_jobs_admitted_total",
            "Jobs admitted by the scheduler.",
            "counter",
            self.admitted_total.load(Ordering::Relaxed),
        );
        metric(
            "flims_jobs_rejected_total",
            "Jobs rejected at admission (server busy).",
            "counter",
            self.rejected_total.load(Ordering::Relaxed),
        );
        metric(
            "flims_jobs_completed_total",
            "Jobs finished successfully.",
            "counter",
            self.completed_total.load(Ordering::Relaxed),
        );
        metric(
            "flims_jobs_failed_total",
            "Jobs finished with an error.",
            "counter",
            self.failed_total.load(Ordering::Relaxed),
        );
        metric(
            "flims_jobs_cancelled_total",
            "Jobs cancelled before or while running.",
            "counter",
            self.cancelled_total.load(Ordering::Relaxed),
        );
        let st = self.state.lock().unwrap();
        let _ = writeln!(out, "# HELP flims_jobs_running Jobs occupying a running slot.");
        let _ = writeln!(out, "# TYPE flims_jobs_running gauge");
        let _ = writeln!(out, "flims_jobs_running {}", st.running);
        let _ = writeln!(out, "# HELP flims_jobs_queued Jobs waiting for a running slot.");
        let _ = writeln!(out, "# TYPE flims_jobs_queued gauge");
        let _ = writeln!(out, "flims_jobs_queued {}", st.queue.len());
        if st.jobs.is_empty() {
            return;
        }
        let series: [(&str, &str, fn(&crate::obs::progress::JobProgress) -> u64); 4] = [
            ("flims_job_runs_sealed", "Runs this job sealed on disk.", |p| p.runs_sealed),
            ("flims_job_merges_fired", "Group merges this job completed.", |p| p.merges_fired),
            ("flims_job_elements_out", "Elements this job wrote to its output.", |p| {
                p.elements_out
            }),
            ("flims_job_bytes_out", "Bytes this job wrote to its output.", |p| p.bytes_out),
        ];
        for (name, help, get) in series {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} gauge");
            for (id, job) in &st.jobs {
                let _ = writeln!(out, "{name}{{job=\"{id}\"}} {}", get(&job.progress.snapshot()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn sched(max_jobs: usize, queue_depth: usize) -> JobScheduler {
        let cfg =
            AppConfig { max_jobs, job_queue_depth: queue_depth, ..AppConfig::default() };
        JobScheduler::new(&cfg)
    }

    #[test]
    fn jobs_run_and_retire_in_order() {
        let s = sched(2, 4);
        let out = s.run("a", |job| {
            assert_eq!(job.id, 1);
            assert_eq!(job.state(), JobState::Running);
            Ok(42)
        });
        assert_eq!(out.unwrap(), 42);
        assert!(s.report().contains("1:done"), "{}", s.report());
        let err = s.run("b", |_| Err::<(), _>(anyhow!("boom"))).unwrap_err();
        assert!(format!("{err:#}").contains("boom"));
        let line = s.status_line(2).unwrap();
        assert!(line.contains("state=failed") && line.ends_with("error=boom"), "{line}");
    }

    #[test]
    fn admission_rejects_beyond_capacity() {
        let s = Arc::new(sched(1, 0));
        let (started_tx, started_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let s2 = Arc::clone(&s);
        let t = std::thread::spawn(move || {
            s2.run("big", |_| {
                started_tx.send(()).unwrap();
                release_rx.recv().unwrap();
                Ok(())
            })
        });
        started_rx.recv().unwrap();
        // Capacity is 1 running + 0 queued: the next job bounces.
        let err = s.run("small", |_| Ok(())).unwrap_err();
        assert!(format!("{err:#}").contains("busy"), "{err:#}");
        release_tx.send(()).unwrap();
        t.join().unwrap().unwrap();
        // Capacity freed: admitted again.
        s.run("after", |_| Ok(())).unwrap();
    }

    #[test]
    fn cancel_while_queued_skips_the_job() {
        let s = Arc::new(sched(1, 4));
        let (started_tx, started_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let s2 = Arc::clone(&s);
        let blocker = std::thread::spawn(move || {
            s2.run("blocker", |_| {
                started_tx.send(()).unwrap();
                release_rx.recv().unwrap();
                Ok(())
            })
        });
        started_rx.recv().unwrap();
        let s3 = Arc::clone(&s);
        let queued = std::thread::spawn(move || s3.run("queued", |_| Ok(())));
        // Wait until job 2 is actually queued, then cancel it.
        while s.active() < 2 {
            std::thread::yield_now();
        }
        s.cancel(2).unwrap();
        let err = queued.join().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("cancelled"), "{err:#}");
        assert!(s.status_line(2).unwrap().contains("state=cancelled"));
        // Cancelling a finished job is an idempotent no-op success (a
        // retried cancel must not error); only unknown ids error, with
        // the uniform "unknown job: <id>" wording status uses too.
        s.cancel(2).unwrap();
        assert!(s.status_line(2).unwrap().contains("state=cancelled"));
        assert!(s.cancel(99).unwrap_err().to_string().contains("unknown job: 99"));
        assert!(s.status_line(99).unwrap_err().to_string().contains("unknown job: 99"));
        release_tx.send(()).unwrap();
        blocker.join().unwrap().unwrap();
    }

    #[test]
    fn running_cancel_classifies_by_token() {
        let s = sched(1, 0);
        let err = s
            .run("self-cancelling", |job| {
                job.cancel.cancel();
                job.cancel.check()?;
                Ok(())
            })
            .unwrap_err();
        assert!(format!("{err:#}").contains("cancelled"));
        assert!(s.status_line(1).unwrap().contains("state=cancelled"));
    }

    #[test]
    fn carve_divides_budgets_with_floors() {
        let s = sched(4, 0);
        let ext = ExternalConfig {
            mem_budget_bytes: 64 << 20,
            threads: 8,
            disk_budget_bytes: Some(1 << 30),
            ..Default::default()
        };
        let c = s.carve(&ext);
        assert_eq!(c.mem_budget_bytes, 16 << 20);
        assert_eq!(c.threads, 2);
        assert_eq!(c.disk_budget_bytes, Some((1 << 30) / 4));
        // Floors: budgets never carve below the smallest valid values.
        let tiny = ExternalConfig {
            mem_budget_bytes: 4096,
            threads: 1,
            disk_budget_bytes: Some(2),
            ..Default::default()
        };
        let c = s.carve(&tiny);
        assert_eq!(c.mem_budget_bytes, 4096);
        assert_eq!(c.threads, 1);
        assert_eq!(c.disk_budget_bytes, Some(1));
        // max_jobs = 1: pass-through, bit for bit.
        let s1 = sched(1, 0);
        assert_eq!(s1.carve(&ext), ext);
    }

    #[test]
    fn if_idle_gates_on_active_jobs() {
        let s = Arc::new(sched(1, 4));
        assert_eq!(s.if_idle(|| 7), Ok(7));
        let (started_tx, started_rx) = mpsc::channel();
        let (release_tx, release_rx) = mpsc::channel::<()>();
        let s2 = Arc::clone(&s);
        let t = std::thread::spawn(move || {
            s2.run("busy", |_| {
                started_tx.send(()).unwrap();
                release_rx.recv().unwrap();
                Ok(())
            })
        });
        started_rx.recv().unwrap();
        assert_eq!(s.if_idle(|| 7), Err(1));
        release_tx.send(()).unwrap();
        t.join().unwrap().unwrap();
        assert_eq!(s.if_idle(|| 7), Ok(7));
    }

    #[test]
    fn prometheus_series_render() {
        let s = sched(2, 4);
        s.run("a", |job| {
            job.ctx().progress.block_out(5, 20);
            Ok(())
        })
        .unwrap();
        let mut out = String::new();
        s.prometheus_into(&mut out);
        assert!(out.contains("flims_jobs_admitted_total 1"), "{out}");
        assert!(out.contains("flims_jobs_completed_total 1"), "{out}");
        assert!(out.contains("flims_jobs_running 0"), "{out}");
        assert!(out.contains("flims_job_elements_out{job=\"1\"} 5"), "{out}");
        assert!(out.contains("flims_job_bytes_out{job=\"1\"} 20"), "{out}");
    }
}
