//! Layer-3 coordinator: sorting-as-a-service.
//!
//! * [`router`] — backend dispatch: every request routes to the native
//!   rust engine (FLiMS sort / merge / parallel sort), the PJRT
//!   runtime executing the AOT Pallas artifacts, or the out-of-core
//!   external pipeline (`sortfile`, with per-request `dtype`/`codec`
//!   overrides).
//! * [`batcher`] — dynamic batching: concurrent sort requests of the
//!   same shape coalesce into one `batched_sort` artifact execution
//!   (vLLM-router-style window + max-batch policy).
//! * [`jobs`] — the multi-tenant job scheduler: admission control over
//!   N concurrent `sortfile`/`sort` jobs, a bounded FIFO queue with
//!   `err busy` backpressure, per-job progress/cancellation, budget
//!   carving, and the shared process-wide spill-writer pool.
//! * [`service`] — a TCP front end with a line-oriented protocol, one
//!   worker thread per connection, shared metrics.

pub mod batcher;
pub mod jobs;
pub mod router;
pub mod service;

pub use batcher::{Batcher, BatcherConfig};
pub use jobs::{Job, JobScheduler, JobState};
pub use router::{Backend, Router};
pub use service::Service;
