//! LSD radix sort — the Intel IPP radix-sort analogue of paper fig. 15.
//!
//! 8-bit digits, counting passes, ping-pong buffers. The paper notes
//! radix's structural traits: it wins on small/mid sizes and restricted
//! key ranges but is capped (their IPP build topped out near 2^28) and
//! is not comparison-based — we mirror the first two by construction and
//! document the cap in the fig. 15 bench.

/// Trait for keys radix-sortable by byte extraction.
pub trait RadixKey: Copy {
    const BYTES: usize;
    fn byte(&self, i: usize) -> u8;
}

impl RadixKey for u32 {
    const BYTES: usize = 4;
    #[inline]
    fn byte(&self, i: usize) -> u8 {
        (self >> (8 * i)) as u8
    }
}

impl RadixKey for u64 {
    const BYTES: usize = 8;
    #[inline]
    fn byte(&self, i: usize) -> u8 {
        (self >> (8 * i)) as u8
    }
}

/// Sort ascending, LSD, 8-bit digits.
pub fn radix_sort_asc<T: RadixKey>(x: &mut Vec<T>) {
    let n = x.len();
    if n <= 1 {
        return;
    }
    let mut buf: Vec<T> = Vec::with_capacity(n);
    // SAFETY-free approach: initialise buf by cloning x once.
    buf.extend_from_slice(x);
    let mut src_is_x = true;
    for pass in 0..T::BYTES {
        let (src, dst): (&[T], &mut [T]) = if src_is_x {
            (&x[..], &mut buf[..])
        } else {
            (&buf[..], &mut x[..])
        };
        let mut counts = [0usize; 256];
        for v in src {
            counts[v.byte(pass) as usize] += 1;
        }
        // Skip passes where all keys share the digit (common for small
        // ranges — the radix advantage the paper calls out).
        if counts.iter().any(|&c| c == n) {
            continue;
        }
        let mut offsets = [0usize; 256];
        let mut acc = 0;
        for d in 0..256 {
            offsets[d] = acc;
            acc += counts[d];
        }
        for v in src {
            let d = v.byte(pass) as usize;
            dst[offsets[d]] = *v;
            offsets[d] += 1;
        }
        src_is_x = !src_is_x;
    }
    if !src_is_x {
        x.copy_from_slice(&buf);
    }
}

/// Sort descending (ascending passes + reverse; radix is not
/// comparison-based so there is no cheaper descending trick for LSD).
pub fn radix_sort_desc<T: RadixKey>(x: &mut Vec<T>) {
    radix_sort_asc(x);
    x.reverse();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_u32, Distribution};
    use crate::util::rng::Rng;

    #[test]
    fn sorts_u32() {
        let mut rng = Rng::new(81);
        for n in [0usize, 1, 2, 100, 10_000] {
            let mut v = gen_u32(&mut rng, n, Distribution::Uniform);
            let mut expect = v.clone();
            expect.sort_unstable();
            radix_sort_asc(&mut v);
            assert_eq!(v, expect, "n={n}");
        }
    }

    #[test]
    fn sorts_u64() {
        let mut rng = Rng::new(82);
        let mut v: Vec<u64> = (0..5000).map(|_| rng.next_u64()).collect();
        let mut expect = v.clone();
        expect.sort_unstable();
        radix_sort_asc(&mut v);
        assert_eq!(v, expect);
    }

    #[test]
    fn descending() {
        let mut rng = Rng::new(83);
        let mut v = gen_u32(&mut rng, 3000, Distribution::Uniform);
        let mut expect = v.clone();
        expect.sort_unstable_by(|a, b| b.cmp(a));
        radix_sort_desc(&mut v);
        assert_eq!(v, expect);
    }

    #[test]
    fn small_range_fast_path_correct() {
        // 10-bit keys: 3 of 4 passes skip — the paper's "restricted
        // range" scenario. Correctness must hold through skipped passes.
        let mut rng = Rng::new(84);
        let mut v: Vec<u32> = (0..10_000).map(|_| rng.below(1024) as u32).collect();
        let mut expect = v.clone();
        expect.sort_unstable();
        radix_sort_asc(&mut v);
        assert_eq!(v, expect);
    }

    #[test]
    fn already_sorted() {
        let mut v: Vec<u32> = (0..1000).collect();
        radix_sort_asc(&mut v);
        assert_eq!(v, (0..1000).collect::<Vec<u32>>());
    }
}
