//! Parallel samplesort — the Boost `block_indirect_sort` analogue of
//! paper fig. 15 ("implements the samplesort sorting algorithm, regarded
//! as one of the best performing C++ sort implementations").
//!
//! Classic structure: oversampled splitter selection, partition into
//! `p` buckets, sort buckets in parallel, concatenate.

use crate::util::rng::Rng;

/// Descending parallel samplesort for u32 keys.
pub fn samplesort_desc(x: &mut Vec<u32>, threads: usize) {
    let n = x.len();
    let threads = if threads == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        threads
    };
    if n < 1 << 14 || threads == 1 {
        x.sort_unstable_by(|a, b| b.cmp(a));
        return;
    }

    let buckets = threads.next_power_of_two().min(64);
    // Oversample: 32 samples per bucket.
    let mut rng = Rng::new(0x5A5A);
    let mut samples: Vec<u32> = (0..buckets * 32)
        .map(|_| x[rng.below(n as u64) as usize])
        .collect();
    samples.sort_unstable_by(|a, b| b.cmp(a));
    let splitters: Vec<u32> = (1..buckets).map(|i| samples[i * 32]).collect();

    // Partition (descending buckets: bucket 0 holds the largest keys).
    let mut parts: Vec<Vec<u32>> = (0..buckets).map(|_| Vec::new()).collect();
    for &v in x.iter() {
        // First splitter that v is greater-than determines the bucket.
        let b = splitters.partition_point(|&s| s >= v);
        parts[b].push(v);
    }

    // Sort buckets in parallel.
    std::thread::scope(|s| {
        for p in &mut parts {
            s.spawn(|| p.sort_unstable_by(|a, b| b.cmp(a)));
        }
    });

    x.clear();
    for p in parts {
        x.extend_from_slice(&p);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_u32, Distribution};
    use crate::util::rng::Rng;

    fn check(mut v: Vec<u32>, threads: usize) {
        let mut expect = v.clone();
        expect.sort_unstable_by(|a, b| b.cmp(a));
        samplesort_desc(&mut v, threads);
        assert_eq!(v, expect);
    }

    #[test]
    fn sorts_large() {
        let mut rng = Rng::new(91);
        check(gen_u32(&mut rng, 100_000, Distribution::Uniform), 4);
    }

    #[test]
    fn sorts_small_fallback() {
        let mut rng = Rng::new(92);
        check(gen_u32(&mut rng, 100, Distribution::Uniform), 4);
    }

    #[test]
    fn skewed_buckets_still_correct() {
        let mut rng = Rng::new(93);
        check(gen_u32(&mut rng, 80_000, Distribution::DupHeavy { alphabet: 3 }), 4);
        check(
            gen_u32(&mut rng, 80_000, Distribution::Zipf { s_x100: 150, n_ranks: 100 }),
            4,
        );
    }

    #[test]
    fn thread_counts() {
        let mut rng = Rng::new(94);
        let v = gen_u32(&mut rng, 60_000, Distribution::Uniform);
        for t in [1usize, 2, 5, 16] {
            check(v.clone(), t);
        }
    }
}
