//! The "basic" high-throughput merger (Table 2 row 1): the
//! Chhugani-et-al / Casper-Olukotun loop built on a FULL 2w-to-2w
//! bitonic merger (paper §2.2, fig. 4).
//!
//! Algorithm: hold a w-batch from each list; merge the two batches with
//! the full bitonic merge network; the upper w goes to output, the lower
//! w is fed back; a single comparison of the next batch heads decides
//! which list refills. This is the design with the `log2(w)+2` feedback
//! the FPGA line of work (and FLiMS) eliminates — kept here both as a
//! software baseline and as the comparator-count reference.

use crate::key::Item;

/// Full bitonic merge of two descending w-batches: sorts the
/// concatenation (a, reverse(b)) — a bitonic sequence — with the
/// log2(2w)-stage network, descending.
#[inline]
fn bitonic_full_merge_desc<T: Item>(buf: &mut [T]) {
    // buf holds [a (desc), b (asc = reversed desc)] of length 2w —
    // bitonic; run the full butterfly over 2w.
    crate::flims::butterfly::butterfly_desc(buf);
}

/// Merge two descending-sorted slices with the basic bitonic-merger loop.
pub fn merge_basic_bitonic<T>(a: &[T], b: &[T], w: usize) -> Vec<T>
where
    T: Item<K = T> + crate::key::Key,
{
    assert!(w.is_power_of_two());
    let total = a.len() + b.len();
    let mut out = Vec::with_capacity(total + 2 * w);
    if total == 0 {
        return out;
    }

    let fetch_batch = |xs: &[T], start: usize, dst: &mut [T]| {
        for (i, d) in dst.iter_mut().enumerate() {
            *d = if start + i < xs.len() { xs[start + i] } else { T::SENTINEL };
        }
    };

    // buf = [current merged lower half | incoming batch reversed]
    let mut buf = vec![T::SENTINEL; 2 * w];

    // Prime: first batch of A in the upper half (as descending), first
    // batch of B reversed into the lower half.
    fetch_batch(a, 0, &mut buf[..w]);
    let mut pos_a = w.min(a.len());
    let mut pos_b;
    {
        let mut tmp = vec![T::SENTINEL; w];
        fetch_batch(b, 0, &mut tmp);
        pos_b = w.min(b.len());
        for i in 0..w {
            buf[w + i] = tmp[w - 1 - i];
        }
    }

    let steps = total.div_ceil(w);
    for _ in 0..steps {
        bitonic_full_merge_desc(&mut buf);
        out.extend_from_slice(&buf[..w]);
        // Lower w feeds back; refill upper from the list whose next head
        // is larger (single comparison — fig. 4).
        let head_a = if pos_a < a.len() { a[pos_a] } else { T::SENTINEL };
        let head_b = if pos_b < b.len() { b[pos_b] } else { T::SENTINEL };
        // Move lower half up, then place the reversed incoming batch low.
        let lower: Vec<T> = buf[w..].to_vec();
        buf[..w].copy_from_slice(&lower);
        let mut tmp = vec![T::SENTINEL; w];
        if head_a > head_b {
            fetch_batch(a, pos_a, &mut tmp);
            pos_a += w.min(a.len().saturating_sub(pos_a));
        } else {
            fetch_batch(b, pos_b, &mut tmp);
            pos_b += w.min(b.len().saturating_sub(pos_b));
        }
        for i in 0..w {
            buf[w + i] = tmp[w - 1 - i];
        }
    }
    out.truncate(total);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_sorted_pair, gen_u32, Distribution};
    use crate::util::rng::Rng;

    fn oracle(a: &[u32], b: &[u32]) -> Vec<u32> {
        let mut v: Vec<u32> = a.iter().chain(b.iter()).copied().collect();
        v.sort_unstable_by(|x, y| y.cmp(x));
        v
    }

    #[test]
    fn matches_oracle() {
        let mut rng = Rng::new(101);
        for w in [2usize, 4, 8, 16, 32] {
            for _ in 0..15 {
                let (na, nb) = (rng.range(0, 300), rng.range(0, 300));
                let (a, b) = gen_sorted_pair(&mut rng, na, nb, Distribution::Uniform, gen_u32);
                let out = merge_basic_bitonic(&a, &b, w);
                assert_eq!(out, oracle(&a, &b), "w={w}");
            }
        }
    }

    #[test]
    fn duplicates() {
        let mut rng = Rng::new(102);
        let (a, b) = gen_sorted_pair(
            &mut rng,
            128,
            128,
            Distribution::DupHeavy { alphabet: 2 },
            gen_u32,
        );
        assert_eq!(merge_basic_bitonic(&a, &b, 8), oracle(&a, &b));
    }

    #[test]
    fn empty_and_one_sided() {
        assert!(merge_basic_bitonic::<u32>(&[], &[], 4).is_empty());
        let a: Vec<u32> = (0..50).rev().collect();
        assert_eq!(merge_basic_bitonic(&a, &[], 8), a);
        assert_eq!(merge_basic_bitonic(&[], &a, 8), a);
    }
}
