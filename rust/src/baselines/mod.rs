//! Baseline algorithms the paper benchmarks against (fig. 15 and the
//! Table 2 "basic" merger):
//!
//! * [`stdsort`]  — `std::sort()` analogue (rust `slice::sort_unstable`).
//! * [`radix`]    — LSD radix sort, the Intel IPP radix analogue.
//! * [`samplesort`] — parallel samplesort, the Boost
//!   `block_indirect_sort` analogue.
//! * [`bitonic_merge`] — the Chhugani/Casper full-bitonic-merger loop
//!   with the `log2(2w)`-stage feedback (Table 2 row "basic").

pub mod bitonic_merge;
pub mod radix;
pub mod samplesort;
pub mod stdsort;

pub use bitonic_merge::merge_basic_bitonic;
pub use radix::radix_sort_desc;
pub use samplesort::samplesort_desc;
pub use stdsort::{std_sort_desc, std_stable_sort_desc};
