//! Standard-library sort wrappers — the `std::sort()` baseline of paper
//! fig. 15 (rust's `sort_unstable` is the idiomatic equivalent: an
//! introsort-family pattern-defeating quicksort).

use crate::key::Item;

/// Descending unstable sort via the standard library.
pub fn std_sort_desc<T: Item>(x: &mut [T]) {
    x.sort_unstable_by(|a, b| b.key().cmp(&a.key()));
}

/// Descending stable sort via the standard library (timsort-family).
pub fn std_stable_sort_desc<T: Item>(x: &mut [T]) {
    x.sort_by(|a, b| b.key().cmp(&a.key()));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::key::{is_sorted_desc, Kv};

    #[test]
    fn sorts() {
        let mut v = vec![3u32, 9, 1];
        std_sort_desc(&mut v);
        assert_eq!(v, vec![9, 3, 1]);
    }

    #[test]
    fn stable_keeps_payload_order() {
        let mut v = vec![Kv::new(5, 0), Kv::new(5, 1), Kv::new(7, 2)];
        std_stable_sort_desc(&mut v);
        assert_eq!(v, vec![Kv::new(7, 2), Kv::new(5, 0), Kv::new(5, 1)]);
        assert!(is_sorted_desc(&v));
    }
}
