//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the rust hot path.
//! Python never runs at request time — the compiled executables are
//! self-contained.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (jax ≥ 0.5 emits 64-bit ids the
//! crate's xla_extension 0.5.1 rejects in proto form).

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::Path;
#[cfg(feature = "pjrt")]
use std::path::PathBuf;

#[cfg(feature = "pjrt")]
use anyhow::Context;
use anyhow::{anyhow, bail, Result};

/// One artifact as listed in `artifacts/manifest.tsv`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub kind: ArtifactKind,
    pub file: String,
    pub n: usize,
    pub w: usize,
    pub chunk: usize,
    pub batch: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Merge2,
    FullSort,
    BatchedSort,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "merge2" => ArtifactKind::Merge2,
            "full_sort" => ArtifactKind::FullSort,
            "batched_sort" => ArtifactKind::BatchedSort,
            other => bail!("unknown artifact kind '{other}'"),
        })
    }
}

/// Parse `manifest.tsv` (name, kind, file, n, w, chunk, batch).
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactSpec>> {
    let mut specs = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split('\t').collect();
        if f.len() != 7 {
            bail!("manifest line {}: expected 7 fields, got {}", ln + 1, f.len());
        }
        let num = |s: &str, what: &str| -> Result<usize> {
            s.parse().map_err(|_| anyhow!("manifest line {}: bad {what} '{s}'", ln + 1))
        };
        specs.push(ArtifactSpec {
            name: f[0].to_string(),
            kind: ArtifactKind::parse(f[1])?,
            file: f[2].to_string(),
            n: num(f[3], "n")?,
            w: num(f[4], "w")?,
            chunk: num(f[5], "chunk")?,
            batch: num(f[6], "batch")?,
        });
    }
    Ok(specs)
}

/// The loaded runtime: a PJRT CPU client plus compiled executables.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    specs: HashMap<String, ArtifactSpec>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Load every artifact in `dir` (per its manifest) and compile.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = std::fs::read_to_string(dir.join("manifest.tsv"))
            .with_context(|| format!("reading {}/manifest.tsv (run `make artifacts`)", dir.display()))?;
        let specs = parse_manifest(&manifest)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut exes = HashMap::new();
        let mut by_name = HashMap::new();
        for spec in specs {
            let path: PathBuf = dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?;
            exes.insert(spec.name.clone(), exe);
            by_name.insert(spec.name.clone(), spec);
        }
        Ok(Runtime { client, exes, specs: by_name })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.specs.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn spec(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    /// Pick the smallest artifact of `kind` that fits `n` elements.
    pub fn best_for(&self, kind: ArtifactKind, n: usize) -> Option<&ArtifactSpec> {
        self.specs
            .values()
            .filter(|s| s.kind == kind && s.n >= n)
            .min_by_key(|s| s.n)
    }

    fn run1(&self, name: &str, args: &[xla::Literal]) -> Result<xla::Literal> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named '{name}'"))?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        lit.to_tuple1().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    /// Execute a `merge2` artifact: two descending-sorted f32 arrays of
    /// exactly the artifact's length → merged output.
    pub fn merge2(&self, name: &str, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let spec = self.spec(name).ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        if spec.kind != ArtifactKind::Merge2 {
            bail!("{name} is not a merge2 artifact");
        }
        if a.len() != spec.n || b.len() != spec.n {
            bail!("{name} expects inputs of {}, got {} and {}", spec.n, a.len(), b.len());
        }
        let la = xla::Literal::vec1(a);
        let lb = xla::Literal::vec1(b);
        let out = self.run1(name, &[la, lb])?;
        out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Execute a `full_sort` artifact on exactly `spec.n` f32 values
    /// (descending output).
    pub fn sort(&self, name: &str, x: &[f32]) -> Result<Vec<f32>> {
        let spec = self.spec(name).ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        if spec.kind != ArtifactKind::FullSort {
            bail!("{name} is not a full_sort artifact");
        }
        if x.len() != spec.n {
            bail!("{name} expects input of {}, got {}", spec.n, x.len());
        }
        let lx = xla::Literal::vec1(x);
        let out = self.run1(name, &[lx])?;
        out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Sort arbitrary-length input by padding up to the artifact size
    /// with -inf (descending order ⇒ pads sort to the tail).
    pub fn sort_padded(&self, x: &[f32]) -> Result<Vec<f32>> {
        let spec = self
            .best_for(ArtifactKind::FullSort, x.len())
            .ok_or_else(|| anyhow!("no full_sort artifact fits n={}", x.len()))?
            .clone();
        let mut padded = x.to_vec();
        padded.resize(spec.n, f32::NEG_INFINITY);
        let mut out = self.sort(&spec.name, &padded)?;
        out.truncate(x.len());
        Ok(out)
    }

    /// Execute a `batched_sort` artifact: `batch` rows of `n` values.
    pub fn batched_sort(&self, name: &str, rows: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let spec = self.spec(name).ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        if spec.kind != ArtifactKind::BatchedSort {
            bail!("{name} is not a batched_sort artifact");
        }
        if rows.len() != spec.batch || rows.iter().any(|r| r.len() != spec.n) {
            bail!("{name} expects {}x{}", spec.batch, spec.n);
        }
        let flat: Vec<f32> = rows.iter().flatten().copied().collect();
        let lit = xla::Literal::vec1(&flat)
            .reshape(&[spec.batch as i64, spec.n as i64])
            .map_err(|e| anyhow!("{e:?}"))?;
        let out = self.run1(name, &[lit])?;
        let flat_out = out.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        Ok(flat_out.chunks(spec.n).map(|c| c.to_vec()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = "m\tmerge2\tm.hlo.txt\t4096\t8\t0\t0\n\
                    s\tfull_sort\ts.hlo.txt\t1024\t8\t128\t0\n\
                    b\tbatched_sort\tb.hlo.txt\t1024\t8\t128\t4\n";
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs.len(), 3);
        assert_eq!(specs[0].kind, ArtifactKind::Merge2);
        assert_eq!(specs[1].chunk, 128);
        assert_eq!(specs[2].batch, 4);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(parse_manifest("too\tfew\tfields\n").is_err());
        assert!(parse_manifest("a\tweird_kind\tf\t1\t2\t3\t4\n").is_err());
        assert!(parse_manifest("a\tmerge2\tf\tNaN\t2\t3\t4\n").is_err());
    }

    #[test]
    fn empty_manifest_is_empty() {
        assert!(parse_manifest("").unwrap().is_empty());
    }

    #[test]
    fn wrong_field_count_reports_line_number() {
        // Line 1 is valid; line 2 has 6 fields. The error must name the
        // offending line (1-based) and both the expected and actual count.
        let text = "ok\tmerge2\tok.hlo.txt\t64\t8\t0\t0\nshort\tmerge2\tf\t1\t2\t3\n";
        let err = format!("{:#}", parse_manifest(text).unwrap_err());
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("expected 7 fields"), "{err}");
        assert!(err.contains("got 6"), "{err}");
    }

    #[test]
    fn bad_number_reports_line_field_and_value() {
        // Blank lines are skipped but still counted for the line number.
        let text = "\na\tfull_sort\ta.hlo.txt\t12x\t8\t128\t0\n";
        let err = format!("{:#}", parse_manifest(text).unwrap_err());
        assert!(err.contains("line 2"), "{err}");
        assert!(err.contains("bad n"), "{err}");
        assert!(err.contains("'12x'"), "{err}");

        let text = "a\tbatched_sort\ta.hlo.txt\t128\t8\t16\t-3\n";
        let err = format!("{:#}", parse_manifest(text).unwrap_err());
        assert!(err.contains("line 1"), "{err}");
        assert!(err.contains("bad batch"), "{err}");
        assert!(err.contains("'-3'"), "{err}");
    }

    #[test]
    fn unknown_kind_reports_kind_name() {
        let text = "ok\tmerge2\tok.hlo.txt\t64\t8\t0\t0\n\
                    bad\tquantum_sort\tb.hlo.txt\t64\t8\t0\t0\n";
        let err = format!("{:#}", parse_manifest(text).unwrap_err());
        assert!(err.contains("unknown artifact kind 'quantum_sort'"), "{err}");
    }
}

// ---------------------------------------------------------------------
// Thread-confined runtime handle
//
// The xla crate's PJRT client is Rc-based (not Send/Sync), so the
// Runtime lives on a dedicated executor thread; the rest of the
// coordinator talks to it through this cloneable channel handle —
// the standard actor pattern for thread-affine resources.

#[cfg(feature = "pjrt")]
use std::sync::mpsc::{channel, Sender};

#[cfg(feature = "pjrt")]
enum Req {
    Merge2 {
        name: String,
        a: Vec<f32>,
        b: Vec<f32>,
        reply: Sender<Result<Vec<f32>>>,
    },
    Sort {
        name: String,
        x: Vec<f32>,
        reply: Sender<Result<Vec<f32>>>,
    },
    SortPadded {
        x: Vec<f32>,
        reply: Sender<Result<Vec<f32>>>,
    },
    BatchedSort {
        name: String,
        rows: Vec<Vec<f32>>,
        reply: Sender<Result<Vec<Vec<f32>>>>,
    },
    Specs {
        reply: Sender<Vec<ArtifactSpec>>,
    },
    Platform {
        reply: Sender<String>,
    },
}

/// Cloneable, Send handle to the executor thread owning the [`Runtime`].
#[cfg(feature = "pjrt")]
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Req>,
}

#[cfg(feature = "pjrt")]
impl RuntimeHandle {
    /// Spawn the executor thread and load all artifacts in `dir`.
    /// Returns once loading finished (or failed).
    pub fn load(dir: &Path) -> Result<Self> {
        let dir = dir.to_path_buf();
        let (tx, rx) = channel::<Req>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        std::thread::Builder::new()
            .name("pjrt-executor".into())
            .spawn(move || {
                let rt = match Runtime::load(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Req::Merge2 { name, a, b, reply } => {
                            let _ = reply.send(rt.merge2(&name, &a, &b));
                        }
                        Req::Sort { name, x, reply } => {
                            let _ = reply.send(rt.sort(&name, &x));
                        }
                        Req::SortPadded { x, reply } => {
                            let _ = reply.send(rt.sort_padded(&x));
                        }
                        Req::BatchedSort { name, rows, reply } => {
                            let _ = reply.send(rt.batched_sort(&name, &rows));
                        }
                        Req::Specs { reply } => {
                            let mut v: Vec<ArtifactSpec> =
                                rt.specs.values().cloned().collect();
                            v.sort_by(|a, b| a.name.cmp(&b.name));
                            let _ = reply.send(v);
                        }
                        Req::Platform { reply } => {
                            let _ = reply.send(rt.platform());
                        }
                    }
                }
            })
            .expect("spawn pjrt-executor");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("pjrt-executor thread died during load"))??;
        Ok(RuntimeHandle { tx })
    }

    fn call<R>(&self, mk: impl FnOnce(Sender<R>) -> Req) -> Result<R> {
        let (tx, rx) = channel();
        self.tx
            .send(mk(tx))
            .map_err(|_| anyhow!("pjrt-executor gone"))?;
        rx.recv().map_err(|_| anyhow!("pjrt-executor dropped reply"))
    }

    pub fn merge2(&self, name: &str, a: Vec<f32>, b: Vec<f32>) -> Result<Vec<f32>> {
        self.call(|reply| Req::Merge2 { name: name.into(), a, b, reply })?
    }

    pub fn sort(&self, name: &str, x: Vec<f32>) -> Result<Vec<f32>> {
        self.call(|reply| Req::Sort { name: name.into(), x, reply })?
    }

    pub fn sort_padded(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.call(|reply| Req::SortPadded { x, reply })?
    }

    pub fn batched_sort(&self, name: &str, rows: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        self.call(|reply| Req::BatchedSort { name: name.into(), rows, reply })?
    }

    pub fn specs(&self) -> Result<Vec<ArtifactSpec>> {
        self.call(|reply| Req::Specs { reply })
    }

    pub fn platform(&self) -> Result<String> {
        self.call(|reply| Req::Platform { reply })
    }

    /// Pick the smallest artifact of `kind` that fits `n` elements.
    pub fn best_for(&self, kind: ArtifactKind, n: usize) -> Result<Option<ArtifactSpec>> {
        Ok(self
            .specs()?
            .into_iter()
            .filter(|s| s.kind == kind && s.n >= n)
            .min_by_key(|s| s.n))
    }
}

// ---------------------------------------------------------------------
// Stub runtime — the offline default.
//
// The real runtime needs the external `xla` crate (feature `pjrt`),
// which the offline image cannot provide. This stub exposes the same
// surface with every entry point reporting the runtime as unavailable;
// `load()` erroring means the service and CLI fall back to native-only
// serving, which is exactly how a missing artifacts/ dir is handled.

/// Cloneable handle matching the PJRT runtime surface; always reports
/// the runtime as not compiled in (build with the `pjrt` feature).
#[cfg(not(feature = "pjrt"))]
#[derive(Clone)]
pub struct RuntimeHandle {
    _priv: (),
}

#[cfg(not(feature = "pjrt"))]
impl RuntimeHandle {
    fn unavailable<T>() -> Result<T> {
        bail!("pjrt runtime not compiled in (rebuild with --features pjrt and a vendored xla crate)")
    }

    /// Always errors: the `pjrt` feature is off in this build.
    pub fn load(_dir: &Path) -> Result<Self> {
        Self::unavailable()
    }

    pub fn merge2(&self, _name: &str, _a: Vec<f32>, _b: Vec<f32>) -> Result<Vec<f32>> {
        Self::unavailable()
    }

    pub fn sort(&self, _name: &str, _x: Vec<f32>) -> Result<Vec<f32>> {
        Self::unavailable()
    }

    pub fn sort_padded(&self, _x: Vec<f32>) -> Result<Vec<f32>> {
        Self::unavailable()
    }

    pub fn batched_sort(&self, _name: &str, _rows: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        Self::unavailable()
    }

    pub fn specs(&self) -> Result<Vec<ArtifactSpec>> {
        Self::unavailable()
    }

    pub fn platform(&self) -> Result<String> {
        Self::unavailable()
    }

    pub fn best_for(&self, _kind: ArtifactKind, _n: usize) -> Result<Option<ArtifactSpec>> {
        Self::unavailable()
    }
}
