//! `flims` — leader entrypoint and CLI.
//!
//! Subcommands:
//!
//! ```text
//! flims sort     --n 1000000 [--dist uniform|zipf|dup] [--backend native|parallel|pjrt|external] [--w 16] [--chunk 128] [--kernel auto|scalar|simd]
//! flims merge    --n 65536 [--w 16] [--kernel auto|scalar|simd]
//! flims sortfile --input data.u32 [--output out.u32] [--dtype u32|u64|i32|i64|kv|kv64|f32]
//!                [--codec raw|delta|flr3] [--overlap on|off] [--kernel auto|scalar|simd]
//!                [--budget-mb 64] [--fan-in 8] [--threads T] [--prefetch B] [--gen N]
//!                [--faults seed:rate:kinds]  # deterministic fault injection (docs/ROBUSTNESS.md)
//!                [--trace out.trace.json]  # Chrome trace-event JSON of the sort
//! flims trace                              # the paper's Table 1 example
//! flims simulate --design flims|flimsj|wms|mms|vms|basic --w 8 [--skew] [--dup]
//! flims report   table2|table3|fig13 [--data-bits 64]
//! flims serve    [--bind 127.0.0.1:7171] [--config flims.toml] [--max-jobs N]
//! flims metrics  [--addr 127.0.0.1:7171]   # Prometheus exposition from a server
//! flims jobs     [--addr 127.0.0.1:7171] [--status ID | --cancel ID]  # job table
//! flims artifacts [--dir artifacts]        # list + smoke-run the AOT artifacts
//! ```
//!
//! (Argument parsing is in-tree: the build is offline, no clap.)

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;
use std::time::Instant;

use flims::baselines::{radix_sort_desc, samplesort_desc};
use flims::external;
use flims::external::{parse_codec_arg, Dtype, ExtItem, ExternalConfig};
use flims::config::{AppConfig, RawConfig};
use flims::coordinator::{BatcherConfig, Router, Service};
use flims::data::{gen_i32, gen_i64, gen_u32, gen_u64, Distribution};
use flims::key::{F32Key, Item, Kv, Kv64};
use flims::flims::scalar::{FlimsMerger, Variant};
use flims::flims::simd::{merge_desc_kernel, MergeKernel};
use flims::flims::sort::sort_desc_with;
use flims::flims::{par_sort_desc, SortConfig};
use flims::flims::parallel::ParSortConfig;
use flims::hw::{self, Design, SimConfig};
use flims::key::is_sorted_desc;
use flims::runtime::RuntimeHandle;
use flims::util::rng::Rng;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match run(args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

/// Parse `--key value` / `--flag` pairs after the subcommand.
fn parse_flags(args: &[String]) -> Result<HashMap<String, String>, String> {
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        let key = a
            .strip_prefix("--")
            .ok_or_else(|| format!("expected --flag, got '{a}'"))?;
        if i + 1 < args.len() && !args[i + 1].starts_with("--") {
            flags.insert(key.to_string(), args[i + 1].clone());
            i += 2;
        } else {
            flags.insert(key.to_string(), "true".to_string());
            i += 1;
        }
    }
    Ok(flags)
}

fn get_usize(f: &HashMap<String, String>, k: &str, default: usize) -> Result<usize, String> {
    match f.get(k) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| format!("--{k}: '{v}' is not an integer")),
    }
}

fn dist_of(f: &HashMap<String, String>) -> Result<Distribution, String> {
    Ok(match f.get("dist").map(|s| s.as_str()).unwrap_or("uniform") {
        "uniform" => Distribution::Uniform,
        "dup" => Distribution::DupHeavy { alphabet: 4 },
        "zipf" => Distribution::Zipf { s_x100: 120, n_ranks: 1 << 16 },
        "sorted" => Distribution::SortedAsc,
        "constant" => Distribution::Constant,
        other => return Err(format!("unknown --dist '{other}'")),
    })
}

fn load_config(f: &HashMap<String, String>) -> Result<AppConfig, String> {
    let mut cfg = AppConfig::default();
    if let Some(path) = f.get("config") {
        let raw = RawConfig::load(std::path::Path::new(path))?;
        cfg.apply(&raw)?;
    }
    if let Some(w) = f.get("w") {
        cfg.w = w.parse().map_err(|_| "--w must be an integer".to_string())?;
    }
    if let Some(c) = f.get("chunk") {
        cfg.chunk = c.parse().map_err(|_| "--chunk must be an integer".to_string())?;
    }
    if let Some(t) = f.get("threads") {
        cfg.threads = t.parse().map_err(|_| "--threads must be an integer".to_string())?;
    }
    if let Some(k) = f.get("kernel") {
        cfg.kernel = MergeKernel::parse(k).map_err(|e| format!("--kernel: {e}"))?;
    }
    if let Some(d) = f.get("dir") {
        cfg.artifacts_dir = d.clone();
    }
    if let Some(b) = f.get("bind") {
        cfg.bind = b.clone();
    }
    if let Some(j) = f.get("max-jobs") {
        cfg.max_jobs = j.parse().map_err(|_| "--max-jobs must be an integer".to_string())?;
    }
    cfg.validate()?;
    Ok(cfg)
}

fn run(args: Vec<String>) -> Result<(), String> {
    let Some(cmd) = args.first() else {
        print_help();
        return Ok(());
    };
    let flags = parse_flags(&args[1..])?;
    match cmd.as_str() {
        "sort" => cmd_sort(&flags),
        "merge" => cmd_merge(&flags),
        "sortfile" => cmd_sortfile(&flags),
        "trace" => cmd_trace(),
        "simulate" => cmd_simulate(&flags),
        "report" => cmd_report(&args[1..], &flags),
        "serve" => cmd_serve(&flags),
        "metrics" => cmd_metrics(&flags),
        "jobs" => cmd_jobs(&flags),
        "artifacts" => cmd_artifacts(&flags),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => Err(format!("unknown command '{other}' (try `flims help`)")),
    }
}

fn print_help() {
    println!(
        "flims — Fast Lightweight 2-way Merge Sorter (paper reproduction)\n\
         \n\
         commands:\n\
           sort      --n N [--dist uniform|dup|zipf|sorted|constant]\n\
                     [--backend native|parallel|pjrt|external|std|radix|samplesort]\n\
                     [--w W] [--chunk C] [--threads T] [--kernel auto|scalar|simd]\n\
                     [--config FILE]\n\
           merge     --n N [--w W] [--kernel auto|scalar|simd]\n\
           sortfile  --input F [--output F] [--dtype u32|u64|i32|i64|kv|kv64|f32]\n\
                     [--codec raw|delta|flr3] [--overlap on|off] [--budget-mb M]\n\
                     [--fan-in K] [--threads T] [--prefetch B]\n\
                     [--kernel auto|scalar|simd]\n\
                     [--faults S:R:K]   (seeded fault injection, e.g. 7:0.01:transient;\n\
                                         kinds transient|enospc|short|stall|all — see\n\
                                         docs/ROBUSTNESS.md)\n\
                     [--trace F]   (Chrome trace-event JSON, for Perfetto)\n\
                     [--gen N [--dist D] [--seed S]]   (raw LE record datasets)\n\
           trace     (replays the paper's Table 1 example, w=4)\n\
           simulate  --design flims|flimsj|wms|mms|vms|basic --w W [--skew] [--dup] [--n N]\n\
           report    table2|table3|fig13 [--data-bits B]\n\
           serve     [--bind ADDR] [--config FILE] [--dir artifacts] [--max-jobs N]\n\
           metrics   [--addr ADDR] [--config FILE]   (Prometheus text from a server)\n\
           jobs      [--addr ADDR] [--status ID | --cancel ID]   (server job table)\n\
           artifacts [--dir artifacts]"
    );
}

fn cmd_sort(f: &HashMap<String, String>) -> Result<(), String> {
    let cfg = load_config(f)?;
    let n = get_usize(f, "n", 1 << 20)?;
    let dist = dist_of(f)?;
    let backend = f.get("backend").map(|s| s.as_str()).unwrap_or("native");
    let mut rng = Rng::new(get_usize(f, "seed", 42)? as u64);
    let mut data = gen_u32(&mut rng, n, dist);

    let t = Instant::now();
    match backend {
        "native" => {
            sort_desc_with(&mut data, SortConfig { w: cfg.w, chunk: cfg.chunk }, cfg.kernel)
        }
        "parallel" => par_sort_desc(
            &mut data,
            ParSortConfig {
                base: SortConfig { w: cfg.w, chunk: cfg.chunk },
                threads: cfg.threads,
                kernel: cfg.kernel,
                ..Default::default()
            },
        ),
        "std" => data.sort_unstable_by(|a, b| b.cmp(a)),
        "radix" => radix_sort_desc(&mut data),
        "samplesort" => samplesort_desc(&mut data, cfg.threads),
        "external" => {
            let (out, stats) =
                external::sort_vec(&data, &cfg.external_config()).map_err(|e| format!("{e:#}"))?;
            data = out;
            println!(
                "  (spilled {} runs / {:.1} MB, {} merge passes)",
                stats.runs_spilled,
                stats.bytes_spilled as f64 / (1 << 20) as f64,
                stats.merge_passes
            );
        }
        "pjrt" => {
            let rt = RuntimeHandle::load(std::path::Path::new(&cfg.artifacts_dir))
                .map_err(|e| format!("{e:#}"))?;
            let fdata: Vec<f32> = data.iter().map(|&x| (x >> 8) as f32).collect();
            let out = rt.sort_padded(fdata).map_err(|e| format!("{e:#}"))?;
            println!(
                "pjrt sorted {} f32 values (platform {}), first 5: {:?}",
                out.len(),
                rt.platform().map_err(|e| format!("{e:#}"))?,
                &out[..5.min(out.len())]
            );
            println!("elapsed: {:?}", t.elapsed());
            return Ok(());
        }
        other => return Err(format!("unknown backend '{other}'")),
    }
    let dt = t.elapsed();
    if !is_sorted_desc(&data) {
        return Err("output is not sorted!".into());
    }
    println!(
        "sorted {} u32 ({}) with {} (kernel {}) in {:?} — {:.1} M elem/s",
        n,
        dist.name(),
        backend,
        cfg.kernel.resolved_name(),
        dt,
        n as f64 / dt.as_secs_f64() / 1e6
    );
    Ok(())
}

fn cmd_merge(f: &HashMap<String, String>) -> Result<(), String> {
    let cfg = load_config(f)?;
    let n = get_usize(f, "n", 1 << 20)?;
    let mut rng = Rng::new(7);
    let mut a = gen_u32(&mut rng, n, Distribution::Uniform);
    let mut b = gen_u32(&mut rng, n, Distribution::Uniform);
    a.sort_unstable_by(|x, y| y.cmp(x));
    b.sort_unstable_by(|x, y| y.cmp(x));
    let t = Instant::now();
    let mut out = Vec::with_capacity(2 * n);
    merge_desc_kernel(&a, &b, cfg.w, cfg.kernel, &mut out);
    let dt = t.elapsed();
    if !is_sorted_desc(&out) {
        return Err("merge output not sorted!".into());
    }
    println!(
        "merged 2x{} u32 at w={} (kernel {}) in {:?} — {:.1} M elem/s",
        n,
        cfg.w,
        cfg.kernel.resolved_name(),
        dt,
        (2 * n) as f64 / dt.as_secs_f64() / 1e6
    );
    Ok(())
}

/// Dataset generation for `sortfile --gen`, per dtype. Payload records
/// carry the input index so stability is visible in the output.
trait GenRecord: ExtItem {
    fn gen_block(rng: &mut Rng, n: usize, dist: Distribution, base_idx: u64) -> Vec<Self>;
}

impl GenRecord for u32 {
    fn gen_block(rng: &mut Rng, n: usize, dist: Distribution, _base: u64) -> Vec<Self> {
        gen_u32(rng, n, dist)
    }
}

impl GenRecord for u64 {
    fn gen_block(rng: &mut Rng, n: usize, dist: Distribution, _base: u64) -> Vec<Self> {
        gen_u64(rng, n, dist)
    }
}

impl GenRecord for i32 {
    fn gen_block(rng: &mut Rng, n: usize, dist: Distribution, _base: u64) -> Vec<Self> {
        gen_i32(rng, n, dist)
    }
}

impl GenRecord for i64 {
    fn gen_block(rng: &mut Rng, n: usize, dist: Distribution, _base: u64) -> Vec<Self> {
        gen_i64(rng, n, dist)
    }
}

impl GenRecord for Kv {
    fn gen_block(rng: &mut Rng, n: usize, dist: Distribution, base: u64) -> Vec<Self> {
        gen_u32(rng, n, dist)
            .into_iter()
            .enumerate()
            .map(|(i, key)| Kv::new(key, (base + i as u64) as u32))
            .collect()
    }
}

impl GenRecord for Kv64 {
    fn gen_block(rng: &mut Rng, n: usize, dist: Distribution, base: u64) -> Vec<Self> {
        gen_u64(rng, n, dist)
            .into_iter()
            .enumerate()
            .map(|(i, key)| Kv64 { key, val: base + i as u64 })
            .collect()
    }
}

impl GenRecord for F32Key {
    fn gen_block(rng: &mut Rng, n: usize, dist: Distribution, _base: u64) -> Vec<Self> {
        gen_u32(rng, n, dist)
            .into_iter()
            .map(|x| F32Key::from_f32(x as f32 - (u32::MAX / 2) as f32))
            .collect()
    }
}

fn cmd_sortfile(f: &HashMap<String, String>) -> Result<(), String> {
    let cfg = load_config(f)?;
    let mut ext = cfg.external_config();
    if let Some(mb) = f.get("budget-mb") {
        let mb: usize = mb.parse().map_err(|_| "--budget-mb must be an integer".to_string())?;
        ext.mem_budget_bytes = mb << 20;
    }
    if let Some(fan) = f.get("fan-in") {
        ext.fan_in = fan.parse().map_err(|_| "--fan-in must be an integer".to_string())?;
    }
    if let Some(t) = f.get("threads") {
        ext.threads = t.parse().map_err(|_| "--threads must be an integer".to_string())?;
    }
    if let Some(p) = f.get("prefetch") {
        ext.prefetch_blocks =
            p.parse().map_err(|_| "--prefetch must be an integer".to_string())?;
    }
    if let Some(d) = f.get("dtype") {
        ext.dtype = Dtype::parse(d).map_err(|e| format!("--dtype: {e}"))?;
    }
    if let Some(c) = f.get("codec") {
        ext.codec = parse_codec_arg(c)?;
    }
    if let Some(o) = f.get("overlap") {
        ext.overlap = external::parse_overlap(o)?;
    }
    // (--kernel already landed in `ext` through load_config →
    // external_config; accept it here too for symmetry with the other
    // sortfile knobs.)
    if let Some(k) = f.get("kernel") {
        ext.kernel = MergeKernel::parse(k).map_err(|e| format!("--kernel: {e}"))?;
    }
    if let Some(plan) = f.get("faults") {
        ext.fault = flims::fault::parse_faults_arg(plan).map_err(|e| format!("--faults: {e}"))?;
    }
    ext.validate()?;
    let input = PathBuf::from(
        f.get("input").ok_or_else(|| "sortfile: --input <path> required".to_string())?,
    );
    let output = f
        .get("output")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(format!("{}.sorted", input.display())));

    let trace = f.get("trace").map(PathBuf::from);
    if trace.as_deref().is_some_and(|p| p.as_os_str().is_empty()) {
        return Err("--trace: empty path".into());
    }
    let trace = trace.as_deref();

    match ext.dtype {
        Dtype::U32 => sortfile_typed::<u32>(f, &ext, &input, &output, trace),
        Dtype::U64 => sortfile_typed::<u64>(f, &ext, &input, &output, trace),
        Dtype::I32 => sortfile_typed::<i32>(f, &ext, &input, &output, trace),
        Dtype::I64 => sortfile_typed::<i64>(f, &ext, &input, &output, trace),
        Dtype::Kv => sortfile_typed::<Kv>(f, &ext, &input, &output, trace),
        Dtype::Kv64 => sortfile_typed::<Kv64>(f, &ext, &input, &output, trace),
        Dtype::F32 => sortfile_typed::<F32Key>(f, &ext, &input, &output, trace),
    }
}

fn sortfile_typed<T: GenRecord>(
    f: &HashMap<String, String>,
    ext: &ExternalConfig,
    input: &std::path::Path,
    output: &std::path::Path,
    trace: Option<&std::path::Path>,
) -> Result<(), String> {
    if let Some(n) = f.get("gen") {
        let n: usize = n.parse().map_err(|_| "--gen must be an integer".to_string())?;
        let dist = dist_of(f)?;
        let mut rng = Rng::new(get_usize(f, "seed", 42)? as u64);
        let mut w = external::RawWriter::<T>::create(input).map_err(|e| format!("{e:#}"))?;
        let mut written = 0usize;
        while written < n {
            let take = (n - written).min(1 << 20);
            let block = T::gen_block(&mut rng, take, dist, written as u64);
            w.write_block(&block).map_err(|e| format!("{e:#}"))?;
            written += take;
        }
        w.finish().map_err(|e| format!("{e:#}"))?;
        println!(
            "generated {} {} ({}) into {}",
            n,
            T::DTYPE.name(),
            dist.name(),
            input.display()
        );
    }

    let t = Instant::now();
    let stats = match trace {
        None => external::sort_file::<T>(input, output, ext).map_err(|e| format!("{e:#}"))?,
        Some(trace_path) => {
            let handle = flims::obs::Trace::enabled();
            let stats = external::sort_file_traced::<T>(input, output, ext, &handle)
                .map_err(|e| format!("{e:#}"))?;
            flims::obs::chrome::write_file(&handle, trace_path)
                .map_err(|e| format!("writing trace {}: {e}", trace_path.display()))?;
            stats
        }
    };
    let dt = t.elapsed();

    // Streaming verification — never loads the dataset whole.
    let mut r = external::RawReader::<T>::open(output).map_err(|e| format!("{e:#}"))?;
    let mut buf: Vec<T> = Vec::new();
    let mut prev: Option<T::K> = None;
    loop {
        buf.clear();
        if r.read_block(&mut buf, 1 << 16).map_err(|e| format!("{e:#}"))? == 0 {
            break;
        }
        if !is_sorted_desc(&buf) || prev.is_some_and(|p| buf[0].key() > p) {
            return Err("output is not sorted!".into());
        }
        prev = buf.last().map(|x| x.key());
    }

    let mb = |bytes: u64| bytes as f64 / (1 << 20) as f64;
    println!(
        "externally sorted {} {} ({:.1} MB) in {:?} — {:.1} M elem/s ({} threads, prefetch {})",
        stats.elements,
        T::DTYPE.name(),
        mb(stats.elements * T::WIRE_BYTES as u64),
        dt,
        stats.elements as f64 / dt.as_secs_f64() / 1e6,
        ext.effective_threads(),
        ext.prefetch_blocks,
    );
    println!(
        "  budget {:.1} MB | {} runs spilled ({:.1} MB written, peak {:.1} MB live) | {} merge passes → {}",
        mb(ext.mem_budget_bytes as u64),
        stats.runs_spilled,
        mb(stats.bytes_spilled),
        mb(stats.peak_spill_bytes),
        stats.merge_passes,
        output.display()
    );
    println!(
        "  codec {} | spilled {:.1} MB encoded vs {:.1} MB raw ({:.2}x) | encode {:.1} ms / decode {:.1} ms",
        ext.codec_for(T::DTYPE).name(),
        mb(stats.bytes_spilled),
        mb(stats.bytes_spilled_raw),
        if stats.bytes_spilled > 0 {
            stats.bytes_spilled_raw as f64 / stats.bytes_spilled as f64
        } else {
            1.0
        },
        stats.codec_encode_us as f64 / 1000.0,
        stats.codec_decode_us as f64 / 1000.0,
    );
    // Effective kernel: the tier this dtype's merges actually ran on,
    // which may sit below the CPU-wide resolved ceiling.
    println!(
        "  schedule {} | kernel {} | phase1 {:.1} ms | phase2 {:.1} ms | wall {:.1} ms | overlapped {:.1} ms",
        if ext.overlap { "pipelined" } else { "serial" },
        T::DTYPE.effective_kernel(ext.kernel),
        stats.phase1_us as f64 / 1000.0,
        stats.phase2_us as f64 / 1000.0,
        stats.wall_us as f64 / 1000.0,
        stats.overlap_us as f64 / 1000.0,
    );
    println!(
        "  prefetch {} hits / {} misses",
        stats.prefetch_hits, stats.prefetch_misses,
    );
    if let Some(trace_path) = trace {
        println!(
            "  trace → {} (load in chrome://tracing or https://ui.perfetto.dev)",
            trace_path.display()
        );
    }
    Ok(())
}

fn cmd_trace() -> Result<(), String> {
    // The paper's Table 1 inputs (descending).
    let a: Vec<u32> = vec![29, 26, 26, 17, 16, 11, 5, 4, 3, 3];
    let b: Vec<u32> = vec![22, 21, 19, 18, 15, 12, 9, 8, 7, 0];
    println!("FLiMS execution trace (paper Table 1, w=4)");
    println!("A = {a:?}");
    println!("B = {b:?}\n");
    let (out, trace) = FlimsMerger::new(&a, &b, 4, Variant::Basic).run_traced();
    print!("{}", trace.render());
    println!("\nmerged: {out:?}");
    Ok(())
}

fn parse_design(s: &str) -> Result<Design, String> {
    Ok(match s.to_lowercase().as_str() {
        "flims" => Design::Flims,
        "flimsj" => Design::Flimsj,
        "wms" => Design::Wms,
        "ehms" => Design::Ehms,
        "mms" => Design::Mms,
        "vms" => Design::Vms,
        "pmt" => Design::Pmt,
        "basic" => Design::Basic,
        other => return Err(format!("unknown design '{other}'")),
    })
}

fn cmd_simulate(f: &HashMap<String, String>) -> Result<(), String> {
    let w = get_usize(f, "w", 8)?;
    let n = get_usize(f, "n", 1 << 14)?;
    let design = parse_design(f.get("design").map(|s| s.as_str()).unwrap_or("flims"))?;
    let skew = f.contains_key("skew");
    let dup = f.contains_key("dup");
    let mut rng = Rng::new(3);
    let dist = if dup { Distribution::DupHeavy { alphabet: 2 } } else { Distribution::Uniform };
    let mut a = gen_u32(&mut rng, n, dist);
    let mut b = gen_u32(&mut rng, n, dist);
    a.sort_unstable_by(|x, y| y.cmp(x));
    b.sort_unstable_by(|x, y| y.cmp(x));

    let sim = SimConfig { fifo_depth: 4, bw_a: w / 2, bw_b: w / 2, ..Default::default() };
    let result = match design {
        Design::Flims => {
            let mut m: hw::FlimsCycle<u32> = hw::FlimsCycle::new(w, skew);
            hw::run_stream(&mut m, &a, &b, sim)
        }
        Design::Flimsj => {
            let mut m: hw::FlimsjCycle<u32> = hw::FlimsjCycle::new(w);
            hw::run_stream(&mut m, &a, &b, sim)
        }
        Design::Wms => {
            let mut m: hw::RowMergerCycle<u32> = hw::RowMergerCycle::new(w, hw::RowClass::Wms);
            hw::run_stream(&mut m, &a, &b, sim)
        }
        Design::Mms => {
            let mut m: hw::RowMergerCycle<u32> = hw::RowMergerCycle::new(w, hw::RowClass::Mms);
            hw::run_stream(&mut m, &a, &b, sim)
        }
        Design::Vms => {
            let mut m: hw::RowMergerCycle<u32> = hw::RowMergerCycle::new(w, hw::RowClass::Vms);
            hw::run_stream(&mut m, &a, &b, sim)
        }
        Design::Basic => {
            let mut m: hw::BasicCycle<u32> = hw::BasicCycle::new(w);
            hw::run_stream(&mut m, &a, &b, sim)
        }
        other => return Err(format!("no cycle model for {} (structural only)", other.name())),
    };
    let mut expect: Vec<u32> = a.iter().chain(b.iter()).copied().collect();
    expect.sort_unstable_by(|x, y| y.cmp(x));
    println!(
        "design={} w={} n=2x{} dist={} skew={}",
        design.name(),
        w,
        n,
        if dup { "dup" } else { "uniform" },
        skew
    );
    println!(
        "cycles={} stalls={} throughput={:.3} elem/cycle correct={}",
        result.cycles,
        result.stall_cycles,
        result.throughput,
        result.output == expect
    );
    Ok(())
}

fn cmd_report(args: &[String], f: &HashMap<String, String>) -> Result<(), String> {
    let which = args
        .iter()
        .find(|a| !a.starts_with("--") && !matches!(a.as_str(), "64" | "32"))
        .map(|s| s.as_str())
        .unwrap_or("table2");
    let bits = get_usize(f, "data-bits", 64)?;
    let ws = [4usize, 8, 16, 32, 64, 128, 256, 512];
    match which {
        "table2" => {
            println!("Table 2: high-throughput 2-way merger comparison (w=16 shown; formulas hold for all w)");
            println!(
                "{:<8} {:>9} {:>8} {:>12}  {:<38} {:<9} {}",
                "design", "feedback", "latency", "comparators", "modules", "topology", "tie-record"
            );
            for d in hw::ALL_DESIGNS {
                println!(
                    "{:<8} {:>9} {:>8} {:>12}  {:<38} {:<9} {}",
                    d.name(),
                    d.feedback_len(16),
                    d.latency(16),
                    d.comparators(16),
                    d.modules(),
                    d.topology(),
                    if d.tie_record_unsafe() { "yes" } else { "no" }
                );
            }
        }
        "table3" => {
            println!("Table 3: estimated resources as AXI peripherals ({bits}-bit)");
            println!("{:<6} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10} {:>10}",
                "w", "FLiMS kL", "kFF", "FLiMSj kL", "kFF", "WMS kL", "kFF", "EHMS kL", "kFF");
            for w in ws {
                let r = |d| hw::estimate(&hw::netlist(d, w, bits));
                let (f_, j, wm, eh) = (
                    r(Design::Flims),
                    r(Design::Flimsj),
                    r(Design::Wms),
                    r(Design::Ehms),
                );
                println!(
                    "{:<6} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>10.1}",
                    w, f_.kluts(), f_.kffs(), j.kluts(), j.kffs(), wm.kluts(), wm.kffs(),
                    eh.kluts(), eh.kffs()
                );
            }
        }
        "fig13" => {
            println!("Fig 13: estimated maximal operating frequency (MHz, {bits}-bit)");
            println!("{:<6} {:>8} {:>8} {:>8} {:>8}", "w", "FLiMS", "FLiMSj", "WMS", "EHMS");
            for w in ws {
                println!(
                    "{:<6} {:>8.0} {:>8.0} {:>8.0} {:>8.0}",
                    w,
                    hw::fmax_mhz(Design::Flims, w, bits),
                    hw::fmax_mhz(Design::Flimsj, w, bits),
                    hw::fmax_mhz(Design::Wms, w, bits),
                    hw::fmax_mhz(Design::Ehms, w, bits),
                );
            }
        }
        other => return Err(format!("unknown report '{other}' (table2|table3|fig13)")),
    }
    Ok(())
}

fn cmd_serve(f: &HashMap<String, String>) -> Result<(), String> {
    let cfg = load_config(f)?;
    // Crash recovery before the first request: sweep orphaned spill
    // directories and half-written runs a previous crashed/killed
    // server left behind, so stale `job-<id>` tmp dirs never eat the
    // disk budget of the new process.
    let swept = external::spill::recover_stale_spills(cfg.external.tmp_dir.as_deref());
    if !swept.is_empty() {
        eprintln!("crash recovery: removed {} stale spill path(s)", swept.len());
    }
    let runtime = match RuntimeHandle::load(std::path::Path::new(&cfg.artifacts_dir)) {
        Ok(rt) => {
            eprintln!(
                "pjrt runtime loaded ({} artifacts)",
                rt.specs().map(|s| s.len()).unwrap_or(0)
            );
            Some(rt)
        }
        Err(e) => {
            eprintln!("pjrt runtime unavailable ({e:#}); serving native only");
            None
        }
    };
    let router = Arc::new(Router::new(cfg.clone(), runtime));
    let service = Arc::new(Service::new(
        router,
        BatcherConfig {
            max_batch: cfg.batch_max,
            window: std::time::Duration::from_micros(cfg.batch_window_us),
        },
    ));
    service.serve(&cfg.bind).map_err(|e| format!("{e:#}"))
}

/// `flims metrics` — fetch the Prometheus text exposition from a
/// running `flims serve` over the line protocol's `metrics` verb and
/// print it (scrape-by-hand, or pipe into a pushgateway). Reads until
/// the `# EOF` terminator the server appends.
fn cmd_metrics(f: &HashMap<String, String>) -> Result<(), String> {
    use std::io::{BufRead, BufReader, Write};
    let cfg = load_config(f)?;
    let addr = f.get("addr").cloned().unwrap_or_else(|| cfg.bind.clone());
    let stream = std::net::TcpStream::connect(&addr)
        .map_err(|e| format!("connecting to {addr}: {e} (is `flims serve` running?)"))?;
    let mut writer = stream.try_clone().map_err(|e| format!("{e}"))?;
    writeln!(writer, "metrics").map_err(|e| format!("{e}"))?;
    let reader = BufReader::new(stream);
    let mut saw_eof = false;
    for line in reader.lines() {
        let line = line.map_err(|e| format!("{e}"))?;
        println!("{line}");
        if line == "# EOF" {
            saw_eof = true;
            break;
        }
    }
    if !saw_eof {
        return Err("connection closed before the # EOF terminator".into());
    }
    Ok(())
}

/// `flims jobs` — query a running `flims serve`'s job table over the
/// line protocol: the `jobs` summary by default, one job's `status`
/// line with `--status <id>`, or trip a job's cancel token with
/// `--cancel <id>`. Prints the server's one-line reply; an `err`
/// reply becomes a nonzero exit.
fn cmd_jobs(f: &HashMap<String, String>) -> Result<(), String> {
    use std::io::{BufRead, BufReader, Write};
    let cfg = load_config(f)?;
    let addr = f.get("addr").cloned().unwrap_or_else(|| cfg.bind.clone());
    let req = if let Some(id) = f.get("status") {
        format!("status {id}")
    } else if let Some(id) = f.get("cancel") {
        format!("cancel {id}")
    } else {
        "jobs".to_string()
    };
    let stream = std::net::TcpStream::connect(&addr)
        .map_err(|e| format!("connecting to {addr}: {e} (is `flims serve` running?)"))?;
    let mut writer = stream.try_clone().map_err(|e| format!("{e}"))?;
    writeln!(writer, "{req}").map_err(|e| format!("{e}"))?;
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| format!("{e}"))?;
    let line = line.trim();
    if line.is_empty() {
        return Err("connection closed before a reply".into());
    }
    println!("{line}");
    if let Some(msg) = line.strip_prefix("err ") {
        return Err(msg.to_string());
    }
    Ok(())
}

fn cmd_artifacts(f: &HashMap<String, String>) -> Result<(), String> {
    let cfg = load_config(f)?;
    let rt = RuntimeHandle::load(std::path::Path::new(&cfg.artifacts_dir))
        .map_err(|e| format!("{e:#}"))?;
    println!("platform: {}", rt.platform().map_err(|e| format!("{e:#}"))?);
    for spec in rt.specs().map_err(|e| format!("{e:#}"))? {
        println!(
            "{:<28} kind={:?} n={} w={} chunk={} batch={}",
            spec.name, spec.kind, spec.n, spec.w, spec.chunk, spec.batch
        );
    }
    // Smoke-run the smallest sort artifact.
    let mut rng = Rng::new(1);
    let data: Vec<f32> = (0..1000).map(|_| rng.f64() as f32).collect();
    let t = Instant::now();
    let out = rt.sort_padded(data).map_err(|e| format!("{e:#}"))?;
    let ok = out.windows(2).all(|p| p[0] >= p[1]);
    println!("smoke sort: 1000 f32 in {:?}, sorted={ok}", t.elapsed());
    Ok(())
}
