//! FLR3 block kernels: FastLanes-style frame-of-reference bitpacking
//! over 1024-key blocks in an 8-lane transposed order.
//!
//! The FLR2 delta+varint codec decodes one byte at a time — an
//! inherently serial loop that caps compressed spill reads well below
//! memory bandwidth. FLR3 trades a little compression ratio for a
//! branch-free layout: every block holds up to [`FLR3_BLOCK`] keys,
//! stores the block minimum (`base`) once, subtracts it from every key
//! (frame of reference), and bitpacks the deltas to the block's maximum
//! delta width `W`. Keys are laid out in the FastLanes "unified
//! transposed order": key `i` lives in lane `FL_ORDER[i % 8]` at slot
//! `i / 8`, so the 8 lanes advance in lockstep and both pack and unpack
//! are the *same* shift/mask arithmetic in every lane — one scalar loop
//! the compiler can vectorise, and explicit SSE2/AVX2/NEON tiers that
//! are arithmetically identical to it, dispatched on the same
//! [`MergeKernel`] knob as the merge kernels (see `docs/KERNELS.md`).
//!
//! ## Packed layout
//!
//! Within a block of width `W` (1..=64), lane `l` owns the 128 deltas
//! at slots `s = 0..128`; delta `(l, s)` occupies bits
//! `[s*W, (s+1)*W)` of lane `l`'s little-endian bitstream, which is
//! exactly `128*W` bits = `2*W` words long. The 16 lanes'-worth of
//! words are interleaved word-major: packed word `j` of lane `l` is
//! `words[j*8 + l]`, so for any slot the word index and bit offset are
//! the same in all 8 lanes and the 8 words involved are contiguous —
//! the shape every SIMD tier wants. `W = 0` (all keys equal `base`)
//! stores no words at all.
//!
//! A delta can straddle two words. With `bit = s*W`, `wj = bit/64`,
//! `off = bit%64`, unpack is
//!
//! ```text
//! v = ((words[wj] >> off) | ((words[wj+1] << 1) << (63 - off))) & mask
//! ```
//!
//! The double shift `(<<1, <<63-off)` keeps every shift count in
//! 0..=63 (shifting by `64 - off` would be undefined at `off = 0`),
//! and the word index `wj + 1` is clamped to the last word of the lane:
//! whenever the clamp engages, `off + W <= 64` so the second term is
//! masked away entirely, and the clamped read stays in bounds. Pack is
//! the mirror image with `|=` stores. Byte order on disk is the words
//! in index order, each little-endian — see `docs/FORMATS.md` for the
//! framing around them.

use crate::flims::simd::MergeKernel;

/// Keys per FLR3 block. Partial blocks (tail of a writer batch) are
/// zero-padded to this length before packing.
pub const FLR3_BLOCK: usize = 1024;

/// SIMD lanes in the transposed order.
pub const FLR3_LANES: usize = 8;

/// Slots per lane: `FLR3_BLOCK / FLR3_LANES`.
pub const FLR3_LANE_SLOTS: usize = FLR3_BLOCK / FLR3_LANES;

/// Bytes of the per-block header: `u32 n | u8 width | [0u8; 3] | u64
/// base`, all little-endian.
pub const FLR3_BLOCK_HEADER_BYTES: usize = 16;

/// The FastLanes 8-lane transposed order (the 04261537 order): key
/// `i` goes to lane `FL_ORDER[i % 8]`. The permutation is its own
/// inverse, so the un-transpose uses the same table.
pub const FL_ORDER: [usize; 8] = [0, 4, 2, 6, 1, 5, 3, 7];

/// Packed `u64` words a block of this delta width stores on disk.
#[inline]
pub fn packed_words(width: usize) -> usize {
    // 128 slots of `width` bits per lane = 2*width words, times 8 lanes.
    2 * width * FLR3_LANES
}

/// Packed bytes a block of this delta width stores on disk.
#[inline]
pub fn packed_bytes(width: usize) -> usize {
    packed_words(width) * 8
}

/// The low-`width` bitmask (`width` in 0..=64).
#[inline]
pub fn mask_for(width: usize) -> u64 {
    if width >= 64 {
        u64::MAX
    } else {
        (1u64 << width) - 1
    }
}

// ---------------------------------------------------------------------
// Block encode / decode (header + transpose around the pack kernels).
// ---------------------------------------------------------------------

/// Append the FLR3 block encoding of `keys` (already mapped to the
/// order-preserving `key_bits` domain) to `out`: one 16-byte header
/// plus `packed_bytes(width)` packed words per `FLR3_BLOCK`-key chunk.
pub fn encode_blocks(keys: &[u64], kernel: MergeKernel, out: &mut Vec<u8>) {
    let mut tr = [0u64; FLR3_BLOCK];
    let mut words: Vec<u64> = Vec::new();
    for block in keys.chunks(FLR3_BLOCK) {
        let base = block.iter().copied().min().unwrap_or(0);
        let maxd = block.iter().map(|&k| k - base).max().unwrap_or(0);
        let width = (64 - maxd.leading_zeros()) as usize;
        out.extend_from_slice(&(block.len() as u32).to_le_bytes());
        out.push(width as u8);
        out.extend_from_slice(&[0u8; 3]);
        out.extend_from_slice(&base.to_le_bytes());
        if width == 0 {
            continue;
        }
        // Transpose the deltas into lane order, zero-padding the tail.
        tr.fill(0);
        for (i, &k) in block.iter().enumerate() {
            tr[(i >> 3) * FLR3_LANES + FL_ORDER[i & 7]] = k - base;
        }
        words.clear();
        words.resize(packed_words(width), 0);
        pack(&tr, width, &mut words, kernel);
        for w in &words {
            out.extend_from_slice(&w.to_le_bytes());
        }
    }
}

/// Decode one FLR3 block back to keys in original order, appending the
/// first `n` to `out`. `words` must hold `packed_words(width)` words
/// (empty for `width == 0`); `mask` is the dtype's key mask
/// (`mask_for(8 * KEY_BYTES)`). Framing validation is the caller's job
/// — this is pure arithmetic and cannot fail.
pub fn decode_block(
    words: &[u64],
    n: usize,
    width: usize,
    base: u64,
    mask: u64,
    kernel: MergeKernel,
    out: &mut Vec<u64>,
) {
    debug_assert!(n <= FLR3_BLOCK);
    debug_assert!(width <= 64);
    let mut tr = [0u64; FLR3_BLOCK];
    if width > 0 {
        unpack(words, width, &mut tr, kernel);
    }
    out.reserve(n);
    for i in 0..n {
        let d = tr[(i >> 3) * FLR3_LANES + FL_ORDER[i & 7]];
        out.push(base.wrapping_add(d) & mask);
    }
}

// ---------------------------------------------------------------------
// Pack / unpack dispatch.
// ---------------------------------------------------------------------

/// Bitpack the transposed deltas `tr` at `width` into `words`
/// (`packed_words(width)` long, pre-zeroed). `width` must be 1..=64
/// and every delta must fit in `width` bits.
pub fn pack(tr: &[u64; FLR3_BLOCK], width: usize, words: &mut [u64], kernel: MergeKernel) {
    debug_assert!((1..=64).contains(&width));
    debug_assert_eq!(words.len(), packed_words(width));
    #[cfg(target_arch = "x86_64")]
    if kernel.wants_simd() {
        if have_avx2() {
            unsafe { pack_avx2(tr, width, words) };
        } else {
            unsafe { pack_sse2(tr, width, words) };
        }
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if kernel.wants_simd() {
        unsafe { pack_neon(tr, width, words) };
        return;
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    let _ = kernel;
    pack_scalar(tr, width, words);
}

/// Unpack `words` at `width` back into the transposed delta buffer
/// `tr`. The inverse of [`pack`]; every tier produces identical bytes.
pub fn unpack(words: &[u64], width: usize, tr: &mut [u64; FLR3_BLOCK], kernel: MergeKernel) {
    debug_assert!((1..=64).contains(&width));
    debug_assert_eq!(words.len(), packed_words(width));
    #[cfg(target_arch = "x86_64")]
    if kernel.wants_simd() {
        if have_avx2() {
            unsafe { unpack_avx2(words, width, tr) };
        } else {
            unsafe { unpack_sse2(words, width, tr) };
        }
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if kernel.wants_simd() {
        unsafe { unpack_neon(words, width, tr) };
        return;
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    let _ = kernel;
    unpack_scalar(words, width, tr);
}

// ---------------------------------------------------------------------
// Scalar reference tier. The 8-lane inner loops read/write contiguous
// words, so the compiler auto-vectorises them; the explicit tiers below
// perform bit-for-bit the same arithmetic.
// ---------------------------------------------------------------------

fn pack_scalar(tr: &[u64; FLR3_BLOCK], width: usize, words: &mut [u64]) {
    let last = 2 * width - 1;
    for s in 0..FLR3_LANE_SLOTS {
        let bit = s * width;
        let wj = bit >> 6;
        let off = (bit & 63) as u32;
        let wj1 = (wj + 1).min(last);
        for l in 0..FLR3_LANES {
            let v = tr[s * FLR3_LANES + l];
            words[wj * FLR3_LANES + l] |= v << off;
            words[wj1 * FLR3_LANES + l] |= (v >> 1) >> (63 - off);
        }
    }
}

fn unpack_scalar(words: &[u64], width: usize, tr: &mut [u64; FLR3_BLOCK]) {
    let mask = mask_for(width);
    let last = 2 * width - 1;
    for s in 0..FLR3_LANE_SLOTS {
        let bit = s * width;
        let wj = bit >> 6;
        let off = (bit & 63) as u32;
        let wj1 = (wj + 1).min(last);
        for l in 0..FLR3_LANES {
            let lo = words[wj * FLR3_LANES + l] >> off;
            let hi = (words[wj1 * FLR3_LANES + l] << 1) << (63 - off);
            tr[s * FLR3_LANES + l] = (lo | hi) & mask;
        }
    }
}

// ---------------------------------------------------------------------
// x86_64 tiers: SSE2 baseline (part of the ABI, no detection), AVX2
// runtime-detected once and cached. `_mm_sll_epi64`/`_mm_srl_epi64`
// take the shift count from a vector, so the per-slot counts stay out
// of the instruction stream.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
fn have_avx2() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    static CACHE: AtomicU8 = AtomicU8::new(0);
    match CACHE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => {
            let v = is_x86_feature_detected!("avx2");
            CACHE.store(if v { 2 } else { 1 }, Ordering::Relaxed);
            v
        }
    }
}

#[cfg(target_arch = "x86_64")]
unsafe fn pack_sse2(tr: &[u64; FLR3_BLOCK], width: usize, words: &mut [u64]) {
    use core::arch::x86_64::*;
    let last = 2 * width - 1;
    for s in 0..FLR3_LANE_SLOTS {
        let bit = s * width;
        let wj = bit >> 6;
        let off = (bit & 63) as i32;
        let wj1 = (wj + 1).min(last);
        let shl = _mm_cvtsi32_si128(off);
        let shr = _mm_cvtsi32_si128(63 - off);
        for h in 0..4 {
            let v = _mm_loadu_si128(tr.as_ptr().add(s * 8 + h * 2) as *const __m128i);
            let lo_p = words.as_mut_ptr().add(wj * 8 + h * 2) as *mut __m128i;
            let lo = _mm_loadu_si128(lo_p as *const __m128i);
            _mm_storeu_si128(lo_p, _mm_or_si128(lo, _mm_sll_epi64(v, shl)));
            let hi_p = words.as_mut_ptr().add(wj1 * 8 + h * 2) as *mut __m128i;
            let hi = _mm_loadu_si128(hi_p as *const __m128i);
            let carry = _mm_srl_epi64(_mm_srli_epi64::<1>(v), shr);
            _mm_storeu_si128(hi_p, _mm_or_si128(hi, carry));
        }
    }
}

#[cfg(target_arch = "x86_64")]
unsafe fn unpack_sse2(words: &[u64], width: usize, tr: &mut [u64; FLR3_BLOCK]) {
    use core::arch::x86_64::*;
    let mask = _mm_set1_epi64x(mask_for(width) as i64);
    let last = 2 * width - 1;
    for s in 0..FLR3_LANE_SLOTS {
        let bit = s * width;
        let wj = bit >> 6;
        let off = (bit & 63) as i32;
        let wj1 = (wj + 1).min(last);
        let shr = _mm_cvtsi32_si128(off);
        let shl = _mm_cvtsi32_si128(63 - off);
        for h in 0..4 {
            let w0 = _mm_loadu_si128(words.as_ptr().add(wj * 8 + h * 2) as *const __m128i);
            let w1 = _mm_loadu_si128(words.as_ptr().add(wj1 * 8 + h * 2) as *const __m128i);
            let lo = _mm_srl_epi64(w0, shr);
            let hi = _mm_sll_epi64(_mm_slli_epi64::<1>(w1), shl);
            let v = _mm_and_si128(_mm_or_si128(lo, hi), mask);
            _mm_storeu_si128(tr.as_mut_ptr().add(s * 8 + h * 2) as *mut __m128i, v);
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn pack_avx2(tr: &[u64; FLR3_BLOCK], width: usize, words: &mut [u64]) {
    use core::arch::x86_64::*;
    let last = 2 * width - 1;
    for s in 0..FLR3_LANE_SLOTS {
        let bit = s * width;
        let wj = bit >> 6;
        let off = (bit & 63) as i32;
        let wj1 = (wj + 1).min(last);
        let shl = _mm_cvtsi32_si128(off);
        let shr = _mm_cvtsi32_si128(63 - off);
        for h in 0..2 {
            let v = _mm256_loadu_si256(tr.as_ptr().add(s * 8 + h * 4) as *const __m256i);
            let lo_p = words.as_mut_ptr().add(wj * 8 + h * 4) as *mut __m256i;
            let lo = _mm256_loadu_si256(lo_p as *const __m256i);
            _mm256_storeu_si256(lo_p, _mm256_or_si256(lo, _mm256_sll_epi64(v, shl)));
            let hi_p = words.as_mut_ptr().add(wj1 * 8 + h * 4) as *mut __m256i;
            let hi = _mm256_loadu_si256(hi_p as *const __m256i);
            let carry = _mm256_srl_epi64(_mm256_srli_epi64::<1>(v), shr);
            _mm256_storeu_si256(hi_p, _mm256_or_si256(hi, carry));
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn unpack_avx2(words: &[u64], width: usize, tr: &mut [u64; FLR3_BLOCK]) {
    use core::arch::x86_64::*;
    let mask = _mm256_set1_epi64x(mask_for(width) as i64);
    let last = 2 * width - 1;
    for s in 0..FLR3_LANE_SLOTS {
        let bit = s * width;
        let wj = bit >> 6;
        let off = (bit & 63) as i32;
        let wj1 = (wj + 1).min(last);
        let shr = _mm_cvtsi32_si128(off);
        let shl = _mm_cvtsi32_si128(63 - off);
        for h in 0..2 {
            let w0 = _mm256_loadu_si256(words.as_ptr().add(wj * 8 + h * 4) as *const __m256i);
            let w1 = _mm256_loadu_si256(words.as_ptr().add(wj1 * 8 + h * 4) as *const __m256i);
            let lo = _mm256_srl_epi64(w0, shr);
            let hi = _mm256_sll_epi64(_mm256_slli_epi64::<1>(w1), shl);
            let v = _mm256_and_si256(_mm256_or_si256(lo, hi), mask);
            _mm256_storeu_si256(tr.as_mut_ptr().add(s * 8 + h * 4) as *mut __m256i, v);
        }
    }
}

// ---------------------------------------------------------------------
// aarch64 NEON tier. `vshlq_u64` shifts left for positive counts and
// (logically) right for negative ones, so both directions use it.
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
unsafe fn pack_neon(tr: &[u64; FLR3_BLOCK], width: usize, words: &mut [u64]) {
    use core::arch::aarch64::*;
    let last = 2 * width - 1;
    for s in 0..FLR3_LANE_SLOTS {
        let bit = s * width;
        let wj = bit >> 6;
        let off = (bit & 63) as i64;
        let wj1 = (wj + 1).min(last);
        let shl = vdupq_n_s64(off);
        let shr = vdupq_n_s64(-(63 - off));
        let one_r = vdupq_n_s64(-1);
        for h in 0..4 {
            let v = vld1q_u64(tr.as_ptr().add(s * 8 + h * 2));
            let lo_p = words.as_mut_ptr().add(wj * 8 + h * 2);
            let lo = vld1q_u64(lo_p as *const u64);
            vst1q_u64(lo_p, vorrq_u64(lo, vshlq_u64(v, shl)));
            let hi_p = words.as_mut_ptr().add(wj1 * 8 + h * 2);
            let hi = vld1q_u64(hi_p as *const u64);
            let carry = vshlq_u64(vshlq_u64(v, one_r), shr);
            vst1q_u64(hi_p, vorrq_u64(hi, carry));
        }
    }
}

#[cfg(target_arch = "aarch64")]
unsafe fn unpack_neon(words: &[u64], width: usize, tr: &mut [u64; FLR3_BLOCK]) {
    use core::arch::aarch64::*;
    let mask = vdupq_n_u64(mask_for(width));
    let last = 2 * width - 1;
    for s in 0..FLR3_LANE_SLOTS {
        let bit = s * width;
        let wj = bit >> 6;
        let off = (bit & 63) as i64;
        let wj1 = (wj + 1).min(last);
        let shr = vdupq_n_s64(-off);
        let shl = vdupq_n_s64(63 - off);
        let one_l = vdupq_n_s64(1);
        for h in 0..4 {
            let w0 = vld1q_u64(words.as_ptr().add(wj * 8 + h * 2));
            let w1 = vld1q_u64(words.as_ptr().add(wj1 * 8 + h * 2));
            let lo = vshlq_u64(w0, shr);
            let hi = vshlq_u64(vshlq_u64(w1, one_l), shl);
            let v = vandq_u64(vorrq_u64(lo, hi), mask);
            vst1q_u64(tr.as_mut_ptr().add(s * 8 + h * 2), v);
        }
    }
}

// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fl_order_is_its_own_inverse() {
        for r in 0..FLR3_LANES {
            assert_eq!(FL_ORDER[FL_ORDER[r]], r);
        }
        let mut seen = [false; FLR3_LANES];
        for &l in &FL_ORDER {
            assert!(!seen[l], "FL_ORDER is not a permutation");
            seen[l] = true;
        }
    }

    #[test]
    fn packed_words_fill_exactly() {
        for width in 1..=64usize {
            assert_eq!(packed_words(width), 16 * width);
            assert_eq!(packed_words(width) * 64, FLR3_BLOCK * width);
            assert_eq!(packed_bytes(width), 128 * width);
        }
    }

    fn random_deltas(width: usize, rng: &mut Rng) -> [u64; FLR3_BLOCK] {
        let mask = mask_for(width);
        let mut tr = [0u64; FLR3_BLOCK];
        for d in tr.iter_mut() {
            *d = rng.next_u64() & mask;
        }
        // Force at least one delta to use the top bit, so `width` really
        // is the block's max width.
        tr[FLR3_BLOCK / 2] |= 1u64 << (width - 1);
        tr
    }

    #[test]
    fn pack_unpack_roundtrip_every_width_scalar() {
        let mut rng = Rng::new(0xf13a);
        for width in 1..=64usize {
            let tr = random_deltas(width, &mut rng);
            let mut words = vec![0u64; packed_words(width)];
            pack(&tr, width, &mut words, MergeKernel::Scalar);
            let mut back = [0u64; FLR3_BLOCK];
            unpack(&words, width, &mut back, MergeKernel::Scalar);
            assert_eq!(tr[..], back[..], "scalar roundtrip failed at width {width}");
        }
    }

    #[test]
    fn simd_tiers_match_scalar_bit_for_bit() {
        let mut rng = Rng::new(0xf13b);
        for width in 1..=64usize {
            let tr = random_deltas(width, &mut rng);
            let mut w_scalar = vec![0u64; packed_words(width)];
            let mut w_auto = vec![0u64; packed_words(width)];
            pack(&tr, width, &mut w_scalar, MergeKernel::Scalar);
            pack(&tr, width, &mut w_auto, MergeKernel::Auto);
            assert_eq!(w_scalar, w_auto, "pack tiers diverge at width {width}");
            let mut t_scalar = [0u64; FLR3_BLOCK];
            let mut t_auto = [0u64; FLR3_BLOCK];
            unpack(&w_scalar, width, &mut t_scalar, MergeKernel::Scalar);
            unpack(&w_scalar, width, &mut t_auto, MergeKernel::Auto);
            assert_eq!(
                t_scalar[..],
                t_auto[..],
                "unpack tiers diverge at width {width}"
            );
            assert_eq!(t_scalar[..], tr[..]);
        }
    }

    /// Parse the byte stream `encode_blocks` produced and decode every
    /// block — the same walk `RunReader` does, minus the framing errors.
    fn decode_stream(bytes: &[u8], kernel: MergeKernel) -> Vec<u64> {
        let mut out = Vec::new();
        let mut at = 0;
        while at < bytes.len() {
            let n = u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()) as usize;
            let width = bytes[at + 4] as usize;
            assert_eq!(&bytes[at + 5..at + 8], &[0u8; 3], "pad bytes must be zero");
            let base = u64::from_le_bytes(bytes[at + 8..at + 16].try_into().unwrap());
            at += FLR3_BLOCK_HEADER_BYTES;
            let words: Vec<u64> = (0..packed_words(width))
                .map(|j| {
                    let p = at + j * 8;
                    u64::from_le_bytes(bytes[p..p + 8].try_into().unwrap())
                })
                .collect();
            at += packed_bytes(width);
            decode_block(&words, n, width, base, u64::MAX, kernel, &mut out);
        }
        assert_eq!(at, bytes.len());
        out
    }

    #[test]
    fn encode_decode_blocks_roundtrip_with_tail() {
        let mut rng = Rng::new(0xf13c);
        for &len in &[0usize, 1, 7, 1023, 1024, 1025, 3000, 4096] {
            let mut keys: Vec<u64> = (0..len).map(|_| rng.next_u64()).collect();
            keys.sort_unstable_by(|a, b| b.cmp(a)); // runs are descending
            let mut bytes = Vec::new();
            encode_blocks(&keys, MergeKernel::Auto, &mut bytes);
            assert_eq!(decode_stream(&bytes, MergeKernel::Auto), keys);
            assert_eq!(decode_stream(&bytes, MergeKernel::Scalar), keys);
        }
    }

    #[test]
    fn all_equal_block_is_header_only() {
        let keys = vec![0xdead_beefu64; 1000];
        let mut bytes = Vec::new();
        encode_blocks(&keys, MergeKernel::Auto, &mut bytes);
        assert_eq!(bytes.len(), FLR3_BLOCK_HEADER_BYTES);
        assert_eq!(decode_stream(&bytes, MergeKernel::Auto), keys);
    }

    #[test]
    fn extreme_keys_roundtrip() {
        // Max-width deltas (0 and u64::MAX in one block) and the sign
        // boundary, descending as a run would be.
        let keys = vec![u64::MAX, 1u64 << 63, (1u64 << 63) - 1, 1, 0];
        let mut bytes = Vec::new();
        encode_blocks(&keys, MergeKernel::Auto, &mut bytes);
        assert_eq!(bytes[4] as usize, 64, "max delta must pack at width 64");
        assert_eq!(decode_stream(&bytes, MergeKernel::Auto), keys);
        assert_eq!(decode_stream(&bytes, MergeKernel::Scalar), keys);
    }

    #[test]
    fn scalar_encode_matches_auto_encode_byte_for_byte() {
        let mut rng = Rng::new(0xf13d);
        let mut keys: Vec<u64> = (0..2500).map(|_| rng.next_u64() >> 20).collect();
        keys.sort_unstable_by(|a, b| b.cmp(a));
        let mut a = Vec::new();
        let mut b = Vec::new();
        encode_blocks(&keys, MergeKernel::Auto, &mut a);
        encode_blocks(&keys, MergeKernel::Scalar, &mut b);
        assert_eq!(a, b);
    }
}
