//! Run codecs: how spilled-run payload bytes are laid out on disk.
//!
//! Three codecs exist (see `docs/FORMATS.md` for the byte-level spec):
//!
//! * [`Codec::Raw`] — fixed-width little-endian records, the `FLR1`
//!   format the external sort has always spilled. Zero CPU cost, one
//!   `WIRE_BYTES` per record.
//! * [`Codec::Delta`] — the `FLR2` format: each block stores its first
//!   key full-width, then every following key as a zigzag-encoded
//!   LEB128 varint of the delta to its predecessor; payloads (for
//!   key-value dtypes) ride alongside fixed-width. Spilled runs are
//!   always sorted, so deltas are small and skewed/sorted datasets
//!   compress 2–4×, cutting the spill-disk bandwidth that dominates
//!   out-of-core sorts — the same "internalise the bandwidth" argument
//!   FLiMS makes for merge trees, applied to the spill boundary.
//! * [`Codec::Flr3`] — the `FLR3` format: FastLanes-style 1024-key
//!   blocks, frame-of-reference subtract fused with a bitpack to the
//!   block's max delta width, keys in the 8-lane transposed order so
//!   encode/decode are branch-free loops with explicit SIMD tiers
//!   riding the same `MergeKernel` dispatch as the merge kernels (see
//!   [`super::flr3`]). Slightly coarser compression than `FLR2`
//!   (per-block width, not per-key), but decode runs at memory
//!   bandwidth instead of one varint byte per iteration.
//!
//! The codec is chosen per sort via `[external] codec` (CLI
//! `--codec`, protocol `codec=<c>`) — [`parse_codec_arg`] is the one
//! parser all three entry points share — with a dtype-aware fallback:
//! `f32` keys have no integer delta domain that is worth encoding, so
//! [`Codec::effective_for`] silently drops them back to `Raw`, and the
//! keys-only FLR3 block layout can't carry `kv`/`kv64` payloads, so
//! those fall back to `Delta`.
//!
//! Encoding runs on the spill writer's double-buffer thread
//! ([`DoubleBufWriter`](super::stream::DoubleBufWriter)) and decoding
//! on the leaf prefetch threads
//! ([`PrefetchStream`](super::stream::PrefetchStream)), so codec CPU
//! overlaps the merge instead of stalling it.

use anyhow::{bail, Result};

use super::format::{Dtype, ExtItem};

/// Maximum records per encoded delta block. Bounds the decode buffer a
/// reader must hold (4096 × 16-byte `kv64` records = 64 KiB) and keeps
/// the per-block framing overhead (8 bytes) negligible.
pub const DELTA_BLOCK_MAX: usize = 4096;

/// Bytes of one delta-block frame header: `u32` record count + `u32`
/// encoded-key-section length.
pub const DELTA_FRAME_BYTES: usize = 8;

/// Longest LEB128 encoding of a `u64` (10 × 7 bits ≥ 64 bits).
pub const MAX_VARINT_BYTES: usize = 10;

/// Spill-run codec selector — the `[external] codec` knob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Codec {
    /// Fixed-width records (`FLR1`), byte-identical to what the
    /// external sort has always written.
    #[default]
    Raw,
    /// Base key + zigzag-delta LEB128 varints per block (`FLR2`),
    /// payloads fixed-width alongside.
    Delta,
    /// Frame-of-reference bitpacked 1024-key blocks in FastLanes
    /// transposed order (`FLR3`), keys only — SIMD decode on the
    /// `MergeKernel` knob.
    Flr3,
}

impl Codec {
    /// Parse a codec name (`raw` | `delta` | `flr3`).
    pub fn parse(s: &str) -> Result<Self, String> {
        Ok(match s {
            "raw" => Codec::Raw,
            "delta" => Codec::Delta,
            "flr3" => Codec::Flr3,
            other => return Err(format!("unknown codec '{other}' (expected raw|delta|flr3)")),
        })
    }

    /// The knob spelling of this codec.
    pub fn name(self) -> &'static str {
        match self {
            Codec::Raw => "raw",
            Codec::Delta => "delta",
            Codec::Flr3 => "flr3",
        }
    }

    /// The codec actually used for `dtype`: `f32` keys stay raw (their
    /// bit patterns have no delta structure worth varint-encoding), and
    /// the keys-only FLR3 block layout drops payload records (`kv`,
    /// `kv64`) back to `Delta` so they still compress. The integer key
    /// dtypes honour the request.
    pub fn effective_for(self, dtype: Dtype) -> Codec {
        match (self, dtype) {
            (Codec::Delta | Codec::Flr3, Dtype::F32) => Codec::Raw,
            (Codec::Flr3, Dtype::Kv | Dtype::Kv64) => Codec::Delta,
            (c, _) => c,
        }
    }
}

/// Parse a codec knob value the way every entry point — `[external]
/// codec` in the config file, `--codec` on the CLI, `codec=<c>` on the
/// protocol — reports it: errors are prefixed with the argument name,
/// so a typo reads `codec argument: unknown codec 'lz4' (expected
/// raw|delta|flr3)` wherever it was typed.
pub fn parse_codec_arg(s: &str) -> Result<Codec, String> {
    Codec::parse(s).map_err(|e| format!("codec argument: {e}"))
}

/// Zigzag-map a signed delta onto the unsigned varint domain
/// (0 → 0, -1 → 1, 1 → 2, -2 → 3, …) so small negatives — the common
/// case in descending runs — stay one byte.
#[inline]
pub fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag`].
#[inline]
pub fn unzigzag(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

/// Append the LEB128 encoding of `v` (7 bits per byte, high bit =
/// continuation) to `out`.
#[inline]
pub fn write_varint(mut v: u64, out: &mut Vec<u8>) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

/// Decode one LEB128 varint from `buf[*pos..]`, advancing `pos`.
/// Rejects truncated input and encodings longer than a `u64`.
#[inline]
pub fn read_varint(buf: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    let mut shift = 0u32;
    loop {
        let Some(&byte) = buf.get(*pos) else {
            bail!("truncated varint");
        };
        *pos += 1;
        if shift == 63 && byte > 1 {
            bail!("varint overflows u64");
        }
        v |= u64::from(byte & 0x7f) << shift;
        if byte & 0x80 == 0 {
            return Ok(v);
        }
        shift += 7;
        if shift > 63 {
            bail!("varint longer than 10 bytes");
        }
    }
}

/// Append the `FLR2` encoding of `xs` to `out`: one framed block per
/// [`DELTA_BLOCK_MAX`] records. Block layout (see `docs/FORMATS.md`):
///
/// ```text
/// u32 n | u32 key_bytes | key section (key_bytes) | n × PAYLOAD_BYTES
/// ```
///
/// where the key section is the first key full-width little-endian
/// followed by `n - 1` zigzag-delta varints. Deltas are computed with
/// wrapping `u64` arithmetic, so every key sequence round-trips —
/// sortedness only buys compression, never correctness.
pub fn encode_delta<T: ExtItem>(xs: &[T], out: &mut Vec<u8>) {
    let payload_bytes = T::WIRE_BYTES - T::KEY_BYTES;
    for block in xs.chunks(DELTA_BLOCK_MAX) {
        out.extend_from_slice(&(block.len() as u32).to_le_bytes());
        let len_at = out.len();
        out.extend_from_slice(&0u32.to_le_bytes()); // key_bytes, patched below
        let keys_at = out.len();

        let mut prev = block[0].key_bits();
        out.extend_from_slice(&prev.to_le_bytes()[..T::KEY_BYTES]);
        for x in &block[1..] {
            let k = x.key_bits();
            write_varint(zigzag(k.wrapping_sub(prev) as i64), out);
            prev = k;
        }
        let key_bytes = (out.len() - keys_at) as u32;
        out[len_at..len_at + 4].copy_from_slice(&key_bytes.to_le_bytes());

        if payload_bytes > 0 {
            let payload_at = out.len();
            out.resize(payload_at + block.len() * payload_bytes, 0);
            for (x, chunk) in
                block.iter().zip(out[payload_at..].chunks_exact_mut(payload_bytes))
            {
                x.encode_payload(chunk);
            }
        }
    }
}

/// Decode the key section of one delta block (`n` keys from `buf`,
/// which must be consumed exactly) into key bit patterns.
pub fn decode_delta_keys<T: ExtItem>(buf: &[u8], n: usize, keys: &mut Vec<u64>) -> Result<()> {
    if buf.len() < T::KEY_BYTES {
        bail!("key section shorter than one full-width key");
    }
    let mut first = [0u8; 8];
    first[..T::KEY_BYTES].copy_from_slice(&buf[..T::KEY_BYTES]);
    let mut prev = u64::from_le_bytes(first);
    // Keep arithmetic inside the key width (shift amount is 0 for
    // 8-byte keys, so this never overflows).
    let mask = u64::MAX >> (64 - 8 * T::KEY_BYTES as u32);
    keys.push(prev);
    let mut pos = T::KEY_BYTES;
    for _ in 1..n {
        let delta = unzigzag(read_varint(buf, &mut pos)?);
        prev = prev.wrapping_add(delta as u64) & mask;
        keys.push(prev);
    }
    if pos != buf.len() {
        bail!("key section has {} trailing bytes", buf.len() - pos);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::key::{Kv, Kv64};

    #[test]
    fn zigzag_round_trips_and_orders_small() {
        for v in [0i64, 1, -1, 2, -2, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v, "{v}");
        }
        // Small magnitudes map to small codes (the compression premise).
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
        assert!(zigzag(-63) < 127);
    }

    #[test]
    fn varint_round_trips_and_sizes() {
        let mut buf = Vec::new();
        for v in [0u64, 1, 127, 128, 16_383, 16_384, u32::MAX as u64, u64::MAX] {
            buf.clear();
            write_varint(v, &mut buf);
            assert!(buf.len() <= MAX_VARINT_BYTES);
            let mut pos = 0;
            assert_eq!(read_varint(&buf, &mut pos).unwrap(), v, "{v}");
            assert_eq!(pos, buf.len());
        }
        // One byte per value below 128.
        buf.clear();
        write_varint(127, &mut buf);
        assert_eq!(buf.len(), 1);
        write_varint(128, &mut buf);
        assert_eq!(buf.len(), 3);
    }

    #[test]
    fn varint_rejects_truncation_and_overflow() {
        assert!(read_varint(&[0x80], &mut 0).is_err());
        assert!(read_varint(&[], &mut 0).is_err());
        // 11 continuation bytes can't be a u64.
        let long = [0x80u8; 11];
        assert!(read_varint(&long, &mut 0).is_err());
        // 10 bytes whose top byte spills past bit 63.
        let mut spill = [0x80u8; 10];
        spill[9] = 0x02;
        assert!(read_varint(&spill, &mut 0).is_err());
    }

    fn round_trip_keys<T: ExtItem>(xs: &[T]) -> Vec<u64> {
        let mut bytes = Vec::new();
        encode_delta(xs, &mut bytes);
        let mut keys = Vec::new();
        let mut pos = 0;
        while pos < bytes.len() {
            let n = u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap()) as usize;
            let kb = u32::from_le_bytes(bytes[pos + 4..pos + 8].try_into().unwrap()) as usize;
            pos += DELTA_FRAME_BYTES;
            decode_delta_keys::<T>(&bytes[pos..pos + kb], n, &mut keys).unwrap();
            pos += kb + n * (T::WIRE_BYTES - T::KEY_BYTES);
        }
        keys
    }

    #[test]
    fn delta_blocks_round_trip_u32_extremes() {
        let xs = [u32::MAX, u32::MAX, 0, 1, u32::MAX - 1, 7, 7, 0];
        assert_eq!(round_trip_keys(&xs), xs.iter().map(|&x| x as u64).collect::<Vec<_>>());
    }

    #[test]
    fn delta_blocks_round_trip_u64_extremes() {
        let xs = [u64::MAX, 0, u64::MAX / 2 + 3, 1, u64::MAX];
        assert_eq!(round_trip_keys(&xs), xs.to_vec());
    }

    #[test]
    fn delta_blocks_split_at_block_max() {
        let xs: Vec<u32> = (0..(DELTA_BLOCK_MAX as u32 * 2 + 5)).rev().collect();
        assert_eq!(round_trip_keys(&xs), xs.iter().map(|&x| x as u64).collect::<Vec<_>>());
    }

    #[test]
    fn kv_payload_bytes_are_fixed_width() {
        let xs = [Kv::new(9, 100), Kv::new(9, 101), Kv::new(3, 102)];
        let mut bytes = Vec::new();
        encode_delta(&xs, &mut bytes);
        // One block: frame + key section + 3 × 4 payload bytes at the tail.
        let tail = &bytes[bytes.len() - 12..];
        assert_eq!(tail, [100, 0, 0, 0, 101, 0, 0, 0, 102, 0, 0, 0]);
        assert_eq!(round_trip_keys(&xs), vec![9, 9, 3]);
        // Kv64 carries 8-byte payloads.
        let xs = [Kv64 { key: 5, val: u64::MAX }];
        bytes.clear();
        encode_delta(&xs, &mut bytes);
        assert_eq!(&bytes[bytes.len() - 8..], [0xff; 8]);
    }

    #[test]
    fn sorted_descending_runs_compress() {
        // A dense descending run: every delta is -1 → 1 varint byte per
        // key vs 4 raw bytes.
        let xs: Vec<u32> = (0..1000u32).rev().collect();
        let mut bytes = Vec::new();
        encode_delta(&xs, &mut bytes);
        assert!(
            bytes.len() < xs.len() * 2,
            "dense descending u32 must compress ≥ 2×: {} bytes for {} keys",
            bytes.len(),
            xs.len()
        );
    }

    #[test]
    fn codec_parse_name_and_fallback() {
        assert_eq!(Codec::parse("raw").unwrap(), Codec::Raw);
        assert_eq!(Codec::parse("delta").unwrap(), Codec::Delta);
        assert_eq!(Codec::parse("flr3").unwrap(), Codec::Flr3);
        assert!(Codec::parse("lz4").unwrap_err().contains("unknown codec"));
        for c in [Codec::Raw, Codec::Delta, Codec::Flr3] {
            assert_eq!(Codec::parse(c.name()).unwrap(), c);
        }
        assert_eq!(Codec::Delta.effective_for(Dtype::F32), Codec::Raw);
        assert_eq!(Codec::Delta.effective_for(Dtype::U32), Codec::Delta);
        assert_eq!(Codec::Delta.effective_for(Dtype::Kv64), Codec::Delta);
        assert_eq!(Codec::Raw.effective_for(Dtype::U32), Codec::Raw);
        assert_eq!(Codec::default(), Codec::Raw);
    }

    #[test]
    fn flr3_fallback_matrix() {
        // Plain integer keys honour the request — signed included:
        // `key_bits` is the order-preserving biased unsigned domain, so
        // FLR2/FLR3 delta arithmetic works on signed runs unchanged.
        assert_eq!(Codec::Flr3.effective_for(Dtype::U32), Codec::Flr3);
        assert_eq!(Codec::Flr3.effective_for(Dtype::U64), Codec::Flr3);
        assert_eq!(Codec::Flr3.effective_for(Dtype::I32), Codec::Flr3);
        assert_eq!(Codec::Flr3.effective_for(Dtype::I64), Codec::Flr3);
        assert_eq!(Codec::Delta.effective_for(Dtype::I32), Codec::Delta);
        assert_eq!(Codec::Delta.effective_for(Dtype::I64), Codec::Delta);
        // … f32 drops to raw like delta does …
        assert_eq!(Codec::Flr3.effective_for(Dtype::F32), Codec::Raw);
        // … and payload records keep compressing via FLR2.
        assert_eq!(Codec::Flr3.effective_for(Dtype::Kv), Codec::Delta);
        assert_eq!(Codec::Flr3.effective_for(Dtype::Kv64), Codec::Delta);
        // Raw is always honoured.
        for d in Dtype::ALL {
            assert_eq!(Codec::Raw.effective_for(d), Codec::Raw);
        }
    }

    #[test]
    fn parse_codec_arg_names_the_argument() {
        assert_eq!(parse_codec_arg("flr3").unwrap(), Codec::Flr3);
        assert_eq!(parse_codec_arg("raw").unwrap(), Codec::Raw);
        assert_eq!(parse_codec_arg("delta").unwrap(), Codec::Delta);
        let err = parse_codec_arg("lz4").unwrap_err();
        assert!(err.starts_with("codec argument: unknown codec 'lz4'"), "{err}");
        assert!(err.contains("raw|delta|flr3"), "{err}");
    }
}
