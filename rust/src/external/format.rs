//! On-disk formats for the external-sort subsystem, generic over the
//! record type.
//!
//! Every supported dataset type implements [`ExtItem`]: a fixed-width
//! little-endian wire encoding plus the in-memory sort used for phase-1
//! runs (stable for payload records — the paper's §6 tie-record
//! guarantee holds out-of-core, not just in RAM). Four layouts share
//! the encoding (byte-level spec with worked hex examples in
//! `docs/FORMATS.md`):
//!
//! * **`FLR1` run files** — length-prefixed fixed-width records: a
//!   4-byte magic, a u64 element count, then `count × WIRE_BYTES`
//!   payload bytes. What [`RunWriter`] produces under [`Codec::Raw`].
//! * **`FLR2` run files** — the same 12-byte header shape (magic
//!   `FLR2`), then a sequence of delta blocks: keys stored as a
//!   full-width base plus zigzag-delta LEB128 varints, payloads
//!   fixed-width alongside ([`Codec::Delta`], [`codec`](super::codec)).
//! * **`FLR3` run files** — the same header shape (magic `FLR3`), then
//!   frame-of-reference bitpacked 1024-key blocks in the FastLanes
//!   transposed order ([`Codec::Flr3`], [`flr3`](super::flr3)). Keys
//!   only — payload dtypes fall back to `FLR2` via
//!   [`Codec::effective_for`] — and decode dispatches on the same
//!   [`MergeKernel`] knob as the merge kernels.
//! * **Raw datasets** ([`RawReader`] / [`RawWriter`]) — headerless
//!   little-endian records, the input/output format of `sort_file` (and
//!   what the `sortfile` CLI/service commands operate on). For `f32`
//!   datasets the wire format is plain IEEE-754 bits; the in-memory
//!   representation is the order-preserving [`F32Key`].
//!
//! [`RunReader::open`] negotiates the version from the magic, so `FLR1`
//! files written before the codec layer existed still load; the element
//! count is patched into the header on [`RunWriter::finish`], so a
//! truncated or crashed spill is detectable on open.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::marker::PhantomData;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::fault::{self, Injector};
use crate::flims::simd::{merge_desc_kernel, MergeKernel, SimdMergeable};
use crate::flims::sort::{sort_desc_with, SortConfig};
use crate::flims::stable::{merge_stable_simd, sort_stable_desc_with};
use crate::key::{F32Key, Item, Kv, Kv64};

use super::codec::{
    decode_delta_keys, encode_delta, Codec, DELTA_BLOCK_MAX, DELTA_FRAME_BYTES, MAX_VARINT_BYTES,
};
use super::flr3::{self, FLR3_BLOCK, FLR3_BLOCK_HEADER_BYTES};

/// Magic prefix of an `FLR1` (raw fixed-width) run file.
pub const RUN_MAGIC: [u8; 4] = *b"FLR1";
/// Magic prefix of an `FLR2` (delta + varint) run file.
pub const RUN_MAGIC_V2: [u8; 4] = *b"FLR2";
/// Magic prefix of an `FLR3` (frame-of-reference bitpacked) run file.
pub const RUN_MAGIC_V3: [u8; 4] = *b"FLR3";
/// Header size shared by every run version: magic + u64 element count.
pub const RUN_HEADER_BYTES: u64 = 12;

/// Dataset element type selector — the `dtype` argument of `sortfile`
/// and the `[external] dtype` config knob, mapping onto the [`ExtItem`]
/// implementations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    /// Plain 32-bit keys.
    U32,
    /// Plain 64-bit keys.
    U64,
    /// Signed 32-bit keys (sign-flip bias kernels on the SIMD tier).
    I32,
    /// Signed 64-bit keys (sign-flip bias kernels on the SIMD tier).
    I64,
    /// 32-bit key + 32-bit payload records.
    Kv,
    /// 64-bit key + 64-bit payload records.
    Kv64,
    /// IEEE-754 single floats (order-preserving in memory).
    F32,
}

impl Dtype {
    /// Every dtype, in knob-spelling order — the single source of truth
    /// for "what dtypes exist" across config, CLI, and protocol.
    pub const ALL: [Dtype; 7] = [
        Dtype::U32,
        Dtype::U64,
        Dtype::I32,
        Dtype::I64,
        Dtype::Kv,
        Dtype::Kv64,
        Dtype::F32,
    ];

    /// The knob spellings of [`ALL`](Dtype::ALL), `|`-joined — what parse
    /// errors and help text enumerate.
    pub const ALL_NAMES: &'static str = "u32|u64|i32|i64|kv|kv64|f32";

    /// Parse a dtype name (one of [`ALL_NAMES`](Dtype::ALL_NAMES)).
    pub fn parse(s: &str) -> Result<Self, String> {
        Dtype::ALL
            .into_iter()
            .find(|d| d.name() == s)
            .ok_or_else(|| format!("unknown dtype '{s}' (expected {})", Dtype::ALL_NAMES))
    }

    /// The knob spelling of this dtype.
    pub fn name(self) -> &'static str {
        match self {
            Dtype::U32 => "u32",
            Dtype::U64 => "u64",
            Dtype::I32 => "i32",
            Dtype::I64 => "i64",
            Dtype::Kv => "kv",
            Dtype::Kv64 => "kv64",
            Dtype::F32 => "f32",
        }
    }

    /// Bytes per record on disk.
    pub fn wire_bytes(self) -> usize {
        match self {
            Dtype::U32 | Dtype::I32 | Dtype::F32 => 4,
            Dtype::U64 | Dtype::I64 | Dtype::Kv => 8,
            Dtype::Kv64 => 16,
        }
    }

    /// The kernel tier this dtype's merges *actually* run on under the
    /// given knob — what the `stats` line, sortfile report, and
    /// `flims_sorts_total{kernel=…}` label surface. Narrower than
    /// [`MergeKernel::resolved_name`], which is the CPU ceiling: a dtype
    /// whose lane width has no kernel on this CPU (e.g. 64-bit lanes
    /// without AVX2) reports `scalar` even when the knob says auto/simd.
    pub fn effective_kernel(self, kernel: MergeKernel) -> &'static str {
        if !kernel.wants_simd() {
            return "scalar";
        }
        match self {
            // 32-bit lanes (f32 rides them as order-preserving bits;
            // i32 through the sign-flip bias wrappers).
            Dtype::U32 | Dtype::I32 | Dtype::F32 => <u32 as SimdMergeable>::simd_tier(),
            // 64-bit lanes: i64 via bias wrappers; Kv packs
            // (key, rank) into u64 lanes, Kv64 merges bare u64 keys
            // then gathers payloads.
            Dtype::U64 | Dtype::I64 | Dtype::Kv | Dtype::Kv64 => {
                <u64 as SimdMergeable>::simd_tier()
            }
        }
    }
}

/// [`Dtype::parse`] with the argument-position error prefix shared by
/// the config, CLI, and protocol surfaces.
pub fn parse_dtype_arg(s: &str) -> Result<Dtype, String> {
    Dtype::parse(s).map_err(|e| format!("dtype argument: {e}"))
}

/// A record the external sort can spill, merge, and stream: an [`Item`]
/// with a fixed-width little-endian wire format, a phase-1 in-memory
/// sort, and the 2-way merge the tree nodes run. Both `sort_run` and
/// `merge_into` must be **stable** (A/earlier-input wins ties) for types
/// with payloads distinct from their key (`Kv`, `Kv64`); plain keys use
/// the faster untagged FLiMS lanes because equal keys are
/// indistinguishable, so the descending value sequence is unique.
///
/// The key/payload split (`KEY_BYTES`, [`key_bits`](ExtItem::key_bits),
/// [`from_parts`](ExtItem::from_parts)) is what the `FLR2` delta codec
/// encodes: keys travel as varint deltas, payloads stay fixed-width.
pub trait ExtItem: Item {
    /// Bytes per record on disk.
    const WIRE_BYTES: usize;
    /// Bytes of the key prefix within the record; `WIRE_BYTES -
    /// KEY_BYTES` payload bytes follow it in the delta layout.
    const KEY_BYTES: usize;
    /// The dtype tag this implementation answers to.
    const DTYPE: Dtype;
    /// Encode into exactly `WIRE_BYTES` bytes.
    fn encode(self, out: &mut [u8]);
    /// Decode from exactly `WIRE_BYTES` bytes.
    fn decode(b: &[u8]) -> Self;
    /// The key as a zero-extended `u64` bit pattern — the delta codec's
    /// arithmetic domain. Must be injective over `KEY_BYTES × 8` bits.
    fn key_bits(self) -> u64;
    /// Rebuild a record from [`key_bits`](ExtItem::key_bits) output and
    /// the `WIRE_BYTES - KEY_BYTES` payload bytes.
    fn from_parts(key: u64, payload: &[u8]) -> Self;
    /// Encode the payload tail into exactly `WIRE_BYTES - KEY_BYTES`
    /// bytes (no-op for plain keys).
    fn encode_payload(self, out: &mut [u8]);
    /// Sort a phase-1 run descending in memory on the given merge
    /// kernel. Plain keys hit the explicit-SIMD tier directly (signed
    /// keys through the sign-flip bias kernels); payload records take
    /// the key–index SIMD stable tier ([`merge_stable_simd`]), which
    /// preserves the §6 guarantee while still vectorising the compares.
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig, kernel: MergeKernel);
    /// Merge two descending-sorted slices, appending to `out` — the
    /// per-block merge of every tree node, on the given merge kernel.
    fn merge_into(a: &[Self], b: &[Self], w: usize, kernel: MergeKernel, out: &mut Vec<Self>);
}

impl ExtItem for u32 {
    const WIRE_BYTES: usize = 4;
    const KEY_BYTES: usize = 4;
    const DTYPE: Dtype = Dtype::U32;
    fn encode(self, out: &mut [u8]) {
        out.copy_from_slice(&self.to_le_bytes());
    }
    fn decode(b: &[u8]) -> Self {
        u32::from_le_bytes(b.try_into().expect("4-byte record"))
    }
    fn key_bits(self) -> u64 {
        self as u64
    }
    fn from_parts(key: u64, _payload: &[u8]) -> Self {
        key as u32
    }
    fn encode_payload(self, _out: &mut [u8]) {}
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig, kernel: MergeKernel) {
        sort_desc_with(buf, cfg, kernel);
    }
    fn merge_into(a: &[Self], b: &[Self], w: usize, kernel: MergeKernel, out: &mut Vec<Self>) {
        merge_desc_kernel(a, b, w, kernel, out);
    }
}

impl ExtItem for u64 {
    const WIRE_BYTES: usize = 8;
    const KEY_BYTES: usize = 8;
    const DTYPE: Dtype = Dtype::U64;
    fn encode(self, out: &mut [u8]) {
        out.copy_from_slice(&self.to_le_bytes());
    }
    fn decode(b: &[u8]) -> Self {
        u64::from_le_bytes(b.try_into().expect("8-byte record"))
    }
    fn key_bits(self) -> u64 {
        self
    }
    fn from_parts(key: u64, _payload: &[u8]) -> Self {
        key
    }
    fn encode_payload(self, _out: &mut [u8]) {}
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig, kernel: MergeKernel) {
        sort_desc_with(buf, cfg, kernel);
    }
    fn merge_into(a: &[Self], b: &[Self], w: usize, kernel: MergeKernel, out: &mut Vec<Self>) {
        merge_desc_kernel(a, b, w, kernel, out);
    }
}

impl ExtItem for i32 {
    const WIRE_BYTES: usize = 4;
    const KEY_BYTES: usize = 4;
    const DTYPE: Dtype = Dtype::I32;
    fn encode(self, out: &mut [u8]) {
        out.copy_from_slice(&self.to_le_bytes());
    }
    fn decode(b: &[u8]) -> Self {
        i32::from_le_bytes(b.try_into().expect("4-byte record"))
    }
    fn key_bits(self) -> u64 {
        // Sign-flip bias: an order-preserving injection into u32, so
        // the delta codec's wrapping arithmetic and the FLR3 descending
        // check both see a domain whose unsigned order matches the
        // signed record order.
        (self as u32 ^ 0x8000_0000) as u64
    }
    fn from_parts(key: u64, _payload: &[u8]) -> Self {
        // The bias is a self-inverse XOR.
        (key as u32 ^ 0x8000_0000) as i32
    }
    fn encode_payload(self, _out: &mut [u8]) {}
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig, kernel: MergeKernel) {
        sort_desc_with(buf, cfg, kernel);
    }
    fn merge_into(a: &[Self], b: &[Self], w: usize, kernel: MergeKernel, out: &mut Vec<Self>) {
        merge_desc_kernel(a, b, w, kernel, out);
    }
}

impl ExtItem for i64 {
    const WIRE_BYTES: usize = 8;
    const KEY_BYTES: usize = 8;
    const DTYPE: Dtype = Dtype::I64;
    fn encode(self, out: &mut [u8]) {
        out.copy_from_slice(&self.to_le_bytes());
    }
    fn decode(b: &[u8]) -> Self {
        i64::from_le_bytes(b.try_into().expect("8-byte record"))
    }
    fn key_bits(self) -> u64 {
        // Sign-flip bias (see the i32 impl).
        (self as u64) ^ (1 << 63)
    }
    fn from_parts(key: u64, _payload: &[u8]) -> Self {
        (key ^ (1 << 63)) as i64
    }
    fn encode_payload(self, _out: &mut [u8]) {}
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig, kernel: MergeKernel) {
        sort_desc_with(buf, cfg, kernel);
    }
    fn merge_into(a: &[Self], b: &[Self], w: usize, kernel: MergeKernel, out: &mut Vec<Self>) {
        merge_desc_kernel(a, b, w, kernel, out);
    }
}

impl ExtItem for F32Key {
    const WIRE_BYTES: usize = 4;
    const KEY_BYTES: usize = 4;
    const DTYPE: Dtype = Dtype::F32;
    fn encode(self, out: &mut [u8]) {
        // On disk: the plain IEEE-754 bits, so datasets interoperate
        // with anything that writes little-endian f32.
        out.copy_from_slice(&self.to_f32().to_bits().to_le_bytes());
    }
    fn decode(b: &[u8]) -> Self {
        F32Key::from_f32(f32::from_bits(u32::from_le_bytes(
            b.try_into().expect("4-byte record"),
        )))
    }
    fn key_bits(self) -> u64 {
        // The order-preserving mapped bits — only ever exercised by
        // tests: `Codec::effective_for` keeps f32 runs on the raw codec.
        self.0 as u64
    }
    fn from_parts(key: u64, _payload: &[u8]) -> Self {
        F32Key(key as u32)
    }
    fn encode_payload(self, _out: &mut [u8]) {}
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig, kernel: MergeKernel) {
        sort_desc_with(buf, cfg, kernel);
    }
    fn merge_into(a: &[Self], b: &[Self], w: usize, kernel: MergeKernel, out: &mut Vec<Self>) {
        merge_desc_kernel(a, b, w, kernel, out);
    }
}

impl ExtItem for Kv {
    const WIRE_BYTES: usize = 8;
    const KEY_BYTES: usize = 4;
    const DTYPE: Dtype = Dtype::Kv;
    fn encode(self, out: &mut [u8]) {
        out[..4].copy_from_slice(&self.key.to_le_bytes());
        out[4..].copy_from_slice(&self.val.to_le_bytes());
    }
    fn decode(b: &[u8]) -> Self {
        Kv {
            key: u32::from_le_bytes(b[..4].try_into().expect("8-byte record")),
            val: u32::from_le_bytes(b[4..].try_into().expect("8-byte record")),
        }
    }
    fn key_bits(self) -> u64 {
        self.key as u64
    }
    fn from_parts(key: u64, payload: &[u8]) -> Self {
        Kv {
            key: key as u32,
            val: u32::from_le_bytes(payload.try_into().expect("4-byte payload")),
        }
    }
    fn encode_payload(self, out: &mut [u8]) {
        out.copy_from_slice(&self.val.to_le_bytes());
    }
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig, kernel: MergeKernel) {
        // §6 stability on the SIMD tier: chunk merges go through the
        // key–index packed kernels, falling back to the tagged scalar
        // merge below the SIMD threshold.
        sort_stable_desc_with(buf, cfg, kernel);
    }
    fn merge_into(a: &[Self], b: &[Self], w: usize, kernel: MergeKernel, out: &mut Vec<Self>) {
        merge_stable_simd(a, b, w, kernel, out);
    }
}

impl ExtItem for Kv64 {
    const WIRE_BYTES: usize = 16;
    const KEY_BYTES: usize = 8;
    const DTYPE: Dtype = Dtype::Kv64;
    fn encode(self, out: &mut [u8]) {
        out[..8].copy_from_slice(&self.key.to_le_bytes());
        out[8..].copy_from_slice(&self.val.to_le_bytes());
    }
    fn decode(b: &[u8]) -> Self {
        Kv64 {
            key: u64::from_le_bytes(b[..8].try_into().expect("16-byte record")),
            val: u64::from_le_bytes(b[8..].try_into().expect("16-byte record")),
        }
    }
    fn key_bits(self) -> u64 {
        self.key
    }
    fn from_parts(key: u64, payload: &[u8]) -> Self {
        Kv64 { key, val: u64::from_le_bytes(payload.try_into().expect("8-byte payload")) }
    }
    fn encode_payload(self, out: &mut [u8]) {
        out.copy_from_slice(&self.val.to_le_bytes());
    }
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig, kernel: MergeKernel) {
        // §6 stability on the SIMD tier: key-only SIMD merge plus a
        // stable payload gather (see `StableSimdMerge` for Kv64).
        sort_stable_desc_with(buf, cfg, kernel);
    }
    fn merge_into(a: &[Self], b: &[Self], w: usize, kernel: MergeKernel, out: &mut Vec<Self>) {
        merge_stable_simd(a, b, w, kernel, out);
    }
}

/// A finished spilled run: its path and sizes, as tracked by the
/// `SpillManager`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RunFile {
    /// Location of the run on disk.
    pub path: PathBuf,
    /// Payload element count.
    pub elems: u64,
    /// Total file size on disk (header + encoded payload).
    pub bytes: u64,
    /// What the file would occupy under [`Codec::Raw`] (header +
    /// `elems × WIRE_BYTES`) — the numerator of the compression ratio.
    pub raw_bytes: u64,
    /// Wall-clock the writer spent inside the codec, nanoseconds
    /// (summed — not truncated — across runs; the stats layer divides
    /// to µs once at the end).
    pub encode_ns: u64,
}

fn encode_block<T: ExtItem>(xs: &[T], byte_buf: &mut Vec<u8>) {
    // resize without clear(): only growth is zero-filled, so the
    // steady-state (same-sized blocks) never memsets before encoding.
    byte_buf.resize(xs.len() * T::WIRE_BYTES, 0);
    for (x, chunk) in xs.iter().zip(byte_buf.chunks_exact_mut(T::WIRE_BYTES)) {
        x.encode(chunk);
    }
}

fn read_record_block<T: ExtItem>(
    inp: &mut BufReader<File>,
    remaining: &mut u64,
    byte_buf: &mut Vec<u8>,
    out: &mut Vec<T>,
    max: usize,
) -> Result<usize> {
    let take = (*remaining).min(max as u64) as usize;
    if take == 0 {
        return Ok(0);
    }
    byte_buf.resize(take * T::WIRE_BYTES, 0);
    inp.read_exact(byte_buf)?;
    out.reserve(take);
    for c in byte_buf.chunks_exact(T::WIRE_BYTES) {
        out.push(T::decode(c));
    }
    *remaining -= take as u64;
    Ok(take)
}

/// Streaming writer for one run file (`FLR1` under [`Codec::Raw`],
/// `FLR2` under [`Codec::Delta`], `FLR3` under [`Codec::Flr3`]).
pub struct RunWriter<T: ExtItem> {
    out: BufWriter<File>,
    path: PathBuf,
    codec: Codec,
    kernel: MergeKernel,
    count: u64,
    payload_bytes: u64,
    encode_ns: u64,
    byte_buf: Vec<u8>,
    key_buf: Vec<u64>,
    fault: Injector,
    /// Set by [`finish`](RunWriter::finish); the drop-guard removes the
    /// partial file when a writer dies unsealed (failure or cancel).
    sealed: bool,
    _elem: PhantomData<T>,
}

impl<T: ExtItem> RunWriter<T> {
    /// Create `path` as a raw (`FLR1`) run — the historical format.
    pub fn create(path: &Path) -> Result<Self> {
        Self::create_with(path, Codec::Raw)
    }

    /// Create `path` with the given codec, writing the matching magic
    /// and a zero count placeholder. Callers pass the *effective* codec
    /// ([`Codec::effective_for`]); this writer encodes whatever it is
    /// told to — except that the keys-only `FLR3` layout rejects
    /// payload dtypes outright.
    pub fn create_with(path: &Path, codec: Codec) -> Result<Self> {
        Self::create_with_kernel(path, codec, MergeKernel::Auto)
    }

    /// [`create_with`](RunWriter::create_with) on an explicit
    /// merge-kernel tier — `FLR3` encode dispatches its bitpack kernels
    /// on it (the other codecs ignore it).
    pub fn create_with_kernel(path: &Path, codec: Codec, kernel: MergeKernel) -> Result<Self> {
        Self::create_with_fault(path, codec, kernel, Injector::disabled())
    }

    /// [`create_with_kernel`](RunWriter::create_with_kernel) with a
    /// fault-injection handle for this writer's I/O seams (create /
    /// write / seal). The spill layer materializes one injector per run
    /// file; direct callers pass [`Injector::disabled`].
    pub fn create_with_fault(
        path: &Path,
        codec: Codec,
        kernel: MergeKernel,
        mut fault: Injector,
    ) -> Result<Self> {
        if codec == Codec::Flr3 && T::WIRE_BYTES != T::KEY_BYTES {
            bail!(
                "codec flr3 cannot carry {} payload records (keys only — \
                 Codec::effective_for falls back to delta)",
                T::DTYPE.name()
            );
        }
        let f = fault::with_retry(&mut fault, fault::Op::Create, || File::create(path))
            .with_context(|| format!("creating run file {}", path.display()))?;
        let mut out = BufWriter::new(f);
        match codec {
            Codec::Raw => out.write_all(&RUN_MAGIC)?,
            Codec::Delta => out.write_all(&RUN_MAGIC_V2)?,
            Codec::Flr3 => out.write_all(&RUN_MAGIC_V3)?,
        }
        out.write_all(&0u64.to_le_bytes())?;
        Ok(RunWriter {
            out,
            path: path.to_path_buf(),
            codec,
            kernel,
            count: 0,
            payload_bytes: 0,
            encode_ns: 0,
            byte_buf: Vec::new(),
            key_buf: Vec::new(),
            fault,
            sealed: false,
            _elem: PhantomData,
        })
    }

    /// The file this writer is producing.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The codec this writer encodes with.
    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Append a block of elements (need not be the whole run). Under
    /// [`Codec::Delta`] and [`Codec::Flr3`] each call frames its own
    /// blocks, so block boundaries — hence output bytes — depend only
    /// on the call sequence, never on thread timing.
    pub fn write_block(&mut self, xs: &[T]) -> Result<()> {
        if xs.is_empty() {
            return Ok(());
        }
        let t = Instant::now();
        match self.codec {
            Codec::Raw => encode_block(xs, &mut self.byte_buf),
            Codec::Delta => {
                self.byte_buf.clear();
                encode_delta(xs, &mut self.byte_buf);
            }
            Codec::Flr3 => {
                self.byte_buf.clear();
                self.key_buf.clear();
                self.key_buf.extend(xs.iter().map(|x| x.key_bits()));
                flr3::encode_blocks(&self.key_buf, self.kernel, &mut self.byte_buf);
            }
        }
        self.encode_ns += t.elapsed().as_nanos() as u64;
        let (fault, out, buf) = (&mut self.fault, &mut self.out, &self.byte_buf);
        fault::with_retry(fault, fault::Op::Write, || out.write_all(buf))
            .with_context(|| format!("writing run block to {}", self.path.display()))?;
        self.payload_bytes += self.byte_buf.len() as u64;
        self.count += xs.len() as u64;
        Ok(())
    }

    /// Flush, patch the element count into the header, and return the
    /// finished run's metadata.
    pub fn finish(mut self) -> Result<RunFile> {
        let (fault, out, count) = (&mut self.fault, &mut self.out, self.count);
        fault::with_retry(fault, fault::Op::Seal, || {
            out.flush()?;
            let f = out.get_mut();
            f.seek(SeekFrom::Start(RUN_MAGIC.len() as u64))?;
            f.write_all(&count.to_le_bytes())
        })
        .with_context(|| format!("sealing run file {}", self.path.display()))?;
        self.sealed = true;
        Ok(RunFile {
            bytes: RUN_HEADER_BYTES + self.payload_bytes,
            raw_bytes: RUN_HEADER_BYTES + self.count * T::WIRE_BYTES as u64,
            encode_ns: self.encode_ns,
            path: std::mem::take(&mut self.path),
            elems: self.count,
        })
    }
}

impl<T: ExtItem> Drop for RunWriter<T> {
    /// RAII guard: a writer dropped before [`finish`](RunWriter::finish)
    /// — merge failure, cancellation, injected fault — removes its
    /// partial run file so a failed sort never leaks spill bytes.
    fn drop(&mut self) {
        if !self.sealed && !self.path.as_os_str().is_empty() {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// Streaming reader for one run file. [`RunReader::open`] sniffs the
/// magic, so it reads `FLR1` (raw), `FLR2` (delta), and `FLR3`
/// (bitpacked) runs; decoding happens inside `read_block`, which is
/// exactly what the prefetch threads call — decompression overlaps the
/// merge.
pub struct RunReader<T: ExtItem> {
    inp: BufReader<File>,
    path: PathBuf,
    codec: Codec,
    kernel: MergeKernel,
    remaining: u64,
    file_len: u64,
    /// Bytes consumed from the file so far (delta/flr3 paths only) —
    /// lets EOF detect trailing garbage that the header count cannot.
    consumed: u64,
    /// Decoded-but-unserved records (delta/flr3 paths only).
    pending: Vec<T>,
    pending_pos: usize,
    byte_buf: Vec<u8>,
    key_buf: Vec<u64>,
    word_buf: Vec<u64>,
    /// Last key served (flr3 path only): spilled runs are descending by
    /// construction, so the decoder enforces it — a mutated
    /// frame-of-reference base or width surfaces as a clean error, not
    /// silently wrong data.
    prev_key: Option<u64>,
    decode_ns: Option<Arc<AtomicU64>>,
    fault: Injector,
    _elem: PhantomData<T>,
}

impl<T: ExtItem> RunReader<T> {
    /// Open a run file, negotiating the format version from its magic.
    pub fn open(path: &Path) -> Result<Self> {
        Self::open_with(path, None)
    }

    /// [`open`](RunReader::open), additionally accumulating decode
    /// wall-clock (nanoseconds) into `decode_ns` — how the merge
    /// surfaces codec CPU time in its stats.
    pub fn open_with(path: &Path, decode_ns: Option<Arc<AtomicU64>>) -> Result<Self> {
        Self::open_with_kernel(path, decode_ns, MergeKernel::Auto)
    }

    /// [`open_with`](RunReader::open_with) on an explicit merge-kernel
    /// tier — `FLR3` decode dispatches its unpack kernels on it (the
    /// other codecs ignore it).
    pub fn open_with_kernel(
        path: &Path,
        decode_ns: Option<Arc<AtomicU64>>,
        kernel: MergeKernel,
    ) -> Result<Self> {
        Self::open_with_fault(path, decode_ns, kernel, Injector::disabled())
    }

    /// [`open_with_kernel`](RunReader::open_with_kernel) with a
    /// fault-injection handle for this reader's I/O seams (open and
    /// every block read). The merge layer materializes one injector per
    /// run file; direct callers pass [`Injector::disabled`].
    pub fn open_with_fault(
        path: &Path,
        decode_ns: Option<Arc<AtomicU64>>,
        kernel: MergeKernel,
        mut fault: Injector,
    ) -> Result<Self> {
        let f = fault::with_retry(&mut fault, fault::Op::Read, || File::open(path))
            .with_context(|| format!("opening run file {}", path.display()))?;
        let len = f.metadata()?.len();
        // A file shorter than the fixed header is a mid-write crash (or
        // an empty placeholder): say so directly instead of surfacing a
        // generic short-read error from the magic sniff below.
        if len < RUN_HEADER_BYTES {
            bail!(
                "run truncated: {} ({len} bytes is shorter than the {RUN_HEADER_BYTES}-byte \
                 run header)",
                path.display()
            );
        }
        let mut inp = BufReader::new(f);
        let mut magic = [0u8; 4];
        inp.read_exact(&mut magic)
            .map_err(|e| anyhow!("{}: reading run header: {e}", path.display()))?;
        let codec = match magic {
            RUN_MAGIC => Codec::Raw,
            RUN_MAGIC_V2 => Codec::Delta,
            RUN_MAGIC_V3 => Codec::Flr3,
            _ => bail!("{}: not a run file (bad magic {magic:?})", path.display()),
        };
        // FLR3 blocks hold key bits only — there are no payload bytes
        // to rebuild a record from, so a payload-typed read is a schema
        // mismatch and must fail here, not panic in `from_parts`.
        if codec == Codec::Flr3 && T::WIRE_BYTES != T::KEY_BYTES {
            bail!(
                "{}: corrupt run (flr3 runs are keys only, cannot decode {} payload records)",
                path.display(),
                T::DTYPE.name()
            );
        }
        let mut cnt = [0u8; 8];
        inp.read_exact(&mut cnt)
            .map_err(|e| anyhow!("{}: reading run header: {e}", path.display()))?;
        let remaining = u64::from_le_bytes(cnt);
        match codec {
            Codec::Raw => {
                // The count is untrusted input: checked math so a corrupt
                // header reports "truncated run" instead of overflowing.
                let expect = remaining
                    .checked_mul(T::WIRE_BYTES as u64)
                    .and_then(|payload| payload.checked_add(RUN_HEADER_BYTES));
                if expect != Some(len) {
                    bail!(
                        "{}: truncated run (header claims {} {} elements, file is {} bytes)",
                        path.display(),
                        remaining,
                        T::DTYPE.name(),
                        len
                    );
                }
            }
            Codec::Delta | Codec::Flr3 => {
                // Encoded payloads are variable-length: full validation
                // is per-block during streaming plus a trailing-bytes
                // check at EOF. Only the cheap lower bound is checkable
                // here.
                let frame = match codec {
                    Codec::Delta => DELTA_FRAME_BYTES as u64 + T::KEY_BYTES as u64,
                    _ => FLR3_BLOCK_HEADER_BYTES as u64,
                };
                let min = if remaining == 0 { RUN_HEADER_BYTES } else { RUN_HEADER_BYTES + frame };
                if len < min {
                    bail!(
                        "{}: truncated run (header claims {} {} elements, file is {} bytes)",
                        path.display(),
                        remaining,
                        T::DTYPE.name(),
                        len
                    );
                }
            }
        }
        Ok(RunReader {
            inp,
            path: path.to_path_buf(),
            codec,
            kernel,
            remaining,
            file_len: len,
            consumed: RUN_HEADER_BYTES,
            pending: Vec::new(),
            pending_pos: 0,
            byte_buf: Vec::new(),
            key_buf: Vec::new(),
            word_buf: Vec::new(),
            prev_key: None,
            decode_ns,
            fault,
            _elem: PhantomData,
        })
    }

    /// Elements not yet read (not yet *decoded*, for delta runs).
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// The codec this file was written with.
    pub fn codec(&self) -> Codec {
        self.codec
    }

    /// Append up to `max` elements to `out`; returns how many were read
    /// (0 = exhausted).
    pub fn read_block(&mut self, out: &mut Vec<T>, max: usize) -> Result<usize> {
        // Fail-before-op injection at the block-read seam: a fault fires
        // before any bytes are consumed, so a retried read re-executes
        // from a clean stream position.
        self.fault
            .checkpoint(fault::Op::Read)
            .with_context(|| format!("reading run block from {}", self.path.display()))?;
        match self.codec {
            Codec::Raw => read_record_block(
                &mut self.inp,
                &mut self.remaining,
                &mut self.byte_buf,
                out,
                max,
            ),
            Codec::Delta | Codec::Flr3 => {
                // Loop across encoded blocks so one call fills up to
                // `max` records whatever the on-disk block granularity
                // — prefetch lookahead and merge-tree call counts stay
                // identical to the raw codec's.
                let mut total = 0usize;
                while total < max {
                    if self.pending_pos == self.pending.len() {
                        if self.remaining == 0 {
                            if self.consumed != self.file_len {
                                bail!(
                                    "{}: corrupt run ({} trailing bytes after the last block)",
                                    self.path.display(),
                                    self.file_len - self.consumed
                                );
                            }
                            break;
                        }
                        match self.codec {
                            Codec::Flr3 => self.fill_pending_flr3()?,
                            _ => self.fill_pending()?,
                        }
                    }
                    let avail = self.pending.len() - self.pending_pos;
                    let take = avail.min(max - total);
                    out.extend_from_slice(
                        &self.pending[self.pending_pos..self.pending_pos + take],
                    );
                    self.pending_pos += take;
                    total += take;
                }
                Ok(total)
            }
        }
    }

    /// Read + decode the next delta block into `pending`.
    fn fill_pending(&mut self) -> Result<()> {
        let path = &self.path;
        let mut hdr = [0u8; DELTA_FRAME_BYTES];
        self.inp.read_exact(&mut hdr).map_err(|e| {
            anyhow!("{}: truncated run (mid block header): {e}", path.display())
        })?;
        let n = u32::from_le_bytes(hdr[..4].try_into().unwrap()) as usize;
        let key_bytes = u32::from_le_bytes(hdr[4..].try_into().unwrap()) as u64;
        if n == 0 || n > DELTA_BLOCK_MAX {
            bail!("{}: corrupt run (block claims {n} records)", path.display());
        }
        if n as u64 > self.remaining {
            bail!(
                "{}: corrupt run (block claims {n} records, only {} remain)",
                path.display(),
                self.remaining
            );
        }
        let max_key_bytes = (T::KEY_BYTES + (n - 1) * MAX_VARINT_BYTES) as u64;
        let left_in_file = self.file_len - self.consumed - DELTA_FRAME_BYTES as u64;
        let key_range = T::KEY_BYTES as u64..=max_key_bytes.min(left_in_file);
        if !key_range.contains(&key_bytes) {
            bail!(
                "{}: corrupt run (block claims {key_bytes} key bytes for {n} records)",
                path.display()
            );
        }
        self.byte_buf.resize(key_bytes as usize, 0);
        self.inp
            .read_exact(&mut self.byte_buf)
            .map_err(|e| anyhow!("{}: truncated run (mid key section): {e}", path.display()))?;
        let t = Instant::now();
        self.key_buf.clear();
        decode_delta_keys::<T>(&self.byte_buf, n, &mut self.key_buf)
            .map_err(|e| anyhow!("{}: corrupt run ({e})", path.display()))?;
        let decode_keys_ns = t.elapsed().as_nanos() as u64;

        let payload_bytes = T::WIRE_BYTES - T::KEY_BYTES;
        self.byte_buf.resize(n * payload_bytes, 0);
        self.inp
            .read_exact(&mut self.byte_buf)
            .map_err(|e| anyhow!("{}: truncated run (mid payload): {e}", path.display()))?;

        let t = Instant::now();
        self.pending.clear();
        self.pending_pos = 0;
        self.pending.reserve(n);
        for (i, &k) in self.key_buf.iter().enumerate() {
            let p = &self.byte_buf[i * payload_bytes..(i + 1) * payload_bytes];
            self.pending.push(T::from_parts(k, p));
        }
        if let Some(c) = &self.decode_ns {
            c.fetch_add(decode_keys_ns + t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        self.consumed += DELTA_FRAME_BYTES as u64 + key_bytes + (n * payload_bytes) as u64;
        self.remaining -= n as u64;
        Ok(())
    }

    /// Read + decode the next FLR3 block into `pending`. Framing is
    /// fully validated here — record count, delta width, zero pad,
    /// packed length against the file — and the decoded keys must keep
    /// the run descending, so a mutated base/width never produces
    /// silently wrong data.
    fn fill_pending_flr3(&mut self) -> Result<()> {
        let path = &self.path;
        let mut hdr = [0u8; FLR3_BLOCK_HEADER_BYTES];
        self.inp.read_exact(&mut hdr).map_err(|e| {
            anyhow!("{}: truncated run (mid block header): {e}", path.display())
        })?;
        let n = u32::from_le_bytes(hdr[..4].try_into().unwrap()) as usize;
        let width = hdr[4] as usize;
        let base = u64::from_le_bytes(hdr[8..16].try_into().unwrap());
        if hdr[5..8] != [0u8; 3] {
            bail!("{}: corrupt run (nonzero pad in block header)", path.display());
        }
        if n == 0 || n > FLR3_BLOCK {
            bail!("{}: corrupt run (block claims {n} records)", path.display());
        }
        if n as u64 > self.remaining {
            bail!(
                "{}: corrupt run (block claims {n} records, only {} remain)",
                path.display(),
                self.remaining
            );
        }
        let max_width = 64.min(8 * T::KEY_BYTES);
        if width > max_width {
            bail!(
                "{}: corrupt run (block claims delta width {width}, {} keys allow at most \
                 {max_width})",
                path.display(),
                T::DTYPE.name()
            );
        }
        let packed = flr3::packed_bytes(width) as u64;
        let left_in_file = self.file_len - self.consumed - FLR3_BLOCK_HEADER_BYTES as u64;
        if packed > left_in_file {
            bail!(
                "{}: truncated run (block needs {packed} packed bytes, {left_in_file} left)",
                path.display()
            );
        }
        self.byte_buf.resize(packed as usize, 0);
        self.inp
            .read_exact(&mut self.byte_buf)
            .map_err(|e| anyhow!("{}: truncated run (mid packed block): {e}", path.display()))?;

        let t = Instant::now();
        self.word_buf.clear();
        self.word_buf.extend(
            self.byte_buf.chunks_exact(8).map(|c| u64::from_le_bytes(c.try_into().unwrap())),
        );
        self.key_buf.clear();
        let mask = flr3::mask_for(8 * T::KEY_BYTES);
        flr3::decode_block(&self.word_buf, n, width, base, mask, self.kernel, &mut self.key_buf);

        self.pending.clear();
        self.pending_pos = 0;
        self.pending.reserve(n);
        for &k in &self.key_buf {
            if self.prev_key.is_some_and(|prev| k > prev) {
                bail!("{}: corrupt run (keys not descending)", path.display());
            }
            self.prev_key = Some(k);
            self.pending.push(T::from_parts(k, &[]));
        }
        if let Some(c) = &self.decode_ns {
            c.fetch_add(t.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
        self.consumed += FLR3_BLOCK_HEADER_BYTES as u64 + packed;
        self.remaining -= n as u64;
        Ok(())
    }
}

/// Streaming reader for a headerless little-endian dataset.
pub struct RawReader<T: ExtItem> {
    inp: BufReader<File>,
    total: u64,
    remaining: u64,
    byte_buf: Vec<u8>,
    _elem: PhantomData<T>,
}

impl<T: ExtItem> RawReader<T> {
    /// Open a raw dataset, validating that its size is a whole number
    /// of records.
    pub fn open(path: &Path) -> Result<Self> {
        let f = File::open(path)
            .with_context(|| format!("opening dataset {}", path.display()))?;
        let len = f.metadata()?.len();
        if len % T::WIRE_BYTES as u64 != 0 {
            bail!(
                "{}: size {} is not a multiple of {} (raw little-endian {} expected)",
                path.display(),
                len,
                T::WIRE_BYTES,
                T::DTYPE.name()
            );
        }
        let total = len / T::WIRE_BYTES as u64;
        Ok(RawReader {
            inp: BufReader::new(f),
            total,
            remaining: total,
            byte_buf: Vec::new(),
            _elem: PhantomData,
        })
    }

    /// Total elements in the file.
    pub fn elems(&self) -> u64 {
        self.total
    }

    /// Append up to `max` elements to `out`; 0 = exhausted.
    pub fn read_block(&mut self, out: &mut Vec<T>, max: usize) -> Result<usize> {
        read_record_block(&mut self.inp, &mut self.remaining, &mut self.byte_buf, out, max)
    }
}

/// Streaming writer for a headerless little-endian dataset.
pub struct RawWriter<T: ExtItem> {
    out: BufWriter<File>,
    count: u64,
    byte_buf: Vec<u8>,
    fault: Injector,
    _elem: PhantomData<T>,
}

impl<T: ExtItem> RawWriter<T> {
    /// Create (truncate) the dataset at `path`.
    pub fn create(path: &Path) -> Result<Self> {
        let f = File::create(path)
            .with_context(|| format!("creating output {}", path.display()))?;
        Ok(RawWriter {
            out: BufWriter::new(f),
            count: 0,
            byte_buf: Vec::new(),
            fault: Injector::disabled(),
            _elem: PhantomData,
        })
    }

    /// Attach a fault-injection handle to this writer's output seam
    /// (the final sink is an injection point like any spill file).
    pub fn with_fault(mut self, fault: Injector) -> Self {
        self.fault = fault;
        self
    }

    /// Append a block of records.
    pub fn write_block(&mut self, xs: &[T]) -> Result<()> {
        encode_block(xs, &mut self.byte_buf);
        let (fault, out, buf) = (&mut self.fault, &mut self.out, &self.byte_buf);
        fault::with_retry(fault, fault::Op::Write, || out.write_all(buf))
            .context("writing output block")?;
        self.count += xs.len() as u64;
        Ok(())
    }

    /// Flush and return the element count written.
    pub fn finish(mut self) -> Result<u64> {
        let (fault, out) = (&mut self.fault, &mut self.out);
        fault::with_retry(fault, fault::Op::Seal, || out.flush())
            .context("flushing output")?;
        Ok(self.count)
    }
}

/// Write a whole dataset in one call (tests, CLI `--gen`).
pub fn write_raw<T: ExtItem>(path: &Path, xs: &[T]) -> Result<u64> {
    let mut w = RawWriter::create(path)?;
    w.write_block(xs)?;
    w.finish()
}

/// Read a whole dataset into memory (verification only — the point of
/// this subsystem is that the sort itself never does this).
pub fn read_raw<T: ExtItem>(path: &Path) -> Result<Vec<T>> {
    let mut r = RawReader::<T>::open(path)?;
    let mut out = Vec::with_capacity(r.elems() as usize);
    while r.read_block(&mut out, 1 << 16)? > 0 {}
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("flims-fmt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn run_round_trip_in_blocks() {
        let path = tmp("rt.flr");
        let mut w = RunWriter::create(&path).unwrap();
        w.write_block(&[9u32, 8, 7]).unwrap();
        w.write_block(&[]).unwrap();
        w.write_block(&[6, 5]).unwrap();
        let run = w.finish().unwrap();
        assert_eq!(run.elems, 5);
        assert_eq!(run.bytes, RUN_HEADER_BYTES + 20);
        assert_eq!(run.raw_bytes, run.bytes, "raw codec: encoded == raw");

        let mut r = RunReader::<u32>::open(&path).unwrap();
        assert_eq!(r.remaining(), 5);
        assert_eq!(r.codec(), Codec::Raw);
        let mut out = Vec::new();
        assert_eq!(r.read_block(&mut out, 2).unwrap(), 2);
        assert_eq!(r.read_block(&mut out, 100).unwrap(), 3);
        assert_eq!(r.read_block(&mut out, 100).unwrap(), 0);
        assert_eq!(out, vec![9, 8, 7, 6, 5]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn delta_run_round_trip_in_blocks() {
        let path = tmp("rt.flr2");
        let mut w = RunWriter::create_with(&path, Codec::Delta).unwrap();
        w.write_block(&[9u32, 8, 7]).unwrap();
        w.write_block(&[]).unwrap();
        w.write_block(&[6, 5]).unwrap();
        let run = w.finish().unwrap();
        assert_eq!(run.elems, 5);
        assert_eq!(run.raw_bytes, RUN_HEADER_BYTES + 20);
        assert_eq!(run.bytes, std::fs::metadata(&path).unwrap().len());
        // Two write calls → two framed blocks: 2 × (8 + 4 + deltas).
        assert_eq!(run.bytes, RUN_HEADER_BYTES + (8 + 4 + 2) + (8 + 4 + 1));

        let mut r = RunReader::<u32>::open(&path).unwrap();
        assert_eq!(r.remaining(), 5);
        assert_eq!(r.codec(), Codec::Delta);
        let mut out = Vec::new();
        assert_eq!(r.read_block(&mut out, 2).unwrap(), 2);
        while r.read_block(&mut out, 2).unwrap() > 0 {}
        assert_eq!(out, vec![9, 8, 7, 6, 5]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn delta_run_round_trip_kv_payloads() {
        let path = tmp("rt-kv.flr2");
        let recs = vec![Kv::new(9, 100), Kv::new(9, 101), Kv::new(3, 102), Kv::new(3, 103)];
        let mut w = RunWriter::create_with(&path, Codec::Delta).unwrap();
        w.write_block(&recs).unwrap();
        let run = w.finish().unwrap();
        assert_eq!(run.elems, 4);
        assert_eq!(run.raw_bytes, RUN_HEADER_BYTES + 32);
        let mut r = RunReader::<Kv>::open(&path).unwrap();
        let mut out = Vec::new();
        while r.read_block(&mut out, 3).unwrap() > 0 {}
        assert_eq!(out, recs, "payloads must survive the delta wire byte-exactly");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn flr3_run_round_trip_in_blocks() {
        let path = tmp("rt.flr3");
        let mut w = RunWriter::create_with(&path, Codec::Flr3).unwrap();
        w.write_block(&[9u32, 8, 7]).unwrap();
        w.write_block(&[]).unwrap();
        w.write_block(&[6, 5]).unwrap();
        let run = w.finish().unwrap();
        assert_eq!(run.elems, 5);
        assert_eq!(run.raw_bytes, RUN_HEADER_BYTES + 20);
        assert_eq!(run.bytes, std::fs::metadata(&path).unwrap().len());
        // Two write calls → two framed blocks: header + 128·width packed
        // bytes each (width 2 for deltas 0..=2, width 1 for 0..=1).
        assert_eq!(run.bytes, RUN_HEADER_BYTES + (16 + 256) + (16 + 128));

        let mut r = RunReader::<u32>::open(&path).unwrap();
        assert_eq!(r.remaining(), 5);
        assert_eq!(r.codec(), Codec::Flr3);
        let mut out = Vec::new();
        assert_eq!(r.read_block(&mut out, 2).unwrap(), 2);
        while r.read_block(&mut out, 2).unwrap() > 0 {}
        assert_eq!(out, vec![9, 8, 7, 6, 5]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn flr3_run_compresses_and_counts_decode_time() {
        let path = tmp("rt-ctr.flr3");
        let data: Vec<u64> = (0..5000u64).rev().collect();
        let mut w = RunWriter::create_with(&path, Codec::Flr3).unwrap();
        w.write_block(&data).unwrap();
        let run = w.finish().unwrap();
        assert!(run.bytes < run.raw_bytes, "dense u64 run must compress under flr3");

        let ctr = Arc::new(AtomicU64::new(0));
        let mut r = RunReader::<u64>::open_with(&path, Some(Arc::clone(&ctr))).unwrap();
        let mut out = Vec::new();
        while r.read_block(&mut out, 512).unwrap() > 0 {}
        assert_eq!(out, data);
        assert!(ctr.load(Ordering::Relaxed) > 0, "decode time must be counted");

        // The scalar tier decodes the same file to the same bytes.
        let mut r =
            RunReader::<u64>::open_with_kernel(&path, None, MergeKernel::Scalar).unwrap();
        let mut out2 = Vec::new();
        while r.read_block(&mut out2, 777).unwrap() > 0 {}
        assert_eq!(out2, data);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn flr3_writer_rejects_payload_dtypes() {
        let path = tmp("reject.flr3");
        let err =
            format!("{:#}", RunWriter::<Kv>::create_with(&path, Codec::Flr3).unwrap_err());
        assert!(err.contains("payload"), "{err}");
        let err =
            format!("{:#}", RunWriter::<Kv64>::create_with(&path, Codec::Flr3).unwrap_err());
        assert!(err.contains("payload"), "{err}");
    }

    #[test]
    fn flr3_reader_rejects_non_descending_runs() {
        // The writer encodes whatever it is given; the reader enforces
        // the descending invariant spilled runs always satisfy.
        let path = tmp("asc.flr3");
        let mut w = RunWriter::create_with(&path, Codec::Flr3).unwrap();
        w.write_block(&[1u32, 2, 3]).unwrap();
        w.finish().unwrap();
        let mut r = RunReader::<u32>::open(&path).unwrap();
        let mut out = Vec::new();
        let err = format!("{:#}", r.read_block(&mut out, 10).unwrap_err());
        assert!(err.contains("not descending"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn delta_run_decode_counter_accumulates() {
        let path = tmp("rt-ctr.flr2");
        let data: Vec<u64> = (0..5000u64).rev().collect();
        let mut w = RunWriter::create_with(&path, Codec::Delta).unwrap();
        w.write_block(&data).unwrap();
        let run = w.finish().unwrap();
        assert!(run.bytes < run.raw_bytes, "dense u64 run must compress");

        let ctr = Arc::new(AtomicU64::new(0));
        let mut r = RunReader::<u64>::open_with(&path, Some(Arc::clone(&ctr))).unwrap();
        let mut out = Vec::new();
        while r.read_block(&mut out, 512).unwrap() > 0 {}
        assert_eq!(out, data);
        assert!(ctr.load(Ordering::Relaxed) > 0, "decode time must be counted");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn run_round_trip_kv_and_kv64() {
        let path = tmp("rt-kv.flr");
        let recs = vec![Kv::new(9, 100), Kv::new(9, 101), Kv::new(3, 102)];
        let mut w = RunWriter::create(&path).unwrap();
        w.write_block(&recs).unwrap();
        let run = w.finish().unwrap();
        assert_eq!(run.elems, 3);
        assert_eq!(run.bytes, RUN_HEADER_BYTES + 3 * 8);
        let mut r = RunReader::<Kv>::open(&path).unwrap();
        let mut out = Vec::new();
        assert_eq!(r.read_block(&mut out, 100).unwrap(), 3);
        assert_eq!(out, recs, "payloads must survive the wire byte-exactly");
        // The same bytes do NOT open as a Kv64 run (size mismatch).
        let err = format!("{:#}", RunReader::<Kv64>::open(&path).unwrap_err());
        assert!(err.contains("truncated run"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn f32_wire_format_is_plain_ieee_bits() {
        let path = tmp("rt.f32");
        let vals = [1.5f32, -2.25, 0.0, -0.0, f32::INFINITY, f32::NEG_INFINITY];
        let keys: Vec<F32Key> = vals.iter().map(|&x| F32Key::from_f32(x)).collect();
        write_raw(&path, &keys).unwrap();
        // Bytes on disk are the raw little-endian f32 values.
        let bytes = std::fs::read(&path).unwrap();
        let expect: Vec<u8> = vals.iter().flat_map(|x| x.to_bits().to_le_bytes()).collect();
        assert_eq!(bytes, expect);
        // And they decode back to the identical keys (bit-exact).
        assert_eq!(read_raw::<F32Key>(&path).unwrap(), keys);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn key_payload_split_round_trips_every_dtype() {
        // from_parts(key_bits, encode_payload bytes) must be the
        // identity for every ExtItem — the delta codec's correctness
        // precondition.
        fn check<T: ExtItem + PartialEq>(xs: &[T]) {
            for &x in xs {
                let mut payload = vec![0u8; T::WIRE_BYTES - T::KEY_BYTES];
                x.encode_payload(&mut payload);
                assert!(T::from_parts(x.key_bits(), &payload) == x, "{x:?}");
            }
        }
        check(&[0u32, 1, u32::MAX, 0x8000_0001]);
        check(&[0u64, 1, u64::MAX]);
        check(&[i32::MIN, -1, 0, 1, i32::MAX]);
        check(&[i64::MIN, -1, 0, 1, i64::MAX]);
        check(&[Kv::new(7, 9), Kv::new(u32::MAX, 0), Kv::new(0, u32::MAX)]);
        check(&[Kv64 { key: u64::MAX, val: 1 }, Kv64 { key: 0, val: u64::MAX }]);
        check(&[F32Key::from_f32(-1.5), F32Key::from_f32(f32::INFINITY)]);
    }

    #[test]
    fn signed_key_bits_preserve_order() {
        // The bias map must be monotone: descending signed records
        // become descending key_bits, or FLR3's descending enforcement
        // and the delta codec's framing would misfire.
        let desc32 = [i32::MAX, 1, 0, -1, i32::MIN + 1, i32::MIN];
        let bits: Vec<u64> = desc32.iter().map(|&x| ExtItem::key_bits(x)).collect();
        assert!(bits.windows(2).all(|w| w[0] > w[1]), "{bits:?}");
        let desc64 = [i64::MAX, 1, 0, -1, i64::MIN + 1, i64::MIN];
        let bits: Vec<u64> = desc64.iter().map(|&x| ExtItem::key_bits(x)).collect();
        assert!(bits.windows(2).all(|w| w[0] > w[1]), "{bits:?}");
    }

    #[test]
    fn signed_runs_round_trip_every_codec() {
        let data: Vec<i32> = vec![i32::MAX, 77, 0, -1, -500, i32::MIN];
        for codec in [Codec::Raw, Codec::Delta, Codec::Flr3] {
            let path = tmp(&format!("signed-{}.flr", codec.name()));
            let mut w = RunWriter::create_with(&path, codec).unwrap();
            w.write_block(&data).unwrap();
            w.finish().unwrap();
            let mut r = RunReader::<i32>::open(&path).unwrap();
            let mut out = Vec::new();
            while r.read_block(&mut out, 4).unwrap() > 0 {}
            assert_eq!(out, data, "codec {}", codec.name());
            std::fs::remove_file(&path).unwrap();
        }
        let data: Vec<i64> = vec![i64::MAX, 1 << 40, 0, -1, i64::MIN];
        for codec in [Codec::Raw, Codec::Delta, Codec::Flr3] {
            let path = tmp(&format!("signed64-{}.flr", codec.name()));
            let mut w = RunWriter::create_with(&path, codec).unwrap();
            w.write_block(&data).unwrap();
            w.finish().unwrap();
            let mut r = RunReader::<i64>::open(&path).unwrap();
            let mut out = Vec::new();
            while r.read_block(&mut out, 4).unwrap() > 0 {}
            assert_eq!(out, data, "codec {}", codec.name());
            std::fs::remove_file(&path).unwrap();
        }
    }

    #[test]
    fn run_reader_rejects_bad_magic_and_truncation() {
        let path = tmp("bad.flr");
        std::fs::write(&path, b"NOPE\x05\x00\x00\x00\x00\x00\x00\x00").unwrap();
        let err = format!("{:#}", RunReader::<u32>::open(&path).unwrap_err());
        assert!(err.contains("bad magic"), "{err}");

        // Valid magic, count claims more data than present.
        let mut bytes = RUN_MAGIC.to_vec();
        bytes.extend_from_slice(&10u64.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = format!("{:#}", RunReader::<u32>::open(&path).unwrap_err());
        assert!(err.contains("truncated run"), "{err}");

        // Corrupt header whose count would overflow count*WIRE_BYTES:
        // must be a clean "truncated run" error, never a wrap/panic.
        let mut bytes = RUN_MAGIC.to_vec();
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = format!("{:#}", RunReader::<u32>::open(&path).unwrap_err());
        assert!(err.contains("truncated run"), "{err}");

        // Wrapping check: count = 2^62 wraps to 12 bytes in unchecked
        // math, which would exactly match a header-only file.
        let mut bytes = RUN_MAGIC.to_vec();
        bytes.extend_from_slice(&(1u64 << 62).to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = format!("{:#}", RunReader::<u32>::open(&path).unwrap_err());
        assert!(err.contains("truncated run"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn unsealed_writer_drop_removes_partial_file() {
        for codec in [Codec::Raw, Codec::Delta, Codec::Flr3] {
            let path = tmp(&format!("dropped-{}.flr", codec.name()));
            let mut w = RunWriter::create_with(&path, codec).unwrap();
            w.write_block(&[9u32, 5, 1]).unwrap();
            assert!(path.exists());
            drop(w);
            assert!(!path.exists(), "{}: unsealed writer must remove its partial file", codec.name());

            // A sealed run survives its writer.
            let mut w = RunWriter::create_with(&path, codec).unwrap();
            w.write_block(&[9u32, 5, 1]).unwrap();
            let run = w.finish().unwrap();
            assert!(run.path.exists(), "{}: sealed run must survive", codec.name());
            std::fs::remove_file(&run.path).unwrap();
        }
    }

    #[test]
    fn sub_header_files_report_run_truncated() {
        let path = tmp("stub.flr");
        for keep in 0..RUN_HEADER_BYTES as usize {
            std::fs::write(&path, &b"FLR1\x00\x00\x00\x00\x00\x00\x00\x00"[..keep]).unwrap();
            let err = format!("{:#}", RunReader::<u32>::open(&path).unwrap_err());
            assert!(err.contains("run truncated:"), "keep={keep}: {err}");
            assert!(err.contains("stub.flr"), "keep={keep}: {err}");
        }
        // Exactly one header claiming zero elements is a legitimate
        // empty run, not a truncation.
        std::fs::write(&path, b"FLR1\x00\x00\x00\x00\x00\x00\x00\x00").unwrap();
        assert!(RunReader::<u32>::open(&path).is_ok());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn raw_round_trip_and_size_check() {
        let path = tmp("data.u32");
        let data: Vec<u32> = (0..1000).rev().collect();
        assert_eq!(write_raw(&path, &data).unwrap(), 1000);
        let back = read_raw::<u32>(&path).unwrap();
        assert_eq!(back, data);

        let mut r = RawReader::<u32>::open(&path).unwrap();
        assert_eq!(r.elems(), 1000);
        let mut out = Vec::new();
        assert_eq!(r.read_block(&mut out, 64).unwrap(), 64);
        assert_eq!(out, data[..64]);

        std::fs::write(&path, [1u8, 2, 3]).unwrap();
        let err = format!("{:#}", RawReader::<u32>::open(&path).unwrap_err());
        assert!(err.contains("not a multiple of 4"), "{err}");
        // 4 bytes are one u32 but not one Kv (8-byte records).
        std::fs::write(&path, [1u8, 2, 3, 4]).unwrap();
        assert!(RawReader::<u32>::open(&path).is_ok());
        let err = format!("{:#}", RawReader::<Kv>::open(&path).unwrap_err());
        assert!(err.contains("not a multiple of 8"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_run_and_empty_raw() {
        let path = tmp("empty.flr");
        let run = RunWriter::<u32>::create(&path).unwrap().finish().unwrap();
        assert_eq!(run.elems, 0);
        let mut r = RunReader::<u32>::open(&path).unwrap();
        let mut out = Vec::new();
        assert_eq!(r.read_block(&mut out, 10).unwrap(), 0);
        std::fs::remove_file(&path).unwrap();

        // An empty delta or flr3 run is just a header too.
        for codec in [Codec::Delta, Codec::Flr3] {
            let path = tmp(&format!("empty-{}.flr", codec.name()));
            let run = RunWriter::<u32>::create_with(&path, codec).unwrap().finish().unwrap();
            assert_eq!(run.elems, 0);
            assert_eq!(run.bytes, RUN_HEADER_BYTES);
            let mut r = RunReader::<u32>::open(&path).unwrap();
            let mut out = Vec::new();
            assert_eq!(r.read_block(&mut out, 10).unwrap(), 0);
            std::fs::remove_file(&path).unwrap();
        }

        let path = tmp("empty.u32");
        write_raw::<u32>(&path, &[]).unwrap();
        assert_eq!(read_raw::<u32>(&path).unwrap(), Vec::<u32>::new());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn dtype_parse_and_names() {
        for d in Dtype::ALL {
            assert_eq!(Dtype::parse(d.name()).unwrap(), d);
            assert!(Dtype::ALL_NAMES.split('|').any(|n| n == d.name()), "{}", d.name());
        }
        assert_eq!(Dtype::ALL_NAMES.split('|').count(), Dtype::ALL.len());
        assert_eq!(Dtype::Kv64.wire_bytes(), 16);
        assert_eq!(Dtype::F32.wire_bytes(), 4);
        assert_eq!(Dtype::I32.wire_bytes(), 4);
        assert_eq!(Dtype::I64.wire_bytes(), 8);
        let err = Dtype::parse("f64").unwrap_err();
        assert!(err.contains("unknown dtype"), "{err}");
        assert!(err.contains(Dtype::ALL_NAMES), "error must enumerate names: {err}");
        let err = parse_dtype_arg("f64").unwrap_err();
        assert!(err.starts_with("dtype argument:"), "{err}");
    }

    #[test]
    fn effective_kernel_is_scalar_when_forced_and_tier_named_otherwise() {
        let valid = ["scalar", "simd-sse2", "simd-avx2", "simd-neon"];
        for d in Dtype::ALL {
            assert_eq!(d.effective_kernel(MergeKernel::Scalar), "scalar", "{}", d.name());
            let eff = d.effective_kernel(MergeKernel::Simd);
            assert!(valid.contains(&eff), "{}: {eff}", d.name());
            assert_eq!(d.effective_kernel(MergeKernel::Auto), eff, "{}", d.name());
        }
        // Same lane width → same effective tier.
        let k = MergeKernel::Auto;
        assert_eq!(Dtype::I32.effective_kernel(k), Dtype::U32.effective_kernel(k));
        assert_eq!(Dtype::F32.effective_kernel(k), Dtype::U32.effective_kernel(k));
        for d in [Dtype::I64, Dtype::Kv, Dtype::Kv64] {
            assert_eq!(d.effective_kernel(k), Dtype::U64.effective_kernel(k), "{}", d.name());
        }
    }
}
