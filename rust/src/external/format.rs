//! On-disk formats for the external-sort subsystem, generic over the
//! record type.
//!
//! Every supported dataset type implements [`ExtItem`]: a fixed-width
//! little-endian wire encoding plus the in-memory sort used for phase-1
//! runs (stable for payload records — the paper's §6 tie-record
//! guarantee holds out-of-core, not just in RAM). Two layouts share the
//! encoding:
//!
//! * **Run files** ([`RunWriter`] / [`RunReader`]) — length-prefixed:
//!   a 4-byte magic (`FLR1`) and a u64 element count, then the payload.
//!   The count is patched into the header on [`RunWriter::finish`], so a
//!   truncated or crashed spill is detectable on open.
//! * **Raw datasets** ([`RawReader`] / [`RawWriter`]) — headerless
//!   little-endian records, the input/output format of `sort_file` (and
//!   what the `sortfile` CLI/service commands operate on). For `f32`
//!   datasets the wire format is plain IEEE-754 bits; the in-memory
//!   representation is the order-preserving [`F32Key`].

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::marker::PhantomData;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::flims::lanes::merge_desc_fast;
use crate::flims::sort::{sort_desc, SortConfig};
use crate::flims::stable::{merge_stable_into, sort_stable_desc};
use crate::key::{F32Key, Item, Kv, Kv64};

/// Magic prefix of a spilled run file.
pub const RUN_MAGIC: [u8; 4] = *b"FLR1";
/// Header size: magic + u64 element count.
pub const RUN_HEADER_BYTES: u64 = 12;

/// Dataset element type selector — the `dtype` argument of `sortfile`
/// and the `[external] dtype` config knob, mapping onto the [`ExtItem`]
/// implementations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    U32,
    U64,
    Kv,
    Kv64,
    F32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Self, String> {
        Ok(match s {
            "u32" => Dtype::U32,
            "u64" => Dtype::U64,
            "kv" => Dtype::Kv,
            "kv64" => Dtype::Kv64,
            "f32" => Dtype::F32,
            other => {
                return Err(format!(
                    "unknown dtype '{other}' (expected u32|u64|kv|kv64|f32)"
                ))
            }
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Dtype::U32 => "u32",
            Dtype::U64 => "u64",
            Dtype::Kv => "kv",
            Dtype::Kv64 => "kv64",
            Dtype::F32 => "f32",
        }
    }

    /// Bytes per record on disk.
    pub fn wire_bytes(self) -> usize {
        match self {
            Dtype::U32 | Dtype::F32 => 4,
            Dtype::U64 | Dtype::Kv => 8,
            Dtype::Kv64 => 16,
        }
    }
}

/// A record the external sort can spill, merge, and stream: an [`Item`]
/// with a fixed-width little-endian wire format, a phase-1 in-memory
/// sort, and the 2-way merge the tree nodes run. Both `sort_run` and
/// `merge_into` must be **stable** (A/earlier-input wins ties) for types
/// with payloads distinct from their key (`Kv`, `Kv64`); plain keys use
/// the faster untagged FLiMS lanes because equal keys are
/// indistinguishable, so the descending value sequence is unique.
pub trait ExtItem: Item {
    /// Bytes per record on disk.
    const WIRE_BYTES: usize;
    /// The dtype tag this implementation answers to.
    const DTYPE: Dtype;
    /// Encode into exactly `WIRE_BYTES` bytes.
    fn encode(self, out: &mut [u8]);
    /// Decode from exactly `WIRE_BYTES` bytes.
    fn decode(b: &[u8]) -> Self;
    /// Sort a phase-1 run descending in memory.
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig);
    /// Merge two descending-sorted slices, appending to `out` — the
    /// per-block merge of every tree node.
    fn merge_into(a: &[Self], b: &[Self], w: usize, out: &mut Vec<Self>);
}

impl ExtItem for u32 {
    const WIRE_BYTES: usize = 4;
    const DTYPE: Dtype = Dtype::U32;
    fn encode(self, out: &mut [u8]) {
        out.copy_from_slice(&self.to_le_bytes());
    }
    fn decode(b: &[u8]) -> Self {
        u32::from_le_bytes(b.try_into().expect("4-byte record"))
    }
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig) {
        sort_desc(buf, cfg);
    }
    fn merge_into(a: &[Self], b: &[Self], w: usize, out: &mut Vec<Self>) {
        merge_desc_fast(a, b, w, out);
    }
}

impl ExtItem for u64 {
    const WIRE_BYTES: usize = 8;
    const DTYPE: Dtype = Dtype::U64;
    fn encode(self, out: &mut [u8]) {
        out.copy_from_slice(&self.to_le_bytes());
    }
    fn decode(b: &[u8]) -> Self {
        u64::from_le_bytes(b.try_into().expect("8-byte record"))
    }
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig) {
        sort_desc(buf, cfg);
    }
    fn merge_into(a: &[Self], b: &[Self], w: usize, out: &mut Vec<Self>) {
        merge_desc_fast(a, b, w, out);
    }
}

impl ExtItem for F32Key {
    const WIRE_BYTES: usize = 4;
    const DTYPE: Dtype = Dtype::F32;
    fn encode(self, out: &mut [u8]) {
        // On disk: the plain IEEE-754 bits, so datasets interoperate
        // with anything that writes little-endian f32.
        out.copy_from_slice(&self.to_f32().to_bits().to_le_bytes());
    }
    fn decode(b: &[u8]) -> Self {
        F32Key::from_f32(f32::from_bits(u32::from_le_bytes(
            b.try_into().expect("4-byte record"),
        )))
    }
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig) {
        sort_desc(buf, cfg);
    }
    fn merge_into(a: &[Self], b: &[Self], w: usize, out: &mut Vec<Self>) {
        merge_desc_fast(a, b, w, out);
    }
}

impl ExtItem for Kv {
    const WIRE_BYTES: usize = 8;
    const DTYPE: Dtype = Dtype::Kv;
    fn encode(self, out: &mut [u8]) {
        out[..4].copy_from_slice(&self.key.to_le_bytes());
        out[4..].copy_from_slice(&self.val.to_le_bytes());
    }
    fn decode(b: &[u8]) -> Self {
        Kv {
            key: u32::from_le_bytes(b[..4].try_into().expect("8-byte record")),
            val: u32::from_le_bytes(b[4..].try_into().expect("8-byte record")),
        }
    }
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig) {
        sort_stable_desc(buf, cfg);
    }
    fn merge_into(a: &[Self], b: &[Self], w: usize, out: &mut Vec<Self>) {
        merge_stable_into(a, b, w, out);
    }
}

impl ExtItem for Kv64 {
    const WIRE_BYTES: usize = 16;
    const DTYPE: Dtype = Dtype::Kv64;
    fn encode(self, out: &mut [u8]) {
        out[..8].copy_from_slice(&self.key.to_le_bytes());
        out[8..].copy_from_slice(&self.val.to_le_bytes());
    }
    fn decode(b: &[u8]) -> Self {
        Kv64 {
            key: u64::from_le_bytes(b[..8].try_into().expect("16-byte record")),
            val: u64::from_le_bytes(b[8..].try_into().expect("16-byte record")),
        }
    }
    fn sort_run(buf: &mut Vec<Self>, cfg: SortConfig) {
        sort_stable_desc(buf, cfg);
    }
    fn merge_into(a: &[Self], b: &[Self], w: usize, out: &mut Vec<Self>) {
        merge_stable_into(a, b, w, out);
    }
}

/// A finished spilled run: its path and sizes, as tracked by the
/// `SpillManager`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RunFile {
    pub path: PathBuf,
    /// Payload element count.
    pub elems: u64,
    /// Total file size (header + payload).
    pub bytes: u64,
}

fn encode_block<T: ExtItem>(xs: &[T], byte_buf: &mut Vec<u8>) {
    // resize without clear(): only growth is zero-filled, so the
    // steady-state (same-sized blocks) never memsets before encoding.
    byte_buf.resize(xs.len() * T::WIRE_BYTES, 0);
    for (x, chunk) in xs.iter().zip(byte_buf.chunks_exact_mut(T::WIRE_BYTES)) {
        x.encode(chunk);
    }
}

fn read_record_block<T: ExtItem>(
    inp: &mut BufReader<File>,
    remaining: &mut u64,
    byte_buf: &mut Vec<u8>,
    out: &mut Vec<T>,
    max: usize,
) -> Result<usize> {
    let take = (*remaining).min(max as u64) as usize;
    if take == 0 {
        return Ok(0);
    }
    byte_buf.resize(take * T::WIRE_BYTES, 0);
    inp.read_exact(byte_buf)?;
    out.reserve(take);
    for c in byte_buf.chunks_exact(T::WIRE_BYTES) {
        out.push(T::decode(c));
    }
    *remaining -= take as u64;
    Ok(take)
}

/// Streaming writer for one run file.
pub struct RunWriter<T: ExtItem> {
    out: BufWriter<File>,
    path: PathBuf,
    count: u64,
    byte_buf: Vec<u8>,
    _elem: PhantomData<T>,
}

impl<T: ExtItem> RunWriter<T> {
    /// Create `path`, writing a header with a zero count placeholder.
    pub fn create(path: &Path) -> Result<Self> {
        let f = File::create(path)
            .with_context(|| format!("creating run file {}", path.display()))?;
        let mut out = BufWriter::new(f);
        out.write_all(&RUN_MAGIC)?;
        out.write_all(&0u64.to_le_bytes())?;
        Ok(RunWriter {
            out,
            path: path.to_path_buf(),
            count: 0,
            byte_buf: Vec::new(),
            _elem: PhantomData,
        })
    }

    /// The file this writer is producing.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append a block of elements (need not be the whole run).
    pub fn write_block(&mut self, xs: &[T]) -> Result<()> {
        encode_block(xs, &mut self.byte_buf);
        self.out.write_all(&self.byte_buf)?;
        self.count += xs.len() as u64;
        Ok(())
    }

    /// Flush, patch the element count into the header, and return the
    /// finished run's metadata.
    pub fn finish(mut self) -> Result<RunFile> {
        self.out.flush()?;
        let f = self.out.get_mut();
        f.seek(SeekFrom::Start(RUN_MAGIC.len() as u64))?;
        f.write_all(&self.count.to_le_bytes())?;
        Ok(RunFile {
            bytes: RUN_HEADER_BYTES + self.count * T::WIRE_BYTES as u64,
            path: self.path,
            elems: self.count,
        })
    }
}

/// Streaming reader for one run file.
pub struct RunReader<T: ExtItem> {
    inp: BufReader<File>,
    remaining: u64,
    byte_buf: Vec<u8>,
    _elem: PhantomData<T>,
}

impl<T: ExtItem> RunReader<T> {
    pub fn open(path: &Path) -> Result<Self> {
        let f = File::open(path)
            .with_context(|| format!("opening run file {}", path.display()))?;
        let len = f.metadata()?.len();
        let mut inp = BufReader::new(f);
        let mut magic = [0u8; 4];
        inp.read_exact(&mut magic)
            .map_err(|e| anyhow!("{}: reading run header: {e}", path.display()))?;
        if magic != RUN_MAGIC {
            bail!("{}: not a run file (bad magic {magic:?})", path.display());
        }
        let mut cnt = [0u8; 8];
        inp.read_exact(&mut cnt)
            .map_err(|e| anyhow!("{}: reading run header: {e}", path.display()))?;
        let remaining = u64::from_le_bytes(cnt);
        // The count is untrusted input: checked math so a corrupt
        // header reports "truncated run" instead of overflowing.
        let expect = remaining
            .checked_mul(T::WIRE_BYTES as u64)
            .and_then(|payload| payload.checked_add(RUN_HEADER_BYTES));
        if expect != Some(len) {
            bail!(
                "{}: truncated run (header claims {} {} elements, file is {} bytes)",
                path.display(),
                remaining,
                T::DTYPE.name(),
                len
            );
        }
        Ok(RunReader { inp, remaining, byte_buf: Vec::new(), _elem: PhantomData })
    }

    /// Elements not yet read.
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// Append up to `max` elements to `out`; returns how many were read
    /// (0 = exhausted).
    pub fn read_block(&mut self, out: &mut Vec<T>, max: usize) -> Result<usize> {
        read_record_block(&mut self.inp, &mut self.remaining, &mut self.byte_buf, out, max)
    }
}

/// Streaming reader for a headerless little-endian dataset.
pub struct RawReader<T: ExtItem> {
    inp: BufReader<File>,
    total: u64,
    remaining: u64,
    byte_buf: Vec<u8>,
    _elem: PhantomData<T>,
}

impl<T: ExtItem> RawReader<T> {
    pub fn open(path: &Path) -> Result<Self> {
        let f = File::open(path)
            .with_context(|| format!("opening dataset {}", path.display()))?;
        let len = f.metadata()?.len();
        if len % T::WIRE_BYTES as u64 != 0 {
            bail!(
                "{}: size {} is not a multiple of {} (raw little-endian {} expected)",
                path.display(),
                len,
                T::WIRE_BYTES,
                T::DTYPE.name()
            );
        }
        let total = len / T::WIRE_BYTES as u64;
        Ok(RawReader {
            inp: BufReader::new(f),
            total,
            remaining: total,
            byte_buf: Vec::new(),
            _elem: PhantomData,
        })
    }

    /// Total elements in the file.
    pub fn elems(&self) -> u64 {
        self.total
    }

    /// Append up to `max` elements to `out`; 0 = exhausted.
    pub fn read_block(&mut self, out: &mut Vec<T>, max: usize) -> Result<usize> {
        read_record_block(&mut self.inp, &mut self.remaining, &mut self.byte_buf, out, max)
    }
}

/// Streaming writer for a headerless little-endian dataset.
pub struct RawWriter<T: ExtItem> {
    out: BufWriter<File>,
    count: u64,
    byte_buf: Vec<u8>,
    _elem: PhantomData<T>,
}

impl<T: ExtItem> RawWriter<T> {
    pub fn create(path: &Path) -> Result<Self> {
        let f = File::create(path)
            .with_context(|| format!("creating output {}", path.display()))?;
        Ok(RawWriter { out: BufWriter::new(f), count: 0, byte_buf: Vec::new(), _elem: PhantomData })
    }

    pub fn write_block(&mut self, xs: &[T]) -> Result<()> {
        encode_block(xs, &mut self.byte_buf);
        self.out.write_all(&self.byte_buf)?;
        self.count += xs.len() as u64;
        Ok(())
    }

    /// Flush and return the element count written.
    pub fn finish(mut self) -> Result<u64> {
        self.out.flush()?;
        Ok(self.count)
    }
}

/// Write a whole dataset in one call (tests, CLI `--gen`).
pub fn write_raw<T: ExtItem>(path: &Path, xs: &[T]) -> Result<u64> {
    let mut w = RawWriter::create(path)?;
    w.write_block(xs)?;
    w.finish()
}

/// Read a whole dataset into memory (verification only — the point of
/// this subsystem is that the sort itself never does this).
pub fn read_raw<T: ExtItem>(path: &Path) -> Result<Vec<T>> {
    let mut r = RawReader::<T>::open(path)?;
    let mut out = Vec::with_capacity(r.elems() as usize);
    while r.read_block(&mut out, 1 << 16)? > 0 {}
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("flims-fmt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn run_round_trip_in_blocks() {
        let path = tmp("rt.flr");
        let mut w = RunWriter::create(&path).unwrap();
        w.write_block(&[9u32, 8, 7]).unwrap();
        w.write_block(&[]).unwrap();
        w.write_block(&[6, 5]).unwrap();
        let run = w.finish().unwrap();
        assert_eq!(run.elems, 5);
        assert_eq!(run.bytes, RUN_HEADER_BYTES + 20);

        let mut r = RunReader::<u32>::open(&path).unwrap();
        assert_eq!(r.remaining(), 5);
        let mut out = Vec::new();
        assert_eq!(r.read_block(&mut out, 2).unwrap(), 2);
        assert_eq!(r.read_block(&mut out, 100).unwrap(), 3);
        assert_eq!(r.read_block(&mut out, 100).unwrap(), 0);
        assert_eq!(out, vec![9, 8, 7, 6, 5]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn run_round_trip_kv_and_kv64() {
        let path = tmp("rt-kv.flr");
        let recs = vec![Kv::new(9, 100), Kv::new(9, 101), Kv::new(3, 102)];
        let mut w = RunWriter::create(&path).unwrap();
        w.write_block(&recs).unwrap();
        let run = w.finish().unwrap();
        assert_eq!(run.elems, 3);
        assert_eq!(run.bytes, RUN_HEADER_BYTES + 3 * 8);
        let mut r = RunReader::<Kv>::open(&path).unwrap();
        let mut out = Vec::new();
        assert_eq!(r.read_block(&mut out, 100).unwrap(), 3);
        assert_eq!(out, recs, "payloads must survive the wire byte-exactly");
        // The same bytes do NOT open as a Kv64 run (size mismatch).
        let err = format!("{:#}", RunReader::<Kv64>::open(&path).unwrap_err());
        assert!(err.contains("truncated run"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn f32_wire_format_is_plain_ieee_bits() {
        let path = tmp("rt.f32");
        let vals = [1.5f32, -2.25, 0.0, -0.0, f32::INFINITY, f32::NEG_INFINITY];
        let keys: Vec<F32Key> = vals.iter().map(|&x| F32Key::from_f32(x)).collect();
        write_raw(&path, &keys).unwrap();
        // Bytes on disk are the raw little-endian f32 values.
        let bytes = std::fs::read(&path).unwrap();
        let expect: Vec<u8> = vals.iter().flat_map(|x| x.to_bits().to_le_bytes()).collect();
        assert_eq!(bytes, expect);
        // And they decode back to the identical keys (bit-exact).
        assert_eq!(read_raw::<F32Key>(&path).unwrap(), keys);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn run_reader_rejects_bad_magic_and_truncation() {
        let path = tmp("bad.flr");
        std::fs::write(&path, b"NOPE\x05\x00\x00\x00\x00\x00\x00\x00").unwrap();
        let err = format!("{:#}", RunReader::<u32>::open(&path).unwrap_err());
        assert!(err.contains("bad magic"), "{err}");

        // Valid magic, count claims more data than present.
        let mut bytes = RUN_MAGIC.to_vec();
        bytes.extend_from_slice(&10u64.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = format!("{:#}", RunReader::<u32>::open(&path).unwrap_err());
        assert!(err.contains("truncated run"), "{err}");

        // Corrupt header whose count would overflow count*WIRE_BYTES:
        // must be a clean "truncated run" error, never a wrap/panic.
        let mut bytes = RUN_MAGIC.to_vec();
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = format!("{:#}", RunReader::<u32>::open(&path).unwrap_err());
        assert!(err.contains("truncated run"), "{err}");

        // Wrapping check: count = 2^62 wraps to 12 bytes in unchecked
        // math, which would exactly match a header-only file.
        let mut bytes = RUN_MAGIC.to_vec();
        bytes.extend_from_slice(&(1u64 << 62).to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = format!("{:#}", RunReader::<u32>::open(&path).unwrap_err());
        assert!(err.contains("truncated run"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn raw_round_trip_and_size_check() {
        let path = tmp("data.u32");
        let data: Vec<u32> = (0..1000).rev().collect();
        assert_eq!(write_raw(&path, &data).unwrap(), 1000);
        let back = read_raw::<u32>(&path).unwrap();
        assert_eq!(back, data);

        let mut r = RawReader::<u32>::open(&path).unwrap();
        assert_eq!(r.elems(), 1000);
        let mut out = Vec::new();
        assert_eq!(r.read_block(&mut out, 64).unwrap(), 64);
        assert_eq!(out, data[..64]);

        std::fs::write(&path, [1u8, 2, 3]).unwrap();
        let err = format!("{:#}", RawReader::<u32>::open(&path).unwrap_err());
        assert!(err.contains("not a multiple of 4"), "{err}");
        // 4 bytes are one u32 but not one Kv (8-byte records).
        std::fs::write(&path, [1u8, 2, 3, 4]).unwrap();
        assert!(RawReader::<u32>::open(&path).is_ok());
        let err = format!("{:#}", RawReader::<Kv>::open(&path).unwrap_err());
        assert!(err.contains("not a multiple of 8"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_run_and_empty_raw() {
        let path = tmp("empty.flr");
        let run = RunWriter::<u32>::create(&path).unwrap().finish().unwrap();
        assert_eq!(run.elems, 0);
        let mut r = RunReader::<u32>::open(&path).unwrap();
        let mut out = Vec::new();
        assert_eq!(r.read_block(&mut out, 10).unwrap(), 0);
        std::fs::remove_file(&path).unwrap();

        let path = tmp("empty.u32");
        write_raw::<u32>(&path, &[]).unwrap();
        assert_eq!(read_raw::<u32>(&path).unwrap(), Vec::<u32>::new());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn dtype_parse_and_names() {
        for d in [Dtype::U32, Dtype::U64, Dtype::Kv, Dtype::Kv64, Dtype::F32] {
            assert_eq!(Dtype::parse(d.name()).unwrap(), d);
        }
        assert_eq!(Dtype::Kv64.wire_bytes(), 16);
        assert_eq!(Dtype::F32.wire_bytes(), 4);
        let err = Dtype::parse("f64").unwrap_err();
        assert!(err.contains("unknown dtype"), "{err}");
    }
}
