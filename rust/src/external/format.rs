//! On-disk formats for the external-sort subsystem.
//!
//! Two layouts, both little-endian u32 payloads with buffered I/O:
//!
//! * **Run files** ([`RunWriter`] / [`RunReader`]) — length-prefixed:
//!   a 4-byte magic (`FLR1`) and a u64 element count, then the payload.
//!   The count is patched into the header on [`RunWriter::finish`], so a
//!   truncated or crashed spill is detectable on open.
//! * **Raw datasets** ([`RawReader`] / [`RawWriter`]) — headerless u32
//!   little-endian, the input/output format of `sort_file` (and what the
//!   `sortfile` CLI/service commands operate on).

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Magic prefix of a spilled run file.
pub const RUN_MAGIC: [u8; 4] = *b"FLR1";
/// Header size: magic + u64 element count.
pub const RUN_HEADER_BYTES: u64 = 12;
/// Bytes per element (u32 keys).
pub const ELEM_BYTES: usize = 4;

/// A finished spilled run: its path and sizes, as tracked by the
/// `SpillManager`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RunFile {
    pub path: PathBuf,
    /// Payload element count.
    pub elems: u64,
    /// Total file size (header + payload).
    pub bytes: u64,
}

/// Streaming writer for one run file.
pub struct RunWriter {
    out: BufWriter<File>,
    path: PathBuf,
    count: u64,
    byte_buf: Vec<u8>,
}

impl RunWriter {
    /// Create `path`, writing a header with a zero count placeholder.
    pub fn create(path: &Path) -> Result<Self> {
        let f = File::create(path)
            .with_context(|| format!("creating run file {}", path.display()))?;
        let mut out = BufWriter::new(f);
        out.write_all(&RUN_MAGIC)?;
        out.write_all(&0u64.to_le_bytes())?;
        Ok(RunWriter { out, path: path.to_path_buf(), count: 0, byte_buf: Vec::new() })
    }

    /// Append a block of elements (need not be the whole run).
    pub fn write_block(&mut self, xs: &[u32]) -> Result<()> {
        self.byte_buf.clear();
        self.byte_buf.reserve(xs.len() * ELEM_BYTES);
        for &x in xs {
            self.byte_buf.extend_from_slice(&x.to_le_bytes());
        }
        self.out.write_all(&self.byte_buf)?;
        self.count += xs.len() as u64;
        Ok(())
    }

    /// Flush, patch the element count into the header, and return the
    /// finished run's metadata.
    pub fn finish(mut self) -> Result<RunFile> {
        self.out.flush()?;
        let f = self.out.get_mut();
        f.seek(SeekFrom::Start(RUN_MAGIC.len() as u64))?;
        f.write_all(&self.count.to_le_bytes())?;
        Ok(RunFile {
            bytes: RUN_HEADER_BYTES + self.count * ELEM_BYTES as u64,
            path: self.path,
            elems: self.count,
        })
    }
}

/// Streaming reader for one run file.
pub struct RunReader {
    inp: BufReader<File>,
    remaining: u64,
    byte_buf: Vec<u8>,
}

impl RunReader {
    pub fn open(path: &Path) -> Result<Self> {
        let f = File::open(path)
            .with_context(|| format!("opening run file {}", path.display()))?;
        let len = f.metadata()?.len();
        let mut inp = BufReader::new(f);
        let mut magic = [0u8; 4];
        inp.read_exact(&mut magic)
            .map_err(|e| anyhow!("{}: reading run header: {e}", path.display()))?;
        if magic != RUN_MAGIC {
            bail!("{}: not a run file (bad magic {magic:?})", path.display());
        }
        let mut cnt = [0u8; 8];
        inp.read_exact(&mut cnt)?;
        let remaining = u64::from_le_bytes(cnt);
        // The count is untrusted input: checked math so a corrupt
        // header reports "truncated run" instead of overflowing.
        let expect = remaining
            .checked_mul(ELEM_BYTES as u64)
            .and_then(|payload| payload.checked_add(RUN_HEADER_BYTES));
        if expect != Some(len) {
            bail!(
                "{}: truncated run (header claims {} elements, file is {} bytes)",
                path.display(),
                remaining,
                len
            );
        }
        Ok(RunReader { inp, remaining, byte_buf: Vec::new() })
    }

    /// Elements not yet read.
    pub fn remaining(&self) -> u64 {
        self.remaining
    }

    /// Append up to `max` elements to `out`; returns how many were read
    /// (0 = exhausted).
    pub fn read_block(&mut self, out: &mut Vec<u32>, max: usize) -> Result<usize> {
        read_u32_block(&mut self.inp, &mut self.remaining, &mut self.byte_buf, out, max)
    }
}

fn read_u32_block(
    inp: &mut BufReader<File>,
    remaining: &mut u64,
    byte_buf: &mut Vec<u8>,
    out: &mut Vec<u32>,
    max: usize,
) -> Result<usize> {
    let take = (*remaining).min(max as u64) as usize;
    if take == 0 {
        return Ok(0);
    }
    byte_buf.resize(take * ELEM_BYTES, 0);
    inp.read_exact(byte_buf)?;
    out.reserve(take);
    for c in byte_buf.chunks_exact(ELEM_BYTES) {
        out.push(u32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    *remaining -= take as u64;
    Ok(take)
}

/// Streaming reader for a headerless little-endian u32 dataset.
pub struct RawReader {
    inp: BufReader<File>,
    total: u64,
    remaining: u64,
    byte_buf: Vec<u8>,
}

impl RawReader {
    pub fn open(path: &Path) -> Result<Self> {
        let f = File::open(path)
            .with_context(|| format!("opening dataset {}", path.display()))?;
        let len = f.metadata()?.len();
        if len % ELEM_BYTES as u64 != 0 {
            bail!(
                "{}: size {} is not a multiple of {} (raw little-endian u32 expected)",
                path.display(),
                len,
                ELEM_BYTES
            );
        }
        let total = len / ELEM_BYTES as u64;
        Ok(RawReader { inp: BufReader::new(f), total, remaining: total, byte_buf: Vec::new() })
    }

    /// Total elements in the file.
    pub fn elems(&self) -> u64 {
        self.total
    }

    /// Append up to `max` elements to `out`; 0 = exhausted.
    pub fn read_block(&mut self, out: &mut Vec<u32>, max: usize) -> Result<usize> {
        read_u32_block(&mut self.inp, &mut self.remaining, &mut self.byte_buf, out, max)
    }
}

/// Streaming writer for a headerless little-endian u32 dataset.
pub struct RawWriter {
    out: BufWriter<File>,
    count: u64,
    byte_buf: Vec<u8>,
}

impl RawWriter {
    pub fn create(path: &Path) -> Result<Self> {
        let f = File::create(path)
            .with_context(|| format!("creating output {}", path.display()))?;
        Ok(RawWriter { out: BufWriter::new(f), count: 0, byte_buf: Vec::new() })
    }

    pub fn write_block(&mut self, xs: &[u32]) -> Result<()> {
        self.byte_buf.clear();
        self.byte_buf.reserve(xs.len() * ELEM_BYTES);
        for &x in xs {
            self.byte_buf.extend_from_slice(&x.to_le_bytes());
        }
        self.out.write_all(&self.byte_buf)?;
        self.count += xs.len() as u64;
        Ok(())
    }

    /// Flush and return the element count written.
    pub fn finish(mut self) -> Result<u64> {
        self.out.flush()?;
        Ok(self.count)
    }
}

/// Write a whole dataset in one call (tests, CLI `--gen`).
pub fn write_raw(path: &Path, xs: &[u32]) -> Result<u64> {
    let mut w = RawWriter::create(path)?;
    w.write_block(xs)?;
    w.finish()
}

/// Read a whole dataset into memory (verification only — the point of
/// this subsystem is that the sort itself never does this).
pub fn read_raw(path: &Path) -> Result<Vec<u32>> {
    let mut r = RawReader::open(path)?;
    let mut out = Vec::with_capacity(r.elems() as usize);
    while r.read_block(&mut out, 1 << 16)? > 0 {}
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("flims-fmt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn run_round_trip_in_blocks() {
        let path = tmp("rt.flr");
        let mut w = RunWriter::create(&path).unwrap();
        w.write_block(&[9, 8, 7]).unwrap();
        w.write_block(&[]).unwrap();
        w.write_block(&[6, 5]).unwrap();
        let run = w.finish().unwrap();
        assert_eq!(run.elems, 5);
        assert_eq!(run.bytes, RUN_HEADER_BYTES + 20);

        let mut r = RunReader::open(&path).unwrap();
        assert_eq!(r.remaining(), 5);
        let mut out = Vec::new();
        assert_eq!(r.read_block(&mut out, 2).unwrap(), 2);
        assert_eq!(r.read_block(&mut out, 100).unwrap(), 3);
        assert_eq!(r.read_block(&mut out, 100).unwrap(), 0);
        assert_eq!(out, vec![9, 8, 7, 6, 5]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn run_reader_rejects_bad_magic_and_truncation() {
        let path = tmp("bad.flr");
        std::fs::write(&path, b"NOPE\x05\x00\x00\x00\x00\x00\x00\x00").unwrap();
        let err = format!("{:#}", RunReader::open(&path).unwrap_err());
        assert!(err.contains("bad magic"), "{err}");

        // Valid magic, count claims more data than present.
        let mut bytes = RUN_MAGIC.to_vec();
        bytes.extend_from_slice(&10u64.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = format!("{:#}", RunReader::open(&path).unwrap_err());
        assert!(err.contains("truncated run"), "{err}");

        // Corrupt header whose count would overflow count*4: must be a
        // clean "truncated run" error, never a wrap/panic.
        let mut bytes = RUN_MAGIC.to_vec();
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = format!("{:#}", RunReader::open(&path).unwrap_err());
        assert!(err.contains("truncated run"), "{err}");

        // Wrapping check: count = 2^62 wraps to 12 bytes in unchecked
        // math, which would exactly match a header-only file.
        let mut bytes = RUN_MAGIC.to_vec();
        bytes.extend_from_slice(&(1u64 << 62).to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = format!("{:#}", RunReader::open(&path).unwrap_err());
        assert!(err.contains("truncated run"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn raw_round_trip_and_size_check() {
        let path = tmp("data.u32");
        let data: Vec<u32> = (0..1000).rev().collect();
        assert_eq!(write_raw(&path, &data).unwrap(), 1000);
        let back = read_raw(&path).unwrap();
        assert_eq!(back, data);

        let mut r = RawReader::open(&path).unwrap();
        assert_eq!(r.elems(), 1000);
        let mut out = Vec::new();
        assert_eq!(r.read_block(&mut out, 64).unwrap(), 64);
        assert_eq!(out, data[..64]);

        std::fs::write(&path, [1u8, 2, 3]).unwrap();
        let err = format!("{:#}", RawReader::open(&path).unwrap_err());
        assert!(err.contains("not a multiple of 4"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_run_and_empty_raw() {
        let path = tmp("empty.flr");
        let run = RunWriter::create(&path).unwrap().finish().unwrap();
        assert_eq!(run.elems, 0);
        let mut r = RunReader::open(&path).unwrap();
        let mut out = Vec::new();
        assert_eq!(r.read_block(&mut out, 10).unwrap(), 0);
        std::fs::remove_file(&path).unwrap();

        let path = tmp("empty.u32");
        write_raw(&path, &[]).unwrap();
        assert_eq!(read_raw(&path).unwrap(), Vec::<u32>::new());
        std::fs::remove_file(&path).unwrap();
    }
}
