//! Phase 1: run generation. Stream the unsorted input in bounded-memory
//! chunks, sort each chunk with the in-memory FLiMS pipeline
//! (per-dtype via [`ExtItem::sort_run`] — stable for payload records),
//! and spill it as one descending run.
//!
//! Since the pipelined schedule landed, phase 1 is a **producer**: the
//! core entry point is [`generate_runs_streaming`], which hands every
//! run to an `emit` callback *the moment it seals* (written, finished,
//! registered) instead of hoarding the whole list. The overlapped
//! scheduler's callback pushes the run over a bounded channel so the
//! merge tree starts absorbing it immediately; the batch schedule (and
//! [`generate_runs`], kept for it and for tests) just collects a `Vec`.
//! Runs are emitted strictly in input order in both modes.
//!
//! With `threads > 1` the chunks flow through a bounded work queue: the
//! coordinating thread reads chunks in input order and feeds a pool of
//! sort workers; sorted chunks come back on a completion channel and are
//! spilled strictly in sequence, so the run layout on disk is identical
//! for every worker count (the determinism the concurrency tests pin
//! down). In-flight chunks are capped at `2 × threads`, bounding resident
//! memory at ≈ `2 × threads × mem_budget_bytes` in parallel mode.
//!
//! Spills are double-buffered
//! ([`DoubleBufWriter`](super::stream::DoubleBufWriter)): each run's
//! encode + disk write happens on a writer thread — drawn from the
//! per-sort [`WriterPool`](super::stream::WriterPool) rather than
//! spawned per run — while the coordinator reads (and, serially, sorts)
//! the next chunk, so the producer never blocks on the spill — at the
//! cost of at most one extra run buffer in flight. Runs are encoded
//! with the effective codec ([`ExternalConfig::codec_for`]): `FLR2`
//! delta blocks compress the sorted runs' small key deltas, cutting
//! phase-1 spill bandwidth.
//!
//! Fault coverage rides along for free: every writer this module
//! creates comes from [`SpillManager::create_run_with`], which attaches
//! the per-run [`Injector`](crate::fault::Injector) when a
//! [`FaultSpec`](crate::fault::FaultSpec) is configured — the
//! create/write/seal seams inject and retry inside
//! [`RunWriter`](super::format::RunWriter) itself, under this module's
//! double-buffered writer threads. An abandoned pending spill (error
//! mid-run) drops its unsealed `RunWriter`, whose drop guard removes
//! the partial file.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::codec::Codec;
use super::format::{ExtItem, RawReader, RunFile, RunWriter, RUN_HEADER_BYTES};
use super::spill::SpillManager;
use super::stream::{DoubleBufWriter, WriterPool};
use super::{ExternalConfig, SortCtx};
use crate::obs::{SpanKind, Trace};

/// Source of unsorted record blocks — a dataset file, an in-memory
/// slice, or anything else that can feed the run generator.
pub trait RecordSource<T: ExtItem> {
    /// Append up to `max` elements to `out`; `Ok(0)` means exhausted.
    fn read_block(&mut self, out: &mut Vec<T>, max: usize) -> Result<usize>;
}

impl<T: ExtItem> RecordSource<T> for RawReader<T> {
    fn read_block(&mut self, out: &mut Vec<T>, max: usize) -> Result<usize> {
        RawReader::read_block(self, out, max)
    }
}

/// In-memory source (service-path sorts, tests).
pub struct SliceSource<'a, T> {
    data: &'a [T],
    pos: usize,
}

impl<'a, T> SliceSource<'a, T> {
    /// Source over `data`, read from the front.
    pub fn new(data: &'a [T]) -> Self {
        SliceSource { data, pos: 0 }
    }
}

impl<T: ExtItem> RecordSource<T> for SliceSource<'_, T> {
    fn read_block(&mut self, out: &mut Vec<T>, max: usize) -> Result<usize> {
        let take = max.min(self.data.len() - self.pos);
        out.extend_from_slice(&self.data[self.pos..self.pos + take]);
        self.pos += take;
        Ok(take)
    }
}

/// The run hand-off callback of [`generate_runs_streaming`]: called once
/// per sealed, registered run, strictly in input order.
pub type RunEmit<'a> = dyn FnMut(RunFile) -> Result<()> + 'a;

/// Read one run-sized chunk (or whatever is left) from the source into
/// a fresh owned buffer. Both phases hand the buffer off whole — to a
/// sort worker and then the spill writer thread — so per-run ownership
/// is the point, not an allocation to optimise away.
fn read_chunk<T: ExtItem>(
    src: &mut dyn RecordSource<T>,
    run_elems: usize,
) -> Result<Vec<T>> {
    let mut buf = Vec::with_capacity(run_elems);
    while buf.len() < run_elems {
        if src.read_block(&mut buf, run_elems - buf.len())? == 0 {
            break;
        }
    }
    Ok(buf)
}

/// One spill in flight: a writer thread encodes + writes the run while
/// the coordinator reads (and sorts) the next chunk. At most one run is
/// pending at a time — classic double buffering — and it is finished
/// (joined, registered, emitted) before the next spill starts, so the
/// budget checks and run accounting stay exactly as strict as the
/// synchronous path.
struct PendingSpill<T: ExtItem> {
    path: PathBuf,
    /// Budget bytes claimed for this write until it registers.
    reserved: u64,
    /// Seal-span start (run creation), when tracing.
    t0: Option<Instant>,
    dbw: DoubleBufWriter<T, RunWriter<T>>,
}

impl<T: ExtItem> PendingSpill<T> {
    /// Reserve budget headroom, create the next run file, and hand the
    /// sorted buffer to the writer thread (reservation up front: fail
    /// before the disk fills, not after — and visibly to the merge
    /// scheduler's own checks when the schedules overlap). The
    /// projection uses the uncompressed size — conservative when the
    /// codec compresses.
    fn start(
        spill: &SpillManager,
        pool: Option<&WriterPool>,
        codec: Codec,
        kernel: crate::flims::simd::MergeKernel,
        buf: Vec<T>,
        trace: &Trace,
    ) -> Result<Self> {
        let t0 = trace.begin();
        let reserved = RUN_HEADER_BYTES + (buf.len() * T::WIRE_BYTES) as u64;
        spill.reserve(reserved)?;
        let started = (|| {
            let writer = spill.create_run_with::<T>(codec, kernel)?;
            let path = writer.path().to_path_buf();
            let mut dbw = DoubleBufWriter::spawn_with(writer, 1, pool)?;
            if let Err(e) = dbw.send(buf) {
                drop(dbw);
                let _ = std::fs::remove_file(&path);
                return Err(e);
            }
            Ok(PendingSpill { path, reserved, t0, dbw })
        })();
        if started.is_err() {
            spill.release(reserved);
        }
        started
    }

    /// Wait for the write to land, swap the reservation for the
    /// finished run's registration, then hand it to `emit` (the
    /// collector's push, or the pipeline channel).
    fn finish(
        self,
        spill: &SpillManager,
        trace: &Trace,
        ctx: &SortCtx,
        emit: &mut RunEmit<'_>,
    ) -> Result<()> {
        match self.dbw.finish().and_then(|w| w.finish()) {
            Ok(run) => {
                // register keeps the run tracked even when it reports
                // a budget breach, so SpillManager::drop still cleans it.
                spill.register_reserved(&run, self.reserved)?;
                // The seal span covers create → registered; the encode
                // span shares its start and attributes the codec CPU
                // measured on the writer thread, so it nests inside.
                if let Some(t0) = self.t0 {
                    trace.record_dur(SpanKind::CodecEncode, t0, run.encode_ns, run.elems);
                }
                trace.end(SpanKind::SealRun, self.t0, run.elems);
                ctx.progress.run_sealed();
                emit(run)
            }
            Err(e) => {
                let _ = std::fs::remove_file(&self.path);
                spill.release(self.reserved);
                Err(e)
            }
        }
    }

    /// Error-path cleanup: stop the writer, delete the partial file
    /// (it was never registered, so the manager won't), and return the
    /// reserved headroom.
    fn abandon(self, spill: &SpillManager) {
        drop(self.dbw);
        let _ = std::fs::remove_file(&self.path);
        spill.release(self.reserved);
    }
}

/// [`generate_runs_streaming`] collecting the emitted runs into a `Vec`
/// — the batch (non-overlapped) schedule's phase 1.
pub fn generate_runs<T: ExtItem>(
    src: &mut dyn RecordSource<T>,
    cfg: &ExternalConfig,
    spill: &SpillManager,
    pool: Option<&WriterPool>,
    trace: &Trace,
) -> Result<Vec<RunFile>> {
    generate_runs_ctx(src, cfg, spill, pool, trace, &SortCtx::default())
}

/// [`generate_runs`] under an explicit [`SortCtx`] (per-job progress +
/// cancellation).
pub fn generate_runs_ctx<T: ExtItem>(
    src: &mut dyn RecordSource<T>,
    cfg: &ExternalConfig,
    spill: &SpillManager,
    pool: Option<&WriterPool>,
    trace: &Trace,
    ctx: &SortCtx,
) -> Result<Vec<RunFile>> {
    let mut runs = Vec::new();
    generate_runs_streaming_ctx(src, cfg, spill, pool, trace, ctx, &mut |run| {
        runs.push(run);
        Ok(())
    })?;
    Ok(runs)
}

/// Consume `src`, spilling sorted runs of at most
/// `cfg.run_elems_for::<T>()` elements each, on `cfg.effective_threads()`
/// workers. Each run is passed to `emit` the moment it seals —
/// numbered and emitted in input order regardless of the worker count —
/// so a downstream merge scheduler can start absorbing runs while later
/// chunks are still being read, sorted, and spilled. An `emit` error
/// aborts the producer (the overlapped scheduler cancels it this way).
pub fn generate_runs_streaming<T: ExtItem>(
    src: &mut dyn RecordSource<T>,
    cfg: &ExternalConfig,
    spill: &SpillManager,
    pool: Option<&WriterPool>,
    trace: &Trace,
    emit: &mut RunEmit<'_>,
) -> Result<()> {
    generate_runs_streaming_ctx(src, cfg, spill, pool, trace, &SortCtx::default(), emit)
}

/// [`generate_runs_streaming`] under an explicit [`SortCtx`]: sealed
/// runs are counted against the job's progress, and the producer
/// checks the cancellation token at the top of every chunk — so a
/// `cancel <id>` lands within one chunk's worth of work and unwinds
/// through the ordinary error path (in-flight spill abandoned,
/// reservations released).
pub fn generate_runs_streaming_ctx<T: ExtItem>(
    src: &mut dyn RecordSource<T>,
    cfg: &ExternalConfig,
    spill: &SpillManager,
    pool: Option<&WriterPool>,
    trace: &Trace,
    ctx: &SortCtx,
    emit: &mut RunEmit<'_>,
) -> Result<()> {
    let threads = cfg.effective_threads();
    if threads <= 1 {
        generate_runs_serial(src, cfg, spill, pool, trace, ctx, emit)
    } else {
        generate_runs_parallel(src, cfg, spill, pool, trace, ctx, emit, threads)
    }
}

fn generate_runs_serial<T: ExtItem>(
    src: &mut dyn RecordSource<T>,
    cfg: &ExternalConfig,
    spill: &SpillManager,
    pool: Option<&WriterPool>,
    trace: &Trace,
    ctx: &SortCtx,
    emit: &mut RunEmit<'_>,
) -> Result<()> {
    let codec = cfg.codec_for(T::DTYPE);
    let run_elems = cfg.run_elems_for(T::WIRE_BYTES);
    let mut in_flight: Option<PendingSpill<T>> = None;
    let result = (|| -> Result<()> {
        loop {
            ctx.cancel.check()?;
            // Owned buffer per run: it is handed to the writer thread,
            // which encodes and writes while we read + sort the next
            // chunk here.
            let mut buf = read_chunk(src, run_elems)?;
            if buf.is_empty() {
                break;
            }
            let t = trace.begin();
            T::sort_run(&mut buf, cfg.sort_config(), cfg.kernel);
            trace.end(SpanKind::ChunkSort, t, buf.len() as u64);
            if let Some(prev) = in_flight.take() {
                prev.finish(spill, trace, ctx, emit)?;
            }
            in_flight =
                Some(PendingSpill::start(spill, pool, codec, cfg.kernel, buf, trace)?);
        }
        if let Some(prev) = in_flight.take() {
            prev.finish(spill, trace, ctx, emit)?;
        }
        Ok(())
    })();
    if let Some(pending) = in_flight.take() {
        pending.abandon(spill); // only reachable on error
    }
    result
}

fn generate_runs_parallel<T: ExtItem>(
    src: &mut dyn RecordSource<T>,
    cfg: &ExternalConfig,
    spill: &SpillManager,
    pool: Option<&WriterPool>,
    trace: &Trace,
    ctx: &SortCtx,
    emit: &mut RunEmit<'_>,
    threads: usize,
) -> Result<()> {
    let run_elems = cfg.run_elems_for(T::WIRE_BYTES);
    let sort_cfg = cfg.sort_config();
    let kernel = cfg.kernel;
    // Cap on chunks that are queued, being sorted, or sorted-but-not-yet
    // spilled: bounds both memory and the reorder window.
    let max_in_flight = 2 * threads as u64;

    let codec = cfg.codec_for(T::DTYPE);

    std::thread::scope(|s| {
        let (work_tx, work_rx) = mpsc::sync_channel::<(u64, Vec<T>)>(threads);
        let work_rx = Arc::new(Mutex::new(work_rx));
        let (done_tx, done_rx) = mpsc::channel::<(u64, Vec<T>)>();
        for _ in 0..threads {
            let rx = Arc::clone(&work_rx);
            let tx = done_tx.clone();
            let trace = trace.clone();
            s.spawn(move || loop {
                let job = rx.lock().unwrap().recv();
                let Ok((seq, mut buf)) = job else { break };
                let t = trace.begin();
                T::sort_run(&mut buf, sort_cfg, kernel);
                trace.end(SpanKind::ChunkSort, t, buf.len() as u64);
                if tx.send((seq, buf)).is_err() {
                    break;
                }
            });
        }
        drop(done_tx);

        let mut pending: BTreeMap<u64, Vec<T>> = BTreeMap::new();
        let mut in_flight: Option<PendingSpill<T>> = None;
        let mut next_read = 0u64; // next chunk sequence number to hand out
        let mut next_write = 0u64; // next sequence number to spill
        let mut eof = false;
        let result = (|| -> Result<()> {
            while !eof || next_write < next_read {
                ctx.cancel.check()?;
                // Keep the queue fed up to the in-flight cap.
                while !eof && next_read - next_write < max_in_flight {
                    let buf = read_chunk(src, run_elems)?;
                    if buf.is_empty() {
                        eof = true;
                        break;
                    }
                    if buf.len() < run_elems {
                        eof = true; // short chunk: source exhausted
                    }
                    work_tx
                        .send((next_read, buf))
                        .map_err(|_| anyhow!("run-gen workers exited early"))?;
                    next_read += 1;
                }
                if next_write >= next_read {
                    break; // eof and everything spilled
                }
                // Collect a sorted chunk, then start spilling every
                // chunk now contiguous with the write frontier — each on
                // the double-buffered writer, finishing (and emitting)
                // its predecessor first so runs leave strictly in input
                // order.
                let (seq, buf) = done_rx
                    .recv()
                    .map_err(|_| anyhow!("run-gen workers exited early"))?;
                pending.insert(seq, buf);
                while let Some(buf) = pending.remove(&next_write) {
                    if let Some(prev) = in_flight.take() {
                        prev.finish(spill, trace, ctx, emit)?;
                    }
                    in_flight = Some(PendingSpill::start(
                        spill, pool, codec, kernel, buf, trace,
                    )?);
                    next_write += 1;
                }
            }
            if let Some(prev) = in_flight.take() {
                prev.finish(spill, trace, ctx, emit)?;
            }
            Ok(())
        })();
        if let Some(p) = in_flight.take() {
            p.abandon(spill); // only reachable on error
        }
        // Closing the work queue releases the pool; the scope joins the
        // workers after the channels (and any queued buffers) drop.
        drop(work_tx);
        result
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_kv, gen_u32, Distribution};
    use crate::external::format::RunReader;
    use crate::key::{is_sorted_desc, Kv};
    use crate::util::rng::Rng;

    fn small_cfg() -> ExternalConfig {
        ExternalConfig {
            mem_budget_bytes: 4096, // 1024-element u32 runs
            ..Default::default()
        }
    }

    fn read_run<T: ExtItem>(run: &RunFile) -> Vec<T> {
        let mut r = RunReader::<T>::open(&run.path).unwrap();
        let mut v = Vec::new();
        while r.read_block(&mut v, 512).unwrap() > 0 {}
        v
    }

    #[test]
    fn runs_cover_input_and_are_sorted() {
        let cfg = small_cfg();
        let mut rng = Rng::new(91);
        let data = gen_u32(&mut rng, 5000, Distribution::Uniform);
        let spill = SpillManager::new(None, None).unwrap();
        let mut src = SliceSource::new(&data);
        let runs = generate_runs(&mut src, &cfg, &spill, None, &Trace::disabled()).unwrap();

        // 5000 elements at 1024/run → 5 runs; sizes sum to the input.
        assert_eq!(runs.len(), 5);
        assert_eq!(runs.iter().map(|r| r.elems).sum::<u64>(), 5000);

        let mut all = Vec::new();
        for run in &runs {
            let v = read_run::<u32>(run);
            assert_eq!(v.len() as u64, run.elems);
            assert!(is_sorted_desc(&v), "run {} not sorted", run.path.display());
            all.extend(v);
        }
        all.sort_unstable();
        let mut expect = data.clone();
        expect.sort_unstable();
        assert_eq!(all, expect, "runs must hold exactly the input multiset");
    }

    #[test]
    fn traced_run_generation_records_spans() {
        for threads in [1usize, 4] {
            let cfg = ExternalConfig { threads, ..small_cfg() };
            let mut rng = Rng::new(96);
            let data = gen_u32(&mut rng, 5000, Distribution::Uniform);
            let spill = SpillManager::new(None, None).unwrap();
            let mut src = SliceSource::new(&data);
            let trace = Trace::enabled();
            let runs = generate_runs(&mut src, &cfg, &spill, None, &trace).unwrap();
            let spans = trace.spans();
            let count = |k| spans.iter().filter(|s| s.kind == k).count();
            assert_eq!(count(SpanKind::ChunkSort), runs.len(), "threads={threads}");
            assert_eq!(count(SpanKind::SealRun), runs.len(), "threads={threads}");
            assert_eq!(count(SpanKind::CodecEncode), runs.len(), "threads={threads}");
            // Every encode span shares its seal span's start and lane
            // and nests inside it.
            for e in spans.iter().filter(|s| s.kind == SpanKind::CodecEncode) {
                let seal = spans.iter().find(|s| {
                    s.kind == SpanKind::SealRun && s.lane == e.lane && s.start_ns == e.start_ns
                });
                assert!(
                    seal.is_some_and(|s| s.dur_ns >= e.dur_ns),
                    "threads={threads}: encode span not nested in a seal span: {e:?}"
                );
            }
        }
    }

    #[test]
    fn parallel_run_layout_matches_serial() {
        // The same input must produce byte-identical, identically-named
        // runs whatever the worker count.
        let mut rng = Rng::new(92);
        let data = gen_u32(&mut rng, 10_000, Distribution::Uniform);
        let mut layouts: Vec<Vec<(String, Vec<u32>)>> = Vec::new();
        for threads in [1usize, 2, 8] {
            let cfg = ExternalConfig { threads, ..small_cfg() };
            let spill = SpillManager::new(None, None).unwrap();
            let mut src = SliceSource::new(&data);
            let runs = generate_runs(&mut src, &cfg, &spill, None, &Trace::disabled()).unwrap();
            layouts.push(
                runs.iter()
                    .map(|r| {
                        let name =
                            r.path.file_name().unwrap().to_string_lossy().into_owned();
                        (name, read_run::<u32>(r))
                    })
                    .collect(),
            );
        }
        assert_eq!(layouts[0], layouts[1], "threads=2 differs from serial");
        assert_eq!(layouts[0], layouts[2], "threads=8 differs from serial");
    }

    #[test]
    fn streaming_emission_is_in_order_and_eager() {
        // The producer must hand run i to the callback before run i+2
        // even starts spilling (double buffering allows exactly one
        // successor in flight) — and strictly in input order, serial
        // and parallel.
        for threads in [1usize, 4] {
            let cfg = ExternalConfig { threads, ..small_cfg() };
            let mut rng = Rng::new(94);
            let data = gen_u32(&mut rng, 6000, Distribution::Uniform);
            let spill = SpillManager::new(None, None).unwrap();
            let mut src = SliceSource::new(&data);
            let mut seen: Vec<RunFile> = Vec::new();
            generate_runs_streaming(&mut src, &cfg, &spill, None, &Trace::disabled(), &mut |run| {
                // Emitted runs are already registered and on disk.
                assert!(run.path.exists(), "emitted run must be sealed");
                seen.push(run);
                Ok(())
            })
            .unwrap();
            assert_eq!(seen.len(), 6, "threads={threads}");
            let mut names: Vec<String> = seen
                .iter()
                .map(|r| r.path.file_name().unwrap().to_string_lossy().into_owned())
                .collect();
            let sorted = {
                let mut s = names.clone();
                s.sort();
                s
            };
            assert_eq!(names, sorted, "threads={threads}: emission out of input order");
            names.dedup();
            assert_eq!(names.len(), 6);
        }
    }

    #[test]
    fn emit_errors_abort_the_producer() {
        // The overlapped scheduler cancels phase 1 by failing the emit
        // callback; the producer must stop promptly and surface it.
        let cfg = ExternalConfig { threads: 4, ..small_cfg() };
        let mut rng = Rng::new(95);
        let data = gen_u32(&mut rng, 20_000, Distribution::Uniform);
        let spill = SpillManager::new(None, None).unwrap();
        let mut src = SliceSource::new(&data);
        let mut emitted = 0usize;
        let err = generate_runs_streaming::<u32>(
            &mut src,
            &cfg,
            &spill,
            None,
            &Trace::disabled(),
            &mut |_| {
                emitted += 1;
                if emitted == 3 {
                    anyhow::bail!("downstream gave up");
                }
                Ok(())
            },
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("downstream gave up"));
        assert_eq!(emitted, 3);
    }

    #[test]
    fn kv_runs_are_stably_sorted() {
        // Duplicate-heavy Kv input: within each run, equal keys must keep
        // input order (payload = input index makes this checkable).
        let mut rng = Rng::new(93);
        let data = gen_kv(&mut rng, 3000, Distribution::DupHeavy { alphabet: 3 });
        let cfg = ExternalConfig {
            mem_budget_bytes: 8192, // 1024-element Kv runs
            threads: 2,
            ..Default::default()
        };
        let spill = SpillManager::new(None, None).unwrap();
        let mut src = SliceSource::new(&data);
        let runs = generate_runs(&mut src, &cfg, &spill, None, &Trace::disabled()).unwrap();
        assert_eq!(runs.len(), 3);
        let run_elems = cfg.run_elems_for(Kv::WIRE_BYTES);
        assert_eq!(run_elems, 1024);
        for (i, run) in runs.iter().enumerate() {
            let got = read_run::<Kv>(run);
            let mut expect = data[i * run_elems..(i * run_elems + got.len())].to_vec();
            expect.sort_by(|a, b| b.key.cmp(&a.key)); // std stable sort
            assert_eq!(got, expect, "run {i} not stably sorted");
        }
    }

    #[test]
    fn empty_input_spills_nothing() {
        for threads in [1usize, 4] {
            let cfg = ExternalConfig { threads, ..small_cfg() };
            let spill = SpillManager::new(None, None).unwrap();
            let mut src = SliceSource::new(&[] as &[u32]);
            let runs = generate_runs(&mut src, &cfg, &spill, None, &Trace::disabled()).unwrap();
            assert!(runs.is_empty());
            assert_eq!(spill.runs_created(), 0);
        }
    }

    #[test]
    fn dribbling_source_still_fills_runs() {
        // A source that yields 7 elements at a time must still produce
        // full-size runs (the generator loops until the buffer fills).
        struct Dribble {
            left: usize,
            next: u32,
        }
        impl RecordSource<u32> for Dribble {
            fn read_block(&mut self, out: &mut Vec<u32>, max: usize) -> Result<usize> {
                let take = self.left.min(max).min(7);
                for _ in 0..take {
                    out.push(self.next);
                    self.next = self.next.wrapping_mul(1664525).wrapping_add(1013904223);
                }
                self.left -= take;
                Ok(take)
            }
        }
        let cfg = small_cfg();
        let spill = SpillManager::new(None, None).unwrap();
        let mut src = Dribble { left: 3000, next: 1 };
        let runs = generate_runs(&mut src, &cfg, &spill, None, &Trace::disabled()).unwrap();
        assert_eq!(runs.len(), 3);
        assert_eq!(runs[0].elems, 1024);
        assert_eq!(runs[2].elems, 3000 - 2048);
    }

    #[test]
    fn source_errors_propagate_in_parallel_mode() {
        struct Failing {
            fed: usize,
        }
        impl RecordSource<u32> for Failing {
            fn read_block(&mut self, out: &mut Vec<u32>, max: usize) -> Result<usize> {
                if self.fed >= 2500 {
                    anyhow::bail!("simulated I/O failure");
                }
                let take = max.min(500);
                out.extend(std::iter::repeat(7u32).take(take));
                self.fed += take;
                Ok(take)
            }
        }
        let cfg = ExternalConfig { threads: 4, ..small_cfg() };
        let spill = SpillManager::new(None, None).unwrap();
        let mut src = Failing { fed: 0 };
        let err = format!(
            "{:#}",
            generate_runs(&mut src, &cfg, &spill, None, &Trace::disabled()).unwrap_err()
        );
        assert!(err.contains("simulated I/O failure"), "{err}");
    }
}
