//! Phase 1: run generation. Stream the unsorted input in bounded-memory
//! chunks, sort each chunk with the in-memory FLiMS pipeline
//! (`flims::sort::sort_desc`), and spill it as one descending run.

use anyhow::Result;

use crate::flims::sort::sort_desc;

use super::format::{RawReader, RunFile};
use super::spill::SpillManager;
use super::ExternalConfig;

/// Source of unsorted u32 blocks — a dataset file, an in-memory slice,
/// or anything else that can feed the run generator.
pub trait U32Source {
    /// Append up to `max` elements to `out`; `Ok(0)` means exhausted.
    fn read_block(&mut self, out: &mut Vec<u32>, max: usize) -> Result<usize>;
}

impl U32Source for RawReader {
    fn read_block(&mut self, out: &mut Vec<u32>, max: usize) -> Result<usize> {
        RawReader::read_block(self, out, max)
    }
}

/// In-memory source (service-path sorts, tests).
pub struct SliceSource<'a> {
    data: &'a [u32],
    pos: usize,
}

impl<'a> SliceSource<'a> {
    pub fn new(data: &'a [u32]) -> Self {
        SliceSource { data, pos: 0 }
    }
}

impl U32Source for SliceSource<'_> {
    fn read_block(&mut self, out: &mut Vec<u32>, max: usize) -> Result<usize> {
        let take = max.min(self.data.len() - self.pos);
        out.extend_from_slice(&self.data[self.pos..self.pos + take]);
        self.pos += take;
        Ok(take)
    }
}

/// Consume `src`, spilling sorted runs of at most `cfg.run_elems()`
/// elements each. The run buffer is the only O(budget) allocation.
pub fn generate_runs(
    src: &mut dyn U32Source,
    cfg: &ExternalConfig,
    spill: &mut SpillManager,
) -> Result<Vec<RunFile>> {
    let run_elems = cfg.run_elems();
    let mut runs = Vec::new();
    let mut buf: Vec<u32> = Vec::with_capacity(run_elems);
    loop {
        buf.clear();
        while buf.len() < run_elems {
            if src.read_block(&mut buf, run_elems - buf.len())? == 0 {
                break;
            }
        }
        if buf.is_empty() {
            break;
        }
        sort_desc(&mut buf, cfg.sort_config());
        // Budget check up front: fail before the disk fills, not after.
        spill.check_headroom(
            crate::external::format::RUN_HEADER_BYTES + (buf.len() * 4) as u64,
        )?;
        let mut writer = spill.create_run()?;
        writer.write_block(&buf)?;
        let run = writer.finish()?;
        spill.register(&run)?;
        runs.push(run);
    }
    Ok(runs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_u32, Distribution};
    use crate::external::format::RunReader;
    use crate::key::is_sorted_desc;
    use crate::util::rng::Rng;

    fn small_cfg() -> ExternalConfig {
        ExternalConfig {
            mem_budget_bytes: 4096, // 1024-element runs
            ..Default::default()
        }
    }

    #[test]
    fn runs_cover_input_and_are_sorted() {
        let cfg = small_cfg();
        let mut rng = Rng::new(91);
        let data = gen_u32(&mut rng, 5000, Distribution::Uniform);
        let mut spill = SpillManager::new(None, None).unwrap();
        let mut src = SliceSource::new(&data);
        let runs = generate_runs(&mut src, &cfg, &mut spill).unwrap();

        // 5000 elements at 1024/run → 5 runs; sizes sum to the input.
        assert_eq!(runs.len(), 5);
        assert_eq!(runs.iter().map(|r| r.elems).sum::<u64>(), 5000);

        let mut all = Vec::new();
        for run in &runs {
            let mut r = RunReader::open(&run.path).unwrap();
            let mut v = Vec::new();
            while r.read_block(&mut v, 512).unwrap() > 0 {}
            assert_eq!(v.len() as u64, run.elems);
            assert!(is_sorted_desc(&v), "run {} not sorted", run.path.display());
            all.extend(v);
        }
        all.sort_unstable();
        let mut expect = data.clone();
        expect.sort_unstable();
        assert_eq!(all, expect, "runs must hold exactly the input multiset");
    }

    #[test]
    fn empty_input_spills_nothing() {
        let cfg = small_cfg();
        let mut spill = SpillManager::new(None, None).unwrap();
        let mut src = SliceSource::new(&[]);
        let runs = generate_runs(&mut src, &cfg, &mut spill).unwrap();
        assert!(runs.is_empty());
        assert_eq!(spill.runs_created(), 0);
    }

    #[test]
    fn dribbling_source_still_fills_runs() {
        // A source that yields 7 elements at a time must still produce
        // full-size runs (the generator loops until the buffer fills).
        struct Dribble {
            left: usize,
            next: u32,
        }
        impl U32Source for Dribble {
            fn read_block(&mut self, out: &mut Vec<u32>, max: usize) -> Result<usize> {
                let take = self.left.min(max).min(7);
                for _ in 0..take {
                    out.push(self.next);
                    self.next = self.next.wrapping_mul(1664525).wrapping_add(1013904223);
                }
                self.left -= take;
                Ok(take)
            }
        }
        let cfg = small_cfg();
        let mut spill = SpillManager::new(None, None).unwrap();
        let mut src = Dribble { left: 3000, next: 1 };
        let runs = generate_runs(&mut src, &cfg, &mut spill).unwrap();
        assert_eq!(runs.len(), 3);
        assert_eq!(runs[0].elems, 1024);
        assert_eq!(runs[2].elems, 3000 - 2048);
    }
}
