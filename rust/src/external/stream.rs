//! Streaming merge nodes: an HPMT-style binary tree of FLiMS 2-way
//! mergers over block-buffered inputs.
//!
//! Each [`MergeStream`] holds a bounded buffer per child and repeatedly
//! emits the *safe prefix* of the two buffers — every element ≥ the
//! larger of the two buffer minima, which no future element from either
//! child can exceed (keys are compared as a multiset, so ties with
//! unseen equal keys are harmless). The safe prefixes are merged with
//! [`merge_desc_into`], the same `w`-lane FLiMS primitive the in-memory
//! sort uses — the Merge-Path-style split just decides *how much* of
//! each buffer the merger may consume this round.

use anyhow::{bail, Result};

use crate::flims::lanes::merge_desc_into;

use super::format::RunReader;

/// A stream of descending-sorted u32 blocks.
pub trait RunStream {
    /// Append the next descending-sorted block to `out`. Returns the
    /// number of elements appended; `Ok(0)` means exhausted for good.
    fn next_block(&mut self, out: &mut Vec<u32>) -> Result<usize>;
}

/// Leaf: a spilled run file, surfaced `block` elements at a time.
pub struct ReaderStream {
    reader: RunReader,
    block: usize,
}

impl ReaderStream {
    pub fn new(reader: RunReader, block: usize) -> Self {
        ReaderStream { reader, block: block.max(1) }
    }
}

impl RunStream for ReaderStream {
    fn next_block(&mut self, out: &mut Vec<u32>) -> Result<usize> {
        self.reader.read_block(out, self.block)
    }
}

/// One buffered input side of a merge node.
struct Side {
    buf: Vec<u32>,
    /// Consumed prefix of `buf`.
    pos: usize,
    /// The child returned 0 — no future elements exist.
    done: bool,
}

impl Side {
    fn new() -> Self {
        Side { buf: Vec::new(), pos: 0, done: false }
    }

    fn avail(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Top up to at least `target` available elements (unless the child
    /// runs dry first). Invariant afterwards: `avail() == 0 ⇒ done`.
    fn refill(&mut self, child: &mut dyn RunStream, target: usize) -> Result<()> {
        if self.done || self.avail() >= target {
            return Ok(());
        }
        self.buf.drain(..self.pos);
        self.pos = 0;
        while self.buf.len() < target {
            if child.next_block(&mut self.buf)? == 0 {
                self.done = true;
                break;
            }
        }
        Ok(())
    }

    /// Minimum key still buffered — a bound on nothing: every *future*
    /// element from this side is ≤ this value (descending input).
    fn min_bound(&self) -> Option<u32> {
        if self.done {
            None // no future elements; no constraint
        } else {
            self.buf.last().copied()
        }
    }
}

/// Internal node: FLiMS 2-way merge of two child streams.
pub struct MergeStream {
    a: Box<dyn RunStream>,
    b: Box<dyn RunStream>,
    sa: Side,
    sb: Side,
    block: usize,
    w: usize,
    scratch: Vec<u32>,
}

impl MergeStream {
    pub fn new(a: Box<dyn RunStream>, b: Box<dyn RunStream>, block: usize, w: usize) -> Self {
        assert!(w.is_power_of_two());
        MergeStream {
            a,
            b,
            sa: Side::new(),
            sb: Side::new(),
            block: block.max(1),
            w,
            scratch: Vec::new(),
        }
    }
}

impl RunStream for MergeStream {
    fn next_block(&mut self, out: &mut Vec<u32>) -> Result<usize> {
        self.sa.refill(self.a.as_mut(), self.block)?;
        self.sb.refill(self.b.as_mut(), self.block)?;
        let (av, bv) = (self.sa.avail(), self.sb.avail());
        if av == 0 && bv == 0 {
            return Ok(0);
        }
        // One side exhausted entirely: pass the other buffer through
        // (refill guarantees avail()==0 implies done).
        if av == 0 {
            out.extend_from_slice(&self.sb.buf[self.sb.pos..]);
            self.sb.pos = self.sb.buf.len();
            return Ok(bv);
        }
        if bv == 0 {
            out.extend_from_slice(&self.sa.buf[self.sa.pos..]);
            self.sa.pos = self.sa.buf.len();
            return Ok(av);
        }
        // Safe-prefix split: elements ≥ t cannot be preceded by anything
        // still unseen, so they may be merged and emitted now.
        let threshold = match (self.sa.min_bound(), self.sb.min_bound()) {
            (Some(x), Some(y)) => Some(x.max(y)),
            (Some(x), None) => Some(x),
            (None, Some(y)) => Some(y),
            (None, None) => None, // both fully buffered: merge everything
        };
        let a_avail = &self.sa.buf[self.sa.pos..];
        let b_avail = &self.sb.buf[self.sb.pos..];
        let (ka, kb) = match threshold {
            None => (av, bv),
            Some(t) => (
                a_avail.partition_point(|&x| x >= t),
                b_avail.partition_point(|&x| x >= t),
            ),
        };
        if ka + kb == 0 {
            // Unreachable: the threshold equals the buffer minimum of a
            // non-exhausted side, so that side's whole buffer qualifies.
            bail!("merge stream stalled (threshold {threshold:?}, avail {av}/{bv})");
        }
        merge_desc_into(&a_avail[..ka], &b_avail[..kb], self.w, &mut self.scratch);
        out.extend_from_slice(&self.scratch);
        self.sa.pos += ka;
        self.sb.pos += kb;
        Ok(ka + kb)
    }
}

/// Fold `streams` into a balanced binary tree of [`MergeStream`] nodes.
/// Panics on an empty input (callers handle the zero-run case).
pub fn build_tree(mut streams: Vec<Box<dyn RunStream>>, block: usize, w: usize) -> Box<dyn RunStream> {
    assert!(!streams.is_empty(), "build_tree needs at least one stream");
    while streams.len() > 1 {
        let mut next: Vec<Box<dyn RunStream>> = Vec::with_capacity(streams.len().div_ceil(2));
        let mut it = streams.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(Box::new(MergeStream::new(a, b, block, w))),
                None => next.push(a),
            }
        }
        streams = next;
    }
    streams.pop().unwrap()
}

/// Drain a stream into `emit` block-by-block; returns total elements.
pub fn pump(stream: &mut dyn RunStream, mut emit: impl FnMut(&[u32]) -> Result<()>) -> Result<u64> {
    let mut chunk = Vec::new();
    let mut total = 0u64;
    loop {
        chunk.clear();
        let n = stream.next_block(&mut chunk)?;
        if n == 0 {
            return Ok(total);
        }
        emit(&chunk)?;
        total += n as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_u32, Distribution};
    use crate::key::is_sorted_desc;
    use crate::util::rng::Rng;

    /// In-memory descending stream with configurable emission sizes.
    struct VecStream {
        data: Vec<u32>,
        pos: usize,
        step: usize,
    }

    impl VecStream {
        fn new(mut data: Vec<u32>, step: usize) -> Self {
            data.sort_unstable_by(|a, b| b.cmp(a));
            VecStream { data, pos: 0, step }
        }
    }

    impl RunStream for VecStream {
        fn next_block(&mut self, out: &mut Vec<u32>) -> Result<usize> {
            let take = self.step.min(self.data.len() - self.pos);
            out.extend_from_slice(&self.data[self.pos..self.pos + take]);
            self.pos += take;
            Ok(take)
        }
    }

    fn drain(stream: &mut dyn RunStream) -> Vec<u32> {
        let mut out = Vec::new();
        pump(stream, |c| {
            out.extend_from_slice(c);
            Ok(())
        })
        .unwrap();
        out
    }

    fn oracle(lists: &[Vec<u32>]) -> Vec<u32> {
        let mut v: Vec<u32> = lists.iter().flatten().copied().collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v
    }

    #[test]
    fn two_way_matches_oracle_across_shapes() {
        let mut rng = Rng::new(81);
        for (na, nb) in [(0, 0), (0, 500), (500, 0), (1, 1), (1000, 37), (512, 512)] {
            for block in [1usize, 7, 64] {
                let a = gen_u32(&mut rng, na, Distribution::Uniform);
                let b = gen_u32(&mut rng, nb, Distribution::Uniform);
                let expect = oracle(&[a.clone(), b.clone()]);
                let mut m = MergeStream::new(
                    Box::new(VecStream::new(a, 13)),
                    Box::new(VecStream::new(b, 5)),
                    block,
                    8,
                );
                assert_eq!(drain(&mut m), expect, "na={na} nb={nb} block={block}");
            }
        }
    }

    #[test]
    fn duplicate_heavy_and_constant_streams() {
        let mut rng = Rng::new(82);
        for dist in [
            Distribution::DupHeavy { alphabet: 2 },
            Distribution::Constant,
            Distribution::Zipf { s_x100: 150, n_ranks: 16 },
        ] {
            let a = gen_u32(&mut rng, 700, dist);
            let b = gen_u32(&mut rng, 300, dist);
            let expect = oracle(&[a.clone(), b.clone()]);
            let mut m = MergeStream::new(
                Box::new(VecStream::new(a, 11)),
                Box::new(VecStream::new(b, 23)),
                32,
                16,
            );
            assert_eq!(drain(&mut m), expect, "{dist:?}");
        }
    }

    #[test]
    fn tree_merges_many_streams() {
        let mut rng = Rng::new(83);
        for k in [1usize, 2, 3, 5, 8, 13] {
            let lists: Vec<Vec<u32>> =
                (0..k).map(|i| gen_u32(&mut rng, 50 + i * 37, Distribution::Uniform)).collect();
            let expect = oracle(&lists);
            let streams: Vec<Box<dyn RunStream>> = lists
                .iter()
                .map(|l| Box::new(VecStream::new(l.clone(), 9)) as Box<dyn RunStream>)
                .collect();
            let mut tree = build_tree(streams, 16, 8);
            let got = drain(tree.as_mut());
            assert!(is_sorted_desc(&got));
            assert_eq!(got, expect, "k={k}");
        }
    }

    #[test]
    fn blocks_are_individually_sorted() {
        let mut rng = Rng::new(84);
        let a = gen_u32(&mut rng, 400, Distribution::Uniform);
        let b = gen_u32(&mut rng, 400, Distribution::Uniform);
        let mut m = MergeStream::new(
            Box::new(VecStream::new(a, 17)),
            Box::new(VecStream::new(b, 29)),
            32,
            8,
        );
        let mut chunk = Vec::new();
        let mut last: Option<u32> = None;
        loop {
            chunk.clear();
            if m.next_block(&mut chunk).unwrap() == 0 {
                break;
            }
            assert!(is_sorted_desc(&chunk));
            // Blocks are globally ordered too: each starts no higher
            // than the previous block's tail.
            if let Some(prev_min) = last {
                assert!(chunk[0] <= prev_min);
            }
            last = chunk.last().copied();
        }
    }
}
