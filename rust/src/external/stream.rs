//! Streaming merge nodes: an HPMT-style binary tree of FLiMS 2-way
//! mergers over block-buffered inputs, generic over the record type.
//!
//! Each [`MergeStream`] holds a bounded buffer per child and repeatedly
//! emits the *safe prefix* of the two buffers. The split is
//! Merge-Path-style but additionally **stability-safe**: side A may
//! emit keys `>=` B's future bound (an equal key arriving later from B
//! belongs after A's copy anyway), while side B may only emit keys
//! *strictly above* A's future bound (an equal future key from A must
//! precede it). The prefixes are merged by [`ExtItem::merge_into`] —
//! the paper's stable §4.2 FLiMS variant for payload records, the fast
//! untagged lanes for plain keys (where ties are unobservable) — so the
//! whole tree preserves input order on ties: the §6 tie-record
//! guarantee, out-of-core.
//!
//! Leaves come in two flavours: [`ReaderStream`] (synchronous
//! `read_block` on the hot path) and [`PrefetchStream`] (a
//! double-buffered reader: a prefetch thread fills the next blocks into
//! a bounded channel while the merger drains the current one, so disk
//! latency overlaps with merge compute — TopSort's phase-overlap idea
//! applied at the leaf). Because `FLR2` decoding happens inside
//! [`RunReader::read_block`], prefetch leaves decompress on their own
//! thread too — codec CPU never lands on the merge hot path.
//!
//! The write side mirrors the leaf: [`DoubleBufWriter`] hands encoded
//! spill writes to a writer thread through a bounded channel, so the
//! producer (the phase-1 coordinator, a phase-2 group merge) keeps
//! sorting/merging while the previous block encodes and hits the disk.
//! Writer threads come from a per-sort [`WriterPool`] of long-lived
//! workers: a thousand-run workload reuses the same few threads instead
//! of paying a thread spawn/teardown per run (the ROADMAP's
//! writer-pooling follow-on), with a dedicated-thread fallback whenever
//! every pool worker is busy.
//!
//! Neither leaf nor writer adds a fault seam of its own: the injected
//! [`RunReader`]/[`RunWriter`](super::format::RunWriter) they wrap
//! carry the per-run [`Injector`](crate::fault::Injector), so prefetch
//! threads and pooled writer threads inherit the same deterministic
//! injection and retry behaviour as the synchronous paths — a retried
//! read happens on the prefetch thread, before the block enters the
//! bounded channel, never on the merge hot path.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Result};

use crate::flims::simd::MergeKernel;
use crate::key::Item;

use super::format::{ExtItem, RunReader};
use super::merge::RecordSink;

/// A stream of descending-sorted blocks of `T`.
pub trait RunStream<T> {
    /// Append the next descending-sorted block to `out`. Returns the
    /// number of elements appended; `Ok(0)` means exhausted for good.
    fn next_block(&mut self, out: &mut Vec<T>) -> Result<usize>;
}

/// Leaf: a spilled run file, surfaced `block` elements at a time with a
/// blocking read on the calling thread.
pub struct ReaderStream<T: ExtItem> {
    reader: RunReader<T>,
    block: usize,
}

impl<T: ExtItem> ReaderStream<T> {
    /// Stream `reader` in blocks of `block` elements (clamped to ≥ 1).
    pub fn new(reader: RunReader<T>, block: usize) -> Self {
        ReaderStream { reader, block: block.max(1) }
    }
}

impl<T: ExtItem> RunStream<T> for ReaderStream<T> {
    fn next_block(&mut self, out: &mut Vec<T>) -> Result<usize> {
        self.reader.read_block(out, self.block)
    }
}

/// Shared counters for the leaves of one sort: a *hit* is a block that
/// was already buffered when the merger asked (the disk read was fully
/// overlapped); a *miss* had to block. `decode_ns` accumulates the
/// wall-clock the leaf readers spent decoding `FLR2` delta blocks.
#[derive(Debug, Default)]
pub struct PrefetchCounters {
    /// Blocks served without blocking the merge.
    pub hits: AtomicU64,
    /// Blocks the merge had to wait for.
    pub misses: AtomicU64,
    /// Nanoseconds spent in codec decode across all leaves (shared with
    /// each [`RunReader`] via [`RunReader::open_with`]).
    pub decode_ns: Arc<AtomicU64>,
    /// The owning sort's span trace: group merges and prefetch waits
    /// record through it (the default is a disabled, no-op trace).
    pub trace: crate::obs::Trace,
}

/// Leaf: a double-buffered run reader. A dedicated thread reads ahead up
/// to `depth` blocks into a bounded channel; `next_block` usually just
/// receives an already-filled buffer, removing the blocking `read_block`
/// from the merge hot path.
pub struct PrefetchStream<T: ExtItem> {
    rx: Option<mpsc::Receiver<Result<Vec<T>>>>,
    handle: Option<JoinHandle<()>>,
    counters: Arc<PrefetchCounters>,
}

impl<T: ExtItem> PrefetchStream<T> {
    /// Errors (instead of aborting the process) when the OS refuses
    /// another thread — large `fan_in × threads` products can ask for a
    /// lot of leaves.
    pub fn spawn(
        mut reader: RunReader<T>,
        block: usize,
        depth: usize,
        counters: Arc<PrefetchCounters>,
    ) -> Result<Self> {
        let block = block.max(1);
        let (tx, rx) = mpsc::sync_channel::<Result<Vec<T>>>(depth.max(1));
        let handle = std::thread::Builder::new()
            .name("flims-prefetch".into())
            .spawn(move || loop {
                let mut buf = Vec::with_capacity(block);
                match reader.read_block(&mut buf, block) {
                    Ok(0) => break, // EOF: closing the channel signals it
                    Ok(_) => {
                        if tx.send(Ok(buf)).is_err() {
                            break; // consumer dropped mid-stream
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(Err(e));
                        break;
                    }
                }
            })
            .map_err(|e| anyhow!("spawning prefetch reader thread: {e}"))?;
        Ok(PrefetchStream { rx: Some(rx), handle: Some(handle), counters })
    }

    fn shut_down(&mut self) {
        // Dropping the receiver unblocks any in-flight send; then the
        // reader thread exits and join cannot deadlock.
        self.rx = None;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl<T: ExtItem> RunStream<T> for PrefetchStream<T> {
    fn next_block(&mut self, out: &mut Vec<T>) -> Result<usize> {
        let Some(rx) = self.rx.take() else { return Ok(0) };
        let received = match rx.try_recv() {
            Ok(b) => {
                self.counters.hits.fetch_add(1, Ordering::Relaxed);
                Some(b)
            }
            Err(TryRecvError::Empty) => {
                // The merge is about to stall on the disk — span the
                // wait so it shows up on the merge lane in traces.
                let t = self.counters.trace.begin();
                let received = match rx.recv() {
                    Ok(b) => {
                        self.counters.misses.fetch_add(1, Ordering::Relaxed);
                        Some(b)
                    }
                    Err(_) => None,
                };
                self.counters.trace.end(crate::obs::SpanKind::PrefetchWait, t, 1);
                received
            }
            Err(TryRecvError::Disconnected) => None,
        };
        let Some(block) = received else {
            // Channel closed = reader finished (EOF or after an error it
            // already reported); reap the thread.
            self.shut_down();
            return Ok(0);
        };
        self.rx = Some(rx);
        let buf = block?;
        out.extend_from_slice(&buf);
        Ok(buf.len())
    }
}

impl<T: ExtItem> Drop for PrefetchStream<T> {
    fn drop(&mut self) {
        self.shut_down();
    }
}

/// A boxed writer-loop job, runnable on a pool worker or a fallback
/// dedicated thread.
pub type PoolJob = Box<dyn FnOnce() + Send + 'static>;

/// A small set of long-lived writer threads shared by every
/// [`DoubleBufWriter`] of one sort. Each writer *occupies* a worker for
/// its whole lifetime (the loop runs until the producer finishes), so
/// the pool is sized to the sort's peak writer concurrency; when every
/// worker is busy, [`try_execute`](WriterPool::try_execute) hands the
/// job back and the caller spawns a dedicated thread — the pre-pool
/// behaviour — instead of risking a wait.
pub struct WriterPool {
    /// `None` after teardown begins (drop closes the queue).
    jobs: Mutex<Option<mpsc::Sender<PoolJob>>>,
    /// Unoccupied workers; claimed at submit, released as a job ends.
    available: Arc<AtomicUsize>,
    workers: Vec<JoinHandle<()>>,
}

impl WriterPool {
    /// Spawn a pool of `workers` threads (clamped to ≥ 1). Errors
    /// (instead of aborting) when the OS refuses a thread.
    pub fn new(workers: usize) -> Result<Self> {
        let n = workers.max(1);
        let (tx, rx) = mpsc::channel::<PoolJob>();
        let rx = Arc::new(Mutex::new(rx));
        let available = Arc::new(AtomicUsize::new(n));
        let mut handles = Vec::with_capacity(n);
        for _ in 0..n {
            let rx = Arc::clone(&rx);
            let handle = std::thread::Builder::new()
                .name("flims-writer-pool".into())
                .spawn(move || loop {
                    let job = rx.lock().unwrap().recv();
                    let Ok(job) = job else { break };
                    job();
                })
                .map_err(|e| anyhow!("spawning writer-pool thread: {e}"))?;
            handles.push(handle);
        }
        Ok(WriterPool { jobs: Mutex::new(Some(tx)), available, workers: handles })
    }

    /// Number of pool workers.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Run `job` on an idle pool worker, or hand it back when every
    /// worker is occupied (the caller then runs it on a dedicated
    /// thread). Never blocks, so a caller that outnumbers the pool
    /// cannot deadlock it.
    pub fn try_execute(&self, job: PoolJob) -> std::result::Result<(), PoolJob> {
        let claimed = self
            .available
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| n.checked_sub(1));
        if claimed.is_err() {
            return Err(job);
        }
        let guard = self.jobs.lock().unwrap();
        let Some(tx) = guard.as_ref() else {
            // Teardown already began: release the claim, hand the job back.
            self.available.fetch_add(1, Ordering::AcqRel);
            return Err(job);
        };
        let avail = Arc::clone(&self.available);
        let wrapped: PoolJob = Box::new(move || {
            job();
            avail.fetch_add(1, Ordering::AcqRel);
        });
        match tx.send(wrapped) {
            Ok(()) => Ok(()),
            // Unreachable while `tx` lives (workers only exit once the
            // queue closes), but stay safe: the returned wrapped job
            // releases the claim when the caller runs it on a fallback
            // thread, so the count still balances.
            Err(e) => Err(e.0),
        }
    }
}

impl Drop for WriterPool {
    fn drop(&mut self) {
        // Closing the queue releases idle workers; busy ones exit after
        // their current writer finishes (every writer is finished or
        // dropped before the pool goes away in normal flow).
        *self.jobs.lock().unwrap() = None;
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Write-side double buffering: a writer thread owns the inner
/// [`RecordSink`] and drains a bounded channel of blocks, so encode +
/// disk write overlap with the producer's next chunk of work instead of
/// blocking it (the mirror image of [`PrefetchStream`]). Blocks arrive
/// in send order from a single producer, so the bytes on disk are
/// identical to the synchronous path — determinism is untouched. The
/// thread is borrowed from a [`WriterPool`] when one is supplied and has
/// an idle worker; otherwise it is a dedicated spawn.
pub struct DoubleBufWriter<T, W> {
    tx: Option<mpsc::SyncSender<Vec<T>>>,
    /// Drained buffers coming back from the writer thread, so the
    /// steady state recycles `depth + 1` allocations instead of
    /// allocating per block.
    recycle: mpsc::Receiver<Vec<T>>,
    /// Resolves once the writer loop ends, handing the inner sink (and
    /// its first error) back — works identically for pooled and
    /// dedicated threads.
    done: Option<mpsc::Receiver<(W, Result<()>)>>,
    /// Present only on the dedicated-thread fallback; joined after
    /// `done` resolves so the thread is reaped.
    handle: Option<JoinHandle<()>>,
}

/// The writer-thread body: drain blocks into `inner` until the channel
/// closes (or the first write error), recycling drained buffers.
fn writer_loop<T: ExtItem, W: RecordSink<T>>(
    mut inner: W,
    rx: mpsc::Receiver<Vec<T>>,
    recycle_tx: mpsc::Sender<Vec<T>>,
) -> (W, Result<()>) {
    let mut res = Ok(());
    while let Ok(mut buf) = rx.recv() {
        if let Err(e) = RecordSink::write_block(&mut inner, &buf) {
            // Breaking drops the receiver; the producer's next send
            // fails and surfaces this error.
            res = Err(e);
            break;
        }
        // Hand the drained buffer back for reuse; the producer may be
        // gone already (send-and-finish).
        buf.clear();
        let _ = recycle_tx.send(buf);
    }
    (inner, res)
}

impl<T: ExtItem, W: RecordSink<T> + Send + 'static> DoubleBufWriter<T, W> {
    /// [`spawn_with`](DoubleBufWriter::spawn_with) on a dedicated
    /// thread (no pool).
    pub fn spawn(inner: W, depth: usize) -> Result<Self> {
        Self::spawn_with(inner, depth, None)
    }

    /// Move `inner` onto a writer thread buffering up to `depth` blocks
    /// (clamped to ≥ 1; `1` is classic double buffering — one block in
    /// flight while the producer fills the next). The thread comes from
    /// `pool` when given and idle, else a dedicated spawn. Errors
    /// (instead of aborting) when the OS refuses another thread.
    pub fn spawn_with(inner: W, depth: usize, pool: Option<&WriterPool>) -> Result<Self> {
        let (tx, rx) = mpsc::sync_channel::<Vec<T>>(depth.max(1));
        let (recycle_tx, recycle) = mpsc::channel::<Vec<T>>();
        let (done_tx, done) = mpsc::channel::<(W, Result<()>)>();
        let mut job: PoolJob = Box::new(move || {
            let _ = done_tx.send(writer_loop(inner, rx, recycle_tx));
        });
        if let Some(pool) = pool {
            match pool.try_execute(job) {
                Ok(()) => {
                    return Ok(DoubleBufWriter {
                        tx: Some(tx),
                        recycle,
                        done: Some(done),
                        handle: None,
                    })
                }
                Err(back) => job = back, // pool saturated: dedicated fallback
            }
        }
        let handle = std::thread::Builder::new()
            .name("flims-spill-write".into())
            .spawn(job)
            .map_err(|e| anyhow!("spawning spill writer thread: {e}"))?;
        Ok(DoubleBufWriter { tx: Some(tx), recycle, done: Some(done), handle: Some(handle) })
    }

    /// Queue an owned block (no copy). Blocks only when `depth` blocks
    /// are already in flight.
    pub fn send(&mut self, buf: Vec<T>) -> Result<()> {
        if buf.is_empty() {
            return Ok(());
        }
        let sent = match &self.tx {
            Some(tx) => tx.send(buf).is_ok(),
            None => bail!("spill writer already finished"),
        };
        if !sent {
            // The writer thread exited early: report its real error.
            return Err(match self.shut_down() {
                Err(e) => e,
                Ok(_) => anyhow!("spill writer thread exited unexpectedly"),
            });
        }
        Ok(())
    }

    /// Queue a copy of `xs` (the streaming-merge path, whose block
    /// buffer is reused). The copy lands in a recycled buffer when one
    /// is available, so the steady state allocates nothing.
    pub fn write_block(&mut self, xs: &[T]) -> Result<()> {
        let mut buf = self.recycle.try_recv().unwrap_or_default();
        buf.clear();
        buf.extend_from_slice(xs);
        self.send(buf)
    }

    /// Close the queue, wait for every block to hit the inner writer,
    /// and hand the inner writer back (for `finish()` etc). Any write
    /// error from the thread surfaces here.
    pub fn finish(mut self) -> Result<W> {
        self.shut_down()
    }

    fn shut_down(&mut self) -> Result<W> {
        self.tx = None; // closing the channel lets the writer drain + exit
        let done = self
            .done
            .take()
            .ok_or_else(|| anyhow!("spill writer already finished"))?;
        let got = done.recv().map_err(|_| anyhow!("spill writer thread panicked"));
        if let Some(h) = self.handle.take() {
            let _ = h.join(); // reap the dedicated fallback thread
        }
        let (inner, res) = got?;
        res?;
        Ok(inner)
    }
}

impl<T, W> Drop for DoubleBufWriter<T, W> {
    fn drop(&mut self) {
        // Error-path cleanup: stop the writer and wait it out so no
        // writes race the caller's file cleanup. The wait cannot
        // deadlock — the block channel is already closed, so the loop
        // (pooled or dedicated) drains and reports promptly.
        self.tx = None;
        if let Some(done) = self.done.take() {
            let _ = done.recv();
        }
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One buffered input side of a merge node.
struct Side<T> {
    buf: Vec<T>,
    /// Consumed prefix of `buf`.
    pos: usize,
    /// The child returned 0 — no future elements exist.
    done: bool,
}

impl<T: Item> Side<T> {
    fn new() -> Self {
        Side { buf: Vec::new(), pos: 0, done: false }
    }

    fn avail(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Top up to at least `target` available elements (unless the child
    /// runs dry first). Invariant afterwards: `avail() == 0 ⇒ done`.
    fn refill(&mut self, child: &mut dyn RunStream<T>, target: usize) -> Result<()> {
        if self.done || self.avail() >= target {
            return Ok(());
        }
        self.buf.drain(..self.pos);
        self.pos = 0;
        while self.buf.len() < target {
            if child.next_block(&mut self.buf)? == 0 {
                self.done = true;
                break;
            }
        }
        Ok(())
    }

    /// Minimum buffered key — every *future* element from this side has
    /// a key ≤ this value (descending input). `None` = exhausted, no
    /// constraint.
    fn min_bound(&self) -> Option<T::K> {
        if self.done {
            None
        } else {
            self.buf.last().map(|x| x.key())
        }
    }
}

/// Internal node: FLiMS 2-way merge of two child streams via
/// [`ExtItem::merge_into`]. Side A must carry the earlier input runs —
/// the stable split and merger give its records priority on key ties.
pub struct MergeStream<T: ExtItem> {
    a: Box<dyn RunStream<T>>,
    b: Box<dyn RunStream<T>>,
    sa: Side<T>,
    sb: Side<T>,
    block: usize,
    w: usize,
    kernel: MergeKernel,
}

impl<T: ExtItem> MergeStream<T> {
    /// Merge node over children `a` (earlier input — wins key ties) and
    /// `b`, buffering `block` elements per side, FLiMS lane width `w`,
    /// per-block merges dispatched through `kernel`.
    pub fn new(
        a: Box<dyn RunStream<T>>,
        b: Box<dyn RunStream<T>>,
        block: usize,
        w: usize,
        kernel: MergeKernel,
    ) -> Self {
        assert!(w.is_power_of_two());
        MergeStream { a, b, sa: Side::new(), sb: Side::new(), block: block.max(1), w, kernel }
    }
}

impl<T: ExtItem> RunStream<T> for MergeStream<T> {
    fn next_block(&mut self, out: &mut Vec<T>) -> Result<usize> {
        self.sa.refill(self.a.as_mut(), self.block)?;
        self.sb.refill(self.b.as_mut(), self.block)?;
        let (av, bv) = (self.sa.avail(), self.sb.avail());
        if av == 0 && bv == 0 {
            return Ok(0);
        }
        // One side exhausted entirely: pass the other buffer through
        // (refill guarantees avail()==0 implies done).
        if av == 0 {
            out.extend_from_slice(&self.sb.buf[self.sb.pos..]);
            self.sb.pos = self.sb.buf.len();
            return Ok(bv);
        }
        if bv == 0 {
            out.extend_from_slice(&self.sa.buf[self.sa.pos..]);
            self.sa.pos = self.sa.buf.len();
            return Ok(av);
        }
        // Stability-safe prefix split. Future B keys are ≤ B's bound, so
        // an A record ≥ that bound can never be preceded by unseen B data
        // (an equal future B key sorts after it: A wins ties). A B record
        // needs its key strictly above A's bound — an equal future A key
        // would have to come first.
        let a_avail = &self.sa.buf[self.sa.pos..];
        let b_avail = &self.sb.buf[self.sb.pos..];
        let ka = match self.sb.min_bound() {
            None => av,
            Some(tb) => a_avail.partition_point(|x| x.key() >= tb),
        };
        let kb = match self.sa.min_bound() {
            None => bv,
            Some(ta) => b_avail.partition_point(|x| x.key() > ta),
        };
        if ka + kb == 0 {
            // Unreachable: if every B key ≤ A's minimum then every A key
            // ≥ B's bound, so the whole A buffer qualifies.
            bail!("merge stream stalled (avail {av}/{bv})");
        }
        T::merge_into(&a_avail[..ka], &b_avail[..kb], self.w, self.kernel, out);
        self.sa.pos += ka;
        self.sb.pos += kb;
        Ok(ka + kb)
    }
}

/// Fold `streams` into a balanced binary tree of [`MergeStream`] nodes.
/// Input order is preserved left-to-right (earlier streams become A
/// sides), so a run list ordered by input position merges stably.
/// Panics on an empty input (callers handle the zero-run case).
pub fn build_tree<T: ExtItem>(
    mut streams: Vec<Box<dyn RunStream<T>>>,
    block: usize,
    w: usize,
    kernel: MergeKernel,
) -> Box<dyn RunStream<T>> {
    assert!(!streams.is_empty(), "build_tree needs at least one stream");
    while streams.len() > 1 {
        let mut next: Vec<Box<dyn RunStream<T>>> = Vec::with_capacity(streams.len().div_ceil(2));
        let mut it = streams.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(Box::new(MergeStream::new(a, b, block, w, kernel))),
                None => next.push(a),
            }
        }
        streams = next;
    }
    streams.pop().unwrap()
}

/// Drain a stream into `emit` block-by-block; returns total elements.
pub fn pump<T>(
    stream: &mut dyn RunStream<T>,
    mut emit: impl FnMut(&[T]) -> Result<()>,
) -> Result<u64> {
    let mut chunk = Vec::new();
    let mut total = 0u64;
    loop {
        chunk.clear();
        let n = stream.next_block(&mut chunk)?;
        if n == 0 {
            return Ok(total);
        }
        emit(&chunk)?;
        total += n as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_kv, gen_u32, Distribution};
    use crate::key::{is_sorted_desc, Kv};
    use crate::util::rng::Rng;

    /// In-memory descending stream with configurable emission sizes.
    struct VecStream {
        data: Vec<u32>,
        pos: usize,
        step: usize,
    }

    impl VecStream {
        fn new(mut data: Vec<u32>, step: usize) -> Self {
            data.sort_unstable_by(|a, b| b.cmp(a));
            VecStream { data, pos: 0, step }
        }
    }

    impl RunStream<u32> for VecStream {
        fn next_block(&mut self, out: &mut Vec<u32>) -> Result<usize> {
            let take = self.step.min(self.data.len() - self.pos);
            out.extend_from_slice(&self.data[self.pos..self.pos + take]);
            self.pos += take;
            Ok(take)
        }
    }

    fn drain(stream: &mut dyn RunStream<u32>) -> Vec<u32> {
        let mut out = Vec::new();
        pump(stream, |c| {
            out.extend_from_slice(c);
            Ok(())
        })
        .unwrap();
        out
    }

    fn oracle(lists: &[Vec<u32>]) -> Vec<u32> {
        let mut v: Vec<u32> = lists.iter().flatten().copied().collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v
    }

    #[test]
    fn two_way_matches_oracle_across_shapes() {
        let mut rng = Rng::new(81);
        for (na, nb) in [(0, 0), (0, 500), (500, 0), (1, 1), (1000, 37), (512, 512)] {
            for block in [1usize, 7, 64] {
                let a = gen_u32(&mut rng, na, Distribution::Uniform);
                let b = gen_u32(&mut rng, nb, Distribution::Uniform);
                let expect = oracle(&[a.clone(), b.clone()]);
                let mut m: MergeStream<u32> = MergeStream::new(
                    Box::new(VecStream::new(a, 13)),
                    Box::new(VecStream::new(b, 5)),
                    block,
                    8,
                    MergeKernel::env_default(),
                );
                assert_eq!(drain(&mut m), expect, "na={na} nb={nb} block={block}");
            }
        }
    }

    #[test]
    fn duplicate_heavy_and_constant_streams() {
        let mut rng = Rng::new(82);
        for dist in [
            Distribution::DupHeavy { alphabet: 2 },
            Distribution::Constant,
            Distribution::Zipf { s_x100: 150, n_ranks: 16 },
        ] {
            let a = gen_u32(&mut rng, 700, dist);
            let b = gen_u32(&mut rng, 300, dist);
            let expect = oracle(&[a.clone(), b.clone()]);
            let mut m: MergeStream<u32> = MergeStream::new(
                Box::new(VecStream::new(a, 11)),
                Box::new(VecStream::new(b, 23)),
                32,
                16,
                MergeKernel::env_default(),
            );
            assert_eq!(drain(&mut m), expect, "{dist:?}");
        }
    }

    #[test]
    fn tree_merges_many_streams() {
        let mut rng = Rng::new(83);
        for k in [1usize, 2, 3, 5, 8, 13] {
            let lists: Vec<Vec<u32>> =
                (0..k).map(|i| gen_u32(&mut rng, 50 + i * 37, Distribution::Uniform)).collect();
            let expect = oracle(&lists);
            let streams: Vec<Box<dyn RunStream<u32>>> = lists
                .iter()
                .map(|l| Box::new(VecStream::new(l.clone(), 9)) as Box<dyn RunStream<u32>>)
                .collect();
            let mut tree = build_tree(streams, 16, 8, MergeKernel::env_default());
            let got = drain(tree.as_mut());
            assert!(is_sorted_desc(&got));
            assert_eq!(got, expect, "k={k}");
        }
    }

    #[test]
    fn blocks_are_individually_sorted() {
        let mut rng = Rng::new(84);
        let a = gen_u32(&mut rng, 400, Distribution::Uniform);
        let b = gen_u32(&mut rng, 400, Distribution::Uniform);
        let mut m: MergeStream<u32> = MergeStream::new(
            Box::new(VecStream::new(a, 17)),
            Box::new(VecStream::new(b, 29)),
            32,
            8,
            MergeKernel::env_default(),
        );
        let mut chunk = Vec::new();
        let mut last: Option<u32> = None;
        loop {
            chunk.clear();
            if m.next_block(&mut chunk).unwrap() == 0 {
                break;
            }
            assert!(is_sorted_desc(&chunk));
            // Blocks are globally ordered too: each starts no higher
            // than the previous block's tail.
            if let Some(prev_min) = last {
                assert!(chunk[0] <= prev_min);
            }
            last = chunk.last().copied();
        }
    }

    /// Kv stream over pre-sorted records, for stability checks.
    struct KvStream {
        data: Vec<Kv>,
        pos: usize,
        step: usize,
    }

    impl RunStream<Kv> for KvStream {
        fn next_block(&mut self, out: &mut Vec<Kv>) -> Result<usize> {
            let take = self.step.min(self.data.len() - self.pos);
            out.extend_from_slice(&self.data[self.pos..self.pos + take]);
            self.pos += take;
            Ok(take)
        }
    }

    #[test]
    fn merge_stream_is_stable_on_ties() {
        // Duplicate-heavy inputs: A's records must precede B's on equal
        // keys, each input keeping its own order — across block splits.
        let mut rng = Rng::new(85);
        for (step_a, step_b, block) in [(3usize, 5usize, 4usize), (16, 7, 32), (1, 1, 1)] {
            let mut a = gen_kv(&mut rng, 300, Distribution::DupHeavy { alphabet: 4 });
            let mut b = gen_kv(&mut rng, 200, Distribution::DupHeavy { alphabet: 4 });
            // B payloads offset so provenance is visible.
            for kv in &mut b {
                kv.val += 10_000;
            }
            a.sort_by(|x, y| y.key.cmp(&x.key)); // std stable sort
            b.sort_by(|x, y| y.key.cmp(&x.key));
            let mut expect: Vec<Kv> = a.iter().chain(b.iter()).copied().collect();
            // Stable oracle: by key desc; ties keep A-then-B order
            // because sort_by is stable and A precedes B in the input.
            expect.sort_by(|x, y| y.key.cmp(&x.key));
            let mut m: MergeStream<Kv> = MergeStream::new(
                Box::new(KvStream { data: a, pos: 0, step: step_a }),
                Box::new(KvStream { data: b, pos: 0, step: step_b }),
                block,
                8,
                MergeKernel::env_default(),
            );
            let mut got = Vec::new();
            pump(&mut m, |c| {
                got.extend_from_slice(c);
                Ok(())
            })
            .unwrap();
            assert_eq!(got, expect, "step_a={step_a} step_b={step_b} block={block}");
        }
    }

    #[test]
    fn prefetch_stream_matches_reader_stream() {
        use super::super::format::{RunReader, RunWriter};
        let dir = std::env::temp_dir().join(format!("flims-prefetch-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pf.flr");
        let mut rng = Rng::new(86);
        let mut data = gen_u32(&mut rng, 10_000, Distribution::Uniform);
        data.sort_unstable_by(|a, b| b.cmp(a));
        let mut w = RunWriter::create(&path).unwrap();
        w.write_block(&data).unwrap();
        w.finish().unwrap();

        for depth in [1usize, 2, 8] {
            let counters = Arc::new(PrefetchCounters::default());
            let mut s: PrefetchStream<u32> = PrefetchStream::spawn(
                RunReader::open(&path).unwrap(),
                257,
                depth,
                Arc::clone(&counters),
            )
            .unwrap();
            let mut got = Vec::new();
            pump(&mut s, |c| {
                got.extend_from_slice(c);
                Ok(())
            })
            .unwrap();
            assert_eq!(got, data, "depth={depth}");
            let served = counters.hits.load(Ordering::Relaxed)
                + counters.misses.load(Ordering::Relaxed);
            assert_eq!(served, (10_000u64).div_ceil(257), "depth={depth}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn prefetch_stream_drops_cleanly_mid_stream() {
        use super::super::format::{RunReader, RunWriter};
        let dir = std::env::temp_dir().join(format!("flims-prefetch-drop-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pf.flr");
        let data: Vec<u32> = (0..50_000u32).rev().collect();
        let mut w = RunWriter::create(&path).unwrap();
        w.write_block(&data).unwrap();
        w.finish().unwrap();

        let counters = Arc::new(PrefetchCounters::default());
        let mut s: PrefetchStream<u32> =
            PrefetchStream::spawn(RunReader::open(&path).unwrap(), 64, 2, counters).unwrap();
        let mut out = Vec::new();
        s.next_block(&mut out).unwrap();
        assert!(!out.is_empty());
        drop(s); // must join the reader thread without deadlocking
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn double_buf_writer_matches_sync_writer_bytes() {
        use super::super::codec::Codec;
        use super::super::format::RunWriter;
        let dir = std::env::temp_dir().join(format!("flims-dbw-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(87);
        let mut data = gen_u32(&mut rng, 20_000, Distribution::Uniform);
        data.sort_unstable_by(|a, b| b.cmp(a));

        for codec in [Codec::Raw, Codec::Delta, Codec::Flr3] {
            let sync_path = dir.join(format!("sync.{}", codec.name()));
            let mut w = RunWriter::create_with(&sync_path, codec).unwrap();
            for chunk in data.chunks(777) {
                w.write_block(chunk).unwrap();
            }
            let sync_run = w.finish().unwrap();

            let async_path = dir.join(format!("async.{}", codec.name()));
            let inner = RunWriter::create_with(&async_path, codec).unwrap();
            let mut dbw = DoubleBufWriter::spawn(inner, 2).unwrap();
            for chunk in data.chunks(777) {
                dbw.write_block(chunk).unwrap();
            }
            let async_run = dbw.finish().unwrap().finish().unwrap();

            assert_eq!(async_run.elems, sync_run.elems, "{codec:?}");
            assert_eq!(async_run.bytes, sync_run.bytes, "{codec:?}");
            assert_eq!(
                std::fs::read(&sync_path).unwrap(),
                std::fs::read(&async_path).unwrap(),
                "double-buffered bytes must be identical ({codec:?})"
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn double_buf_writer_surfaces_inner_errors() {
        struct Failing {
            after: usize,
        }
        impl RecordSink<u32> for Failing {
            fn write_block(&mut self, xs: &[u32]) -> Result<()> {
                if self.after < xs.len() {
                    anyhow::bail!("simulated disk full");
                }
                self.after -= xs.len();
                Ok(())
            }
        }
        let mut dbw = DoubleBufWriter::spawn(Failing { after: 100 }, 1).unwrap();
        // Keep feeding until the failure propagates back through send
        // (the channel disconnect) or finish.
        let mut failed = None;
        for _ in 0..100 {
            if let Err(e) = dbw.write_block(&[1u32; 64]) {
                failed = Some(format!("{e:#}"));
                break;
            }
        }
        let msg = match failed {
            Some(m) => m,
            None => format!("{:#}", dbw.finish().map(|_| ()).unwrap_err()),
        };
        assert!(msg.contains("simulated disk full"), "{msg}");
    }

    #[test]
    fn pooled_writer_matches_dedicated_bytes() {
        use super::super::codec::Codec;
        use super::super::format::RunWriter;
        let dir = std::env::temp_dir().join(format!("flims-pool-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(88);
        let mut data = gen_u32(&mut rng, 20_000, Distribution::Uniform);
        data.sort_unstable_by(|a, b| b.cmp(a));

        let pool = WriterPool::new(2);
        let pool = pool.unwrap();
        // Many sequential runs through the same 2-worker pool: the whole
        // point of pooling — no per-run thread spawn — and the bytes
        // must match the dedicated-thread writer exactly.
        for (i, codec) in [Codec::Raw, Codec::Delta, Codec::Flr3, Codec::Raw, Codec::Delta]
            .into_iter()
            .enumerate()
        {
            let ded_path = dir.join(format!("ded-{i}.flr"));
            let mut ded = DoubleBufWriter::spawn(
                RunWriter::<u32>::create_with(&ded_path, codec).unwrap(),
                1,
            )
            .unwrap();
            let pooled_path = dir.join(format!("pooled-{i}.flr"));
            let mut pooled = DoubleBufWriter::spawn_with(
                RunWriter::<u32>::create_with(&pooled_path, codec).unwrap(),
                1,
                Some(&pool),
            )
            .unwrap();
            for chunk in data.chunks(997) {
                ded.write_block(chunk).unwrap();
                pooled.write_block(chunk).unwrap();
            }
            let d = ded.finish().unwrap().finish().unwrap();
            let p = pooled.finish().unwrap().finish().unwrap();
            assert_eq!(d.bytes, p.bytes, "run {i}");
            assert_eq!(
                std::fs::read(&ded_path).unwrap(),
                std::fs::read(&pooled_path).unwrap(),
                "pooled bytes must be identical (run {i})"
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn saturated_pool_falls_back_to_dedicated_threads() {
        use super::super::format::RunWriter;
        let dir = std::env::temp_dir().join(format!("flims-poolsat-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let pool = WriterPool::new(1);
        let pool = pool.unwrap();
        assert_eq!(pool.workers(), 1);
        // Three *concurrently live* writers against a 1-worker pool: the
        // extra two ride the dedicated-thread fallback, and all three
        // land their data.
        let mut writers = Vec::new();
        for i in 0..3 {
            let path = dir.join(format!("w{i}.flr"));
            let inner = RunWriter::<u32>::create(&path).unwrap();
            writers.push((path, DoubleBufWriter::spawn_with(inner, 1, Some(&pool)).unwrap()));
        }
        for (i, (_, w)) in writers.iter_mut().enumerate() {
            w.write_block(&[i as u32, 100 + i as u32]).unwrap();
        }
        for (i, (path, w)) in writers.into_iter().enumerate() {
            let run = w.finish().unwrap().finish().unwrap();
            assert_eq!(run.elems, 2, "writer {i}");
            assert!(path.exists());
        }
        // The pool worker is idle again: a fresh job goes through it.
        let ran = Arc::new(AtomicU64::new(0));
        let flag = Arc::clone(&ran);
        assert!(pool.try_execute(Box::new(move || { flag.fetch_add(1, Ordering::SeqCst); })).is_ok());
        drop(pool); // drop joins workers, so the job has run
        assert_eq!(ran.load(Ordering::SeqCst), 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
