//! Phase 2: arrange spilled runs into FLiMS merge trees and execute the
//! (possibly multi-pass) k-way merge, generic over the record type.
//!
//! A [`MergePlan`] caps every tree at the configured fan-in: while more
//! runs exist than the fan-in allows, a pass merges balanced groups of
//! runs into fresh (larger) spilled runs; the final pass streams the
//! surviving ≤ fan-in runs straight into the caller's sink. Group merges
//! within a pass are independent, so they run concurrently in batches of
//! `cfg.effective_threads()` — the HPMT replication argument (§5) at the
//! tree-of-trees level. Consumed runs are deleted as each group's result
//! lands, so live spill stays near the dataset size rather than growing
//! with the pass count. Tree leaves are double-buffered
//! ([`PrefetchStream`](super::stream::PrefetchStream)) when
//! `cfg.prefetch_blocks > 0`, overlapping disk reads with merging.
//!
//! Runs enter and leave every pass in input order and each tree keeps
//! earlier runs on A sides, so key ties resolve to input order end to
//! end (the §6 stability guarantee).

use std::sync::atomic::Ordering;
use std::sync::Arc;

use anyhow::{anyhow, Error, Result};

use super::format::{ExtItem, RawWriter, RunFile, RunReader, RunWriter, RUN_HEADER_BYTES};
use super::spill::SpillManager;
use super::stream::{
    build_tree, pump, DoubleBufWriter, PrefetchCounters, PrefetchStream, ReaderStream, RunStream,
};
use super::ExternalConfig;

/// The pass/group structure for merging `k` runs at a given fan-in.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MergePlan {
    /// Maximum runs per tree.
    pub fan_in: usize,
    /// Group sizes for each intermediate (spilling) pass.
    pub intermediate: Vec<Vec<usize>>,
    /// Number of runs entering the final (streaming) pass.
    pub final_width: usize,
}

impl MergePlan {
    /// Plan the merge of `k` runs at `fan_in` (≥ 2).
    pub fn new(k: usize, fan_in: usize) -> Self {
        assert!(fan_in >= 2, "fan_in must be at least 2");
        let mut intermediate = Vec::new();
        let mut k = k;
        while k > fan_in {
            intermediate.push(group_sizes(k, fan_in));
            k = k.div_ceil(fan_in);
        }
        MergePlan { fan_in, intermediate, final_width: k }
    }

    /// Total passes over the data, counting the final streaming pass.
    pub fn passes(&self) -> u64 {
        self.intermediate.len() as u64 + u64::from(self.final_width > 0)
    }
}

/// Split `k` runs into `ceil(k / fan_in)` balanced groups (sizes differ
/// by at most one), avoiding the degenerate 1-run groups a plain
/// chunks-of-fan-in split produces when `k % fan_in == 1`.
fn group_sizes(k: usize, fan_in: usize) -> Vec<usize> {
    let groups = k.div_ceil(fan_in);
    let base = k / groups;
    let extra = k % groups;
    (0..groups).map(|i| base + usize::from(i < extra)).collect()
}

/// Where the merged output goes: the final dataset file, a fresh run, or
/// an in-memory buffer (service-path small sorts, tests).
pub trait RecordSink<T: ExtItem> {
    /// Append one block of merged records.
    fn write_block(&mut self, xs: &[T]) -> Result<()>;
}

impl<T: ExtItem> RecordSink<T> for Vec<T> {
    fn write_block(&mut self, xs: &[T]) -> Result<()> {
        self.extend_from_slice(xs);
        Ok(())
    }
}

impl<T: ExtItem> RecordSink<T> for RawWriter<T> {
    fn write_block(&mut self, xs: &[T]) -> Result<()> {
        RawWriter::write_block(self, xs)
    }
}

impl<T: ExtItem> RecordSink<T> for RunWriter<T> {
    fn write_block(&mut self, xs: &[T]) -> Result<()> {
        RunWriter::write_block(self, xs)
    }
}

// A double-buffered writer is a sink too: `sort_file` wraps its output
// `RawWriter` in one (so the final pass's merge never blocks on the
// output disk — the ROADMAP's write-side-buffering follow-on) and the
// spill paths wrap `RunWriter`s.
impl<T: ExtItem, W: RecordSink<T> + Send + 'static> RecordSink<T> for DoubleBufWriter<T, W> {
    fn write_block(&mut self, xs: &[T]) -> Result<()> {
        DoubleBufWriter::write_block(self, xs)
    }
}

/// Result of executing a merge plan.
#[derive(Clone, Copy, Debug, Default)]
pub struct MergeOutcome {
    /// Elements streamed into the sink by the final pass.
    pub elements: u64,
    /// Passes over the data (intermediate + final).
    pub merge_passes: u64,
    /// Leaf blocks served without blocking (prefetch already had them).
    pub prefetch_hits: u64,
    /// Leaf blocks the merger had to wait for.
    pub prefetch_misses: u64,
    /// Wall-clock the leaf readers spent decoding `FLR2` blocks, µs
    /// (overlapped with merging whenever prefetch is on).
    pub codec_decode_us: u64,
}

fn open_group<T: ExtItem>(
    group: &[RunFile],
    cfg: &ExternalConfig,
    counters: &Arc<PrefetchCounters>,
) -> Result<Box<dyn RunStream<T>>> {
    let block = cfg.block_elems_for(T::WIRE_BYTES);
    let mut streams: Vec<Box<dyn RunStream<T>>> = Vec::with_capacity(group.len());
    for run in group {
        let reader =
            RunReader::<T>::open_with(&run.path, Some(Arc::clone(&counters.decode_ns)))?;
        if cfg.prefetch_blocks > 0 {
            streams.push(Box::new(PrefetchStream::spawn(
                reader,
                block,
                cfg.prefetch_blocks,
                Arc::clone(counters),
            )?));
        } else {
            streams.push(Box::new(ReaderStream::new(reader, block)));
        }
    }
    Ok(build_tree(streams, block, cfg.w))
}

/// Merge one group of runs into a pre-created run writer. Runs on a
/// worker thread during intermediate passes; touches no shared state
/// beyond the prefetch counters. The writer is double-buffered so
/// re-encoding + writing the merged run overlaps with merging the next
/// block instead of stalling it.
fn merge_group<T: ExtItem>(
    group: &[RunFile],
    cfg: &ExternalConfig,
    counters: &Arc<PrefetchCounters>,
    writer: RunWriter<T>,
) -> Result<(RunFile, u64)> {
    let mut tree = open_group::<T>(group, cfg, counters)?;
    let mut dbw = DoubleBufWriter::spawn(writer, 1)?;
    let written = pump(tree.as_mut(), |chunk| dbw.write_block(chunk))?;
    Ok((dbw.finish()?.finish()?, written))
}

/// Merge `runs` into `sink` per `MergePlan::new(runs.len(), fan_in)`,
/// spilling intermediate passes through `spill` (group merges of a pass
/// run concurrently) and deleting consumed runs as results land.
pub fn merge_runs<T: ExtItem>(
    mut runs: Vec<RunFile>,
    cfg: &ExternalConfig,
    spill: &mut SpillManager,
    sink: &mut dyn RecordSink<T>,
) -> Result<MergeOutcome> {
    let plan = MergePlan::new(runs.len(), cfg.fan_in);
    let counters = Arc::new(PrefetchCounters::default());
    let threads = cfg.effective_threads().max(1);
    let codec = cfg.codec_for(T::DTYPE);

    for sizes in &plan.intermediate {
        let mut next: Vec<Option<RunFile>> = vec![None; sizes.len()];
        let mut jobs: Vec<(usize, Vec<RunFile>)> = Vec::new();
        let mut idx = 0;
        for (gi, &sz) in sizes.iter().enumerate() {
            let group = runs[idx..idx + sz].to_vec();
            idx += sz;
            if sz == 1 {
                // A lone run needs no merging; carry it forward as-is.
                next[gi] = Some(group.into_iter().next().unwrap());
            } else {
                jobs.push((gi, group));
            }
        }

        for batch in jobs.chunks(threads) {
            // Enforce the disk budget for the whole batch before any
            // merged run is written, not after the disk has filled. The
            // projection is the uncompressed size — conservative when
            // the codec compresses.
            let upcoming: u64 = batch
                .iter()
                .map(|(_, g)| {
                    RUN_HEADER_BYTES
                        + g.iter().map(|r| r.elems).sum::<u64>() * T::WIRE_BYTES as u64
                })
                .sum();
            spill.check_headroom(upcoming)?;
            // Writers are created in group order on this thread, so run
            // numbering stays deterministic for any worker count.
            // Intermediate runs re-encode through the same codec as
            // phase 1 — every byte crossing the spill boundary flows
            // through the codec layer in both phases.
            let mut writers = Vec::with_capacity(batch.len());
            for _ in batch {
                writers.push(spill.create_run::<T>(codec)?);
            }
            let out_paths: Vec<std::path::PathBuf> =
                writers.iter().map(|w| w.path().to_path_buf()).collect();

            let results: Vec<Result<(RunFile, u64)>> = std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(batch.len());
                for ((_, group), writer) in batch.iter().zip(writers) {
                    let counters = Arc::clone(&counters);
                    handles.push(s.spawn(move || merge_group::<T>(group, cfg, &counters, writer)));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("merge worker panicked"))
                    .collect()
            });

            // Register outputs / delete inputs in group order; on error,
            // sweep the batch's remaining outputs so nothing leaks.
            let mut first_err: Option<Error> = None;
            for (((gi, group), res), out_path) in batch.iter().zip(results).zip(&out_paths) {
                match res {
                    Err(e) => {
                        let _ = std::fs::remove_file(out_path);
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                    Ok((merged, written)) => {
                        if first_err.is_some() {
                            let _ = std::fs::remove_file(&merged.path);
                            continue;
                        }
                        let expect: u64 = group.iter().map(|r| r.elems).sum();
                        if written != expect {
                            first_err = Some(anyhow!(
                                "merge pass lost data: wrote {written} of {expect} elements"
                            ));
                            let _ = std::fs::remove_file(&merged.path);
                            continue;
                        }
                        // register() keeps the run tracked even when it
                        // reports a budget breach, so Drop still cleans it.
                        if let Err(e) = spill.register(&merged) {
                            first_err = Some(e);
                            continue;
                        }
                        for run in group {
                            if let Err(e) = spill.consume(run) {
                                if first_err.is_none() {
                                    first_err = Some(e);
                                }
                            }
                        }
                        next[*gi] = Some(merged);
                    }
                }
            }
            if let Some(e) = first_err {
                return Err(e);
            }
        }
        runs = next
            .into_iter()
            .map(|r| r.expect("every group produced a run"))
            .collect();
    }

    debug_assert_eq!(runs.len(), plan.final_width);
    let mut elements = 0u64;
    if !runs.is_empty() {
        let mut tree = open_group::<T>(&runs, cfg, &counters)?;
        elements = pump(tree.as_mut(), |chunk| sink.write_block(chunk))?;
        drop(tree); // joins prefetch threads before the files go away
        for run in &runs {
            spill.consume(run)?;
        }
    }
    Ok(MergeOutcome {
        elements,
        merge_passes: plan.passes(),
        prefetch_hits: counters.hits.load(Ordering::Relaxed),
        prefetch_misses: counters.misses.load(Ordering::Relaxed),
        codec_decode_us: counters.decode_ns.load(Ordering::Relaxed) / 1000,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_single_pass_when_k_fits() {
        let p = MergePlan::new(6, 8);
        assert!(p.intermediate.is_empty());
        assert_eq!(p.final_width, 6);
        assert_eq!(p.passes(), 1);
    }

    #[test]
    fn plan_multi_pass_shapes() {
        // 20 runs at fan-in 4: pass 1 → 5 groups of 4, pass 2 → 5 runs
        // still > 4 → groups [3, 2], final over 2.
        let p = MergePlan::new(20, 4);
        assert_eq!(p.intermediate, vec![vec![4, 4, 4, 4, 4], vec![3, 2]]);
        assert_eq!(p.final_width, 2);
        assert_eq!(p.passes(), 3);
    }

    #[test]
    fn plan_avoids_degenerate_groups() {
        // 9 runs at fan-in 8: a naive split is [8, 1]; balanced is [5, 4].
        let p = MergePlan::new(9, 8);
        assert_eq!(p.intermediate, vec![vec![5, 4]]);
        assert_eq!(p.final_width, 2);
    }

    #[test]
    fn plan_zero_runs() {
        let p = MergePlan::new(0, 8);
        assert_eq!(p.final_width, 0);
        assert_eq!(p.passes(), 0);
    }

    #[test]
    fn group_sizes_cover_and_cap() {
        for k in 1..200usize {
            for fan in [2usize, 3, 4, 8, 16] {
                let sizes = group_sizes(k, fan);
                assert_eq!(sizes.iter().sum::<usize>(), k, "k={k} fan={fan}");
                assert!(sizes.iter().all(|&s| s <= fan), "k={k} fan={fan} {sizes:?}");
                assert_eq!(sizes.len(), k.div_ceil(fan));
            }
        }
    }
}
