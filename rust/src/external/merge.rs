//! Phase 2: arrange spilled runs into FLiMS merge trees and execute the
//! (possibly multi-pass) k-way merge, generic over the record type —
//! either as the classic batch schedule ([`merge_runs`]: every run
//! exists before the first tree opens) or as the overlapped pipeline
//! ([`sort_pipelined`]: groups start merging while phase 1 is still
//! spilling, the TopSort observation that the two-phase shape otherwise
//! leaves half the machine idle).
//!
//! A [`MergePlan`] caps every tree at the configured fan-in. Group
//! shapes are **prefix-stable**: pass groups are consecutive chunks of
//! exactly `fan_in` runs, so group `j` depends only on runs
//! `[j·fan_in, (j+1)·fan_in)` and can be scheduled the moment those
//! runs exist — no knowledge of the final run count needed. A lone
//! trailing run (`k % fan_in == 1`) is carried into the next pass
//! as-is, unmerged, which costs nothing (no copy pass) and keeps the
//! shapes identical between the batch and pipelined schedules — that,
//! plus runs entering and leaving every pass in input order with
//! earlier runs on tree A sides (the §6 stability guarantee), is why
//! the sorted output is byte-identical with overlap on or off. The
//! final pass streams the surviving ≤ fan-in runs straight into the
//! caller's sink.
//!
//! Group merges within a pass are independent, so they run concurrently
//! on `cfg.effective_threads()` workers — the HPMT replication argument
//! (§5) at the tree-of-trees level; under the pipeline the workers also
//! run concurrently with late phase-1 spills *and* with groups of later
//! passes. Consumed runs are deleted as each group's result lands, so
//! live spill stays near the dataset size rather than growing with the
//! pass count, and the disk budget is enforced before each group is
//! scheduled (in-flight outputs reserved). Tree leaves are
//! double-buffered ([`PrefetchStream`](super::stream::PrefetchStream))
//! when `cfg.prefetch_blocks > 0`, overlapping disk reads with merging.

use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Error, Result};

use super::format::{ExtItem, RawWriter, RunFile, RunReader, RunWriter, RUN_HEADER_BYTES};
use crate::fault::{self, Injector};
use super::run_gen::{generate_runs_streaming_ctx, RecordSource};
use super::spill::SpillManager;
use super::stream::{
    build_tree, pump, DoubleBufWriter, PrefetchCounters, PrefetchStream, ReaderStream, RunStream,
    WriterPool,
};
use super::{ExternalConfig, SortCtx};
use crate::obs::progress::ProgressHandle;
use crate::obs::{SpanKind, Trace};

/// The pass/group structure for merging `k` runs at a given fan-in.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MergePlan {
    /// Maximum runs per tree.
    pub fan_in: usize,
    /// Group sizes for each intermediate (spilling) pass. A trailing
    /// size-1 group is carried into the next pass unmerged.
    pub intermediate: Vec<Vec<usize>>,
    /// Number of runs entering the final (streaming) pass.
    pub final_width: usize,
}

impl MergePlan {
    /// Plan the merge of `k` runs at `fan_in` (≥ 2).
    pub fn new(k: usize, fan_in: usize) -> Self {
        assert!(fan_in >= 2, "fan_in must be at least 2");
        let mut intermediate = Vec::new();
        let mut k = k;
        while k > fan_in {
            intermediate.push(group_sizes(k, fan_in));
            k = k.div_ceil(fan_in);
        }
        MergePlan { fan_in, intermediate, final_width: k }
    }

    /// Total passes over the data, counting the final streaming pass.
    pub fn passes(&self) -> u64 {
        self.intermediate.len() as u64 + u64::from(self.final_width > 0)
    }
}

/// Split `k` runs into consecutive chunks of `fan_in` (the last chunk
/// holds the remainder). Prefix-stable by construction: chunk `j` is
/// fixed once runs `j·fan_in .. (j+1)·fan_in` exist, which is what lets
/// the pipelined scheduler fire groups mid-stream; a trailing 1-run
/// chunk is not a copy pass — the executor carries it forward as-is.
fn group_sizes(k: usize, fan_in: usize) -> Vec<usize> {
    (0..k.div_ceil(fan_in))
        .map(|i| fan_in.min(k - i * fan_in))
        .collect()
}

/// Where the merged output goes: the final dataset file, a fresh run, or
/// an in-memory buffer (service-path small sorts, tests).
pub trait RecordSink<T: ExtItem> {
    /// Append one block of merged records.
    fn write_block(&mut self, xs: &[T]) -> Result<()>;
}

impl<T: ExtItem> RecordSink<T> for Vec<T> {
    fn write_block(&mut self, xs: &[T]) -> Result<()> {
        self.extend_from_slice(xs);
        Ok(())
    }
}

impl<T: ExtItem> RecordSink<T> for RawWriter<T> {
    fn write_block(&mut self, xs: &[T]) -> Result<()> {
        RawWriter::write_block(self, xs)
    }
}

impl<T: ExtItem> RecordSink<T> for RunWriter<T> {
    fn write_block(&mut self, xs: &[T]) -> Result<()> {
        RunWriter::write_block(self, xs)
    }
}

// A double-buffered writer is a sink too: `sort_file` wraps its output
// `RawWriter` in one (so the final pass's merge never blocks on the
// output disk) and the spill paths wrap `RunWriter`s.
impl<T: ExtItem, W: RecordSink<T> + Send + 'static> RecordSink<T> for DoubleBufWriter<T, W> {
    fn write_block(&mut self, xs: &[T]) -> Result<()> {
        DoubleBufWriter::write_block(self, xs)
    }
}

/// Result of executing a merge plan.
#[derive(Clone, Copy, Debug, Default)]
pub struct MergeOutcome {
    /// Elements streamed into the sink by the final pass.
    pub elements: u64,
    /// Passes over the data (intermediate + final).
    pub merge_passes: u64,
    /// Leaf blocks served without blocking (prefetch already had them).
    pub prefetch_hits: u64,
    /// Leaf blocks the merger had to wait for.
    pub prefetch_misses: u64,
    /// Wall-clock the leaf readers spent decoding `FLR2` blocks, µs
    /// (overlapped with merging whenever prefetch is on).
    pub codec_decode_us: u64,
}

fn open_group<T: ExtItem>(
    group: &[RunFile],
    cfg: &ExternalConfig,
    counters: &Arc<PrefetchCounters>,
) -> Result<Box<dyn RunStream<T>>> {
    let block = cfg.block_elems_for(T::WIRE_BYTES);
    let mut streams: Vec<Box<dyn RunStream<T>>> = Vec::with_capacity(group.len());
    for run in group {
        // Keyed by the run's file name — assigned in input order by the
        // SpillManager — so the injected-fault sequence is independent
        // of worker count and group scheduling.
        let inj = match cfg.fault {
            None => Injector::disabled(),
            Some(_) => {
                let name = run.path.file_name().map(|n| n.to_string_lossy());
                Injector::for_site(cfg.fault, name.as_deref().unwrap_or("run"), &counters.trace)
            }
        };
        let reader = RunReader::<T>::open_with_fault(
            &run.path,
            Some(Arc::clone(&counters.decode_ns)),
            cfg.kernel,
            inj,
        )?;
        if cfg.prefetch_blocks > 0 {
            streams.push(Box::new(PrefetchStream::spawn(
                reader,
                block,
                cfg.prefetch_blocks,
                Arc::clone(counters),
            )?));
        } else {
            streams.push(Box::new(ReaderStream::new(reader, block)));
        }
    }
    Ok(build_tree(streams, block, cfg.w, cfg.kernel))
}

/// Merge one group of runs into a pre-created run writer. Runs on a
/// worker thread during intermediate passes; touches no shared state
/// beyond the prefetch counters. The writer is double-buffered (via the
/// per-sort writer pool when one is given) so re-encoding + writing the
/// merged run overlaps with merging the next block instead of stalling
/// it.
fn merge_group<T: ExtItem>(
    group: &[RunFile],
    cfg: &ExternalConfig,
    counters: &Arc<PrefetchCounters>,
    writer: RunWriter<T>,
    pool: Option<&WriterPool>,
    progress: &ProgressHandle,
) -> Result<(RunFile, u64)> {
    let t = counters.trace.begin();
    let mut tree = open_group::<T>(group, cfg, counters)?;
    let mut dbw = DoubleBufWriter::spawn_with(writer, 1, pool)?;
    let written = pump(tree.as_mut(), |chunk| dbw.write_block(chunk))?;
    let out = dbw.finish()?.finish()?;
    counters.trace.end(SpanKind::GroupMerge, t, written);
    progress.merge_fired();
    Ok((out, written))
}

/// Merge `runs` into `sink` per `MergePlan::new(runs.len(), fan_in)` —
/// the batch schedule: all runs exist up front, passes execute one
/// after another, spilling intermediate passes through `spill` (group
/// merges of a pass run concurrently) and deleting consumed runs as
/// results land.
pub fn merge_runs<T: ExtItem>(
    runs: Vec<RunFile>,
    cfg: &ExternalConfig,
    spill: &SpillManager,
    pool: Option<&WriterPool>,
    sink: &mut dyn RecordSink<T>,
    trace: &Trace,
) -> Result<MergeOutcome> {
    merge_runs_ctx(runs, cfg, spill, pool, sink, trace, &SortCtx::default())
}

/// [`merge_runs`] with an explicit [`SortCtx`]: progress lands on the
/// job's counters (as well as the process totals) and the job's cancel
/// token is honoured between group batches and block by block during
/// the final drain.
pub fn merge_runs_ctx<T: ExtItem>(
    mut runs: Vec<RunFile>,
    cfg: &ExternalConfig,
    spill: &SpillManager,
    pool: Option<&WriterPool>,
    sink: &mut dyn RecordSink<T>,
    trace: &Trace,
    ctx: &SortCtx,
) -> Result<MergeOutcome> {
    let plan = MergePlan::new(runs.len(), cfg.fan_in);
    // The counters carry the trace so group merges (worker threads) and
    // prefetch waits (leaf readers) can record spans without threading
    // another handle through every layer.
    let counters =
        Arc::new(PrefetchCounters { trace: trace.clone(), ..Default::default() });
    let threads = cfg.effective_threads().max(1);
    let codec = cfg.codec_for(T::DTYPE);

    for sizes in &plan.intermediate {
        let mut next: Vec<Option<RunFile>> = vec![None; sizes.len()];
        let mut jobs: Vec<(usize, Vec<RunFile>)> = Vec::new();
        let mut idx = 0;
        for (gi, &sz) in sizes.iter().enumerate() {
            let group = runs[idx..idx + sz].to_vec();
            idx += sz;
            if sz == 1 {
                // A lone run needs no merging; carry it forward as-is.
                next[gi] = Some(group.into_iter().next().unwrap());
            } else {
                jobs.push((gi, group));
            }
        }

        // Disk-pressure degradation ladder: when a batch's projected
        // outputs breach the disk budget, first shrink the batch width
        // to one group at a time — groups are independent and processed
        // in input order, so the output bytes are unchanged, only the
        // concurrency is lost — then wait briefly in case a concurrent
        // deletion reclaims space, and only then fail the job with the
        // original budget error.
        let mut width = threads;
        let mut at = 0;
        while at < jobs.len() {
            ctx.cancel.check()?;
            let take = width.min(jobs.len() - at);
            let batch = &jobs[at..at + take];
            // Enforce the disk budget for the whole batch before any
            // merged run is written, not after the disk has filled. The
            // projection is the uncompressed size — conservative when
            // the codec compresses.
            let upcoming: u64 = batch
                .iter()
                .map(|(_, g)| {
                    RUN_HEADER_BYTES
                        + g.iter().map(|r| r.elems).sum::<u64>() * T::WIRE_BYTES as u64
                })
                .sum();
            if let Err(err) = spill.check_headroom(upcoming) {
                if take > 1 {
                    width = 1;
                    fault::note_job_degraded();
                    continue;
                }
                // Already down to one group: a short bounded wait gives
                // any still-unlinking consumed runs a chance to return
                // their bytes, then the job fails with one clean error
                // (never the process).
                let mut reclaimed = false;
                for _ in 0..5 {
                    std::thread::sleep(Duration::from_millis(2));
                    if spill.check_headroom(upcoming).is_ok() {
                        reclaimed = true;
                        break;
                    }
                }
                if !reclaimed {
                    return Err(err);
                }
                fault::note_job_degraded();
            }
            // Writers are created in group order on this thread, so run
            // numbering stays deterministic for any worker count.
            // Intermediate runs re-encode through the same codec as
            // phase 1 — every byte crossing the spill boundary flows
            // through the codec layer in both phases.
            let mut writers = Vec::with_capacity(batch.len());
            for _ in batch {
                writers.push(spill.create_run_with::<T>(codec, cfg.kernel)?);
            }
            let out_paths: Vec<std::path::PathBuf> =
                writers.iter().map(|w| w.path().to_path_buf()).collect();

            let results: Vec<Result<(RunFile, u64)>> = std::thread::scope(|s| {
                let mut handles = Vec::with_capacity(batch.len());
                for ((_, group), writer) in batch.iter().zip(writers) {
                    let counters = Arc::clone(&counters);
                    handles.push(s.spawn(move || {
                        merge_group::<T>(group, cfg, &counters, writer, pool, &ctx.progress)
                    }));
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("merge worker panicked"))
                    .collect()
            });

            // Register outputs / delete inputs in group order; on error,
            // sweep the batch's remaining outputs so nothing leaks.
            let mut first_err: Option<Error> = None;
            for (((gi, group), res), out_path) in batch.iter().zip(results).zip(&out_paths) {
                match res {
                    Err(e) => {
                        let _ = std::fs::remove_file(out_path);
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                    Ok((merged, written)) => {
                        if first_err.is_some() {
                            let _ = std::fs::remove_file(&merged.path);
                            continue;
                        }
                        let expect: u64 = group.iter().map(|r| r.elems).sum();
                        if written != expect {
                            first_err = Some(anyhow!(
                                "merge pass lost data: wrote {written} of {expect} elements"
                            ));
                            let _ = std::fs::remove_file(&merged.path);
                            continue;
                        }
                        // register() keeps the run tracked even when it
                        // reports a budget breach, so Drop still cleans it.
                        if let Err(e) = spill.register(&merged) {
                            first_err = Some(e);
                            continue;
                        }
                        for run in group {
                            if let Err(e) = spill.consume(run) {
                                if first_err.is_none() {
                                    first_err = Some(e);
                                }
                            }
                        }
                        next[*gi] = Some(merged);
                    }
                }
            }
            if let Some(e) = first_err {
                return Err(e);
            }
            at += take;
        }
        runs = next
            .into_iter()
            .map(|r| r.expect("every group produced a run"))
            .collect();
    }

    debug_assert_eq!(runs.len(), plan.final_width);
    let mut elements = 0u64;
    if !runs.is_empty() {
        let t = trace.begin();
        let mut tree = open_group::<T>(&runs, cfg, &counters)?;
        elements = pump(tree.as_mut(), |chunk| {
            ctx.cancel.check()?;
            ctx.progress.block_out(chunk.len() as u64, (chunk.len() * T::WIRE_BYTES) as u64);
            sink.write_block(chunk)
        })?;
        trace.end(SpanKind::FinalDrain, t, elements);
        drop(tree); // joins prefetch threads before the files go away
        for run in &runs {
            spill.consume(run)?;
        }
    }
    Ok(MergeOutcome {
        elements,
        merge_passes: plan.passes(),
        prefetch_hits: counters.hits.load(Ordering::Relaxed),
        prefetch_misses: counters.misses.load(Ordering::Relaxed),
        codec_decode_us: counters.decode_ns.load(Ordering::Relaxed) / 1000,
    })
}

/// What [`sort_pipelined`] hands back: the merge outcome plus the phase
/// spans the batch path would otherwise measure around its two calls
/// (they overlap here — that is the point).
#[derive(Debug)]
pub struct PipelineOutcome {
    /// The merge result (elements, passes, prefetch/codec counters).
    pub outcome: MergeOutcome,
    /// Elements phase 1 spilled — checked against `outcome.elements`.
    pub input_elems: u64,
    /// Wall-clock of the phase-1 producer (first read → last run
    /// sealed), microseconds.
    pub phase1_us: u64,
    /// Wall-clock of the merge side (first group scheduled, or the
    /// final pass when nothing spilled past one tree → sink complete),
    /// microseconds.
    pub phase2_us: u64,
}

/// Scheduler messages: sealed phase-1 runs, the producer's completion,
/// and finished group merges.
enum Event {
    Run(RunFile),
    ProducerDone { result: Result<()>, elapsed_us: u64 },
    Merged { stage: usize, group: usize, result: Result<(RunFile, u64)> },
}

/// One group merge handed to the worker pool.
struct MergeJob<T: ExtItem> {
    stage: usize,
    group: usize,
    inputs: Vec<RunFile>,
    writer: RunWriter<T>,
}

/// Per-pass bookkeeping inside the pipeline scheduler. Stage `s`
/// consumes the in-order output stream of stage `s-1` (stage 0 consumes
/// phase-1 runs) and emits its own in-order stream of merged/carried
/// runs.
#[derive(Default)]
struct StageState {
    /// Received, not yet grouped (≤ fan_in by construction).
    buf: Vec<RunFile>,
    /// Completed outputs waiting for earlier siblings (out-of-order
    /// merge completions reorder here).
    done: BTreeMap<usize, RunFile>,
    /// Next output slot to forward downstream.
    next_deliver: usize,
    /// Output slots allotted so far (submitted merges + carried runs).
    groups_out: usize,
    /// The stage merged at least one group — i.e. it is an intermediate
    /// pass, not the final one.
    merged_any: bool,
    /// Upstream finished and every remainder was flushed: `groups_out`
    /// is final.
    input_closed: bool,
}

/// A submitted-but-unfinished group: what the scheduler needs to
/// register/consume on success and to sweep on failure.
struct InFlightGroup {
    inputs: Vec<RunFile>,
    out_path: PathBuf,
    expect: u64,
    projected: u64,
}

/// The pipeline scheduler's mutable state (driven by the event loop in
/// [`sort_pipelined`]).
struct Scheduler<'a, T: ExtItem> {
    cfg: &'a ExternalConfig,
    spill: &'a SpillManager,
    codec: super::codec::Codec,
    job_tx: mpsc::Sender<MergeJob<T>>,
    stages: Vec<StageState>,
    inflight: HashMap<(usize, usize), InFlightGroup>,
    /// Submitted merge jobs not yet reported back.
    outstanding: usize,
    /// Set once the final stage closes: the ≤ fan_in survivors.
    final_runs: Option<Vec<RunFile>>,
    /// First merge activity (phase 2 begins here).
    phase2_start: Option<Instant>,
}

impl<T: ExtItem> Scheduler<'_, T> {
    /// Feed one run into `stage`, firing a group merge the moment a
    /// full fan-in chunk *plus one more run* exists — the extra run
    /// proves the stage's input exceeds the fan-in, i.e. this cannot be
    /// the final pass.
    fn arrive(&mut self, stage: usize, run: RunFile) -> Result<()> {
        while self.stages.len() <= stage {
            self.stages.push(StageState::default());
        }
        let fan = self.cfg.fan_in;
        self.stages[stage].buf.push(run);
        if self.stages[stage].buf.len() > fan {
            let group: Vec<RunFile> = self.stages[stage].buf.drain(..fan).collect();
            self.submit(stage, group)?;
        }
        Ok(())
    }

    /// Budget-check, allot the next output slot, and hand the group to
    /// a merge worker.
    fn submit(&mut self, stage: usize, inputs: Vec<RunFile>) -> Result<()> {
        let group = {
            let st = &mut self.stages[stage];
            let g = st.groups_out;
            st.groups_out += 1;
            st.merged_any = true;
            g
        };
        let expect: u64 = inputs.iter().map(|r| r.elems).sum();
        let projected = RUN_HEADER_BYTES + expect * T::WIRE_BYTES as u64;
        // Reserve every in-flight output with the SpillManager itself:
        // several groups merge at once (and, overlapped, phase 1 spills
        // concurrently), none registered until it completes — a plain
        // headroom check here would be blind to the others, and theirs
        // to ours. No degradation ladder here, deliberately: reclaim
        // (`consume`/`release`) runs on this same event-loop thread, so
        // sleeping for it would deadlock — a budget breach under the
        // pipeline fails the job cleanly instead (docs/ROBUSTNESS.md).
        self.spill.reserve(projected)?;
        let writer = match self.spill.create_run_with::<T>(self.codec, self.cfg.kernel) {
            Ok(w) => w,
            Err(e) => {
                self.spill.release(projected);
                return Err(e);
            }
        };
        let out_path = writer.path().to_path_buf();
        self.inflight.insert(
            (stage, group),
            InFlightGroup { inputs: inputs.clone(), out_path, expect, projected },
        );
        self.phase2_start.get_or_insert_with(Instant::now);
        self.outstanding += 1;
        if self.job_tx.send(MergeJob { stage, group, inputs, writer }).is_err() {
            self.spill.release(projected);
            return Err(anyhow!("merge workers exited early"));
        }
        Ok(())
    }

    /// A completed group merge came back: account for it, delete its
    /// inputs (eager reclaim), and forward it downstream in order.
    fn on_merged(
        &mut self,
        stage: usize,
        group: usize,
        merged: RunFile,
        written: u64,
    ) -> Result<()> {
        let info = self
            .inflight
            .remove(&(stage, group))
            .ok_or_else(|| anyhow!("merge result for unknown group"))?;
        if written != info.expect {
            self.spill.release(info.projected);
            let _ = std::fs::remove_file(&merged.path);
            bail!("merge pass lost data: wrote {written} of {} elements", info.expect);
        }
        // Swap the reservation for the registration atomically;
        // register keeps the run tracked even when it reports a budget
        // breach, so SpillManager::drop still cleans it.
        self.spill.register_reserved(&merged, info.projected)?;
        for run in &info.inputs {
            self.spill.consume(run)?;
        }
        self.deliver(stage, group, merged)
    }

    /// Slot a finished output into `stage`'s reorder window and forward
    /// everything now contiguous to the next stage, in order.
    fn deliver(&mut self, stage: usize, group: usize, run: RunFile) -> Result<()> {
        self.stages[stage].done.insert(group, run);
        loop {
            let next = {
                let st = &mut self.stages[stage];
                match st.done.remove(&st.next_deliver) {
                    Some(r) => {
                        st.next_deliver += 1;
                        r
                    }
                    None => break,
                }
            };
            self.arrive(stage + 1, next)?;
        }
        self.maybe_close_downstream(stage)
    }

    /// Once `stage` is closed and fully delivered, its successor's
    /// input is complete too.
    fn maybe_close_downstream(&mut self, stage: usize) -> Result<()> {
        let ready = {
            let st = &self.stages[stage];
            st.input_closed && st.merged_any && st.next_deliver == st.groups_out
        };
        if ready {
            self.close_input(stage + 1)?;
        }
        Ok(())
    }

    /// `stage`'s input stream ended: either this is the final pass
    /// (nothing was merged — ≤ fan_in runs total) or flush the
    /// remainder group / carry a lone trailing run.
    fn close_input(&mut self, stage: usize) -> Result<()> {
        while self.stages.len() <= stage {
            self.stages.push(StageState::default()); // zero-run input
        }
        if self.stages[stage].input_closed {
            return Ok(());
        }
        self.stages[stage].input_closed = true;
        if !self.stages[stage].merged_any {
            // Never exceeded the fan-in: these runs feed the sink.
            self.final_runs = Some(std::mem::take(&mut self.stages[stage].buf));
            return Ok(());
        }
        let rest = std::mem::take(&mut self.stages[stage].buf);
        match rest.len() {
            0 => {}
            1 => {
                // A lone trailing run needs no merging; forward it
                // as-is in its positional slot.
                let group = {
                    let st = &mut self.stages[stage];
                    let g = st.groups_out;
                    st.groups_out += 1;
                    g
                };
                let run = rest.into_iter().next().unwrap();
                return self.deliver(stage, group, run);
            }
            _ => self.submit(stage, rest)?,
        }
        self.maybe_close_downstream(stage)
    }
}

/// The overlapped (TopSort-style) schedule: run phase 1 as a producer
/// on its own thread, announce each sealed run over a bounded channel,
/// and start merging a group the moment its fan-in chunk is complete —
/// so intermediate passes execute concurrently with late phase-1
/// spills, and by the time the producer finishes only the final
/// streaming pass (and whatever merges are still in flight) remains.
/// Group shapes, run order, and therefore the output bytes are
/// identical to [`merge_runs`] after [`generate_runs`]; only the
/// wall-clock schedule differs.
///
/// On any error — a phase-1 source failure, a merge failure, a budget
/// breach — the producer is cancelled, in-flight merges drain, every
/// unregistered output file is swept here, and the registered runs die
/// with the `SpillManager`: no spill files outlive the sort.
///
/// [`generate_runs`]: super::run_gen::generate_runs
pub fn sort_pipelined<T: ExtItem>(
    src: &mut (dyn RecordSource<T> + Send),
    cfg: &ExternalConfig,
    spill: &SpillManager,
    pool: Option<&WriterPool>,
    sink: &mut dyn RecordSink<T>,
    trace: &Trace,
) -> Result<PipelineOutcome> {
    sort_pipelined_ctx(src, cfg, spill, pool, sink, trace, &SortCtx::default())
}

/// [`sort_pipelined`] with an explicit [`SortCtx`]. The job's cancel
/// token doubles as the pipeline's internal abort flag: an external
/// `cancel <id>` trips the same machinery an internal error does (the
/// producer bails, in-flight merges drain, spill files are swept), and
/// progress lands on the job's counters as well as the process totals.
pub fn sort_pipelined_ctx<T: ExtItem>(
    src: &mut (dyn RecordSource<T> + Send),
    cfg: &ExternalConfig,
    spill: &SpillManager,
    pool: Option<&WriterPool>,
    sink: &mut dyn RecordSink<T>,
    trace: &Trace,
    ctx: &SortCtx,
) -> Result<PipelineOutcome> {
    let threads = cfg.effective_threads().max(1);
    let counters =
        Arc::new(PrefetchCounters { trace: trace.clone(), ..Default::default() });
    let cancel = &ctx.cancel;

    std::thread::scope(|scope| -> Result<PipelineOutcome> {
        // Bounded hand-off: phase 1 runs at most a few sealed runs
        // ahead of the scheduler's bookkeeping (the real pacing is the
        // disk and the merge workers, not this channel).
        let (event_tx, event_rx) = mpsc::sync_channel::<Event>(cfg.fan_in + threads);
        let (job_tx, job_rx) = mpsc::channel::<MergeJob<T>>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        for _ in 0..threads {
            let rx = Arc::clone(&job_rx);
            let tx = event_tx.clone();
            let counters = Arc::clone(&counters);
            scope.spawn(move || loop {
                let job = rx.lock().unwrap().recv();
                let Ok(job) = job else { break };
                let MergeJob { stage, group, inputs, writer } = job;
                let result = if cancel.is_cancelled() {
                    Err(anyhow!("merge cancelled")) // writer dropped; file swept below
                } else {
                    // A panicking group merge must still report, or the
                    // scheduler waits on `outstanding` forever (the
                    // batch path surfaces this via join().expect()).
                    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        merge_group::<T>(&inputs, cfg, &counters, writer, pool, &ctx.progress)
                    }))
                    .unwrap_or_else(|_| Err(anyhow!("merge worker panicked")))
                };
                if tx.send(Event::Merged { stage, group, result }).is_err() {
                    break;
                }
            });
        }

        let producer_tx = event_tx.clone();
        scope.spawn(move || {
            let t = Instant::now();
            let result =
                generate_runs_streaming_ctx::<T>(src, cfg, spill, pool, trace, ctx, &mut |run| {
                    if cancel.is_cancelled() {
                        anyhow::bail!("sort aborted");
                    }
                    producer_tx
                        .send(Event::Run(run))
                        .map_err(|_| anyhow!("pipeline scheduler exited early"))
                });
            let elapsed_us = t.elapsed().as_micros() as u64;
            let _ = producer_tx.send(Event::ProducerDone { result, elapsed_us });
        });
        drop(event_tx);

        let mut sched = Scheduler::<T> {
            cfg,
            spill,
            codec: cfg.codec_for(T::DTYPE),
            job_tx,
            stages: Vec::new(),
            inflight: HashMap::new(),
            outstanding: 0,
            final_runs: None,
            phase2_start: None,
        };
        let mut first_err: Option<Error> = None;
        let abort = |err: Error, slot: &mut Option<Error>| {
            if slot.is_none() {
                *slot = Some(err);
            }
            cancel.cancel();
        };
        let mut producer_done = false;
        let mut phase1_us = 0u64;
        let mut input_elems = 0u64;

        // Drain events until the producer has finished AND every
        // submitted merge has reported — true on the error path too, so
        // nothing still writes when cleanup starts.
        while !(producer_done && sched.outstanding == 0) {
            let event = match event_rx.recv() {
                Ok(ev) => ev,
                Err(_) => {
                    abort(anyhow!("pipeline threads exited early"), &mut first_err);
                    break;
                }
            };
            match event {
                Event::Run(run) => {
                    input_elems += run.elems;
                    if first_err.is_none() {
                        if let Err(e) = sched.arrive(0, run) {
                            abort(e, &mut first_err);
                        }
                    }
                    // After an error the run is already registered; the
                    // SpillManager deletes it when the sort unwinds.
                }
                Event::ProducerDone { result, elapsed_us } => {
                    producer_done = true;
                    phase1_us = elapsed_us;
                    match result {
                        Ok(()) if first_err.is_none() => {
                            if let Err(e) = sched.close_input(0) {
                                abort(e, &mut first_err);
                            }
                        }
                        Err(e) if first_err.is_none() => abort(e, &mut first_err),
                        _ => {}
                    }
                }
                Event::Merged { stage, group, result } => {
                    sched.outstanding -= 1;
                    match result {
                        Ok((merged, written)) => {
                            if first_err.is_some() {
                                let _ = std::fs::remove_file(&merged.path);
                                if let Some(info) = sched.inflight.remove(&(stage, group)) {
                                    spill.release(info.projected);
                                }
                            } else if let Err(e) = sched.on_merged(stage, group, merged, written)
                            {
                                abort(e, &mut first_err);
                            }
                        }
                        Err(e) => {
                            if let Some(info) = sched.inflight.remove(&(stage, group)) {
                                let _ = std::fs::remove_file(&info.out_path);
                                spill.release(info.projected);
                            }
                            if first_err.is_none() {
                                abort(e, &mut first_err);
                            }
                        }
                    }
                }
            }
        }

        let Scheduler { job_tx, final_runs, stages, mut phase2_start, inflight, .. } = sched;
        drop(job_tx); // releases the merge workers; the scope joins them
        if let Some(e) = first_err {
            // Normally every in-flight group has reported (and been
            // swept) by now; entries remain only if a worker died
            // without reporting — remove their never-registered
            // outputs and return their reservations. Registered runs
            // die with the SpillManager.
            for info in inflight.values() {
                let _ = std::fs::remove_file(&info.out_path);
                spill.release(info.projected);
            }
            return Err(e);
        }

        // Final streaming pass: the ≤ fan_in survivors of every earlier
        // stage, all sealed by now.
        let final_runs =
            final_runs.ok_or_else(|| anyhow!("pipeline ended without a final pass"))?;
        let mut elements = 0u64;
        if !final_runs.is_empty() {
            phase2_start.get_or_insert_with(Instant::now);
            let t = trace.begin();
            let mut tree = open_group::<T>(&final_runs, cfg, &counters)?;
            elements = pump(tree.as_mut(), |chunk| {
                ctx.cancel.check()?;
                ctx.progress.block_out(chunk.len() as u64, (chunk.len() * T::WIRE_BYTES) as u64);
                sink.write_block(chunk)
            })?;
            trace.end(SpanKind::FinalDrain, t, elements);
            drop(tree); // joins prefetch threads before the files go away
            for run in &final_runs {
                spill.consume(run)?;
            }
        }
        let phase2_us = phase2_start.map(|t| t.elapsed().as_micros() as u64).unwrap_or(0);
        let merge_passes = stages.iter().filter(|s| s.merged_any).count() as u64
            + u64::from(!final_runs.is_empty());
        Ok(PipelineOutcome {
            outcome: MergeOutcome {
                elements,
                merge_passes,
                prefetch_hits: counters.hits.load(Ordering::Relaxed),
                prefetch_misses: counters.misses.load(Ordering::Relaxed),
                codec_decode_us: counters.decode_ns.load(Ordering::Relaxed) / 1000,
            },
            input_elems,
            phase1_us,
            phase2_us,
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_single_pass_when_k_fits() {
        let p = MergePlan::new(6, 8);
        assert!(p.intermediate.is_empty());
        assert_eq!(p.final_width, 6);
        assert_eq!(p.passes(), 1);
    }

    #[test]
    fn plan_multi_pass_shapes() {
        // 20 runs at fan-in 4: pass 1 → 5 chunks of 4, pass 2 → 5 runs
        // still > 4 → [4, 1] (the 1 carries forward free), final over 2.
        let p = MergePlan::new(20, 4);
        assert_eq!(p.intermediate, vec![vec![4, 4, 4, 4, 4], vec![4, 1]]);
        assert_eq!(p.final_width, 2);
        assert_eq!(p.passes(), 3);
    }

    #[test]
    fn plan_groups_are_prefix_stable() {
        // The pipelined scheduler fires group j as soon as runs
        // j·fan .. (j+1)·fan exist — legal only because adding more
        // runs never reshapes the groups already planned.
        for fan in [2usize, 4, 8] {
            for k in fan + 1..100 {
                let prev = MergePlan::new(k, fan);
                let next = MergePlan::new(k + 1, fan);
                let full_prev = prev.intermediate[0].iter().filter(|&&s| s == fan).count();
                assert!(
                    next.intermediate[0][..full_prev]
                        .iter()
                        .all(|&s| s == fan),
                    "k={k} fan={fan}: full groups reshaped by one more run"
                );
            }
        }
        // A lone trailing run is carried, not copy-merged: 9 runs at
        // fan-in 8 plan as [8, 1] (the 1 re-enters the next pass as-is).
        let p = MergePlan::new(9, 8);
        assert_eq!(p.intermediate, vec![vec![8, 1]]);
        assert_eq!(p.final_width, 2);
    }

    #[test]
    fn plan_zero_runs() {
        let p = MergePlan::new(0, 8);
        assert_eq!(p.final_width, 0);
        assert_eq!(p.passes(), 0);
    }

    #[test]
    fn group_sizes_cover_and_cap() {
        for k in 1..200usize {
            for fan in [2usize, 3, 4, 8, 16] {
                let sizes = group_sizes(k, fan);
                assert_eq!(sizes.iter().sum::<usize>(), k, "k={k} fan={fan}");
                assert!(sizes.iter().all(|&s| s <= fan), "k={k} fan={fan} {sizes:?}");
                assert_eq!(sizes.len(), k.div_ceil(fan));
                // Every group but the last is exactly fan_in — the
                // prefix-stability invariant.
                assert!(sizes[..sizes.len() - 1].iter().all(|&s| s == fan));
            }
        }
    }
}
