//! Phase 2: arrange spilled runs into FLiMS merge trees and execute the
//! (possibly multi-pass) k-way merge.
//!
//! A [`MergePlan`] caps every tree at the configured fan-in: while more
//! runs exist than the fan-in allows, a pass merges balanced groups of
//! runs into fresh (larger) spilled runs; the final pass streams the
//! surviving ≤ fan-in runs straight into the caller's sink. Consumed
//! runs are deleted eagerly after each group, so live spill stays near
//! the dataset size rather than growing with the pass count.

use anyhow::{bail, Result};

use super::format::{RunFile, RunReader};
use super::spill::SpillManager;
use super::stream::{build_tree, pump, ReaderStream, RunStream};
use super::ExternalConfig;

/// The pass/group structure for merging `k` runs at a given fan-in.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MergePlan {
    pub fan_in: usize,
    /// Group sizes for each intermediate (spilling) pass.
    pub intermediate: Vec<Vec<usize>>,
    /// Number of runs entering the final (streaming) pass.
    pub final_width: usize,
}

impl MergePlan {
    pub fn new(k: usize, fan_in: usize) -> Self {
        assert!(fan_in >= 2, "fan_in must be at least 2");
        let mut intermediate = Vec::new();
        let mut k = k;
        while k > fan_in {
            intermediate.push(group_sizes(k, fan_in));
            k = k.div_ceil(fan_in);
        }
        MergePlan { fan_in, intermediate, final_width: k }
    }

    /// Total passes over the data, counting the final streaming pass.
    pub fn passes(&self) -> u64 {
        self.intermediate.len() as u64 + u64::from(self.final_width > 0)
    }
}

/// Split `k` runs into `ceil(k / fan_in)` balanced groups (sizes differ
/// by at most one), avoiding the degenerate 1-run groups a plain
/// chunks-of-fan-in split produces when `k % fan_in == 1`.
fn group_sizes(k: usize, fan_in: usize) -> Vec<usize> {
    let groups = k.div_ceil(fan_in);
    let base = k / groups;
    let extra = k % groups;
    (0..groups).map(|i| base + usize::from(i < extra)).collect()
}

/// Where the merged output goes: the final dataset file, a fresh run, or
/// an in-memory buffer (service-path small sorts, tests).
pub trait U32Sink {
    fn write_block(&mut self, xs: &[u32]) -> Result<()>;
}

impl U32Sink for Vec<u32> {
    fn write_block(&mut self, xs: &[u32]) -> Result<()> {
        self.extend_from_slice(xs);
        Ok(())
    }
}

impl U32Sink for super::format::RawWriter {
    fn write_block(&mut self, xs: &[u32]) -> Result<()> {
        self.write_block(xs)
    }
}

/// Result of executing a merge plan.
#[derive(Clone, Copy, Debug, Default)]
pub struct MergeOutcome {
    /// Elements streamed into the sink by the final pass.
    pub elements: u64,
    /// Passes over the data (intermediate + final).
    pub merge_passes: u64,
}

fn open_group(group: &[RunFile], cfg: &ExternalConfig) -> Result<Box<dyn RunStream>> {
    let block = cfg.block_elems();
    let mut streams: Vec<Box<dyn RunStream>> = Vec::with_capacity(group.len());
    for run in group {
        streams.push(Box::new(ReaderStream::new(RunReader::open(&run.path)?, block)));
    }
    Ok(build_tree(streams, block, cfg.w))
}

/// Merge `runs` into `sink` per `MergePlan::new(runs.len(), fan_in)`,
/// spilling intermediate passes through `spill` and deleting consumed
/// runs eagerly.
pub fn merge_runs(
    mut runs: Vec<RunFile>,
    cfg: &ExternalConfig,
    spill: &mut SpillManager,
    sink: &mut dyn U32Sink,
) -> Result<MergeOutcome> {
    let plan = MergePlan::new(runs.len(), cfg.fan_in);
    for sizes in &plan.intermediate {
        let mut next = Vec::with_capacity(sizes.len());
        let mut idx = 0;
        for &sz in sizes {
            let group = &runs[idx..idx + sz];
            idx += sz;
            if sz == 1 {
                // A lone run needs no merging; carry it forward as-is.
                next.push(group[0].clone());
                continue;
            }
            // Enforce the disk budget before the merged run is written,
            // not after the disk has already filled.
            let expect: u64 = group.iter().map(|r| r.elems).sum();
            spill.check_headroom(crate::external::format::RUN_HEADER_BYTES + expect * 4)?;
            let mut tree = open_group(group, cfg)?;
            let mut writer = spill.create_run()?;
            let written = pump(tree.as_mut(), |chunk| writer.write_block(chunk))?;
            let merged = writer.finish()?;
            if written != expect {
                bail!("merge pass lost data: wrote {written} of {expect} elements");
            }
            spill.register(&merged)?;
            for run in group {
                spill.consume(run)?;
            }
            next.push(merged);
        }
        runs = next;
    }

    debug_assert_eq!(runs.len(), plan.final_width);
    let mut elements = 0u64;
    if !runs.is_empty() {
        let mut tree = open_group(&runs, cfg)?;
        elements = pump(tree.as_mut(), |chunk| sink.write_block(chunk))?;
        for run in &runs {
            spill.consume(run)?;
        }
    }
    Ok(MergeOutcome { elements, merge_passes: plan.passes() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_single_pass_when_k_fits() {
        let p = MergePlan::new(6, 8);
        assert!(p.intermediate.is_empty());
        assert_eq!(p.final_width, 6);
        assert_eq!(p.passes(), 1);
    }

    #[test]
    fn plan_multi_pass_shapes() {
        // 20 runs at fan-in 4: pass 1 → 5 groups of 4, pass 2 → 5 runs
        // still > 4 → groups [3, 2], final over 2.
        let p = MergePlan::new(20, 4);
        assert_eq!(p.intermediate, vec![vec![4, 4, 4, 4, 4], vec![3, 2]]);
        assert_eq!(p.final_width, 2);
        assert_eq!(p.passes(), 3);
    }

    #[test]
    fn plan_avoids_degenerate_groups() {
        // 9 runs at fan-in 8: a naive split is [8, 1]; balanced is [5, 4].
        let p = MergePlan::new(9, 8);
        assert_eq!(p.intermediate, vec![vec![5, 4]]);
        assert_eq!(p.final_width, 2);
    }

    #[test]
    fn plan_zero_runs() {
        let p = MergePlan::new(0, 8);
        assert_eq!(p.final_width, 0);
        assert_eq!(p.passes(), 0);
    }

    #[test]
    fn group_sizes_cover_and_cap() {
        for k in 1..200usize {
            for fan in [2usize, 3, 4, 8, 16] {
                let sizes = group_sizes(k, fan);
                assert_eq!(sizes.iter().sum::<usize>(), k, "k={k} fan={fan}");
                assert!(sizes.iter().all(|&s| s <= fan), "k={k} fan={fan} {sizes:?}");
                assert_eq!(sizes.len(), k.div_ceil(fan));
            }
        }
    }
}
