//! Spill-file lifecycle: temp-dir ownership, run naming, disk-budget
//! enforcement, and eager deletion of consumed runs.
//!
//! Every run file the external sort creates flows through one
//! [`SpillManager`]: `create_run` names the file, `register` starts
//! tracking a finished run (and enforces the disk budget), `consume`
//! deletes it the moment the merge has drained it. `Drop` removes any
//! stragglers (and the temp dir, when the manager created it), so an
//! aborted sort never leaks disk.
//!
//! Since the overlapped schedule landed, one manager is **shared by
//! both phases running concurrently**: every method takes `&self`, with
//! the mutable bookkeeping behind an internal mutex, so the phase-1
//! producer thread can register fresh runs while the merge scheduler
//! registers merged outputs and consumes drained inputs. The budget and
//! eager-delete semantics are unchanged — `register` still hard-fails
//! the moment live bytes cross the budget, whichever thread gets there
//! first.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::fault::{self, FaultSpec, Injector};
use crate::obs::Trace;

use super::codec::Codec;
use super::format::{ExtItem, RunFile, RunWriter};

/// Distinguishes concurrent spill dirs within one process.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// The mutable bookkeeping, behind [`SpillManager`]'s mutex.
#[derive(Default)]
struct SpillState {
    next_run: u64,
    live: Vec<RunFile>,
    live_bytes: u64,
    /// Projected bytes of writes in flight ([`SpillManager::reserve`])
    /// — not yet on disk, but already claimed against the budget so
    /// concurrent writers' pre-write checks see each other.
    reserved_bytes: u64,
    /// Lifetime counters (monotonic, survive consume()).
    runs_created: u64,
    runs_deleted: u64,
    bytes_written: u64,
    raw_bytes_written: u64,
    encode_ns: u64,
    peak_live_bytes: u64,
}

/// Tracks live spill files and enforces the disk byte budget. Shareable
/// across threads (`&self` everywhere): the two phases of an overlapped
/// sort hold one reference each.
pub struct SpillManager {
    dir: PathBuf,
    /// We created the directory, so we remove it on drop.
    own_dir: bool,
    disk_budget: Option<u64>,
    /// Fault plan materialized into one [`Injector`] per run file the
    /// manager creates or deletes (`None` in production: zero overhead).
    fault_spec: Option<FaultSpec>,
    /// Where the injectors record retry/stall spans.
    trace: Trace,
    state: Mutex<SpillState>,
}

impl SpillManager {
    /// `dir = None` creates (and owns) a fresh directory under the
    /// system temp dir; `Some(d)` spills into `d` without owning it.
    pub fn new(dir: Option<PathBuf>, disk_budget: Option<u64>) -> Result<Self> {
        let (dir, own_dir) = match dir {
            Some(d) => {
                std::fs::create_dir_all(&d)
                    .with_context(|| format!("creating spill dir {}", d.display()))?;
                (d, false)
            }
            None => {
                let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
                let d = std::env::temp_dir()
                    .join(format!("flims-spill-{}-{}", std::process::id(), seq));
                std::fs::create_dir_all(&d)
                    .with_context(|| format!("creating spill dir {}", d.display()))?;
                (d, true)
            }
        };
        Ok(SpillManager {
            dir,
            own_dir,
            disk_budget,
            fault_spec: None,
            trace: Trace::disabled(),
            state: Mutex::new(SpillState::default()),
        })
    }

    /// Attach a fault plan: every run writer this manager creates, and
    /// every eager delete it performs, gets a per-file [`Injector`]
    /// seeded from `spec` and the file name. `trace` receives the
    /// retry/stall spans.
    pub fn with_faults(mut self, spec: Option<FaultSpec>, trace: Trace) -> Self {
        self.fault_spec = spec;
        self.trace = trace;
        self
    }

    /// The active fault plan, if any — how downstream seams (run
    /// readers, the output sink) derive their own injectors from the
    /// one plan a sort carries.
    pub fn fault_spec(&self) -> Option<FaultSpec> {
        self.fault_spec
    }

    fn state(&self) -> std::sync::MutexGuard<'_, SpillState> {
        self.state.lock().unwrap()
    }

    /// The directory runs spill into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Open a writer for the next run file, encoding with `codec`
    /// (callers pass the *effective* codec —
    /// [`Codec::effective_for`] already applied). Naming is sequential
    /// in call order. Within each phase, writers are created on one
    /// coordinating thread in input order, so a phase's run layout is
    /// deterministic for any worker count; under the overlapped
    /// schedule the two phases' `create_run` calls interleave, so only
    /// the *names* vary run-to-run — never the sorted output bytes,
    /// which depend on run order and contents alone.
    pub fn create_run<T: ExtItem>(&self, codec: Codec) -> Result<RunWriter<T>> {
        self.create_run_with(codec, crate::flims::simd::MergeKernel::Auto)
    }

    /// [`create_run`](SpillManager::create_run) with an explicit
    /// [`MergeKernel`](crate::flims::simd::MergeKernel) for codecs
    /// whose encode loop dispatches on it (FLR3 bitpacking).
    pub fn create_run_with<T: ExtItem>(
        &self,
        codec: Codec,
        kernel: crate::flims::simd::MergeKernel,
    ) -> Result<RunWriter<T>> {
        let seq = {
            let mut st = self.state();
            let seq = st.next_run;
            st.next_run += 1;
            seq
        };
        let name = format!("run-{seq:06}.flr");
        let path = self.dir.join(&name);
        // Injector streams are seeded by the file *name*, which is
        // assigned in input order regardless of worker count — the same
        // plan replays the same fault sequence at any thread count.
        let fault = Injector::for_site(self.fault_spec, &name, &self.trace);
        RunWriter::create_with_fault(&path, codec, kernel, fault)
    }

    fn headroom_locked(&self, st: &SpillState, upcoming_bytes: u64) -> Result<()> {
        if let Some(budget) = self.disk_budget {
            let projected = st.live_bytes + st.reserved_bytes + upcoming_bytes;
            if projected > budget {
                bail!(
                    "spill disk budget exceeded: {} bytes live + {} reserved + {} upcoming > {} budget",
                    st.live_bytes,
                    st.reserved_bytes,
                    upcoming_bytes,
                    budget
                );
            }
        }
        Ok(())
    }

    /// Check that `upcoming_bytes` more spill fits the disk budget —
    /// called *before* writing, so the budget is enforced ahead of the
    /// disk filling, not after. The projection counts live bytes *and*
    /// every outstanding [`reserve`](SpillManager::reserve), so a
    /// checker sees other writers' in-flight output too.
    pub fn check_headroom(&self, upcoming_bytes: u64) -> Result<()> {
        self.headroom_locked(&self.state(), upcoming_bytes)
    }

    /// Claim budget headroom for a write about to start (a phase-1 run
    /// spilling, a merge output being produced): the headroom check,
    /// plus holding `upcoming_bytes` reserved until
    /// [`release`](SpillManager::release) or
    /// [`register_reserved`](SpillManager::register_reserved). This is
    /// what keeps the pre-write check meaningful when both phases write
    /// concurrently — neither side's check is blind to the other's
    /// in-flight bytes.
    pub fn reserve(&self, upcoming_bytes: u64) -> Result<()> {
        let mut st = self.state();
        self.headroom_locked(&st, upcoming_bytes)?;
        st.reserved_bytes += upcoming_bytes;
        Ok(())
    }

    /// Drop a reservation made with [`reserve`](SpillManager::reserve)
    /// (the write was abandoned or failed). Saturating, so error-path
    /// cleanup can never underflow the count.
    pub fn release(&self, reserved_bytes: u64) {
        let mut st = self.state();
        st.reserved_bytes = st.reserved_bytes.saturating_sub(reserved_bytes);
    }

    /// Bytes currently reserved by in-flight writes.
    pub fn reserved_bytes(&self) -> u64 {
        self.state().reserved_bytes
    }

    /// Start tracking a finished run; errors if it pushes live spill
    /// bytes past the disk budget (the run stays registered so Drop
    /// still cleans it up).
    pub fn register(&self, run: &RunFile) -> Result<()> {
        self.register_locked(&mut self.state(), run)
    }

    /// Atomically swap a [`reserve`](SpillManager::reserve) for the
    /// finished run's actual bytes — release + register under one
    /// lock, so concurrent checks never see the write double-counted
    /// or momentarily uncounted.
    pub fn register_reserved(&self, run: &RunFile, reserved_bytes: u64) -> Result<()> {
        let mut st = self.state();
        st.reserved_bytes = st.reserved_bytes.saturating_sub(reserved_bytes);
        self.register_locked(&mut st, run)
    }

    fn register_locked(&self, st: &mut SpillState, run: &RunFile) -> Result<()> {
        st.live.push(run.clone());
        st.live_bytes += run.bytes;
        st.bytes_written += run.bytes;
        st.raw_bytes_written += run.raw_bytes;
        st.encode_ns += run.encode_ns;
        st.runs_created += 1;
        st.peak_live_bytes = st.peak_live_bytes.max(st.live_bytes);
        if let Some(budget) = self.disk_budget {
            if st.live_bytes > budget {
                bail!(
                    "spill disk budget exceeded: {} bytes live > {} budget ({} runs)",
                    st.live_bytes,
                    budget,
                    st.live.len()
                );
            }
        }
        Ok(())
    }

    /// Delete a fully-consumed run eagerly, reclaiming its disk.
    pub fn consume(&self, run: &RunFile) -> Result<()> {
        // One deterministic decision per file, derived from the file
        // name alone — consume order varies with merge timing, but the
        // injected-fault sequence does not.
        let mut inj = match self.fault_spec {
            None => Injector::disabled(),
            Some(_) => {
                let name = run.path.file_name().map(|n| n.to_string_lossy());
                Injector::for_site(self.fault_spec, name.as_deref().unwrap_or("run"), &self.trace)
            }
        };
        fault::with_retry(&mut inj, fault::Op::Delete, || std::fs::remove_file(&run.path))
            .with_context(|| format!("deleting consumed run {}", run.path.display()))?;
        let mut st = self.state();
        st.live.retain(|r| r.path != run.path);
        st.live_bytes = st.live_bytes.saturating_sub(run.bytes);
        st.runs_deleted += 1;
        Ok(())
    }

    /// Bytes currently occupied by live (not yet consumed) runs.
    pub fn live_bytes(&self) -> u64 {
        self.state().live_bytes
    }

    /// High-water mark of [`live_bytes`](SpillManager::live_bytes).
    pub fn peak_live_bytes(&self) -> u64 {
        self.state().peak_live_bytes
    }

    /// Runs registered over this manager's lifetime.
    pub fn runs_created(&self) -> u64 {
        self.state().runs_created
    }

    /// Runs consumed (deleted) over this manager's lifetime.
    pub fn runs_deleted(&self) -> u64 {
        self.state().runs_deleted
    }

    /// Encoded bytes written across every registered run.
    pub fn bytes_written(&self) -> u64 {
        self.state().bytes_written
    }

    /// What the same spill traffic would have occupied uncompressed
    /// (`elems × WIRE_BYTES` + headers) — `bytes_written /
    /// raw_bytes_written` is the achieved compression ratio.
    pub fn raw_bytes_written(&self) -> u64 {
        self.state().raw_bytes_written
    }

    /// Cumulative wall-clock the run writers spent encoding, µs
    /// (nanosecond-accumulated, divided once here so sub-µs runs are
    /// not truncated away).
    pub fn encode_us(&self) -> u64 {
        self.state().encode_ns / 1000
    }
}

impl Drop for SpillManager {
    fn drop(&mut self) {
        let st = self.state.get_mut().unwrap();
        for run in &st.live {
            let _ = std::fs::remove_file(&run.path);
        }
        if self.own_dir {
            let _ = std::fs::remove_dir(&self.dir);
        }
    }
}

/// Startup crash recovery: sweep on-disk state a previous process left
/// behind. Two families are reclaimed:
///
/// * Inside the configured spill dir (`tmp_dir`, when set): orphaned
///   per-job `job-<id>` directories and stray half-written `run-*.flr`
///   files. The server owns that directory and no jobs are running at
///   startup, so anything present is leakage from a crash.
/// * Under the system temp dir: `flims-spill-<pid>-<seq>` directories
///   whose owning pid is no longer alive (checked via `/proc`; skipped
///   on systems without it, where liveness cannot be told).
///
/// Returns the paths removed, for the caller to log. Never errors: a
/// sweep that cannot remove something leaves it and moves on.
pub fn recover_stale_spills(tmp_dir: Option<&Path>) -> Vec<PathBuf> {
    let mut removed = Vec::new();
    if let Some(dir) = tmp_dir {
        if let Ok(entries) = std::fs::read_dir(dir) {
            for e in entries.flatten() {
                let name = e.file_name();
                let name = name.to_string_lossy();
                let is_dir = e.file_type().map(|t| t.is_dir()).unwrap_or(false);
                let p = e.path();
                if is_dir && name.starts_with("job-") {
                    if std::fs::remove_dir_all(&p).is_ok() {
                        removed.push(p);
                    }
                } else if !is_dir && name.starts_with("run-") && name.ends_with(".flr") {
                    if std::fs::remove_file(&p).is_ok() {
                        removed.push(p);
                    }
                }
            }
        }
    }
    if let Ok(entries) = std::fs::read_dir(std::env::temp_dir()) {
        for e in entries.flatten() {
            let name = e.file_name();
            let name = name.to_string_lossy();
            let Some(rest) = name.strip_prefix("flims-spill-") else { continue };
            let Some((pid, _seq)) = rest.split_once('-') else { continue };
            let Ok(pid) = pid.parse::<u32>() else { continue };
            if pid == std::process::id() || !pid_is_dead(pid) {
                continue;
            }
            let p = e.path();
            if std::fs::remove_dir_all(&p).is_ok() {
                removed.push(p);
            }
        }
    }
    removed
}

/// Conservatively decide a pid is dead: only claim death when `/proc`
/// exists and the pid has no entry. Where liveness cannot be observed,
/// stale dirs are kept (leak-on-doubt beats deleting a live sort's
/// spill).
fn pid_is_dead(pid: u32) -> bool {
    let proc_root = Path::new("/proc");
    proc_root.is_dir() && !proc_root.join(pid.to_string()).exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spill_run(sm: &SpillManager, data: &[u32]) -> RunFile {
        let mut w = sm.create_run(Codec::Raw).unwrap();
        w.write_block(data).unwrap();
        let run = w.finish().unwrap();
        sm.register(&run).unwrap();
        run
    }

    #[test]
    fn create_register_consume_cycle() {
        let sm = SpillManager::new(None, None).unwrap();
        let dir = sm.dir().to_path_buf();
        let r1 = spill_run(&sm, &[3, 2, 1]);
        let r2 = spill_run(&sm, &[9, 9]);
        assert!(r1.path.exists() && r2.path.exists());
        assert_eq!(sm.runs_created(), 2);
        assert_eq!(sm.live_bytes(), r1.bytes + r2.bytes);

        sm.consume(&r1).unwrap();
        assert!(!r1.path.exists(), "consumed run must be deleted eagerly");
        assert_eq!(sm.live_bytes(), r2.bytes);
        assert_eq!(sm.runs_deleted(), 1);

        drop(sm);
        assert!(!r2.path.exists(), "drop must clean leftover runs");
        assert!(!dir.exists(), "drop must remove the owned temp dir");
    }

    #[test]
    fn disk_budget_enforced() {
        // Budget fits one 3-element run (12 bytes header + 12 payload)
        // but not two.
        let sm = SpillManager::new(None, Some(30)).unwrap();
        let mut w = sm.create_run(Codec::Raw).unwrap();
        w.write_block(&[5u32, 4, 3]).unwrap();
        let r1 = w.finish().unwrap();
        sm.register(&r1).unwrap();

        let mut w = sm.create_run(Codec::Raw).unwrap();
        w.write_block(&[2u32, 1, 0]).unwrap();
        let r2 = w.finish().unwrap();
        let err = format!("{:#}", sm.register(&r2).unwrap_err());
        assert!(err.contains("disk budget exceeded"), "{err}");

        // Consuming reclaims budget headroom.
        sm.consume(&r1).unwrap();
        assert!(sm.live_bytes() <= 30);
    }

    #[test]
    fn headroom_is_checked_before_writing() {
        let sm = SpillManager::new(None, Some(100)).unwrap();
        assert!(sm.check_headroom(100).is_ok());
        let err = format!("{:#}", sm.check_headroom(101).unwrap_err());
        assert!(err.contains("disk budget exceeded"), "{err}");
        // Live bytes count against the headroom.
        let r = spill_run(&sm, &[1, 2, 3]); // 12 + 12 = 24 bytes
        assert!(sm.check_headroom(76).is_ok());
        assert!(sm.check_headroom(77).is_err());
        sm.consume(&r).unwrap();
        assert!(sm.check_headroom(100).is_ok());
    }

    #[test]
    fn reservations_gate_concurrent_writers() {
        let sm = SpillManager::new(None, Some(100)).unwrap();
        sm.reserve(60).unwrap();
        assert_eq!(sm.reserved_bytes(), 60);
        // A second writer's pre-write check sees the first's in-flight
        // bytes — the overlapped-schedule guarantee.
        let err = format!("{:#}", sm.reserve(60).unwrap_err());
        assert!(err.contains("disk budget exceeded"), "{err}");
        assert!(err.contains("60 reserved"), "{err}");
        assert!(sm.check_headroom(41).is_err());
        assert!(sm.check_headroom(40).is_ok());
        // Swapping the reservation for the real (smaller) run frees the
        // difference atomically.
        let mut w = sm.create_run(Codec::Raw).unwrap();
        w.write_block(&[1u32, 2, 3]).unwrap(); // 12 header + 12 payload
        let run = w.finish().unwrap();
        sm.register_reserved(&run, 60).unwrap();
        assert_eq!(sm.reserved_bytes(), 0);
        assert_eq!(sm.live_bytes(), 24);
        assert!(sm.check_headroom(76).is_ok());
        // A stray release saturates instead of underflowing.
        sm.release(999);
        assert_eq!(sm.reserved_bytes(), 0);
        sm.consume(&run).unwrap();
    }

    #[test]
    fn external_dir_is_not_removed() {
        let dir = std::env::temp_dir().join(format!("flims-keep-{}", std::process::id()));
        let sm = SpillManager::new(Some(dir.clone()), None).unwrap();
        let run = spill_run(&sm, &[1]);
        drop(sm);
        assert!(!run.path.exists(), "runs are still cleaned");
        assert!(dir.exists(), "caller-provided dir must survive");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn raw_vs_encoded_accounting() {
        let sm = SpillManager::new(None, None).unwrap();
        // A dense descending run compresses well under the delta codec.
        let data: Vec<u32> = (0..2000u32).rev().collect();
        let mut w = sm.create_run::<u32>(Codec::Delta).unwrap();
        w.write_block(&data).unwrap();
        let run = w.finish().unwrap();
        sm.register(&run).unwrap();
        assert_eq!(sm.raw_bytes_written(), 12 + 2000 * 4);
        assert_eq!(sm.bytes_written(), run.bytes);
        assert!(
            sm.bytes_written() < sm.raw_bytes_written() / 2,
            "dense delta run must compress ≥ 2×: {} vs {}",
            sm.bytes_written(),
            sm.raw_bytes_written()
        );
        // Budget + live accounting use the *encoded* (actual) size.
        assert_eq!(sm.live_bytes(), run.bytes);
        sm.consume(&run).unwrap();
        assert_eq!(sm.live_bytes(), 0);
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let sm = SpillManager::new(None, None).unwrap();
        let r1 = spill_run(&sm, &[1, 2, 3, 4]);
        let peak_after_one = sm.peak_live_bytes();
        sm.consume(&r1).unwrap();
        let _r2 = spill_run(&sm, &[1]);
        assert!(sm.peak_live_bytes() >= peak_after_one);
        assert!(sm.live_bytes() < sm.peak_live_bytes());
    }

    #[test]
    fn recovery_sweep_reclaims_orphans_and_keeps_strangers() {
        let dir = std::env::temp_dir().join(format!("flims-sweep-{}", std::process::id()));
        std::fs::create_dir_all(dir.join("job-17")).unwrap();
        std::fs::write(dir.join("job-17").join("run-000000.flr"), b"junk").unwrap();
        std::fs::write(dir.join("run-000042.flr"), b"half-written").unwrap();
        std::fs::write(dir.join("keep.txt"), b"not ours").unwrap();
        std::fs::create_dir_all(dir.join("not-a-job")).unwrap();

        let removed = recover_stale_spills(Some(&dir));
        assert_eq!(removed.len(), 2, "{removed:?}");
        assert!(!dir.join("job-17").exists(), "orphaned job dir must be swept");
        assert!(!dir.join("run-000042.flr").exists(), "stray run must be swept");
        assert!(dir.join("keep.txt").exists(), "unrelated files must survive");
        assert!(dir.join("not-a-job").exists(), "unrelated dirs must survive");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn recovery_sweep_removes_dead_pid_dirs_and_keeps_live_ones() {
        if !Path::new("/proc").is_dir() {
            return; // liveness unobservable here; the sweep keeps everything
        }
        // A pid far outside any real pid space: /proc/<pid> cannot exist.
        let dead = std::env::temp_dir().join("flims-spill-4294967295-7");
        std::fs::create_dir_all(&dead).unwrap();
        std::fs::write(dead.join("run-000000.flr"), b"junk").unwrap();
        // Our own (live) dir must never be swept.
        let live = std::env::temp_dir()
            .join(format!("flims-spill-{}-999999", std::process::id()));
        std::fs::create_dir_all(&live).unwrap();

        let removed = recover_stale_spills(None);
        assert!(removed.contains(&dead), "{removed:?}");
        assert!(!dead.exists());
        assert!(live.exists(), "a live process's spill dir must survive the sweep");
        std::fs::remove_dir_all(&live).unwrap();
    }

    #[test]
    fn concurrent_registration_from_two_threads() {
        // The overlapped schedule registers phase-1 and merged runs from
        // different threads at once; counters must not lose updates and
        // every run must stay tracked (drop cleans them all).
        let sm = SpillManager::new(None, None).unwrap();
        std::thread::scope(|s| {
            for t in 0..4u32 {
                let sm = &sm;
                s.spawn(move || {
                    for i in 0..16u32 {
                        let mut w = sm.create_run(Codec::Raw).unwrap();
                        w.write_block(&[t * 100 + i]).unwrap();
                        let run = w.finish().unwrap();
                        sm.register(&run).unwrap();
                    }
                });
            }
        });
        assert_eq!(sm.runs_created(), 64);
        assert_eq!(sm.live_bytes(), 64 * (12 + 4));
        let dir = sm.dir().to_path_buf();
        drop(sm);
        assert!(!dir.exists(), "drop must clean every registered run");
    }
}
