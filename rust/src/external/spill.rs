//! Spill-file lifecycle: temp-dir ownership, run naming, disk-budget
//! enforcement, and eager deletion of consumed runs.
//!
//! Every run file the external sort creates flows through one
//! [`SpillManager`]: `create_run` names the file, `register` starts
//! tracking a finished run (and enforces the disk budget), `consume`
//! deletes it the moment the merge has drained it. `Drop` removes any
//! stragglers (and the temp dir, when the manager created it), so an
//! aborted sort never leaks disk.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

use super::codec::Codec;
use super::format::{ExtItem, RunFile, RunWriter};

/// Distinguishes concurrent spill dirs within one process.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// Tracks live spill files and enforces the disk byte budget.
pub struct SpillManager {
    dir: PathBuf,
    /// We created the directory, so we remove it on drop.
    own_dir: bool,
    next_run: u64,
    live: Vec<RunFile>,
    live_bytes: u64,
    disk_budget: Option<u64>,
    /// Lifetime counters (monotonic, survive consume()).
    runs_created: u64,
    runs_deleted: u64,
    bytes_written: u64,
    raw_bytes_written: u64,
    encode_ns: u64,
    peak_live_bytes: u64,
}

impl SpillManager {
    /// `dir = None` creates (and owns) a fresh directory under the
    /// system temp dir; `Some(d)` spills into `d` without owning it.
    pub fn new(dir: Option<PathBuf>, disk_budget: Option<u64>) -> Result<Self> {
        let (dir, own_dir) = match dir {
            Some(d) => {
                std::fs::create_dir_all(&d)
                    .with_context(|| format!("creating spill dir {}", d.display()))?;
                (d, false)
            }
            None => {
                let seq = SPILL_SEQ.fetch_add(1, Ordering::Relaxed);
                let d = std::env::temp_dir()
                    .join(format!("flims-spill-{}-{}", std::process::id(), seq));
                std::fs::create_dir_all(&d)
                    .with_context(|| format!("creating spill dir {}", d.display()))?;
                (d, true)
            }
        };
        Ok(SpillManager {
            dir,
            own_dir,
            next_run: 0,
            live: Vec::new(),
            live_bytes: 0,
            disk_budget,
            runs_created: 0,
            runs_deleted: 0,
            bytes_written: 0,
            raw_bytes_written: 0,
            encode_ns: 0,
            peak_live_bytes: 0,
        })
    }

    /// The directory runs spill into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Open a writer for the next run file, encoding with `codec`
    /// (callers pass the *effective* codec —
    /// [`Codec::effective_for`] already applied). Naming is sequential
    /// in call order, which the parallel phases rely on for
    /// deterministic run layouts: writers are always created on the
    /// coordinating thread in input order, only the merging/sorting
    /// work fans out.
    pub fn create_run<T: ExtItem>(&mut self, codec: Codec) -> Result<RunWriter<T>> {
        let path = self.dir.join(format!("run-{:06}.flr", self.next_run));
        self.next_run += 1;
        RunWriter::create_with(&path, codec)
    }

    /// Check that `upcoming_bytes` more spill fits the disk budget —
    /// called *before* writing a run, so the budget is enforced ahead
    /// of the disk filling, not after.
    pub fn check_headroom(&self, upcoming_bytes: u64) -> Result<()> {
        if let Some(budget) = self.disk_budget {
            let projected = self.live_bytes + upcoming_bytes;
            if projected > budget {
                bail!(
                    "spill disk budget exceeded: {} bytes live + {} upcoming > {} budget",
                    self.live_bytes,
                    upcoming_bytes,
                    budget
                );
            }
        }
        Ok(())
    }

    /// Start tracking a finished run; errors if it pushes live spill
    /// bytes past the disk budget (the run stays registered so Drop
    /// still cleans it up).
    pub fn register(&mut self, run: &RunFile) -> Result<()> {
        self.live.push(run.clone());
        self.live_bytes += run.bytes;
        self.bytes_written += run.bytes;
        self.raw_bytes_written += run.raw_bytes;
        self.encode_ns += run.encode_ns;
        self.runs_created += 1;
        self.peak_live_bytes = self.peak_live_bytes.max(self.live_bytes);
        if let Some(budget) = self.disk_budget {
            if self.live_bytes > budget {
                bail!(
                    "spill disk budget exceeded: {} bytes live > {} budget ({} runs)",
                    self.live_bytes,
                    budget,
                    self.live.len()
                );
            }
        }
        Ok(())
    }

    /// Delete a fully-consumed run eagerly, reclaiming its disk.
    pub fn consume(&mut self, run: &RunFile) -> Result<()> {
        std::fs::remove_file(&run.path)
            .with_context(|| format!("deleting consumed run {}", run.path.display()))?;
        self.live.retain(|r| r.path != run.path);
        self.live_bytes = self.live_bytes.saturating_sub(run.bytes);
        self.runs_deleted += 1;
        Ok(())
    }

    /// Bytes currently occupied by live (not yet consumed) runs.
    pub fn live_bytes(&self) -> u64 {
        self.live_bytes
    }

    /// High-water mark of [`live_bytes`](SpillManager::live_bytes).
    pub fn peak_live_bytes(&self) -> u64 {
        self.peak_live_bytes
    }

    /// Runs registered over this manager's lifetime.
    pub fn runs_created(&self) -> u64 {
        self.runs_created
    }

    /// Runs consumed (deleted) over this manager's lifetime.
    pub fn runs_deleted(&self) -> u64 {
        self.runs_deleted
    }

    /// Encoded bytes written across every registered run.
    pub fn bytes_written(&self) -> u64 {
        self.bytes_written
    }

    /// What the same spill traffic would have occupied uncompressed
    /// (`elems × WIRE_BYTES` + headers) — `bytes_written /
    /// raw_bytes_written` is the achieved compression ratio.
    pub fn raw_bytes_written(&self) -> u64 {
        self.raw_bytes_written
    }

    /// Cumulative wall-clock the run writers spent encoding, µs
    /// (nanosecond-accumulated, divided once here so sub-µs runs are
    /// not truncated away).
    pub fn encode_us(&self) -> u64 {
        self.encode_ns / 1000
    }
}

impl Drop for SpillManager {
    fn drop(&mut self) {
        for run in &self.live {
            let _ = std::fs::remove_file(&run.path);
        }
        if self.own_dir {
            let _ = std::fs::remove_dir(&self.dir);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spill_run(sm: &mut SpillManager, data: &[u32]) -> RunFile {
        let mut w = sm.create_run(Codec::Raw).unwrap();
        w.write_block(data).unwrap();
        let run = w.finish().unwrap();
        sm.register(&run).unwrap();
        run
    }

    #[test]
    fn create_register_consume_cycle() {
        let mut sm = SpillManager::new(None, None).unwrap();
        let dir = sm.dir().to_path_buf();
        let r1 = spill_run(&mut sm, &[3, 2, 1]);
        let r2 = spill_run(&mut sm, &[9, 9]);
        assert!(r1.path.exists() && r2.path.exists());
        assert_eq!(sm.runs_created(), 2);
        assert_eq!(sm.live_bytes(), r1.bytes + r2.bytes);

        sm.consume(&r1).unwrap();
        assert!(!r1.path.exists(), "consumed run must be deleted eagerly");
        assert_eq!(sm.live_bytes(), r2.bytes);
        assert_eq!(sm.runs_deleted(), 1);

        drop(sm);
        assert!(!r2.path.exists(), "drop must clean leftover runs");
        assert!(!dir.exists(), "drop must remove the owned temp dir");
    }

    #[test]
    fn disk_budget_enforced() {
        // Budget fits one 3-element run (12 bytes header + 12 payload)
        // but not two.
        let mut sm = SpillManager::new(None, Some(30)).unwrap();
        let mut w = sm.create_run(Codec::Raw).unwrap();
        w.write_block(&[5u32, 4, 3]).unwrap();
        let r1 = w.finish().unwrap();
        sm.register(&r1).unwrap();

        let mut w = sm.create_run(Codec::Raw).unwrap();
        w.write_block(&[2u32, 1, 0]).unwrap();
        let r2 = w.finish().unwrap();
        let err = format!("{:#}", sm.register(&r2).unwrap_err());
        assert!(err.contains("disk budget exceeded"), "{err}");

        // Consuming reclaims budget headroom.
        sm.consume(&r1).unwrap();
        assert!(sm.live_bytes() <= 30);
    }

    #[test]
    fn headroom_is_checked_before_writing() {
        let mut sm = SpillManager::new(None, Some(100)).unwrap();
        assert!(sm.check_headroom(100).is_ok());
        let err = format!("{:#}", sm.check_headroom(101).unwrap_err());
        assert!(err.contains("disk budget exceeded"), "{err}");
        // Live bytes count against the headroom.
        let r = spill_run(&mut sm, &[1, 2, 3]); // 12 + 12 = 24 bytes
        assert!(sm.check_headroom(76).is_ok());
        assert!(sm.check_headroom(77).is_err());
        sm.consume(&r).unwrap();
        assert!(sm.check_headroom(100).is_ok());
    }

    #[test]
    fn external_dir_is_not_removed() {
        let dir = std::env::temp_dir().join(format!("flims-keep-{}", std::process::id()));
        let mut sm = SpillManager::new(Some(dir.clone()), None).unwrap();
        let run = spill_run(&mut sm, &[1]);
        drop(sm);
        assert!(!run.path.exists(), "runs are still cleaned");
        assert!(dir.exists(), "caller-provided dir must survive");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn raw_vs_encoded_accounting() {
        let mut sm = SpillManager::new(None, None).unwrap();
        // A dense descending run compresses well under the delta codec.
        let data: Vec<u32> = (0..2000u32).rev().collect();
        let mut w = sm.create_run::<u32>(Codec::Delta).unwrap();
        w.write_block(&data).unwrap();
        let run = w.finish().unwrap();
        sm.register(&run).unwrap();
        assert_eq!(sm.raw_bytes_written(), 12 + 2000 * 4);
        assert_eq!(sm.bytes_written(), run.bytes);
        assert!(
            sm.bytes_written() < sm.raw_bytes_written() / 2,
            "dense delta run must compress ≥ 2×: {} vs {}",
            sm.bytes_written(),
            sm.raw_bytes_written()
        );
        // Budget + live accounting use the *encoded* (actual) size.
        assert_eq!(sm.live_bytes(), run.bytes);
        sm.consume(&run).unwrap();
        assert_eq!(sm.live_bytes(), 0);
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut sm = SpillManager::new(None, None).unwrap();
        let r1 = spill_run(&mut sm, &[1, 2, 3, 4]);
        let peak_after_one = sm.peak_live_bytes();
        sm.consume(&r1).unwrap();
        let _r2 = spill_run(&mut sm, &[1]);
        assert!(sm.peak_live_bytes() >= peak_after_one);
        assert!(sm.live_bytes() < sm.peak_live_bytes());
    }
}
