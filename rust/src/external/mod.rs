//! Out-of-core external sort: spill runs to disk, then k-way merge them
//! with trees of FLiMS 2-way mergers — parallel in both phases, generic
//! over the dataset type, and (with `[external] overlap = on`) running
//! the two phases as one pipeline.
//!
//! The paper positions FLiMS inside "parallel merge trees to achieve
//! high-throughput sorting, where the resource utilisation of the merger
//! is critical for building large trees and internalising the workload"
//! (§1). This module is that use case for datasets larger than RAM,
//! in the classic two-phase external-sort shape (TopSort's phase
//! structure, Merge-Path-style safe splits at the nodes):
//!
//! 1. **Run generation** ([`run_gen`]): the input streams through a
//!    bounded work queue to a pool of `threads` sort workers; each chunk
//!    is sorted by the in-memory FLiMS pipeline ([`format::ExtItem::sort_run`]
//!    — stable for payload records) and spilled in input order as a
//!    descending run ([`format::RunWriter`]).
//! 2. **k-way streaming merge** ([`merge`], [`stream`]): runs feed an
//!    HPMT-style binary tree of block-buffered *stable* FLiMS mergers.
//!    When the run count exceeds the configured fan-in, intermediate
//!    passes re-spill merged runs, with the independent group merges of
//!    a pass running concurrently; the [`spill::SpillManager`] deletes
//!    consumed runs eagerly and enforces the disk budget. Tree leaves
//!    are double-buffered ([`stream::PrefetchStream`]): a prefetch
//!    thread fills the next blocks while the merger drains the current
//!    one, so the hot path never blocks on `read_block`.
//!
//! # The pipelined (overlapped) schedule
//!
//! With `overlap = off` the phases run back to back: every run exists
//! before the first merge tree opens, which leaves the merge hardware
//! idle all through phase 1 and the sort/spill hardware idle all
//! through phase 2 — TopSort's half-idle-machine observation. With
//! `overlap = on`, [`sort_stream`] instead runs phase 1 as a
//! **producer** ([`run_gen::generate_runs_streaming`]) that announces
//! each run over a bounded channel the moment it seals, and a pipeline
//! scheduler ([`merge::sort_pipelined`]) fires a group merge as soon as
//! a full fan-in chunk of runs (plus proof that more input exists)
//! is available — so intermediate passes, of every depth, execute
//! concurrently with late phase-1 spills, and when the producer
//! finishes only the final streaming pass (plus whatever groups are
//! still in flight) remains. Group shapes are prefix-stable chunks of
//! `fan_in` ([`merge::MergePlan`]), identical under both schedules, and
//! runs flow through every pass in input order — which is why the
//! sorted output is **byte-identical** with overlap on or off, for
//! every thread count, codec, and dtype (the overlap determinism
//! suite pins this). One shared [`spill::SpillManager`] serves both
//! concurrently-running phases; the disk budget (with in-flight merge
//! outputs reserved) and eager run deletion hold throughout, and
//! [`SpillStats::wall_us`] / [`SpillStats::overlap_us`] report how much
//! of the two phase spans actually ran concurrently. Spill writers on
//! both sides draw their threads from one long-lived per-sort
//! [`stream::WriterPool`] instead of spawning per run.
//!
//! Datasets are headerless little-endian record files ([`format::RawReader`])
//! in any supported [`Dtype`] (`u32`, `u64`, `kv`, `kv64`, `f32`);
//! output is the same format, descending, with key ties keeping input
//! order (the §6 tie-record guarantee — see the stability property
//! tests). Resident memory stays within a small constant factor of
//! `mem_budget_bytes` (× `2·threads` when phase 1 runs parallel, plus
//! one run buffer in flight on the double-buffered spill writer).
//!
//! Every byte crossing the spill boundary flows through the run-codec
//! layer ([`codec`]): `[external] codec = raw` spills fixed-width
//! `FLR1` runs, `codec = delta` spills `FLR2` delta + varint runs
//! (~2–4× smaller on sorted/skewed keys), and `codec = flr3` spills
//! `FLR3` frame-of-reference bitpacked runs ([`flr3`]) whose decode is
//! a branch-free SIMD loop on the [`MergeKernel`] knob, re-encoding
//! intermediate passes too. Encoding rides the write-side
//! double-buffer threads and decoding the prefetch threads, so codec
//! CPU trades against spill bandwidth without lengthening the merge's
//! critical path.

pub mod codec;
pub mod flr3;
pub mod format;
pub mod merge;
pub mod run_gen;
pub mod spill;
pub mod stream;

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

pub use codec::{parse_codec_arg, Codec};
pub use format::{
    parse_dtype_arg, read_raw, write_raw, Dtype, ExtItem, RawReader, RawWriter, RunFile,
    RunReader, RunWriter,
};
pub use merge::{
    merge_runs, sort_pipelined, MergeOutcome, MergePlan, PipelineOutcome, RecordSink,
};
pub use run_gen::{
    generate_runs, generate_runs_streaming, RecordSource, RunEmit, SliceSource,
};
pub use spill::SpillManager;
pub use stream::{
    build_tree, DoubleBufWriter, MergeStream, PoolJob, PrefetchCounters, PrefetchStream,
    ReaderStream, RunStream, WriterPool,
};

use crate::fault::{parse_faults_arg, FaultSpec, Injector};
use crate::flims::simd::MergeKernel;
use crate::flims::sort::SortConfig;
use crate::key::{F32Key, Kv, Kv64};
use crate::obs::progress::ProgressHandle;
use crate::obs::{self, progress, SpanKind, Trace};

/// A cooperative cancellation flag shared between a running sort and
/// whoever may abort it (the job scheduler's `cancel <id>` verb). The
/// pipeline polls it at its natural batch boundaries — the top of every
/// phase-1 chunk, before every group merge, per block of the final
/// drain — so cancellation lands within one chunk/block of work, and
/// the normal error path then unwinds the sort: in-flight merges
/// drain, the [`SpillManager`] deletes every live run, nothing leaks.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// A fresh, uncancelled token.
    pub fn new() -> Self {
        Self::default()
    }

    /// Request cancellation (idempotent, thread-safe).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::Relaxed);
    }

    /// Whether cancellation was requested.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::Relaxed)
    }

    /// `Err("sort cancelled")` once cancellation was requested — the
    /// form the pipeline's check points use so the abort flows through
    /// the existing error unwinding.
    pub fn check(&self) -> Result<()> {
        if self.is_cancelled() {
            Err(anyhow!("sort cancelled"))
        } else {
            Ok(())
        }
    }
}

/// Per-job context threaded through one external sort: where progress
/// is reported and how the sort is cancelled. The default value —
/// which every non-`_ctx` entry point uses — reports to the
/// process-wide progress totals only and is never cancelled, so
/// standalone sorts behave exactly as before the job scheduler
/// existed.
#[derive(Clone, Debug, Default)]
pub struct SortCtx {
    /// Progress sink: global totals, plus one job's counters when the
    /// sort runs under the scheduler.
    pub progress: ProgressHandle,
    /// Cooperative cancellation flag.
    pub cancel: CancelToken,
}

/// Tuning for the external sort.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExternalConfig {
    /// Target resident memory for the sort (run buffer in phase 1, the
    /// merge-tree buffers in phase 2). Actual peak stays within a small
    /// constant factor — `2 × threads` run buffers when phase 1 runs
    /// parallel, since sorted chunks queue for in-order spilling.
    pub mem_budget_bytes: usize,
    /// Maximum runs merged by one tree; more runs ⇒ extra spill passes.
    pub fan_in: usize,
    /// FLiMS lane width for the in-memory sort and every tree node.
    pub w: usize,
    /// Sort-in-chunks run length for the in-memory sort.
    pub chunk: usize,
    /// Worker threads for phase-1 chunk sorting and phase-2 group
    /// merges. `1` = fully serial (the default); `0` = one per core.
    /// The sorted output is byte-identical for every value.
    pub threads: usize,
    /// Blocks each tree leaf reads ahead on its prefetch thread;
    /// `0` disables double-buffering (leaves block on `read_block`).
    pub prefetch_blocks: usize,
    /// Overlap phase 1 with phase 2 (the TopSort-style pipelined
    /// schedule): group merges start while later runs still spill.
    /// `false` preserves the serial back-to-back schedule; the sorted
    /// output is byte-identical either way. Defaults from the
    /// `FLIMS_EXTERNAL_OVERLAP` environment variable (`on`/`off`,
    /// unset = off) so CI can run the whole suite pipelined.
    pub overlap: bool,
    /// Default dataset element type for file sorts when the request
    /// does not name one. Defaults from the `FLIMS_DTYPE` environment
    /// variable (unset = `u32`) so CI can run the whole integration
    /// suite on payload records.
    pub dtype: Dtype,
    /// Run codec for spilled runs (phase 1 and intermediate passes).
    /// `delta` and `flr3` fall back to `raw` for dtypes without an
    /// integer delta domain (`f32`), and the keys-only `flr3` falls
    /// back to `delta` for payload records — see
    /// [`Codec::effective_for`]. Defaults from the `FLIMS_CODEC`
    /// environment variable (unset = `raw`) so CI can run the whole
    /// suite on any codec.
    pub codec: Codec,
    /// Spill directory (`None` = fresh dir under the system temp dir).
    pub tmp_dir: Option<PathBuf>,
    /// Cap on live spill bytes (`None` = unlimited).
    pub disk_budget_bytes: Option<u64>,
    /// Merge-kernel tier for the phase-1 chunk sorts and every tree
    /// node's inner merge loop: `auto` (explicit SIMD where a kernel
    /// exists), `scalar` (force the branchless scalar lanes), or
    /// `simd`. Plain keys — unsigned, signed via the sign-flip bias
    /// wrappers, f32 via the order-preserving bit map — merge on the
    /// SSE2/AVX2/NEON lanes directly; payload dtypes (`kv`, `kv64`)
    /// take the key–index SIMD stable tier, which keeps the §6
    /// guarantee (see `merge_stable_simd`). The sorted output is
    /// byte-identical for every value. Per-dtype reality is surfaced by
    /// [`Dtype::effective_kernel`]. Defaults from the `FLIMS_KERNEL`
    /// environment variable (unset = `auto`) so CI can run the whole
    /// suite on the scalar tier.
    pub kernel: MergeKernel,
    /// Deterministic fault-injection plan for the spill-I/O seams
    /// (`None` = disabled, the production default: one null check per
    /// seam, no clock, no allocation). When set, every run
    /// create/write/seal/read/delete and the output sink draw from a
    /// seeded per-site decision stream ([`crate::fault`]), injecting
    /// transient errors, disk-full, short I/O, and latency stalls —
    /// recovery must keep the sorted output byte-identical or fail the
    /// job with one clean error (see `docs/ROBUSTNESS.md`). Defaults
    /// from the `FLIMS_FAULTS` environment variable
    /// (`<seed>:<rate>:<kinds>`, unset = off) so CI can run the whole
    /// suite under a low-rate fault plan.
    pub fault: Option<FaultSpec>,
    /// When set, every sort records a span trace (phase-1 chunk sorts,
    /// sealed runs, group merges, codec and prefetch activity) and
    /// auto-writes it into this directory as Chrome trace-event JSON on
    /// completion (`flims-trace-<pid>-<seq>.json` — load it in
    /// Perfetto; see `docs/OBSERVABILITY.md`). `None` disables tracing:
    /// the [`Trace`] handle threaded through the pipeline is a no-op
    /// that allocates nothing and never touches the clock, and the
    /// sorted output is byte-identical either way. Defaults from the
    /// `FLIMS_TRACE_DIR` environment variable (unset/empty = off) so CI
    /// can run the whole suite traced.
    pub trace_dir: Option<PathBuf>,
}

impl Default for ExternalConfig {
    fn default() -> Self {
        ExternalConfig {
            mem_budget_bytes: 64 << 20,
            fan_in: 8,
            w: 16,
            chunk: 128,
            threads: 1,
            prefetch_blocks: 2,
            overlap: overlap_default(),
            dtype: dtype_default(),
            codec: codec_default(),
            tmp_dir: None,
            disk_budget_bytes: None,
            kernel: MergeKernel::env_default(),
            fault: fault_default(),
            trace_dir: trace_dir_default(),
        }
    }
}

/// The `fault` default: the `FLIMS_FAULTS` environment variable when
/// set, else off. This is how the `test-faults` CI lane runs the full
/// integration suite under a seeded low-rate fault plan without
/// touching every test's config. Like the other env knobs, an
/// unparseable value warns on stderr instead of silently meaning
/// "off" — a typo should not quietly turn the fault lane into a second
/// fault-free run.
fn fault_default() -> Option<FaultSpec> {
    match std::env::var("FLIMS_FAULTS") {
        Err(_) => None,
        Ok(v) => parse_faults_arg(&v).unwrap_or_else(|e| {
            eprintln!("warning: FLIMS_FAULTS ignored: {e}");
            None
        }),
    }
}

/// The `trace_dir` default: the `FLIMS_TRACE_DIR` environment variable
/// when set and non-empty, else off. Any non-empty value is a valid
/// path, so unlike `FLIMS_EXTERNAL_OVERLAP` there is nothing to warn
/// about.
fn trace_dir_default() -> Option<PathBuf> {
    match std::env::var_os("FLIMS_TRACE_DIR") {
        Some(v) if !v.is_empty() => Some(PathBuf::from(v)),
        _ => None,
    }
}

/// Parse an overlap knob value: `on`/`off` (the documented spellings),
/// with `true`/`false`/`1`/`0` accepted as aliases, case-insensitive.
pub fn parse_overlap(s: &str) -> Result<bool, String> {
    match s.trim().to_ascii_lowercase().as_str() {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        _ => Err(format!("unknown overlap value '{s}' (expected on|off)")),
    }
}

/// The `overlap` default: the `FLIMS_EXTERNAL_OVERLAP` environment
/// variable when set, else off. This is how CI runs the full
/// integration suite under the pipelined schedule without touching
/// every test's config. An unparseable value warns on stderr instead
/// of silently meaning "off" — otherwise a typo would quietly turn the
/// overlap CI job into a second serial run.
fn overlap_default() -> bool {
    match std::env::var("FLIMS_EXTERNAL_OVERLAP") {
        Err(_) => false,
        Ok(v) => parse_overlap(&v).unwrap_or_else(|e| {
            eprintln!("warning: FLIMS_EXTERNAL_OVERLAP ignored: {e}");
            false
        }),
    }
}

/// The `dtype` default: the `FLIMS_DTYPE` environment variable when
/// set, else `u32`. This is how a CI lane runs the full integration
/// suite over payload records (`FLIMS_DTYPE=kv64`) without touching
/// every test's config. Like the other env knobs, an unparseable value
/// warns on stderr instead of silently meaning `u32`.
fn dtype_default() -> Dtype {
    match std::env::var("FLIMS_DTYPE") {
        Err(_) => Dtype::U32,
        Ok(v) => Dtype::parse(&v).unwrap_or_else(|e| {
            eprintln!("warning: FLIMS_DTYPE ignored: {e}");
            Dtype::U32
        }),
    }
}

/// The `codec` default: the `FLIMS_CODEC` environment variable when
/// set, else raw. This is how the `test-codec-flr3` CI lane runs the
/// full integration suite with every spill compressed through FLR3
/// without touching each test's config. Like the overlap knob, an
/// unparseable value warns on stderr instead of silently meaning
/// "raw" — a typo should not quietly turn the codec lane into a
/// second raw run.
fn codec_default() -> Codec {
    match std::env::var("FLIMS_CODEC") {
        Err(_) => Codec::Raw,
        Ok(v) => Codec::parse(&v).unwrap_or_else(|e| {
            eprintln!("warning: FLIMS_CODEC ignored: {e}");
            Codec::Raw
        }),
    }
}

impl ExternalConfig {
    /// Reject configurations the pipeline cannot run with.
    pub fn validate(&self) -> Result<(), String> {
        if self.mem_budget_bytes < 4096 {
            return Err(format!(
                "external.mem_budget_bytes = {} must be at least 4096",
                self.mem_budget_bytes
            ));
        }
        if self.fan_in < 2 {
            return Err(format!("external.fan_in = {} must be at least 2", self.fan_in));
        }
        if self.threads > 1024 {
            return Err(format!(
                "external.threads = {} is absurd (max 1024, 0 = one per core)",
                self.threads
            ));
        }
        if self.prefetch_blocks > 1024 {
            return Err(format!(
                "external.prefetch_blocks = {} is absurd (max 1024, 0 disables prefetch)",
                self.prefetch_blocks
            ));
        }
        SortConfig { w: self.w, chunk: self.chunk }.validate()
    }

    /// Elements per phase-1 run for records of `wire_bytes` each (the
    /// whole budget is one run buffer; independent of the thread count
    /// so the spill layout is too).
    pub fn run_elems_for(&self, wire_bytes: usize) -> usize {
        (self.mem_budget_bytes / wire_bytes).max(1)
    }

    /// Elements per merge-tree block buffer: the budget divided across
    /// the tree's buffers (≈ 3 per node, ≤ 2·fan_in nodes, plus slack).
    pub fn block_elems_for(&self, wire_bytes: usize) -> usize {
        (self.run_elems_for(wire_bytes) / (8 * self.fan_in)).max(64)
    }

    /// The codec actually used for runs of `dtype` — the configured one
    /// with the dtype-aware fallback applied (`f32` keys stay raw).
    pub fn codec_for(&self, dtype: Dtype) -> Codec {
        self.codec.effective_for(dtype)
    }

    /// Resolved worker count (`0` = one per core).
    pub fn effective_threads(&self) -> usize {
        if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        }
    }

    /// The in-memory FLiMS sort tuning used by phase 1.
    pub fn sort_config(&self) -> SortConfig {
        SortConfig { w: self.w, chunk: self.chunk }
    }

    /// The trace handle sorts started through the non-`_traced` entry
    /// points record into: enabled iff [`trace_dir`](Self::trace_dir)
    /// is set.
    pub fn make_trace(&self) -> Trace {
        if self.trace_dir.is_some() {
            Trace::enabled()
        } else {
            Trace::disabled()
        }
    }
}

/// What an external sort did — surfaced through `metrics` by the
/// coordinator and printed by the CLI.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpillStats {
    /// Elements sorted (== input length).
    pub elements: u64,
    /// Runs written to disk (phase 1 + intermediate passes).
    pub runs_spilled: u64,
    /// Total *encoded* bytes written to spill files — what actually hit
    /// the disk.
    pub bytes_spilled: u64,
    /// What the same spill traffic would have occupied under the raw
    /// codec (`elems × WIRE_BYTES` + headers); `bytes_spilled /
    /// bytes_spilled_raw` is the achieved compression ratio (1.0 for
    /// `codec = raw`).
    pub bytes_spilled_raw: u64,
    /// Merge passes over the data (intermediate + final).
    pub merge_passes: u64,
    /// High-water mark of live spill bytes.
    pub peak_spill_bytes: u64,
    /// Wall-clock of phase 1 (run generation), microseconds. Under the
    /// overlapped schedule this span runs concurrently with `phase2_us`
    /// rather than before it.
    pub phase1_us: u64,
    /// Wall-clock of phase 2 (k-way merge: first group merge → sink
    /// complete), microseconds.
    pub phase2_us: u64,
    /// End-to-end wall-clock of the whole sort, microseconds. Serially
    /// this is ≈ `phase1_us + phase2_us`; overlapped it is less — the
    /// saving the pipeline buys.
    pub wall_us: u64,
    /// Time both phases ran concurrently: `phase1_us + phase2_us −
    /// wall_us`, clamped at 0 (always 0 under the serial schedule).
    pub overlap_us: u64,
    /// Leaf blocks the prefetch threads had ready before the merger
    /// asked (the disk read was fully overlapped with merging).
    pub prefetch_hits: u64,
    /// Leaf blocks the merger had to wait for.
    pub prefetch_misses: u64,
    /// Wall-clock spent encoding runs, µs (on the double-buffered
    /// writer threads, overlapped with the producer).
    pub codec_encode_us: u64,
    /// Wall-clock spent decoding runs, µs (on the leaf reader threads,
    /// overlapped with the merge when prefetch is on).
    pub codec_decode_us: u64,
}

/// Sort any [`RecordSource`] into any [`RecordSink`] with bounded
/// memory. `cfg.overlap` picks the schedule: serial back-to-back
/// phases, or the pipelined schedule that merges fan-in groups while
/// later runs still spill — same output bytes either way. (The source
/// must be `Send` because the pipelined producer runs on its own
/// thread; every in-tree source is.)
pub fn sort_stream<T: ExtItem>(
    src: &mut (dyn RecordSource<T> + Send),
    sink: &mut dyn RecordSink<T>,
    cfg: &ExternalConfig,
) -> Result<SpillStats> {
    let trace = cfg.make_trace();
    let stats = sort_stream_traced(src, sink, cfg, &trace)?;
    if let Some(dir) = &cfg.trace_dir {
        obs::chrome::write_auto(&trace, dir);
    }
    Ok(stats)
}

/// [`sort_stream`] recording spans into a caller-owned [`Trace`] — the
/// entry point for callers that render or write the trace themselves
/// (`--trace <path>`, the protocol's `trace=` option). Never writes a
/// trace file; `cfg.trace_dir` is ignored here.
pub fn sort_stream_traced<T: ExtItem>(
    src: &mut (dyn RecordSource<T> + Send),
    sink: &mut dyn RecordSink<T>,
    cfg: &ExternalConfig,
    trace: &Trace,
) -> Result<SpillStats> {
    sort_stream_ctx(src, sink, cfg, &SortCtx::default(), None, trace)
}

/// [`sort_stream_traced`] under an explicit [`SortCtx`] (per-job
/// progress + cancellation) and, optionally, a caller-owned shared
/// [`WriterPool`] — the entry point the job scheduler uses so N
/// concurrent sorts draw writer threads from one long-lived
/// process-wide pool instead of spawning a fresh pool each. With
/// `shared_pool = None` the sort builds its own per-sort pool (the
/// pre-scheduler behaviour).
pub fn sort_stream_ctx<T: ExtItem>(
    src: &mut (dyn RecordSource<T> + Send),
    sink: &mut dyn RecordSink<T>,
    cfg: &ExternalConfig,
    ctx: &SortCtx,
    shared_pool: Option<&WriterPool>,
    trace: &Trace,
) -> Result<SpillStats> {
    cfg.validate().map_err(|e| anyhow!("{e}"))?;
    let _active = progress::sort_started();
    let spill = SpillManager::new(cfg.tmp_dir.clone(), cfg.disk_budget_bytes)?
        .with_faults(cfg.fault, trace.clone());
    // One long-lived writer thread per concurrent spill writer (the
    // phase-1 producer + up to `threads` group merges, plus slack) —
    // thousand-run sorts reuse these instead of spawning per run.
    // When the job scheduler supplies its process-wide pool, use that;
    // `try_execute` falls back to a dedicated thread under saturation,
    // so sharing can never deadlock concurrent jobs.
    let own_pool = match shared_pool {
        Some(_) => None,
        None => Some(WriterPool::new(cfg.effective_threads() + 2)?),
    };
    let pool = shared_pool.or(own_pool.as_ref());
    let wall = Instant::now();
    let (outcome, input_elems, phase1_us, phase2_us) = if cfg.overlap {
        let p = merge::sort_pipelined_ctx(src, cfg, &spill, pool, sink, trace, ctx)?;
        (p.outcome, p.input_elems, p.phase1_us, p.phase2_us)
    } else {
        let t1 = Instant::now();
        let runs = run_gen::generate_runs_ctx(src, cfg, &spill, pool, trace, ctx)?;
        let phase1_us = t1.elapsed().as_micros() as u64;
        let input_elems: u64 = runs.iter().map(|r| r.elems).sum();
        let t2 = Instant::now();
        let outcome = merge::merge_runs_ctx(runs, cfg, &spill, pool, sink, trace, ctx)?;
        (outcome, input_elems, phase1_us, t2.elapsed().as_micros() as u64)
    };
    // Decode work happens on the prefetch/reader threads in slices too
    // small to span individually; attribute the total as one aggregate
    // span over the sort (see the span taxonomy in OBSERVABILITY.md).
    if outcome.codec_decode_us > 0 {
        trace.record_dur(SpanKind::CodecDecode, wall, outcome.codec_decode_us * 1000, 0);
    }
    let wall_us = wall.elapsed().as_micros() as u64;
    if outcome.elements != input_elems {
        return Err(anyhow!(
            "external sort corrupted: {} elements in, {} out",
            input_elems,
            outcome.elements
        ));
    }
    Ok(SpillStats {
        elements: outcome.elements,
        runs_spilled: spill.runs_created(),
        bytes_spilled: spill.bytes_written(),
        bytes_spilled_raw: spill.raw_bytes_written(),
        merge_passes: outcome.merge_passes,
        peak_spill_bytes: spill.peak_live_bytes(),
        phase1_us,
        phase2_us,
        wall_us,
        overlap_us: (phase1_us + phase2_us).saturating_sub(wall_us),
        prefetch_hits: outcome.prefetch_hits,
        prefetch_misses: outcome.prefetch_misses,
        codec_encode_us: spill.encode_us(),
        codec_decode_us: outcome.codec_decode_us,
    })
}

/// Sort the raw dataset at `input` into `output` (descending),
/// spilling through temp files; resident memory is bounded by the
/// configured budget, not the dataset size. `output` must be a
/// different file — creating it truncates, so sorting in place would
/// destroy the input before it was read.
pub fn sort_file<T: ExtItem>(
    input: &Path,
    output: &Path,
    cfg: &ExternalConfig,
) -> Result<SpillStats> {
    let trace = cfg.make_trace();
    let stats = sort_file_traced::<T>(input, output, cfg, &trace)?;
    if let Some(dir) = &cfg.trace_dir {
        obs::chrome::write_auto(&trace, dir);
    }
    Ok(stats)
}

/// [`sort_file`] recording spans into a caller-owned [`Trace`] (see
/// [`sort_stream_traced`]); never writes a trace file itself.
pub fn sort_file_traced<T: ExtItem>(
    input: &Path,
    output: &Path,
    cfg: &ExternalConfig,
    trace: &Trace,
) -> Result<SpillStats> {
    let same_file = input == output
        || match (input.canonicalize(), output.canonicalize()) {
            (Ok(a), Ok(b)) => a == b,
            _ => false, // output usually doesn't exist yet
        };
    if same_file {
        return Err(anyhow!(
            "refusing to sort {} in place: output would truncate the input (pick a different --output)",
            input.display()
        ));
    }
    let run = || -> Result<SpillStats> {
        let mut src = RawReader::<T>::open(input)?;
        // Double-buffer the output too: the final merge pass hands
        // blocks to a writer thread instead of blocking on the output
        // disk.
        let writer =
            RawWriter::<T>::create(output)?.with_fault(output_injector(cfg, output, trace));
        let mut sink = DoubleBufWriter::spawn(writer, 2)?;
        let stats = sort_stream_traced(&mut src, &mut sink, cfg, trace)?;
        let written = sink.finish()?.finish()?;
        debug_assert_eq!(written, stats.elements);
        Ok(stats)
    };
    let res = run();
    // A failed sort leaves no partial output behind — the same
    // guarantee `sort_file_ctx` gives the job path.
    if res.is_err() {
        let _ = std::fs::remove_file(output);
    }
    res
}

/// The output-sink injector for a file sort, keyed by the output file
/// name so the injected-fault sequence is stable run to run. Builds no
/// site string when faults are off — the disabled path stays
/// allocation-free.
fn output_injector(cfg: &ExternalConfig, output: &Path, trace: &Trace) -> Injector {
    match cfg.fault {
        None => Injector::disabled(),
        Some(_) => {
            let name = output.file_name().map(|n| n.to_string_lossy());
            Injector::for_site(cfg.fault, name.as_deref().unwrap_or("output"), trace)
        }
    }
}

/// [`sort_file`] dispatched over a runtime [`Dtype`] — the entry point
/// the router and CLI use for `sortfile <path> [dtype]`.
pub fn sort_file_dtype(
    input: &Path,
    output: &Path,
    cfg: &ExternalConfig,
    dtype: Dtype,
) -> Result<SpillStats> {
    match dtype {
        Dtype::U32 => sort_file::<u32>(input, output, cfg),
        Dtype::U64 => sort_file::<u64>(input, output, cfg),
        Dtype::I32 => sort_file::<i32>(input, output, cfg),
        Dtype::I64 => sort_file::<i64>(input, output, cfg),
        Dtype::Kv => sort_file::<Kv>(input, output, cfg),
        Dtype::Kv64 => sort_file::<Kv64>(input, output, cfg),
        Dtype::F32 => sort_file::<F32Key>(input, output, cfg),
    }
}

/// [`sort_file_dtype`] recording spans into a caller-owned [`Trace`]
/// (see [`sort_stream_traced`]); never writes a trace file itself.
pub fn sort_file_dtype_traced(
    input: &Path,
    output: &Path,
    cfg: &ExternalConfig,
    dtype: Dtype,
    trace: &Trace,
) -> Result<SpillStats> {
    match dtype {
        Dtype::U32 => sort_file_traced::<u32>(input, output, cfg, trace),
        Dtype::U64 => sort_file_traced::<u64>(input, output, cfg, trace),
        Dtype::I32 => sort_file_traced::<i32>(input, output, cfg, trace),
        Dtype::I64 => sort_file_traced::<i64>(input, output, cfg, trace),
        Dtype::Kv => sort_file_traced::<Kv>(input, output, cfg, trace),
        Dtype::Kv64 => sort_file_traced::<Kv64>(input, output, cfg, trace),
        Dtype::F32 => sort_file_traced::<F32Key>(input, output, cfg, trace),
    }
}

/// [`sort_file_traced`] under an explicit [`SortCtx`] and optional
/// shared [`WriterPool`] (see [`sort_stream_ctx`]). On any error —
/// including cancellation — the partially written `output` file is
/// removed, so a cancelled job leaves nothing behind.
pub fn sort_file_ctx<T: ExtItem>(
    input: &Path,
    output: &Path,
    cfg: &ExternalConfig,
    ctx: &SortCtx,
    shared_pool: Option<&WriterPool>,
    trace: &Trace,
) -> Result<SpillStats> {
    let same_file = input == output
        || match (input.canonicalize(), output.canonicalize()) {
            (Ok(a), Ok(b)) => a == b,
            _ => false, // output usually doesn't exist yet
        };
    if same_file {
        return Err(anyhow!(
            "refusing to sort {} in place: output would truncate the input (pick a different --output)",
            input.display()
        ));
    }
    let run = || -> Result<SpillStats> {
        let mut src = RawReader::<T>::open(input)?;
        let writer =
            RawWriter::<T>::create(output)?.with_fault(output_injector(cfg, output, trace));
        let mut sink = DoubleBufWriter::spawn(writer, 2)?;
        let stats = sort_stream_ctx(&mut src, &mut sink, cfg, ctx, shared_pool, trace)?;
        let written = sink.finish()?.finish()?;
        debug_assert_eq!(written, stats.elements);
        Ok(stats)
    };
    let res = run();
    if res.is_err() {
        let _ = std::fs::remove_file(output);
    }
    res
}

/// [`sort_file_ctx`] dispatched over a runtime [`Dtype`] — what the
/// router's job closures call.
pub fn sort_file_dtype_ctx(
    input: &Path,
    output: &Path,
    cfg: &ExternalConfig,
    dtype: Dtype,
    ctx: &SortCtx,
    shared_pool: Option<&WriterPool>,
    trace: &Trace,
) -> Result<SpillStats> {
    match dtype {
        Dtype::U32 => sort_file_ctx::<u32>(input, output, cfg, ctx, shared_pool, trace),
        Dtype::U64 => sort_file_ctx::<u64>(input, output, cfg, ctx, shared_pool, trace),
        Dtype::I32 => sort_file_ctx::<i32>(input, output, cfg, ctx, shared_pool, trace),
        Dtype::I64 => sort_file_ctx::<i64>(input, output, cfg, ctx, shared_pool, trace),
        Dtype::Kv => sort_file_ctx::<Kv>(input, output, cfg, ctx, shared_pool, trace),
        Dtype::Kv64 => sort_file_ctx::<Kv64>(input, output, cfg, ctx, shared_pool, trace),
        Dtype::F32 => sort_file_ctx::<F32Key>(input, output, cfg, ctx, shared_pool, trace),
    }
}

/// Sort an in-memory vector through the external pipeline (descending).
/// Exists for the service's `Backend::External` route and for tests.
/// Inputs that fit a single run skip the spill machinery entirely — one
/// in-memory sort, no run file round-trip — and report `runs_spilled = 0`.
pub fn sort_vec<T: ExtItem>(data: &[T], cfg: &ExternalConfig) -> Result<(Vec<T>, SpillStats)> {
    sort_vec_ctx(data, cfg, &SortCtx::default(), None)
}

/// [`sort_vec`] under an explicit [`SortCtx`] and optional shared
/// [`WriterPool`] (see [`sort_stream_ctx`]). The single-run fast path
/// is identical — it touches no spill machinery, so there is nothing
/// to cancel or report mid-flight.
pub fn sort_vec_ctx<T: ExtItem>(
    data: &[T],
    cfg: &ExternalConfig,
    ctx: &SortCtx,
    shared_pool: Option<&WriterPool>,
) -> Result<(Vec<T>, SpillStats)> {
    cfg.validate().map_err(|e| anyhow!("{e}"))?;
    if data.len() <= cfg.run_elems_for(T::WIRE_BYTES) {
        let t = Instant::now();
        let mut out = data.to_vec();
        T::sort_run(&mut out, cfg.sort_config(), cfg.kernel);
        let us = t.elapsed().as_micros() as u64;
        let stats = SpillStats {
            elements: data.len() as u64,
            phase1_us: us,
            wall_us: us,
            ..Default::default()
        };
        return Ok((out, stats));
    }
    let trace = cfg.make_trace();
    let mut src = SliceSource::new(data);
    let mut out = Vec::with_capacity(data.len());
    let stats = sort_stream_ctx(&mut src, &mut out, cfg, ctx, shared_pool, &trace)?;
    if let Some(dir) = &cfg.trace_dir {
        obs::chrome::write_auto(&trace, dir);
    }
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_kv, gen_u32, Distribution};
    use crate::key::is_sorted_desc;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ExternalConfig {
        ExternalConfig {
            mem_budget_bytes: 4096, // 1024-element u32 runs
            fan_in: 4,
            ..Default::default()
        }
    }

    #[test]
    fn sort_vec_multi_pass_matches_std() {
        // 20k elements / 1024-run budget → 20 runs → multiple passes at
        // fan-in 4.
        let mut rng = Rng::new(101);
        let data = gen_u32(&mut rng, 20_000, Distribution::Uniform);
        let (got, stats) = sort_vec(&data, &tiny_cfg()).unwrap();
        let mut expect = data.clone();
        expect.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(got, expect);
        assert_eq!(stats.elements, 20_000);
        // 20 → 5 → 2 → sink; pass 2 merges one chunk of 4 and carries
        // the fifth run forward free (prefix-stable chunked plan).
        assert_eq!(stats.runs_spilled, 20 + 5 + 1);
        assert_eq!(stats.merge_passes, 3);
        assert!(stats.bytes_spilled >= 20_000 * 4);
        assert!(stats.wall_us > 0);
    }

    #[test]
    fn overlap_schedule_matches_serial_exactly() {
        // Same input, same config, overlap on vs off: identical sorted
        // output AND identical spill layout (runs, passes, bytes) —
        // only the wall-clock schedule may differ. Multi-pass workload
        // (20 runs ≫ fan-in 4), serial and parallel, all three codecs.
        let mut rng = Rng::new(109);
        let data = gen_u32(&mut rng, 20_000, Distribution::Uniform);
        for threads in [1usize, 4] {
            for codec in [Codec::Raw, Codec::Delta, Codec::Flr3] {
                let off = ExternalConfig {
                    overlap: false,
                    threads,
                    codec,
                    ..tiny_cfg()
                };
                let on = ExternalConfig { overlap: true, ..off.clone() };
                let (serial, serial_stats) = sort_vec(&data, &off).unwrap();
                let (piped, piped_stats) = sort_vec(&data, &on).unwrap();
                assert_eq!(piped, serial, "threads={threads} {codec:?}");
                assert_eq!(piped_stats.elements, serial_stats.elements);
                assert_eq!(piped_stats.runs_spilled, serial_stats.runs_spilled);
                assert_eq!(piped_stats.merge_passes, serial_stats.merge_passes);
                assert_eq!(piped_stats.bytes_spilled, serial_stats.bytes_spilled);
                assert_eq!(
                    piped_stats.bytes_spilled_raw,
                    serial_stats.bytes_spilled_raw
                );
                // The serial schedule by definition has no overlap.
                assert_eq!(serial_stats.overlap_us, 0, "threads={threads} {codec:?}");
                assert!(piped_stats.wall_us > 0);
            }
        }
    }

    #[test]
    fn overlap_parse_spellings() {
        for (s, v) in [
            ("on", true),
            ("off", false),
            ("true", true),
            ("false", false),
            // Env vars get typed by humans: case and whitespace forgiven.
            ("ON", true),
            ("Off", false),
            (" on ", true),
            ("1", true),
            ("0", false),
        ] {
            assert_eq!(parse_overlap(s).unwrap(), v, "{s:?}");
        }
        let err = parse_overlap("sideways").unwrap_err();
        assert!(err.contains("unknown overlap value"), "{err}");
    }

    #[test]
    fn delta_codec_sorts_identically_and_compresses_sorted_input() {
        // Nearly-sorted input → tiny deltas → real compression; the
        // sorted output must be exactly what the raw codec produces.
        let data: Vec<u32> = (0..20_000u32).map(|i| i.wrapping_mul(7) % 30_000).collect();
        let (raw_out, raw_stats) = sort_vec(&data, &tiny_cfg()).unwrap();
        let cfg = ExternalConfig { codec: Codec::Delta, ..tiny_cfg() };
        let (delta_out, delta_stats) = sort_vec(&data, &cfg).unwrap();
        assert_eq!(delta_out, raw_out);
        assert_eq!(delta_stats.runs_spilled, raw_stats.runs_spilled);
        assert_eq!(delta_stats.merge_passes, raw_stats.merge_passes);
        // Raw accounting matches the raw codec's actual bytes…
        assert_eq!(delta_stats.bytes_spilled_raw, raw_stats.bytes_spilled);
        assert_eq!(raw_stats.bytes_spilled_raw, raw_stats.bytes_spilled);
        // …and the encoded bytes beat them on this key range (runs of
        // 1024 keys from a 30k space: ~2-byte varints vs 4-byte raw).
        assert!(
            delta_stats.bytes_spilled < raw_stats.bytes_spilled,
            "delta {} vs raw {}",
            delta_stats.bytes_spilled,
            raw_stats.bytes_spilled
        );
        assert!(delta_stats.codec_encode_us > 0 || delta_stats.bytes_spilled == 0);
    }

    #[test]
    fn delta_codec_matches_raw_for_every_dtype_and_thread_count() {
        use crate::data::gen_u64;
        let dir = std::env::temp_dir().join(format!("flims-codec-eq-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(108);

        fn case<T: ExtItem + PartialEq>(dir: &std::path::Path, data: &[T]) {
            let base = ExternalConfig {
                mem_budget_bytes: 4096 * T::WIRE_BYTES / 4,
                fan_in: 4,
                tmp_dir: Some(dir.to_path_buf()),
                ..Default::default()
            };
            let (raw_out, _) = sort_vec(data, &base).unwrap();
            for threads in [1usize, 4] {
                let cfg =
                    ExternalConfig { codec: Codec::Delta, threads, ..base.clone() };
                let (delta_out, _) = sort_vec(data, &cfg).unwrap();
                assert!(
                    delta_out == raw_out,
                    "{:?} threads={threads}: delta output differs from raw",
                    T::DTYPE
                );
            }
        }

        case::<u32>(&dir, &gen_u32(&mut rng, 9000, Distribution::Uniform));
        let zipf = Distribution::Zipf { s_x100: 150, n_ranks: 64 };
        case::<u64>(&dir, &gen_u64(&mut rng, 9000, zipf));
        case::<i32>(&dir, &crate::data::gen_i32(&mut rng, 9000, Distribution::Uniform));
        case::<i64>(&dir, &crate::data::gen_i64(&mut rng, 9000, zipf));
        case::<crate::key::Kv>(
            &dir,
            &gen_kv(&mut rng, 9000, Distribution::DupHeavy { alphabet: 5 }),
        );
        case::<crate::key::Kv64>(
            &dir,
            &gen_u64(&mut rng, 9000, Distribution::Uniform)
                .into_iter()
                .enumerate()
                .map(|(i, key)| crate::key::Kv64 { key, val: i as u64 })
                .collect::<Vec<_>>(),
        );
        // f32 falls back to raw silently: same output, same bytes.
        let f32s: Vec<crate::key::F32Key> = gen_u32(&mut rng, 9000, Distribution::Uniform)
            .into_iter()
            .map(|x| crate::key::F32Key::from_f32(x as f32 - 1e9))
            .collect();
        case::<crate::key::F32Key>(&dir, &f32s);
        let cfg = ExternalConfig {
            mem_budget_bytes: 4096,
            fan_in: 4,
            codec: Codec::Delta,
            tmp_dir: Some(dir.clone()),
            ..Default::default()
        };
        let (_, stats) = sort_vec(&f32s, &cfg).unwrap();
        assert_eq!(
            stats.bytes_spilled, stats.bytes_spilled_raw,
            "f32 must fall back to the raw codec"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn flr3_codec_matches_raw_and_falls_back_per_dtype() {
        use crate::data::gen_u64;
        let dir = std::env::temp_dir().join(format!("flims-flr3-eq-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(111);

        fn case<T: ExtItem + PartialEq>(dir: &std::path::Path, data: &[T]) {
            let base = ExternalConfig {
                mem_budget_bytes: 4096 * T::WIRE_BYTES / 4,
                fan_in: 4,
                tmp_dir: Some(dir.to_path_buf()),
                ..Default::default()
            };
            let (raw_out, _) = sort_vec(data, &base).unwrap();
            for threads in [1usize, 4] {
                let cfg = ExternalConfig { codec: Codec::Flr3, threads, ..base.clone() };
                let (flr3_out, _) = sort_vec(data, &cfg).unwrap();
                assert!(
                    flr3_out == raw_out,
                    "{:?} threads={threads}: flr3 output differs from raw",
                    T::DTYPE
                );
            }
        }

        // Key-only dtypes take the real FLR3 path; kv/kv64 fall back to
        // delta and f32 to raw — all must sort identically regardless.
        case::<u32>(&dir, &gen_u32(&mut rng, 9000, Distribution::Uniform));
        let zipf = Distribution::Zipf { s_x100: 150, n_ranks: 64 };
        case::<u64>(&dir, &gen_u64(&mut rng, 9000, zipf));
        case::<i32>(&dir, &crate::data::gen_i32(&mut rng, 9000, zipf));
        case::<i64>(&dir, &crate::data::gen_i64(&mut rng, 9000, Distribution::Uniform));
        case::<crate::key::Kv>(
            &dir,
            &gen_kv(&mut rng, 9000, Distribution::DupHeavy { alphabet: 5 }),
        );
        let f32s: Vec<crate::key::F32Key> = gen_u32(&mut rng, 9000, Distribution::Uniform)
            .into_iter()
            .map(|x| crate::key::F32Key::from_f32(x as f32 - 1e9))
            .collect();
        case::<crate::key::F32Key>(&dir, &f32s);

        // Sorted-ish u32 keys → small per-block deltas → FLR3 beats raw.
        let near: Vec<u32> = (0..20_000u32).map(|i| i.wrapping_mul(7) % 30_000).collect();
        let cfg = ExternalConfig {
            mem_budget_bytes: 4096,
            fan_in: 4,
            codec: Codec::Flr3,
            tmp_dir: Some(dir.clone()),
            ..Default::default()
        };
        let (_, stats) = sort_vec(&near, &cfg).unwrap();
        assert!(
            stats.bytes_spilled < stats.bytes_spilled_raw,
            "flr3 {} vs raw {}",
            stats.bytes_spilled,
            stats.bytes_spilled_raw
        );
        assert!(stats.codec_encode_us > 0 || stats.bytes_spilled == 0);

        // f32 falls back to raw: byte accounting identical.
        let (_, f32_stats) = sort_vec(&f32s, &cfg).unwrap();
        assert_eq!(
            f32_stats.bytes_spilled, f32_stats.bytes_spilled_raw,
            "f32 must fall back to the raw codec under flr3"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn trace_dir_auto_writes_chrome_json() {
        let dir = std::env::temp_dir().join(format!("flims-tracedir-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = ExternalConfig { trace_dir: Some(dir.clone()), ..tiny_cfg() };
        let mut rng = Rng::new(110);
        let data = gen_u32(&mut rng, 10_000, Distribution::Uniform);
        let (got, _) = sort_vec(&data, &cfg).unwrap();
        assert!(is_sorted_desc(&got));
        let traces: Vec<_> =
            std::fs::read_dir(&dir).unwrap().map(|e| e.unwrap().path()).collect();
        assert_eq!(traces.len(), 1, "one sort, one trace file: {traces:?}");
        let json = std::fs::read_to_string(&traces[0]).unwrap();
        assert!(json.starts_with("{\"traceEvents\":["), "{json}");
        assert!(json.contains("\"name\":\"chunk_sort\""), "{json}");
        assert!(json.contains("\"name\":\"seal_run\""), "{json}");
        assert!(json.contains("\"name\":\"group_merge\""), "{json}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn parallel_sort_vec_matches_serial_exactly() {
        let mut rng = Rng::new(105);
        let data = gen_u32(&mut rng, 30_000, Distribution::Uniform);
        let (serial, serial_stats) = sort_vec(&data, &tiny_cfg()).unwrap();
        for threads in [2usize, 8] {
            for prefetch in [0usize, 3] {
                let cfg = ExternalConfig { threads, prefetch_blocks: prefetch, ..tiny_cfg() };
                let (got, stats) = sort_vec(&data, &cfg).unwrap();
                assert_eq!(got, serial, "threads={threads} prefetch={prefetch}");
                assert_eq!(stats.runs_spilled, serial_stats.runs_spilled);
                assert_eq!(stats.merge_passes, serial_stats.merge_passes);
                assert_eq!(stats.bytes_spilled, serial_stats.bytes_spilled);
            }
        }
    }

    #[test]
    fn prefetch_counters_account_for_leaf_blocks() {
        let mut rng = Rng::new(106);
        let data = gen_u32(&mut rng, 20_000, Distribution::Uniform);
        let cfg = ExternalConfig { prefetch_blocks: 2, ..tiny_cfg() };
        let (_, stats) = sort_vec(&data, &cfg).unwrap();
        assert!(
            stats.prefetch_hits + stats.prefetch_misses > 0,
            "prefetch leaves must serve blocks: {stats:?}"
        );
        let cfg = ExternalConfig { prefetch_blocks: 0, ..tiny_cfg() };
        let (_, stats) = sort_vec(&data, &cfg).unwrap();
        assert_eq!(stats.prefetch_hits + stats.prefetch_misses, 0, "prefetch disabled");
    }

    #[test]
    fn sort_vec_single_run_skips_spilling() {
        let mut rng = Rng::new(102);
        let data = gen_u32(&mut rng, 500, Distribution::Uniform);
        let (got, stats) = sort_vec(&data, &tiny_cfg()).unwrap();
        assert!(is_sorted_desc(&got));
        assert_eq!(got.len(), 500);
        let mut expect = data.clone();
        expect.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(got, expect);
        // Fast path: no run files, no merge passes, nothing spilled.
        assert_eq!(stats.runs_spilled, 0);
        assert_eq!(stats.merge_passes, 0);
        assert_eq!(stats.bytes_spilled, 0);
        assert_eq!(stats.elements, 500);
    }

    #[test]
    fn sort_vec_fast_path_is_stable_for_kv() {
        let mut rng = Rng::new(107);
        let data = gen_kv(&mut rng, 400, Distribution::DupHeavy { alphabet: 3 });
        let (got, stats) = sort_vec(&data, &tiny_cfg()).unwrap();
        assert_eq!(stats.runs_spilled, 0);
        let mut expect = data.clone();
        expect.sort_by(|a, b| b.key.cmp(&a.key)); // std stable sort
        assert_eq!(got, expect);
    }

    #[test]
    fn sort_vec_empty() {
        let (got, stats) = sort_vec::<u32>(&[], &tiny_cfg()).unwrap();
        assert!(got.is_empty());
        assert_eq!(stats.runs_spilled, 0);
        assert_eq!(stats.merge_passes, 0);
    }

    #[test]
    fn config_validation() {
        let mut cfg = ExternalConfig::default();
        assert!(cfg.validate().is_ok());
        cfg.fan_in = 1;
        assert!(cfg.validate().is_err());
        let mut cfg = ExternalConfig { mem_budget_bytes: 100, ..Default::default() };
        assert!(cfg.validate().is_err());
        cfg = ExternalConfig { w: 3, ..Default::default() };
        assert!(cfg.validate().is_err());
        cfg = ExternalConfig { chunk: 8, w: 16, ..Default::default() };
        assert!(cfg.validate().is_err());
        cfg = ExternalConfig { threads: 5000, ..Default::default() };
        assert!(cfg.validate().is_err());
        let err = ExternalConfig { prefetch_blocks: 4096, ..Default::default() }
            .validate()
            .unwrap_err();
        assert!(err.contains("external.prefetch_blocks = 4096 is absurd"), "{err}");
        cfg = ExternalConfig { prefetch_blocks: 1024, ..Default::default() };
        assert!(cfg.validate().is_ok(), "1024 is the inclusive bound");
        cfg = ExternalConfig { threads: 0, prefetch_blocks: 0, ..Default::default() };
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn spill_files_are_cleaned_up() {
        let dir = std::env::temp_dir().join(format!("flims-ext-clean-{}", std::process::id()));
        let cfg = ExternalConfig { tmp_dir: Some(dir.clone()), threads: 4, ..tiny_cfg() };
        let mut rng = Rng::new(103);
        let data = gen_u32(&mut rng, 10_000, Distribution::Uniform);
        let (got, _) = sort_vec(&data, &cfg).unwrap();
        assert!(is_sorted_desc(&got));
        let leftovers: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert!(leftovers.is_empty(), "spill files leaked: {leftovers:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn in_place_sort_is_refused_and_input_survives() {
        let dir = std::env::temp_dir().join(format!("flims-inplace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("data.u32");
        let data: Vec<u32> = (0..2000).collect();
        format::write_raw(&path, &data).unwrap();

        let err = format!("{:#}", sort_file::<u32>(&path, &path, &tiny_cfg()).unwrap_err());
        assert!(err.contains("in place"), "{err}");
        assert_eq!(format::read_raw::<u32>(&path).unwrap(), data, "input must be untouched");

        // Same file through a non-identical path spelling.
        let alias = dir.join(".").join("data.u32");
        let err = format!("{:#}", sort_file::<u32>(&path, &alias, &tiny_cfg()).unwrap_err());
        assert!(err.contains("in place"), "{err}");
        assert_eq!(format::read_raw::<u32>(&path).unwrap(), data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn cancelled_sort_unwinds_and_leaks_nothing() {
        let dir = std::env::temp_dir().join(format!("flims-cancel-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let input = dir.join("in.u32");
        let output = dir.join("out.u32");
        let mut rng = Rng::new(111);
        let data = gen_u32(&mut rng, 20_000, Distribution::Uniform);
        format::write_raw(&input, &data).unwrap();
        for overlap in [false, true] {
            let spill_dir = dir.join(format!("spill-{overlap}"));
            std::fs::create_dir_all(&spill_dir).unwrap();
            let cfg =
                ExternalConfig { overlap, tmp_dir: Some(spill_dir.clone()), ..tiny_cfg() };
            let ctx = SortCtx::default();
            ctx.cancel.cancel(); // cancelled before the first chunk
            let err = format!(
                "{:#}",
                sort_file_ctx::<u32>(&input, &output, &cfg, &ctx, None, &Trace::disabled())
                    .unwrap_err()
            );
            assert!(err.contains("cancel") || err.contains("abort"), "{err}");
            assert!(!output.exists(), "partial output must be removed on cancellation");
            let leftovers: Vec<_> = std::fs::read_dir(&spill_dir).unwrap().collect();
            assert!(leftovers.is_empty(), "overlap={overlap}: spill leaked: {leftovers:?}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn disk_budget_violation_errors_cleanly() {
        for threads in [1usize, 4] {
            let cfg = ExternalConfig {
                disk_budget_bytes: Some(1024), // far below the dataset
                threads,
                ..tiny_cfg()
            };
            let mut rng = Rng::new(104);
            let data = gen_u32(&mut rng, 10_000, Distribution::Uniform);
            let err = format!("{:#}", sort_vec(&data, &cfg).unwrap_err());
            assert!(err.contains("disk budget exceeded"), "threads={threads}: {err}");
        }
    }

    #[test]
    fn derived_sizes_are_sane() {
        let cfg = tiny_cfg();
        assert_eq!(cfg.run_elems_for(4), 1024);
        assert_eq!(cfg.run_elems_for(8), 512); // Kv records are twice as wide
        assert_eq!(cfg.block_elems_for(4), 64); // clamped to the minimum
        let big = ExternalConfig::default();
        assert_eq!(big.run_elems_for(4), 16 << 20);
        assert_eq!(big.block_elems_for(4), (16 << 20) / 64);
        assert_eq!(big.run_elems_for(16), 4 << 20);
        assert!(big.effective_threads() >= 1);
    }

    #[test]
    fn sort_file_dtype_dispatches_every_dtype() {
        let dir = std::env::temp_dir().join(format!("flims-dtype-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let cfg = ExternalConfig { tmp_dir: Some(dir.clone()), ..tiny_cfg() };
        for dtype in Dtype::ALL {
            let input = dir.join(format!("in.{}", dtype.name()));
            let output = dir.join(format!("out.{}", dtype.name()));
            // 600 records of `wire_bytes` each, from a shared byte soup.
            let n = 600usize;
            let bytes: Vec<u8> =
                (0..n * dtype.wire_bytes()).map(|i| (i as u32).wrapping_mul(2654435761) as u8).collect();
            std::fs::write(&input, &bytes).unwrap();
            let stats = sort_file_dtype(&input, &output, &cfg, dtype).unwrap();
            assert_eq!(stats.elements, n as u64, "{dtype:?}");
            assert_eq!(
                std::fs::metadata(&output).unwrap().len() as usize,
                n * dtype.wire_bytes(),
                "{dtype:?}"
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
