//! Out-of-core external sort: spill runs to disk, then k-way merge them
//! with trees of FLiMS 2-way mergers.
//!
//! The paper positions FLiMS inside "parallel merge trees to achieve
//! high-throughput sorting, where the resource utilisation of the merger
//! is critical for building large trees and internalising the workload"
//! (§1). This module is that use case for datasets larger than RAM,
//! in the classic two-phase external-sort shape (TopSort's phase
//! structure, Merge-Path-style safe splits at the nodes):
//!
//! 1. **Run generation** ([`run_gen`]): the input streams through a
//!    bounded buffer; each chunk is sorted by the in-memory FLiMS
//!    pipeline and spilled as a descending run ([`format::RunWriter`]).
//! 2. **k-way streaming merge** ([`merge`], [`stream`]): runs feed an
//!    HPMT-style binary tree of block-buffered FLiMS mergers
//!    (`flims::lanes::merge_desc_into` at every node). When the run
//!    count exceeds the configured fan-in, intermediate passes re-spill
//!    merged runs; the [`spill::SpillManager`] deletes consumed runs
//!    eagerly and enforces the disk budget.
//!
//! Datasets are headerless little-endian u32 files ([`format::RawReader`]);
//! output is the same format, descending. Resident memory stays within a
//! small constant factor of `mem_budget_bytes` regardless of input size.

pub mod format;
pub mod merge;
pub mod run_gen;
pub mod spill;
pub mod stream;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

pub use format::{RawReader, RawWriter, RunFile, RunReader, RunWriter};
pub use merge::{merge_runs, MergeOutcome, MergePlan, U32Sink};
pub use run_gen::{generate_runs, SliceSource, U32Source};
pub use spill::SpillManager;
pub use stream::{build_tree, MergeStream, ReaderStream, RunStream};

use crate::flims::sort::SortConfig;

/// Tuning for the external sort.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExternalConfig {
    /// Target resident memory for the sort (run buffer in phase 1, the
    /// merge-tree buffers in phase 2). Actual peak stays within a small
    /// constant factor.
    pub mem_budget_bytes: usize,
    /// Maximum runs merged by one tree; more runs ⇒ extra spill passes.
    pub fan_in: usize,
    /// FLiMS lane width for the in-memory sort and every tree node.
    pub w: usize,
    /// Sort-in-chunks run length for the in-memory sort.
    pub chunk: usize,
    /// Spill directory (`None` = fresh dir under the system temp dir).
    pub tmp_dir: Option<PathBuf>,
    /// Cap on live spill bytes (`None` = unlimited).
    pub disk_budget_bytes: Option<u64>,
}

impl Default for ExternalConfig {
    fn default() -> Self {
        ExternalConfig {
            mem_budget_bytes: 64 << 20,
            fan_in: 8,
            w: 16,
            chunk: 128,
            tmp_dir: None,
            disk_budget_bytes: None,
        }
    }
}

impl ExternalConfig {
    pub fn validate(&self) -> Result<(), String> {
        if self.mem_budget_bytes < 4096 {
            return Err(format!(
                "external.mem_budget_bytes = {} must be at least 4096",
                self.mem_budget_bytes
            ));
        }
        if self.fan_in < 2 {
            return Err(format!("external.fan_in = {} must be at least 2", self.fan_in));
        }
        SortConfig { w: self.w, chunk: self.chunk }.validate()
    }

    /// Elements per phase-1 run (the whole budget is one run buffer).
    pub fn run_elems(&self) -> usize {
        self.mem_budget_bytes / format::ELEM_BYTES
    }

    /// Elements per merge-tree block buffer: the budget divided across
    /// the tree's buffers (≈ 3 per node, ≤ 2·fan_in nodes, plus slack).
    pub fn block_elems(&self) -> usize {
        (self.run_elems() / (8 * self.fan_in)).max(64)
    }

    pub fn sort_config(&self) -> SortConfig {
        SortConfig { w: self.w, chunk: self.chunk }
    }
}

/// What an external sort did — surfaced through `metrics` by the
/// coordinator and printed by the CLI.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpillStats {
    /// Elements sorted (== input length).
    pub elements: u64,
    /// Runs written to disk (phase 1 + intermediate passes).
    pub runs_spilled: u64,
    /// Total bytes written to spill files.
    pub bytes_spilled: u64,
    /// Merge passes over the data (intermediate + final).
    pub merge_passes: u64,
    /// High-water mark of live spill bytes.
    pub peak_spill_bytes: u64,
}

/// Sort any [`U32Source`] into any [`U32Sink`] with bounded memory.
pub fn sort_stream(
    src: &mut dyn U32Source,
    sink: &mut dyn U32Sink,
    cfg: &ExternalConfig,
) -> Result<SpillStats> {
    cfg.validate().map_err(|e| anyhow!("{e}"))?;
    let mut spill = SpillManager::new(cfg.tmp_dir.clone(), cfg.disk_budget_bytes)?;
    let runs = generate_runs(src, cfg, &mut spill)?;
    let input_elems: u64 = runs.iter().map(|r| r.elems).sum();
    let outcome = merge_runs(runs, cfg, &mut spill, sink)?;
    if outcome.elements != input_elems {
        return Err(anyhow!(
            "external sort corrupted: {} elements in, {} out",
            input_elems,
            outcome.elements
        ));
    }
    Ok(SpillStats {
        elements: outcome.elements,
        runs_spilled: spill.runs_created(),
        bytes_spilled: spill.bytes_written(),
        merge_passes: outcome.merge_passes,
        peak_spill_bytes: spill.peak_live_bytes(),
    })
}

/// Sort the raw-u32 dataset at `input` into `output` (descending),
/// spilling through temp files; resident memory is bounded by the
/// configured budget, not the dataset size. `output` must be a
/// different file — creating it truncates, so sorting in place would
/// destroy the input before it was read.
pub fn sort_file(input: &Path, output: &Path, cfg: &ExternalConfig) -> Result<SpillStats> {
    let same_file = input == output
        || match (input.canonicalize(), output.canonicalize()) {
            (Ok(a), Ok(b)) => a == b,
            _ => false, // output usually doesn't exist yet
        };
    if same_file {
        return Err(anyhow!(
            "refusing to sort {} in place: output would truncate the input (pick a different --output)",
            input.display()
        ));
    }
    let mut src = RawReader::open(input)?;
    let mut sink = RawWriter::create(output)?;
    let stats = sort_stream(&mut src, &mut sink, cfg)?;
    let written = sink.finish()?;
    debug_assert_eq!(written, stats.elements);
    Ok(stats)
}

/// Sort an in-memory vector through the external pipeline (descending).
/// Exists for the service's `Backend::External` route and for tests —
/// the data still round-trips through spill files.
pub fn sort_vec(data: &[u32], cfg: &ExternalConfig) -> Result<(Vec<u32>, SpillStats)> {
    let mut src = SliceSource::new(data);
    let mut out = Vec::with_capacity(data.len());
    let stats = sort_stream(&mut src, &mut out, cfg)?;
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_u32, Distribution};
    use crate::key::is_sorted_desc;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ExternalConfig {
        ExternalConfig {
            mem_budget_bytes: 4096, // 1024-element runs
            fan_in: 4,
            ..Default::default()
        }
    }

    #[test]
    fn sort_vec_multi_pass_matches_std() {
        // 20k elements / 1024-run budget → 20 runs → multiple passes at
        // fan-in 4.
        let mut rng = Rng::new(101);
        let data = gen_u32(&mut rng, 20_000, Distribution::Uniform);
        let (got, stats) = sort_vec(&data, &tiny_cfg()).unwrap();
        let mut expect = data.clone();
        expect.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(got, expect);
        assert_eq!(stats.elements, 20_000);
        assert_eq!(stats.runs_spilled, 20 + 5 + 2); // 20 → 5 → 2 → sink
        assert_eq!(stats.merge_passes, 3);
        assert!(stats.bytes_spilled >= 20_000 * 4);
    }

    #[test]
    fn sort_vec_single_run() {
        let mut rng = Rng::new(102);
        let data = gen_u32(&mut rng, 500, Distribution::Uniform);
        let (got, stats) = sort_vec(&data, &tiny_cfg()).unwrap();
        assert!(is_sorted_desc(&got));
        assert_eq!(got.len(), 500);
        assert_eq!(stats.runs_spilled, 1);
        assert_eq!(stats.merge_passes, 1);
    }

    #[test]
    fn sort_vec_empty() {
        let (got, stats) = sort_vec(&[], &tiny_cfg()).unwrap();
        assert!(got.is_empty());
        assert_eq!(stats.runs_spilled, 0);
        assert_eq!(stats.merge_passes, 0);
    }

    #[test]
    fn config_validation() {
        let mut cfg = ExternalConfig::default();
        assert!(cfg.validate().is_ok());
        cfg.fan_in = 1;
        assert!(cfg.validate().is_err());
        let mut cfg = ExternalConfig { mem_budget_bytes: 100, ..Default::default() };
        assert!(cfg.validate().is_err());
        cfg = ExternalConfig { w: 3, ..Default::default() };
        assert!(cfg.validate().is_err());
        cfg = ExternalConfig { chunk: 8, w: 16, ..Default::default() };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn spill_files_are_cleaned_up() {
        let dir = std::env::temp_dir().join(format!("flims-ext-clean-{}", std::process::id()));
        let cfg = ExternalConfig { tmp_dir: Some(dir.clone()), ..tiny_cfg() };
        let mut rng = Rng::new(103);
        let data = gen_u32(&mut rng, 10_000, Distribution::Uniform);
        let (got, _) = sort_vec(&data, &cfg).unwrap();
        assert!(is_sorted_desc(&got));
        let leftovers: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert!(leftovers.is_empty(), "spill files leaked: {leftovers:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn in_place_sort_is_refused_and_input_survives() {
        let dir = std::env::temp_dir().join(format!("flims-inplace-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("data.u32");
        let data: Vec<u32> = (0..2000).collect();
        format::write_raw(&path, &data).unwrap();

        let err = format!("{:#}", sort_file(&path, &path, &tiny_cfg()).unwrap_err());
        assert!(err.contains("in place"), "{err}");
        assert_eq!(format::read_raw(&path).unwrap(), data, "input must be untouched");

        // Same file through a non-identical path spelling.
        let alias = dir.join(".").join("data.u32");
        let err = format!("{:#}", sort_file(&path, &alias, &tiny_cfg()).unwrap_err());
        assert!(err.contains("in place"), "{err}");
        assert_eq!(format::read_raw(&path).unwrap(), data);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn disk_budget_violation_errors_cleanly() {
        let cfg = ExternalConfig {
            disk_budget_bytes: Some(1024), // far below the dataset
            ..tiny_cfg()
        };
        let mut rng = Rng::new(104);
        let data = gen_u32(&mut rng, 10_000, Distribution::Uniform);
        let err = format!("{:#}", sort_vec(&data, &cfg).unwrap_err());
        assert!(err.contains("disk budget exceeded"), "{err}");
    }

    #[test]
    fn derived_sizes_are_sane() {
        let cfg = tiny_cfg();
        assert_eq!(cfg.run_elems(), 1024);
        assert_eq!(cfg.block_elems(), 64); // clamped to the minimum
        let big = ExternalConfig::default();
        assert_eq!(big.run_elems(), 16 << 20);
        assert_eq!(big.block_elems(), (16 << 20) / 64);
    }
}
