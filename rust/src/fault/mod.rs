//! Deterministic fault injection and I/O recovery for the external sorter.
//!
//! Production sorts run for minutes across thousands of spill-file
//! operations; a single transient `EINTR`, a full disk, or a torn write
//! must not cost the whole job. This module provides both halves of that
//! story:
//!
//! * **Injection** — a seeded, deterministic fault plan ([`FaultSpec`])
//!   that wraps every spill-I/O seam (run create / write / seal, block
//!   read, run delete, output sink) behind per-site [`Injector`] handles.
//!   Faults are injected *before* the real syscall runs (fail-before-op),
//!   so a retried operation re-executes from clean state and recovery is
//!   byte-identical by construction. The plan comes from the `[fault]`
//!   config section, the `FLIMS_FAULTS=seed:rate:kinds` env var, the
//!   `faults=` protocol token, or the `--faults` CLI flag (see
//!   `docs/ROBUSTNESS.md` for the grammar).
//! * **Recovery** — bounded exponential-backoff retry ([`with_retry`])
//!   for transient I/O errors, injected or real, plus process-wide
//!   counters (`flims_io_retries_total`, `flims_faults_injected_total`,
//!   `flims_jobs_degraded_total`) surfaced through the `metrics` verb.
//!
//! Determinism: each injector derives an independent decision stream from
//! `mix(plan.seed, hash(site))` where `site` is the spill file name. Run
//! files are named in input order regardless of worker count
//! (`run-000042.flr`), so the same seed and plan produce the same fault
//! sequence at every thread count and overlap mode.
//!
//! Zero overhead when disabled: a disabled [`Injector`] is a `None` — one
//! null check per seam crossing, no clock reads, no heap traffic (pinned
//! by the counting-allocator test in `tests/fault_alloc.rs`).

use std::io;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::obs::{SpanKind, Trace};

/// Bitmask flag: transient I/O errors (`EINTR`-class), recovered by retry.
pub const KIND_TRANSIENT: u8 = 1;
/// Bitmask flag: disk-full (`ENOSPC`) errors, surfaced to the caller.
pub const KIND_DISK_FULL: u8 = 2;
/// Bitmask flag: short reads/writes. Injected fail-before-op, these are
/// transient-class: the caller re-issues the whole operation.
pub const KIND_SHORT_IO: u8 = 4;
/// Bitmask flag: latency stalls — the operation succeeds after a small
/// deterministic delay (recorded as a [`SpanKind::FaultStall`] span).
pub const KIND_STALL: u8 = 8;
/// All fault kinds.
pub const KIND_ALL: u8 = KIND_TRANSIENT | KIND_DISK_FULL | KIND_SHORT_IO | KIND_STALL;

/// Retries per operation after the first attempt (4 attempts total).
pub const MAX_RETRIES: u32 = 3;

/// How long an injected stall sleeps.
const STALL_DELAY: Duration = Duration::from_micros(200);

/// A seeded fault-injection plan: pure configuration data, carried in
/// [`crate::ExternalConfig::fault`] and materialized into per-site
/// [`Injector`]s at each I/O seam.
///
/// `rate_ppm` is the per-operation fault probability in parts-per-million
/// (so the decision is a single integer compare, no floats on the hot
/// path); `kinds` is a bitmask of the `KIND_*` flags.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    /// Seed for the deterministic decision streams.
    pub seed: u64,
    /// Per-operation fault probability, parts-per-million (0..=1_000_000).
    pub rate_ppm: u32,
    /// Bitmask of `KIND_*` flags eligible for injection.
    pub kinds: u8,
}

impl FaultSpec {
    /// True when this plan can ever fire a fault.
    pub fn is_active(&self) -> bool {
        self.rate_ppm > 0 && self.kinds != 0
    }
}

/// Parse a fault-plan argument in the `seed:rate:kinds` grammar shared by
/// the `FLIMS_FAULTS` env var, the `[fault] plan` config key, the
/// `faults=` protocol token, and the `--faults` CLI flag.
///
/// * `seed` — u64 decimal.
/// * `rate` — per-operation fault probability as a float in `[0, 1]`.
/// * `kinds` — comma-separated subset of
///   `transient`, `enospc`, `short`, `stall`, or `all`.
///
/// `off` / `none` / the empty string parse to `None` (faults disabled),
/// so a per-request `faults=off` can override an env-level plan.
///
/// ```
/// use flims::fault::{parse_faults_arg, KIND_STALL, KIND_TRANSIENT};
/// let spec = parse_faults_arg("7:0.002:transient,stall").unwrap().unwrap();
/// assert_eq!(spec.seed, 7);
/// assert_eq!(spec.rate_ppm, 2000);
/// assert_eq!(spec.kinds, KIND_TRANSIENT | KIND_STALL);
/// assert!(parse_faults_arg("off").unwrap().is_none());
/// assert!(parse_faults_arg("1:2.5:all").is_err());
/// ```
pub fn parse_faults_arg(s: &str) -> Result<Option<FaultSpec>, String> {
    let s = s.trim();
    if s.is_empty() || s.eq_ignore_ascii_case("off") || s.eq_ignore_ascii_case("none") {
        return Ok(None);
    }
    let mut parts = s.splitn(3, ':');
    let (seed, rate, kinds) = match (parts.next(), parts.next(), parts.next()) {
        (Some(a), Some(b), Some(c)) => (a.trim(), b.trim(), c.trim()),
        _ => return Err(format!("expected <seed>:<rate>:<kinds>, got \"{s}\"")),
    };
    let seed: u64 = seed.parse().map_err(|_| format!("bad seed \"{seed}\" (want u64)"))?;
    let rate: f64 = rate.parse().map_err(|_| format!("bad rate \"{rate}\" (want float)"))?;
    if !rate.is_finite() || !(0.0..=1.0).contains(&rate) {
        return Err(format!("rate {rate} out of [0, 1]"));
    }
    let rate_ppm = (rate * 1_000_000.0).round() as u32;
    let mut mask = 0u8;
    for kind in kinds.split(',') {
        mask |= match kind.trim() {
            "transient" => KIND_TRANSIENT,
            "enospc" | "disk_full" => KIND_DISK_FULL,
            "short" => KIND_SHORT_IO,
            "stall" => KIND_STALL,
            "all" => KIND_ALL,
            other => {
                return Err(format!(
                    "unknown fault kind \"{other}\" (want transient|enospc|short|stall|all)"
                ))
            }
        };
    }
    Ok(Some(FaultSpec { seed, rate_ppm, kinds: mask }))
}

/// Which I/O seam an injector decision applies to. Mixed into each draw
/// so distinct operations at the same site see independent decisions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Op {
    /// Creating a run / output file.
    Create = 1,
    /// Writing an encoded block (or the output sink).
    Write = 2,
    /// Sealing a finished run (flush + header count rewrite).
    Seal = 3,
    /// Opening or reading a run block.
    Read = 4,
    /// Deleting a consumed run.
    Delete = 5,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Transient,
    DiskFull,
    ShortIo,
    Stall,
}

#[inline]
fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// FNV-1a over the site name; stable across platforms and runs.
fn hash_site(site: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in site.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[derive(Debug)]
struct InjectorState {
    spec: FaultSpec,
    /// Per-site stream base: `mix(seed, hash(site))`.
    stream: u64,
    /// Draws taken so far; the counter makes each decision a pure
    /// function of `(seed, site, draw index, op)`.
    draws: u64,
    trace: Trace,
}

impl InjectorState {
    fn decide(&mut self, op: Op) -> Option<Kind> {
        self.draws = self.draws.wrapping_add(1);
        let r = splitmix64(self.stream ^ self.draws.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ ((op as u64) << 56));
        if (r % 1_000_000) as u32 >= self.spec.rate_ppm {
            return None;
        }
        // Fault fires: pick deterministically among the enabled kinds.
        let mut enabled = [Kind::Transient; 4];
        let mut n = 0usize;
        for (flag, kind) in [
            (KIND_TRANSIENT, Kind::Transient),
            (KIND_DISK_FULL, Kind::DiskFull),
            (KIND_SHORT_IO, Kind::ShortIo),
            (KIND_STALL, Kind::Stall),
        ] {
            if self.spec.kinds & flag != 0 {
                enabled[n] = kind;
                n += 1;
            }
        }
        if n == 0 {
            return None;
        }
        Some(enabled[((r >> 32) % n as u64) as usize])
    }
}

/// A per-site fault-injection handle, owned by each run writer, run
/// reader, output sink, or delete seam. Disabled (the default and the
/// production configuration) it is a `None`: every seam crossing costs
/// one null check and nothing else.
///
/// Decisions advance through `&mut self` — no locks, no allocation — and
/// are a pure function of `(plan seed, site name, draw index, op)`, so a
/// given file's fault sequence is reproducible at any thread count.
#[derive(Debug, Default)]
pub struct Injector(Option<InjectorState>);

impl Injector {
    /// An injector that never fires. This is `const`, so embedding a
    /// disabled injector in a struct costs nothing at runtime.
    pub const fn disabled() -> Self {
        Injector(None)
    }

    /// Materialize an injector for one I/O site (a spill file name). With
    /// `spec == None` this is [`Injector::disabled`]. `trace` receives
    /// [`SpanKind::IoRetry`] / [`SpanKind::FaultStall`] spans when the
    /// sort is traced; pass `&Trace::disabled()` where no trace exists.
    pub fn for_site(spec: Option<FaultSpec>, site: &str, trace: &Trace) -> Self {
        match spec {
            None => Injector(None),
            Some(spec) => Injector(Some(InjectorState {
                spec,
                stream: splitmix64(spec.seed ^ hash_site(site)),
                draws: 0,
                trace: trace.clone(),
            })),
        }
    }

    /// True when a plan is attached (even at rate 0).
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// The fail-before-op injection point: call immediately before the
    /// real operation. Transient-class faults are retried internally with
    /// the same bounded backoff as real errors (each retry re-draws, so a
    /// low-rate plan recovers almost surely); disk-full surfaces
    /// immediately; a stall sleeps [`STALL_DELAY`] and then lets the real
    /// operation proceed.
    #[inline]
    pub fn checkpoint(&mut self, op: Op) -> io::Result<()> {
        let st = match &mut self.0 {
            None => return Ok(()),
            Some(st) => st,
        };
        let mut attempt = 0u32;
        loop {
            match st.decide(op) {
                None => return Ok(()),
                Some(Kind::Stall) => {
                    FAULTS_INJECTED.fetch_add(1, Ordering::Relaxed);
                    let t0 = Instant::now();
                    std::thread::sleep(STALL_DELAY);
                    let ns = t0.elapsed().as_nanos() as u64;
                    st.trace.record_dur(SpanKind::FaultStall, t0, ns, op as u64);
                    return Ok(());
                }
                Some(Kind::DiskFull) => {
                    FAULTS_INJECTED.fetch_add(1, Ordering::Relaxed);
                    return Err(disk_full_error());
                }
                Some(kind @ (Kind::Transient | Kind::ShortIo)) => {
                    FAULTS_INJECTED.fetch_add(1, Ordering::Relaxed);
                    let err = match kind {
                        Kind::ShortIo => io::Error::new(
                            io::ErrorKind::Interrupted,
                            "injected short I/O (partial transfer)",
                        ),
                        _ => io::Error::new(
                            io::ErrorKind::Interrupted,
                            "injected transient I/O fault",
                        ),
                    };
                    if attempt >= MAX_RETRIES {
                        return Err(err);
                    }
                    attempt += 1;
                    IO_RETRIES.fetch_add(1, Ordering::Relaxed);
                    let t0 = Instant::now();
                    std::thread::sleep(backoff(attempt));
                    let ns = t0.elapsed().as_nanos() as u64;
                    st.trace.record_dur(SpanKind::IoRetry, t0, ns, attempt as u64);
                }
            }
        }
    }

    fn record_retry(&self, t0: Instant, attempt: u32) {
        if let Some(st) = &self.0 {
            let ns = t0.elapsed().as_nanos() as u64;
            st.trace.record_dur(SpanKind::IoRetry, t0, ns, attempt as u64);
        }
    }
}

/// Bounded exponential backoff: 250 µs, 500 µs, 1 ms, ...
fn backoff(attempt: u32) -> Duration {
    Duration::from_micros(125u64 << attempt.min(6))
}

/// Run `f` with fail-before-op injection and bounded exponential-backoff
/// retry of transient errors (injected or real). The retry loop
/// re-executes `f` from scratch, which is safe at every seam this crate
/// wraps because faults fire *before* the underlying syscall mutates
/// state. Non-transient errors surface on the first occurrence.
#[inline]
pub fn with_retry<T>(
    inj: &mut Injector,
    op: Op,
    mut f: impl FnMut() -> io::Result<T>,
) -> io::Result<T> {
    inj.checkpoint(op)?;
    let mut attempt = 0u32;
    loop {
        match f() {
            Ok(v) => return Ok(v),
            Err(e) if attempt < MAX_RETRIES && io_error_is_transient(&e) => {
                attempt += 1;
                IO_RETRIES.fetch_add(1, Ordering::Relaxed);
                let t0 = Instant::now();
                std::thread::sleep(backoff(attempt));
                inj.record_retry(t0, attempt);
            }
            Err(e) => return Err(e),
        }
    }
}

/// The error an injected `ENOSPC` fault produces (a real `ENOSPC` on
/// unix, a tagged error elsewhere).
fn disk_full_error() -> io::Error {
    #[cfg(unix)]
    {
        io::Error::from_raw_os_error(28) // ENOSPC
    }
    #[cfg(not(unix))]
    {
        io::Error::new(io::ErrorKind::Other, "injected disk full (ENOSPC)")
    }
}

/// True for transient (retryable) I/O errors: `EINTR`-class interruptions,
/// which covers both real interrupted syscalls and every injected
/// transient/short fault.
pub fn io_error_is_transient(e: &io::Error) -> bool {
    e.kind() == io::ErrorKind::Interrupted
}

/// True when an I/O error means the disk is out of space (real or
/// injected `ENOSPC`).
pub fn io_error_is_disk_full(e: &io::Error) -> bool {
    e.raw_os_error() == Some(28) || e.to_string().contains("injected disk full")
}

/// True when a job-level failure is transient at heart — its error chain
/// bottoms out in an interrupted I/O operation (injected or real). The
/// `[server] job_retries` policy re-admits such jobs.
pub fn error_is_transient(err: &anyhow::Error) -> bool {
    if let Some(src) = err.source() {
        if let Some(ioe) = src.downcast_ref::<io::Error>() {
            if io_error_is_transient(ioe) {
                return true;
            }
        }
    }
    let rendered = format!("{err:#}");
    rendered.contains("injected transient") || rendered.contains("injected short")
}

static IO_RETRIES: AtomicU64 = AtomicU64::new(0);
static FAULTS_INJECTED: AtomicU64 = AtomicU64::new(0);
static JOBS_DEGRADED: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of I/O operations retried after a transient error.
pub fn io_retries() -> u64 {
    IO_RETRIES.load(Ordering::Relaxed)
}

/// Process-wide count of faults the injector has fired.
pub fn faults_injected() -> u64 {
    FAULTS_INJECTED.load(Ordering::Relaxed)
}

/// Process-wide count of jobs that engaged the disk-pressure degradation
/// ladder (shrunk merge fan-out or waited for reclaim).
pub fn jobs_degraded() -> u64 {
    JOBS_DEGRADED.load(Ordering::Relaxed)
}

/// Record one engagement of the degradation ladder.
pub fn note_job_degraded() {
    JOBS_DEGRADED.fetch_add(1, Ordering::Relaxed);
}

/// Append the fault/recovery counters in Prometheus text exposition
/// format (called from the `metrics` verb's renderer).
pub fn prometheus_into(out: &mut String) {
    use std::fmt::Write;
    let rows = [
        ("flims_io_retries_total", "I/O operations retried after a transient error", io_retries()),
        ("flims_faults_injected_total", "faults fired by the deterministic injector", faults_injected()),
        ("flims_jobs_degraded_total", "jobs that engaged the disk-pressure degradation ladder", jobs_degraded()),
    ];
    for (name, help, value) in rows {
        let _ = writeln!(out, "# HELP {name} Process-wide count of {help}.");
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {value}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(rate: f64, kinds: u8) -> FaultSpec {
        FaultSpec { seed: 42, rate_ppm: (rate * 1e6) as u32, kinds }
    }

    #[test]
    fn parse_grammar() {
        let s = parse_faults_arg("123:0.5:all").unwrap().unwrap();
        assert_eq!(s, FaultSpec { seed: 123, rate_ppm: 500_000, kinds: KIND_ALL });
        let s = parse_faults_arg(" 0 : 1 : enospc ").unwrap().unwrap();
        assert_eq!(s.rate_ppm, 1_000_000);
        assert_eq!(s.kinds, KIND_DISK_FULL);
        let s = parse_faults_arg("9:0:transient,short,stall").unwrap().unwrap();
        assert!(!s.is_active());
        assert_eq!(s.kinds, KIND_TRANSIENT | KIND_SHORT_IO | KIND_STALL);
        for off in ["", "off", "OFF", "none"] {
            assert!(parse_faults_arg(off).unwrap().is_none(), "{off:?}");
        }
        for bad in ["7", "7:0.1", "x:0.1:all", "7:nan:all", "7:1.5:all", "7:-0.1:all", "7:0.1:bogus"] {
            assert!(parse_faults_arg(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn decision_stream_is_deterministic_per_site() {
        let trace = Trace::disabled();
        let plan = Some(spec(0.25, KIND_ALL));
        let draw_all = |site: &str| {
            let mut inj = Injector::for_site(plan, site, &trace);
            let st = inj.0.as_mut().unwrap();
            (0..512).map(|_| st.decide(Op::Write)).collect::<Vec<_>>()
        };
        let a = draw_all("run-000001.flr");
        let b = draw_all("run-000001.flr");
        assert_eq!(a, b, "same seed + site must replay the same fault sequence");
        let c = draw_all("run-000002.flr");
        assert_ne!(a, c, "distinct sites should draw independent streams");
        assert!(a.iter().any(|d| d.is_some()), "a 25% plan must fire in 512 draws");
        assert!(a.iter().any(|d| d.is_none()), "a 25% plan must also pass ops");
    }

    #[test]
    fn rate_bounds_zero_and_one() {
        let trace = Trace::disabled();
        let mut never = Injector::for_site(Some(spec(0.0, KIND_ALL)), "x", &trace);
        let mut always = Injector::for_site(Some(spec(1.0, KIND_STALL)), "x", &trace);
        for _ in 0..256 {
            assert!(never.0.as_mut().unwrap().decide(Op::Read).is_none());
            assert!(always.0.as_mut().unwrap().decide(Op::Read).is_some());
        }
    }

    #[test]
    fn checkpoint_recovers_transients_and_surfaces_disk_full() {
        let trace = Trace::disabled();
        // Transient-only plan at a moderate rate: checkpoint must always
        // come back Ok (each internal retry re-draws at rate 0.2, so four
        // consecutive faults are ~1.6e-3 per op; 200 ops keeps the test
        // deterministic enough — and a failure here would be a real
        // signal that retry re-drawing broke).
        let plan = Some(spec(0.2, KIND_TRANSIENT | KIND_SHORT_IO | KIND_STALL));
        let mut inj = Injector::for_site(plan, "recovering-site", &trace);
        let retries_before = io_retries();
        let injected_before = faults_injected();
        for _ in 0..200 {
            inj.checkpoint(Op::Write).unwrap();
        }
        assert!(faults_injected() > injected_before, "plan at 20% must fire");
        assert!(io_retries() >= retries_before, "retry counter must not regress");

        let mut full = Injector::for_site(Some(spec(1.0, KIND_DISK_FULL)), "full-site", &trace);
        let err = full.checkpoint(Op::Write).unwrap_err();
        assert!(io_error_is_disk_full(&err), "want ENOSPC, got {err}");
        assert!(!io_error_is_transient(&err));
    }

    #[test]
    fn with_retry_recovers_real_interrupted_errors() {
        let mut inj = Injector::disabled();
        let mut failures = 2;
        let out = with_retry(&mut inj, Op::Write, || {
            if failures > 0 {
                failures -= 1;
                Err(io::Error::new(io::ErrorKind::Interrupted, "spurious EINTR"))
            } else {
                Ok(7u32)
            }
        })
        .unwrap();
        assert_eq!(out, 7);

        // Non-transient errors surface on the first attempt.
        let mut calls = 0;
        let err = with_retry(&mut inj, Op::Write, || -> io::Result<()> {
            calls += 1;
            Err(io::Error::new(io::ErrorKind::PermissionDenied, "nope"))
        })
        .unwrap_err();
        assert_eq!(calls, 1);
        assert_eq!(err.kind(), io::ErrorKind::PermissionDenied);
    }

    #[test]
    fn transient_job_errors_are_recognized_through_context_chains() {
        use anyhow::Context;
        let base: io::Result<()> =
            Err(io::Error::new(io::ErrorKind::Interrupted, "injected transient I/O fault"));
        let err = base.context("writing run block").unwrap_err();
        assert!(error_is_transient(&err));
        let plain = anyhow::Error::msg("external sort: injected transient I/O fault");
        assert!(error_is_transient(&plain));
        let other = anyhow::Error::msg("disk budget exceeded");
        assert!(!error_is_transient(&other));
    }

    #[test]
    fn prometheus_rows_render() {
        let mut out = String::new();
        prometheus_into(&mut out);
        for name in [
            "flims_io_retries_total",
            "flims_faults_injected_total",
            "flims_jobs_degraded_total",
        ] {
            assert!(out.contains(&format!("# TYPE {name} counter")), "{out}");
            assert!(out.contains(&format!("\n{name} ")), "{out}");
        }
    }
}
