//! Maximum-operating-frequency model (fig. 13 substitute).
//!
//! Critical-path estimate per design: a base clock-to-out + the worst
//! pipeline-stage logic (one comparator level for all these designs), a
//! control term that captures the selector's dequeue-decision fan-out
//! (distributed and O(1) in FLiMS; a w-wide broadcast in the row-dequeue
//! designs; the whole feedback loop in basic/PMT), and a routing term
//! that grows with the placed area (√kLUT — congestion), matching the
//! paper's observation that place-and-route degrades large designs and
//! WMS stops routing at w ≥ 256.
//!
//! Constants are calibrated to reproduce fig. 13's *shape*: FLiMS
//! 500→200 MHz over w = 4…512, WMS/EHMS below it with the gap growing
//! to ≳1.5–2× at large w, FLiMSj slightly under FLiMS.

use super::analytical::{log2, Design};
use super::cost::estimate;
use super::gen::netlist;

/// ns components
const T_BASE: f64 = 1.45;
const T_CMP_PER_LG: f64 = 0.085; // comparator tree depth grows mildly with w
const T_ROUTE_PER_SQRT_KLUT: f64 = 0.155;

/// Estimated maximum frequency in MHz for a design instance.
pub fn fmax_mhz(design: Design, w: usize, data_bits: usize) -> f64 {
    let n = netlist(design, w, data_bits);
    let r = estimate(&n);
    let lg = log2(w) as f64;

    let t_ctl = match design {
        // Distributed MAX units: dequeue decision is local (O(1)).
        Design::Flims => 0.0,
        // cR steering + src/dir sync adds a mux level.
        Design::Flimsj => 0.22,
        // Row-dequeue broadcast: the select signal fans out to w banks.
        Design::Wms => 0.45 + 0.0042 * w as f64,
        Design::Ehms => 0.55 + 0.0048 * w as f64,
        Design::Mms | Design::Vms => 0.50 + 0.0040 * w as f64,
        // Feedback squeezed into one cycle: the whole loop is the path.
        Design::Basic => 0.60 * (lg + 2.0),
        Design::Pmt => 0.45 * (lg + 1.0),
    };

    let t = T_BASE + T_CMP_PER_LG * lg + t_ctl
        + T_ROUTE_PER_SQRT_KLUT * r.kluts().sqrt();
    1000.0 / t
}

/// Routability check: the paper could not route WMS at w ≥ 256 with any
/// directive. Model: un-routable once the control fan-out term crosses
/// a placement budget.
pub fn routable(design: Design, w: usize, data_bits: usize) -> bool {
    match design {
        Design::Wms => w < 256 || {
            // mirrors "for WMS with w>=256 the directives did not help";
            // report the estimated frequency anyway, flagged.
            false
        },
        _ => {
            let _ = data_bits;
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::analytical::ALL_DESIGNS;

    #[test]
    fn flims_beats_wms_and_ehms_everywhere() {
        for wexp in 2..=9 {
            let w = 1 << wexp;
            let f = fmax_mhz(Design::Flims, w, 64);
            assert!(f > fmax_mhz(Design::Wms, w, 64), "w={w}");
            assert!(f > fmax_mhz(Design::Ehms, w, 64), "w={w}");
        }
    }

    #[test]
    fn gap_grows_towards_2x_at_large_w() {
        // Fig. 13: "sometimes yielding more than double the operating
        // frequency" — check the large-w gap.
        let f = fmax_mhz(Design::Flims, 512, 64);
        let wm = fmax_mhz(Design::Wms, 512, 64);
        let eh = fmax_mhz(Design::Ehms, 512, 64);
        assert!(f / wm > 1.5, "FLiMS/WMS = {:.2}", f / wm);
        assert!(f / eh > 1.5, "FLiMS/EHMS = {:.2}", f / eh);
    }

    #[test]
    fn flims_absolute_range_plausible() {
        // Fig. 13 shape: hundreds of MHz at small w, degrading with w.
        let f4 = fmax_mhz(Design::Flims, 4, 64);
        let f512 = fmax_mhz(Design::Flims, 512, 64);
        assert!((380.0..650.0).contains(&f4), "w=4: {f4:.0} MHz");
        assert!((150.0..350.0).contains(&f512), "w=512: {f512:.0} MHz");
        assert!(f4 > f512);
    }

    #[test]
    fn flimsj_small_overhead_over_flims() {
        for w in [8usize, 32, 128] {
            let f = fmax_mhz(Design::Flims, w, 64);
            let j = fmax_mhz(Design::Flimsj, w, 64);
            assert!(j < f, "w={w}");
            assert!(j > f * 0.80, "w={w}: FLiMSj should be a *small* overhead");
        }
    }

    #[test]
    fn basic_and_pmt_scale_worst() {
        // The long-feedback designs degrade fastest with w (the reason
        // the feedback-less line of work exists).
        for w in [64usize, 256] {
            let basic = fmax_mhz(Design::Basic, w, 64);
            for d in ALL_DESIGNS {
                if !matches!(d, Design::Basic) {
                    assert!(
                        fmax_mhz(d, w, 64) > basic,
                        "{} should beat basic at w={w}",
                        d.name()
                    );
                }
            }
        }
    }

    #[test]
    fn wms_routability_limit() {
        assert!(routable(Design::Wms, 128, 64));
        assert!(!routable(Design::Wms, 256, 64));
        assert!(routable(Design::Flims, 512, 64));
    }

    #[test]
    fn monotone_decreasing_in_w() {
        for d in ALL_DESIGNS {
            let mut prev = f64::INFINITY;
            for wexp in 2..=9 {
                let f = fmax_mhz(d, 1 << wexp, 64);
                assert!(f < prev, "{} not decreasing at w={}", d.name(), 1 << wexp);
                prev = f;
            }
        }
    }
}
