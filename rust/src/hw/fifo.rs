//! Banked FIFO queues — the input/output memory structure of every
//! merger in the paper (§3.1: data written round-robin across `w` banks;
//! §7: evaluation FIFOs are 2 elements deep per bank).

use crate::key::Item;
use std::collections::VecDeque;

/// `w` banks, each a bounded FIFO. The producer writes round-robin; the
/// merger dequeues per-bank (FLiMS) or whole rows (FLiMSj/WMS/…).
#[derive(Clone, Debug)]
pub struct BankedFifo<T> {
    banks: Vec<VecDeque<T>>,
    depth: usize,
    /// next bank the producer writes (round-robin position)
    write_bank: usize,
    /// true once the producer has delivered the entire stream
    pub ended: bool,
}

impl<T: Item> BankedFifo<T> {
    pub fn new(w: usize, depth: usize) -> Self {
        BankedFifo {
            banks: (0..w).map(|_| VecDeque::with_capacity(depth)).collect(),
            depth,
            write_bank: 0,
            ended: false,
        }
    }

    pub fn w(&self) -> usize {
        self.banks.len()
    }

    /// Producer side: push up to `budget` elements from `src[*pos..]`
    /// round-robin; advances `pos`. Returns elements actually written
    /// (stops at full banks — backpressure).
    pub fn feed(&mut self, src: &[T], pos: &mut usize, budget: usize) -> usize {
        let mut written = 0;
        while written < budget && *pos < src.len() {
            let bank = &mut self.banks[self.write_bank];
            if bank.len() >= self.depth {
                break; // round-robin order must be preserved: stop.
            }
            bank.push_back(src[*pos]);
            *pos += 1;
            self.write_bank = (self.write_bank + 1) % self.banks.len();
            written += 1;
        }
        if *pos >= src.len() {
            self.ended = true;
        }
        written
    }

    /// Peek the head of bank `i` (None = empty).
    pub fn head(&self, i: usize) -> Option<&T> {
        self.banks[i].front()
    }

    /// Dequeue from bank `i`.
    pub fn pop(&mut self, i: usize) -> Option<T> {
        self.banks[i].pop_front()
    }

    /// Is a whole aligned row available (one element in every bank)?
    pub fn row_available(&self) -> bool {
        self.banks.iter().all(|b| !b.is_empty())
    }

    /// Dequeue one element from every bank (a whole row).
    pub fn pop_row(&mut self) -> Option<Vec<T>> {
        if !self.row_available() {
            return None;
        }
        Some(self.banks.iter_mut().map(|b| b.pop_front().unwrap()).collect())
    }

    /// Total buffered elements.
    pub fn len(&self) -> usize {
        self.banks.iter().map(|b| b.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Stream fully consumed (producer done and banks drained)?
    pub fn exhausted(&self) -> bool {
        self.ended && self.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_feed() {
        let mut f: BankedFifo<u32> = BankedFifo::new(4, 2);
        let src: Vec<u32> = (0..8).collect();
        let mut pos = 0;
        let n = f.feed(&src, &mut pos, 100);
        assert_eq!(n, 8);
        assert!(f.ended);
        // bank i holds src[i], src[i+4]
        for i in 0..4 {
            assert_eq!(*f.head(i).unwrap(), i as u32);
        }
        let row = f.pop_row().unwrap();
        assert_eq!(row, vec![0, 1, 2, 3]);
        assert_eq!(f.pop_row().unwrap(), vec![4, 5, 6, 7]);
        assert!(f.exhausted());
    }

    #[test]
    fn backpressure_stops_at_full_bank() {
        let mut f: BankedFifo<u32> = BankedFifo::new(2, 1);
        let src: Vec<u32> = (0..10).collect();
        let mut pos = 0;
        assert_eq!(f.feed(&src, &mut pos, 100), 2); // both banks full
        assert_eq!(pos, 2);
        assert!(!f.ended);
        f.pop(0);
        // Round-robin preserved: next write goes to bank 0.
        assert_eq!(f.feed(&src, &mut pos, 100), 1);
        assert_eq!(*f.head(0).unwrap(), 2);
    }

    #[test]
    fn budget_respected() {
        let mut f: BankedFifo<u32> = BankedFifo::new(4, 8);
        let src: Vec<u32> = (0..100).collect();
        let mut pos = 0;
        assert_eq!(f.feed(&src, &mut pos, 3), 3);
        assert_eq!(f.len(), 3);
    }

    #[test]
    fn row_unavailable_when_a_bank_is_empty() {
        let mut f: BankedFifo<u32> = BankedFifo::new(2, 4);
        let src = vec![1u32];
        let mut pos = 0;
        f.feed(&src, &mut pos, 10);
        assert!(!f.row_available());
        assert!(f.pop_row().is_none());
    }
}
