//! Closed-form Table 2: feedback length, latency and comparator count
//! for every high-throughput 2-way merger the paper compares. The
//! structural generators in [`super::gen`] must agree with these — the
//! same cross-check the paper performs between its formulas and yosys
//! synthesis of the generated Verilog.

/// The eight designs of Table 2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Design {
    /// Chhugani/Casper full bitonic-merger loop [12], [17]
    Basic,
    /// Song et al. parallel merge tree building block [3]
    Pmt,
    /// Saitoh et al. bitonic, two partial mergers + shift regs [4]
    Mms,
    /// Saitoh & Kise odd-even variant [5]
    Vms,
    /// Elsayed & Kise 3w-to-w odd-even [6], [7]
    Wms,
    /// Elsayed & Kise 2.5w-to-w odd-even [6], [7]
    Ehms,
    /// this paper
    Flims,
    /// §4.3 whole-row variant
    Flimsj,
}

pub const ALL_DESIGNS: [Design; 8] = [
    Design::Basic,
    Design::Pmt,
    Design::Mms,
    Design::Vms,
    Design::Wms,
    Design::Ehms,
    Design::Flims,
    Design::Flimsj,
];

impl Design {
    pub fn name(&self) -> &'static str {
        match self {
            Design::Basic => "basic",
            Design::Pmt => "PMT",
            Design::Mms => "MMS",
            Design::Vms => "VMS",
            Design::Wms => "WMS",
            Design::Ehms => "EHMS",
            Design::Flims => "FLiMS",
            Design::Flimsj => "FLiMSj",
        }
    }

    /// Feedback datapath length in stages (Table 2).
    pub fn feedback_len(&self, w: usize) -> usize {
        let lg = log2(w);
        match self {
            Design::Basic => lg + 2,
            Design::Pmt => lg + 1,
            _ => 1,
        }
    }

    /// Pipeline latency in cycles (Table 2).
    pub fn latency(&self, w: usize) -> usize {
        let lg = log2(w);
        match self {
            Design::Basic => lg + 2,
            Design::Pmt => 2 * lg + 1,
            Design::Mms | Design::Vms => 2 * lg + 3,
            Design::Wms | Design::Ehms => lg + 3,
            Design::Flims => lg + 1,
            Design::Flimsj => lg + 2,
        }
    }

    /// Comparator count (Table 2; the WMS/EHMS forms derive from Cullen
    /// numbers per the paper).
    pub fn comparators(&self, w: usize) -> usize {
        let lg = log2(w);
        match self {
            Design::Basic => w + w * lg,
            Design::Pmt => w + (w * lg) / 2,
            Design::Mms | Design::Vms => 2 * w + w * lg + 1,
            Design::Wms => 3 * w + (w * lg) / 2,
            Design::Ehms => (5 * w) / 2 + (w * lg) / 2 + 2,
            Design::Flims => w + (w * lg) / 2,
            Design::Flimsj => w + (w * lg) / 2,
        }
    }

    /// Does the design suffer the tie-record issue (Table 2)?
    pub fn tie_record_unsafe(&self) -> bool {
        matches!(self, Design::Mms | Design::Vms | Design::Wms | Design::Ehms)
    }

    /// Merger-topology family (Table 2).
    pub fn topology(&self) -> &'static str {
        match self {
            Design::Basic | Design::Pmt | Design::Mms | Design::Flims | Design::Flimsj => {
                "bitonic"
            }
            Design::Vms | Design::Wms | Design::Ehms => "odd-even",
        }
    }

    /// Hardware-module summary string (Table 2 column 5).
    pub fn modules(&self) -> &'static str {
        match self {
            Design::Basic => "1x 2w-to-2w merger",
            Design::Pmt => "1x 2w-to-w merger + 2 barrel shifters",
            Design::Mms | Design::Vms => "2x 2w-to-w mergers + shift registers",
            Design::Wms => "1x 3w-to-w merger",
            Design::Ehms => "1x 2.5w-to-w merger",
            Design::Flims | Design::Flimsj => "1x 2w-to-w merger",
        }
    }
}

pub fn log2(w: usize) -> usize {
    debug_assert!(w.is_power_of_two());
    w.trailing_zeros() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_comparators_at_w4() {
        // Spot-check the closed forms at w=4 (lg=2).
        assert_eq!(Design::Basic.comparators(4), 12); // w + w lg = 4+8
        assert_eq!(Design::Pmt.comparators(4), 8); // 4+4
        assert_eq!(Design::Mms.comparators(4), 17); // 8+8+1
        assert_eq!(Design::Vms.comparators(4), 17);
        assert_eq!(Design::Wms.comparators(4), 16); // 12+4
        assert_eq!(Design::Ehms.comparators(4), 16); // 10+4+2
        assert_eq!(Design::Flims.comparators(4), 8);
        assert_eq!(Design::Flimsj.comparators(4), 8);
    }

    #[test]
    fn flims_has_fewest_comparators_everywhere() {
        for wexp in 1..=9 {
            let w = 1 << wexp;
            let f = Design::Flims.comparators(w);
            for d in ALL_DESIGNS {
                assert!(
                    d.comparators(w) >= f,
                    "{} beats FLiMS at w={w}",
                    d.name()
                );
            }
        }
    }

    #[test]
    fn flims_has_least_latency_everywhere() {
        for wexp in 1..=9 {
            let w = 1 << wexp;
            let f = Design::Flims.latency(w);
            for d in ALL_DESIGNS {
                assert!(d.latency(w) >= f, "{} latency at w={w}", d.name());
            }
        }
    }

    #[test]
    fn feedback_classes() {
        // basic/PMT have growing feedback; the rest are feedback-less.
        assert_eq!(Design::Basic.feedback_len(64), 8);
        assert_eq!(Design::Pmt.feedback_len(64), 7);
        for d in [Design::Mms, Design::Vms, Design::Wms, Design::Ehms, Design::Flims, Design::Flimsj]
        {
            assert_eq!(d.feedback_len(64), 1, "{}", d.name());
        }
    }

    #[test]
    fn tie_record_column() {
        assert!(!Design::Basic.tie_record_unsafe());
        assert!(!Design::Pmt.tie_record_unsafe());
        assert!(Design::Mms.tie_record_unsafe());
        assert!(Design::Vms.tie_record_unsafe());
        assert!(Design::Wms.tie_record_unsafe());
        assert!(Design::Ehms.tie_record_unsafe());
        assert!(!Design::Flims.tie_record_unsafe());
        assert!(!Design::Flimsj.tie_record_unsafe());
    }

    #[test]
    fn latencies_match_table2_at_w8() {
        // lg = 3
        assert_eq!(Design::Basic.latency(8), 5);
        assert_eq!(Design::Pmt.latency(8), 7);
        assert_eq!(Design::Mms.latency(8), 9);
        assert_eq!(Design::Vms.latency(8), 9);
        assert_eq!(Design::Wms.latency(8), 6);
        assert_eq!(Design::Ehms.latency(8), 6);
        assert_eq!(Design::Flims.latency(8), 4);
        assert_eq!(Design::Flimsj.latency(8), 5);
    }
}
