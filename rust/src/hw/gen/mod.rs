//! Structural netlist generators for the eight mergers of Table 2.
//!
//! These play the role of the paper's Verilog generator scripts: given a
//! degree of parallelism `w` and a data width, they emit the comparator/
//! mux/register structure of each design. Comparator totals, latencies
//! and feedback lengths are cross-checked against the closed forms in
//! [`super::analytical`] (the paper's yosys validation analogue); the
//! cost and timing models consume the structural quantities.
//!
//! Where a competitor's exact internal wiring is not fully specified by
//! its paper (WMS/EHMS pruning details), stages are laid out to match
//! the published comparator totals, stage counts and row widths — a
//! resource-equivalent structural model (see DESIGN.md §4). Functional
//! behaviour is modelled separately in [`super::behavior`].

use super::analytical::{log2, Design};
use super::types::{butterfly_stages, Netlist, Op, Stage};

/// FIFO depth per bank used by the §7 evaluation (2 elements per bank,
/// input and output ⇒ 4w total).
pub const EVAL_FIFO_DEPTH: usize = 2;

/// Build the netlist for a design instance.
pub fn netlist(design: Design, w: usize, data_bits: usize) -> Netlist {
    assert!(w.is_power_of_two() && w >= 2, "w must be a power of two >= 2");
    match design {
        Design::Flims => flims(w, data_bits),
        Design::Flimsj => flimsj(w, data_bits),
        Design::Basic => basic(w, data_bits),
        Design::Pmt => pmt(w, data_bits),
        Design::Mms => mms_vms(w, data_bits, Design::Mms),
        Design::Vms => mms_vms(w, data_bits, Design::Vms),
        Design::Wms => wms(w, data_bits),
        Design::Ehms => ehms(w, data_bits),
    }
}

fn base(design: Design, w: usize, data_bits: usize) -> Netlist {
    Netlist {
        name: design.name().to_string(),
        w,
        data_bits,
        stages: Vec::new(),
        feedback_len: design.feedback_len(w),
        extra_reg_wires: 0,
        extra_mux2: 0,
        fifo_elems: 4 * w * EVAL_FIFO_DEPTH / 2, // 2w in + 2w out at depth 2
        tie_record_unsafe: design.tie_record_unsafe(),
        dequeue_granularity: 1,
    }
}

/// FLiMS (fig. 9): MAX selector stage integrated as the first pipeline
/// stage, then the butterfly. Head registers cA/cB (2w wires) stand in
/// for the banked-BRAM read registers.
fn flims(w: usize, data_bits: usize) -> Netlist {
    let mut n = base(Design::Flims, w, data_bits);
    let selector = Stage {
        ops: (0..w).map(|i| Op::Max(i as u32, (2 * w - 1 - i) as u32)).collect(),
        reg_wires: w,
    };
    n.stages.push(selector);
    n.stages.extend(butterfly_stages(w));
    n.extra_reg_wires = 2 * w; // cA + cB head registers
    n.dequeue_granularity = 1; // per-bank dequeue signals
    n
}

/// FLiMSj (§4.3): FLiMS plus the shared row buffer cR and one extra
/// staging cycle; dequeues whole w-rows.
fn flimsj(w: usize, data_bits: usize) -> Netlist {
    let mut n = flims(w, data_bits);
    n.name = Design::Flimsj.name().to_string();
    // The src/dir staging consumes one extra cycle before the selector.
    n.stages.insert(0, Stage { ops: vec![], reg_wires: w });
    n.extra_reg_wires += w; // cR row
    // Candidate steering muxes (src_i ? cA : cR etc.): 2 per lane.
    n.extra_mux2 += 2 * w;
    n.dequeue_granularity = w;
    n
}

/// Basic Chhugani/Casper loop (fig. 4): a full 2w-to-2w bitonic merger;
/// the feedback spans the whole network plus the select stage.
fn basic(w: usize, data_bits: usize) -> Netlist {
    let mut n = base(Design::Basic, w, data_bits);
    let lg = log2(w);
    // Full bitonic merger over 2w wires: lg(2w) = lg+1 stages of w CAS.
    for s in 0..=lg {
        let stride = w >> s; // 2w/2, …, 1
        let mut ops = Vec::new();
        let mut g = 0;
        while g < 2 * w {
            for i in g..g + stride {
                ops.push(Op::Cas(i as u32, (i + stride) as u32));
            }
            g += 2 * stride;
        }
        n.stages.push(Stage { ops, reg_wires: 2 * w });
    }
    // Batch-select stage (single head comparison + row steering).
    n.stages.push(Stage { ops: vec![Op::Cas(0, 1)], reg_wires: 2 * w });
    // The Table-2 count excludes the select comparator bookkeeping:
    // remove it from the comparator total by modelling it as muxes.
    n.stages.last_mut().unwrap().ops = vec![Op::Mux2(0, 1)];
    n.extra_mux2 += w; // input-batch steering
    n.dequeue_granularity = w;
    n
}

/// PMT building block (fig. 5): two barrel shifters (log2(w) mux stages
/// each) feeding a 2w-to-w bitonic partial merger.
fn pmt(w: usize, data_bits: usize) -> Netlist {
    let mut n = base(Design::Pmt, w, data_bits);
    let lg = log2(w);
    // Barrel shifters: lg stages of 2w Mux2 (both inputs shift in
    // parallel; they share pipeline columns).
    for s in 0..lg {
        let _ = s;
        let ops = (0..2 * w).map(|i| Op::Mux2(i as u32, i as u32)).collect();
        n.stages.push(Stage { ops, reg_wires: 2 * w });
    }
    // Half-cleaner + butterfly (the 2w-to-w partial merger).
    let half = Stage {
        ops: (0..w).map(|i| Op::Cas(i as u32, (2 * w - 1 - i) as u32)).collect(),
        reg_wires: w,
    };
    n.stages.push(half);
    n.stages.extend(butterfly_stages(w));
    n.extra_reg_wires = 2 * w;
    n.dequeue_granularity = 1;
    n
}

/// MMS [4] / VMS [5]: a 1-cycle selector (one extra comparator plus row
/// steering) followed by two 2w-to-w partial mergers back-to-back, with
/// shift registers carrying candidate rows.
fn mms_vms(w: usize, data_bits: usize, d: Design) -> Netlist {
    let mut n = base(d, w, data_bits);
    // Selector stage: the "extra comparator and multiplexer".
    n.stages.push(Stage { ops: vec![Op::Cas(0, 1)], reg_wires: 2 * w });
    for _ in 0..2 {
        let half = Stage {
            ops: (0..w).map(|i| Op::Cas(i as u32, (2 * w - 1 - i) as u32)).collect(),
            reg_wires: w,
        };
        n.stages.push(half);
        n.stages.extend(butterfly_stages(w));
    }
    // Shift registers carrying the two candidate rows alongside.
    n.extra_reg_wires = 2 * w;
    n.extra_mux2 += w;
    n.dequeue_granularity = w;
    n
}

/// WMS [6]: one 3w-to-w merger (pruned 4w odd-even network), one
/// selector stage — lg+3 stages, 3w + ½w·lg comparators.
fn wms(w: usize, data_bits: usize) -> Netlist {
    let mut n = base(Design::Wms, w, data_bits);
    let _lg = log2(w);
    // Three w-wide comparator columns prune the 3w candidates…
    let widths = [3 * w, 2 * w, w];
    for (s, &row) in widths.iter().enumerate() {
        let ops = (0..w).map(|i| Op::Cas(i as u32, (i + w) as u32)).collect();
        n.stages.push(Stage {
            ops,
            reg_wires: if s + 1 < widths.len() { row.min(3 * w) } else { w },
        });
    }
    // …then the w-wide butterfly cleanup.
    n.stages.extend(butterfly_stages(w));
    n.extra_reg_wires = 2 * w; // retained candidate rows
    n.dequeue_granularity = w;
    n
}

/// EHMS [6]: the 2.5w-to-w variant — same stage count as WMS, fewer
/// comparators (the first w/2 inputs are unused), two extra comparators
/// in the selector.
fn ehms(w: usize, data_bits: usize) -> Netlist {
    let mut n = base(Design::Ehms, w, data_bits);
    let col = |c: usize| -> Vec<Op> {
        (0..c).map(|i| Op::Cas(i as u32, (i + w) as u32)).collect()
    };
    n.stages.push(Stage { ops: col(w), reg_wires: 5 * w / 2 });
    n.stages.push(Stage { ops: col(w), reg_wires: 3 * w / 2 });
    n.stages.push(Stage { ops: col(w / 2 + 2), reg_wires: w });
    n.stages.extend(butterfly_stages(w));
    n.extra_reg_wires = 3 * w / 2;
    n.dequeue_granularity = w / 2;
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::analytical::ALL_DESIGNS;

    #[test]
    fn structural_counts_match_closed_forms() {
        // The paper validates Table 2 with yosys; we validate the
        // generators against the closed forms for every design and w.
        for d in ALL_DESIGNS {
            for wexp in 1..=9 {
                let w = 1 << wexp;
                let n = netlist(d, w, 64);
                assert_eq!(
                    n.comparators(),
                    d.comparators(w),
                    "{} comparators at w={w}",
                    d.name()
                );
                assert_eq!(n.latency(), d.latency(w), "{} latency at w={w}", d.name());
                assert_eq!(n.feedback_len, d.feedback_len(w), "{} feedback", d.name());
                assert_eq!(n.tie_record_unsafe, d.tie_record_unsafe());
            }
        }
    }

    #[test]
    fn flims_minimal_resources() {
        for wexp in 2..=8 {
            let w = 1 << wexp;
            let f = netlist(Design::Flims, w, 64);
            for d in [Design::Wms, Design::Ehms, Design::Mms, Design::Vms] {
                let n = netlist(d, w, 64);
                assert!(n.cmp_bits() > f.cmp_bits(), "{} cmp at w={w}", d.name());
                assert!(n.reg_bits() > f.reg_bits(), "{} regs at w={w}", d.name());
            }
        }
    }

    #[test]
    fn dequeue_granularity_per_design() {
        let w = 16;
        assert_eq!(netlist(Design::Flims, w, 64).dequeue_granularity, 1);
        assert_eq!(netlist(Design::Flimsj, w, 64).dequeue_granularity, w);
        assert_eq!(netlist(Design::Wms, w, 64).dequeue_granularity, w);
        assert_eq!(netlist(Design::Ehms, w, 64).dequeue_granularity, w / 2);
    }

    #[test]
    fn pmt_has_barrel_shifter_muxes() {
        let n = netlist(Design::Pmt, 16, 64);
        let mux_ops: usize = n
            .stages
            .iter()
            .flat_map(|s| &s.ops)
            .filter(|o| matches!(o, Op::Mux2(..)))
            .count();
        // lg(16)=4 stages × 2w=32 muxes
        assert_eq!(mux_ops, 128);
    }

    #[test]
    fn w2_minimum_size_works() {
        for d in ALL_DESIGNS {
            let n = netlist(d, 2, 64);
            assert!(n.comparators() > 0, "{}", d.name());
        }
    }
}
