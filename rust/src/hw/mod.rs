//! Hardware substrate: the FPGA-evaluation stand-in (DESIGN.md §4).
//!
//! * [`types`] / [`gen`] — structural netlists for all eight mergers of
//!   Table 2 (comparator/mux/register counts, validated against the
//!   closed forms in [`analytical`]).
//! * [`behavior`] / [`fifo`] / [`engine`] — cycle-accurate streaming
//!   simulation: throughput, stalls, the §4.1 skew experiment and the
//!   §6 tie-record demonstration.
//! * [`cost`] — LUT/FF model (Table 3, fig. 12).
//! * [`timing`] — Fmax model (fig. 13).

pub mod analytical;
pub mod behavior;
pub mod cost;
pub mod engine;
pub mod fifo;
pub mod gen;
pub mod timing;
pub mod types;

pub use analytical::{Design, ALL_DESIGNS};
pub use behavior::{BasicCycle, CycleMerger, FlimsCycle, FlimsjCycle, RowClass, RowMergerCycle};
pub use cost::{estimate, Resources};
pub use engine::{run_stream, SimConfig, SimResult};
pub use fifo::BankedFifo;
pub use gen::netlist;
pub use timing::fmax_mhz;
