//! FPGA resource-cost model: LUT/FF estimates from structural netlist
//! quantities — the stand-in for the paper's Vivado 2020.1 reports
//! (Table 3 / fig. 12). See DESIGN.md §4 for the substitution argument.
//!
//! Calibration: the per-unit constants are fitted once against the
//! paper's *FLiMS column* of Table 3 (64-bit, Alveo U280) and then
//! applied uniformly to every design — so cross-design *ratios* (the
//! paper's actual claim: FLiMS is ~1.5–2× more resource-efficient) are
//! genuine predictions of the structural model, not fits.

use super::types::{Netlist, Op};

/// Estimated FPGA resources.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Resources {
    pub luts: f64,
    pub ffs: f64,
}

impl Resources {
    pub fn kluts(&self) -> f64 {
        self.luts / 1000.0
    }
    pub fn kffs(&self) -> f64 {
        self.ffs / 1000.0
    }
}

/// LUTs per data bit for a full CAS (comparator + two swap muxes,
/// LUT6+carry packing).
const LUT_PER_CAS_BIT: f64 = 2.2;
/// LUTs per data bit for a MAX unit (comparator + one mux + dequeue ctl).
const LUT_PER_MAX_BIT: f64 = 1.5;
/// LUTs per data bit for a bare 2:1 mux (barrel shifters…).
const LUT_PER_MUX2_BIT: f64 = 0.55;
/// Fixed AXI-peripheral / control overhead, plus per-bank logic.
const LUT_BASE: f64 = 600.0;
const LUT_PER_BANK: f64 = 8.0;

/// FF duplication factor for clock-enables/replication on wide columns.
const FF_REG_FACTOR: f64 = 1.1;
/// Control FFs (valids, cursors) per bank and fixed.
const FF_PER_BANK: f64 = 10.0;
const FF_BASE: f64 = 200.0;

/// Estimate LUT/FF usage for one design instance (as an AXI peripheral,
/// matching the §7 methodology).
pub fn estimate(n: &Netlist) -> Resources {
    let bits = n.data_bits as f64;
    let mut cas = 0usize;
    let mut max = 0usize;
    let mut mux2 = n.extra_mux2;
    for s in &n.stages {
        for op in &s.ops {
            match op {
                Op::Cas(..) => cas += 1,
                Op::Max(..) => max += 1,
                Op::Mux2(..) => mux2 += 1,
            }
        }
    }
    let luts = bits * (cas as f64 * LUT_PER_CAS_BIT + max as f64 * LUT_PER_MAX_BIT
        + mux2 as f64 * LUT_PER_MUX2_BIT)
        + LUT_BASE
        + LUT_PER_BANK * (2 * n.w) as f64;

    let ffs = n.reg_bits() as f64 * FF_REG_FACTOR
        + n.fifo_bits() as f64
        + FF_PER_BANK * (2 * n.w) as f64
        + FF_BASE;

    Resources { luts, ffs }
}

/// Paper Table 3, FLiMS columns (kLUT, kFF) for 64-bit on Alveo U280 —
/// the calibration/validation reference.
pub const PAPER_FLIMS_TABLE3: [(usize, f64, f64); 8] = [
    (4, 1.7, 2.9),
    (8, 3.6, 6.3),
    (16, 7.0, 14.0), // paper prints "1.4" kFF at w=16 — an obvious typo for ~14
    (32, 15.4, 29.0),
    (64, 33.7, 62.0),
    (128, 73.4, 132.2),
    (256, 158.6, 280.7),
    (512, 345.3, 594.0),
];

/// Paper Table 3, WMS and EHMS columns, for ratio validation.
pub const PAPER_WMS_TABLE3: [(usize, f64, f64); 8] = [
    (4, 2.7, 5.3),
    (8, 5.6, 11.0),
    (16, 11.7, 23.1),
    (32, 23.5, 48.3),
    (64, 53.3, 100.8),
    (128, 106.6, 209.8),
    (256, 224.0, 436.0),
    (512, 473.0, 904.7),
];

pub const PAPER_EHMS_TABLE3: [(usize, f64, f64); 8] = [
    (4, 3.1, 4.8),
    (8, 6.2, 10.3),
    (16, 13.0, 21.6),
    (32, 26.7, 45.3),
    (64, 57.9, 94.6),
    (128, 120.4, 197.5),
    (256, 252.2, 411.4),
    (512, 525.3, 855.6),
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::analytical::Design;
    use crate::hw::gen::netlist;

    #[test]
    fn flims_estimates_track_paper_table3() {
        // Within ±30% of the Vivado numbers across the whole sweep —
        // a structural model can't be exact, but must track the scaling.
        for (w, kl, kf) in PAPER_FLIMS_TABLE3 {
            let r = estimate(&netlist(Design::Flims, w, 64));
            let lut_err = (r.kluts() - kl).abs() / kl;
            let ff_err = (r.kffs() - kf).abs() / kf;
            assert!(lut_err < 0.30, "w={w}: pred {:.1} vs paper {kl} kLUT", r.kluts());
            assert!(ff_err < 0.30, "w={w}: pred {:.1} vs paper {kf} kFF", r.kffs());
        }
    }

    #[test]
    fn wms_ehms_ratio_bands_match_fig12() {
        // Fig. 12 claim: FLiMS is "roughly about 1.5 to 2 times more
        // hardware resource efficient". Check the predicted ratios stay
        // in a generous band around that for w >= 16.
        for w in [16usize, 32, 64, 128, 256, 512] {
            let f = estimate(&netlist(Design::Flims, w, 64));
            for d in [Design::Wms, Design::Ehms] {
                let r = estimate(&netlist(d, w, 64));
                let lut_ratio = r.luts / f.luts;
                let ff_ratio = r.ffs / f.ffs;
                assert!(
                    (1.2..2.6).contains(&lut_ratio),
                    "{} w={w} LUT ratio {lut_ratio:.2}",
                    d.name()
                );
                assert!(
                    (1.2..2.6).contains(&ff_ratio),
                    "{} w={w} FF ratio {ff_ratio:.2}",
                    d.name()
                );
            }
        }
    }

    #[test]
    fn flimsj_sits_between_flims_and_wms() {
        // §7: FLiMSj ≈ FLiMS in FFs, ~1.3× in LUTs, always below WMS/EHMS.
        for w in [16usize, 64, 256] {
            let f = estimate(&netlist(Design::Flims, w, 64));
            let j = estimate(&netlist(Design::Flimsj, w, 64));
            let wm = estimate(&netlist(Design::Wms, w, 64));
            assert!(j.luts > f.luts && j.luts < wm.luts, "w={w}");
            assert!(j.ffs >= f.ffs * 0.98 && j.ffs < wm.ffs, "w={w}");
        }
    }

    #[test]
    fn resources_scale_roughly_linearly_in_w() {
        let r64 = estimate(&netlist(Design::Flims, 64, 64));
        let r128 = estimate(&netlist(Design::Flims, 128, 64));
        let g = r128.luts / r64.luts;
        assert!((1.8..2.6).contains(&g), "growth {g}");
    }

    #[test]
    fn data_width_scales_costs() {
        let r32 = estimate(&netlist(Design::Flims, 32, 32));
        let r64 = estimate(&netlist(Design::Flims, 32, 64));
        assert!(r64.luts > r32.luts * 1.6);
        assert!(r64.ffs > r32.ffs * 1.6);
    }
}
