//! Structural hardware model: comparator-network netlists.
//!
//! Each merger design (Table 2) is generated as a pipeline of stages of
//! unit *ops* over `w`-lane wire columns, plus design-level attributes
//! (feedback length, extra register rows, barrel shifters, FIFO
//! geometry). The cost and timing models (`hw::cost`, `hw::timing`)
//! consume only these structural quantities — the same way the paper
//! derives Table 2 analytically and validates it "by using yosys through
//! synthesising the Verilog implementations".

/// A unit in one pipeline stage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Compare-and-swap: two inputs, two outputs (a full comparator +
    /// two data muxes).
    Cas(u32, u32),
    /// MAX unit (FLiMS selector): two inputs, one selected output plus a
    /// dequeue decision (comparator + one data mux + control).
    Max(u32, u32),
    /// A 2:1 data multiplexer (no comparator) — barrel-shifter stages,
    /// feedback selects.
    Mux2(u32, u32),
}

impl Op {
    pub fn is_comparator(&self) -> bool {
        matches!(self, Op::Cas(..) | Op::Max(..))
    }
    /// Data-bit multiplexers implied by the op (per data bit).
    pub fn mux_count(&self) -> usize {
        match self {
            Op::Cas(..) => 2, // both outputs select
            Op::Max(..) => 1, // one selected output
            Op::Mux2(..) => 1,
        }
    }
}

/// One pipeline stage: a column of ops plus the registered wires that
/// cross it.
#[derive(Clone, Debug, Default)]
pub struct Stage {
    pub ops: Vec<Op>,
    /// wires registered at the end of this stage (usually `w`, more for
    /// designs that carry candidate rows forward)
    pub reg_wires: usize,
}

/// Structural description of one merger design instance.
#[derive(Clone, Debug)]
pub struct Netlist {
    pub name: String,
    pub w: usize,
    /// key+payload width in bits (the paper's evaluation: 64)
    pub data_bits: usize,
    pub stages: Vec<Stage>,
    /// feedback datapath length in stages (Table 2 column 2)
    pub feedback_len: usize,
    /// standalone register rows outside the pipeline (head registers
    /// cA/cB, FLiMSj's cR, MMS/VMS shift registers…), in wires
    pub extra_reg_wires: usize,
    /// 2:1 mux count outside stages (barrel shifters etc.), per data bit
    pub extra_mux2: usize,
    /// input+output FIFO capacity in elements (the §7 evaluation uses
    /// depth-2 FIFOs per bank: 4w elements total)
    pub fifo_elems: usize,
    /// does a key tie corrupt key-value payloads? (Table 2 last column)
    pub tie_record_unsafe: bool,
    /// dequeue granularity in elements (w for row-dequeue designs, 1 for
    /// FLiMS's per-bank signals, w/2 for EHMS)
    pub dequeue_granularity: usize,
}

impl Netlist {
    /// Total comparators (Table 2 column 4).
    pub fn comparators(&self) -> usize {
        self.stages
            .iter()
            .map(|s| s.ops.iter().filter(|o| o.is_comparator()).count())
            .sum()
    }

    /// Pipeline latency in cycles (Table 2 column 3).
    pub fn latency(&self) -> usize {
        self.stages.len()
    }

    /// Total registered data bits (pipeline + standalone rows).
    pub fn reg_bits(&self) -> usize {
        let pipeline: usize = self.stages.iter().map(|s| s.reg_wires).sum();
        (pipeline + self.extra_reg_wires) * self.data_bits
    }

    /// Total 2:1 data-mux bit count (swap muxes inside ops + barrel
    /// shifters etc.).
    pub fn mux_bits(&self) -> usize {
        let op_muxes: usize = self
            .stages
            .iter()
            .map(|s| s.ops.iter().map(|o| o.mux_count()).sum::<usize>())
            .sum();
        (op_muxes + self.extra_mux2) * self.data_bits
    }

    /// Comparator bit count (each comparator compares `data_bits` keys —
    /// the §7 evaluation compares full 64-bit values).
    pub fn cmp_bits(&self) -> usize {
        self.comparators() * self.data_bits
    }

    /// FIFO storage bits.
    pub fn fifo_bits(&self) -> usize {
        self.fifo_elems * self.data_bits
    }

    /// Worst-stage comparator count (a routing-pressure proxy for the
    /// timing model).
    pub fn worst_stage_ops(&self) -> usize {
        self.stages.iter().map(|s| s.ops.len()).max().unwrap_or(0)
    }
}

/// Convenience: build the butterfly stage columns (strides w/2 … 1) over
/// wires `0..w` — shared by several generators.
pub fn butterfly_stages(w: usize) -> Vec<Stage> {
    let mut stages = Vec::new();
    let mut stride = w / 2;
    while stride >= 1 {
        let mut ops = Vec::new();
        let mut g = 0;
        while g < w {
            for i in g..g + stride {
                ops.push(Op::Cas(i as u32, (i + stride) as u32));
            }
            g += 2 * stride;
        }
        stages.push(Stage { ops, reg_wires: w });
        stride /= 2;
    }
    stages
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn butterfly_stage_counts() {
        let s = butterfly_stages(8);
        assert_eq!(s.len(), 3); // log2(8)
        assert!(s.iter().all(|st| st.ops.len() == 4)); // w/2 per column
        let total: usize = s.iter().map(|st| st.ops.len()).sum();
        assert_eq!(total, 12); // ½ w log2 w
    }

    #[test]
    fn op_counting() {
        let n = Netlist {
            name: "t".into(),
            w: 4,
            data_bits: 64,
            stages: vec![
                Stage { ops: vec![Op::Max(0, 1), Op::Max(2, 3)], reg_wires: 4 },
                Stage { ops: vec![Op::Cas(0, 1), Op::Mux2(2, 3)], reg_wires: 4 },
            ],
            feedback_len: 1,
            extra_reg_wires: 8,
            extra_mux2: 0,
            fifo_elems: 16,
            tie_record_unsafe: false,
            dequeue_granularity: 1,
        };
        assert_eq!(n.comparators(), 3); // Mux2 is not a comparator
        assert_eq!(n.latency(), 2);
        assert_eq!(n.reg_bits(), (4 + 4 + 8) * 64);
        assert_eq!(n.mux_bits(), (1 + 1 + 2 + 1) * 64);
        assert_eq!(n.fifo_bits(), 16 * 64);
        assert_eq!(n.worst_stage_ops(), 2);
    }
}
