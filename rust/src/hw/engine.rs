//! Cycle-accurate streaming harness: drives a [`CycleMerger`] with
//! bandwidth-limited banked FIFOs, models the pipeline delay, and
//! measures cycles / stalls / throughput — the simulator counterpart of
//! the paper's FPGA testbench (§7), with the §4.1 rate-mismatch
//! experiment expressible through the feed bandwidths.

use super::behavior::{CycleMerger, StepOut};
use super::fifo::BankedFifo;
use crate::key::Item;
use std::collections::VecDeque;

/// Simulation parameters.
#[derive(Clone, Copy, Debug)]
pub struct SimConfig {
    /// FIFO depth per bank (the §7 evaluation uses 2)
    pub fifo_depth: usize,
    /// elements deliverable per cycle into A's banks (the "fixed
    /// bandwidth, less than w" of §4.1)
    pub bw_a: usize,
    /// same for B
    pub bw_b: usize,
    /// hard cycle cap (safety)
    pub max_cycles: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig { fifo_depth: 2, bw_a: usize::MAX, bw_b: usize::MAX, max_cycles: 100_000_000 }
    }
}

/// Measured results of one streaming run.
#[derive(Clone, Debug)]
pub struct SimResult<T> {
    pub output: Vec<T>,
    /// total clock cycles from first input to last output
    pub cycles: usize,
    /// cycles the selector spent waiting on input
    pub stall_cycles: usize,
    /// elements per cycle over the whole run
    pub throughput: f64,
}

/// Run `merger` over the two descending-sorted inputs until drained.
pub fn run_stream<T: Item, M: CycleMerger<T>>(
    merger: &mut M,
    a: &[T],
    b: &[T],
    cfg: SimConfig,
) -> SimResult<T> {
    let w = merger.w();
    let mut qa: BankedFifo<T> = BankedFifo::new(w, cfg.fifo_depth);
    let mut qb: BankedFifo<T> = BankedFifo::new(w, cfg.fifo_depth);
    let (mut pos_a, mut pos_b) = (0usize, 0usize);
    if a.is_empty() {
        qa.ended = true;
    }
    if b.is_empty() {
        qb.ended = true;
    }

    let total = a.len() + b.len();
    let mut output = Vec::with_capacity(total);
    // Pipeline delay line: chunks age `latency` cycles before emerging.
    let mut pipe: VecDeque<Vec<T>> = VecDeque::new();
    let mut cycles = 0usize;
    let mut stall_cycles = 0usize;
    let mut done_selecting = false;
    let cps = merger.cycles_per_select();

    while output.len() < total && cycles < cfg.max_cycles {
        // Producer side: feed both FIFOs this cycle.
        qa.feed(a, &mut pos_a, cfg.bw_a);
        qb.feed(b, &mut pos_b, cfg.bw_b);

        if !done_selecting {
            match merger.select(&mut qa, &mut qb) {
                StepOut::Chunk(chunk) => {
                    pipe.push_back(chunk);
                    cycles += cps;
                }
                StepOut::StallInput => {
                    pipe.push_back(Vec::new());
                    stall_cycles += 1;
                    cycles += 1;
                }
                StepOut::Done => {
                    done_selecting = true;
                    cycles += 1;
                }
            }
        } else {
            cycles += 1;
        }

        // Drain the pipeline with the modelled latency: one chunk
        // emerges per cycle once the fill depth is reached, and the tail
        // drains one per cycle after the last selection.
        while pipe.len() > merger.latency() {
            output.extend(pipe.pop_front().unwrap());
        }
        if done_selecting {
            if let Some(chunk) = pipe.pop_front() {
                output.extend(chunk);
            }
        }
    }
    // Flush any residue (e.g. cap hit exactly at the end).
    while let Some(chunk) = pipe.pop_front() {
        output.extend(chunk);
    }

    let throughput = if cycles > 0 { output.len() as f64 / cycles as f64 } else { 0.0 };
    SimResult { output, cycles, stall_cycles, throughput }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{gen_sorted_pair, gen_u32, Distribution};
    use crate::hw::behavior::{BasicCycle, FlimsCycle, FlimsjCycle, RowClass, RowMergerCycle};
    use crate::key::Kv;
    use crate::util::rng::Rng;

    fn oracle(a: &[u32], b: &[u32]) -> Vec<u32> {
        let mut v: Vec<u32> = a.iter().chain(b.iter()).copied().collect();
        v.sort_unstable_by(|x, y| y.cmp(x));
        v
    }

    fn pair(seed: u64, na: usize, nb: usize, dist: Distribution) -> (Vec<u32>, Vec<u32>) {
        let mut rng = Rng::new(seed);
        gen_sorted_pair(&mut rng, na, nb, dist, gen_u32)
    }

    #[test]
    fn flims_cycle_correct_all_w() {
        for wexp in 1..=5 {
            let w = 1 << wexp;
            let (a, b) = pair(wexp as u64, 130, 190, Distribution::Uniform);
            let mut m: FlimsCycle<u32> = FlimsCycle::new(w, false);
            let r = run_stream(&mut m, &a, &b, SimConfig::default());
            assert_eq!(r.output, oracle(&a, &b), "w={w}");
        }
    }

    #[test]
    fn flims_cycle_skew_correct() {
        let (a, b) = pair(7, 200, 200, Distribution::DupHeavy { alphabet: 2 });
        let mut m: FlimsCycle<u32> = FlimsCycle::new(8, true);
        let r = run_stream(&mut m, &a, &b, SimConfig::default());
        assert_eq!(r.output, oracle(&a, &b));
    }

    #[test]
    fn flimsj_cycle_correct() {
        let (a, b) = pair(8, 256, 128, Distribution::Uniform);
        let mut m: FlimsjCycle<u32> = FlimsjCycle::new(8);
        let r = run_stream(&mut m, &a, &b, SimConfig::default());
        assert_eq!(r.output, oracle(&a, &b));
    }

    #[test]
    fn row_merger_correct_unique_keys() {
        for class in [RowClass::Mms, RowClass::Vms, RowClass::Wms] {
            let (a, b) = pair(9, 160, 240, Distribution::Uniform);
            let mut m: RowMergerCycle<u32> = RowMergerCycle::new(8, class);
            let r = run_stream(&mut m, &a, &b, SimConfig::default());
            assert_eq!(r.output, oracle(&a, &b), "{class:?}");
        }
    }

    #[test]
    fn basic_cycle_correct_but_slow() {
        let (a, b) = pair(10, 128, 128, Distribution::Uniform);
        let mut m: BasicCycle<u32> = BasicCycle::new(8);
        let r = run_stream(&mut m, &a, &b, SimConfig::default());
        assert_eq!(r.output, oracle(&a, &b));
        // Feedback of lg(8)+2 = 5 cycles per selection: throughput well
        // below w per cycle.
        assert!(r.throughput < 8.0 / 4.0, "throughput {}", r.throughput);
    }

    #[test]
    fn full_bandwidth_throughput_near_w() {
        let (a, b) = pair(11, 4096, 4096, Distribution::Uniform);
        let mut m: FlimsCycle<u32> = FlimsCycle::new(8, false);
        let r = run_stream(&mut m, &a, &b, SimConfig { fifo_depth: 4, ..Default::default() });
        assert_eq!(r.output, oracle(&a, &b));
        assert!(r.throughput > 7.0, "throughput {}", r.throughput);
    }

    #[test]
    fn skew_optimisation_reduces_stalls_on_duplicates() {
        // §4.1's experiment: per-input bandwidth w/2 (aggregate w). On
        // duplicate-heavy data algorithm 1 drains one side at rate w
        // while refills arrive at w/2 → stalls; algorithm 2 balances.
        let w = 8;
        let a = vec![5u32; 2048];
        let b = vec![5u32; 2048];
        let cfg = SimConfig { fifo_depth: 4, bw_a: w / 2, bw_b: w / 2, ..Default::default() };

        let mut basic: FlimsCycle<u32> = FlimsCycle::new(w, false);
        let rb = run_stream(&mut basic, &a, &b, cfg);
        let mut skew: FlimsCycle<u32> = FlimsCycle::new(w, true);
        let rs = run_stream(&mut skew, &a, &b, cfg);

        assert_eq!(rb.output.len(), 4096);
        assert_eq!(rs.output.len(), 4096);
        assert!(
            rs.stall_cycles * 2 < rb.stall_cycles,
            "skew {} vs basic {} stalls",
            rs.stall_cycles,
            rb.stall_cycles
        );
        assert!(rs.throughput > rb.throughput * 1.5);
    }

    #[test]
    fn tie_record_issue_reproduced_and_flims_immune() {
        // §6: duplicate keys with payloads. The row-dequeue class (no
        // workaround) corrupts the payload multiset; FLiMS must not.
        let mk = |base: u32, n: usize| -> Vec<Kv> {
            (0..n).map(|i| Kv::new(7, base + i as u32)).collect()
        };
        let a = mk(0, 64);
        let b = mk(1000, 64);

        let mut flims: FlimsCycle<Kv> = FlimsCycle::new(8, false);
        let rf = run_stream(&mut flims, &a, &b, SimConfig::default());
        let mut vals: Vec<u32> = rf.output.iter().map(|kv| kv.val).collect();
        vals.sort_unstable();
        let mut expect: Vec<u32> = (0..64).chain(1000..1064).collect();
        expect.sort_unstable();
        assert_eq!(vals, expect, "FLiMS must preserve payloads");

        let mut wms: RowMergerCycle<Kv> = RowMergerCycle::new(8, RowClass::Wms);
        assert!(wms.tie_unsafe);
        let rw = run_stream(&mut wms, &a, &b, SimConfig::default());
        let mut wvals: Vec<u32> = rw.output.iter().map(|kv| kv.val).collect();
        wvals.sort_unstable();
        assert_ne!(wvals, expect, "tie-unsafe row merger should corrupt payloads");

        // With the workaround the row class is clean again.
        let mut wms_fixed: RowMergerCycle<Kv> = RowMergerCycle::new(8, RowClass::Wms);
        wms_fixed.tie_unsafe = false;
        let rfix = run_stream(&mut wms_fixed, &a, &b, SimConfig::default());
        let mut fvals: Vec<u32> = rfix.output.iter().map(|kv| kv.val).collect();
        fvals.sort_unstable();
        assert_eq!(fvals, expect);
    }

    #[test]
    fn empty_inputs() {
        let mut m: FlimsCycle<u32> = FlimsCycle::new(4, false);
        let r = run_stream(&mut m, &[], &[], SimConfig::default());
        assert!(r.output.is_empty());
    }
}
