//! Behavioural (cycle-level) merger models driven by [`super::engine`].
//!
//! * [`FlimsCycle`] — algorithms 1/2: per-bank dequeues through the
//!   distributed MAX selector; stalls only when a *needed* bank head is
//!   missing.
//! * [`FlimsjCycle`] — algorithm 4 granularity: needs whole rows.
//! * [`RowMergerCycle`] — the MMS/VMS/WMS feedback-less row-dequeue
//!   class (figs. 6–7): one whole row per cycle from the side whose head
//!   is larger, merged against the carried row. Its `tie_unsafe` mode
//!   reproduces the *tie-record issue* mechanism (§6): output and carry
//!   are computed by two independent unstable orders, so records with
//!   duplicate keys can be duplicated or lost across the boundary.
//! * [`BasicCycle`] — the Chhugani/Casper loop with its long feedback:
//!   the engine charges `feedback_len` cycles per selection.

use super::fifo::BankedFifo;
use crate::key::Item;

/// One merger selection step: either a produced chunk of up to `w`
/// records, or a stall with a reason.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StepOut<T> {
    Chunk(Vec<T>),
    StallInput,
    Done,
}

/// Cycle-level behaviour: one `select` per clock. The engine adds the
/// pipeline latency and measures stalls/throughput.
pub trait CycleMerger<T: Item> {
    fn w(&self) -> usize;
    /// pipeline latency in cycles (selection → output)
    fn latency(&self) -> usize;
    /// cycles consumed per selection (1 for the feedback-less designs;
    /// `feedback_len` for basic/PMT whose loop cannot be pipelined)
    fn cycles_per_select(&self) -> usize {
        1
    }
    fn select(&mut self, qa: &mut BankedFifo<T>, qb: &mut BankedFifo<T>) -> StepOut<T>;
}

// ------------------------------------------------------------- FLiMS

/// Lane state for the FLiMS selector.
#[derive(Clone, Copy, Debug)]
struct Slot<T> {
    item: T,
    real: bool,
}

/// FLiMS / FLiMS-skew cycle model (paper algorithms 1 & 2).
pub struct FlimsCycle<T> {
    w: usize,
    latency: usize,
    skew: bool,
    c_a: Vec<Option<Slot<T>>>, // None = register empty, must load
    c_b: Vec<Option<Slot<T>>>,
    dir: Vec<bool>,
    emitted: usize,
    total_hint: Option<usize>,
}

impl<T: Item> FlimsCycle<T> {
    pub fn new(w: usize, skew: bool) -> Self {
        let latency = crate::hw::analytical::log2(w) + 1;
        FlimsCycle {
            w,
            latency,
            skew,
            c_a: vec![None; w],
            c_b: vec![None; w],
            dir: vec![false; w],
            emitted: 0,
            total_hint: None,
        }
    }

    /// Try to fill empty lane registers from the FIFOs / end-of-stream.
    fn load(&mut self, qa: &mut BankedFifo<T>, qb: &mut BankedFifo<T>) -> bool {
        let w = self.w;
        let mut ok = true;
        for i in 0..w {
            if self.c_a[i].is_none() {
                if let Some(x) = qa.pop(i) {
                    self.c_a[i] = Some(Slot { item: x, real: true });
                } else if qa.ended {
                    self.c_a[i] = Some(Slot { item: T::sentinel(), real: false });
                } else {
                    ok = false;
                }
            }
            if self.c_b[i].is_none() {
                let bank = w - 1 - i;
                if let Some(x) = qb.pop(bank) {
                    self.c_b[i] = Some(Slot { item: x, real: true });
                } else if qb.ended {
                    self.c_b[i] = Some(Slot { item: T::sentinel(), real: false });
                } else {
                    ok = false;
                }
            }
        }
        ok
    }
}

impl<T: Item> CycleMerger<T> for FlimsCycle<T> {
    fn w(&self) -> usize {
        self.w
    }
    fn latency(&self) -> usize {
        self.latency
    }

    fn select(&mut self, qa: &mut BankedFifo<T>, qb: &mut BankedFifo<T>) -> StepOut<T> {
        if !self.load(qa, qb) {
            return StepOut::StallInput;
        }
        let w = self.w;
        // All real work done and registers hold only pads → done.
        if self.c_a.iter().chain(self.c_b.iter()).all(|s| !s.unwrap().real) {
            return StepOut::Done;
        }
        let mut chosen: Vec<Slot<T>> = Vec::with_capacity(w);
        for i in 0..w {
            let (ca, cb) = (self.c_a[i].unwrap(), self.c_b[i].unwrap());
            let gt = match ca.item.key().cmp(&cb.item.key()) {
                std::cmp::Ordering::Greater => true,
                std::cmp::Ordering::Less => false,
                std::cmp::Ordering::Equal => {
                    if ca.real != cb.real {
                        ca.real
                    } else if self.skew {
                        self.dir[i] // algorithm 2 oscillation
                    } else {
                        false // algorithm 1: ties take B
                    }
                }
            };
            if gt {
                chosen.push(ca);
                self.c_a[i] = None; // dequeued: reload next cycle
                self.dir[i] = false;
            } else {
                chosen.push(cb);
                self.c_b[i] = None;
                self.dir[i] = true;
            }
        }
        // CAS network (combinational within the pipeline).
        let mut stride = w / 2;
        while stride >= 1 {
            let mut g = 0;
            while g < w {
                for i in g..g + stride {
                    let (a, b) = (chosen[i], chosen[i + stride]);
                    let swap = match b.item.key().cmp(&a.item.key()) {
                        std::cmp::Ordering::Greater => true,
                        std::cmp::Ordering::Less => false,
                        std::cmp::Ordering::Equal => b.real && !a.real,
                    };
                    if swap {
                        chosen.swap(i, i + stride);
                    }
                }
                g += 2 * stride;
            }
            stride /= 2;
        }
        let out: Vec<T> = chosen.iter().filter(|s| s.real).map(|s| s.item).collect();
        self.emitted += out.len();
        let _ = self.total_hint;
        StepOut::Chunk(out)
    }
}

// ---------------------------------------------------- row-dequeue class

/// Which published design this row-merger instance stands for (affects
/// latency and the tie-record behaviour flag only — the dequeue
/// architecture is common to the class, figs. 6–7).
#[derive(Clone, Copy, Debug)]
pub enum RowClass {
    Mms,
    Vms,
    Wms,
}

/// MMS/VMS/WMS-style feedback-less row merger.
pub struct RowMergerCycle<T> {
    w: usize,
    latency: usize,
    /// reproduce the §6 tie-record corruption (true = no workaround)
    pub tie_unsafe: bool,
    carry: Vec<T>,
    carry_real: Vec<bool>,
    primed_a: bool,
    primed_b: bool,
}

impl<T: Item> RowMergerCycle<T> {
    pub fn new(w: usize, class: RowClass) -> Self {
        let lg = crate::hw::analytical::log2(w);
        let latency = match class {
            RowClass::Mms | RowClass::Vms => 2 * lg + 3,
            RowClass::Wms => lg + 3,
        };
        RowMergerCycle {
            w,
            latency,
            tie_unsafe: true,
            carry: vec![T::sentinel(); w],
            carry_real: vec![false; w],
            primed_a: false,
            primed_b: false,
        }
    }

    fn take_row(q: &mut BankedFifo<T>, w: usize) -> Option<(Vec<T>, Vec<bool>)> {
        if q.row_available() {
            let row = q.pop_row().unwrap();
            let real = vec![true; w];
            Some((row, real))
        } else if q.ended {
            // Partial final row: drain what exists, pad the rest.
            let mut row = Vec::with_capacity(w);
            let mut real = Vec::with_capacity(w);
            for i in 0..w {
                match q.pop(i) {
                    Some(x) => {
                        row.push(x);
                        real.push(true);
                    }
                    None => {
                        row.push(T::sentinel());
                        real.push(false);
                    }
                }
            }
            Some((row, real))
        } else {
            None
        }
    }
}

impl<T: Item> CycleMerger<T> for RowMergerCycle<T> {
    fn w(&self) -> usize {
        self.w
    }
    fn latency(&self) -> usize {
        self.latency
    }

    fn select(&mut self, qa: &mut BankedFifo<T>, qb: &mut BankedFifo<T>) -> StepOut<T> {
        let w = self.w;
        // Prime the carry with the first row of A (fig. 6: the merger
        // starts once both streams present a row).
        if !self.primed_a {
            match Self::take_row(qa, w) {
                Some((row, real)) => {
                    self.carry = row;
                    self.carry_real = real;
                    self.primed_a = true;
                }
                None => return StepOut::StallInput,
            }
        }
        if !self.primed_b && !qb.row_available() && !qb.ended {
            return StepOut::StallInput;
        }
        self.primed_b = true;

        // Everything drained and carry empty → done.
        let carry_live = self.carry_real.iter().any(|&r| r);
        if qa.exhausted() && qb.exhausted() && !carry_live {
            return StepOut::Done;
        }

        // Row choice: the side whose bank-0 head is larger feeds next
        // (the single head comparison of figs. 4/6).
        let head_a = qa.head(0).map(|x| x.key());
        let head_b = qb.head(0).map(|x| x.key());
        let from_a = match (head_a, head_b) {
            (Some(a), Some(b)) => a > b,
            (Some(_), None) => {
                if !qb.ended {
                    return StepOut::StallInput;
                }
                true
            }
            (None, Some(_)) => {
                if !qa.ended {
                    return StepOut::StallInput;
                }
                false
            }
            (None, None) => {
                if !(qa.ended && qb.ended) {
                    return StepOut::StallInput;
                }
                // Only the carry remains.
                let mut pairs: Vec<(T, bool)> = self
                    .carry
                    .iter()
                    .copied()
                    .zip(self.carry_real.iter().copied())
                    .collect();
                pairs.sort_by(|x, y| y.0.key().cmp(&x.0.key()).then(y.1.cmp(&x.1)));
                let out: Vec<T> =
                    pairs.iter().filter(|(_, r)| *r).map(|(x, _)| *x).collect();
                self.carry_real = vec![false; w];
                return if out.is_empty() { StepOut::Done } else { StepOut::Chunk(out) };
            }
        };
        let (row, row_real) = match Self::take_row(if from_a { qa } else { qb }, w) {
            Some(r) => r,
            None => return StepOut::StallInput,
        };

        // Candidate set: carry ∪ row (2w records). The published designs
        // compute the OUTPUT (top w) and the NEW CARRY (bottom w) through
        // two independent unstable merge networks. With unique keys both
        // agree; with duplicate keys crossing the boundary they may not —
        // the tie-record issue (§6). We reproduce exactly that: the top
        // half is selected preferring carry-side on ties, the bottom half
        // preferring row-side, so a tied record can be kept twice or
        // dropped.
        let mut cand: Vec<(T, bool, bool)> = Vec::with_capacity(2 * w); // (item, real, from_carry)
        for i in 0..w {
            cand.push((self.carry[i], self.carry_real[i], true));
        }
        for i in 0..w {
            cand.push((row[i], row_real[i], false));
        }

        let top = {
            let mut v = cand.clone();
            // order 1: ties prefer carry
            v.sort_by(|x, y| {
                y.0.key()
                    .cmp(&x.0.key())
                    .then(y.1.cmp(&x.1))
                    .then(y.2.cmp(&x.2))
            });
            v.truncate(w);
            v
        };
        let bottom = if self.tie_unsafe {
            let mut v = cand;
            // order 2: ties prefer row — independent recomputation, the
            // corruption source
            v.sort_by(|x, y| {
                y.0.key()
                    .cmp(&x.0.key())
                    .then(y.1.cmp(&x.1))
                    .then(x.2.cmp(&y.2))
            });
            v.split_off(w)
        } else {
            // Workaround enabled: single consistent order.
            let mut v = cand;
            v.sort_by(|x, y| {
                y.0.key()
                    .cmp(&x.0.key())
                    .then(y.1.cmp(&x.1))
                    .then(y.2.cmp(&x.2))
            });
            v.split_off(w)
        };

        for (i, (item, real, _)) in bottom.into_iter().enumerate() {
            self.carry[i] = item;
            self.carry_real[i] = real;
        }
        let out: Vec<T> = top.iter().filter(|(_, r, _)| *r).map(|(x, _, _)| *x).collect();
        StepOut::Chunk(out)
    }
}

// ----------------------------------------------------------- basic loop

/// Chhugani/Casper basic merger: functionally the row class with the
/// consistent order (no tie issue), but its feedback spans the whole
/// network — `cycles_per_select` = feedback length (Table 2 row 1).
pub struct BasicCycle<T> {
    inner: RowMergerCycle<T>,
    feedback: usize,
}

impl<T: Item> BasicCycle<T> {
    pub fn new(w: usize) -> Self {
        let mut inner = RowMergerCycle::new(w, RowClass::Wms);
        inner.tie_unsafe = false;
        let lg = crate::hw::analytical::log2(w);
        BasicCycle { inner, feedback: lg + 2 }
    }
}

impl<T: Item> CycleMerger<T> for BasicCycle<T> {
    fn w(&self) -> usize {
        self.inner.w
    }
    fn latency(&self) -> usize {
        self.feedback
    }
    fn cycles_per_select(&self) -> usize {
        // The feedback loop cannot accept a new selection until the
        // previous result returns: throughput = w / feedback_len.
        self.feedback
    }
    fn select(&mut self, qa: &mut BankedFifo<T>, qb: &mut BankedFifo<T>) -> StepOut<T> {
        self.inner.select(qa, qb)
    }
}

// ----------------------------------------------------------- FLiMSj

/// FLiMSj cycle model: FLiMS selection logic, whole-row input
/// granularity (a lane stalls until its entire row is present), one
/// extra pipeline stage.
pub struct FlimsjCycle<T> {
    inner: FlimsCycle<T>,
}

impl<T: Item> FlimsjCycle<T> {
    pub fn new(w: usize) -> Self {
        FlimsjCycle { inner: FlimsCycle::new(w, false) }
    }
}

impl<T: Item> CycleMerger<T> for FlimsjCycle<T> {
    fn w(&self) -> usize {
        self.inner.w
    }
    fn latency(&self) -> usize {
        self.inner.latency + 1
    }
    fn select(&mut self, qa: &mut BankedFifo<T>, qb: &mut BankedFifo<T>) -> StepOut<T> {
        // Whole-row dequeue: refuse to start a cycle that would dequeue
        // from a partially-filled row unless the stream has ended.
        let needs_a = self.inner.c_a.iter().any(|s| s.is_none());
        let needs_b = self.inner.c_b.iter().any(|s| s.is_none());
        if (needs_a && !qa.row_available() && !qa.ended && qa.len() > 0)
            || (needs_b && !qb.row_available() && !qb.ended && qb.len() > 0)
        {
            return StepOut::StallInput;
        }
        self.inner.select(qa, qb)
    }
}
