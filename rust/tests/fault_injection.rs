//! Determinism properties of the seeded fault-injection plan
//! (`rust/src/fault`): the same seed + plan injects the identical
//! fault sequence, recovery is transparent (output bytes identical to
//! a fault-free sort), and a zero-rate plan never fires.
//!
//! The fault counters are process-wide, so every test reading them
//! serializes on a file-local mutex — this binary owns its process,
//! and within it only one counter-sensitive sort runs at a time.

use std::sync::Mutex;

use flims::external::{self, ExternalConfig};
use flims::fault::{self, FaultSpec, KIND_ALL, KIND_STALL, KIND_TRANSIENT};

static LOCK: Mutex<()> = Mutex::new(());

fn dataset(n: usize) -> Vec<u32> {
    (0..n as u32).map(|i| i.wrapping_mul(2654435761)).collect()
}

/// A config that really spills (tiny budget), with the fault plan
/// pinned explicitly — the `FLIMS_FAULTS` CI lane must not leak its
/// own plan into these measurements.
fn cfg(threads: usize, overlap: bool, fault: Option<FaultSpec>) -> ExternalConfig {
    let mut c = ExternalConfig::default();
    c.mem_budget_bytes = 4096;
    c.threads = threads;
    c.overlap = overlap;
    c.fault = fault;
    c
}

/// The tentpole property: for every scheduling shape, a survivable
/// fault plan (transient + stall) recovers to output bytes identical
/// to the fault-free sort; and wherever the spill-file numbering is
/// deterministic (the batch schedule — writers are created in group
/// order for any worker count), repeating the sort injects *exactly*
/// the same number of faults and retries, for every thread count.
///
/// The pipelined schedule assigns intermediate run numbers in event
/// arrival order, which legitimately varies with thread timing — there
/// the guarantee under test is recovery byte-identity, not the count.
#[test]
fn same_seed_same_plan_is_deterministic_and_byte_identical() {
    let _g = LOCK.lock().unwrap();
    let data = dataset(30_000);
    let plan =
        Some(FaultSpec { seed: 7, rate_ppm: 20_000, kinds: KIND_TRANSIENT | KIND_STALL });

    let (reference, stats) = external::sort_vec(&data, &cfg(2, false, None)).unwrap();
    assert!(stats.runs_spilled > 1, "the dataset must really spill");

    // One (faults_injected, io_retries) signature for the whole batch
    // family: identical across repeats AND across thread counts.
    let mut batch_sig: Option<(u64, u64)> = None;
    for threads in [1usize, 2, 8] {
        for overlap in [false, true] {
            let c = cfg(threads, overlap, plan);
            let mut deltas = Vec::new();
            for repeat in 0..2 {
                let before = (fault::faults_injected(), fault::io_retries());
                let (out, _) = external::sort_vec(&data, &c).unwrap();
                deltas.push((
                    fault::faults_injected() - before.0,
                    fault::io_retries() - before.1,
                ));
                assert_eq!(
                    out, reference,
                    "threads={threads} overlap={overlap} repeat={repeat}: \
                     injected faults must recover to the fault-free bytes"
                );
            }
            if !overlap {
                assert_eq!(
                    deltas[0], deltas[1],
                    "threads={threads}: same seed + plan must inject the identical \
                     fault sequence on repeat"
                );
                match batch_sig {
                    None => batch_sig = Some(deltas[0]),
                    Some(sig) => assert_eq!(
                        deltas[0], sig,
                        "threads={threads}: batch-schedule fault counts must not \
                         depend on the worker count"
                    ),
                }
            }
        }
    }
    let sig = batch_sig.unwrap();
    assert!(sig.0 > 0, "the plan must actually fire (got {sig:?})");
    assert!(sig.1 > 0, "transient faults must be recovered via retries (got {sig:?})");
}

/// A zero-rate plan is armed but silent: no faults, no retries, and
/// the output bytes match the fault-free sort exactly.
#[test]
fn zero_rate_plan_injects_nothing() {
    let _g = LOCK.lock().unwrap();
    let data = dataset(20_000);
    let (reference, _) = external::sort_vec(&data, &cfg(2, false, None)).unwrap();

    let plan = Some(FaultSpec { seed: 1, rate_ppm: 0, kinds: KIND_ALL });
    let before = fault::faults_injected();
    let (out, _) = external::sort_vec(&data, &cfg(2, false, plan)).unwrap();
    assert_eq!(out, reference);
    assert_eq!(fault::faults_injected(), before, "a zero rate must never fire");
}
