//! Integration: the pipelined (overlapped) external-sort schedule.
//!
//! The contract under test: `overlap = on` changes *when* work happens
//! — group merges fire while later runs still spill — but never *what*
//! comes out. The determinism suite pins byte-identical output across
//! overlap {on, off} × threads {1, 2, 8} × codec {raw, delta} on a
//! multi-pass workload (k ≫ fan_in), stability included (Kv payload
//! ties); the error tests pin clean cancellation — a phase-1 source
//! failure stops in-flight group merges, leaks no spill files, and
//! surfaces as one `err` line through the service.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use flims::baselines::std_sort_desc;
use flims::config::AppConfig;
use flims::coordinator::{BatcherConfig, Router, Service};
use flims::data::{gen_u32, Distribution};
use flims::external::format::{read_raw, write_raw};
use flims::external::{
    sort_file, sort_stream, sort_vec, Codec, ExternalConfig, RecordSource, SliceSource,
};
use flims::key::Kv;
use flims::util::rng::Rng;

fn test_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("flims-ovl-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// 4 KiB budget → 1024-element u32 runs; the workloads below spill
/// dozens of runs at fan-in 4, forcing ≥ 2 intermediate passes so the
/// pipeline has real mid-stream work to overlap.
fn multi_pass_cfg(tmp: &Path) -> ExternalConfig {
    ExternalConfig {
        mem_budget_bytes: 4096,
        fan_in: 4,
        tmp_dir: Some(tmp.to_path_buf()),
        ..Default::default()
    }
}

#[test]
fn overlap_determinism_across_threads_and_codecs() {
    // The acceptance matrix: overlap {off, on} × threads {1, 2, 8} ×
    // codec {raw, delta} must produce one identical output file.
    let dir = test_dir("det");
    let mut rng = Rng::new(7001);
    let n = 120_000usize; // ≈ 117 runs at 1024/run → 3 intermediate passes
    let data = gen_u32(&mut rng, n, Distribution::Zipf { s_x100: 130, n_ranks: 1 << 12 });
    let input = dir.join("det.u32");
    write_raw(&input, &data).unwrap();

    let mut expect = data;
    std_sort_desc(&mut expect);
    let expect_bytes: Vec<u8> = expect.iter().flat_map(|x| x.to_le_bytes()).collect();

    let mut baseline: Option<(u64, u64, Vec<u8>)> = None;
    for overlap in [false, true] {
        for threads in [1usize, 2, 8] {
            for codec in [Codec::Raw, Codec::Delta] {
                let output = dir.join(format!(
                    "det.sorted.o{overlap}.t{threads}.{}",
                    codec.name()
                ));
                let cfg = ExternalConfig {
                    overlap,
                    threads,
                    codec,
                    ..multi_pass_cfg(&dir)
                };
                let stats = sort_file::<u32>(&input, &output, &cfg).unwrap();
                let tag = format!("overlap={overlap} threads={threads} codec={:?}", codec);
                assert_eq!(stats.elements, n as u64, "{tag}");
                assert!(stats.merge_passes >= 3, "{tag}: {}", stats.merge_passes);
                let bytes = std::fs::read(&output).unwrap();
                assert_eq!(bytes, expect_bytes, "{tag}: output differs from std sort");
                // Spill layout is schedule-invariant too (same chunked
                // plan): runs and passes match the serial raw baseline;
                // encoded bytes match within the same codec.
                match &baseline {
                    None => baseline = Some((stats.runs_spilled, stats.merge_passes, bytes)),
                    Some((runs, passes, base_bytes)) => {
                        assert_eq!(stats.runs_spilled, *runs, "{tag}");
                        assert_eq!(stats.merge_passes, *passes, "{tag}");
                        assert_eq!(&bytes, base_bytes, "{tag}");
                    }
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn overlap_keeps_kv_stability() {
    // The §6 tie-record guarantee must survive the pipeline: payload =
    // input index over a tiny key alphabet, compared against std's
    // stable sort — overlapped, parallel, multi-pass.
    let dir = test_dir("kv");
    let mut rng = Rng::new(7002);
    let n = 60_000usize;
    let recs: Vec<Kv> = (0..n).map(|i| Kv::new(rng.below(9) as u32, i as u32)).collect();

    let mut expect = recs.clone();
    expect.sort_by(|a, b| b.key.cmp(&a.key)); // std stable sort

    for threads in [1usize, 4] {
        let cfg = ExternalConfig {
            overlap: true,
            threads,
            mem_budget_bytes: 8192, // 1024-record Kv runs
            fan_in: 4,
            tmp_dir: Some(dir.clone()),
            ..Default::default()
        };
        let (got, stats) = sort_vec(&recs, &cfg).unwrap();
        assert_eq!(stats.elements, n as u64);
        assert!(stats.merge_passes >= 3, "threads={threads}");
        assert_eq!(got, expect, "threads={threads}: pipeline broke stability");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn overlap_reports_concurrent_phase_time() {
    // Sanity on the new stats: wall is measured, and the accounting
    // identity overlap_us = phase1 + phase2 − wall holds.
    let dir = test_dir("stats");
    let mut rng = Rng::new(7003);
    let data = gen_u32(&mut rng, 100_000, Distribution::Uniform);
    let cfg = ExternalConfig { overlap: true, threads: 2, ..multi_pass_cfg(&dir) };
    let (_, stats) = sort_vec(&data, &cfg).unwrap();
    assert!(stats.wall_us > 0);
    assert_eq!(
        stats.overlap_us,
        (stats.phase1_us + stats.phase2_us).saturating_sub(stats.wall_us)
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A source that feeds a few runs' worth of data, then fails — while
/// the pipeline already has group merges in flight.
struct FailingSource {
    fed: usize,
    fail_at: usize,
}

impl RecordSource<u32> for FailingSource {
    fn read_block(&mut self, out: &mut Vec<u32>, max: usize) -> Result<usize> {
        if self.fed >= self.fail_at {
            anyhow::bail!("simulated phase-1 I/O failure");
        }
        let take = max.min(512);
        out.extend((0..take).map(|i| ((self.fed + i) as u32).wrapping_mul(2654435761)));
        self.fed += take;
        Ok(take)
    }
}

#[test]
fn phase1_error_cancels_inflight_merges_without_leaks() {
    // 40+ runs spill (several groups already merged or merging) before
    // the source dies. The error must surface verbatim, and the spill
    // dir must be empty afterwards — in-flight group outputs swept,
    // registered runs reclaimed by the manager.
    let dir = test_dir("cancel");
    for threads in [1usize, 4] {
        let cfg = ExternalConfig {
            overlap: true,
            threads,
            mem_budget_bytes: 4096,
            fan_in: 4,
            tmp_dir: Some(dir.clone()),
            ..Default::default()
        };
        let mut src = FailingSource { fed: 0, fail_at: 45_000 };
        let mut sink: Vec<u32> = Vec::new();
        let err = format!("{:#}", sort_stream(&mut src, &mut sink, &cfg).unwrap_err());
        assert!(err.contains("simulated phase-1 I/O failure"), "threads={threads}: {err}");
        let leftovers: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert!(
            leftovers.is_empty(),
            "threads={threads}: spill files leaked after cancel: {leftovers:?}"
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn overlap_disk_budget_still_enforced() {
    // The cap must hold while both phases run concurrently: a budget
    // far below the dataset errors cleanly (whichever side trips it
    // first) and leaks nothing.
    let dir = test_dir("budget");
    for threads in [1usize, 4] {
        let cfg = ExternalConfig {
            overlap: true,
            threads,
            mem_budget_bytes: 4096,
            fan_in: 4,
            disk_budget_bytes: Some(16 << 10), // a few runs fit; the sort cannot
            tmp_dir: Some(dir.clone()),
            ..Default::default()
        };
        let mut rng = Rng::new(7004);
        let data = gen_u32(&mut rng, 50_000, Distribution::Uniform);
        let err = format!("{:#}", sort_vec(&data, &cfg).unwrap_err());
        assert!(err.contains("disk budget exceeded"), "threads={threads}: {err}");
        let leftovers: Vec<_> = std::fs::read_dir(&dir).unwrap().collect();
        assert!(
            leftovers.is_empty(),
            "threads={threads}: budget abort leaked spill: {leftovers:?}"
        );
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn overlap_errors_are_one_err_line_through_the_service() {
    // An overlapped sortfile that fails (missing input; output path
    // squatted by a directory) answers exactly one `err` line and the
    // connection logic stays usable — no partial replies, no hang.
    let dir = test_dir("svc");
    let mut app = AppConfig::default();
    // u32 fixtures, no dtype= in the requests: pin against FLIMS_DTYPE.
    app.external.dtype = flims::external::Dtype::U32;
    app.external.mem_budget_bytes = 4096;
    app.external.overlap = true;
    app.external.threads = 2;
    app.external.tmp_dir = Some(dir.clone());
    let router = Arc::new(Router::new(app, None));
    let service = Service::new(
        router,
        BatcherConfig { max_batch: 2, window: Duration::from_micros(1) },
    );

    let resp = service.handle_line("sortfile external /nonexistent/nope.u32 overlap=on");
    assert!(resp.starts_with("err "), "{resp}");
    assert!(!resp.contains('\n'), "must stay one line: {resp:?}");

    let input = dir.join("blocked.u32");
    write_raw(&input, &(0..10_000u32).rev().collect::<Vec<_>>()).unwrap();
    std::fs::create_dir_all(dir.join("blocked.u32.sorted")).unwrap();
    let resp = service.handle_line(&format!("sortfile external {}", input.display()));
    assert!(resp.starts_with("err "), "{resp}");
    assert!(!resp.contains('\n'), "must stay one line: {resp:?}");

    // No spill leftovers from the failed overlapped request (only the
    // test's own fixtures remain), and the service still answers.
    let leftovers: Vec<String> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
        .filter(|name| name.starts_with("run-"))
        .collect();
    assert!(leftovers.is_empty(), "leaked spill runs: {leftovers:?}");
    assert_eq!(service.handle_line("sort native 2 1 3"), "ok 3 2 1");
    assert_eq!(service.router.metrics.errors.get(), 2);

    // And a working overlapped request still goes through end to end.
    let good = dir.join("good.u32");
    let data: Vec<u32> = (0..30_000u32).map(|i| i.wrapping_mul(2246822519)).collect();
    write_raw(&good, &data).unwrap();
    let resp = service.handle_line(&format!("sortfile external {} overlap=on", good.display()));
    assert_eq!(resp, format!("ok 30000 {}.sorted", good.display()));
    let mut expect = data;
    std_sort_desc(&mut expect);
    assert_eq!(
        read_raw::<u32>(Path::new(&format!("{}.sorted", good.display()))).unwrap(),
        expect
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn overlap_handles_single_run_and_empty_inputs() {
    // Degenerate pipeline shapes: zero runs (empty input) and a single
    // run (final pass only, no intermediate stage ever fires).
    let cfg = ExternalConfig { overlap: true, mem_budget_bytes: 4096, ..Default::default() };
    let mut src = SliceSource::new(&[] as &[u32]);
    let mut sink: Vec<u32> = Vec::new();
    let stats = sort_stream(&mut src, &mut sink, &cfg).unwrap();
    assert!(sink.is_empty());
    assert_eq!(stats.elements, 0);
    assert_eq!(stats.merge_passes, 0);
    assert_eq!(stats.runs_spilled, 0);

    // Force the spill path (bypass sort_vec's single-run fast path) by
    // calling sort_stream directly on a 2-run input.
    let data: Vec<u32> = (0..1500).collect();
    let mut src = SliceSource::new(&data);
    let mut sink: Vec<u32> = Vec::new();
    let stats = sort_stream(&mut src, &mut sink, &cfg).unwrap();
    assert_eq!(stats.elements, 1500);
    assert_eq!(stats.merge_passes, 1, "2 runs ≤ fan_in: final pass only");
    let mut expect = data;
    std_sort_desc(&mut expect);
    assert_eq!(sink, expect);
}
