//! Allocation regression test for `merge_asc`: it used to clone both
//! inputs into reversed temporaries (two O(n) allocations) before
//! merging. It now merges through reversed *views* and reverses only
//! the output, in place — so the bytes allocated per call must stay
//! within the output buffer plus small O(w) lane state, never scale
//! with 2× the input again.
//!
//! Measured with a counting global allocator; this lives in its own
//! integration-test binary so the counter sees only this file's tests.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCATED_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn merge_asc_allocates_only_the_output() {
    const N: usize = 1 << 16; // per side
    let w = 16usize;
    let a: Vec<u32> = (0..N as u32).map(|x| x.wrapping_mul(7)).collect();
    let b: Vec<u32> = (0..N as u32).map(|x| x.wrapping_mul(13)).collect();
    let mut a = a;
    let mut b = b;
    a.sort_unstable();
    b.sort_unstable();

    // Warm up once (lazy runtime allocations, kernel detection, &c.).
    let warm = flims::merge_asc(&a, &b, w);
    assert_eq!(warm.len(), 2 * N);

    let before = ALLOCATED_BYTES.load(Ordering::Relaxed);
    let out = flims::merge_asc(&a, &b, w);
    let delta = ALLOCATED_BYTES.load(Ordering::Relaxed) - before;
    assert_eq!(out.len(), 2 * N);
    assert!(out.windows(2).all(|p| p[0] <= p[1]), "output must be ascending");

    let output_bytes = (2 * N * std::mem::size_of::<u32>()) as u64;
    // Output buffer + O(w) lane state + slack. The old implementation
    // also cloned both inputs (another `output_bytes`), which this
    // bound rejects.
    let budget = output_bytes + 16 * 1024;
    assert!(
        delta <= budget,
        "merge_asc allocated {delta} bytes for a {output_bytes}-byte output \
         (budget {budget}) — did the reversed-copy regression return?"
    );
}
