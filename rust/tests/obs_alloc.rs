//! Zero-overhead guarantee for disabled tracing: every sort carries a
//! [`flims::obs::Trace`] handle, so the disabled handle must cost
//! nothing — no clock reads (checked in obs unit tests) and, here, no
//! heap traffic on any hot-path operation. A disabled trace that
//! allocates would tax every untraced sort.
//!
//! Measured with a counting global allocator; this lives in its own
//! integration-test binary so the counter sees only this file's tests.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use flims::obs::{SpanKind, Trace};

struct CountingAlloc;

static ALLOCATED_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn disabled_trace_never_touches_the_heap() {
    let trace = Trace::disabled();
    let clone = trace.clone();
    let start = Instant::now();

    // Warm up once (lane thread-local &c. — none should exist on the
    // disabled path, but the measurement must not depend on that).
    trace.end(SpanKind::ChunkSort, trace.begin(), 1);

    let before = ALLOCATED_BYTES.load(Ordering::Relaxed);
    for kind in SpanKind::ALL {
        for i in 0..10_000u64 {
            let t = trace.begin();
            assert!(t.is_none(), "disabled trace must skip the clock");
            trace.end(kind, t, i);
            trace.record_dur(kind, start, i, i);
            clone.end(kind, clone.begin(), i);
        }
    }
    assert!(!trace.is_enabled());
    assert_eq!(trace.recorded(), 0);
    assert_eq!(trace.dropped(), 0);
    assert!(trace.spans().is_empty());
    let delta = ALLOCATED_BYTES.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "disabled tracing allocated {delta} bytes across \
         {} hot-path calls — it must be free",
        SpanKind::ALL.len() * 10_000 * 3
    );
}

#[test]
fn enabled_trace_records_without_reallocating_the_ring() {
    // The enabled ring is allocated once up front; steady-state
    // recording must not grow it (the final `spans()` drain may copy).
    let trace = Trace::with_capacity(1024);
    let start = Instant::now();
    trace.record_dur(SpanKind::GroupMerge, start, 10, 1); // warmup + lane init

    let before = ALLOCATED_BYTES.load(Ordering::Relaxed);
    for i in 0..100_000u64 {
        trace.record_dur(SpanKind::GroupMerge, start, 10, i);
    }
    let delta = ALLOCATED_BYTES.load(Ordering::Relaxed) - before;
    assert_eq!(delta, 0, "steady-state recording allocated {delta} bytes");
    assert_eq!(trace.recorded(), 1024, "ring keeps the newest capacity-many spans");
    assert_eq!(trace.dropped(), 100_001 - 1024);
}
